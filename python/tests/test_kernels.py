"""L1 correctness: pallas kernels vs the pure-jnp oracle (ref.py).

The hypothesis sweep is the paper-mandated contract: for arbitrary valid
(N, C, V, K, M) geometry and value distributions, the fused pallas kernel
must agree with the reference bit-for-bit on indices and to float tolerance
on outputs.
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import lut_amm, ref

hypothesis.settings.register_profile(
    "ci", max_examples=25, deadline=None,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("ci")


def make_case(seed, n, c, v, k, m, scale=1.0):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(scale=scale, size=(n, c * v)), jnp.float32)
    b = jnp.asarray(rng.normal(scale=scale, size=(c * v, m)), jnp.float32)
    p = jnp.asarray(rng.normal(scale=scale, size=(c, k, v)), jnp.float32)
    t = ref.build_table_ref(p, b)
    return a, b, p, t


class TestOracleInternals:
    def test_distances_match_naive(self):
        a, _, p, _ = make_case(0, 13, 3, 5, 7, 4)
        d = ref.distances_ref(a, p)
        sub = np.asarray(ref.split_subvectors(a, 3))
        pn = np.asarray(p)
        naive = np.zeros((13, 3, 7), np.float32)
        for n in range(13):
            for c in range(3):
                for k in range(7):
                    naive[n, c, k] = np.sum((sub[n, c] - pn[c, k]) ** 2)
        np.testing.assert_allclose(np.asarray(d), naive, rtol=1e-4, atol=1e-4)

    def test_table_matches_naive(self):
        _, b, p, t = make_case(1, 4, 3, 5, 7, 6)
        bn = np.asarray(b)
        pn = np.asarray(p)
        for c in range(3):
            for k in range(7):
                np.testing.assert_allclose(
                    np.asarray(t)[c, k],
                    pn[c, k] @ bn[c * 5:(c + 1) * 5],
                    rtol=1e-4, atol=1e-5)

    def test_exact_when_input_is_centroid(self):
        """If every sub-vector IS a centroid, AMM must equal exact MM."""
        rng = np.random.default_rng(2)
        c, k, v, m = 4, 8, 3, 10
        p = jnp.asarray(rng.normal(size=(c, k, v)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(c * v, m)), jnp.float32)
        choice = rng.integers(k, size=(16, c))
        a = jnp.asarray(
            np.stack([np.concatenate([p[ci, choice[n, ci]]
                                      for ci in range(c)])
                      for n in range(16)]), jnp.float32)
        t = ref.build_table_ref(p, b)
        np.testing.assert_allclose(
            np.asarray(ref.lut_amm_ref(a, p, t)),
            np.asarray(ref.dense_ref(a, b)), rtol=1e-3, atol=1e-3)

    def test_quantize_table_ranges(self):
        _, _, _, t = make_case(3, 4, 3, 5, 7, 6)
        for bits in (8, 4):
            q, s = ref.quantize_table_ref(t, bits)
            qmax = 2 ** (bits - 1) - 1
            assert int(jnp.max(q)) <= qmax
            assert int(jnp.min(q)) >= -qmax - 1
            deq = np.asarray(q, np.float32) * np.asarray(s)[:, None, None]
            err = np.abs(deq - np.asarray(t)).max()
            step = np.asarray(s).max()
            assert err <= step * 0.501 + 1e-6

    def test_quantize_zero_table(self):
        q, s = ref.quantize_table_ref(jnp.zeros((2, 4, 3)), 8)
        assert np.all(np.asarray(q) == 0)
        assert np.all(np.asarray(s) == 1.0)


class TestPallasVsOracle:
    def test_fused_matches(self):
        a, _, p, t = make_case(10, 64, 8, 9, 16, 32)
        np.testing.assert_allclose(
            np.asarray(lut_amm.lut_amm(a, p, t, block_n=32)),
            np.asarray(ref.lut_amm_ref(a, p, t)), rtol=1e-4, atol=1e-4)

    def test_argmin_matches(self):
        a, _, p, _ = make_case(11, 100, 4, 4, 16, 8)
        idx_pl = lut_amm.dist_argmin(a, p, block_n=32)
        idx_ref = ref.encode_ref(a, p)
        assert bool(jnp.all(idx_pl == idx_ref))

    def test_quantized_matches(self):
        a, _, p, t = make_case(12, 48, 8, 9, 16, 24)
        q, s = ref.quantize_table_ref(t, 8)
        np.testing.assert_allclose(
            np.asarray(lut_amm.lut_amm_quantized(a, p, q, s, block_n=16)),
            np.asarray(ref.lut_amm_quantized_ref(a, p, q, s)),
            rtol=1e-4, atol=1e-4)

    def test_bias(self):
        a, _, p, t = make_case(13, 24, 4, 9, 8, 12)
        bias = jnp.arange(12, dtype=jnp.float32)
        np.testing.assert_allclose(
            np.asarray(lut_amm.lut_amm(a, p, t, bias, block_n=8)),
            np.asarray(ref.lut_amm_ref(a, p, t, bias)),
            rtol=1e-4, atol=1e-4)

    def test_row_padding(self):
        """N not divisible by block_n exercises the pad/unpad path."""
        a, _, p, t = make_case(14, 37, 4, 3, 8, 10)
        np.testing.assert_allclose(
            np.asarray(lut_amm.lut_amm(a, p, t, block_n=16)),
            np.asarray(ref.lut_amm_ref(a, p, t)), rtol=1e-4, atol=1e-4)

    @hypothesis.given(
        n=st.integers(1, 70),
        c=st.integers(1, 6),
        v=st.sampled_from([1, 2, 4, 9]),
        k=st.sampled_from([4, 8, 16]),
        m=st.integers(1, 40),
        seed=st.integers(0, 2 ** 16),
        scale=st.sampled_from([0.1, 1.0, 10.0]),
    )
    def test_fused_matches_property(self, n, c, v, k, m, seed, scale):
        a, _, p, t = make_case(seed, n, c, v, k, m, scale=scale)
        got = np.asarray(lut_amm.lut_amm(a, p, t, block_n=16))
        want = np.asarray(ref.lut_amm_ref(a, p, t))
        np.testing.assert_allclose(got, want,
                                   rtol=1e-3, atol=1e-3 * scale * scale)

    @hypothesis.given(
        n=st.integers(1, 64),
        c=st.integers(1, 4),
        v=st.sampled_from([2, 4, 9]),
        k=st.sampled_from([8, 16]),
        seed=st.integers(0, 2 ** 16),
    )
    def test_argmin_matches_property(self, n, c, v, k, seed):
        a, _, p, _ = make_case(seed, n, c, v, k, 4)
        assert bool(jnp.all(lut_amm.dist_argmin(a, p, block_n=16)
                            == ref.encode_ref(a, p)))


class TestVmemModel:
    def test_footprint_monotone_in_block(self):
        f1 = lut_amm.vmem_footprint_bytes(64, 64, 16, 9, 512)
        f2 = lut_amm.vmem_footprint_bytes(128, 64, 16, 9, 512)
        assert f2 > f1

    def test_pick_block_n_fits_budget(self):
        for (c, k, v, m) in [(64, 16, 9, 512), (512, 16, 4, 64),
                             (48, 16, 16, 3072)]:
            bn = lut_amm.pick_block_n(c, k, v, m)
            assert lut_amm.vmem_footprint_bytes(bn, c, k, v, m) <= 8 << 20 \
                or bn == 8

    def test_pick_block_n_default_shape(self):
        assert lut_amm.pick_block_n(64, 16, 9, 512) >= 128
