"""k-means / PQ codebook learning + MADDNESS baseline tests."""

import numpy as np
import pytest

from compile import maddness, pqkmeans


class TestKmeans:
    def test_recovers_separated_clusters(self):
        rng = np.random.default_rng(0)
        centers = np.array([[0, 0], [10, 0], [0, 10], [10, 10]], np.float32)
        x = np.concatenate([c + 0.1 * rng.standard_normal((50, 2))
                            for c in centers]).astype(np.float32)
        got, assign = pqkmeans.kmeans(x, 4, seed=1)
        # every true center has a learned centroid within 0.5
        for c in centers:
            assert np.min(np.linalg.norm(got - c, axis=1)) < 0.5
        assert len(np.unique(assign)) == 4

    def test_mse_not_worse_than_random_codebook(self):
        rng = np.random.default_rng(1)
        x = rng.standard_normal((512, 8)).astype(np.float32)
        learned = pqkmeans.learn_codebooks(x, 2, 16, seed=0)
        random_cb = rng.standard_normal(learned.shape).astype(np.float32)
        assert (pqkmeans.quantization_mse(x, learned)
                < pqkmeans.quantization_mse(x, random_cb))

    def test_more_centroids_lower_mse(self):
        rng = np.random.default_rng(2)
        x = rng.standard_normal((512, 8)).astype(np.float32)
        mses = [pqkmeans.quantization_mse(
            x, pqkmeans.learn_codebooks(x, 2, k, seed=0))
            for k in (2, 8, 32)]
        assert mses[0] > mses[1] > mses[2]

    def test_fewer_samples_than_centroids(self):
        rng = np.random.default_rng(3)
        x = rng.standard_normal((5, 4)).astype(np.float32)
        c, _ = pqkmeans.kmeans(x, 16, seed=0)
        assert c.shape == (16, 4)
        assert np.isfinite(c).all()

    def test_identical_points(self):
        x = np.ones((64, 4), np.float32)
        c, _ = pqkmeans.kmeans(x, 4, seed=0)
        assert np.isfinite(c).all()
        # all centroids should sit on (or extremely near) the single point
        assert np.abs(c - 1.0).max() < 1e-2

    def test_codebook_shape(self):
        rng = np.random.default_rng(4)
        x = rng.standard_normal((128, 36)).astype(np.float32)
        cb = pqkmeans.learn_codebooks(x, 4, 16, seed=0)
        assert cb.shape == (4, 16, 9)


class TestMaddness:
    def make_data(self, seed=0, n=512, d=12, m=8):
        rng = np.random.default_rng(seed)
        a = rng.standard_normal((n, d)).astype(np.float32)
        w = rng.standard_normal((d, m)).astype(np.float32)
        return a, w

    def test_tree_shapes(self):
        a, _ = self.make_data()
        tree = maddness.learn_hash_tree(a[:, :4], depth=4)
        assert tree.prototypes.shape == (16, 4)
        assert len(tree.split_dims) == 4

    def test_encode_range_and_determinism(self):
        a, _ = self.make_data(1)
        tree = maddness.learn_hash_tree(a[:, :4], depth=4)
        idx1 = maddness.encode_with_tree(a[:, :4], tree)
        idx2 = maddness.encode_with_tree(a[:, :4], tree)
        assert (idx1 == idx2).all()
        assert idx1.min() >= 0 and idx1.max() < 16

    def test_balanced_leaves(self):
        """Median splits must produce roughly balanced buckets."""
        a, _ = self.make_data(2, n=1024)
        tree = maddness.learn_hash_tree(a[:, :4], depth=4)
        idx = maddness.encode_with_tree(a[:, :4], tree)
        counts = np.bincount(idx, minlength=16)
        assert counts.max() < 1024 // 16 * 4

    def test_amm_better_than_zero_and_worse_than_exact(self):
        a, w = self.make_data(3)
        op = maddness.learn_maddness(a, w, None, n_codebooks=3, depth=4)
        approx = maddness.maddness_amm(a, op)
        exact = a @ w
        err = np.mean((approx - exact) ** 2)
        base = np.mean(exact ** 2)
        assert err < base          # captures signal
        assert err > 1e-6          # but is approximate

    def test_hashing_worse_than_kmeans_encoding(self):
        """Paper §2.1/Fig. 3: hashing has higher quantization error than
        k-means argmin encoding at equal K."""
        from compile import pqkmeans
        import jax.numpy as jnp
        from compile.kernels import ref

        a, w = self.make_data(4, n=1024)
        c = 3
        op = maddness.learn_maddness(a, w, None, n_codebooks=c, depth=4)
        cb = pqkmeans.learn_codebooks(a, c, 16, seed=0)
        table = ref.build_table_ref(jnp.asarray(cb), jnp.asarray(w))
        pq_out = np.asarray(ref.lut_amm_ref(jnp.asarray(a), jnp.asarray(cb),
                                            table))
        md_out = maddness.maddness_amm(a, op)
        exact = a @ w
        assert np.mean((md_out - exact) ** 2) > np.mean((pq_out - exact) ** 2)

    def test_bias_applied(self):
        a, w = self.make_data(5)
        bias = np.arange(8, dtype=np.float32)
        op = maddness.learn_maddness(a, w, bias, n_codebooks=3)
        op0 = maddness.MaddnessOp(op.trees, op.table, None)
        np.testing.assert_allclose(
            maddness.maddness_amm(a, op),
            maddness.maddness_amm(a, op0) + bias, rtol=1e-6)
