"""Training loop, optimizer, bundle export round-trip, AOT lowering."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import datasets, export, models, optim, softpq, train


class TestOptim:
    def test_adam_minimizes_quadratic(self):
        params = {"x": jnp.asarray([5.0, -3.0])}
        opt = optim.adam_init(params)
        for _ in range(400):
            g = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
            params, opt = optim.adam_update(g, opt, params, lr=0.1)
        assert float(jnp.abs(params["x"]).max()) < 1e-2

    def test_lr_scale_freezes_leaf(self):
        params = {"a": jnp.ones(3), "b": jnp.ones(3)}
        scale = {"a": 1.0, "b": 0.0}
        opt = optim.adam_init(params)
        g = {"a": jnp.ones(3), "b": jnp.ones(3)}
        new, _ = optim.adam_update(g, opt, params, lr=0.1, lr_scale=scale)
        assert not np.allclose(np.asarray(new["a"]), 1.0)
        np.testing.assert_allclose(np.asarray(new["b"]), 1.0)

    def test_cosine_schedule_endpoints(self):
        sched = optim.cosine_schedule(1.0, 100)
        assert float(sched(jnp.asarray(0))) == pytest.approx(1.0)
        assert float(sched(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-6)

    def test_grad_clip(self):
        params = {"x": jnp.zeros(2)}
        opt = optim.adam_init(params)
        g = {"x": jnp.asarray([1e6, 1e6])}
        new, _ = optim.adam_update(g, opt, params, lr=0.1, grad_clip=1.0)
        assert np.isfinite(np.asarray(new["x"])).all()


class TestTrainLoop:
    def test_loss_decreases_dense(self):
        x, y = datasets.synth_image(256, seed=0)
        model = models.VggTiny(widths=(4, 8))
        p, s = model.init(0)
        cfg = train.TrainConfig(steps=30, batch_size=32, lr=3e-3,
                                log_every=1)
        p, s = train.train_model(model, p, s, x, y, cfg)
        losses = [h["loss"] for h in cfg.history]
        assert losses[-1] < losses[0]

    def test_softpq_finetune_improves_over_kmeans_init(self):
        """The paper's core claim in miniature: soft-PQ fine-tuning beats
        vanilla-PQ conversion on the *model loss* (here: test accuracy)."""
        x, y = datasets.synth_image(768, seed=1)
        x_tr, y_tr, x_te, y_te = x[:640], y[:640], x[640:], y[640:]
        model = models.VggTiny(widths=(8, 8))
        p, s = model.init(0)
        cfg = train.TrainConfig(steps=120, batch_size=64, lr=3e-3)
        p, s = train.train_model(model, p, s, x_tr, y_tr, cfg)
        caps = train.capture_activations(model, p, s, x_tr[:256])
        lut0 = models.convert_model(model, p, caps, model.lut_layers(),
                                    n_centroids=8, kmeans_iters=8)
        acc_pq = train.evaluate(model, lut0, s, x_te, y_te, table_bits=8)
        ft = train.TrainConfig(steps=80, batch_size=64, lr=1e-3, log_every=1)
        lut1, s1 = train.train_model(model, lut0, s, x_tr, y_tr, ft)
        acc_ft = train.evaluate(model, lut1, s1, x_te, y_te, table_bits=8)
        # At smoke scale accuracies are noisy; the robust claims are that
        # (a) soft-PQ fine-tuning reduces the model loss through the STE
        # path and (b) the learned temperature actually moves. The full
        # accuracy reproduction is experiments/table4_accuracy.py.
        losses = [h["loss"] for h in ft.history]
        assert min(losses[-10:]) < losses[0]
        assert acc_ft > 0.0 and np.isfinite(acc_ft)
        t0 = float(jnp.exp(lut0["c1"].log_t))
        t1 = float(jnp.exp(lut1["c1"].log_t))
        assert t0 != pytest.approx(t1)

    def test_mse_vs_dense_positive(self):
        x, y = datasets.synth_image(128, seed=2)
        model = models.VggTiny(widths=(4, 8))
        p, s = model.init(0)
        caps = train.capture_activations(model, p, s, x[:64])
        lut = models.convert_model(model, p, caps, model.lut_layers(),
                                   n_centroids=8, kmeans_iters=3)
        mse = train.mse_vs_dense(model, p, lut, s, x[:32])
        assert mse > 0.0
        assert np.isfinite(mse)


class TestExport:
    def _trained_tiny(self):
        x, y = datasets.synth_image(128, seed=0)
        model = models.ResNetTiny(widths=(4, 8, 8))
        p, s = model.init(0)
        caps = train.capture_activations(model, p, s, x[:64])
        lut = models.convert_model(model, p, caps, model.lut_layers(),
                                   n_centroids=8, kmeans_iters=3)
        return model, p, lut, s, x

    def test_bundle_roundtrip(self, tmp_path):
        model, p, lut, s, x = self._trained_tiny()
        path = str(tmp_path / "m.lutnn")
        size = export.export_cnn(model, lut, s, path, name="t",
                                 input_shape=[1, 16, 16, 3])
        assert size == os.path.getsize(path)
        header, arrays = export.read_bundle(path)
        assert header["model"] == "t"
        # LUT layer blobs present and shaped correctly
        e = header["layers"]["b0c1"]
        assert e["kind"] == "lut"
        cent = arrays["b0c1"]["centroids"]
        assert cent.ndim == 3 and cent.shape[1] == 8
        tq = arrays["b0c1"]["table_q"]
        assert tq.dtype == np.int8
        assert np.abs(tq).max() <= 127
        # graph references only existing layers
        for op in header["graph"]:
            if "layer" in op:
                assert op["layer"] in header["layers"]

    def test_bundle_blob_alignment(self, tmp_path):
        model, p, lut, s, x = self._trained_tiny()
        path = str(tmp_path / "m.lutnn")
        export.export_cnn(model, lut, s, path, name="t",
                          input_shape=[1, 16, 16, 3])
        header, _ = export.read_bundle(path)
        for entry in header["layers"].values():
            for v in entry.values():
                if isinstance(v, dict) and "offset" in v:
                    assert v["offset"] % export.ALIGN == 0

    def test_bundle_dense_model(self, tmp_path):
        model, p, lut, s, x = self._trained_tiny()
        path = str(tmp_path / "d.lutnn")
        export.export_cnn(model, p, s, path, name="dense",
                          input_shape=[1, 16, 16, 3])
        header, arrays = export.read_bundle(path)
        assert header["layers"]["b0c1"]["kind"] == "dense"
        w = arrays["b0c1"]["w"]
        np.testing.assert_allclose(w, np.asarray(p["b0c1"]["w"]))

    def test_bert_bundle(self, tmp_path):
        model = models.MiniBert(n_layers=2)
        p, s = model.init(0)
        path = str(tmp_path / "b.lutnn")
        export.export_bert(model, p, path)
        header, arrays = export.read_bundle(path)
        assert header["meta"]["n_layers"] == 2
        assert "emb" in header["layers"]
        assert arrays["emb"]["tok"].shape == (64, 32)


class TestAotLowering:
    def test_lut_amm_op_lowers(self):
        from compile import aot

        txt = aot.lower_lut_amm_op(n=16, c=2, k=8, v=4, m=8)
        assert "ENTRY" in txt and "f32[" in txt

    def test_model_lowers_with_pallas(self):
        from compile import aot

        model = models.VggTiny(widths=(4, 4))
        p, s = model.init(0)
        x, _ = datasets.synth_image(32, seed=0)
        caps = train.capture_activations(model, p, s, x)
        lut = models.convert_model(model, p, caps, ["c1"],
                                   n_centroids=8, kmeans_iters=2)
        ex = jnp.zeros((1, 16, 16, 3), jnp.float32)
        txt = aot.lower_model(model, lut, s, ex, table_bits=8,
                              use_pallas=True)
        assert "ENTRY" in txt
        # pallas flag must be reset afterwards
        from compile import layers as _l
        assert _l._USE_PALLAS is False
