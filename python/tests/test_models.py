"""Model zoo: shapes, conversion, capture plumbing, dataset generators."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import datasets, layers, models, softpq, train


class TestDatasets:
    def test_image_shapes_and_determinism(self):
        x1, y1 = datasets.synth_image(32, seed=7)
        x2, y2 = datasets.synth_image(32, seed=7)
        assert x1.shape == (32, 16, 16, 3)
        assert (x1 == x2).all() and (y1 == y2).all()
        assert set(np.unique(y1)) <= set(range(10))

    def test_speech_shapes(self):
        x, y = datasets.synth_speech(16, seed=1)
        assert x.shape == (16, 32, 16, 1)
        assert y.max() < datasets.SPEECH_CLASSES

    def test_age_targets_in_range(self):
        x, y = datasets.synth_age(16, seed=2)
        assert (y >= 0).all() and (y <= 10).all()

    def test_nlp_bigram_planted(self):
        x, y = datasets.synth_nlp(64, seed=3)
        # every sample must contain its class bigram at least once
        for i in range(64):
            c = int(y[i])
            found = any(x[i, j] == 2 * c + 2 and x[i, j + 1] == 2 * c + 3
                        for j in range(x.shape[1] - 1))
            assert found

    def test_sts_target_matches_halves(self):
        x, y = datasets.synth_sts(32, seed=4)
        half = x.shape[1] // 2
        for i in range(32):
            assert y[i] == pytest.approx(
                float(np.mean(x[i, half:] == x[i, :half])))

    def test_batches_iterator(self):
        x, y = datasets.synth_image(70, seed=5)
        seen = 0
        for xb, yb in datasets.batches(x, y, 32, seed=0):
            assert xb.shape[0] == 32
            seen += 32
        assert seen == 64


class TestCnnModels:
    @pytest.mark.parametrize("cls", [models.ResNetTiny, models.VggTiny])
    def test_forward_shape(self, cls):
        model = cls()
        p, s = model.init(0)
        x = jnp.zeros((4, 16, 16, 3), jnp.float32)
        out, ns = model.apply(p, s, x, train=False, table_bits=None)
        assert out.shape == (4, 10)

    def test_train_updates_bn_state(self):
        model = models.ResNetTiny()
        p, s = model.init(0)
        x = jnp.asarray(np.random.default_rng(0)
                        .standard_normal((4, 16, 16, 3)), jnp.float32)
        _, ns = model.apply(p, s, x, train=True, table_bits=None)
        assert not np.allclose(np.asarray(ns["stem_bn"]["mean"]),
                               np.asarray(s["stem_bn"]["mean"]))

    def test_capture_covers_lut_layers(self):
        model = models.ResNetTiny()
        p, s = model.init(0)
        x = jnp.zeros((2, 16, 16, 3), jnp.float32)
        cap = {}
        model.apply(p, s, x, train=False, table_bits=None, capture=cap)
        for name in model.lut_layers():
            if name in p:
                assert name in cap, name

    def test_convert_and_forward(self):
        model = models.ResNetTiny(widths=(4, 8, 8))
        p, s = model.init(0)
        x = jnp.asarray(np.random.default_rng(1)
                        .standard_normal((8, 16, 16, 3)), jnp.float32)
        caps = train.capture_activations(model, p, s, np.asarray(x))
        lut = models.convert_model(model, p, caps, model.lut_layers(),
                                   n_centroids=8, kmeans_iters=3)
        assert isinstance(lut["b0c1"], softpq.LutParams)
        assert isinstance(lut["stem"], dict)     # first conv stays dense
        out, _ = model.apply(lut, s, x, train=False, table_bits=8)
        assert out.shape == (8, 10)
        assert np.isfinite(np.asarray(out)).all()

    def test_im2col_layout_channel_major(self):
        """im2col features must be (Cin, kh, kw) channel-major — the layout
        contract shared with the rust engine (DESIGN.md)."""
        x = jnp.arange(2 * 3 * 3 * 2, dtype=jnp.float32).reshape(2, 3, 3, 2)
        p = layers.im2col(x, 3, 1, "SAME")
        # center patch of image 0: feature vector length 2*9
        center = np.asarray(p)[0, 1, 1]
        img = np.asarray(x)[0]
        want = np.concatenate([img[:, :, c].reshape(-1) for c in range(2)])
        np.testing.assert_allclose(center, want)

    def test_conv_weight_as_matrix_matches_lax_conv(self):
        import jax

        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.standard_normal((2, 5, 5, 3)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((3, 3, 3, 4)), jnp.float32)
        direct = jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        wm = layers.conv_weight_as_matrix(w)
        patches = layers.im2col(x, 3, 1, "SAME")
        out = (patches.reshape(-1, 27) @ wm).reshape(2, 5, 5, 4)
        np.testing.assert_allclose(np.asarray(direct), np.asarray(out),
                                   rtol=1e-4, atol=1e-4)


class TestMiniBert:
    def test_forward_shape(self):
        model = models.MiniBert()
        p, s = model.init(0)
        x = jnp.zeros((4, 16), jnp.int32)
        out, _ = model.apply(p, s, x, train=False, table_bits=None)
        assert out.shape == (4, 4)

    def test_lut_layers_last(self):
        model = models.MiniBert(n_layers=4)
        names = model.lut_layers_last(2)
        assert all(n.startswith(("l2", "l3")) for n in names)
        assert len(names) == 12

    def test_convert_and_forward(self):
        model = models.MiniBert(n_layers=2)
        p, s = model.init(0)
        x, _ = datasets.synth_nlp(32, seed=0)
        caps = train.capture_activations(model, p, s, x)
        lut = models.convert_model(model, p, caps, model.lut_layers_last(1),
                                   n_centroids=8, kmeans_iters=3)
        assert isinstance(lut["l1f1"], softpq.LutParams)
        out, _ = model.apply(lut, s, jnp.asarray(x), train=False,
                             table_bits=8)
        assert out.shape == (32, 4)
        assert np.isfinite(np.asarray(out)).all()


class TestGeometry:
    def test_codebook_geometry(self):
        assert layers.codebook_geometry(27, 3) == 9      # 3x3 conv
        assert layers.codebook_geometry(64, 1) == 4      # 1x1 conv
        assert layers.codebook_geometry(512, 0) == 16    # wide FC
        assert layers.codebook_geometry(10, 0) == 2      # odd small FC
        assert layers.codebook_geometry(7, 0) == 1       # prime fallback
