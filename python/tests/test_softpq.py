"""§3 differentiable centroid learning: STE semantics, gradients, QAT."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import softpq
from compile.kernels import ref


def make_params(seed=0, c=4, k=8, v=3, m=10, bias=True):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(c * v, m)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(m,)), jnp.float32) if bias else None
    p = jnp.asarray(rng.normal(size=(c, k, v)), jnp.float32)
    return softpq.init_lut_params(w, b, p, init_t=1.0)


def make_input(seed=1, n=16, d=12):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, d)), jnp.float32)


class TestForwardSemantics:
    def test_hard_forward_equals_inference(self):
        """Eq. 6: training forward VALUE must equal the inference path."""
        params = make_params()
        a = make_input()
        train_out = softpq.softpq_forward(params, a, table_bits=8)
        infer_out = softpq.inference_forward(params, a, table_bits=8)
        np.testing.assert_allclose(np.asarray(train_out),
                                   np.asarray(infer_out),
                                   rtol=1e-5, atol=1e-5)

    def test_fp32_forward_equals_ref(self):
        params = make_params(bias=False)
        a = make_input()
        out = softpq.softpq_forward(params, a, table_bits=None)
        t = ref.build_table_ref(params.centroids, params.weight)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.lut_amm_ref(a, params.centroids, t)),
            rtol=1e-5, atol=1e-5)

    def test_soft_forward_approaches_hard_as_t_to_0(self):
        params = make_params()
        cold = params._replace(log_t=jnp.asarray(np.log(1e-4), jnp.float32))
        a = make_input()
        soft = softpq.softpq_forward(cold, a, table_bits=None, hard=False)
        hard = softpq.softpq_forward(cold, a, table_bits=None, hard=True)
        np.testing.assert_allclose(np.asarray(soft), np.asarray(hard),
                                   rtol=1e-3, atol=1e-3)

    def test_soft_forward_approaches_mean_as_t_to_inf(self):
        params = make_params()
        hot = params._replace(log_t=jnp.asarray(np.log(1e6), jnp.float32))
        a = make_input()
        soft = softpq.softpq_forward(hot, a, table_bits=None, hard=False)
        t = ref.build_table_ref(params.centroids, params.weight)
        mean_out = jnp.sum(jnp.mean(t, axis=1), axis=0) + params.bias
        np.testing.assert_allclose(
            np.asarray(soft),
            np.broadcast_to(np.asarray(mean_out), soft.shape),
            rtol=1e-2, atol=1e-2)


class TestGradients:
    def loss(self, params, a):
        out = softpq.softpq_forward(params, a, table_bits=8)
        return jnp.sum(out ** 2)

    def test_centroid_gradient_nonzero(self):
        params = make_params()
        a = make_input()
        g = jax.grad(self.loss)(params, a)
        assert float(jnp.abs(g.centroids).max()) > 0.0

    def test_temperature_gradient_nonzero(self):
        """§3.2: the learned-temperature path must receive gradients."""
        params = make_params()
        a = make_input()
        g = jax.grad(self.loss)(params, a)
        assert float(jnp.abs(g.log_t)) > 0.0

    def test_gradient_matches_soft_path(self):
        """STE: grad of the hard forward == grad of the soft forward."""
        params = make_params()
        a = make_input()

        def loss_soft(p):
            out = softpq.softpq_forward(p, a, table_bits=None, hard=False)
            return jnp.sum(out ** 2)

        def loss_hard(p):
            out = softpq.softpq_forward(p, a, table_bits=None, hard=True)
            return jnp.sum(out ** 2)

        gs = jax.grad(loss_soft)(params).centroids
        gh = jax.grad(loss_hard)(params).centroids
        # Not identical (the value entering downstream ops differs:
        # hard vs soft output), but the *encoding* path gradient must be
        # live and finite through argmin — the whole point of Eq. 6.
        assert np.isfinite(np.asarray(gh)).all()
        assert float(jnp.abs(gh).max()) > 0.0
        # both use the softmax jacobian, so directions correlate strongly
        cos = float(jnp.sum(gs * gh) /
                    (jnp.linalg.norm(gs) * jnp.linalg.norm(gh) + 1e-9))
        assert cos > 0.5

    def test_no_gradient_without_ste(self):
        """Pure argmin forward (no STE) has zero centroid gradient —
        the exact problem §3 solves."""
        params = make_params(bias=False)
        a = make_input()

        def loss_argmin_only(p):
            t = ref.build_table_ref(p.centroids, p.weight)
            return jnp.sum(ref.lut_amm_ref(a, p.centroids, t) ** 2)

        g = jax.grad(loss_argmin_only)(params).centroids
        # gradient via the table values exists, but the *encoding* grad is
        # zero: perturbing a centroid that is never selected changes nothing
        sel = np.unique(np.asarray(ref.encode_ref(a, params.centroids)))
        unsel = [k for k in range(params.centroids.shape[1]) if k not in sel]
        if unsel:
            assert float(jnp.abs(g[:, unsel[0], :]).max()) == pytest.approx(0.0)


class TestQAT:
    def test_quantize_ste_forward_is_quantized(self):
        params = make_params()
        t = ref.build_table_ref(params.centroids, params.weight)
        tq = softpq.quantize_ste(t, 8)
        q, s = ref.quantize_table_ref(t, 8)
        np.testing.assert_allclose(
            np.asarray(tq),
            np.asarray(q, np.float32) * np.asarray(s)[:, None, None],
            rtol=1e-6, atol=1e-6)

    def test_quantize_ste_backward_is_identity(self):
        params = make_params()
        t = ref.build_table_ref(params.centroids, params.weight)
        g = jax.grad(lambda x: jnp.sum(softpq.quantize_ste(x, 8) ** 2))(t)
        g_id = jax.grad(lambda x: jnp.sum(x ** 2))(
            softpq.quantize_ste(t, 8))
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_id),
                                   rtol=1e-5, atol=1e-5)

    def test_int4_coarser_than_int8(self):
        params = make_params()
        a = make_input()
        exact = softpq.inference_forward(params, a, table_bits=None)
        e8 = float(jnp.abs(
            softpq.inference_forward(params, a, table_bits=8) - exact).mean())
        e4 = float(jnp.abs(
            softpq.inference_forward(params, a, table_bits=4) - exact).mean())
        assert e4 > e8


class TestTrainableFilter:
    def test_filter_marks_right_leaves(self):
        params = make_params()
        f = softpq.trainable_filter(params)
        assert float(f.centroids.min()) == 1.0
        assert float(f.log_t) == 1.0
        assert float(f.weight.max()) == 0.0
        assert float(f.bias.max()) == 0.0
