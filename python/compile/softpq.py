"""L2 — Differentiable centroid learning (paper §3).

Implements the three approximation-adaptation methods:

1. **soft-PQ** (§3.1): forward pass encodes with hard argmin (what
   inference uses); backward pass flows gradients through the softmax
   encoding. Realised with the straight-through estimator of Eq. 6:

       out = soft - sg(soft - hard)

   which evaluates to ``hard`` in the forward pass and to ``soft`` for
   gradient purposes.

2. **learned temperature** (§3.2): the per-layer softmax temperature ``t``
   is a trainable parameter (stored as ``log_t`` so t > 0 always), updated
   by the same backprop (with its own, larger, learning rate — Table 3).

3. **quantization-aware training** (§3.3): the forward pass uses the
   INT8/INT4-quantized lookup table (as inference will); the backward pass
   sees the real-valued table, again via a straight-through estimator.

A LUT layer's trainable state is ``(centroids [C,K,V], log_t [])`` plus the
frozen weight ``B [D,M]`` from which the table is rebuilt every step
(paper Fig. 4 "rebuild lookup tables with the updated centroids").
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import ref


class LutParams(NamedTuple):
    """Trainable + frozen state of one LUT-replaced linear operator."""

    centroids: jnp.ndarray        # [C, K, V]  trainable
    log_t: jnp.ndarray            # []         trainable (temperature)
    weight: jnp.ndarray           # [D, M]     frozen (table rebuilt from it)
    bias: jnp.ndarray | None      # [M]        frozen


def init_lut_params(
    weight: jnp.ndarray,
    bias: jnp.ndarray | None,
    centroids: jnp.ndarray,
    init_t: float = 1.0,
) -> LutParams:
    return LutParams(
        centroids=centroids.astype(jnp.float32),
        log_t=jnp.asarray(jnp.log(init_t), jnp.float32),
        weight=weight.astype(jnp.float32),
        bias=None if bias is None else bias.astype(jnp.float32),
    )


def quantize_ste(table: jnp.ndarray, bits: int) -> jnp.ndarray:
    """QAT table: forward = quantize->dequantize, backward = identity."""
    q, scale = ref.quantize_table_ref(table, bits)
    deq = q.astype(jnp.float32) * scale[:, None, None]
    return table + jax.lax.stop_gradient(deq - table)


def softpq_forward(
    params: LutParams,
    a: jnp.ndarray,
    *,
    table_bits: int | None = 8,
    hard: bool = True,
) -> jnp.ndarray:
    """Soft-PQ AMM: a [N, D] -> [N, M].

    hard=True is the training/inference forward of Eq. 6 (argmin value,
    softmax gradient). hard=False returns the pure softmax relaxation
    (useful for diagnostics/tests of the gradient path).
    """
    p = params.centroids
    c, k, v = p.shape
    t = jnp.exp(params.log_t)

    table = ref.build_table_ref(p, params.weight)     # [C, K, M]
    if table_bits is not None:
        table = quantize_ste(table, table_bits)       # QAT (§3.3)

    d = ref.distances_ref(a, p)                       # [N, C, K]
    soft = jax.nn.softmax(-d / t, axis=-1)            # Eq. 5
    if hard:
        onehot = jax.nn.one_hot(jnp.argmin(d, axis=-1), k, dtype=jnp.float32)
        g = soft - jax.lax.stop_gradient(soft - onehot)   # Eq. 6 (STE)
    else:
        g = soft
    # sum_c g[n,c,:] @ table[c,:,:]
    out = jnp.einsum("nck,ckm->nm", g, table)
    if params.bias is not None:
        out = out + params.bias
    return out


def inference_forward(params: LutParams, a: jnp.ndarray, *, table_bits=8,
                      use_pallas: bool = False):
    """The deployed path: hard argmin + quantized table, no grad tricks.

    Matches what the rust engine and the AOT HLO graph compute; used by
    tests to pin training-forward == inference-forward numerics.
    use_pallas routes through the L1 pallas kernels (interpret=True) so the
    AOT lowering contains the kernel's block schedule (aot.py sets this).
    """
    table = ref.build_table_ref(params.centroids, params.weight)
    if use_pallas:
        from .kernels import lut_amm as _k

        bn = _k.pick_block_n(*params.centroids.shape, table.shape[2])
        if table_bits is None:
            return _k.lut_amm(a, params.centroids, table, params.bias,
                              block_n=bn)
        q, scale = ref.quantize_table_ref(table, table_bits)
        return _k.lut_amm_quantized(a, params.centroids, q, scale,
                                    params.bias, block_n=bn)
    if table_bits is None:
        return ref.lut_amm_ref(a, params.centroids, table, params.bias)
    q, scale = ref.quantize_table_ref(table, table_bits)
    return ref.lut_amm_quantized_ref(a, params.centroids, q, scale, params.bias)


def trainable_filter(params: LutParams) -> LutParams:
    """Mask: 1 where trainable (centroids, log_t), 0 where frozen."""
    return LutParams(
        centroids=jnp.ones_like(params.centroids),
        log_t=jnp.ones_like(params.log_t),
        weight=jnp.zeros_like(params.weight),
        bias=None if params.bias is None else jnp.zeros_like(params.bias),
    )
