"""Vanilla-PQ centroid learning: k-means per codebook (paper §2.1, Eq. 1).

Used to *initialize* soft-PQ centroids ("we initialize centroids using
k-means clustering" — §6.1) and as the no-fine-tuning vanilla-PQ baseline
of Fig. 3a. Implemented with numpy (build-time only; never on the request
path). k-means++ seeding + Lloyd iterations, with empty-cluster respawn.
"""

from __future__ import annotations

import numpy as np


def _kmeans_pp_init(x: np.ndarray, k: int, rng: np.random.Generator):
    """k-means++ seeding over rows of x [n, v]."""
    n = x.shape[0]
    centers = np.empty((k, x.shape[1]), dtype=x.dtype)
    centers[0] = x[rng.integers(n)]
    d2 = np.sum((x - centers[0]) ** 2, axis=1)
    for i in range(1, k):
        total = d2.sum()
        if total <= 1e-12:
            centers[i:] = x[rng.integers(n, size=k - i)]
            break
        probs = d2 / total
        centers[i] = x[rng.choice(n, p=probs)]
        d2 = np.minimum(d2, np.sum((x - centers[i]) ** 2, axis=1))
    return centers


def kmeans(
    x: np.ndarray,
    k: int,
    n_iters: int = 25,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Lloyd's algorithm. x: [n, v] -> (centroids [k, v], assign [n])."""
    x = np.asarray(x, dtype=np.float32)
    n = x.shape[0]
    rng = np.random.default_rng(seed)
    if n < k:
        # Degenerate: fewer samples than centroids — pad by jittered copies.
        reps = int(np.ceil(k / max(n, 1)))
        x = np.concatenate([x] * reps, axis=0)
        x = x + rng.normal(scale=1e-4, size=x.shape).astype(np.float32)
        n = x.shape[0]
    centers = _kmeans_pp_init(x, k, rng)
    assign = np.zeros(n, dtype=np.int64)
    for _ in range(n_iters):
        # [n, k] distances via |x|^2 - 2 x.c + |c|^2
        d = (
            np.sum(x * x, axis=1, keepdims=True)
            - 2.0 * (x @ centers.T)
            + np.sum(centers * centers, axis=1)[None, :]
        )
        new_assign = np.argmin(d, axis=1)
        if np.array_equal(new_assign, assign):
            assign = new_assign
            break
        assign = new_assign
        for j in range(k):
            mask = assign == j
            if mask.any():
                centers[j] = x[mask].mean(axis=0)
            else:
                # Respawn empty cluster at the point farthest from its center.
                far = np.argmax(d[np.arange(n), assign])
                centers[j] = x[far]
    return centers, assign


def learn_codebooks(
    activations: np.ndarray,
    n_codebooks: int,
    k: int,
    n_iters: int = 25,
    seed: int = 0,
    max_rows: int = 8192,
) -> np.ndarray:
    """Paper Eq. 1 over all codebooks. activations: [N, D] -> [C, K, V].

    Subsamples rows to ``max_rows`` (the paper uses 1024 input samples,
    which after im2col is far more rows than needed for K<=64 clusters).
    """
    n, d = activations.shape
    assert d % n_codebooks == 0
    v = d // n_codebooks
    rng = np.random.default_rng(seed)
    if n > max_rows:
        sel = rng.choice(n, size=max_rows, replace=False)
        activations = activations[sel]
    sub = activations.reshape(activations.shape[0], n_codebooks, v)
    out = np.empty((n_codebooks, k, v), dtype=np.float32)
    for c in range(n_codebooks):
        out[c], _ = kmeans(sub[:, c, :], k, n_iters=n_iters, seed=seed + c)
    return out


def quantization_mse(activations: np.ndarray, codebooks: np.ndarray) -> float:
    """Mean |a^c - nearest centroid|^2 — the quantity PQ minimizes (Eq. 1)."""
    c, k, v = codebooks.shape
    sub = activations.reshape(activations.shape[0], c, v)
    total = 0.0
    for ci in range(c):
        x = sub[:, ci, :]
        d = (
            np.sum(x * x, axis=1, keepdims=True)
            - 2.0 * (x @ codebooks[ci].T)
            + np.sum(codebooks[ci] ** 2, axis=1)[None, :]
        )
        total += float(np.min(d, axis=1).mean())
    return total / c
