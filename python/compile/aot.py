"""AOT compile path: train the tiny models, export `.lutnn` bundles and
HLO **text** for the rust runtime (`make artifacts` entrypoint).

HLO text — NOT ``lowered.compiler_ir("hlo").serialize()`` — is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs (artifacts/):
  resnet_tiny_dense.lutnn / resnet_tiny_lut.lutnn    trained bundles
  mini_bert_dense.lutnn   / mini_bert_lut.lutnn
  resnet_tiny_{dense,lut}_b{1,8}.hlo.txt             model graphs (PJRT)
  mini_bert_{dense,lut}_b{1,8}.hlo.txt
  lut_amm_op.hlo.txt                                 single fused L1 op
  model.hlo.txt                                      alias of lut b1 graph
  manifest.json                                      inventory + metrics
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import export, layers, train
from .kernels import lut_amm as lut_kernels
from .kernels import ref


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default printer elides big literals
    # as `constant({...})`, which the rust-side text parser reads back as
    # ZEROS — silently corrupting any graph with baked weights.
    return comp.as_hlo_text(True)


def lower_model(model, params, state, example, *, table_bits=8,
                use_pallas=True) -> str:
    """Bake params as constants; lower fwd(x) -> logits to HLO text."""
    layers.set_pallas(use_pallas)
    try:
        def fwd(x):
            out, _ = model.apply(params, state, x, train=False,
                                 table_bits=table_bits)
            return (out,)

        spec = jax.ShapeDtypeStruct(example.shape, example.dtype)
        return to_hlo_text(jax.jit(fwd).lower(spec))
    finally:
        layers.set_pallas(False)


def lower_lut_amm_op(n=256, c=64, k=16, v=9, m=128) -> str:
    """Standalone fused L1 kernel graph: (a, centroids, table_q, scale)."""
    specs = [
        jax.ShapeDtypeStruct((n, c * v), jnp.float32),
        jax.ShapeDtypeStruct((c, k, v), jnp.float32),
        jax.ShapeDtypeStruct((c, k, m), jnp.int8),
        jax.ShapeDtypeStruct((c,), jnp.float32),
    ]
    bn = lut_kernels.pick_block_n(c, k, v, m)

    def op(a, p, tq, s):
        return (lut_kernels.lut_amm_quantized(a, p, tq, s, block_n=bn),)

    return to_hlo_text(jax.jit(op).lower(*specs))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file alias")
    ap.add_argument("--quick", action="store_true",
                    help="minimal training (CI smoke)")
    args = ap.parse_args()
    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)
    t0 = time.time()
    manifest: dict = {"created": "make artifacts", "models": {}}

    dense_steps = 60 if args.quick else 500
    ft_steps = 40 if args.quick else 300
    n_train = 512 if args.quick else 3072

    # ---------------- ResNet-tiny on synth-image --------------------------
    x_tr, y_tr, x_te, y_te, model, _ = train.quick_task(
        "image", n_train=n_train, n_test=512)
    res = train.lutnn_pipeline(
        model, x_tr, y_tr, x_te, y_te,
        dense_cfg=train.TrainConfig(steps=dense_steps, lr=2e-3),
        finetune_cfg=train.TrainConfig(steps=ft_steps, lr=1e-3),
        n_capture=min(1024, n_train), kmeans_iters=15)
    print(f"[aot] resnet_tiny dense={res.dense_metric:.4f} "
          f"lut={res.lut_metric:.4f} ({time.time()-t0:.0f}s)")

    export.export_cnn(model, res.dense_params, res.state,
                      f"{out_dir}/resnet_tiny_dense.lutnn",
                      name="resnet_tiny", input_shape=[1, 16, 16, 3],
                      meta={"accuracy": res.dense_metric})
    export.export_cnn(model, res.lut_params, res.state,
                      f"{out_dir}/resnet_tiny_lut.lutnn",
                      name="resnet_tiny_lut", input_shape=[1, 16, 16, 3],
                      meta={"accuracy": res.lut_metric})
    manifest["models"]["resnet_tiny"] = {
        "dense_acc": res.dense_metric, "lut_acc": res.lut_metric,
        "input_shape": [1, 16, 16, 3]}

    for batch in (1, 8):
        ex = jnp.zeros((batch, 16, 16, 3), jnp.float32)
        for variant, p, pallas in (("dense", res.dense_params, False),
                                   ("lut", res.lut_params, True)):
            txt = lower_model(model, p, res.state, ex,
                              table_bits=8 if variant == "lut" else None,
                              use_pallas=pallas)
            path = f"{out_dir}/resnet_tiny_{variant}_b{batch}.hlo.txt"
            with open(path, "w") as f:
                f.write(txt)
            print(f"[aot] wrote {path} ({len(txt)} chars)")

    # Golden I/O vectors so rust integration tests can pin exact numerics.
    gx = x_te[:8].astype(np.float32)
    gout, _ = model.apply(res.lut_params, res.state, jnp.asarray(gx),
                          train=False, table_bits=8)
    gdense, _ = model.apply(res.dense_params, res.state, jnp.asarray(gx),
                            train=False, table_bits=None)
    np.savez(f"{out_dir}/golden_resnet_tiny.npz", x=gx,
             lut_out=np.asarray(gout), dense_out=np.asarray(gdense))
    # flat binary copies for the no-npz rust side
    gx.tofile(f"{out_dir}/golden_input_b8.f32")
    np.asarray(gout, np.float32).tofile(f"{out_dir}/golden_lut_out_b8.f32")
    np.asarray(gdense, np.float32).tofile(
        f"{out_dir}/golden_dense_out_b8.f32")

    # ---------------- mini-BERT on synth-nlp ------------------------------
    xb_tr, yb_tr, xb_te, yb_te, bert, _ = train.quick_task(
        "nlp", n_train=n_train, n_test=512)
    replace = bert.lut_layers_last(bert.n_layers // 2)  # paper: last half
    bres = train.lutnn_pipeline(
        bert, xb_tr, yb_tr, xb_te, yb_te, replace=replace,
        dense_cfg=train.TrainConfig(steps=dense_steps, lr=2e-3),
        finetune_cfg=train.TrainConfig(steps=ft_steps, lr=1e-3),
        n_capture=min(1024, n_train), kmeans_iters=15)
    print(f"[aot] mini_bert dense={bres.dense_metric:.4f} "
          f"lut={bres.lut_metric:.4f} ({time.time()-t0:.0f}s)")
    export.export_bert(bert, bres.dense_params,
                       f"{out_dir}/mini_bert_dense.lutnn",
                       name="mini_bert", meta={"accuracy": bres.dense_metric})
    export.export_bert(bert, bres.lut_params,
                       f"{out_dir}/mini_bert_lut.lutnn",
                       name="mini_bert_lut", meta={"accuracy": bres.lut_metric})
    manifest["models"]["mini_bert"] = {
        "dense_acc": bres.dense_metric, "lut_acc": bres.lut_metric,
        "input_shape": [1, bert.seq_len]}
    for batch in (1, 8):
        ex = jnp.zeros((batch, bert.seq_len), jnp.int32)
        for variant, p, pallas in (("dense", bres.dense_params, False),
                                   ("lut", bres.lut_params, True)):
            txt = lower_model(bert, p, bres.state, ex,
                              table_bits=8 if variant == "lut" else None,
                              use_pallas=pallas)
            path = f"{out_dir}/mini_bert_{variant}_b{batch}.hlo.txt"
            with open(path, "w") as f:
                f.write(txt)
            print(f"[aot] wrote {path} ({len(txt)} chars)")
    gbx = xb_te[:8].astype(np.int32)
    gbout, _ = bert.apply(bres.lut_params, bres.state, jnp.asarray(gbx),
                          train=False, table_bits=8)
    np.savez(f"{out_dir}/golden_mini_bert.npz", x=gbx,
             lut_out=np.asarray(gbout))

    # ---------------- standalone fused kernel -----------------------------
    txt = lower_lut_amm_op()
    with open(f"{out_dir}/lut_amm_op.hlo.txt", "w") as f:
        f.write(txt)
    # Golden vectors for the op graph.
    rng = np.random.default_rng(0)
    a = rng.normal(size=(256, 64 * 9)).astype(np.float32)
    p = rng.normal(size=(64, 16, 9)).astype(np.float32)
    b = rng.normal(size=(64 * 9, 128)).astype(np.float32)
    tbl = np.asarray(ref.build_table_ref(jnp.asarray(p), jnp.asarray(b)))
    q, scale = ref.quantize_table_ref(jnp.asarray(tbl), 8)
    out = np.asarray(ref.lut_amm_quantized_ref(
        jnp.asarray(a), jnp.asarray(p), q, scale))
    np.savez(f"{out_dir}/golden_lut_amm_op.npz", a=a, p=p,
             tq=np.asarray(q, np.int8), scale=np.asarray(scale), out=out)
    a.tofile(f"{out_dir}/lut_amm_op_a.f32")
    np.asarray(p, np.float32).tofile(f"{out_dir}/lut_amm_op_p.f32")
    np.asarray(q, np.int8).tofile(f"{out_dir}/lut_amm_op_tq.i8")
    np.asarray(scale, np.float32).tofile(f"{out_dir}/lut_amm_op_scale.f32")
    out.astype(np.float32).tofile(f"{out_dir}/lut_amm_op_out.f32")

    # legacy single-file alias expected by the Makefile contract
    alias = args.out or f"{out_dir}/model.hlo.txt"
    with open(f"{out_dir}/resnet_tiny_lut_b1.hlo.txt") as f:
        model_txt = f.read()
    with open(alias, "w") as f:
        f.write(model_txt)

    manifest["elapsed_s"] = round(time.time() - t0, 1)
    with open(f"{out_dir}/manifest.json", "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] done in {manifest['elapsed_s']}s -> {out_dir}")


if __name__ == "__main__":
    main()
