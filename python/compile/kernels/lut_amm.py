"""L1 — Pallas kernels for the LUT-NN table-lookup AMM hot spot.

TPU adaptation of the paper's §5 (DESIGN.md §Hardware-Adaptation): instead
of NEON/SSE shuffle instructions, both stages are cast as MXU-shaped
matmuls with the codebook pinned in VMEM across the whole row grid
(the VMEM analogue of the paper's centroid-stationary scheme):

  stage 1  distance     [bN, V] @ [V, K]  per codebook  (+ |p|^2 bias row)
  stage 2  table read   onehot[bN, K] @ T[K, M]         per codebook

The kernels run with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls, so on this testbed Pallas is a *structural*
target (block schedule, VMEM budget) validated numerically against
``ref.py``; real-TPU perf is estimated in DESIGN.md §Perf.

Grid: 1-D over row blocks of size ``block_n``. Per grid step the VMEM
footprint is
    bN*(C*V + C*K + M)*4 B  (input block, distance scratch, output block)
  + C*K*(V + M)*4 B         (codebook + table, resident)
which for the default (bN=128, C=64, V=9, K=16, M=512) is ~2.9 MiB —
inside a 16 MiB TPU VMEM with double-buffering headroom.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 128


def _dist_argmin_kernel(a_ref, p_ref, idx_ref):
    """Closest-centroid search (paper §5.1) for one row block.

    a_ref:   [bN, C, V]  input sub-vectors
    p_ref:   [C, K, V]   codebooks (grid-invariant -> stays in VMEM)
    idx_ref: [bN, C]     output centroid indices (int32)
    """
    a = a_ref[...]
    p = p_ref[...]
    # |a - p|^2 = |a|^2 - 2 a.p + |p|^2 ; |a|^2 is constant over k and
    # does not change the argmin, so it is dropped (fewer VPU ops).
    cross = jax.lax.dot_general(
        a.transpose(1, 0, 2),            # [C, bN, V]
        p.transpose(0, 2, 1),            # [C, V, K]
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                     # [C, bN, K]
    p2 = jnp.sum(p * p, axis=-1)          # [C, K]
    d = p2[:, None, :] - 2.0 * cross      # [C, bN, K]
    idx_ref[...] = jnp.argmin(d, axis=-1).astype(jnp.int32).T


def _lut_amm_kernel(a_ref, p_ref, t_ref, o_ref):
    """Fused distance -> argmin -> table read -> accumulate for one block.

    a_ref: [bN, C, V], p_ref: [C, K, V], t_ref: [C, K, M], o_ref: [bN, M].
    The table read is a one-hot [C, bN, K] @ [C, K, M] batched matmul —
    MXU-shaped, replacing the CPU shuffle instruction of the paper.
    """
    a = a_ref[...]
    p = p_ref[...]
    t = t_ref[...]
    k = p.shape[1]
    cross = jax.lax.dot_general(
        a.transpose(1, 0, 2),
        p.transpose(0, 2, 1),
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                     # [C, bN, K]
    p2 = jnp.sum(p * p, axis=-1)
    d = p2[:, None, :] - 2.0 * cross
    onehot = jax.nn.one_hot(jnp.argmin(d, axis=-1), k, dtype=jnp.float32)
    # [C, bN, K] @ [C, K, M] -> [C, bN, M]; sum over codebooks -> [bN, M]
    per_c = jax.lax.dot_general(
        onehot,
        t,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = jnp.sum(per_c, axis=0)


def _lut_amm_q_kernel(a_ref, p_ref, tq_ref, s_ref, o_ref):
    """INT8-table variant: gather in int space, scale per codebook (§5.2).

    tq_ref: [C, K, M] int8, s_ref: [C] f32. Mixed-precision accumulation:
    the one-hot matmul runs over the int8 table upcast to f32 lane-wise
    (interpret mode); on real TPU this maps to int8 MXU passes with i32
    accumulators, mirroring the paper's INT16->INT32 two-stage scheme.
    """
    a = a_ref[...]
    p = p_ref[...]
    tq = tq_ref[...].astype(jnp.float32)
    s = s_ref[...]
    k = p.shape[1]
    cross = jax.lax.dot_general(
        a.transpose(1, 0, 2),
        p.transpose(0, 2, 1),
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    p2 = jnp.sum(p * p, axis=-1)
    d = p2[:, None, :] - 2.0 * cross
    onehot = jax.nn.one_hot(jnp.argmin(d, axis=-1), k, dtype=jnp.float32)
    per_c = jax.lax.dot_general(
        onehot, tq,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                     # [C, bN, M]
    o_ref[...] = jnp.sum(per_c * s[:, None, None], axis=0)


def _pad_rows(a: jnp.ndarray, block_n: int):
    n = a.shape[0]
    pad = (-n) % block_n
    if pad:
        a = jnp.concatenate([a, jnp.zeros((pad,) + a.shape[1:], a.dtype)], 0)
    return a, n


@functools.partial(jax.jit, static_argnames=("block_n",))
def dist_argmin(a, centroids, *, block_n: int = DEFAULT_BLOCK_N):
    """Pallas closest-centroid search. a: [N, D], centroids: [C, K, V] -> [N, C]."""
    c, _, v = centroids.shape
    n = a.shape[0]
    sub = a.reshape(n, c, v)
    sub, n_orig = _pad_rows(sub, block_n)
    grid = (sub.shape[0] // block_n,)
    out = pl.pallas_call(
        _dist_argmin_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, c, v), lambda i: (i, 0, 0)),
            pl.BlockSpec(centroids.shape, lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sub.shape[0], c), jnp.int32),
        interpret=True,
    )(sub, centroids)
    return out[:n_orig]


@functools.partial(jax.jit, static_argnames=("block_n",))
def lut_amm(a, centroids, table, bias=None, *, block_n: int = DEFAULT_BLOCK_N):
    """Fused LUT-NN AMM. a: [N, D], centroids: [C, K, V], table: [C, K, M]."""
    c, k, v = centroids.shape
    m = table.shape[2]
    n = a.shape[0]
    sub = a.reshape(n, c, v)
    sub, n_orig = _pad_rows(sub, block_n)
    grid = (sub.shape[0] // block_n,)
    out = pl.pallas_call(
        _lut_amm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, c, v), lambda i: (i, 0, 0)),
            pl.BlockSpec((c, k, v), lambda i: (0, 0, 0)),
            pl.BlockSpec((c, k, m), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sub.shape[0], m), jnp.float32),
        interpret=True,
    )(sub, centroids, table)
    out = out[:n_orig]
    if bias is not None:
        out = out + bias
    return out


@functools.partial(jax.jit, static_argnames=("block_n",))
def lut_amm_quantized(
    a, centroids, table_q, scale, bias=None, *, block_n: int = DEFAULT_BLOCK_N
):
    """INT8-table fused LUT-NN AMM (paper §3.3/§5.2)."""
    c, k, v = centroids.shape
    m = table_q.shape[2]
    n = a.shape[0]
    sub = a.reshape(n, c, v)
    sub, n_orig = _pad_rows(sub, block_n)
    grid = (sub.shape[0] // block_n,)
    out = pl.pallas_call(
        _lut_amm_q_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, c, v), lambda i: (i, 0, 0)),
            pl.BlockSpec((c, k, v), lambda i: (0, 0, 0)),
            pl.BlockSpec((c, k, m), lambda i: (0, 0, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sub.shape[0], m), jnp.float32),
        interpret=True,
    )(sub, centroids, table_q.astype(jnp.int8), scale)
    out = out[:n_orig]
    if bias is not None:
        out = out + bias
    return out


def vmem_footprint_bytes(block_n: int, c: int, k: int, v: int, m: int) -> int:
    """Static VMEM estimate for one grid step of the fused kernel (DESIGN §Perf)."""
    resident = c * k * (v + m) * 4            # codebook + table
    per_block = block_n * (c * v + m) * 4     # input block + output block
    scratch = c * block_n * k * 4             # distance / one-hot scratch
    return resident + per_block + scratch


def pick_block_n(c: int, k: int, v: int, m: int, budget: int = 8 << 20) -> int:
    """Largest power-of-two row block whose footprint fits the VMEM budget."""
    bn = 512
    while bn > 8 and vmem_footprint_bytes(bn, c, k, v, m) > budget:
        bn //= 2
    return bn
