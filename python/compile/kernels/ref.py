"""Pure-jnp reference oracle for the LUT-NN AMM kernels.

Everything in this file is straight-line jnp with no pallas, no tricks —
it is the numerics contract that ``lut_amm.py`` (L1 pallas kernels) and the
rust ``lut::engine`` (L3 native engine) are tested against.

Shapes and symbols follow the paper (§2.2, Table 1):
  A  : [N, D]      input matrix (rows are feature vectors)
  B  : [D, M]      weight matrix (constant at inference)
  C  : number of codebooks, D = C * V
  V  : sub-vector length
  K  : centroids per codebook
  P  : [C, K, V]   centroids ("codebooks")
  T  : [C, K, M]   lookup table, T[c, k] = P[c, k] @ B[c*V:(c+1)*V]
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def split_subvectors(a: jnp.ndarray, n_codebooks: int) -> jnp.ndarray:
    """[N, D] -> [N, C, V] contiguous sub-vector view (paper Fig. 2)."""
    n, d = a.shape
    assert d % n_codebooks == 0, f"D={d} not divisible by C={n_codebooks}"
    return a.reshape(n, n_codebooks, d // n_codebooks)


def distances_ref(a: jnp.ndarray, centroids: jnp.ndarray) -> jnp.ndarray:
    """Squared Euclidean distance of every sub-vector to every centroid.

    a: [N, D], centroids: [C, K, V] -> [N, C, K].
    Uses the expanded form |a|^2 - 2 a.p + |p|^2 (same as the fast path).
    """
    c, _, v = centroids.shape
    sub = split_subvectors(a, c)                      # [N, C, V]
    a2 = jnp.sum(sub * sub, axis=-1, keepdims=True)   # [N, C, 1]
    p2 = jnp.sum(centroids * centroids, axis=-1)      # [C, K]
    cross = jnp.einsum("ncv,ckv->nck", sub, centroids)
    return a2 - 2.0 * cross + p2[None, :, :]


def encode_ref(a: jnp.ndarray, centroids: jnp.ndarray) -> jnp.ndarray:
    """Paper Eq. 2: argmin_k ||a^c - P_k^c||^2  -> [N, C] int32 indices."""
    return jnp.argmin(distances_ref(a, centroids), axis=-1).astype(jnp.int32)


def build_table_ref(centroids: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Paper Eq. 3: T[c, k] = P[c, k] . b^c   -> [C, K, M]."""
    c, _, v = centroids.shape
    d, m = b.shape
    assert d == c * v
    b_sub = b.reshape(c, v, m)
    return jnp.einsum("ckv,cvm->ckm", centroids, b_sub)


def lut_amm_ref(
    a: jnp.ndarray,
    centroids: jnp.ndarray,
    table: jnp.ndarray,
    bias: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Paper Eq. 4: a.b ~= sum_c onehot(argmin) . T^c    -> [N, M].

    The gather formulation (take_along_axis) is the semantic ground truth;
    the pallas kernel realises the same thing as a one-hot matmul so it can
    ride the MXU.
    """
    idx = encode_ref(a, centroids)                    # [N, C]
    gathered = jnp.take_along_axis(
        table[None, :, :, :],                         # [1, C, K, M]
        idx[:, :, None, None],                        # [N, C, 1, 1]
        axis=2,
    )                                                 # [N, C, 1, M]
    out = jnp.sum(gathered[:, :, 0, :], axis=1)       # [N, M]
    if bias is not None:
        out = out + bias
    return out


def lut_amm_quantized_ref(
    a: jnp.ndarray,
    centroids: jnp.ndarray,
    table_q: jnp.ndarray,
    scale: jnp.ndarray,
    bias: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """INT8 lookup-table variant (paper §3.3 + §5.2).

    table_q: [C, K, M] int8-range values, scale: [C] per-codebook symmetric
    scale. Accumulates the gathered rows in int32 per codebook (the
    mixed-precision accumulation of §5.2), then applies the scale in f32.
    """
    idx = encode_ref(a, centroids)
    gathered = jnp.take_along_axis(
        table_q[None, :, :, :].astype(jnp.int32),
        idx[:, :, None, None],
        axis=2,
    )[:, :, 0, :]                                     # [N, C, M] int32
    out = jnp.sum(gathered.astype(jnp.float32) * scale[None, :, None], axis=1)
    if bias is not None:
        out = out + bias
    return out


def quantize_table_ref(table: jnp.ndarray, bits: int = 8):
    """Range-based symmetric scalar quantization (paper §3.3).

    r = s * q, s = max|value| / (2^(n-1) - 1), per codebook.
    Returns (q [C,K,M] int32 in the signed n-bit range, scale [C]).
    """
    qmax = float(2 ** (bits - 1) - 1)
    absmax = jnp.max(jnp.abs(table), axis=(1, 2))     # [C]
    scale = jnp.where(absmax > 0, absmax / qmax, 1.0)
    q = jnp.clip(jnp.round(table / scale[:, None, None]), -qmax - 1, qmax)
    return q.astype(jnp.int32), scale


def softpq_encode_ref(
    a: jnp.ndarray, centroids: jnp.ndarray, temperature
) -> jnp.ndarray:
    """Paper Eq. 5: softmax(-d^2 / t) over centroids -> [N, C, K]."""
    d = distances_ref(a, centroids)
    return jax.nn.softmax(-d / temperature, axis=-1)


def dense_ref(a: jnp.ndarray, b: jnp.ndarray, bias: jnp.ndarray | None = None):
    """The exact MM that LUT-AMM approximates."""
    out = a @ b
    if bias is not None:
        out = out + bias
    return out
