"""Synthetic dataset generators (substitution for CIFAR/GTSRB/SVHN/
SpeechCommand/UTKFace/GLUE — see DESIGN.md §Substitutions).

Each generator is procedural, seeded, and produces a non-trivially
learnable task that exercises the same code path as the paper's real
datasets: conv stacks over 2-D images, conv stacks over spectrogram-like
1×F×T inputs, token-sequence classification for the BERT path, and a
scalar regression head for the UTKFace analogue.

All return (x, y) numpy arrays; x is NHWC float32 (images/speech) or int32
token ids (nlp); y is int64 labels or float32 targets.
"""

from __future__ import annotations

import numpy as np

IMAGE_SIZE = 16
IMAGE_CLASSES = 10
SPEECH_FRAMES = 32
SPEECH_BINS = 16
SPEECH_CLASSES = 8
NLP_SEQ_LEN = 16
NLP_VOCAB = 64
NLP_CLASSES = 4


def _grid(size):
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float32)
    return (yy - size / 2 + 0.5) / size, (xx - size / 2 + 0.5) / size


def synth_image(n: int, seed: int = 0, size: int = IMAGE_SIZE, noise: float = 0.25):
    """10-class 2-D shape+texture discrimination task ("synth-cifar").

    Class  = (shape in {disc, ring, square, cross, diag}) x (stripes in
    {horizontal, vertical}); each sample gets random position jitter,
    per-channel tint, stripe phase, and additive Gaussian noise.
    """
    rng = np.random.default_rng(seed)
    y = rng.integers(IMAGE_CLASSES, size=n).astype(np.int64)
    shapes = y // 2          # 5 shapes
    stripes = y % 2          # 2 stripe orientations
    x = np.zeros((n, size, size, 3), dtype=np.float32)
    yy, xx = _grid(size)
    for i in range(n):
        cy = rng.uniform(-0.15, 0.15)
        cx = rng.uniform(-0.15, 0.15)
        r = np.sqrt((yy - cy) ** 2 + (xx - cx) ** 2)
        s = shapes[i]
        if s == 0:
            mask = (r < 0.3).astype(np.float32)
        elif s == 1:
            mask = ((r > 0.18) & (r < 0.33)).astype(np.float32)
        elif s == 2:
            mask = ((np.abs(yy - cy) < 0.25) & (np.abs(xx - cx) < 0.25)).astype(
                np.float32
            )
        elif s == 3:
            mask = (
                (np.abs(yy - cy) < 0.08) | (np.abs(xx - cx) < 0.08)
            ).astype(np.float32)
        else:
            mask = (np.abs((yy - cy) - (xx - cx)) < 0.12).astype(np.float32)
        phase = rng.uniform(0, 2 * np.pi)
        freq = rng.uniform(3.5, 4.5)
        if stripes[i] == 0:
            tex = 0.5 + 0.5 * np.sin(2 * np.pi * freq * yy + phase)
        else:
            tex = 0.5 + 0.5 * np.sin(2 * np.pi * freq * xx + phase)
        tint = rng.uniform(0.5, 1.0, size=3).astype(np.float32)
        img = (mask * tex)[:, :, None] * tint[None, None, :]
        img += noise * rng.standard_normal((size, size, 3))
        x[i] = img.astype(np.float32)
    return x, y


def synth_speech(n: int, seed: int = 0, noise: float = 0.3):
    """8-"word" keyword-spotting analogue: harmonic-stack spectrograms.

    Each class is a base bin + harmonic spacing + temporal envelope shape;
    output is [T, F, 1] NHWC so the same conv stack consumes it.
    """
    rng = np.random.default_rng(seed)
    y = rng.integers(SPEECH_CLASSES, size=n).astype(np.int64)
    x = np.zeros((n, SPEECH_FRAMES, SPEECH_BINS, 1), dtype=np.float32)
    t = np.linspace(0, 1, SPEECH_FRAMES, dtype=np.float32)[:, None]
    f = np.arange(SPEECH_BINS, dtype=np.float32)[None, :]
    for i in range(n):
        c = y[i]
        base = 1.5 + (c % 4) * 2.5 + rng.uniform(-0.4, 0.4)
        spacing = 3.0 + (c // 4) * 2.0
        env = np.exp(-((t - rng.uniform(0.3, 0.7)) ** 2) / 0.08)
        spec = np.zeros((SPEECH_FRAMES, SPEECH_BINS), dtype=np.float32)
        for h in range(3):
            fb = base + h * spacing
            spec += env * np.exp(-((f - fb) ** 2) / 1.2) / (1 + h)
        spec += noise * rng.standard_normal(spec.shape)
        x[i, :, :, 0] = spec
    return x, y


def synth_age(n: int, seed: int = 0, size: int = IMAGE_SIZE, noise: float = 0.2):
    """UTKFace-analogue regression: target = ring count + radius (continuous).

    Images contain concentric rings whose count/spacing encode a scalar in
    [0, 10]; the model must regress it (MAE reported, lower is better).
    """
    rng = np.random.default_rng(seed)
    age = rng.uniform(0.0, 10.0, size=n).astype(np.float32)
    x = np.zeros((n, size, size, 3), dtype=np.float32)
    yy, xx = _grid(size)
    r = np.sqrt(yy ** 2 + xx ** 2)
    for i in range(n):
        freq = 2.0 + age[i]
        img = 0.5 + 0.5 * np.cos(2 * np.pi * freq * r)
        img = img * np.exp(-r * 1.5)
        tint = rng.uniform(0.6, 1.0, size=3).astype(np.float32)
        out = img[:, :, None] * tint[None, None, :]
        out += noise * rng.standard_normal(out.shape)
        x[i] = out.astype(np.float32)
    return x, age


def synth_nlp(n: int, seed: int = 0, seq_len: int = NLP_SEQ_LEN):
    """4-class token-sequence classification with planted bigram motifs.

    Class c plants the bigram (2c+2, 2c+3) at a random position (twice)
    in an otherwise uniform-random token stream; a transformer must attend
    to adjacent-token structure to solve it (BoW is insufficient because
    all tokens appear in all classes as background).
    """
    rng = np.random.default_rng(seed)
    y = rng.integers(NLP_CLASSES, size=n).astype(np.int64)
    x = rng.integers(10, NLP_VOCAB, size=(n, seq_len)).astype(np.int32)
    for i in range(n):
        c = int(y[i])
        # adversarial background first: singletons of other classes' tokens
        # (planted before the motif so they can never clobber it)
        for other in range(NLP_CLASSES):
            if other != c:
                x[i, rng.integers(0, seq_len)] = 2 * other + 2
        for _ in range(2):
            pos = rng.integers(0, seq_len - 1)
            x[i, pos] = 2 * c + 2
            x[i, pos + 1] = 2 * c + 3
    return x, y


def synth_sts(n: int, seed: int = 0, seq_len: int = NLP_SEQ_LEN):
    """STS-B-analogue regression for Fig. 13: similarity of two half-seqs.

    The sequence is [first half | second half]; the target is the fraction
    of aligned positions whose tokens match between halves (in [0, 1]).
    """
    rng = np.random.default_rng(seed)
    half = seq_len // 2
    x = rng.integers(2, 10, size=(n, seq_len)).astype(np.int32)
    sim = rng.uniform(0, 1, size=n).astype(np.float32)
    for i in range(n):
        n_match = int(round(sim[i] * half))
        pos = rng.permutation(half)[:n_match]
        x[i, half + pos] = x[i, pos]
        # exact target after rounding
        sim[i] = float(np.mean(x[i, half:] == x[i, :half]))
    return x, sim


def batches(x, y, batch_size: int, seed: int = 0, shuffle: bool = True):
    """Deterministic mini-batch iterator."""
    n = len(x)
    idx = np.arange(n)
    if shuffle:
        np.random.default_rng(seed).shuffle(idx)
    for i in range(0, n - batch_size + 1, batch_size):
        sel = idx[i : i + batch_size]
        yield x[sel], y[sel]
