"""Generate the committed cross-language export-parity fixture.

Writes ``rust/tests/fixtures/py_export_tiny.lutnn`` — a tiny MLP bundle
(dense fc1 -> relu -> LUT fc2) produced by this package's real export
path (``BundleWriter.add_lut`` -> ``ref.build_table_ref`` +
``ref.quantize_table_ref``), with everything the rust test
``rust/tests/py_parity.rs`` needs stashed in the header ``meta``:

* ``fixture_input`` / ``expected_output`` — a deterministic eval batch
  and the python reference forward (``lut_amm_quantized_ref``), so rust
  `Session` numerics are pinned against the L2 oracle;
* ``teacher`` — the frozen dense weight/bias of the LUT layer, so rust
  can rebuild (and re-train) the same operator independently.

The script asserts a safety margin between each sub-vector's best and
second-best centroid, so FP-order differences between the two encoders
cannot flip an argmin in the committed fixture.

Run from ``python/``:  python3 -m compile.make_parity_fixture
"""

from __future__ import annotations

import os

import numpy as np

from . import pqkmeans, softpq
from .export import BundleWriter, read_bundle
from .kernels import ref

OUT = os.path.join(os.path.dirname(__file__), "..", "..", "rust", "tests",
                   "fixtures", "py_export_tiny.lutnn")

D0 = 8          # model input features
H = 8           # fc1 output / fc2 input features
M = 5           # fc2 output features
C, V, K = 2, 4, 16
N_CAL = 256     # calibration rows for k-means
N_FIX = 8       # committed eval rows
TOL = 1e-4      # documented rust-vs-python forward tolerance (f32 FP order)


def main() -> None:
    rng = np.random.default_rng(0)
    f32 = np.float32

    w1 = rng.normal(0, 0.5, size=(D0, H)).astype(f32)
    b1 = rng.normal(0, 0.2, size=(H,)).astype(f32)
    w2 = rng.normal(0, 0.5, size=(H, M)).astype(f32)
    b2 = rng.normal(0, 0.2, size=(M,)).astype(f32)

    x_cal = rng.normal(0, 1.0, size=(N_CAL, D0)).astype(f32)
    h_cal = np.maximum(x_cal @ w1 + b1, 0.0).astype(f32)

    cents = np.stack(
        [pqkmeans.kmeans(h_cal[:, c * V:(c + 1) * V], K, n_iters=25,
                         seed=c)[0] for c in range(C)]
    ).astype(f32)                                     # [C, K, V]
    params = softpq.init_lut_params(w2, b2, cents, init_t=0.5)

    x_fix = rng.normal(0, 1.0, size=(N_FIX, D0)).astype(f32)
    h_fix = np.maximum(x_fix @ w1 + b1, 0.0).astype(f32)

    # Argmin safety margin: rust computes distances in a different FP
    # order; a committed fixture must not sit on a near-tie.
    d = np.asarray(ref.distances_ref(h_fix, cents))   # [N, C, K]
    top2 = np.sort(d, axis=-1)[..., :2]
    margin = float(np.min(top2[..., 1] - top2[..., 0]))
    assert margin > 1e-3, f"near-tie in fixture encode (margin {margin})"

    table = ref.build_table_ref(cents, w2)
    q, scale = ref.quantize_table_ref(table, 8)
    expected = np.asarray(
        ref.lut_amm_quantized_ref(h_fix, cents, q, scale, b2), dtype=f32)

    graph = [
        {"op": "linear", "layer": "fc1"},
        {"op": "relu"},
        {"op": "linear", "layer": "fc2"},
    ]
    meta = {
        "fixture_input": {"shape": [N_FIX, D0],
                          "data": x_fix.reshape(-1).tolist()},
        "expected_output": {"shape": [N_FIX, M],
                            "data": expected.reshape(-1).tolist()},
        "tolerance": TOL,
        "teacher": {"w": w2.reshape(-1).tolist(), "b": b2.tolist(),
                    "c": C, "k": K},
        "encode_margin": margin,
    }
    w = BundleWriter("py_export_tiny", [1, D0], graph, meta=meta)
    w.add_dense("fc1", w1, b1)
    w.add_lut("fc2", params, table_bits=8)
    total = w.write(OUT)

    header, arrays = read_bundle(OUT)
    assert header["model"] == "py_export_tiny"
    assert arrays["fc2"]["table_q"].shape == (C, K, M)
    np.testing.assert_array_equal(arrays["fc2"]["centroids"], cents)
    print(f"wrote {os.path.normpath(OUT)} ({total} bytes, "
          f"encode margin {margin:.4f})")


if __name__ == "__main__":
    main()
