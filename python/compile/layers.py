"""L2 — minimal functional layer library (params as pytrees).

Every linear op (Conv2d via im2col, Linear) exists in two forms:

  dense : {"w": ..., "b": ...}            — the original model
  lut   : softpq.LutParams                — after centroid conversion

``apply_*`` dispatch on which form the params dict holds, so the same
model graph runs the original model, the soft-PQ training forward, and
the quantized inference forward (paper Fig. 1 "transform linear layers
to table lookup").

im2col layout contract (shared with the rust engine and the pallas
kernel): patch features are ordered (Cin, kh, kw) channel-major, so with
V = kh*kw each codebook covers exactly one input channel's window —
the paper's (K, V) = (16, 9) for 3x3 convs and (16, 4)... for 1x1 convs
the paper uses V=4, i.e. one codebook per 4 input channels; we follow
that by using (kh*kw metric) V=9 for 3x3 and V=4 over channels for 1x1.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import softpq

Params = dict[str, Any]

# When True, LUT inference forwards route through the L1 pallas kernels
# (interpret=True) instead of the jnp reference — set by aot.py so the AOT
# lowering carries the kernel's block schedule. Module-level because it is
# a build-time lowering switch, not a runtime knob.
_USE_PALLAS = False


def set_pallas(flag: bool) -> None:
    global _USE_PALLAS
    _USE_PALLAS = flag


# ---------------------------------------------------------------- init utils

def _he_init(rng, shape, fan_in):
    return (np.random.default_rng(rng).standard_normal(shape) *
            np.sqrt(2.0 / fan_in)).astype(np.float32)


def conv2d_init(seed: int, cin: int, cout: int, k: int) -> Params:
    w = _he_init(seed, (cin * k * k, cout), cin * k * k)
    return {"w": jnp.asarray(w), "b": jnp.zeros((cout,), jnp.float32)}


def linear_init(seed: int, din: int, dout: int) -> Params:
    w = _he_init(seed, (din, dout), din)
    return {"w": jnp.asarray(w), "b": jnp.zeros((dout,), jnp.float32)}


def bn_init(ch: int) -> tuple[Params, Params]:
    params = {"gamma": jnp.ones((ch,), jnp.float32),
              "beta": jnp.zeros((ch,), jnp.float32)}
    state = {"mean": jnp.zeros((ch,), jnp.float32),
             "var": jnp.ones((ch,), jnp.float32)}
    return params, state


def ln_init(ch: int) -> Params:
    return {"gamma": jnp.ones((ch,), jnp.float32),
            "beta": jnp.zeros((ch,), jnp.float32)}


# ------------------------------------------------------------------- im2col

def im2col(x: jnp.ndarray, k: int, stride: int, padding: str) -> jnp.ndarray:
    """NHWC -> [N, Ho, Wo, Cin*k*k] patches, (Cin, kh, kw) channel-major."""
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(k, k),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return patches  # feature dim is Cin*k*k, channel-major per lax docs


def conv_weight_as_matrix(w_hwio: jnp.ndarray) -> jnp.ndarray:
    """[kh, kw, Cin, Cout] -> [Cin*kh*kw, Cout] matching im2col layout."""
    kh, kw, cin, cout = w_hwio.shape
    return w_hwio.transpose(2, 0, 1, 3).reshape(cin * kh * kw, cout)


# ------------------------------------------------------------------- apply

def apply_linear(params, x2d: jnp.ndarray, *, train: bool,
                 table_bits: int | None, capture: dict | None = None,
                 name: str = "") -> jnp.ndarray:
    """x2d: [rows, D] -> [rows, M]; dispatches dense vs LUT."""
    if capture is not None:
        capture[name] = x2d
    if isinstance(params, softpq.LutParams):
        if train:
            return softpq.softpq_forward(params, x2d, table_bits=table_bits)
        return softpq.inference_forward(params, x2d, table_bits=table_bits,
                                        use_pallas=_USE_PALLAS)
    if type(params).__name__ == "MaddnessOp":  # baseline, eager-only path
        from . import maddness as _m

        return jnp.asarray(_m.maddness_amm(np.asarray(x2d), params))
    return x2d @ params["w"] + params["b"]


def apply_conv(params, x: jnp.ndarray, *, k: int, stride: int,
               padding: str = "SAME", train: bool,
               table_bits: int | None, capture=None, name="") -> jnp.ndarray:
    """NHWC conv as im2col + (dense | LUT) matmul."""
    n = x.shape[0]
    patches = im2col(x, k, stride, padding)
    ho, wo = patches.shape[1], patches.shape[2]
    rows = patches.reshape(n * ho * wo, patches.shape[3])
    out = apply_linear(params, rows, train=train, table_bits=table_bits,
                       capture=capture, name=name)
    return out.reshape(n, ho, wo, out.shape[-1])


def apply_bn(params, state, x, *, train: bool, momentum: float = 0.9):
    """BatchNorm over NHWC (reduce N,H,W). Returns (y, new_state)."""
    if train:
        axes = tuple(range(x.ndim - 1))
        mean = jnp.mean(x, axis=axes)
        var = jnp.var(x, axis=axes)
        new_state = {
            "mean": momentum * state["mean"] + (1 - momentum) * mean,
            "var": momentum * state["var"] + (1 - momentum) * var,
        }
    else:
        mean, var = state["mean"], state["var"]
        new_state = state
    inv = jax.lax.rsqrt(var + 1e-5)
    y = (x - mean) * inv * params["gamma"] + params["beta"]
    return y, new_state


def apply_ln(params, x):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + 1e-5) * params["gamma"] + params["beta"]


def global_avg_pool(x):
    return jnp.mean(x, axis=(1, 2))


def max_pool(x, k: int = 2, stride: int = 2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max,
        (1, k, k, 1), (1, stride, stride, 1), "VALID")


# -------------------------------------------------------- LUT conversion

def codebook_geometry(d: int, kernel: int) -> int:
    """Sub-vector length V for a linear op with input dim D (paper §6.1).

    3x3 convs -> V = 9 (one codebook per input channel's window);
    1x1 convs / small FC -> V = 4; wide FC (BERT-like, D >= 256) -> V = 16.
    Falls back to the largest of {9, 4, 2, 1} dividing D.
    """
    if kernel == 3 and d % 9 == 0:
        return 9
    if d >= 256 and d % 16 == 0:
        return 16
    for v in (4, 2, 1):
        if d % v == 0:
            return v
    return 1


def to_lut(params: Params, activations: np.ndarray, *, n_centroids: int,
           subvec_len: int, init_t: float = 1.0, seed: int = 0,
           kmeans_iters: int = 25) -> softpq.LutParams:
    """Convert a dense linear op to LUT form: k-means init (paper §6.1)."""
    from . import pqkmeans

    w = np.asarray(params["w"])
    d = w.shape[0]
    assert d % subvec_len == 0, f"D={d} % V={subvec_len} != 0"
    c = d // subvec_len
    centroids = pqkmeans.learn_codebooks(
        np.asarray(activations, np.float32), c, n_centroids,
        n_iters=kmeans_iters, seed=seed)
    return softpq.init_lut_params(
        jnp.asarray(w), jnp.asarray(params["b"]),
        jnp.asarray(centroids), init_t=init_t)
