"""Adam optimizer + schedules, hand-rolled (no optax on this image).

Supports per-leaf learning-rate scaling — the paper trains centroids and
the temperature with *different* learning rates (Table 3: centroid LR
1e-3/1e-4, temperature LR 1e-1) — via an ``lr_scale`` pytree that mirrors
the params: each leaf's effective LR is ``base_lr * scale_leaf``.
Frozen leaves (scale 0) skip their update entirely.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: object          # pytree like params
    nu: object


def adam_init(params) -> AdamState:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return AdamState(jnp.zeros((), jnp.int32), zeros,
                     jax.tree_util.tree_map(jnp.zeros_like, params))


def adam_update(grads, opt_state: AdamState, params, *, lr, lr_scale=None,
                b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                grad_clip=None):
    """One Adam step. lr may be a scalar or jnp scalar (schedule value)."""
    step = opt_state.step + 1
    if grad_clip is not None:
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in
                             jax.tree_util.tree_leaves(grads)) + 1e-12)
        factor = jnp.minimum(1.0, grad_clip / gnorm)
        grads = jax.tree_util.tree_map(lambda g: g * factor, grads)
    mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                                opt_state.mu, grads)
    nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                                opt_state.nu, grads)
    mu_hat_f = 1.0 - b1 ** step.astype(jnp.float32)
    nu_hat_f = 1.0 - b2 ** step.astype(jnp.float32)

    if lr_scale is None:
        lr_scale = jax.tree_util.tree_map(lambda p: 1.0, params)

    def upd(p, m, v, s):
        step_size = lr * s
        delta = step_size * (m / mu_hat_f) / (jnp.sqrt(v / nu_hat_f) + eps)
        if weight_decay:
            delta = delta + step_size * weight_decay * p
        return p - delta

    new_params = jax.tree_util.tree_map(upd, params, mu, nu, lr_scale)
    return new_params, AdamState(step, mu, nu)


def cosine_schedule(base_lr: float, total_steps: int):
    """Cosine annealing (paper Table 3 'Cosine Annealing' LR scheduler)."""

    def lr_at(step):
        frac = jnp.minimum(step.astype(jnp.float32) / max(total_steps, 1), 1.0)
        return base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))

    return lr_at


def constant_schedule(base_lr: float):
    def lr_at(step):
        return jnp.asarray(base_lr, jnp.float32)

    return lr_at
