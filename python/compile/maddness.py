"""MADDNESS baseline (Blalock & Guttag, ICML'21) — hashing-based encoding.

The paper's primary accuracy baseline (§2.1 "Hashing for acceleration with
bigger error", Fig. 3b, Table 4). MADDNESS replaces the k-means argmin
encoder with a 4-level balanced binary regression tree per codebook: each
level splits on one fixed sub-vector index against per-node thresholds;
the leaf reached is the bucket (K = 16 leaves). Prototypes are the bucket
means; the lookup table is prototypes @ B, exactly as in vanilla PQ.

This reproduces the *behavioural* core (greedy heuristic split selection,
balanced tree, bucket-mean prototypes). The original's low-level bit
tricks (averaging ints, 4-bit packing) are performance details that do not
change accuracy and live in the rust engine instead.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np


class HashTree(NamedTuple):
    """One codebook's balanced binary regression tree (depth levels)."""

    split_dims: np.ndarray    # [depth]          index into the sub-vector
    thresholds: np.ndarray    # [depth, 2^l max] per-node split thresholds
    prototypes: np.ndarray    # [K, V]           bucket means (K = 2^depth)


def _heuristic_split_dim(x: np.ndarray, buckets: np.ndarray, n_buckets: int):
    """Pick the dim with the largest within-bucket variance sum (MADDNESS §4)."""
    v = x.shape[1]
    scores = np.zeros(v)
    for b in range(n_buckets):
        xb = x[buckets == b]
        if len(xb) > 1:
            scores += xb.var(axis=0) * len(xb)
    return int(np.argmax(scores))


def learn_hash_tree(x: np.ndarray, depth: int = 4, seed: int = 0) -> HashTree:
    """Greedy balanced-tree learning over sub-vectors x [n, v]."""
    rng = np.random.default_rng(seed)
    n, v = x.shape
    if n == 0:
        raise ValueError("empty training set for hash tree")
    k = 2 ** depth
    split_dims = np.zeros(depth, dtype=np.int64)
    thresholds = np.zeros((depth, k // 2 if depth > 0 else 1), dtype=np.float32)
    buckets = np.zeros(n, dtype=np.int64)
    for level in range(depth):
        n_buckets = 2 ** level
        dim = _heuristic_split_dim(x, buckets, n_buckets)
        split_dims[level] = dim
        new_buckets = np.zeros_like(buckets)
        for b in range(n_buckets):
            mask = buckets == b
            vals = x[mask, dim]
            # Balanced split: median threshold (keeps leaves ~equal-sized).
            thr = float(np.median(vals)) if mask.any() else 0.0
            thresholds[level, b] = thr
            go_right = x[:, dim] > thr
            new_buckets[mask] = 2 * b + go_right[mask].astype(np.int64)
        buckets = new_buckets
    prototypes = np.zeros((k, v), dtype=np.float32)
    for b in range(k):
        mask = buckets == b
        if mask.any():
            prototypes[b] = x[mask].mean(axis=0)
        else:
            prototypes[b] = x[rng.integers(n)]
    return HashTree(split_dims, thresholds, prototypes)


def encode_with_tree(x: np.ndarray, tree: HashTree) -> np.ndarray:
    """Traverse the tree for every row of x [n, v] -> bucket ids [n]."""
    n = x.shape[0]
    buckets = np.zeros(n, dtype=np.int64)
    for level in range(len(tree.split_dims)):
        dim = tree.split_dims[level]
        thr = tree.thresholds[level, buckets]
        buckets = 2 * buckets + (x[:, dim] > thr).astype(np.int64)
    return buckets


class MaddnessOp(NamedTuple):
    """A full MADDNESS-encoded linear operator (all codebooks)."""

    trees: list            # C HashTrees
    table: np.ndarray      # [C, K, M]
    bias: np.ndarray | None


def learn_maddness(
    activations: np.ndarray,
    weight: np.ndarray,
    bias: np.ndarray | None,
    n_codebooks: int,
    depth: int = 4,
    seed: int = 0,
    max_rows: int = 8192,
) -> MaddnessOp:
    """Learn hash trees from sample activations [N, D]; build tables from W."""
    n, d = activations.shape
    assert d % n_codebooks == 0
    v = d // n_codebooks
    rng = np.random.default_rng(seed)
    if n > max_rows:
        activations = activations[rng.choice(n, size=max_rows, replace=False)]
    sub = activations.reshape(activations.shape[0], n_codebooks, v)
    m = weight.shape[1]
    trees = []
    table = np.zeros((n_codebooks, 2 ** depth, m), dtype=np.float32)
    for c in range(n_codebooks):
        tree = learn_hash_tree(sub[:, c, :], depth=depth, seed=seed + c)
        trees.append(tree)
        table[c] = tree.prototypes @ weight[c * v : (c + 1) * v, :]
    return MaddnessOp(trees, table, bias)


def maddness_amm(a: np.ndarray, op: MaddnessOp) -> np.ndarray:
    """Approximate a @ B via hash-tree encoding + table read. a: [N, D]."""
    c = len(op.trees)
    n, d = a.shape
    v = d // c
    sub = a.reshape(n, c, v)
    out = np.zeros((n, op.table.shape[2]), dtype=np.float32)
    for ci in range(c):
        idx = encode_with_tree(sub[:, ci, :], op.trees[ci])
        out += op.table[ci, idx, :]
    if op.bias is not None:
        out += op.bias
    return out
