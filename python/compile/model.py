"""L2 facade — re-exports the model zoo and the two forward paths.

Kept as the module named in the repo scaffold contract; the substance
lives in models.py (architectures), softpq.py (soft-PQ learning),
layers.py (ops) and kernels/ (L1 pallas + oracle).
"""

from .models import MiniBert, ResNetTiny, VggTiny, convert_model  # noqa: F401
from .softpq import LutParams, inference_forward, softpq_forward  # noqa: F401
