"""L2 — training harness: dense pre-training + soft-PQ fine-tuning.

Implements the paper's full learning pipeline (§3, §6.1):

  1. train the original dense model on the task;
  2. run it over a sampled sub-dataset and *capture* every replaceable
     linear op's im2col'd input activations;
  3. k-means-initialize centroids per codebook (vanilla PQ, Eq. 1);
  4. replace the chosen ops with LUT params and fine-tune with soft-PQ
     (argmin forward / softmax backward, learned temperature, QAT), using
     separate learning rates for centroids and temperature (Table 3);
  5. evaluate with the *inference* forward (hard argmin + INT8 tables) —
     the same numerics the rust engine executes.

Build-time only. Experiments (python/experiments/*) drive these functions
with different knobs; `make artifacts` drives them via aot.py/export.py.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets, models, optim, softpq


# ------------------------------------------------------------------ losses

def softmax_xent(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def mse_loss(pred, target):
    return jnp.mean((pred[:, 0] - target) ** 2)


def accuracy(logits, labels) -> float:
    return float(jnp.mean(jnp.argmax(logits, -1) == labels))


def mae(pred, target) -> float:
    return float(jnp.mean(jnp.abs(pred[:, 0] - target)))


# ----------------------------------------------------------------- config

@dataclass
class TrainConfig:
    steps: int = 300
    batch_size: int = 64
    lr: float = 1e-3
    temperature_lr: float = 1e-1      # paper Table 3
    weight_decay: float = 0.0
    table_bits: int | None = 8
    regression: bool = False
    seed: int = 0
    log_every: int = 50
    eval_fn: object = None            # optional (params, state) -> metric
    history: list = field(default_factory=list)


# ------------------------------------------------------------- train loop

def _lr_scale_tree(params, cfg: TrainConfig):
    """Per-leaf LR scaling: temperature gets temperature_lr/lr, LUT frozen
    weight/bias get 0, everything else 1 (paper Table 3 two-LR setup)."""
    t_scale = cfg.temperature_lr / cfg.lr

    def scale_entry(p):
        if isinstance(p, softpq.LutParams):
            return softpq.LutParams(
                centroids=1.0, log_t=t_scale, weight=0.0,
                bias=None if p.bias is None else 0.0)
        return jax.tree_util.tree_map(lambda _: 1.0, p)

    return {k: scale_entry(v) for k, v in params.items()}


def train_model(model, params, state, x, y, cfg: TrainConfig,
                x_val=None, y_val=None):
    """Generic Adam training loop over (x, y). Returns (params, state)."""
    loss_core = mse_loss if cfg.regression else softmax_xent

    def loss_fn(p, s, xb, yb):
        out, ns = model.apply(p, s, xb, train=True, table_bits=cfg.table_bits)
        return loss_core(out, yb), ns

    grad_fn = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))
    sched = optim.cosine_schedule(cfg.lr, cfg.steps)
    lr_scale = _lr_scale_tree(params, cfg)
    opt = optim.adam_init(params)

    @jax.jit
    def update(p, s, o, xb, yb):
        (loss, ns), grads = jax.value_and_grad(loss_fn, has_aux=True)(p, s, xb, yb)
        new_p, new_o = optim.adam_update(
            grads, o, p, lr=sched(o.step), lr_scale=lr_scale,
            weight_decay=cfg.weight_decay, grad_clip=5.0)
        return new_p, ns, new_o, loss

    step = 0
    t0 = time.time()
    while step < cfg.steps:
        for xb, yb in datasets.batches(x, y, cfg.batch_size,
                                       seed=cfg.seed + step):
            xb = jnp.asarray(xb)
            yb = jnp.asarray(yb)
            params, state, opt, loss = update(params, state, opt, xb, yb)
            step += 1
            if step % cfg.log_every == 0 or step == cfg.steps:
                entry = {"step": step, "loss": float(loss),
                         "elapsed_s": round(time.time() - t0, 2)}
                if cfg.eval_fn is not None:
                    entry["metric"] = cfg.eval_fn(params, state)
                cfg.history.append(entry)
            if step >= cfg.steps:
                break
    return params, state


def evaluate(model, params, state, x, y, *, table_bits=8, regression=False,
             batch_size=256) -> float:
    """Inference-forward metric: accuracy (or MAE if regression)."""
    outs, labels = [], []
    for i in range(0, len(x), batch_size):
        xb = jnp.asarray(x[i:i + batch_size])
        out, _ = model.apply(params, state, xb, train=False,
                             table_bits=table_bits)
        outs.append(out)
        labels.append(y[i:i + batch_size])
    out = jnp.concatenate(outs)
    yy = jnp.asarray(np.concatenate(labels))
    return mae(out, yy) if regression else accuracy(out, yy)


def mse_vs_dense(model, dense_params, lut_params, state, x,
                 *, table_bits=8) -> float:
    """Output MSE between the original model and the LUT model (Fig. 3)."""
    xb = jnp.asarray(x)
    ref, _ = model.apply(dense_params, state, xb, train=False, table_bits=None)
    approx, _ = model.apply(lut_params, state, xb, train=False,
                            table_bits=table_bits)
    return float(jnp.mean((ref - approx) ** 2))


# --------------------------------------------------------------- captures

def capture_activations(model, params, state, x, batch_size=256):
    """Run the model eagerly, recording each linear op's 2-D input rows."""
    captures: dict[str, list] = {}
    for i in range(0, len(x), batch_size):
        cap: dict = {}
        model.apply(params, state, jnp.asarray(x[i:i + batch_size]),
                    train=False, table_bits=None, capture=cap)
        for k, v in cap.items():
            captures.setdefault(k, []).append(np.asarray(v))
    return {k: np.concatenate(v) for k, v in captures.items()}


# ------------------------------------------------------------- pipelines

@dataclass
class PipelineResult:
    model: object
    dense_params: dict
    lut_params: dict
    state: dict
    dense_metric: float
    lut_metric: float
    history: list


def lutnn_pipeline(model, x_train, y_train, x_test, y_test, *,
                   replace: list[str] | None = None,
                   n_centroids: int = 16,
                   subvec_len: int | None = None,
                   dense_cfg: TrainConfig | None = None,
                   finetune_cfg: TrainConfig | None = None,
                   n_capture: int = 1024,
                   kmeans_iters: int = 25,
                   seed: int = 0) -> PipelineResult:
    """The full LUT-NN recipe on one (model, task)."""
    dense_cfg = dense_cfg or TrainConfig()
    finetune_cfg = finetune_cfg or TrainConfig(steps=dense_cfg.steps,
                                               lr=1e-3)
    regression = dense_cfg.regression
    finetune_cfg.regression = regression

    params, state = model.init(seed)
    params, state = train_model(model, params, state, x_train, y_train,
                                dense_cfg)
    dense_metric = evaluate(model, params, state, x_test, y_test,
                            table_bits=None, regression=regression)

    captures = capture_activations(model, params, state, x_train[:n_capture])
    replace = replace if replace is not None else model.lut_layers()
    lut_params = models.convert_model(model, params, captures, replace,
                                      n_centroids=n_centroids, seed=seed,
                                      kmeans_iters=kmeans_iters,
                                      subvec_len=subvec_len)
    lut_params, state = train_model(model, lut_params, state, x_train,
                                    y_train, finetune_cfg)
    lut_metric = evaluate(model, lut_params, state, x_test, y_test,
                          table_bits=finetune_cfg.table_bits,
                          regression=regression)
    return PipelineResult(model, params, lut_params, state, dense_metric,
                          lut_metric, finetune_cfg.history)


def quick_task(task: str = "image", n_train: int = 2048, n_test: int = 512,
               seed: int = 0):
    """Small (x_train, y_train, x_test, y_test, model, regression) bundle."""
    if task == "image":
        x, y = datasets.synth_image(n_train + n_test, seed=seed)
        model = models.ResNetTiny()
        reg = False
    elif task == "speech":
        x, y = datasets.synth_speech(n_train + n_test, seed=seed)
        model = models.ResNetTiny(cin=1, n_classes=datasets.SPEECH_CLASSES)
        reg = False
    elif task == "age":
        x, y = datasets.synth_age(n_train + n_test, seed=seed)
        model = models.ResNetTiny(n_classes=1)
        reg = True
    elif task == "nlp":
        x, y = datasets.synth_nlp(n_train + n_test, seed=seed)
        model = models.MiniBert()
        reg = False
    else:
        raise ValueError(task)
    return (x[:n_train], y[:n_train], x[n_train:], y[n_train:], model, reg)
