"""L2 — model zoo: ResNet-tiny, VGG-tiny (CNNs) and mini-BERT.

Scaled-down analogues of the paper's ResNet18 / VGG11 / BERT-base
(DESIGN.md §Substitutions) that train in minutes on one CPU core while
keeping the structural features the paper's technique interacts with:
residual blocks, 3x3 + 1x1 convs, BN, attention + FFN linears.

Every model exposes:
  init(seed)                          -> (params, state)
  apply(params, state, x, train=..., table_bits=..., capture=...)
                                      -> (output, new_state)
  lut_layers()                        -> ordered list of replaceable linear
                                         op names (first conv excluded, as
                                         in the paper §6.1)
  convert(params, captures, names, K) -> params with named ops LUT-ized

Shape-exact configs of the *paper's* models (for the analytic cost model
and the rust kernels benches) live in rust/src/nn/models.rs; these python
models are the trainable stand-ins.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import layers, softpq

Params = dict[str, Any]


# ============================================================ CNN builders

class ResNetTiny:
    """3-stage pre-downscaled ResNet (ResNet20-family shape, thin).

    stem conv3x3(cin->w0) [kept dense — paper keeps first layer dense],
    per stage: BasicBlock(w, stride) x1 with identity/projection skip,
    then GAP + fc. Widths default (8, 16, 32).
    """

    def __init__(self, cin=3, widths=(8, 16, 32), n_classes=10):
        self.cin = cin
        self.widths = widths
        self.n_classes = n_classes

    # ---- construction -----------------------------------------------
    def init(self, seed: int = 0):
        p: Params = {}
        s: Params = {}
        w0 = self.widths[0]
        p["stem"] = layers.conv2d_init(seed, self.cin, w0, 3)
        p["stem_bn"], s["stem_bn"] = layers.bn_init(w0)
        cin = w0
        for i, w in enumerate(self.widths):
            blk = f"b{i}"
            p[f"{blk}c1"] = layers.conv2d_init(seed + 10 * i + 1, cin, w, 3)
            p[f"{blk}bn1"], s[f"{blk}bn1"] = layers.bn_init(w)
            p[f"{blk}c2"] = layers.conv2d_init(seed + 10 * i + 2, w, w, 3)
            p[f"{blk}bn2"], s[f"{blk}bn2"] = layers.bn_init(w)
            if cin != w or i > 0:
                p[f"{blk}sc"] = layers.conv2d_init(seed + 10 * i + 3, cin, w, 1)
                p[f"{blk}scbn"], s[f"{blk}scbn"] = layers.bn_init(w)
            cin = w
        p["fc"] = layers.linear_init(seed + 99, self.widths[-1], self.n_classes)
        return p, s

    def lut_layers(self):
        names = []
        for i in range(len(self.widths)):
            names += [f"b{i}c1", f"b{i}c2"]
            names.append(f"b{i}sc")
        names.append("fc")
        return names

    def conv_geometry(self, name: str) -> int:
        """kernel size of a named conv (for V selection)."""
        if name.endswith("sc"):
            return 1
        if name == "fc":
            return 0
        return 3

    # ---- forward ------------------------------------------------------
    def apply(self, p, s, x, *, train=False, table_bits=8, capture=None):
        ns = dict(s)
        y = layers.apply_conv(p["stem"], x, k=3, stride=1, train=train,
                              table_bits=table_bits, capture=capture,
                              name="stem")
        y, ns["stem_bn"] = layers.apply_bn(p["stem_bn"], s["stem_bn"], y,
                                           train=train)
        y = jax.nn.relu(y)
        for i, _w in enumerate(self.widths):
            blk = f"b{i}"
            stride = 1 if i == 0 else 2
            ident = y
            z = layers.apply_conv(p[f"{blk}c1"], y, k=3, stride=stride,
                                  train=train, table_bits=table_bits,
                                  capture=capture, name=f"{blk}c1")
            z, ns[f"{blk}bn1"] = layers.apply_bn(p[f"{blk}bn1"],
                                                 s[f"{blk}bn1"], z, train=train)
            z = jax.nn.relu(z)
            z = layers.apply_conv(p[f"{blk}c2"], z, k=3, stride=1,
                                  train=train, table_bits=table_bits,
                                  capture=capture, name=f"{blk}c2")
            z, ns[f"{blk}bn2"] = layers.apply_bn(p[f"{blk}bn2"],
                                                 s[f"{blk}bn2"], z, train=train)
            if f"{blk}sc" in p:
                ident = layers.apply_conv(p[f"{blk}sc"], ident, k=1,
                                          stride=stride, train=train,
                                          table_bits=table_bits,
                                          capture=capture, name=f"{blk}sc")
                ident, ns[f"{blk}scbn"] = layers.apply_bn(
                    p[f"{blk}scbn"], s[f"{blk}scbn"], ident, train=train)
            y = jax.nn.relu(z + ident)
        feat = layers.global_avg_pool(y)
        out = layers.apply_linear(p["fc"], feat, train=train,
                                  table_bits=table_bits, capture=capture,
                                  name="fc")
        return out, ns


class VggTiny:
    """VGG-style plain conv stack: conv-bn-relu x4 with pooling, then fc."""

    def __init__(self, cin=3, widths=(8, 16, 32, 32), n_classes=10):
        self.cin = cin
        self.widths = widths
        self.n_classes = n_classes

    def init(self, seed: int = 0):
        p: Params = {}
        s: Params = {}
        cin = self.cin
        for i, w in enumerate(self.widths):
            p[f"c{i}"] = layers.conv2d_init(seed + i, cin, w, 3)
            p[f"bn{i}"], s[f"bn{i}"] = layers.bn_init(w)
            cin = w
        p["fc"] = layers.linear_init(seed + 99, self.widths[-1], self.n_classes)
        return p, s

    def lut_layers(self):
        return [f"c{i}" for i in range(1, len(self.widths))] + ["fc"]

    def conv_geometry(self, name: str) -> int:
        return 0 if name == "fc" else 3

    def apply(self, p, s, x, *, train=False, table_bits=8, capture=None):
        ns = dict(s)
        y = x
        for i in range(len(self.widths)):
            y = layers.apply_conv(p[f"c{i}"], y, k=3, stride=1, train=train,
                                  table_bits=table_bits, capture=capture,
                                  name=f"c{i}")
            y, ns[f"bn{i}"] = layers.apply_bn(p[f"bn{i}"], s[f"bn{i}"], y,
                                              train=train)
            y = jax.nn.relu(y)
            if i % 2 == 1:
                y = layers.max_pool(y)
        feat = layers.global_avg_pool(y)
        out = layers.apply_linear(p["fc"], feat, train=train,
                                  table_bits=table_bits, capture=capture,
                                  name="fc")
        return out, ns


# ============================================================== mini-BERT

class MiniBert:
    """Tiny BERT-style encoder for the GLUE-analogue tasks.

    n_layers blocks of MHA + FFN with LayerNorm (post-LN), mean pooling,
    classification/regression head. LUT-replaceable ops: per block the
    q/k/v/o projections and the two FFN linears (paper replaces the FC
    operators of the last-k layers; attention itself stays exact — §8).
    """

    def __init__(self, vocab=64, seq_len=16, d=32, n_heads=2, d_ff=64,
                 n_layers=4, n_out=4):
        self.vocab, self.seq_len, self.d = vocab, seq_len, d
        self.n_heads, self.d_ff, self.n_layers = n_heads, d_ff, n_layers
        self.n_out = n_out

    def init(self, seed: int = 0):
        rng = np.random.default_rng(seed)
        p: Params = {
            "tok_emb": jnp.asarray(
                rng.standard_normal((self.vocab, self.d)) * 0.1, jnp.float32),
            "pos_emb": jnp.asarray(
                rng.standard_normal((self.seq_len, self.d)) * 0.1, jnp.float32),
        }
        for i in range(self.n_layers):
            for nm, (di, do) in {
                "q": (self.d, self.d), "k": (self.d, self.d),
                "v": (self.d, self.d), "o": (self.d, self.d),
                "f1": (self.d, self.d_ff), "f2": (self.d_ff, self.d),
            }.items():
                p[f"l{i}{nm}"] = layers.linear_init(seed + 7 * i + hash(nm) % 97,
                                                    di, do)
            p[f"l{i}ln1"] = layers.ln_init(self.d)
            p[f"l{i}ln2"] = layers.ln_init(self.d)
        p["head"] = layers.linear_init(seed + 999, self.d, self.n_out)
        return p, {}

    def lut_layers(self):
        names = []
        for i in range(self.n_layers):
            names += [f"l{i}{nm}" for nm in ("q", "k", "v", "o", "f1", "f2")]
        return names

    def lut_layers_last(self, k_layers: int):
        """Ops of the last k transformer layers (paper default: last 6 of 12;
        here last k of n_layers)."""
        names = []
        for i in range(self.n_layers - k_layers, self.n_layers):
            names += [f"l{i}{nm}" for nm in ("q", "k", "v", "o", "f1", "f2")]
        return names

    def conv_geometry(self, name: str) -> int:
        return 0

    def apply(self, p, s, tokens, *, train=False, table_bits=8, capture=None):
        n, t = tokens.shape
        h = p["tok_emb"][tokens] + p["pos_emb"][None, :t, :]
        nh, dh = self.n_heads, self.d // self.n_heads
        for i in range(self.n_layers):
            def lin(nm, x2d):
                return layers.apply_linear(
                    p[f"l{i}{nm}"], x2d, train=train, table_bits=table_bits,
                    capture=capture, name=f"l{i}{nm}")
            flat = h.reshape(n * t, self.d)
            q = lin("q", flat).reshape(n, t, nh, dh).transpose(0, 2, 1, 3)
            k = lin("k", flat).reshape(n, t, nh, dh).transpose(0, 2, 1, 3)
            v = lin("v", flat).reshape(n, t, nh, dh).transpose(0, 2, 1, 3)
            att = jax.nn.softmax(q @ k.transpose(0, 1, 3, 2) / np.sqrt(dh),
                                 axis=-1)
            ctx = (att @ v).transpose(0, 2, 1, 3).reshape(n * t, self.d)
            h = layers.apply_ln(p[f"l{i}ln1"],
                                h + lin("o", ctx).reshape(n, t, self.d))
            flat = h.reshape(n * t, self.d)
            ff = lin("f2", jax.nn.gelu(lin("f1", flat)))
            h = layers.apply_ln(p[f"l{i}ln2"], h + ff.reshape(n, t, self.d))
        pooled = jnp.mean(h, axis=1)
        out = layers.apply_linear(p["head"], pooled, train=train,
                                  table_bits=table_bits, capture=capture,
                                  name="head")
        return out, s


# ===================================================== conversion helper

def convert_model(model, params, captures: dict[str, np.ndarray],
                  names: list[str], *, n_centroids: int = 16,
                  seed: int = 0, kmeans_iters: int = 25,
                  subvec_len: int | None = None) -> Params:
    """Replace named linear ops with k-means-initialized LUT params."""
    new = dict(params)
    for nm in names:
        if nm not in params:
            continue
        acts = np.asarray(captures[nm])
        d = np.asarray(params[nm]["w"]).shape[0]
        v = subvec_len or layers.codebook_geometry(d, model.conv_geometry(nm))
        new[nm] = layers.to_lut(params[nm], acts, n_centroids=n_centroids,
                                subvec_len=v, seed=seed,
                                kmeans_iters=kmeans_iters)
    return new
