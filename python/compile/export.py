"""`.lutnn` model-bundle writer (format v1 — see DESIGN.md).

Layout (little-endian):
  magic  b"LUTN"
  u32    version (1)
  u32    header JSON length
  bytes  header JSON (utf-8)
  ...    blobs, each aligned to 64 bytes, in header order

The header carries the execution graph (an instruction list the rust
graph executor interprets: conv/bn/relu/maxpool/gap/linear/save/restore/
add) plus per-layer blob descriptors {offset, shape, dtype}. LUT layers
store centroids f32[C,K,V], quantized table i8[C,K,M] (or i32 for
table_bits > 8 paths), per-codebook scale f32[C], temperature, bias.

The rust reader is rust/src/model_fmt/; round-trip is tested on both
sides (python/tests/test_export.py, rust model_fmt tests).
"""

from __future__ import annotations

import json
import struct

import numpy as np

from . import softpq
from .kernels import ref

MAGIC = b"LUTN"
VERSION = 1
ALIGN = 64

_DTYPES = {"f32": np.float32, "i8": np.int8, "i32": np.int32}


class BundleWriter:
    def __init__(self, model_name: str, input_shape, graph: list[dict],
                 meta: dict | None = None):
        self.header = {
            "model": model_name,
            "input_shape": list(input_shape),
            "graph": graph,
            "layers": {},
            "meta": meta or {},
        }
        self.blobs: list[np.ndarray] = []

    def _add_blob(self, arr: np.ndarray, dtype: str) -> dict:
        arr = np.ascontiguousarray(arr.astype(_DTYPES[dtype]))
        self.blobs.append(arr)
        return {"index": len(self.blobs) - 1, "shape": list(arr.shape),
                "dtype": dtype}

    def add_dense(self, name: str, w: np.ndarray, b: np.ndarray | None):
        entry = {"kind": "dense", "w": self._add_blob(w, "f32")}
        if b is not None:
            entry["b"] = self._add_blob(b, "f32")
        self.header["layers"][name] = entry

    def add_lut(self, name: str, params: softpq.LutParams,
                table_bits: int = 8):
        p = np.asarray(params.centroids, np.float32)
        table = np.asarray(ref.build_table_ref(params.centroids,
                                               params.weight))
        q, scale = ref.quantize_table_ref(table, table_bits)
        q = np.asarray(q)
        entry = {
            "kind": "lut",
            "table_bits": table_bits,
            "temperature": float(np.exp(params.log_t)),
            "centroids": self._add_blob(p, "f32"),
            "table_q": self._add_blob(q, "i8" if table_bits <= 8 else "i32"),
            "scale": self._add_blob(np.asarray(scale), "f32"),
        }
        if params.bias is not None:
            entry["b"] = self._add_blob(np.asarray(params.bias), "f32")
        self.header["layers"][name] = entry

    def add_bn(self, name: str, gamma, beta, mean, var):
        self.header["layers"][name] = {
            "kind": "bn",
            "gamma": self._add_blob(np.asarray(gamma), "f32"),
            "beta": self._add_blob(np.asarray(beta), "f32"),
            "mean": self._add_blob(np.asarray(mean), "f32"),
            "var": self._add_blob(np.asarray(var), "f32"),
        }

    def add_raw(self, name: str, kind: str, **arrays):
        entry = {"kind": kind}
        for k, arr in arrays.items():
            entry[k] = self._add_blob(np.asarray(arr), "f32")
        self.header["layers"][name] = entry

    def write(self, path: str):
        # First pass: compute blob offsets (relative to file start).
        header_json = b"{}"
        # Iterate: header length changes offsets; fix-point in two passes
        # by computing with a placeholder then patching exact offsets.
        offsets = []

        def layout(header_len: int):
            pos = 4 + 4 + 4 + header_len
            offs = []
            for arr in self.blobs:
                pos = (pos + ALIGN - 1) // ALIGN * ALIGN
                offs.append(pos)
                pos += arr.nbytes
            return offs, pos

        # Install offsets into header entries via blob index.
        def patch(offs):
            def visit(entry):
                for v in entry.values():
                    if isinstance(v, dict) and "index" in v:
                        v["offset"] = offs[v["index"]]
            for entry in self.header["layers"].values():
                visit(entry)

        # Two-pass fixpoint: JSON length may change once offsets are added;
        # iterate until stable (bounded: offsets only grow monotonically).
        header_len = 0
        for _ in range(8):
            offs, _total = layout(header_len)
            patch(offs)
            header_json = json.dumps(self.header,
                                     separators=(",", ":")).encode()
            if len(header_json) == header_len:
                break
            header_len = len(header_json)
        offs, total = layout(len(header_json))
        patch(offs)
        header_json = json.dumps(self.header, separators=(",", ":")).encode()
        assert len(header_json) == header_len, "header fixpoint failed"

        buf = bytearray(total)
        buf[0:4] = MAGIC
        struct.pack_into("<II", buf, 4, VERSION, len(header_json))
        buf[12:12 + len(header_json)] = header_json
        for arr, off in zip(self.blobs, offs):
            raw = arr.tobytes()
            buf[off:off + len(raw)] = raw
        with open(path, "wb") as f:
            f.write(bytes(buf))
        return total


def read_bundle(path: str):
    """Python-side reader (used by tests to round-trip)."""
    with open(path, "rb") as f:
        data = f.read()
    assert data[:4] == MAGIC, "bad magic"
    version, hlen = struct.unpack_from("<II", data, 4)
    assert version == VERSION
    header = json.loads(data[12:12 + hlen].decode())
    arrays: dict[str, dict[str, np.ndarray]] = {}
    for name, entry in header["layers"].items():
        arrays[name] = {}
        for k, v in entry.items():
            if isinstance(v, dict) and "offset" in v:
                dt = _DTYPES[v["dtype"]]
                n = int(np.prod(v["shape"])) if v["shape"] else 1
                arr = np.frombuffer(data, dtype=dt, count=n,
                                    offset=v["offset"]).reshape(v["shape"])
                arrays[name][k] = arr
    return header, arrays


# ------------------------------------------------- model-specific exports

def resnet_tiny_graph(model) -> list[dict]:
    g: list[dict] = [
        {"op": "conv", "layer": "stem", "k": 3, "stride": 1},
        {"op": "bn", "layer": "stem_bn"},
        {"op": "relu"},
    ]
    for i in range(len(model.widths)):
        blk = f"b{i}"
        stride = 1 if i == 0 else 2
        g += [
            {"op": "save", "slot": 0},
            {"op": "conv", "layer": f"{blk}c1", "k": 3, "stride": stride},
            {"op": "bn", "layer": f"{blk}bn1"},
            {"op": "relu"},
            {"op": "conv", "layer": f"{blk}c2", "k": 3, "stride": 1},
            {"op": "bn", "layer": f"{blk}bn2"},
            {"op": "save", "slot": 1},
            {"op": "restore", "slot": 0},
        ]
        g += [
            {"op": "conv", "layer": f"{blk}sc", "k": 1, "stride": stride},
            {"op": "bn", "layer": f"{blk}scbn"},
        ]
        g += [
            {"op": "add", "slot": 1},
            {"op": "relu"},
        ]
    g += [{"op": "gap"}, {"op": "linear", "layer": "fc"}]
    return g


def vgg_tiny_graph(model) -> list[dict]:
    g: list[dict] = []
    for i in range(len(model.widths)):
        g += [
            {"op": "conv", "layer": f"c{i}", "k": 3, "stride": 1},
            {"op": "bn", "layer": f"bn{i}"},
            {"op": "relu"},
        ]
        if i % 2 == 1:
            g.append({"op": "maxpool", "k": 2, "stride": 2})
    g += [{"op": "gap"}, {"op": "linear", "layer": "fc"}]
    return g


def export_cnn(model, params, state, path: str, *, name: str,
               input_shape, table_bits: int = 8, meta=None):
    """Write a trained (possibly LUT-converted) CNN as a .lutnn bundle."""
    from . import models as _models

    if isinstance(model, _models.ResNetTiny):
        graph = resnet_tiny_graph(model)
    else:
        graph = vgg_tiny_graph(model)
    # Drop graph entries whose layer is absent (e.g. first block w/o sc).
    graph = [op for op in graph
             if "layer" not in op or op["layer"] in params]
    w = BundleWriter(name, input_shape, graph, meta=meta)
    for lname, p in params.items():
        if isinstance(p, softpq.LutParams):
            w.add_lut(lname, p, table_bits=table_bits)
        elif lname in state:  # bn
            w.add_bn(lname, p["gamma"], p["beta"],
                     state[lname]["mean"], state[lname]["var"])
        else:
            w.add_dense(lname, np.asarray(p["w"]), np.asarray(p["b"]))
    return w.write(path)


def export_bert(model, params, path: str, *, name: str = "mini_bert",
                table_bits: int = 8, meta=None):
    """Write a (possibly LUT-converted) MiniBert as a .lutnn bundle."""
    graph = [{"op": "bert"}]
    m = dict(meta or {})
    m.update({"vocab": model.vocab, "seq_len": model.seq_len, "d": model.d,
              "n_heads": model.n_heads, "d_ff": model.d_ff,
              "n_layers": model.n_layers, "n_out": model.n_out})
    w = BundleWriter(name, [1, model.seq_len], graph, meta=m)
    w.add_raw("emb", "embedding", tok=np.asarray(params["tok_emb"]),
              pos=np.asarray(params["pos_emb"]))
    for lname, p in params.items():
        if lname in ("tok_emb", "pos_emb"):
            continue
        if isinstance(p, softpq.LutParams):
            w.add_lut(lname, p, table_bits=table_bits)
        elif "gamma" in p:
            w.add_raw(lname, "ln", gamma=p["gamma"], beta=p["beta"])
        else:
            w.add_dense(lname, np.asarray(p["w"]), np.asarray(p["b"]))
    return w.write(path)
