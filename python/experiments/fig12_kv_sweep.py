"""Fig. 12 — accuracy and GFLOPs vs centroid count K and sub-vector
length V.

Paper result: accuracy improves with more centroids K and degrades with
longer sub-vectors V; GFLOPs grow with K and shrink with V (Table 1
formulas). (K, V) = (16, 9) balances both.
"""

from __future__ import annotations

from compile import models, train
from experiments import common


def flops_estimate(model, params, k, v_map):
    """Analytic LUT FLOPs for the tiny model (same Table 1 formulas the
    rust cost model implements; duplicated here for the sweep output)."""
    import numpy as np

    total = 0
    for name, p in params.items():
        if not isinstance(p, dict) or "w" not in p:
            continue
        w = np.asarray(p["w"])
        d, m = w.shape
        # rows per inference at 16x16 input: stem/b0 256, b1 64, b2 16, fc 1
        n = {"stem": 256, "b0": 256, "b1": 64, "b2": 16, "fc": 1}[
            name[:2] if name[:2] in ("b0", "b1", "b2") else name[:4]
            if name[:4] == "stem" else "fc"]
        if name in v_map:
            v = v_map[name]
            total += n * d * k + n * m * (d // v)
        else:
            total += n * d * m
    return total / 1e9


def run_setting(model, params, state, caps, x_tr, y_tr, x_te, y_te,
                k, v, ft_steps):
    names = [n for n in model.lut_layers() if n in params]
    # keep only ops whose D is divisible by v
    import numpy as np

    names = [n for n in names if np.asarray(params[n]["w"]).shape[0] % v == 0]
    lut = models.convert_model(model, params, caps, names, n_centroids=k,
                               kmeans_iters=8, subvec_len=v)
    cfg = train.TrainConfig(steps=ft_steps, lr=1e-3)
    lut, s2 = train.train_model(model, lut, dict(state), x_tr, y_tr, cfg)
    acc = train.evaluate(model, lut, s2, x_te, y_te, table_bits=8)
    gflops = flops_estimate(model, params, k, {n: v for n in names})
    return acc, gflops, len(names)


def main():
    dense_steps, ft_steps, n_train = common.budget()
    ft_steps = max(ft_steps // 2, 50)  # sweep has many settings
    x_tr, y_tr, x_te, y_te, model, _ = train.quick_task(
        "image", n_train=n_train, n_test=512)
    params, state = model.init(0)
    with common.Timer("dense training"):
        params, state = train.train_model(
            model, params, state, x_tr, y_tr,
            train.TrainConfig(steps=dense_steps, lr=2e-3))
    base = train.evaluate(model, params, state, x_te, y_te, table_bits=None)
    caps = train.capture_activations(model, params, state, x_tr[:512])

    rows = []
    # K sweep at V=9 (paper: accuracy grows with K)
    for k in [4, 8, 16, 32]:
        with common.Timer(f"K={k}"):
            acc, gf, n_ops = run_setting(model, params, state, caps, x_tr,
                                         y_tr, x_te, y_te, k, 9, ft_steps)
        rows.append([f"K={k},V=9", f"{acc:.4f}", f"{gf:.5f}", n_ops])
    # V sweep at K=16 (paper: accuracy degrades with V)
    for v in [3, 9, 18]:
        with common.Timer(f"V={v}"):
            acc, gf, n_ops = run_setting(model, params, state, caps, x_tr,
                                         y_tr, x_te, y_te, 16, v, ft_steps)
        rows.append([f"K=16,V={v}", f"{acc:.4f}", f"{gf:.5f}", n_ops])
    rows.append(["dense", f"{base:.4f}", f"{flops_estimate(model, params, 0, {}):.5f}", 0])

    common.save_rows("fig12_kv_sweep",
                     ["setting", "accuracy", "gflops", "n_lut_ops"], rows)


if __name__ == "__main__":
    main()
