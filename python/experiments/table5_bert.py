"""Table 5 — mini-BERT on the GLUE-analogue suite: classification
(synth-nlp, 4 classes ~ SST/QNLI stand-in) and regression (synth-sts,
STS-B stand-in), LUT-NN (last-half layers replaced) vs original.

Paper result: average ~1.9 points below BERT-base across GLUE tasks.
"""

from __future__ import annotations

import numpy as np

from compile import datasets, models, train
from experiments import common


def run_cls():
    dense_steps, ft_steps, n_train = common.budget()
    x_tr, y_tr, x_te, y_te, model, _ = train.quick_task(
        "nlp", n_train=n_train, n_test=512)
    res = train.lutnn_pipeline(
        model, x_tr, y_tr, x_te, y_te,
        replace=model.lut_layers_last(model.n_layers // 2),
        dense_cfg=train.TrainConfig(steps=dense_steps, lr=2e-3),
        finetune_cfg=train.TrainConfig(steps=ft_steps, lr=1e-3),
        n_capture=512, kmeans_iters=10)
    return res.dense_metric, res.lut_metric


def run_sts():
    dense_steps, ft_steps, n_train = common.budget()
    x, y = datasets.synth_sts(n_train + 512, seed=0)
    x_tr, y_tr, x_te, y_te = x[:n_train], y[:n_train], x[n_train:], y[n_train:]
    model = models.MiniBert(vocab=16, n_out=1)
    res = train.lutnn_pipeline(
        model, x_tr, y_tr, x_te, y_te,
        replace=model.lut_layers_last(model.n_layers // 2),
        dense_cfg=train.TrainConfig(steps=dense_steps, lr=2e-3,
                                    regression=True),
        finetune_cfg=train.TrainConfig(steps=ft_steps, lr=1e-3,
                                       regression=True),
        n_capture=512, kmeans_iters=10)
    return res.dense_metric, res.lut_metric  # MAE, lower better


def main():
    rows = []
    with common.Timer("synth-nlp classification"):
        d, l = run_cls()
    rows.append(["synth-nlp (acc)", f"{d:.4f}", f"{l:.4f}"])
    with common.Timer("synth-sts regression"):
        d, l = run_sts()
    rows.append(["synth-sts (MAE)", f"{d:.4f}", f"{l:.4f}"])
    common.save_rows("table5_bert", ["task", "BERT base", "LUT-NN"], rows)
    print("\nshape check (paper): LUT-NN within ~2 points of the original.")


if __name__ == "__main__":
    main()
