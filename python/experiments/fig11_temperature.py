"""Fig. 11 — soft-PQ learning curves under three temperature strategies:
learned temperature (ours), fixed t=1, and annealing 1 -> 0.1.

Paper result: learned temperature reaches the highest accuracy and
converges fastest (94.4% vs 91.55% annealed vs 89.85% fixed on
ResNet18/CIFAR10).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from compile import models, train
from experiments import common


def finetune(model, lut0, state, x_tr, y_tr, x_te, y_te, mode: str,
             steps: int):
    cfg = train.TrainConfig(steps=steps, lr=1e-3, log_every=max(steps // 12, 1))
    if mode == "fixed":
        cfg.temperature_lr = 0.0          # log_t frozen at init (t = 1)
    lut = {k: v for k, v in lut0.items()}
    if mode == "anneal":
        # piecewise: retrain in 4 chunks, setting t manually 1 -> 0.1
        curve = []
        chunk = steps // 4
        for i, t_val in enumerate(np.geomspace(1.0, 0.1, 4)):
            for name, p in list(lut.items()):
                if hasattr(p, "log_t"):
                    lut[name] = p._replace(
                        log_t=jnp.asarray(np.log(t_val), jnp.float32))
            c = train.TrainConfig(steps=chunk, lr=1e-3, temperature_lr=0.0,
                                  log_every=max(chunk // 3, 1))
            lut, state = train.train_model(model, lut, state, x_tr, y_tr, c)
            acc = train.evaluate(model, lut, state, x_te, y_te, table_bits=8)
            curve.append(((i + 1) * chunk, acc))
        return curve
    evals = []

    def eval_fn(p, s):
        return train.evaluate(model, p, s, x_te, y_te, table_bits=8)

    cfg.eval_fn = eval_fn
    lut, state = train.train_model(model, lut, state, x_tr, y_tr, cfg)
    for h in cfg.history:
        if "metric" in h:
            evals.append((h["step"], h["metric"]))
    return evals


def main():
    dense_steps, ft_steps, n_train = common.budget()
    x_tr, y_tr, x_te, y_te, model, _ = train.quick_task(
        "image", n_train=n_train, n_test=512)
    params, state = model.init(0)
    with common.Timer("dense training"):
        params, state = train.train_model(
            model, params, state, x_tr, y_tr,
            train.TrainConfig(steps=dense_steps, lr=2e-3))
    caps = train.capture_activations(model, params, state, x_tr[:512])
    lut0 = models.convert_model(model, params, caps, model.lut_layers(),
                                n_centroids=16, kmeans_iters=10)

    rows = []
    finals = {}
    for mode in ["learned", "fixed", "anneal"]:
        with common.Timer(f"finetune[{mode}]"):
            curve = finetune(model, lut0, dict(state), x_tr, y_tr, x_te,
                             y_te, mode, ft_steps)
        for step, acc in curve:
            rows.append([mode, step, f"{acc:.4f}"])
        finals[mode] = curve[-1][1] if curve else float("nan")
        print(f"{mode}: final acc {finals[mode]:.4f}")

    common.save_rows("fig11_temperature", ["mode", "step", "accuracy"], rows)
    print("\nshape check (paper: learned > anneal > fixed):",
          {k: round(v, 4) for k, v in finals.items()})


if __name__ == "__main__":
    main()
