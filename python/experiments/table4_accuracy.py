"""Table 4 — LUT-NN vs MADDNESS vs original accuracy across tasks and
models (synthetic-task substitution, DESIGN.md).

Paper result: LUT-NN lands within ~1-2.4 points of the original on every
task while direct MADDNESS collapses to near-chance; on the regression
task (UTKFace analogue) LUT-NN can even beat the original (MAE, lower
is better).
"""

from __future__ import annotations

import numpy as np

from compile import layers as L
from compile import maddness, models, train
from experiments import common

TASKS = [
    ("synth-image", "image", "ResNetTiny", False),
    ("synth-image", "image-vgg", "VggTiny", False),
    ("synth-speech", "speech", "ResNetTiny", False),
    ("synth-age (MAE)", "age", "ResNetTiny", True),
]


def run_task(tag, task, regression):
    dense_steps, ft_steps, n_train = common.budget()
    if task == "image-vgg":
        x_tr, y_tr, x_te, y_te, _, _ = train.quick_task("image",
                                                        n_train=n_train,
                                                        n_test=512)
        model = models.VggTiny()
    else:
        x_tr, y_tr, x_te, y_te, model, _ = train.quick_task(
            task, n_train=n_train, n_test=512)
    dense_cfg = train.TrainConfig(steps=dense_steps, lr=2e-3,
                                  regression=regression)
    ft_cfg = train.TrainConfig(steps=ft_steps, lr=1e-3,
                               regression=regression)
    res = train.lutnn_pipeline(model, x_tr, y_tr, x_te, y_te,
                               dense_cfg=dense_cfg, finetune_cfg=ft_cfg,
                               n_capture=512, kmeans_iters=10)
    # MADDNESS baseline: replace the same ops, no fine-tuning
    caps = train.capture_activations(model, res.dense_params, res.state,
                                     x_tr[:512])
    md = dict(res.dense_params)
    for nm in model.lut_layers():
        if nm not in md:
            continue
        w = np.asarray(res.dense_params[nm]["w"])
        v = L.codebook_geometry(w.shape[0], model.conv_geometry(nm))
        md[nm] = maddness.learn_maddness(
            np.asarray(caps[nm]), w, np.asarray(res.dense_params[nm]["b"]),
            w.shape[0] // v, depth=4)
    md_metric = train.evaluate(model, md, res.state, x_te, y_te,
                               table_bits=None, regression=regression)
    return res.lut_metric, md_metric, res.dense_metric


def main():
    rows = []
    for tag, task, model_name, regression in TASKS:
        with common.Timer(f"{tag}/{model_name}"):
            lut, md, dense = run_task(tag, task, regression)
        rows.append([tag, model_name, f"{lut:.4f}", f"{md:.4f}",
                     f"{dense:.4f}"])
        print(f"{tag} {model_name}: lut {lut:.4f} maddness {md:.4f} "
              f"dense {dense:.4f}")
    common.save_rows("table4_accuracy",
                     ["dataset", "model", "LUT-NN", "MADDNESS", "baseline"],
                     rows)
    print("\nshape check (paper): LUT-NN ~ baseline >> MADDNESS "
          "(MAE: LUT-NN <= baseline << MADDNESS).")


if __name__ == "__main__":
    main()
