"""§6.3 scalar-quantization ablation — accuracy with FP32 / INT8 / INT4
lookup tables under quantization-aware soft-PQ training.

Paper result (ResNet18/CIFAR10): 94.44 (FP32) / 94.40 (INT8) /
94.27 (INT4) — QAT makes the quantized tables nearly free.
"""

from __future__ import annotations

from compile import models, train
from experiments import common


def main():
    dense_steps, ft_steps, n_train = common.budget()
    x_tr, y_tr, x_te, y_te, model, _ = train.quick_task(
        "image", n_train=n_train, n_test=512)
    params, state = model.init(0)
    with common.Timer("dense training"):
        params, state = train.train_model(
            model, params, state, x_tr, y_tr,
            train.TrainConfig(steps=dense_steps, lr=2e-3))
    caps = train.capture_activations(model, params, state, x_tr[:512])
    lut0 = models.convert_model(model, params, caps, model.lut_layers(),
                                n_centroids=16, kmeans_iters=10)

    rows = []
    for bits in [None, 8, 4]:
        label = "FP32" if bits is None else f"INT{bits}"
        cfg = train.TrainConfig(steps=ft_steps, lr=1e-3, table_bits=bits)
        with common.Timer(f"finetune {label}"):
            lut, s2 = train.train_model(model, dict(lut0), dict(state),
                                        x_tr, y_tr, cfg)
        acc = train.evaluate(model, lut, s2, x_te, y_te, table_bits=bits)
        rows.append([label, f"{acc:.4f}"])
        print(f"{label}: {acc:.4f}")

    common.save_rows("quant_ablation", ["table_format", "accuracy"], rows)
    print("\nshape check (paper): FP32 ~ INT8 ~ INT4 within ~0.2 points.")


if __name__ == "__main__":
    main()
