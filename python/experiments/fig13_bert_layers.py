"""Fig. 13 — mini-BERT accuracy vs number of transformer layers whose
linear ops are replaced by table lookup (replacing from the LAST layer
toward the front, with soft-PQ fine-tuning).

Paper result (BERT/STS-B): accuracy holds for the last ~9 layers and
drops sharply when the front layers are replaced (the paper keeps the
first layers dense; replacing the first two costs 80% accuracy).
"""

from __future__ import annotations

from compile import models, train
from experiments import common


def main():
    dense_steps, ft_steps, n_train = common.budget()
    x_tr, y_tr, x_te, y_te, model, _ = train.quick_task(
        "nlp", n_train=n_train, n_test=512)
    params, state = model.init(0)
    with common.Timer("dense training"):
        params, state = train.train_model(
            model, params, state, x_tr, y_tr,
            train.TrainConfig(steps=dense_steps, lr=2e-3))
    base = train.evaluate(model, params, state, x_te, y_te, table_bits=None)
    caps = train.capture_activations(model, params, state, x_tr[:512])

    rows = [["0", f"{base:.4f}"]]
    for k_layers in range(1, model.n_layers + 1):
        names = model.lut_layers_last(k_layers)
        lut = models.convert_model(model, params, caps, names,
                                   n_centroids=16, kmeans_iters=8)
        cfg = train.TrainConfig(steps=ft_steps, lr=1e-3)
        with common.Timer(f"replace last {k_layers}"):
            lut, s2 = train.train_model(model, lut, dict(state), x_tr, y_tr,
                                        cfg)
        acc = train.evaluate(model, lut, s2, x_te, y_te, table_bits=8)
        rows.append([str(k_layers), f"{acc:.4f}"])
        print(f"last {k_layers} layers replaced: acc {acc:.4f}")

    common.save_rows("fig13_bert_layers", ["layers_replaced", "accuracy"],
                     rows)
    print("\nshape check (paper): flat for last layers, drop at the front.")


if __name__ == "__main__":
    main()
