"""Fig. 3 — accuracy & output MSE vs number of layers replaced by
PQ-based AMM WITHOUT fine-tuning, for (a) vanilla PQ (k-means argmin) and
(b) MADDNESS (hash-tree encoding).

Paper result: accuracy collapses toward chance as more layers are
replaced (MSE accumulates layer by layer); MADDNESS collapses faster
than vanilla PQ because hashing has higher quantization error.
"""

from __future__ import annotations

import numpy as np

from compile import maddness, models, train
from experiments import common


def main():
    dense_steps, _, n_train = common.budget()
    x_tr, y_tr, x_te, y_te, model, _ = train.quick_task(
        "image", n_train=n_train, n_test=512)
    params, state = model.init(0)
    with common.Timer("dense training"):
        params, state = train.train_model(
            model, params, state, x_tr, y_tr,
            train.TrainConfig(steps=dense_steps, lr=2e-3))
    base_acc = train.evaluate(model, params, state, x_te, y_te,
                              table_bits=None)
    caps = train.capture_activations(model, params, state, x_tr[:512])

    # replace from the LAST layer toward the FRONT (paper's sweep order)
    layer_order = [n for n in reversed(model.lut_layers()) if n in params]
    rows = [["0", f"{base_acc:.4f}", "0.0", f"{base_acc:.4f}", "0.0"]]
    for n_replaced in range(1, len(layer_order) + 1):
        names = layer_order[:n_replaced]
        # vanilla PQ (k-means, argmin encode), no fine-tuning
        pq_params = models.convert_model(model, params, caps, names,
                                         n_centroids=16, kmeans_iters=10)
        acc_pq = train.evaluate(model, pq_params, state, x_te, y_te,
                                table_bits=None)
        mse_pq = train.mse_vs_dense(model, params, pq_params, state,
                                    x_te[:128], table_bits=None)
        # MADDNESS (hash trees), no fine-tuning
        md_params = dict(params)
        for nm in names:
            w = np.asarray(params[nm]["w"])
            d = w.shape[0]
            from compile import layers as L
            v = L.codebook_geometry(d, model.conv_geometry(nm))
            md_params[nm] = maddness.learn_maddness(
                np.asarray(caps[nm]), w, np.asarray(params[nm]["b"]),
                d // v, depth=4)
        acc_md = train.evaluate(model, md_params, state, x_te, y_te,
                                table_bits=None)
        mse_md = train.mse_vs_dense(model, params, md_params, state,
                                    x_te[:128], table_bits=None)
        rows.append([str(n_replaced), f"{acc_pq:.4f}", f"{mse_pq:.4f}",
                     f"{acc_md:.4f}", f"{mse_md:.4f}"])
        print(f"replaced {n_replaced}: pq acc {acc_pq:.3f} mse {mse_pq:.4f}"
              f" | maddness acc {acc_md:.3f} mse {mse_md:.4f}")

    common.save_rows(
        "fig3_layer_replacement",
        ["n_replaced", "vanilla_pq_acc", "vanilla_pq_mse",
         "maddness_acc", "maddness_mse"],
        rows)
    # paper shape assertions (soft): accuracy decreases, maddness <= pq
    accs_pq = [float(r[1]) for r in rows]
    accs_md = [float(r[3]) for r in rows]
    print("\nshape check: pq end-acc drop:",
          f"{accs_pq[0]:.3f} -> {accs_pq[-1]:.3f};",
          "maddness end-acc:", f"{accs_md[-1]:.3f}")


if __name__ == "__main__":
    main()
