"""Shared experiment harness: budgets, result output, tiny CSV writer.

Budgets are sized for a single CPU core (DESIGN.md §Substitutions); set
LUTNN_EXP_QUICK=1 for a fast smoke pass or LUTNN_EXP_FULL=1 to train
longer (closer to the paper's accuracy levels).
"""

from __future__ import annotations

import os
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "results")


def budget():
    """(dense_steps, finetune_steps, n_train) for the current mode."""
    if os.environ.get("LUTNN_EXP_QUICK"):
        return 80, 60, 768
    if os.environ.get("LUTNN_EXP_FULL"):
        return 1200, 800, 8192
    return 350, 250, 2048


def save_rows(name: str, header: list[str], rows: list[list]):
    """Write results/<name>.csv and echo a markdown table."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.csv")
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for row in rows:
            f.write(",".join(str(x) for x in row) + "\n")
    print(f"\n== {name} ==")
    print("| " + " | ".join(header) + " |")
    print("|" + "---|" * len(header))
    for row in rows:
        print("| " + " | ".join(str(x) for x in row) + " |")
    print(f"(saved {path})")


class Timer:
    def __init__(self, label: str):
        self.label = label

    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        print(f"[{self.label}] {time.time() - self.t0:.1f}s")
