//! Train centroids natively: dense teacher -> differentiable soft-PQ
//! distillation -> `.lutnn` bundle -> `api::Session` — the whole LUT-NN
//! compile path (paper §3) without Python in the loop.
//!
//!   cargo run --release --example train_centroids
//!
//! Walks the same pipeline as `lutnn compile`, printing the per-layer
//! training-loss curves and the teacher-vs-compiled output error.

use lutnn::api::SessionBuilder;
use lutnn::model_fmt;
use lutnn::nn::models::{build_cnn_graph, ConvSpec};
use lutnn::tensor::Tensor;
use lutnn::train::{compile_graph, TrainConfig};
use lutnn::util::prng::Prng;

fn main() -> anyhow::Result<()> {
    let mut rng = Prng::new(0);

    // 1. A dense teacher (stand-in for a trained model bundle).
    let teacher = build_cnn_graph(
        "teacher",
        [12, 12, 3],
        &[
            ConvSpec { cout: 8, k: 3, stride: 1 },
            ConvSpec { cout: 16, k: 3, stride: 2 },
        ],
        10,
        0,
    );

    // 2. Calibration activations (deployment-distribution inputs).
    let sample = Tensor::new(vec![16, 12, 12, 3], rng.normal_vec(16 * 12 * 12 * 3, 1.0));

    // 3. Differentiable centroid learning: soft-argmin encode, learned
    //    + annealed temperature, Adam, distilled against each dense
    //    layer's own output (the first conv stays dense, paper §6.1).
    let cfg = TrainConfig { epochs: 10, anneal: 0.8, ..TrainConfig::default() };
    let (compiled, reports) = compile_graph(&teacher, &sample, 16, 8, &cfg)?;
    for r in &reports {
        let l = &r.report;
        println!(
            "layer {:<4} loss {:.5} -> {:.5} | hard mse {:.5} -> {:.5} | final t {:.4}",
            r.name,
            l.epoch_loss.first().copied().unwrap_or(f32::NAN),
            l.epoch_loss.last().copied().unwrap_or(f32::NAN),
            l.hard_mse_init,
            l.hard_mse_final,
            l.final_temperature,
        );
    }

    // 4. Export through the bundle writer and load back into a session.
    let dir = std::env::temp_dir().join("lutnn_examples");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("teacher_compiled.lutnn").to_string_lossy().into_owned();
    model_fmt::save_bundle(&compiled, &path)?;
    let reloaded = model_fmt::load_bundle(&path)?;
    println!(
        "bundle: {path} ({} -> {} param bytes)",
        teacher.param_bytes(),
        reloaded.param_bytes()
    );

    // 5. Teacher vs compiled model on fresh inputs.
    let x = Tensor::new(vec![8, 12, 12, 3], rng.normal_vec(8 * 12 * 12 * 3, 1.0));
    let mut s_teacher = SessionBuilder::new(&teacher).max_batch(8).build()?;
    let mut s_compiled = SessionBuilder::new(&reloaded).max_batch(8).build()?;
    let want = s_teacher.run_alloc(&x)?;
    let got = s_compiled.run_alloc(&x)?;
    let sig: f32 = want.data.iter().map(|v| v * v).sum::<f32>() / want.len() as f32;
    println!("{}", s_compiled.describe());
    println!("output mse vs teacher: {:.5} (signal power {sig:.5})", got.mse(&want));
    let agree = want
        .argmax_rows()
        .iter()
        .zip(got.argmax_rows())
        .filter(|(a, b)| **a == *b)
        .count();
    println!("argmax agreement: {agree}/8");
    Ok(())
}
