//! Importing models: parse an NNEF-style text graph, compile it to LUT
//! layers with the native trainer, and serve it through `api::Session`
//! — the full import -> compile -> serve flow in one file.
//!
//!   cargo run --release --example import_model
//!
//! Uses the committed `cnn_tiny` zoo fixture (embedded via
//! `include_str!`), so the example always runs with no artifacts.

use lutnn::api::SessionBuilder;
use lutnn::model_fmt::{load_bundle, save_bundle};
use lutnn::model_import::{import_str, zoo};
use lutnn::tensor::Tensor;
use lutnn::train::{compile_graph, TrainConfig};
use lutnn::util::prng::Prng;

fn main() -> anyhow::Result<()> {
    // 1. Import: text graph -> validated dense Graph. Every weight is
    //    derived deterministically from the fixture's `seed` attribute,
    //    so this model is identical on every machine.
    let dense = import_str(zoo::CNN_TINY).map_err(|e| anyhow::anyhow!("import failed: {e}"))?;
    println!("imported '{}': input {:?}, {} ops, {} layers", dense.name, dense.input_shape,
        dense.ops.len(), dense.layers.len());

    // 2. Compile: distill every conv/linear after the stem into LUT
    //    layers on a calibration batch (paper §3; the stem stays dense
    //    per §6.1). A few epochs suffice for a demo.
    let mut rng = Prng::new(0);
    let item: usize = dense.input_shape[1..].iter().product();
    let mut shape = vec![16usize];
    shape.extend_from_slice(&dense.input_shape[1..]);
    let sample = Tensor::new(shape, rng.normal_vec(16 * item, 1.0));
    let cfg = TrainConfig { epochs: 3, kmeans_iters: 6, anneal: 0.8, ..TrainConfig::default() };
    let (compiled, reports) = compile_graph(&dense, &sample, 16, 8, &cfg)?;
    for r in &reports {
        println!(
            "  distilled {:<4} hard mse {:.4} -> {:.4}",
            r.name, r.report.hard_mse_init, r.report.hard_mse_final
        );
    }

    // 3. Bundle round-trip: the same `.lutnn` format `lutnn import` and
    //    `lutnn compile` write from the CLI.
    let path = std::env::temp_dir().join("import_model_example.lutnn");
    let path = path.to_string_lossy().into_owned();
    save_bundle(&compiled, &path)?;
    let reloaded = load_bundle(&path)?;
    println!("bundle round-trip ok: {path}");

    // 4. Serve: compile the session once, classify a batch.
    let mut session = SessionBuilder::new(&reloaded).max_batch(4).build()?;
    println!("{}", session.describe());
    let x = Tensor::new(vec![4, 16, 16, 3], rng.normal_vec(4 * item, 1.0));
    let mut logits = Tensor::zeros(vec![0]);
    session.run(&x, &mut logits)?;
    for (i, row) in logits.data.chunks(logits.cols()).enumerate() {
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        println!("  input {i}: class {pred} (logit {:.3})", row[pred]);
    }
    Ok(())
}
