//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): serve the trained LUT-NN and
//! dense models through the full coordinator stack — TCP server, router,
//! dynamic batcher, native table-lookup engine — under a Poisson open-loop
//! workload, and report latency percentiles + throughput for both.
//!
//!   make artifacts
//!   cargo run --release --example serve_requests [-- --requests 200 --rate 50]
//!
//! This is the serving-paper analogue of "load a small real model and
//! serve batched requests": the model is the actually-trained resnet_tiny
//! (synthetic-image task, accuracies recorded in artifacts/manifest.json),
//! every request crosses the wire, and the LUT vs dense comparison runs
//! on identical traffic.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use lutnn::coordinator::server::{Client, Server, ServerConfig};
use lutnn::coordinator::trace::poisson_trace;
use lutnn::coordinator::{ModelEntry, Registry};
use lutnn::lut::LutOpts;
use lutnn::model_fmt;
use lutnn::runtime::{artifact_path, artifacts_available};
use lutnn::util::benchmark::Table;
use lutnn::util::cli::Args;
use lutnn::util::stats::Summary;

fn drive(
    addr: std::net::SocketAddr,
    model: &str,
    requests: usize,
    rate: f64,
    item_len: usize,
    clients: usize,
) -> (Summary, f64) {
    let trace = poisson_trace(rate, requests, item_len, 7);
    let latencies = Arc::new(Mutex::new(Vec::with_capacity(requests)));
    let t0 = Instant::now();
    // `clients` connections share the trace round-robin; each replays its
    // slice with open-loop timing (sleep until the arrival timestamp).
    std::thread::scope(|s| {
        for c in 0..clients {
            let trace = &trace;
            let latencies = Arc::clone(&latencies);
            let model = model.to_string();
            s.spawn(move || {
                let mut client = Client::connect(&addr).expect("connect");
                for ev in trace.iter().skip(c).step_by(clients) {
                    let now = t0.elapsed().as_secs_f64();
                    if ev.at_s > now {
                        std::thread::sleep(Duration::from_secs_f64(ev.at_s - now));
                    }
                    let sent = Instant::now();
                    let out = client.infer(&model, &ev.input).expect("infer");
                    assert_eq!(out.len(), 10);
                    latencies
                        .lock()
                        .unwrap()
                        .push(sent.elapsed().as_secs_f64());
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let lat = latencies.lock().unwrap();
    (Summary::of(&lat), requests as f64 / wall)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let requests = args.get_usize("requests", 200);
    let rate = args.get_f64("rate", 50.0);
    let clients = args.get_usize("clients", 4);
    // Engine replicas per model: N sessions from one shared bundle,
    // drained by N work-stealing batcher workers (--replicas 4 on a
    // multi-core host scales closed-loop throughput near-linearly).
    let replicas = args.get_usize("replicas", 1).max(1);

    anyhow::ensure!(
        artifacts_available(),
        "run `make artifacts` first — this driver serves the trained models"
    );
    let mut registry = Registry::new();
    for name in ["resnet_tiny_lut", "resnet_tiny_dense"] {
        let graph = model_fmt::load_bundle(&artifact_path(&format!("{name}.lutnn")))?;
        // Compile to a Session-backed engine pool; the batcher borrows
        // each stacked batch, so requests are never cloned on the hot
        // path, and each replica owns its own scratch arenas.
        // ServerConfig::replicas grows the pool — one knob.
        registry.register(ModelEntry::native(name, &graph, LutOpts::deployed(), 8, 1)?);
    }
    let server = Server::start(
        registry,
        ServerConfig { addr: "127.0.0.1:0".into(), replicas, ..Default::default() },
    )?;
    println!(
        "serving on {} — {requests} requests @ {rate}/s, {clients} clients, \
         {replicas} replica(s)\n",
        server.addr
    );

    let mut table = Table::new(&[
        "model", "throughput req/s", "p50 ms", "p95 ms", "p99 ms", "max ms",
    ]);
    for model in ["resnet_tiny_lut", "resnet_tiny_dense"] {
        let (lat, thr) = drive(server.addr, model, requests, rate, 768, clients);
        table.row(&[
            model.into(),
            format!("{:.1}", thr),
            format!("{:.2}", lat.p50 * 1e3),
            format!("{:.2}", lat.p95 * 1e3),
            format!("{:.2}", lat.p99 * 1e3),
            format!("{:.2}", lat.max * 1e3),
        ]);
    }
    table.print();

    // control-plane metrics
    let mut c = Client::connect(&server.addr)?;
    let m = c.call(&lutnn::util::json::Json::obj(vec![(
        "cmd",
        lutnn::util::json::Json::str("metrics"),
    )]))?;
    println!("\nserver metrics: {}", lutnn::util::json::to_string(&m));
    Ok(())
}
