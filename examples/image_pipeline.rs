//! Domain example: an on-device image-classification pipeline that
//! LUT-converts a dense model *in rust* (k-means over its own calibration
//! activations — no python anywhere), verifies prediction agreement,
//! saves the converted bundle, reloads it, and compares speed — the
//! mobile-deployment story of the paper's §1.
//!
//!   cargo run --release --example image_pipeline

use lutnn::api::SessionBuilder;
use lutnn::lut::LutOpts;
use lutnn::model_fmt;
use lutnn::nn::models::{build_cnn_graph, lutify_graph, ConvSpec};
use lutnn::tensor::Tensor;
use lutnn::util::prng::Prng;
use std::time::Instant;

/// Tiny procedural "shape + stripes" image generator (rust twin of
/// python/compile/datasets.synth_image): class = shape x orientation.
fn synth_image(rng: &mut Prng, n: usize, size: usize) -> (Tensor, Vec<usize>) {
    let mut data = vec![0.0f32; n * size * size * 3];
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let class = rng.below(10);
        labels.push(class);
        let shape = class / 2;
        let vertical = class % 2 == 1;
        let cy = rng.range(-0.15, 0.15);
        let cx = rng.range(-0.15, 0.15);
        let freq = rng.range(3.5, 4.5);
        let phase = rng.range(0.0, std::f32::consts::TAU);
        let tint = [rng.range(0.5, 1.0), rng.range(0.5, 1.0), rng.range(0.5, 1.0)];
        for y in 0..size {
            for x in 0..size {
                let fy = (y as f32 - size as f32 / 2.0 + 0.5) / size as f32;
                let fx = (x as f32 - size as f32 / 2.0 + 0.5) / size as f32;
                let r = ((fy - cy).powi(2) + (fx - cx).powi(2)).sqrt();
                let mask = match shape {
                    0 => r < 0.3,
                    1 => r > 0.18 && r < 0.33,
                    2 => (fy - cy).abs() < 0.25 && (fx - cx).abs() < 0.25,
                    3 => (fy - cy).abs() < 0.08 || (fx - cx).abs() < 0.08,
                    _ => ((fy - cy) - (fx - cx)).abs() < 0.12,
                };
                let coord = if vertical { fx } else { fy };
                let tex = 0.5 + 0.5 * (std::f32::consts::TAU * freq * coord + phase).sin();
                let base = if mask { tex } else { 0.0 };
                for c in 0..3 {
                    data[((i * size + y) * size + x) * 3 + c] =
                        base * tint[c] + 0.25 * rng.normal();
                }
            }
        }
    }
    (Tensor::new(vec![n, size, size, 3], data), labels)
}

fn main() -> anyhow::Result<()> {
    let mut rng = Prng::new(42);
    let size = 16;

    // 1. the "pretrained" dense model (random weights: this example
    //    demonstrates the conversion machinery and perf, not accuracy —
    //    the accuracy story is python-side, see EXPERIMENTS.md Table 4)
    println!("[1/5] building dense CNN");
    let dense = build_cnn_graph(
        "mobile_cnn",
        [size, size, 3],
        &[
            ConvSpec { cout: 32, k: 3, stride: 1 },
            ConvSpec { cout: 64, k: 3, stride: 2 },
            ConvSpec { cout: 64, k: 3, stride: 1 },
        ],
        10,
        1,
    );

    // 2. calibration pass + in-rust LUT conversion (paper Eq. 1 k-means)
    println!("[2/5] LUT conversion with K=16 centroids (k-means on calibration images)");
    let (calib, _) = synth_image(&mut rng, 8, size);
    let t0 = Instant::now();
    let lut = lutify_graph(&dense, &calib, 16, 8, 0);
    println!("        converted in {:.2}s; params {} -> {} bytes",
             t0.elapsed().as_secs_f64(), dense.param_bytes(), lut.param_bytes());

    // 3. fidelity: prediction agreement between dense and LUT models,
    //    both compiled once to zero-alloc sessions
    println!("[3/5] fidelity check on 64 fresh images");
    let mut dense_sess = SessionBuilder::new(&dense)
        .opts(LutOpts::deployed())
        .max_batch(64)
        .build()?;
    let mut lut_sess = SessionBuilder::new(&lut)
        .opts(LutOpts::deployed())
        .max_batch(64)
        .build()?;
    let (test, _labels) = synth_image(&mut rng, 64, size);
    let d_out = dense_sess.run_alloc(&test)?;
    let l_out = lut_sess.run_alloc(&test)?;
    let agree = d_out
        .argmax_rows()
        .iter()
        .zip(l_out.argmax_rows())
        .filter(|(a, b)| **a == *b)
        .count();
    println!("        prediction agreement {agree}/64, output MSE {:.4}",
             d_out.mse(&l_out));

    // 4. round-trip through the bundle format
    println!("[4/5] save + reload .lutnn bundle");
    let path = std::env::temp_dir().join("mobile_cnn_lut.lutnn");
    model_fmt::save_bundle(&lut, path.to_str().unwrap())?;
    let reloaded = model_fmt::load_bundle(path.to_str().unwrap())?;
    let mut reloaded_sess = SessionBuilder::new(&reloaded)
        .opts(LutOpts::deployed())
        .max_batch(64)
        .build()?;
    let r_out = reloaded_sess.run_alloc(&test)?;
    assert!(r_out.max_abs_diff(&l_out) < 1e-5, "bundle round-trip mismatch");
    println!("        round-trip exact ({} bytes on disk)",
             std::fs::metadata(&path)?.len());

    // 5. latency comparison (sessions reuse their arenas and the output
    //    tensor — the loop allocates nothing)
    println!("[5/5] latency (batch 16)");
    let (batch, _) = synth_image(&mut rng, 16, size);
    let mut out = Tensor::zeros(vec![0]);
    for _ in 0..2 {
        dense_sess.run(&batch, &mut out)?;
        lut_sess.run(&batch, &mut out)?;
    }
    let reps = 10;
    let t0 = Instant::now();
    for _ in 0..reps {
        dense_sess.run(&batch, &mut out)?;
        std::hint::black_box(&out);
    }
    let dt_dense = t0.elapsed().as_secs_f64() / reps as f64;
    let t0 = Instant::now();
    for _ in 0..reps {
        lut_sess.run(&batch, &mut out)?;
        std::hint::black_box(&out);
    }
    let dt_lut = t0.elapsed().as_secs_f64() / reps as f64;
    println!(
        "        dense {:.2} ms | lut {:.2} ms | speedup {:.2}x",
        dt_dense * 1e3,
        dt_lut * 1e3,
        dt_dense / dt_lut
    );
    Ok(())
}
