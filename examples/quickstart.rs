//! Quickstart: load a trained `.lutnn` bundle, compile it to a
//! `Session`, and classify a batch — the smallest end-to-end use of the
//! public API.
//!
//!   make artifacts                 # once: trains + exports the bundles
//!   cargo run --release --example quickstart
//!
//! Falls back to an in-process synthetic model when artifacts are absent
//! so the example always runs.

use lutnn::api::SessionBuilder;
use lutnn::lut::LutOpts;
use lutnn::model_fmt;
use lutnn::nn::models::{build_cnn_graph, lutify_graph, ConvSpec};
use lutnn::runtime::{artifact_path, artifacts_available};
use lutnn::tensor::Tensor;
use lutnn::util::prng::Prng;

fn main() -> anyhow::Result<()> {
    let mut rng = Prng::new(0);

    let graph = if artifacts_available() {
        println!("loading trained bundle: resnet_tiny_lut.lutnn");
        model_fmt::load_bundle(&artifact_path("resnet_tiny_lut.lutnn"))?
    } else {
        println!("artifacts missing — building a synthetic LUT model instead");
        let dense = build_cnn_graph(
            "synthetic",
            [16, 16, 3],
            &[
                ConvSpec { cout: 16, k: 3, stride: 1 },
                ConvSpec { cout: 32, k: 3, stride: 2 },
            ],
            10,
            0,
        );
        let sample = Tensor::new(vec![4, 16, 16, 3], rng.normal_vec(4 * 16 * 16 * 3, 1.0));
        lutify_graph(&dense, &sample, 16, 8, 0)
    };

    // Compile once: kernels picked from the registry by each layer's
    // tag, scratch arenas sized for batch 4.
    let mut session = SessionBuilder::new(&graph)
        .opts(LutOpts::deployed())
        .max_batch(4)
        .build()?;
    println!("{}", session.describe());
    println!("deployed kernel param bytes: {}", session.param_bytes());

    // Classify a batch of 4 random inputs — zero-clone, zero-alloc run.
    let item: usize = session.item_shape().iter().product();
    let mut shape = vec![4usize];
    shape.extend_from_slice(session.item_shape());
    let x = Tensor::new(shape, rng.normal_vec(4 * item, 1.0));
    let mut logits = Tensor::zeros(vec![0]);

    let t0 = std::time::Instant::now();
    session.run(&x, &mut logits)?;
    let dt = t0.elapsed();

    println!("logits shape {:?} in {:.2} ms", logits.shape, dt.as_secs_f64() * 1e3);
    for (i, row) in logits.data.chunks(logits.cols()).enumerate() {
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        println!("  input {i}: class {pred} (logit {:.3})", row[pred]);
    }
    Ok(())
}
