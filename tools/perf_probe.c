/* perf_probe — measurement + cross-validation harness for the kernel
 * matrix, in C.
 *
 * Why this exists: no authoring container for this repo has carried a
 * rust toolchain (see CHANGES.md), but the perf gate needs *measured*
 * baseline numbers and the new intrinsic arms (AVX-512 encode,
 * dense-i8 AVX2 madd micro-kernel) need their lane bookkeeping
 * validated on real hardware. This file transliterates the portable
 * Rust kernels 1:1 (same loops, same blocking constants, same
 * accumulation order) so that:
 *
 *   1. the committed BENCH_e2e_latency.json baseline carries honestly
 *      measured portable-backend numbers (provenance recorded in the
 *      document's `note` field), and
 *   2. the intrinsic arms are proven bit-identical / exactly-equal to
 *      their portable counterparts before the Rust versions ship.
 *
 * Build + run (from the repo root):
 *
 *   gcc -O3 -ffp-contract=off -o /tmp/perf_probe tools/perf_probe.c -lm
 *   /tmp/perf_probe
 *
 * -ffp-contract=off forbids mul+add fusion in scalar tails — the same
 * no-FMA guarantee rustc gives — so the bitwise cross-checks are
 * meaningful. The timed kernels are the *portable* paths at default
 * x86-64 codegen (SSE2 baseline, like a rustc build without
 * `--features simd`); the intrinsic arms are compiled per-function via
 * __attribute__((target(...))) and used only for validation, never in
 * the timed loops.
 *
 * Transliterated from (keep in sync):
 *   rust/src/nn/gemm.rs            gemm (MC=64 KC=256, 4-row micro-kernel)
 *   rust/src/lut/engine.rs         encode_centroid_stationary,
 *                                  accumulate_int_blocked (GROUP=256), argmin
 *   rust/src/lut/simd.rs           distance_accumulate_portable/avx2/avx512
 *   rust/src/api/kernel.rs         LutI8Kernel / DecLutKernel / DenseI8Kernel
 *                                  accumulate loops
 */
#include <immintrin.h>
#include <math.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

/* ------------------------------------------------------------------ */
/* rng (distribution stand-in; kernel timing is data-independent)      */
/* ------------------------------------------------------------------ */
static uint64_t rng_state = 0x9E3779B97F4A7C15ull;
static uint64_t splitmix(void) {
    uint64_t z = (rng_state += 0x9E3779B97F4A7C15ull);
    z = (z ^ (z >> 30)) * 0xBF58476D1CE4E5B9ull;
    z = (z ^ (z >> 27)) * 0x94D049BB133111EBull;
    return z ^ (z >> 31);
}
static float frand(void) { return (float)((splitmix() >> 11) * (1.0 / 9007199254740992.0)); }
static float nrand(void) { /* Box-Muller */
    float u1 = frand() + 1e-12f, u2 = frand();
    return sqrtf(-2.0f * logf(u1)) * cosf(2.0f * (float)M_PI * u2);
}
static void fill_normal(float *p, size_t n) { for (size_t i = 0; i < n; i++) p[i] = nrand(); }

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return ts.tv_sec + ts.tv_nsec * 1e-9;
}

/* ------------------------------------------------------------------ */
/* nn::gemm::gemm — blocked f32 GEMM (dense kernel core)               */
/* ------------------------------------------------------------------ */
#define MC 64
#define KC 256
static void gemm_block(const float *a, const float *b, float *out, size_t i0, size_t i1,
                       size_t k0, size_t k1, size_t d, size_t m) {
    size_t i = i0;
    while (i + 4 <= i1) {
        for (size_t k = k0; k < k1; k++) {
            float a0 = a[i * d + k], a1 = a[(i + 1) * d + k];
            float a2 = a[(i + 2) * d + k], a3 = a[(i + 3) * d + k];
            const float *brow = b + k * m;
            float *o0 = out + i * m, *o1 = o0 + m, *o2 = o1 + m, *o3 = o2 + m;
            for (size_t j = 0; j < m; j++) {
                float bv = brow[j];
                o0[j] += a0 * bv;
                o1[j] += a1 * bv;
                o2[j] += a2 * bv;
                o3[j] += a3 * bv;
            }
        }
        i += 4;
    }
    while (i < i1) {
        for (size_t k = k0; k < k1; k++) {
            float av = a[i * d + k];
            const float *brow = b + k * m;
            float *orow = out + i * m;
            for (size_t j = 0; j < m; j++) orow[j] += av * brow[j];
        }
        i += 1;
    }
}
static void gemm(const float *a, const float *b, float *out, size_t n, size_t d, size_t m) {
    for (size_t i0 = 0; i0 < n; i0 += MC) {
        size_t i1 = i0 + MC < n ? i0 + MC : n;
        for (size_t k0 = 0; k0 < d; k0 += KC) {
            size_t k1 = k0 + KC < d ? k0 + KC : d;
            gemm_block(a, b, out, i0, i1, k0, k1, d, m);
        }
    }
}

/* ------------------------------------------------------------------ */
/* lut::engine::argmin (sequential + interleaved)                      */
/* ------------------------------------------------------------------ */
static size_t argmin_seq(const float *s, size_t k) {
    size_t best = 0;
    float bv = s[0];
    for (size_t i = 1; i < k; i++)
        if (s[i] < bv) { bv = s[i]; best = i; }
    return best;
}
static size_t argmin_il(const float *s, size_t k) {
    if (k < 8) return argmin_seq(s, k);
    float lanes[4] = {INFINITY, INFINITY, INFINITY, INFINITY};
    size_t full = k & ~(size_t)3;
    for (size_t i = 0; i < full; i += 4)
        for (size_t l = 0; l < 4; l++)
            lanes[l] = s[i + l] < lanes[l] ? s[i + l] : lanes[l];
    float mn0 = lanes[0] < lanes[1] ? lanes[0] : lanes[1];
    float mn1 = lanes[2] < lanes[3] ? lanes[2] : lanes[3];
    float mn = mn0 < mn1 ? mn0 : mn1;
    for (size_t i = full; i < k; i++) mn = s[i] < mn ? s[i] : mn;
    for (size_t i = 0; i < k; i++)
        if (s[i] == mn) return i;
    return 0;
}

/* ------------------------------------------------------------------ */
/* LUT fixture: codebooks, sqn, cb_t2, common-scale i8 table           */
/* ------------------------------------------------------------------ */
typedef struct {
    size_t c, k, v, m, d;
    float *cb;       /* [C, K, V] */
    float *sqn;      /* [C, K]    */
    float *cb_t2;    /* [C, V, K] = -2 * centroid, K-contiguous */
    int8_t *qcommon; /* [C, K, M] common-scale table */
    float common_scale;
    float *table_f32; /* [C, K, M] dequantized */
    float *bias;      /* [M] */
} Lut;

static Lut lut_build(size_t c, size_t k, size_t v, size_t m) {
    Lut l = {c, k, v, m, c * v, 0, 0, 0, 0, 0, 0, 0};
    l.cb = malloc(c * k * v * 4);
    fill_normal(l.cb, c * k * v);
    l.sqn = malloc(c * k * 4);
    l.cb_t2 = malloc(c * v * k * 4);
    for (size_t ci = 0; ci < c; ci++)
        for (size_t kk = 0; kk < k; kk++) {
            float s = 0;
            for (size_t t = 0; t < v; t++) {
                float x = l.cb[(ci * k + kk) * v + t];
                s += x * x;
                l.cb_t2[(ci * v + t) * k + kk] = -2.0f * x;
            }
            l.sqn[ci * k + kk] = s;
        }
    /* table: per-codebook scales then requantized to the common scale */
    size_t tn = c * k * m;
    l.table_f32 = malloc(tn * 4);
    fill_normal(l.table_f32, tn);
    float *scale = malloc(c * 4);
    for (size_t ci = 0; ci < c; ci++) {
        float mx = 0;
        for (size_t i = 0; i < k * m; i++) {
            float ab = fabsf(l.table_f32[ci * k * m + i]);
            mx = ab > mx ? ab : mx;
        }
        scale[ci] = mx / 127.0f > 1e-30f ? mx / 127.0f : 1e-30f;
    }
    float cs = 0;
    for (size_t ci = 0; ci < c; ci++) cs = scale[ci] > cs ? scale[ci] : cs;
    l.common_scale = cs > 1e-30f ? cs : 1e-30f;
    l.qcommon = malloc(tn);
    for (size_t ci = 0; ci < c; ci++)
        for (size_t i = 0; i < k * m; i++) {
            float q = roundf(l.table_f32[ci * k * m + i] / l.common_scale);
            q = q < -128 ? -128 : (q > 127 ? 127 : q);
            l.qcommon[ci * k * m + i] = (int8_t)q;
        }
    free(scale);
    l.bias = malloc(m * 4);
    for (size_t j = 0; j < m; j++) l.bias[j] = 0.1f;
    return l;
}

/* encode_centroid_stationary: slab copy + sqn seed + [n,v]x[v,k] gemm */
static void lut_encode_scalar(const Lut *l, const float *a, size_t n, float *slab,
                              float *scores, uint16_t *idx) {
    size_t c = l->c, k = l->k, v = l->v, d = l->d;
    for (size_t ci = 0; ci < c; ci++) {
        const float *cbt2 = l->cb_t2 + ci * v * k;
        const float *sqn = l->sqn + ci * k;
        for (size_t i = 0; i < n; i++) {
            memcpy(slab + i * v, a + i * d + ci * v, v * 4);
            memcpy(scores + i * k, sqn, k * 4);
        }
        gemm(slab, cbt2, scores, n, v, k);
        for (size_t i = 0; i < n; i++)
            idx[i * c + ci] = (uint16_t)argmin_seq(scores + i * k, k); /* deployed: sequential */
    }
}

/* lut::simd distance_accumulate_portable: 8 independent K-lanes */
static void dist_acc_portable(const float *sub, size_t v, const float *w, float *scores,
                              size_t k) {
    size_t k8 = k & ~(size_t)7;
    for (size_t t = 0; t < v; t++) {
        float a = sub[t];
        const float *wrow = w + t * k;
        size_t kk = 0;
        for (; kk < k8; kk += 8)
            for (size_t j = 0; j < 8; j++) scores[kk + j] += a * wrow[kk + j];
        for (; kk < k; kk++) scores[kk] += a * wrow[kk];
    }
}

/* lut::simd encode_simd (portable arm): per-(c,row) scores + interleaved argmin */
static void lut_encode_simd_portable(const Lut *l, const float *a, size_t n, float *scores,
                                     uint16_t *idx) {
    size_t c = l->c, k = l->k, v = l->v, d = l->d;
    for (size_t ci = 0; ci < c; ci++) {
        const float *cbt2 = l->cb_t2 + ci * v * k;
        const float *sqn = l->sqn + ci * k;
        for (size_t i = 0; i < n; i++) {
            const float *sub = a + i * d + ci * v;
            memcpy(scores, sqn, k * 4);
            dist_acc_portable(sub, v, cbt2, scores, k);
            idx[i * c + ci] = (uint16_t)argmin_il(scores, k);
        }
    }
}

/* accumulate_int_blocked: i16 lanes in GROUP=256 codebook groups -> i32 */
#define GROUP 256
static void lut_acc_int_blocked(const Lut *l, const uint16_t *idx, size_t n, int16_t *acc16,
                                int32_t *acc32, float *out) {
    size_t c = l->c, k = l->k, m = l->m;
    for (size_t i = 0; i < n; i++) {
        memset(acc32, 0, m * 4);
        const uint16_t *row_idx = idx + i * c;
        for (size_t g0 = 0; g0 < c; g0 += GROUP) {
            size_t g1 = g0 + GROUP < c ? g0 + GROUP : c;
            memset(acc16, 0, m * 2);
            for (size_t ci = g0; ci < g1; ci++) {
                const int8_t *row = l->qcommon + (ci * k + row_idx[ci]) * m;
                for (size_t j = 0; j < m; j++) acc16[j] += row[j];
            }
            for (size_t j = 0; j < m; j++) acc32[j] += acc16[j];
        }
        float *dst = out + i * m;
        for (size_t j = 0; j < m; j++) dst[j] = acc32[j] * l->common_scale + l->bias[j];
    }
}

/* LutI8Kernel accumulate: one global scale, pure i32 lookup-adds */
static void lut_i8_acc(const Lut *l, const int8_t *q, float gscale, const uint16_t *idx,
                       size_t n, int32_t *acc32, float *out) {
    size_t c = l->c, k = l->k, m = l->m;
    for (size_t i = 0; i < n; i++) {
        memset(acc32, 0, m * 4);
        for (size_t ci = 0; ci < c; ci++) {
            const int8_t *row = q + (ci * k + idx[i * c + ci]) * m;
            for (size_t j = 0; j < m; j++) acc32[j] += row[j];
        }
        float *dst = out + i * m;
        for (size_t j = 0; j < m; j++) dst[j] = acc32[j] * gscale + l->bias[j];
    }
}

/* DecLutKernel accumulate: shared f32 base + per-codebook 4-bit nibbles */
static void lut_dec_acc(const Lut *l, const float *base_total, const uint8_t *resid,
                        const float *scales, const uint16_t *idx, size_t n, float *out) {
    size_t c = l->c, k = l->k, m = l->m;
    size_t row_bytes = (m + 1) / 2;
    for (size_t i = 0; i < n; i++) {
        float *dst = out + i * m;
        memcpy(dst, base_total, m * 4);
        for (size_t ci = 0; ci < c; ci++) {
            const uint8_t *row = resid + (ci * k + idx[i * c + ci]) * row_bytes;
            float s = scales[ci];
            for (size_t j = 0; j < m; j++) {
                uint8_t byte = row[j / 2];
                uint8_t nib = (j & 1) == 0 ? (byte & 0x0F) : (byte >> 4);
                dst[j] += ((int32_t)nib - 8) * s;
            }
        }
        for (size_t j = 0; j < m; j++) dst[j] += l->bias[j];
    }
}

/* ------------------------------------------------------------------ */
/* dense-i8: per-row dynamic input quant, global weight scale, i32 acc */
/* ------------------------------------------------------------------ */
static void dense_i8_portable(const int8_t *qw, float sw, const float *a, size_t n, size_t d,
                              size_t m, const float *bias, int8_t *qa, int32_t *acc,
                              float *out) {
    for (size_t i = 0; i < n; i++) {
        const float *row = a + i * d;
        float mx = 0;
        for (size_t t = 0; t < d; t++) {
            float ab = fabsf(row[t]);
            mx = ab > mx ? ab : mx;
        }
        float sa = mx / 127.0f > 1e-30f ? mx / 127.0f : 1e-30f;
        for (size_t t = 0; t < d; t++) {
            float q = roundf(row[t] / sa);
            qa[t] = (int8_t)(q < -127 ? -127 : (q > 127 ? 127 : q));
        }
        memset(acc, 0, m * 4);
        /* depth-blocked (KC) like the f32 gemm; i32 adds are exact so
         * blocking is free */
        for (size_t t0 = 0; t0 < d; t0 += KC) {
            size_t t1 = t0 + KC < d ? t0 + KC : d;
            for (size_t t = t0; t < t1; t++) {
                int32_t av = qa[t];
                const int8_t *wrow = qw + t * m;
                for (size_t j = 0; j < m; j++) acc[j] += av * (int32_t)wrow[j];
            }
        }
        float deq = sa * sw;
        float *dst = out + i * m;
        for (size_t j = 0; j < m; j++) dst[j] = acc[j] * deq + bias[j];
    }
}

/* AVX2 madd micro-kernel for one row's i32 accumulator: t processed in
 * pairs, 16 outputs per step via unpacklo/hi + _mm256_madd_epi16.
 * Lane bookkeeping: after unpack, acc_lo holds j {0..3, 8..11} and
 * acc_hi holds j {4..7, 12..15} (within-128-bit-lane interleave). */
__attribute__((target("avx2"))) static void dense_i8_row_avx2(const int8_t *qw,
                                                              const int8_t *qa, size_t d,
                                                              size_t m, int32_t *acc) {
    memset(acc, 0, m * 4);
    size_t m16 = m & ~(size_t)15;
    size_t d2 = d & ~(size_t)1;
    for (size_t j0 = 0; j0 < m16; j0 += 16) {
        __m256i acc_lo = _mm256_setzero_si256();
        __m256i acc_hi = _mm256_setzero_si256();
        for (size_t t = 0; t < d2; t += 2) {
            __m256i vt0 = _mm256_cvtepi8_epi16(
                _mm_loadu_si128((const __m128i *)(qw + t * m + j0)));
            __m256i vt1 = _mm256_cvtepi8_epi16(
                _mm_loadu_si128((const __m128i *)(qw + (t + 1) * m + j0)));
            __m256i lo = _mm256_unpacklo_epi16(vt0, vt1);
            __m256i hi = _mm256_unpackhi_epi16(vt0, vt1);
            uint32_t pair = (uint16_t)qa[t] | ((uint32_t)(uint16_t)qa[t + 1] << 16);
            __m256i av = _mm256_set1_epi32((int32_t)pair);
            acc_lo = _mm256_add_epi32(acc_lo, _mm256_madd_epi16(lo, av));
            acc_hi = _mm256_add_epi32(acc_hi, _mm256_madd_epi16(hi, av));
        }
        int32_t tmp_lo[8], tmp_hi[8];
        _mm256_storeu_si256((__m256i *)tmp_lo, acc_lo);
        _mm256_storeu_si256((__m256i *)tmp_hi, acc_hi);
        for (size_t j = 0; j < 4; j++) {
            acc[j0 + j] = tmp_lo[j];
            acc[j0 + 4 + j] = tmp_hi[j];
            acc[j0 + 8 + j] = tmp_lo[4 + j];
            acc[j0 + 12 + j] = tmp_hi[4 + j];
        }
        if (d2 < d) { /* odd depth: scalar last t */
            int32_t av = qa[d - 1];
            const int8_t *wrow = qw + (d - 1) * m;
            for (size_t j = j0; j < j0 + 16; j++) acc[j] += av * (int32_t)wrow[j];
        }
    }
    /* output-column remainder: plain scalar columns */
    for (size_t j = m16; j < m; j++) {
        int32_t s = 0;
        for (size_t t = 0; t < d; t++) s += (int32_t)qa[t] * (int32_t)qw[t * m + j];
        acc[j] = s;
    }
}

/* ------------------------------------------------------------------ */
/* AVX-512 16-lane distance accumulate (validation arm)                */
/* ------------------------------------------------------------------ */
__attribute__((target("avx512f"))) static void dist_acc_avx512(const float *sub, size_t v,
                                                               const float *w, float *scores,
                                                               size_t k) {
    size_t k16 = k & ~(size_t)15;
    for (size_t t = 0; t < v; t++) {
        __m512 av = _mm512_set1_ps(sub[t]);
        const float *wrow = w + t * k;
        size_t kk = 0;
        while (kk < k16) {
            __m512 acc = _mm512_loadu_ps(scores + kk);
            __m512 prod = _mm512_mul_ps(av, _mm512_loadu_ps(wrow + kk));
            _mm512_storeu_ps(scores + kk, _mm512_add_ps(acc, prod));
            kk += 16;
        }
        while (kk < k) {
            scores[kk] += sub[t] * wrow[kk];
            kk += 1;
        }
    }
}

__attribute__((target("avx2"))) static void dist_acc_avx2(const float *sub, size_t v,
                                                          const float *w, float *scores,
                                                          size_t k) {
    size_t k8 = k & ~(size_t)7;
    for (size_t t = 0; t < v; t++) {
        __m256 av = _mm256_set1_ps(sub[t]);
        const float *wrow = w + t * k;
        size_t kk = 0;
        while (kk < k8) {
            __m256 acc = _mm256_loadu_ps(scores + kk);
            __m256 prod = _mm256_mul_ps(av, _mm256_loadu_ps(wrow + kk));
            _mm256_storeu_ps(scores + kk, _mm256_add_ps(acc, prod));
            kk += 8;
        }
        while (kk < k) {
            scores[kk] += sub[t] * wrow[kk];
            kk += 1;
        }
    }
}

/* strict scalar oracle: one dependent chain per element, t ascending */
static void dist_acc_oracle(const float *sub, size_t v, const float *w, float *scores,
                            size_t k) {
    for (size_t t = 0; t < v; t++)
        for (size_t kk = 0; kk < k; kk++) scores[kk] += sub[t] * w[t * k + kk];
}

/* ------------------------------------------------------------------ */
/* validation                                                          */
/* ------------------------------------------------------------------ */
static int validate(void) {
    int fails = 0;
    int have512 = __builtin_cpu_supports("avx512f");
    int have2 = __builtin_cpu_supports("avx2");
    printf("cpu: avx2=%d avx512f=%d\n", have2, have512);
    /* distance accumulate: every arm bitwise vs the scalar oracle for
     * k = 1..40 (crosses 8- and 16-lane boundaries + remainders) */
    for (size_t k = 1; k <= 40; k++) {
        for (size_t v = 1; v <= 12; v += 3) {
            float sub[16], w[40 * 16], seed[40];
            fill_normal(sub, v);
            fill_normal(w, v * k);
            fill_normal(seed, k);
            float want[40], got[40];
            memcpy(want, seed, k * 4);
            dist_acc_oracle(sub, v, w, want, k);
            memcpy(got, seed, k * 4);
            dist_acc_portable(sub, v, w, got, k);
            if (memcmp(got, want, k * 4)) { printf("FAIL portable k=%zu v=%zu\n", k, v); fails++; }
            if (have2) {
                memcpy(got, seed, k * 4);
                dist_acc_avx2(sub, v, w, got, k);
                if (memcmp(got, want, k * 4)) { printf("FAIL avx2 k=%zu v=%zu\n", k, v); fails++; }
            }
            if (have512) {
                memcpy(got, seed, k * 4);
                dist_acc_avx512(sub, v, w, got, k);
                if (memcmp(got, want, k * 4)) { printf("FAIL avx512 k=%zu v=%zu\n", k, v); fails++; }
            }
        }
    }
    printf("distance accumulate: portable/avx2/avx512 bitwise vs oracle (k=1..40): %s\n",
           fails ? "FAIL" : "ok");
    /* dense-i8 avx2 madd micro-kernel: exact i32 equality vs portable,
     * including odd depth and column remainders */
    size_t shapes[][2] = {{576, 128}, {577, 128}, {64, 17}, {7, 16}, {1, 1}, {33, 31}};
    for (size_t s = 0; s < sizeof(shapes) / sizeof(shapes[0]); s++) {
        size_t d = shapes[s][0], m = shapes[s][1];
        int8_t *qw = malloc(d * m), *qa = malloc(d);
        for (size_t i = 0; i < d * m; i++) qw[i] = (int8_t)(splitmix() % 255 - 127);
        for (size_t i = 0; i < d; i++) qa[i] = (int8_t)(splitmix() % 255 - 127);
        int32_t *want = malloc(m * 4), *got = malloc(m * 4);
        for (size_t j = 0; j < m; j++) {
            int32_t acc = 0;
            for (size_t t = 0; t < d; t++) acc += (int32_t)qa[t] * (int32_t)qw[t * m + j];
            want[j] = acc;
        }
        if (have2) {
            dense_i8_row_avx2(qw, qa, d, m, got);
            if (memcmp(got, want, m * 4)) { printf("FAIL dense-i8 avx2 d=%zu m=%zu\n", d, m); fails++; }
        }
        free(qw); free(qa); free(want); free(got);
    }
    printf("dense-i8 avx2 madd micro-kernel: exact i32 vs reference: %s\n",
           fails ? "FAIL" : "ok");
    return fails;
}

/* ------------------------------------------------------------------ */
/* timed shootout at the bench shape                                   */
/* ------------------------------------------------------------------ */
typedef void (*bench_fn)(void *);
static double timeit(bench_fn f, void *ctx) {
    for (int i = 0; i < 3; i++) f(ctx); /* warmup */
    double t0 = now_s();
    int iters = 0;
    do {
        f(ctx);
        iters++;
    } while (now_s() - t0 < 0.7 || iters < 10);
    return (now_s() - t0) / iters;
}

typedef struct {
    Lut *l;
    const float *a;
    size_t n;
    float *slab, *scores, *out;
    uint16_t *idx;
    int16_t *acc16;
    int32_t *acc32;
    /* dense */
    const float *w;
    /* lut-i8 */
    int8_t *qi8;
    float gscale;
    /* lut-dec */
    float *base_total;
    uint8_t *resid;
    float *dscales;
    /* dense-i8 */
    int8_t *qw, *qa;
    float sw;
} Ctx;

static void run_dense(void *p) {
    Ctx *c = p;
    memset(c->out, 0, c->n * c->l->m * 4);
    gemm(c->a, c->w, c->out, c->n, c->l->d, c->l->m);
    for (size_t i = 0; i < c->n; i++)
        for (size_t j = 0; j < c->l->m; j++) c->out[i * c->l->m + j] += c->l->bias[j];
}
static void run_lut(void *p) {
    Ctx *c = p;
    lut_encode_scalar(c->l, c->a, c->n, c->slab, c->scores, c->idx);
    lut_acc_int_blocked(c->l, c->idx, c->n, c->acc16, c->acc32, c->out);
}
static void run_lut_simd(void *p) {
    Ctx *c = p;
    lut_encode_simd_portable(c->l, c->a, c->n, c->scores, c->idx);
    lut_acc_int_blocked(c->l, c->idx, c->n, c->acc16, c->acc32, c->out);
}
static void run_lut_i8(void *p) {
    Ctx *c = p;
    lut_encode_simd_portable(c->l, c->a, c->n, c->scores, c->idx);
    lut_i8_acc(c->l, c->qi8, c->gscale, c->idx, c->n, c->acc32, c->out);
}
static void run_lut_dec(void *p) {
    Ctx *c = p;
    lut_encode_simd_portable(c->l, c->a, c->n, c->scores, c->idx);
    lut_dec_acc(c->l, c->base_total, c->resid, c->dscales, c->idx, c->n, c->out);
}
static void run_dense_i8(void *p) {
    Ctx *c = p;
    dense_i8_portable(c->qw, c->sw, c->a, c->n, c->l->d, c->l->m, c->l->bias, c->qa, c->acc32,
                      c->out);
}
static void run_encode_only(void *p) {
    Ctx *c = p;
    lut_encode_scalar(c->l, c->a, c->n, c->slab, c->scores, c->idx);
}

int main(void) {
    int fails = validate();
    if (fails) {
        printf("VALIDATION FAILED (%d)\n", fails);
        return 1;
    }

    /* the bench shape: rows=256, C=64, V=9, K=16, M=128 (D=576) */
    size_t n = 256, cc = 64, v = 9, k = 16, m = 128;
    Lut l = lut_build(cc, k, v, m);
    Ctx c = {0};
    c.l = &l;
    c.n = n;
    float *a = malloc(n * l.d * 4);
    fill_normal(a, n * l.d);
    c.a = a;
    c.slab = malloc(n * v * 4);
    c.scores = malloc((n * k > k ? n * k : k) * 4);
    c.out = malloc(n * m * 4);
    c.idx = malloc(n * cc * 2);
    c.acc16 = malloc(m * 2);
    c.acc32 = malloc(m * 4);
    float *w = malloc(l.d * m * 4);
    fill_normal(w, l.d * m);
    c.w = w;
    /* lut-i8 global-scale table */
    float mx = 0;
    for (size_t i = 0; i < cc * k * m; i++) {
        float ab = fabsf(l.table_f32[i]);
        mx = ab > mx ? ab : mx;
    }
    c.gscale = mx / 127.0f;
    c.qi8 = malloc(cc * k * m);
    for (size_t i = 0; i < cc * k * m; i++) {
        float q = roundf(l.table_f32[i] / c.gscale);
        c.qi8[i] = (int8_t)(q < -127 ? -127 : (q > 127 ? 127 : q));
    }
    /* lut-dec decomposition (timing-faithful: mean-row base + 4-bit resid) */
    c.base_total = calloc(m, 4);
    c.dscales = malloc(cc * 4);
    size_t row_bytes = (m + 1) / 2;
    c.resid = calloc(cc * k * row_bytes, 1);
    for (size_t ci = 0; ci < cc; ci++) {
        float *mean = calloc(m, 4);
        for (size_t kk = 0; kk < k; kk++)
            for (size_t j = 0; j < m; j++) mean[j] += l.table_f32[(ci * k + kk) * m + j];
        for (size_t j = 0; j < m; j++) {
            mean[j] /= (float)k;
            c.base_total[j] += mean[j];
        }
        float rmax = 0;
        for (size_t kk = 0; kk < k; kk++)
            for (size_t j = 0; j < m; j++) {
                float r = fabsf(l.table_f32[(ci * k + kk) * m + j] - mean[j]);
                rmax = r > rmax ? r : rmax;
            }
        c.dscales[ci] = rmax / 7.0f > 1e-30f ? rmax / 7.0f : 1e-30f;
        for (size_t kk = 0; kk < k; kk++)
            for (size_t j = 0; j < m; j++) {
                float r = (l.table_f32[(ci * k + kk) * m + j] - mean[j]) / c.dscales[ci];
                int32_t q = (int32_t)roundf(r) + 8;
                q = q < 0 ? 0 : (q > 15 ? 15 : q);
                uint8_t *byte = &c.resid[(ci * k + kk) * row_bytes + j / 2];
                if ((j & 1) == 0)
                    *byte = (*byte & 0xF0) | (uint8_t)q;
                else
                    *byte = (*byte & 0x0F) | ((uint8_t)q << 4);
            }
        free(mean);
    }
    /* dense-i8 weights */
    float wmx = 0;
    for (size_t i = 0; i < l.d * m; i++) {
        float ab = fabsf(w[i]);
        wmx = ab > wmx ? ab : wmx;
    }
    c.sw = wmx / 127.0f;
    c.qw = malloc(l.d * m);
    for (size_t i = 0; i < l.d * m; i++) {
        float q = roundf(w[i] / c.sw);
        c.qw[i] = (int8_t)(q < -127 ? -127 : (q > 127 ? 127 : q));
    }
    c.qa = malloc(l.d);

    struct { const char *name; bench_fn f; } benches[] = {
        {"dense", run_dense},     {"lut", run_lut},         {"lut-simd", run_lut_simd},
        {"lut-i8", run_lut_i8},   {"lut-dec", run_lut_dec}, {"dense-i8", run_dense_i8},
        {"(encode only)", run_encode_only},
    };
    size_t nb = sizeof(benches) / sizeof(benches[0]);
    double ms[16];
    double lut_ms = 0;
    printf("\n== kernel shootout (rows=%zu D=%zu M=%zu K=%zu V=%zu, portable/gcc -O3) ==\n",
           n, l.d, m, k, v);
    for (size_t b = 0; b < nb; b++) {
        ms[b] = timeit(benches[b].f, &c) * 1e3;
        if (strcmp(benches[b].name, "lut") == 0) lut_ms = ms[b];
        fprintf(stderr, "  measured %s\n", benches[b].name);
    }
    for (size_t b = 0; b < nb; b++)
        printf("%-14s %9.4f ms   ratio_vs_lut %.4f\n", benches[b].name, ms[b],
               ms[b] / lut_ms);
    printf("\n(ratios are what the perf gate pins; see docs/benching.md)\n");
    return 0;
}
