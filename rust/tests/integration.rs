//! Cross-layer integration tests: python-trained artifacts executed by
//! the rust native engine and the PJRT runtime, pinned against golden
//! outputs computed by the L2 jax reference at export time.
//!
//! All tests skip (with a notice) when `make artifacts` has not run —
//! `cargo test` must stay green on a fresh checkout; `make test` runs
//! the full matrix.

use lutnn::api::{Engine, PjrtEngine, SessionBuilder};
use lutnn::coordinator::batcher::{Batcher, BatcherConfig};
use lutnn::coordinator::server::{Client, Server, ServerConfig};
use lutnn::coordinator::{ModelEntry, Registry};
use lutnn::lut::LutOpts;
use lutnn::model_fmt;
use lutnn::runtime::{
    artifact_path, artifacts_available, pjrt_available, read_f32_file, PjRtEngine,
};
use lutnn::tensor::Tensor;
use lutnn::util::json::Json;

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

/// PJRT tests additionally need a real (non-stub) xla toolchain.
macro_rules! require_pjrt {
    () => {
        require_artifacts!();
        if !pjrt_available() {
            eprintln!("skipping: PJRT unavailable in this build");
            return;
        }
    };
}

fn golden_input() -> Tensor {
    let x = read_f32_file(&artifact_path("golden_input_b8.f32")).unwrap();
    Tensor::new(vec![8, 16, 16, 3], x)
}

fn argmax_rows(data: &[f32], cols: usize) -> Vec<usize> {
    data.chunks_exact(cols)
        .map(|r| {
            r.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0
        })
        .collect()
}

#[test]
fn native_engine_matches_python_golden_lut() {
    require_artifacts!();
    let graph = model_fmt::load_bundle(&artifact_path("resnet_tiny_lut.lutnn")).unwrap();
    let want = read_f32_file(&artifact_path("golden_lut_out_b8.f32")).unwrap();
    let mut sess = SessionBuilder::new(&graph).opts(LutOpts::all()).max_batch(8).build().unwrap();
    let got = sess.run_alloc(&golden_input()).unwrap();
    assert_eq!(got.shape, vec![8, 10]);
    // The LUT forward is exact-reproducible only up to argmin tie-breaks:
    // the jnp oracle computes |a|^2 - 2a.p + |p|^2 while the engine drops
    // the |a|^2 term, so near-equidistant centroids (k-means duplicates
    // after QAT training) can flip, swapping whole table rows. The
    // tight cross-language contracts are the op-level golden (random,
    // non-degenerate data) and the dense model golden; here we require
    // prediction-level agreement on most rows plus logit correlation.
    let agree = argmax_rows(&got.data, 10)
        .iter()
        .zip(argmax_rows(&want, 10))
        .filter(|(a, b)| **a == *b)
        .count();
    assert!(agree >= 6, "only {agree}/8 predictions agree");
    let mean_diff = got
        .data
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .sum::<f32>()
        / want.len() as f32;
    assert!(mean_diff < 0.5, "mean logit diff {mean_diff}");
}

#[test]
fn native_engine_matches_python_golden_dense() {
    require_artifacts!();
    let graph = model_fmt::load_bundle(&artifact_path("resnet_tiny_dense.lutnn")).unwrap();
    let want = read_f32_file(&artifact_path("golden_dense_out_b8.f32")).unwrap();
    let mut sess = SessionBuilder::new(&graph).opts(LutOpts::all()).max_batch(8).build().unwrap();
    let got = sess.run_alloc(&golden_input()).unwrap();
    let max_diff = got
        .data
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    // Dense path has no argmin ties / quant re-rounding: tight tolerance.
    assert!(max_diff < 2e-3, "max logit diff {max_diff}");
}

#[test]
fn pjrt_model_matches_python_golden() {
    require_pjrt!();
    let engine = PjRtEngine::cpu().unwrap();
    let model = engine
        .load_hlo_text(&artifact_path("resnet_tiny_lut_b8.hlo.txt"), None)
        .unwrap();
    let want = read_f32_file(&artifact_path("golden_lut_out_b8.f32")).unwrap();
    let got = model.run_f32(&golden_input()).unwrap();
    // The golden comes from the jnp reference path; the AOT graph routes
    // through the pallas kernel. Measured agreement is ~1e-7 on this
    // model, so keep a tight bound (near-tie argmin flips would show up
    // here first if the two paths ever diverge).
    let max_diff = got
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-3, "max diff {max_diff}");
}

#[test]
fn pjrt_lut_amm_op_matches_oracle() {
    require_pjrt!();
    let engine = PjRtEngine::cpu().unwrap();
    let model = engine
        .load_hlo_text(&artifact_path("lut_amm_op.hlo.txt"), None)
        .unwrap();
    let a = read_f32_file(&artifact_path("lut_amm_op_a.f32")).unwrap();
    let p = read_f32_file(&artifact_path("lut_amm_op_p.f32")).unwrap();
    let tq_bytes = std::fs::read(artifact_path("lut_amm_op_tq.i8")).unwrap();
    let scale = read_f32_file(&artifact_path("lut_amm_op_scale.f32")).unwrap();
    let want = read_f32_file(&artifact_path("lut_amm_op_out.f32")).unwrap();

    let lit_a = xla::Literal::vec1(&a).reshape(&[256, 576]).unwrap();
    let lit_p = xla::Literal::vec1(&p).reshape(&[64, 16, 9]).unwrap();
    // i8 literals go through the untyped-data constructor (vec1 only
    // covers the float/int NativeType set).
    let lit_t = xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S8,
        &[64, 16, 128],
        &tq_bytes,
    )
    .unwrap();
    let lit_s = xla::Literal::vec1(&scale);
    let got = model.run_literals(&[lit_a, lit_p, lit_t, lit_s]).unwrap();
    assert_eq!(got.len(), want.len());
    let max_diff = got
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-2, "max diff {max_diff}");
}

#[test]
fn rust_lut_engine_matches_op_golden() {
    // The rust native engine against the python oracle on the exact same
    // (a, centroids, table, scale) — the cross-language kernel contract.
    require_artifacts!();
    let a = read_f32_file(&artifact_path("lut_amm_op_a.f32")).unwrap();
    let p = read_f32_file(&artifact_path("lut_amm_op_p.f32")).unwrap();
    let tq_bytes = std::fs::read(artifact_path("lut_amm_op_tq.i8")).unwrap();
    let scale = read_f32_file(&artifact_path("lut_amm_op_scale.f32")).unwrap();
    let want = read_f32_file(&artifact_path("lut_amm_op_out.f32")).unwrap();

    let cb = lutnn::pq::Codebooks::new(64, 16, 9, p);
    let qt = lutnn::tensor::QTable {
        data: tq_bytes.iter().map(|&b| b as i8).collect(),
        c: 64,
        k: 16,
        m: 128,
        scale,
    };
    let lut = lutnn::lut::LutLinear::from_parts(cb, qt, None);
    // f32-blocked path applies per-codebook scales exactly like the oracle
    let opts = LutOpts { mixed_accum: false, ..LutOpts::all() };
    let got = lut.forward(&a, 256, opts);
    let max_diff = got
        .iter()
        .zip(&want)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-2, "max diff {max_diff}");
}

#[test]
fn serve_trained_bundle_over_tcp() {
    require_artifacts!();
    let graph = model_fmt::load_bundle(&artifact_path("resnet_tiny_lut.lutnn")).unwrap();
    let mut registry = Registry::new();
    registry.register(ModelEntry::native("resnet_tiny_lut", &graph, LutOpts::all(), 8, 2).unwrap());
    let mut server = Server::start(
        registry,
        ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
    )
    .unwrap();

    let golden = golden_input();
    let want = read_f32_file(&artifact_path("golden_lut_out_b8.f32")).unwrap();
    let mut client = Client::connect(&server.addr).unwrap();
    for i in 0..4 {
        let item = golden.data[i * 768..(i + 1) * 768].to_vec();
        let out = client.infer("resnet_tiny_lut", &item).unwrap();
        assert_eq!(out.len(), 10);
        assert_eq!(
            argmax_rows(&out, 10)[0],
            argmax_rows(&want[i * 10..(i + 1) * 10], 10)[0],
            "row {i}"
        );
    }
    let metrics = client
        .call(&Json::obj(vec![("cmd", Json::str("metrics"))]))
        .unwrap();
    assert!(metrics.get("ok").unwrap().as_bool().unwrap());
    server.shutdown();
}

#[test]
fn batcher_on_pjrt_engine_pads_batches() {
    require_pjrt!();
    let (_host, mut models) = lutnn::runtime::PjrtHost::spawn(vec![artifact_path(
        "resnet_tiny_lut_b8.hlo.txt",
    )])
    .unwrap();
    let entry = std::sync::Arc::new(ModelEntry::from_engine(
        "pjrt8",
        Box::new(PjrtEngine::new(models.remove(0), 8, false)),
        vec![16, 16, 3],
    ));
    // Self-consistency: the batcher (padding 1 -> 8) must reproduce what
    // the hosted model returns for the full golden batch, row 0.
    let golden = golden_input();
    let mut full = Tensor::zeros(vec![0]);
    entry.engine().run_batch(&golden, &mut full).unwrap();
    let b = Batcher::spawn(std::sync::Arc::clone(&entry), BatcherConfig::default());
    let out = b.submit(golden.data[..768].to_vec()).unwrap();
    assert_eq!(out.len(), 10);
    for (a, bb) in out.iter().zip(&full.data[..10]) {
        assert!((a - bb).abs() < 1e-4, "{a} vs {bb}");
    }
}

#[test]
fn mini_bert_bundle_runs_natively() {
    require_artifacts!();
    let graph = model_fmt::load_bundle(&artifact_path("mini_bert_lut.lutnn")).unwrap();
    assert!(graph.bert.is_some());
    let tokens = Tensor::new(vec![2, 16], (0..32).map(|i| (i % 60) as f32).collect());
    let mut sess = SessionBuilder::new(&graph).opts(LutOpts::all()).build().unwrap();
    let out = sess.run_alloc(&tokens).unwrap();
    assert_eq!(out.shape, vec![2, 4]);
    assert!(out.data.iter().all(|v| v.is_finite()));
}
