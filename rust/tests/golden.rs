//! Golden-file tests: one small model's `Session` outputs pinned
//! bit-for-bit across refactors, for the scalar (`dense` + `lut`)
//! kernels.
//!
//! Outputs are stored as f32 *bit patterns* (`to_bits()` as JSON
//! integers) under `rust/tests/golden/`, so any refactor that changes
//! output bytes — reordered float ops, a different accumulation scheme,
//! a changed PRNG — fails loudly here rather than shipping silently.
//!
//! Bootstrap: when a golden file is missing the test writes it and
//! passes (so a fresh checkout stays green); commit the generated file
//! to pin. Regenerate intentionally with `UPDATE_GOLDEN=1 cargo test
//! --test golden`. Note the fixture PRNG draws through `f64::ln`/`cos`
//! (libm), so goldens are pinned per libm family (CI: x86_64 glibc) —
//! see `rust/tests/golden/README.md`.

use std::path::PathBuf;

use lutnn::api::SessionBuilder;
use lutnn::nn::graph::Graph;
use lutnn::nn::models::{build_cnn_graph, lutify_graph, ConvSpec};
use lutnn::tensor::Tensor;
use lutnn::util::json::{self, Json};
use lutnn::util::prng::Prng;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden")
}

/// Fixed fixture: tiny 2-conv CNN (dense + its LUT conversion) and a
/// fixed 2-item batch. Everything is seeded — same bytes every build.
fn fixture() -> (Graph, Graph, Tensor) {
    let dense = build_cnn_graph(
        "golden",
        [8, 8, 3],
        &[
            ConvSpec { cout: 8, k: 3, stride: 1 },
            ConvSpec { cout: 16, k: 3, stride: 2 },
        ],
        5,
        42,
    );
    let mut rng = Prng::new(7);
    let sample = Tensor::new(vec![4, 8, 8, 3], rng.normal_vec(4 * 8 * 8 * 3, 1.0));
    let lut = lutify_graph(&dense, &sample, 8, 8, 42);
    let mut rng = Prng::new(99);
    let x = Tensor::new(vec![2, 8, 8, 3], rng.normal_vec(2 * 8 * 8 * 3, 1.0));
    (dense, lut, x)
}

fn run_session(graph: &Graph, x: &Tensor) -> Tensor {
    let mut sess = SessionBuilder::new(graph).max_batch(2).build().unwrap();
    sess.run_alloc(x).unwrap()
}

fn to_json(out: &Tensor) -> Json {
    Json::obj(vec![
        (
            "shape",
            Json::Arr(out.shape.iter().map(|&s| Json::num(s as f64)).collect()),
        ),
        (
            "bits",
            Json::Arr(
                out.data
                    .iter()
                    .map(|v| Json::num(v.to_bits() as f64))
                    .collect(),
            ),
        ),
    ])
}

fn check_golden(name: &str, out: &Tensor) {
    let path = golden_dir().join(format!("{name}.json"));
    let update = lutnn::util::env_flag("UPDATE_GOLDEN");
    if update || !path.exists() {
        std::fs::create_dir_all(golden_dir()).unwrap();
        std::fs::write(&path, json::to_string(&to_json(out))).unwrap();
        eprintln!(
            "golden: wrote {} — commit this file to pin scalar-kernel output bytes",
            path.display()
        );
        return;
    }
    let text = std::fs::read_to_string(&path).unwrap();
    let want = json::parse(&text).unwrap_or_else(|e| panic!("golden '{name}' unparseable: {e:?}"));
    assert_eq!(
        want.get("shape").and_then(Json::as_usize_vec),
        Some(out.shape.clone()),
        "golden '{name}' shape"
    );
    let bits: Vec<u32> = want
        .get("bits")
        .and_then(Json::as_arr)
        .expect("golden bits array")
        .iter()
        .map(|j| j.as_f64().expect("bit pattern") as u32)
        .collect();
    assert_eq!(bits.len(), out.data.len(), "golden '{name}' length");
    for (i, (&v, &b)) in out.data.iter().zip(&bits).enumerate() {
        assert_eq!(
            v.to_bits(),
            b,
            "golden '{name}' elem {i}: got {v} ({:#010x}), want bits {b:#010x}. \
             A refactor changed scalar-kernel output bytes; if intentional, \
             regenerate with UPDATE_GOLDEN=1 and commit.",
            v.to_bits()
        );
    }
}

#[test]
fn golden_dense_session_bitwise_stable() {
    let (dense, _, x) = fixture();
    check_golden("cnn_dense", &run_session(&dense, &x));
}

#[test]
fn golden_lut_session_bitwise_stable() {
    let (_, lut, x) = fixture();
    check_golden("cnn_lut", &run_session(&lut, &x));
}

#[test]
fn golden_lut_dec_session_bitwise_stable() {
    // The decomposed kernel is an *approximation* with its own output
    // bytes (documented tolerance lives in kernel_parity); what must
    // not drift silently is those bytes themselves — table split,
    // residual quantization, accumulation order are all pinned here.
    let (_, lut, x) = fixture();
    let mut sess = SessionBuilder::new(&lut)
        .kernel_override("c1", "lut-dec")
        .kernel_override("fc", "lut-dec")
        .max_batch(2)
        .build()
        .unwrap();
    check_golden("cnn_lut_dec", &sess.run_alloc(&x).unwrap());
}

#[test]
fn golden_dense_i8_session_bitwise_stable() {
    // Like lut-dec, dense-i8 is an approximation with its own output
    // bytes (tolerance vs "dense" lives in kernel_parity); this pins
    // those bytes — per-channel weight quantization, i32 accumulation
    // order, and dequant scaling must not drift silently.
    let (dense, _, x) = fixture();
    let mut sess = SessionBuilder::new(&dense)
        .kernel_override("c0", "dense-i8")
        .kernel_override("c1", "dense-i8")
        .kernel_override("fc", "dense-i8")
        .max_batch(2)
        .build()
        .unwrap();
    check_golden("cnn_dense_i8", &sess.run_alloc(&x).unwrap());
}

/// The committed python-exported fixture is a *version 1* bundle; the
/// v2-capable loader must keep reading it forever, the lazy loader must
/// page it in bitwise-identical to the eager path, and its session
/// output bytes are pinned like any other golden.
#[test]
fn golden_v1_fixture_loads_lazily_and_stays_bitwise_stable() {
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/rust/tests/fixtures/py_export_tiny.lutnn"
    );
    let eager = lutnn::model_fmt::load_bundle(path).expect("committed v1 fixture must load");
    let lazy = lutnn::model_fmt::load_bundle_lazy(path).expect("lazy open of v1 fixture");
    assert_eq!(lazy.version(), 1, "committed fixture must stay a v1 bundle");
    assert_eq!(lazy.model_name(), eager.name);
    assert_eq!(lazy.input_shape(), eager.input_shape.as_slice());
    let paged = lazy.graph().expect("paging in the v1 fixture");

    let key = |g: &Graph| -> Vec<u8> {
        let mut bytes = Vec::new();
        for (name, p) in &g.layers {
            bytes.extend_from_slice(name.as_bytes());
            if let lutnn::nn::graph::LayerParams::Lut(l) = p {
                bytes.extend(l.qtable.data.iter().map(|&q| q as u8));
                for v in l.cb.data.iter().chain(&l.qtable.scale).chain(&l.table_f32) {
                    bytes.extend_from_slice(&v.to_bits().to_le_bytes());
                }
            }
        }
        bytes
    };
    assert_eq!(key(&eager), key(&paged), "lazy paging must be bitwise eager");

    let batch = eager.input_shape[0];
    let numel: usize = eager.input_shape.iter().product();
    let mut rng = Prng::new(4242);
    let x = Tensor::new(eager.input_shape.clone(), rng.normal_vec(numel, 1.0));
    let mut sess = SessionBuilder::new(&eager).max_batch(batch).build().unwrap();
    check_golden("py_fixture_session", &sess.run_alloc(&x).unwrap());
}

#[test]
fn simd_session_matches_scalar_fixture_bitwise() {
    // Not file-pinned (the file pins the scalar reference); instead pin
    // the cross-kernel invariant on the same fixture: the lut-simd
    // session must reproduce the scalar session's bytes exactly.
    let (_, lut, x) = fixture();
    let want = run_session(&lut, &x);
    let mut sess = SessionBuilder::new(&lut)
        .kernel_override("c1", "lut-simd")
        .kernel_override("fc", "lut-simd")
        .max_batch(2)
        .build()
        .unwrap();
    let got = sess.run_alloc(&x).unwrap();
    assert_eq!(got.shape, want.shape);
    assert_eq!(got.data, want.data, "lut-simd session must be bitwise scalar");
}
