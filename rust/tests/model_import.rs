//! Integration tests for `model_import`: every committed zoo fixture
//! must survive the whole import -> shape-check -> bundle -> compile ->
//! `api::Session::run` chain, and the importer's diagnostics must pin
//! failures to 1-based source lines through the public API. Unlike the
//! artifact-gated tests in `integration.rs`, everything here runs from
//! the embedded fixtures — no `make artifacts` required.

use lutnn::api::SessionBuilder;
use lutnn::model_fmt::{load_bundle, save_bundle};
use lutnn::model_import::{import_str, parse_module, zoo};
use lutnn::nn::graph::{Graph, LayerParams};
use lutnn::tensor::Tensor;
use lutnn::train::{compile_graph, TrainConfig};
use lutnn::util::prng::Prng;

/// A batch shaped like the graph's input: token ids for BERT graphs,
/// unit normals otherwise.
fn sample_for(g: &Graph, batch: usize, seed: u64) -> Tensor {
    let mut shape = vec![batch];
    shape.extend_from_slice(&g.input_shape[1..]);
    let n: usize = shape.iter().product();
    let mut rng = Prng::new(seed);
    match &g.bert {
        Some(b) => Tensor::new(shape, (0..n).map(|_| rng.below(b.vocab) as f32).collect()),
        None => Tensor::new(shape, rng.normal_vec(n, 1.0)),
    }
}

fn tmp_path(file: &str) -> String {
    let dir = std::env::temp_dir().join("lutnn_model_import_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(file).to_string_lossy().into_owned()
}

fn small_cfg() -> TrainConfig {
    TrainConfig { epochs: 3, kmeans_iters: 6, anneal: 0.8, ..TrainConfig::default() }
}

#[test]
fn every_zoo_fixture_round_trips_to_a_session() {
    for m in &zoo::MODELS {
        let g = import_str(m.source).unwrap_or_else(|e| panic!("{}: {e}", m.name));
        let x = sample_for(&g, g.input_shape[0].max(1), 3);
        let mut s = SessionBuilder::new(&g).build().unwrap();
        let out = s.run_alloc(&x).unwrap();
        assert!(out.data.iter().all(|v| v.is_finite()), "{}: non-finite output", m.name);

        // the imported dense graph itself bundles, byte-exactly
        let path = tmp_path(&format!("{}.lutnn", m.name));
        save_bundle(&g, &path).unwrap();
        let reloaded = load_bundle(&path).unwrap();
        let out2 = SessionBuilder::new(&reloaded).build().unwrap().run_alloc(&x).unwrap();
        assert_eq!(out.data, out2.data, "{}: bundle round-trip must be forward-exact", m.name);
    }
}

#[test]
fn imports_are_deterministic_across_calls() {
    let a = import_str(zoo::CNN_TINY).unwrap();
    let b = import_str(zoo::CNN_TINY).unwrap();
    let x = sample_for(&a, 2, 7);
    let ya = SessionBuilder::new(&a).max_batch(2).build().unwrap().run_alloc(&x).unwrap();
    let yb = SessionBuilder::new(&b).max_batch(2).build().unwrap().run_alloc(&x).unwrap();
    assert_eq!(ya.data, yb.data, "seeded weight generation must be reproducible");
}

#[test]
fn imported_cnn_compiles_and_tracks_its_dense_teacher() {
    let dense = import_str(zoo::CNN_TINY).unwrap();
    let sample = sample_for(&dense, 16, 5);
    let (compiled, reports) = compile_graph(&dense, &sample, 16, 8, &small_cfg()).unwrap();

    assert!(matches!(compiled.layers["c0"], LayerParams::Dense { .. }), "stem stays dense");
    for name in ["c1", "c2", "y"] {
        assert!(matches!(compiled.layers[name], LayerParams::Lut(_)), "{name} must be LUT");
    }
    assert_eq!(reports.len(), 3);

    let path = tmp_path("cnn_tiny_compiled.lutnn");
    save_bundle(&compiled, &path).unwrap();
    let reloaded = load_bundle(&path).unwrap();

    let want =
        SessionBuilder::new(&dense).max_batch(16).build().unwrap().run_alloc(&sample).unwrap();
    let got =
        SessionBuilder::new(&reloaded).max_batch(16).build().unwrap().run_alloc(&sample).unwrap();
    assert_eq!(got.shape, want.shape);
    assert!(got.data.iter().all(|v| v.is_finite()));
    // Documented end-to-end tolerance: three stacked approximate layers
    // (c1, c2, y), so the envelope is wider than the 2x-signal bound the
    // two-layer distill test pins.
    let sig: f32 = want.data.iter().map(|v| v * v).sum::<f32>() / want.len() as f32;
    let err = got.mse(&want);
    assert!(err < 3.0 * sig, "compiled cnn_tiny too far from teacher: mse {err} sig {sig}");
}

#[test]
fn imported_kws_net_compiles_and_serves() {
    let dense = import_str(zoo::KWS_TINY).unwrap();
    let sample = sample_for(&dense, 16, 9);
    let (compiled, reports) = compile_graph(&dense, &sample, 16, 8, &small_cfg()).unwrap();

    assert!(matches!(compiled.layers["c0"], LayerParams::Dense { .. }), "stem stays dense");
    assert!(matches!(compiled.layers["c1"], LayerParams::Lut(_)));
    assert!(matches!(compiled.layers["y"], LayerParams::Lut(_)), "post-flatten fc must be LUT");
    assert_eq!(reports.len(), 2);

    let got =
        SessionBuilder::new(&compiled).max_batch(16).build().unwrap().run_alloc(&sample).unwrap();
    assert_eq!(got.shape, vec![16, 12], "12 keyword classes");
    assert!(got.data.iter().all(|v| v.is_finite()));
}

#[test]
fn imported_bert_compiles_and_tracks_its_dense_teacher() {
    let dense = import_str(zoo::BERT_TINY).unwrap();
    let sample = sample_for(&dense, 4, 11);
    let (compiled, reports) = compile_graph(&dense, &sample, 16, 8, &small_cfg()).unwrap();

    assert!(matches!(compiled.layers["head"], LayerParams::Dense { .. }), "head stays dense");
    for l in 0..2 {
        for nm in ["q", "k", "v", "o", "f1", "f2"] {
            let name = format!("l{l}{nm}");
            assert!(matches!(compiled.layers[&name], LayerParams::Lut(_)), "{name} must be LUT");
        }
    }
    assert_eq!(reports.len(), 12, "6 projections x 2 blocks");

    let path = tmp_path("bert_tiny_compiled.lutnn");
    save_bundle(&compiled, &path).unwrap();
    let reloaded = load_bundle(&path).unwrap();

    let want =
        SessionBuilder::new(&dense).max_batch(4).build().unwrap().run_alloc(&sample).unwrap();
    let got =
        SessionBuilder::new(&reloaded).max_batch(4).build().unwrap().run_alloc(&sample).unwrap();
    assert_eq!(got.shape, want.shape);
    assert!(got.data.iter().all(|v| v.is_finite()));
    // Residual connections and layernorm keep the per-projection
    // approximation error from compounding; 1.5x signal power leaves
    // headroom over the synthetic-teacher bound pinned in train::distill.
    let sig: f32 = want.data.iter().map(|v| v * v).sum::<f32>() / want.len() as f32;
    let err = got.mse(&want);
    assert!(err < 1.5 * sig, "compiled bert_tiny too far from teacher: mse {err} sig {sig}");
}

#[test]
#[allow(deprecated)] // parity against the legacy Graph::run entry point
fn session_matches_legacy_graph_run_on_imported_graphs() {
    use lutnn::lut::LutOpts;
    for m in &zoo::MODELS {
        let g = import_str(m.source).unwrap();
        let x = sample_for(&g, g.input_shape[0].max(1), 13);
        let want = g.run(x.clone(), LutOpts::deployed());
        let got = SessionBuilder::new(&g).build().unwrap().run_alloc(&x).unwrap();
        assert_eq!(got.shape, want.shape, "{}", m.name);
        assert_eq!(got.data, want.data, "{}: Session must match Graph::run bitwise", m.name);
    }
}

#[test]
fn diagnostics_pin_failures_to_source_lines() {
    // unknown op
    let e = import_str("model \"m\";\ninput x: f32[1, 4];\ny = frobnicate(x);\noutput y;\n")
        .unwrap_err();
    assert_eq!(e.line, 3);
    assert!(e.message.contains("unknown op 'frobnicate'"), "{e}");

    // shape mismatch: linear needs rank-2
    let e =
        import_str("model \"m\";\ninput x: f32[1, 4, 4, 2];\ny = linear(x) { out = 3 };\noutput y;\n")
            .unwrap_err();
    assert_eq!(e.line, 3);
    assert!(e.message.contains("rank-2"), "{e}");

    // bad attribute value: even conv kernels have no same-padding
    let e = import_str(
        "model \"m\";\ninput x: f32[1, 8, 8, 2];\n\nc = conv2d(x) { out = 4, kernel = 2 };\noutput c;\n",
    )
    .unwrap_err();
    assert_eq!(e.line, 4, "blank lines still count");
    assert!(e.message.contains("must be odd"), "{e}");

    // unknown attribute key
    let e = import_str(
        "model \"m\";\ninput x: f32[1, 4];\ny = relu(x) { alpha = 1 };\noutput y;\n",
    )
    .unwrap_err();
    assert!(e.message.contains("unsupported attribute 'alpha'"), "{e}");

    // dangling tensor reference
    let e = import_str("model \"m\";\ninput x: f32[1, 4];\ny = relu(ghost);\noutput y;\n")
        .unwrap_err();
    assert_eq!(e.line, 3);
    assert!(e.message.contains("unknown tensor 'ghost'"), "{e}");

    // non-flatten reshape
    let e = import_str(
        "model \"m\";\ninput x: f32[1, 4, 4, 2];\nr = reshape(x) { shape = [4, 8] };\noutput r;\n",
    )
    .unwrap_err();
    assert_eq!(e.line, 3);
    assert!(e.message.contains("only reshape to [-1]"), "{e}");

    // Display carries the line for anyhow-style call sites
    assert!(format!("{e}").starts_with("line 3:"), "{e}");
}

#[test]
fn parse_module_exposes_inferred_shapes() {
    let m = parse_module(zoo::KWS_TINY).unwrap();
    assert_eq!(m.input_shape, vec![1, 25, 12, 1]);
    let flat = m.nodes.iter().find(|n| n.name == "f").expect("kws_tiny has a flatten node");
    assert_eq!(flat.shape, vec![1, 1152], "12x6x16 feature map flattened");
    let y = m.nodes.iter().find(|n| n.name == "y").unwrap();
    assert_eq!(y.shape, vec![1, 12]);
    assert_eq!(m.output, "y");
}
