//! Seeded fuzz harness for the `.lutnn` bundle format — the v2
//! entropy-coded sections and the lazy loader in particular.
//!
//! Properties:
//! * **Round-trip**: a random graph saved raw (v1) and entropy-coded
//!   (v2) parses back bitwise-identical both ways — every layer kind,
//!   every f32 bit pattern, every quantized table byte.
//! * **Lazy parity**: `load_bundle_lazy(..).graph()` is bitwise equal
//!   to the eager `load_bundle` on the same file.
//! * **Truncation**: a compressed bundle cut at every byte boundary
//!   errors typed, never panics.
//! * **Corruption**: random byte flips anywhere in the file must never
//!   panic the parser (parsing may succeed — a flipped table byte is
//!   still a valid bundle — but it must return, not crash).
//!
//! Seed: `BUNDLE_FUZZ_SEED` (decimal, env) — CI pins one so failures
//! reproduce; locally each value explores a different stream.

use lutnn::model_fmt::{
    load_bundle, load_bundle_lazy, parse_bundle, save_bundle, save_bundle_compressed, V1, VERSION,
};
use lutnn::nn::graph::{Graph, LayerParams};
use lutnn::nn::models::{build_cnn_graph, lutify_graph, ConvSpec};
use lutnn::tensor::Tensor;
use lutnn::util::prop::{self, Gen};

fn fuzz_seed() -> u64 {
    std::env::var("BUNDLE_FUZZ_SEED")
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(0xB0B5)
}

fn tmp(name: &str) -> String {
    let dir = std::env::temp_dir().join("lutnn_bundle_fuzz");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name).to_string_lossy().into_owned()
}

/// A random small CNN graph; about half the cases are lutified so both
/// dense-only and LUT-table bundles are exercised.
fn gen_graph(g: &mut Gen) -> Graph {
    let convs: Vec<ConvSpec> = (0..g.usize(1..3))
        .map(|_| ConvSpec { cout: *g.pick(&[4usize, 8]), k: 3, stride: *g.pick(&[1usize, 2]) })
        .collect();
    let nout = g.usize(2..7);
    let seed = g.usize(0..1000) as u64;
    let base = build_cnn_graph("fuzz", [8, 8, 3], &convs, nout, seed);
    if g.bool() {
        let n = g.usize(2..5);
        let x = Tensor::new(vec![n, 8, 8, 3], g.f32_vec(n * 192, 1.0));
        let k = *g.pick(&[8usize, 16]);
        lutify_graph(&base, &x, k, 8, seed)
    } else {
        base
    }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Bitwise equality over every layer kind the format carries. `a` is
/// the reference; when it is an in-memory graph built by
/// `LutLinear::new` (not a parse), pass `a_is_source = true`: the
/// builder keeps the exact FP32 table while the loader dequantizes it
/// from the persisted INT8 table, so `table_f32` is only comparable
/// between two *parsed* graphs.
fn assert_graphs_bitwise(a: &Graph, b: &Graph, a_is_source: bool) -> Result<(), String> {
    if a.ops != b.ops {
        return Err("ops differ".into());
    }
    if a.layers.len() != b.layers.len() {
        return Err("layer count differs".into());
    }
    for (name, pa) in &a.layers {
        let pb = b.layers.get(name).ok_or_else(|| format!("layer '{name}' missing"))?;
        let ok = match (pa, pb) {
            (LayerParams::Dense { w: wa, b: ba, m: ma }, LayerParams::Dense { w: wb, b: bb, m: mb }) => {
                ma == mb
                    && bits(wa) == bits(wb)
                    && ba.as_deref().map(bits) == bb.as_deref().map(bits)
            }
            (LayerParams::Lut(la), LayerParams::Lut(lb)) => {
                la.qtable.data == lb.qtable.data
                    && bits(&la.qtable.scale) == bits(&lb.qtable.scale)
                    && bits(&la.cb.data) == bits(&lb.cb.data)
                    && (a_is_source || bits(&la.table_f32) == bits(&lb.table_f32))
                    && la.bias.as_deref().map(bits) == lb.bias.as_deref().map(bits)
            }
            (
                LayerParams::Bn { gamma: ga, beta: ba, mean: ma, var: va },
                LayerParams::Bn { gamma: gb, beta: bb, mean: mb, var: vb },
            ) => {
                bits(ga) == bits(gb)
                    && bits(ba) == bits(bb)
                    && bits(ma) == bits(mb)
                    && bits(va) == bits(vb)
            }
            (LayerParams::Ln { gamma: ga, beta: ba }, LayerParams::Ln { gamma: gb, beta: bb }) => {
                bits(ga) == bits(gb) && bits(ba) == bits(bb)
            }
            (
                LayerParams::Embedding { tok: ta, pos: pa, d: da },
                LayerParams::Embedding { tok: tb, pos: pb, d: db },
            ) => da == db && bits(ta) == bits(tb) && bits(pa) == bits(pb),
            _ => false,
        };
        if !ok {
            return Err(format!("layer '{name}' differs bitwise"));
        }
    }
    Ok(())
}

#[test]
fn random_graphs_round_trip_bitwise_through_v1_and_v2() {
    prop::check_seeded(fuzz_seed() ^ 0xB17E, 12, |g| {
        let graph = gen_graph(g);
        let p1 = tmp("rt_v1.lutnn");
        let p2 = tmp("rt_v2.lutnn");
        save_bundle(&graph, &p1).map_err(|e| e.to_string())?;
        save_bundle_compressed(&graph, &p2).map_err(|e| e.to_string())?;

        let d1 = std::fs::read(&p1).unwrap();
        let d2 = std::fs::read(&p2).unwrap();
        let v1 = u32::from_le_bytes(d1[4..8].try_into().unwrap());
        let v2 = u32::from_le_bytes(d2[4..8].try_into().unwrap());
        if v1 != V1 {
            return Err(format!("raw writer must stay on version {V1}, wrote {v1}"));
        }
        if v2 != V1 && v2 != VERSION {
            return Err(format!("compressed writer wrote unknown version {v2}"));
        }
        if d2.len() > d1.len() {
            return Err(format!("compressed bundle grew: {} > {}", d2.len(), d1.len()));
        }

        let g1 = parse_bundle(&d1).map_err(|e| e.to_string())?;
        let g2 = parse_bundle(&d2).map_err(|e| e.to_string())?;
        assert_graphs_bitwise(&graph, &g1, true)?;
        assert_graphs_bitwise(&g1, &g2, false)
    });
}

#[test]
fn lazy_loader_matches_eager_bitwise_on_random_bundles() {
    prop::check_seeded(fuzz_seed() ^ 0x1A2B, 8, |g| {
        let graph = gen_graph(g);
        let path = tmp("lazy_fuzz.lutnn");
        if g.bool() {
            save_bundle(&graph, &path).map_err(|e| e.to_string())?;
        } else {
            save_bundle_compressed(&graph, &path).map_err(|e| e.to_string())?;
        }
        let lazy = load_bundle_lazy(&path).map_err(|e| e.to_string())?;
        if lazy.model_name() != graph.name {
            return Err(format!("lazy header name '{}' != '{}'", lazy.model_name(), graph.name));
        }
        if lazy.input_shape() != graph.input_shape.as_slice() {
            return Err("lazy header input shape differs".into());
        }
        let eager = load_bundle(&path).map_err(|e| e.to_string())?;
        let paged = lazy.graph().map_err(|e| e.to_string())?;
        assert_graphs_bitwise(&eager, &paged, false)
    });
}

#[test]
fn truncated_compressed_bundles_error_at_every_byte() {
    let mut g = Gen::from_seed(fuzz_seed() ^ 0x7C0F);
    let graph = gen_graph(&mut g);
    let path = tmp("trunc_fuzz.lutnn");
    save_bundle_compressed(&graph, &path).unwrap();
    let data = std::fs::read(&path).unwrap();
    assert!(parse_bundle(&data).is_ok());
    for cut in 0..data.len() {
        assert!(parse_bundle(&data[..cut]).is_err(), "cut at {cut} must fail");
    }
}

#[test]
fn random_corruption_never_panics_the_parser() {
    // a few base bundles built once (kmeans is the slow part), then many
    // cheap flip cases over them: flips land in envelope, header and
    // blob regions alike
    let bases: Vec<Vec<u8>> = (0..3u64)
        .map(|i| {
            let mut gg = Gen::from_seed(fuzz_seed() ^ 0x5151 ^ i);
            let graph = gen_graph(&mut gg);
            let path = tmp(&format!("corrupt_fuzz_{i}.lutnn"));
            save_bundle_compressed(&graph, &path).unwrap();
            std::fs::read(&path).unwrap()
        })
        .collect();
    prop::check_seeded(fuzz_seed() ^ 0xDEAD, 100, |g| {
        let mut data = g.pick(&bases).clone();
        for _ in 0..g.usize(1..6) {
            let at = g.usize(0..data.len());
            data[at] ^= 1u8 << g.usize(0..8);
        }
        // must return (Ok or typed Err), never panic
        let _ = parse_bundle(&data);
        Ok(())
    });
}
