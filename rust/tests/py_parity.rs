//! Cross-language export parity: a committed fixture bundle produced by
//! `python/compile/export.py` (regenerate with
//! `python3 -m compile.make_parity_fixture` from `python/`) is parsed,
//! executed and rebuilt by the rust side.
//!
//! Three contracts:
//! 1. `model_fmt::parse_bundle` + `api::Session` reproduce the python
//!    reference forward (`ref.lut_amm_quantized_ref`) within the
//!    fixture's documented tolerance (1e-4: f32 FP-order differences;
//!    the fixture generator asserts an argmin safety margin so encode
//!    cannot tie-flip);
//! 2. rust's table builder + quantizer (`pq::build_table` +
//!    `pq::quantize_table`) reproduce the python-exported INT8 table
//!    from the same centroids/weights within one quantization LSB;
//! 3. a rust-*trained* equivalent (`train::distill_layer` on the same
//!    dense teacher) tracks the teacher as well as the python export
//!    does — both within the documented mse < 0.5 * signal envelope,
//!    which algebraically bounds their pairwise distance.

use lutnn::api::SessionBuilder;
use lutnn::lut::{LutLinear, LutOpts};
use lutnn::model_fmt;
use lutnn::nn::graph::LayerParams;
use lutnn::nn::ops;
use lutnn::tensor::Tensor;
use lutnn::train::{distill_layer, TrainConfig};
use lutnn::util::json::{self, Json};
use lutnn::util::prng::Prng;
use lutnn::util::prop;

const FIXTURE: &[u8] = include_bytes!("fixtures/py_export_tiny.lutnn");

/// The bundle header's `meta` object (parse_bundle drops it).
fn meta() -> Json {
    let hlen = u32::from_le_bytes(FIXTURE[8..12].try_into().unwrap()) as usize;
    let header = json::parse(std::str::from_utf8(&FIXTURE[12..12 + hlen]).unwrap()).unwrap();
    header.get("meta").expect("fixture meta").clone()
}

fn f32_vec(j: &Json) -> Vec<f32> {
    j.as_arr().unwrap().iter().map(|v| v.as_f64().unwrap() as f32).collect()
}

/// (input batch, python expected output, documented tolerance)
fn fixture_io(m: &Json) -> (Tensor, Vec<f32>, f32) {
    let fi = m.get("fixture_input").unwrap();
    let x = Tensor::new(
        fi.get("shape").unwrap().as_usize_vec().unwrap(),
        f32_vec(fi.get("data").unwrap()),
    );
    let expected = f32_vec(m.get("expected_output").unwrap().get("data").unwrap());
    let tol = m.get("tolerance").unwrap().as_f64().unwrap() as f32;
    (x, expected, tol)
}

fn lut_layer(g: &lutnn::nn::graph::Graph, name: &str) -> LutLinear {
    match &g.layers[name] {
        LayerParams::Lut(l) => l.clone(),
        _ => panic!("layer '{name}' should be lut"),
    }
}

/// fc1 (dense) + relu — the fixture model's prefix, used to derive the
/// LUT layer's input activations.
fn fc1_forward(g: &lutnn::nn::graph::Graph, x: &Tensor) -> Tensor {
    let LayerParams::Dense { w, b, m } = &g.layers["fc1"] else {
        panic!("fc1 should be dense");
    };
    let mut h = ops::linear(x, w, b.as_deref(), *m);
    ops::relu(&mut h);
    h
}

fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| (x as f64 - y as f64).powi(2)).sum::<f64>() / a.len() as f64
}

#[test]
fn session_forward_matches_python_reference() {
    let g = model_fmt::parse_bundle(FIXTURE).expect("python fixture must parse");
    assert_eq!(g.name, "py_export_tiny");
    let (x, expected, tol) = fixture_io(&meta());

    // f32 table accumulation mirrors the python oracle's math exactly
    // (per-codebook scale applied in f32) — only FP order differs.
    let f32_opts = LutOpts { mixed_accum: false, ..LutOpts::deployed() };
    let mut sess = SessionBuilder::new(&g).opts(f32_opts).max_batch(8).build().unwrap();
    let got = sess.run_alloc(&x).unwrap();
    assert_eq!(got.shape, vec![8, 5]);
    prop::assert_close(&got.data, &expected, 0.0, tol).unwrap();

    // the deployed integer path re-rounds onto a common scale: one
    // extra quantization step per accumulated codebook row.
    let lut = lut_layer(&g, "fc2");
    let deployed_tol = tol + lut.cb.c as f32 * lut.common_scale();
    let mut sess = SessionBuilder::new(&g).max_batch(8).build().unwrap();
    let got = sess.run_alloc(&x).unwrap();
    prop::assert_close(&got.data, &expected, 0.0, deployed_tol).unwrap();
}

#[test]
fn rust_table_builder_matches_python_export() {
    let g = model_fmt::parse_bundle(FIXTURE).unwrap();
    let m = meta();
    let teacher = m.get("teacher").unwrap();
    let w2 = f32_vec(teacher.get("w").unwrap());
    let b2 = f32_vec(teacher.get("b").unwrap());
    let parsed = lut_layer(&g, "fc2");

    // Rebuild the operator from the same centroids + dense weight
    // through rust's Eq. 3 table builder and §3.3 quantizer.
    let rebuilt = LutLinear::new(parsed.cb.clone(), &w2, parsed.m, Some(b2), 8);
    for (c, (&sa, &sb)) in rebuilt.qtable.scale.iter().zip(&parsed.qtable.scale).enumerate() {
        assert!((sa - sb).abs() <= 1e-6 * sb.abs().max(1e-6), "scale[{c}]: {sa} vs {sb}");
    }
    for (i, (&qa, &qb)) in rebuilt.qtable.data.iter().zip(&parsed.qtable.data).enumerate() {
        assert!(
            (qa as i16 - qb as i16).abs() <= 1,
            "table entry {i} drifted: rust {qa} vs python {qb}"
        );
    }

    // forward parity on the fixture's activations: identical centroids
    // mean identical encodes, so outputs differ by at most 1.5 LSB of
    // the largest per-codebook scale per accumulated row.
    let (x, _, _) = fixture_io(&m);
    let h = fc1_forward(&g, &x);
    let smax = parsed.qtable.scale.iter().cloned().fold(0.0f32, f32::max);
    let atol = parsed.cb.c as f32 * 1.5 * smax + 1e-4;
    let out_a = rebuilt.forward_f32_table(&h.data, h.rows(), LutOpts::deployed());
    let out_b = parsed.forward_f32_table(&h.data, h.rows(), LutOpts::deployed());
    prop::assert_close(&out_a, &out_b, 0.0, atol).unwrap();
}

#[test]
fn rust_distilled_equivalent_tracks_the_same_teacher() {
    let g = model_fmt::parse_bundle(FIXTURE).unwrap();
    let m = meta();
    let teacher = m.get("teacher").unwrap();
    let w2 = f32_vec(teacher.get("w").unwrap());
    let b2 = f32_vec(teacher.get("b").unwrap());
    let c = teacher.get("c").unwrap().as_usize().unwrap();
    let k = teacher.get("k").unwrap().as_usize().unwrap();
    let out_m = lut_layer(&g, "fc2").m;

    // Calibrate on rust-generated activations from the same model
    // prefix, then distill against the identical dense teacher.
    let mut rng = Prng::new(0);
    let x_cal = Tensor::new(vec![256, 8], rng.normal_vec(256 * 8, 1.0));
    let h_cal = fc1_forward(&g, &x_cal);
    let cfg = TrainConfig { epochs: 8, anneal: 0.8, ..TrainConfig::default() };
    let (layer, report) =
        distill_layer(&h_cal.data, h_cal.rows(), &w2, Some(&b2), out_m, c, k, &cfg);
    assert!(report.epoch_loss.iter().all(|l| l.is_finite()));

    // Evaluate on the committed fixture batch. Documented tolerance:
    // both the python export and the rust-trained equivalent stay
    // within mse < 0.5 * teacher signal power, which bounds their
    // pairwise mse by 2 * (mse_py + mse_rust).
    let (x, expected, _) = fixture_io(&m);
    let h_fix = fc1_forward(&g, &x);
    let teacher_out = ops::linear(&h_fix, &w2, Some(&b2), out_m);
    let f32_opts = LutOpts { mixed_accum: false, ..LutOpts::deployed() };
    let rust_out = layer.into_lut(8).forward(&h_fix.data, h_fix.rows(), f32_opts);

    let sig = teacher_out.data.iter().map(|v| (v * v) as f64).sum::<f64>()
        / teacher_out.len() as f64;
    let mse_py = mse(&expected, &teacher_out.data);
    let mse_rust = mse(&rust_out, &teacher_out.data);
    assert!(mse_py < 0.5 * sig, "python export off teacher: {mse_py} vs signal {sig}");
    assert!(mse_rust < 0.5 * sig, "rust distillation off teacher: {mse_rust} vs signal {sig}");
    let pairwise = mse(&rust_out, &expected);
    assert!(
        pairwise <= 2.0 * (mse_py + mse_rust) + 1e-6,
        "pairwise {pairwise} vs bound {}",
        2.0 * (mse_py + mse_rust)
    );
}
