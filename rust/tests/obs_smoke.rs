//! Observability smoke: the machine-readable metrics surface, end to
//! end over TCP on a zoo model.
//!
//! CI (`obs-smoke`) runs this twice: once plain (must pass), and once
//! with `OBS_SMOKE_CORRUPT=1`, which injects a malformed exposition
//! line so the prometheus parse must fail — proving the gate can
//! actually go red (the same pattern as `memory-gate`).

use lutnn::coordinator::server::{Client, Server, ServerConfig};
use lutnn::coordinator::{ModelEntry, Registry};
use lutnn::lut::LutOpts;
use lutnn::model_import::zoo;
use lutnn::obs::prom;
use lutnn::util::json::Json;

fn prom_text(c: &mut Client) -> String {
    let req = Json::obj(vec![("cmd", Json::str("metrics")), ("format", Json::str("prometheus"))]);
    let resp = c.call(&req).unwrap();
    let mut text = resp.get("text").unwrap().as_str().unwrap().to_string();
    if std::env::var("OBS_SMOKE_CORRUPT").is_ok() {
        // Red path: CI asserts this corruption makes the test fail.
        text.push_str("0bad{x=\"y\" 1\n");
    }
    text
}

fn requests_total(samples: &[prom::Sample], model: &str) -> f64 {
    samples
        .iter()
        .find(|s| s.name == "lutnn_requests_total" && s.label("model") == Some(model))
        .expect("lutnn_requests_total sample for the model")
        .value
}

#[test]
fn obs_smoke_structured_metrics_prometheus_and_spans() {
    let graph = zoo::import("cnn_tiny").unwrap();
    let mut registry = Registry::new();
    let entry = ModelEntry::native("cnn_tiny", &graph, LutOpts::deployed(), 8, 1).unwrap();
    registry.register(entry);
    let mut server = Server::start(
        registry,
        ServerConfig { addr: "127.0.0.1:0".into(), profile: true, ..Default::default() },
    )
    .unwrap();
    let mut c = Client::connect(&server.addr).unwrap();
    let input = vec![0.1f32; 16 * 16 * 3];
    for _ in 0..12 {
        let out = c.infer("cnn_tiny", &input).unwrap();
        assert!(out.iter().all(|v| v.is_finite()));
    }

    // Structured numeric JSON: exact counters, ordered quantiles.
    let resp = c.call(&Json::obj(vec![("cmd", Json::str("metrics"))])).unwrap();
    assert!(resp.get("ok").unwrap().as_bool().unwrap());
    let m = resp.get("metrics").unwrap().get("cnn_tiny").unwrap();
    assert_eq!(m.get("requests").unwrap().as_usize().unwrap(), 12, "{resp:?}");
    assert_eq!(m.get("errors").unwrap().as_usize().unwrap(), 0);
    assert!(m.get("batches").unwrap().as_usize().unwrap() >= 1);
    let lat = m.get("latency").unwrap();
    let p50 = lat.get("p50").unwrap().as_f64().unwrap();
    let p95 = lat.get("p95").unwrap().as_f64().unwrap();
    let p99 = lat.get("p99").unwrap().as_f64().unwrap();
    assert!(p50 > 0.0, "latency histogram recorded nothing: {lat:?}");
    assert!(p50 <= p95 && p95 <= p99, "quantile order: {p50} {p95} {p99}");
    let residency = resp.get("residency").unwrap();
    assert!(residency.get("resident_bytes").unwrap().as_f64().is_some());

    // Prometheus exposition parses and counters are monotone.
    let samples = prom::parse(&prom_text(&mut c)).expect("exposition must parse");
    let first = requests_total(&samples, "cnn_tiny");
    assert_eq!(first, 12.0);
    c.infer("cnn_tiny", &input).unwrap();
    let again = prom::parse(&prom_text(&mut c)).expect("exposition must parse");
    let second = requests_total(&again, "cnn_tiny");
    assert!(second > first, "counter must be monotone: {first} -> {second}");

    // The span ring saw every request and recorded clean outcomes.
    let spans = c.call(&Json::obj(vec![("cmd", Json::str("spans"))])).unwrap();
    let model = spans.get("models").unwrap().get("cnn_tiny").unwrap();
    assert!(model.get("offered").unwrap().as_usize().unwrap() >= 13, "{spans:?}");
    let arr = model.get("spans").unwrap().as_arr().unwrap();
    assert!(!arr.is_empty());
    assert!(arr.iter().all(|s| s.get("outcome").unwrap().as_str().unwrap() == "ok"));
    server.shutdown();
}
