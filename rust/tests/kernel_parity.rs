//! Cross-kernel parity fuzz harness: every kernel in the default
//! `KernelRegistry` must agree with the scalar reference on randomized
//! layer shapes, within its documented per-kernel tolerance:
//!
//! * `"lut-simd"` — **bitwise-equal** to `"lut"` (the SIMD encode
//!   performs the same FP ops in the same per-element order; rustc
//!   never reorders or fuses float math, so any byte difference is a
//!   kernel bug, not "noise").
//! * `"lut-i8"`  — within `LutI8Kernel::abs_tolerance()` absolute error
//!   per element (global-scale table requantization bound).
//! * `"lut-dec"` — within `DecLutKernel::abs_tolerance()` absolute error
//!   per element (4-bit residual quantization of the decomposed table
//!   plus the reference's own common-scale re-rounding).
//! * `"dense"`   — bitwise-equal to `nn::ops::linear`.
//! * `"dense-i8"` — within `DenseI8Kernel::abs_tolerance(input_max_abs)`
//!   absolute error per element (per-output-channel weight
//!   requantization bound; scales with the input magnitude, so the
//!   tolerance is computed from each case's actual max-abs input).
//!
//! Shapes are drawn from a seeded PRNG (`util::prop`) including the
//! edge cases n=1, C=1, K=1, M=1, V=1, and K values that straddle every
//! vector-lane width the runtime arms use (4-lane NEON, 8-lane AVX2,
//! 16-lane AVX-512) — 7/9/15/17 force remainder tails on each arm. The
//! `lut-simd` bitwise tests run against whichever backend
//! `lut::simd::active_backend()` selected on this host (CI logs it via
//! `active_backend_is_a_known_enum_member`); the per-arm direct-call
//! bitwise pinning for *every* executable arm lives in
//! `lut::simd::tests::every_executable_arm_is_bitwise_the_oracle`.
//! Every future kernel added to the registry gets pre-verified by
//! extending `LUT_FAMILY` / adding a tolerance arm here.
//!
//! Seed: `KERNEL_PARITY_SEED` (decimal, env) — CI pins one so failures
//! reproduce; locally each value explores a different shape stream.
//! Replay one case with `util::prop::check_one(<case_seed>, ...)`.

use lutnn::api::{
    DecLutKernel, DenseI8Kernel, KernelBuildCtx, KernelRegistry, LinearKernel, LutI8Kernel, Scratch,
};
use lutnn::lut::{LutLinear, LutOpts};
use lutnn::nn::graph::LayerParams;
use lutnn::nn::ops;
use lutnn::pq::kmeans::learn_codebooks;
use lutnn::tensor::Tensor;
use lutnn::util::prop::{self, Gen};

/// ≥ 200 randomized shape cases per kernel (acceptance floor).
const CASES: usize = 220;

fn fuzz_seed() -> u64 {
    std::env::var("KERNEL_PARITY_SEED")
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// One random LUT layer: geometry, input batch, and the built LutLinear.
struct LutCase {
    n: usize,
    m: usize,
    a: Vec<f32>,
    lut: LutLinear,
}

fn gen_lut_case(g: &mut Gen) -> LutCase {
    // Edge-heavy shape distribution: 1s are always in the pool, and K
    // straddles every vector-lane boundary the backends use (4-lane
    // NEON, 8-lane AVX2, 16-lane AVX-512): 7/9 around 8, 15/17 around
    // 16, plus exact multiples — so lane-remainder tails are always hit.
    let n = *g.pick(&[1usize, 2, 3, 5, 8, 13]);
    let c = *g.pick(&[1usize, 2, 3, 4, 5]);
    let v = *g.pick(&[1usize, 2, 3, 4, 9]);
    let k = *g.pick(&[1usize, 4, 7, 8, 9, 12, 15, 16, 17]);
    let m = *g.pick(&[1usize, 2, 5, 8, 17]);
    let d = c * v;
    let a = g.f32_vec(n * d, 1.0);
    let w = g.f32_vec(d * m, 1.0);
    let cb = learn_codebooks(&a, n, d, c, k, 4, g.case_seed);
    let bias = if g.bool() { Some(g.f32_vec(m, 0.5)) } else { None };
    let lut = LutLinear::new(cb, &w, m, bias, 8);
    LutCase { n, m, a, lut }
}

/// Run `tag` on the case through the default registry; output buffer is
/// pre-poisoned so a kernel that under-writes fails loudly.
fn run_kernel(tag: &str, case: &LutCase, opts: LutOpts, poison: f32) -> Vec<f32> {
    let registry = KernelRegistry::with_defaults();
    let ctx = KernelBuildCtx { opts };
    let params = LayerParams::Lut(case.lut.clone());
    let kernel = registry.build(tag, &params, &ctx).expect(tag);
    assert_eq!(kernel.name(), tag);
    let mut scratch = Scratch::default();
    let mut out = vec![poison; case.n * case.m];
    kernel.forward_into(&case.a, case.n, &mut scratch, &mut out);
    out
}

#[test]
fn lut_simd_bitwise_equals_scalar_reference() {
    prop::check_seeded(fuzz_seed(), CASES, |g| {
        let case = gen_lut_case(g);
        // centroid_stationary stays on (the bitwise contract's domain —
        // every shipped config sets it); accumulate toggles vary.
        let opts = LutOpts {
            centroid_stationary: true,
            interleaved_argmin: g.bool(),
            blocked_table_read: g.bool(),
            mixed_accum: g.bool(),
        };
        let want = run_kernel("lut", &case, opts, 3.0);
        let got = run_kernel("lut-simd", &case, opts, -3.0);
        if got != want {
            let diff = got
                .iter()
                .zip(&want)
                .enumerate()
                .find(|(_, (x, y))| x != y)
                .map(|(i, (x, y))| format!("elem {i}: {x} vs {y}"))
                .unwrap_or_default();
            return Err(format!(
                "lut-simd diverged (n={} m={} c={} k={} v={} {opts:?}): {diff}",
                case.n, case.m, case.lut.cb.c, case.lut.cb.k, case.lut.cb.v
            ));
        }
        if !got.iter().all(|x| x.is_finite()) {
            return Err("non-finite output".into());
        }
        Ok(())
    });
}

#[test]
fn lut_i8_within_documented_tolerance_of_scalar_reference() {
    prop::check_seeded(fuzz_seed() ^ 0x5EED_1, CASES, |g| {
        let case = gen_lut_case(g);
        let opts = LutOpts::deployed();
        let want = run_kernel("lut", &case, opts, 7.0);
        let got = run_kernel("lut-i8", &case, opts, -7.0);
        let tol = LutI8Kernel::new(case.lut.clone()).abs_tolerance();
        prop::assert_close(&got, &want, 0.0, tol).map_err(|e| {
            format!(
                "lut-i8 out of tolerance {tol} (n={} m={} c={} k={} v={}): {e}",
                case.n, case.m, case.lut.cb.c, case.lut.cb.k, case.lut.cb.v
            )
        })
    });
}

#[test]
fn lut_dec_within_documented_tolerance_of_scalar_reference() {
    prop::check_seeded(fuzz_seed() ^ 0x5EED_4, CASES, |g| {
        let case = gen_lut_case(g);
        let opts = LutOpts::deployed();
        let want = run_kernel("lut", &case, opts, 9.0);
        let got = run_kernel("lut-dec", &case, opts, -9.0);
        let tol = DecLutKernel::new(case.lut.clone()).abs_tolerance();
        prop::assert_close(&got, &want, 0.0, tol).map_err(|e| {
            format!(
                "lut-dec out of tolerance {tol} (n={} m={} c={} k={} v={}): {e}",
                case.n, case.m, case.lut.cb.c, case.lut.cb.k, case.lut.cb.v
            )
        })
    });
}

#[test]
fn dense_kernel_bitwise_equals_ops_linear() {
    prop::check_seeded(fuzz_seed() ^ 0x5EED_2, CASES, |g| {
        let n = *g.pick(&[1usize, 2, 3, 7, 16]);
        let d = g.usize(1..40);
        let m = g.usize(1..24);
        let x = Tensor::new(vec![n, d], g.f32_vec(n * d, 1.0));
        let w = g.f32_vec(d * m, 1.0);
        let bias = if g.bool() { Some(g.f32_vec(m, 0.5)) } else { None };
        let want = ops::linear(&x, &w, bias.as_deref(), m);
        let registry = KernelRegistry::with_defaults();
        let ctx = KernelBuildCtx { opts: LutOpts::deployed() };
        let params = LayerParams::Dense { w, b: bias, m };
        let kernel = registry.build("dense", &params, &ctx).unwrap();
        let mut scratch = Scratch::default();
        let mut out = vec![5.0f32; n * m];
        kernel.forward_into(&x.data, n, &mut scratch, &mut out);
        if out != want.data {
            return Err(format!("dense kernel diverged (n={n} d={d} m={m})"));
        }
        Ok(())
    });
}

#[test]
fn dense_i8_within_documented_tolerance_of_ops_linear() {
    prop::check_seeded(fuzz_seed() ^ 0x5EED_5, CASES, |g| {
        let n = *g.pick(&[1usize, 2, 3, 7, 16]);
        let d = g.usize(1..40);
        let m = g.usize(1..24);
        let x = Tensor::new(vec![n, d], g.f32_vec(n * d, 1.0));
        let w = g.f32_vec(d * m, 1.0);
        let bias = if g.bool() { Some(g.f32_vec(m, 0.5)) } else { None };
        let want = ops::linear(&x, &w, bias.as_deref(), m);
        let registry = KernelRegistry::with_defaults();
        let ctx = KernelBuildCtx { opts: LutOpts::deployed() };
        let params = LayerParams::Dense { w: w.clone(), b: bias.clone(), m };
        let kernel = registry.build("dense-i8", &params, &ctx).unwrap();
        assert_eq!(kernel.name(), "dense-i8");
        let mut scratch = Scratch::default();
        let mut out = vec![-5.0f32; n * m];
        kernel.forward_into(&x.data, n, &mut scratch, &mut out);
        let amax = x.data.iter().fold(0.0f32, |acc, v| acc.max(v.abs()));
        let tol = DenseI8Kernel::new(w, bias, m).abs_tolerance(amax);
        prop::assert_close(&out, &want.data, 0.0, tol)
            .map_err(|e| format!("dense-i8 out of tolerance {tol} (n={n} d={d} m={m}): {e}"))
    });
}

#[test]
fn active_backend_is_a_known_enum_member() {
    // Logged under `-- --nocapture` in CI so the parity run records which
    // simd arm the fuzz actually exercised on that runner; the value must
    // be one of the documented `BACKENDS` enum members (the committed
    // bench baseline and the schema mirror both key off this set).
    use lutnn::lut::simd;
    let backend = simd::active_backend();
    eprintln!("kernel_parity: active simd backend = {backend}");
    assert!(
        simd::BACKENDS.contains(&backend),
        "active_backend() returned {backend:?}, not in the documented set {:?}",
        simd::BACKENDS
    );
}

#[test]
fn all_lut_family_kernels_agree_on_explicit_edge_shapes() {
    // Deterministic sweep of the corners the fuzzer samples: every
    // (n, c, v, k, m) with a 1 somewhere, plus lane remainders.
    let shapes: &[(usize, usize, usize, usize, usize)] = &[
        (1, 1, 1, 1, 1),   // everything degenerate
        (1, 3, 4, 16, 9),  // single row
        (4, 1, 9, 8, 3),   // single codebook
        (5, 3, 2, 1, 4),   // single centroid (argmin over K=1)
        (3, 2, 3, 12, 1),  // single output, K with lane remainder
        (2, 4, 9, 16, 31), // M not a lane multiple
        (3, 2, 5, 7, 6),   // K=7: remainder on 4- and 8-lane arms
        (2, 3, 4, 9, 11),  // K=9: one full 8-lane vector + 1 tail
        (3, 2, 9, 15, 5),  // K=15: one short of the 16-lane width
        (2, 3, 3, 17, 8),  // K=17: one past the 16-lane width
    ];
    for &(n, c, v, k, m) in shapes {
        let mut g = Gen::from_seed(0xED6E ^ ((n * 31 + c * 7 + v * 3 + k + m) as u64));
        let d = c * v;
        let a = g.f32_vec(n * d, 1.0);
        let w = g.f32_vec(d * m, 1.0);
        let cb = learn_codebooks(&a, n, d, c, k, 4, 0);
        let lut = LutLinear::new(cb, &w, m, Some(g.f32_vec(m, 0.5)), 8);
        let case = LutCase { n, m, a, lut };
        let opts = LutOpts::deployed();
        let want = run_kernel("lut", &case, opts, 2.0);
        let got_simd = run_kernel("lut-simd", &case, opts, -2.0);
        assert_eq!(got_simd, want, "lut-simd @ ({n},{c},{v},{k},{m})");
        let got_i8 = run_kernel("lut-i8", &case, opts, -2.0);
        let tol = LutI8Kernel::new(case.lut.clone()).abs_tolerance();
        prop::assert_close(&got_i8, &want, 0.0, tol)
            .unwrap_or_else(|e| panic!("lut-i8 @ ({n},{c},{v},{k},{m}): {e}"));
        let got_dec = run_kernel("lut-dec", &case, opts, -2.0);
        let tol = DecLutKernel::new(case.lut.clone()).abs_tolerance();
        prop::assert_close(&got_dec, &want, 0.0, tol)
            .unwrap_or_else(|e| panic!("lut-dec @ ({n},{c},{v},{k},{m}): {e}"));
    }
}

#[test]
fn zoo_model_shapes_hold_parity_across_the_lut_family() {
    // The fuzz tests above draw synthetic geometries; this sweep replays
    // the *real* layer shapes of the three committed importable models
    // (`model_import::zoo`), split with the same `pick_v` heuristic the
    // compile path uses — so the shapes kernels see in production are
    // pre-verified here, down to the 27-wide conv stem and the
    // 1152-wide post-flatten classifier.
    use lutnn::nn::models::pick_v;
    let shapes = lutnn::model_import::zoo::linear_shapes();
    assert!(shapes.len() >= 8, "zoo must contribute a real spread of geometries: {shapes:?}");
    let mut g = Gen::from_seed(fuzz_seed() ^ 0x5EED_3);
    for &(d, m) in &shapes {
        let v = pick_v(d);
        let c = d / v;
        for &k in &[8usize, 16] {
            let n = *g.pick(&[1usize, 3, 8]);
            let a = g.f32_vec(n * d, 1.0);
            let w = g.f32_vec(d * m, 1.0);
            let cb = learn_codebooks(&a, n, d, c, k, 4, g.case_seed);
            let lut = LutLinear::new(cb, &w, m, Some(g.f32_vec(m, 0.5)), 8);
            let case = LutCase { n, m, a, lut };
            let opts = LutOpts::deployed();
            let want = run_kernel("lut", &case, opts, 4.0);
            let got_simd = run_kernel("lut-simd", &case, opts, -4.0);
            assert_eq!(got_simd, want, "lut-simd @ zoo shape (d={d}, m={m}, k={k})");
            let got_i8 = run_kernel("lut-i8", &case, opts, -4.0);
            let tol = LutI8Kernel::new(case.lut.clone()).abs_tolerance();
            prop::assert_close(&got_i8, &want, 0.0, tol)
                .unwrap_or_else(|e| panic!("lut-i8 @ zoo shape (d={d}, m={m}, k={k}): {e}"));
            let got_dec = run_kernel("lut-dec", &case, opts, -4.0);
            let tol = DecLutKernel::new(case.lut.clone()).abs_tolerance();
            prop::assert_close(&got_dec, &want, 0.0, tol)
                .unwrap_or_else(|e| panic!("lut-dec @ zoo shape (d={d}, m={m}, k={k}): {e}"));
        }
    }
}

#[test]
fn scratch_reuse_across_kernels_is_deterministic() {
    // The session shares one Scratch across heterogeneous layers; a
    // kernel reading stale scratch state would show up as run-order
    // dependence. Interleave all four LUT kernels over two shapes and
    // compare against fresh-scratch runs.
    let mut g = Gen::from_seed(0xACE5);
    let mk = |g: &mut Gen, n: usize, c: usize, v: usize, k: usize, m: usize| {
        let d = c * v;
        let a = g.f32_vec(n * d, 1.0);
        let w = g.f32_vec(d * m, 1.0);
        let cb = learn_codebooks(&a, n, d, c, k, 4, 1);
        LutCase { n, m, a, lut: LutLinear::new(cb, &w, m, None, 8) }
    };
    let case1 = mk(&mut g, 7, 3, 4, 16, 6);
    let case2 = mk(&mut g, 2, 5, 9, 8, 13);
    let registry = KernelRegistry::with_defaults();
    let ctx = KernelBuildCtx { opts: LutOpts::deployed() };
    let mut shared = Scratch::default();
    for round in 0..2 {
        for case in [&case1, &case2] {
            for tag in ["lut", "lut-simd", "lut-i8", "lut-dec"] {
                let params = LayerParams::Lut(case.lut.clone());
                let kernel = registry.build(tag, &params, &ctx).unwrap();
                let mut out = vec![0.0f32; case.n * case.m];
                kernel.forward_into(&case.a, case.n, &mut shared, &mut out);
                let fresh = run_kernel(tag, case, LutOpts::deployed(), 0.0);
                assert_eq!(out, fresh, "{tag} round {round} shape-dependent scratch");
            }
        }
    }
}
