//! Stub for the `xla` crate (PJRT bindings).
//!
//! This build environment does not carry the XLA/PJRT native toolchain,
//! so this crate provides the exact API surface `lutnn::runtime` uses —
//! types, signatures, generics — with every entry point that would touch
//! PJRT returning [`Error`] at runtime. The `lutnn` code gates all PJRT
//! paths behind `runtime::pjrt_available()` / artifact checks, so a
//! stubbed build compiles, tests and serves the native engine normally.
//!
//! To enable real PJRT execution, point the `xla` path dependency in the
//! workspace `Cargo.toml` at the vendored real crate; no `lutnn` source
//! changes are required.

/// Stub error. Call sites format this with `{:?}`.
pub struct Error(pub String);

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

fn unavailable() -> Error {
    Error("xla stub: PJRT toolchain not present in this build (see rust/vendor/xla)".to_string())
}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types used by untyped literal construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    S8,
    S32,
    F32,
}

/// Scalar types accepted by [`Literal::vec1`].
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Host-side literal (tensor value). Stub: carries nothing.
pub struct Literal(());

impl Literal {
    pub fn vec1<T: NativeType>(_values: &[T]) -> Literal {
        Literal(())
    }

    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[i64],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

/// Parsed HLO module. Stub: construction always fails.
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// An XLA computation ready for compilation.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device buffer returned by execution.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Compiled executable. Stub: cannot be constructed.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// PJRT client. Stub: `cpu()` reports the toolchain is absent.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}
