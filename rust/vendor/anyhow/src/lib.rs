//! Vendored minimal `anyhow` stand-in.
//!
//! This build environment vendors no crates.io closure, so the error
//! conveniences the repo uses (`anyhow!`, `bail!`, `ensure!`, `Result`,
//! `Context`) are implemented here at the scale the project needs.
//! Semantics mirror the real crate for the used surface:
//!
//! * `Error` carries a message plus a context chain; `{e}` prints the
//!   outermost context, `{e:#}` prints the full `outer: ...: root` chain.
//! * `?` converts any `std::error::Error + Send + Sync + 'static`.
//! * `Context::{context, with_context}` wrap `Result` and `Option`.
//!
//! Swapping back to the real `anyhow` is a one-line Cargo.toml change —
//! no call site depends on anything beyond the real crate's API.

use std::fmt;

/// Error with a human-readable message and an optional cause chain.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Construct from any displayable message (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { msg: msg.to_string(), source: None }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The cause chain, outermost first (including `self`).
    pub fn chain(&self) -> impl Iterator<Item = &Error> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

// NOTE: like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes the blanket `From` below
// coherent (no overlap with `impl From<T> for T`).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve the std source chain as context entries.
        let mut stack = Vec::new();
        let mut cur: Option<&(dyn std::error::Error + 'static)> = Some(&e);
        while let Some(err) = cur {
            stack.push(err.to_string());
            cur = err.source();
        }
        let mut out: Option<Error> = None;
        for msg in stack.into_iter().rev() {
            out = Some(match out {
                Some(inner) => inner.context(msg),
                None => Error::msg(msg),
            });
        }
        out.unwrap_or_else(|| Error::msg("unknown error"))
    }
}

/// `anyhow::Result<T>` alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to `Result` / `Option` (mirrors `anyhow::Context`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Early-return an error from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/7f3a").map(|_| ())?;
        Ok(())
    }

    #[test]
    fn context_chain_formats() {
        let e = io_fail().context("loading bundle").unwrap_err();
        assert_eq!(format!("{e}"), "loading bundle");
        assert!(format!("{e:#}").starts_with("loading bundle: "));
        assert!(e.chain().count() >= 2);
    }

    #[test]
    fn macros_and_ensure() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(3).unwrap_err()), "three is right out");
        let e: Error = anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
    }
}
