//! L3 serving coordinator: model registry, router, replica pools with a
//! work-stealing dynamic batcher, metrics, workload traces and a TCP
//! front-end.
//!
//! Request path (no python anywhere):
//!
//! ```text
//!   client ──TCP line-JSON──> Server ─┐
//!   in-proc callers (examples/benches)┼──> Router (Registry::resolve)
//!                                     │        │
//!                                     │        v
//!                                     │  shared injector queue (per model,
//!                                     │  bounded; try_submit sheds on full
//!                                     │  queue or exceeded deadline)
//!                                     │    │        │        │
//!                                     │    v        v        v
//!                                     │  worker0  worker1 … workerN-1
//!                                     │  (one per replica; idle workers
//!                                     │   steal from the shared queue,
//!                                     │   each batches up to its OWN
//!                                     │   replica's max_batch)
//!                                     │    │        │        │
//!                                     │    v        v        v
//!                                     └─ EnginePool: dyn api::Engine × N
//!                                         │                   │
//!                                   NativeEngine         PjrtEngine
//!                                   (Session per          (AOT XLA,
//!                                    replica — no          fixed batch,
//!                                    arena contention)     padded)
//! ```
//!
//! The stack is backend-agnostic: a [`ModelEntry`] carries an
//! [`pool::EnginePool`] of `Box<dyn Engine>` replicas (see
//! [`crate::api::engine`]). Each batcher worker stacks requests into
//! one borrowed batch tensor and runs its own replica — no per-request
//! input clone on the native path, no cross-replica lock contention.
//! New backends implement the `Engine` trait (plus `clone_replica` to
//! opt into homogeneous pooling) and register here; the batcher, server
//! and router never change.

pub mod batcher;
pub mod metrics;
pub mod pool;
pub mod server;
pub mod trace;

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

pub use crate::api::engine::{Engine, NativeEngine, PjrtEngine};
use crate::lut::LutOpts;
use crate::nn::graph::Graph;
pub use pool::EnginePool;

/// One registered model: a name, a pool of engine replicas, and the
/// per-request input shape the router validates against.
pub struct ModelEntry {
    pub name: String,
    pub pool: EnginePool,
    /// per-request input shape (without batch dim)
    pub item_shape: Vec<usize>,
}

impl ModelEntry {
    /// Register a graph on the rust-native engine: `replicas` sessions
    /// compiled from one shared immutable bundle (each replica owns its
    /// scratch arenas; the graph is lutified/loaded exactly once), each
    /// with arenas sized for `max_batch`.
    pub fn native(
        name: &str,
        graph: &Graph,
        opts: LutOpts,
        max_batch: usize,
        replicas: usize,
    ) -> Result<ModelEntry> {
        let engine = NativeEngine::from_graph(graph, opts, max_batch)?;
        let item_shape = engine.item_shape();
        Ok(ModelEntry {
            name: name.to_string(),
            pool: EnginePool::replicate(Box::new(engine), replicas)?,
            item_shape,
        })
    }

    /// Register any single engine implementation (one-replica pool).
    pub fn from_engine(
        name: &str,
        engine: Box<dyn Engine>,
        item_shape: Vec<usize>,
    ) -> ModelEntry {
        ModelEntry {
            name: name.to_string(),
            pool: EnginePool::single(engine),
            item_shape,
        }
    }

    /// Register a heterogeneous replica pool (e.g. a fixed-batch
    /// `PjrtEngine` beside elastic `NativeEngine`s). The replicas must
    /// compute the same function; the batcher routes any request to any
    /// replica and batches against each replica's own `max_batch`.
    pub fn from_engines(
        name: &str,
        engines: Vec<Box<dyn Engine>>,
        item_shape: Vec<usize>,
    ) -> Result<ModelEntry> {
        Ok(ModelEntry {
            name: name.to_string(),
            pool: EnginePool::from_engines(engines)?,
            item_shape,
        })
    }

    /// The pool's primary replica, for direct (unbatched) execution.
    pub fn engine(&self) -> &dyn Engine {
        self.pool.primary()
    }

    pub fn item_len(&self) -> usize {
        self.item_shape.iter().product()
    }
}

/// Name -> model registry with routing aliases.
#[derive(Default)]
pub struct Registry {
    models: BTreeMap<String, Arc<ModelEntry>>,
    aliases: BTreeMap<String, String>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn register(&mut self, entry: ModelEntry) {
        self.models.insert(entry.name.clone(), Arc::new(entry));
    }

    /// Route alias, e.g. "default" -> "resnet_tiny_lut".
    pub fn alias(&mut self, from: &str, to: &str) {
        self.aliases.insert(from.to_string(), to.to_string());
    }

    pub fn resolve(&self, name: &str) -> Result<Arc<ModelEntry>> {
        let target = self.aliases.get(name).map(|s| s.as_str()).unwrap_or(name);
        self.models
            .get(target)
            .cloned()
            .ok_or_else(|| anyhow!("unknown model '{name}'"))
    }

    pub fn names(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }

    /// Grow every model's pool to at least `n` replicas (best effort:
    /// engines without `clone_replica` — and entries whose `Arc` has
    /// already been shared out — keep their explicit pool size). Errors
    /// only when a supported clone actually fails.
    pub fn replicate_to(&mut self, n: usize) -> Result<()> {
        for entry in self.models.values_mut() {
            if let Some(e) = Arc::get_mut(entry) {
                e.pool.try_grow_to(n)?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::models::{build_cnn_graph, ConvSpec};
    use crate::tensor::Tensor;

    fn native_entry(name: &str) -> ModelEntry {
        let g = build_cnn_graph(
            name,
            [8, 8, 3],
            &[ConvSpec { cout: 4, k: 3, stride: 1 }],
            5,
            0,
        );
        ModelEntry::native(name, &g, LutOpts::all(), 8, 1).unwrap()
    }

    #[test]
    fn registry_resolve_and_alias() {
        let mut r = Registry::new();
        r.register(native_entry("m1"));
        r.alias("default", "m1");
        assert_eq!(r.resolve("m1").unwrap().name, "m1");
        assert_eq!(r.resolve("default").unwrap().name, "m1");
        assert!(r.resolve("missing").is_err());
        assert_eq!(r.names(), vec!["m1".to_string()]);
    }

    #[test]
    fn native_entry_runs_any_batch() {
        let e = native_entry("m");
        let mut out = Tensor::zeros(vec![0]);
        for n in [1usize, 3, 7] {
            let x = Tensor::zeros(vec![n, 8, 8, 3]);
            e.engine().run_batch(&x, &mut out).unwrap();
            assert_eq!(out.shape, vec![n, 5]);
        }
        assert_eq!(e.engine().max_batch(), None);
        assert_eq!(e.item_len(), 192);
    }

    #[test]
    fn native_entry_builds_replica_pools() {
        let g = build_cnn_graph(
            "mr",
            [8, 8, 3],
            &[ConvSpec { cout: 4, k: 3, stride: 1 }],
            5,
            0,
        );
        let e = ModelEntry::native("mr", &g, LutOpts::all(), 8, 3).unwrap();
        assert_eq!(e.pool.len(), 3);
        // replicas are numerically interchangeable
        let x = Tensor::new(vec![2, 8, 8, 3], vec![0.5; 2 * 192]);
        let mut a = Tensor::zeros(vec![0]);
        let mut b = Tensor::zeros(vec![0]);
        e.pool.replica(0).run_batch(&x, &mut a).unwrap();
        e.pool.replica(2).run_batch(&x, &mut b).unwrap();
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn registry_replicate_to_grows_native_pools() {
        let mut r = Registry::new();
        r.register(native_entry("grow"));
        assert_eq!(r.resolve("grow").unwrap().pool.len(), 1);
        // the resolve() Arc above is temporary, so get_mut succeeds
        r.replicate_to(4).unwrap();
        assert_eq!(r.resolve("grow").unwrap().pool.len(), 4);
    }
}
