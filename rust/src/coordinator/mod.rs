//! L3 serving coordinator: model registry, router, replica pools with a
//! work-stealing dynamic batcher, metrics, workload traces and a TCP
//! front-end.
//!
//! Request path (no python anywhere):
//!
//! ```text
//!   client ──TCP line-JSON──> Server ─┐
//!   in-proc callers (examples/benches)┼──> Router (Registry::resolve)
//!                                     │        │
//!                                     │        v
//!                                     │  shared injector queue (per model,
//!                                     │  bounded; try_submit sheds on full
//!                                     │  queue or exceeded deadline)
//!                                     │    │        │        │
//!                                     │    v        v        v
//!                                     │  worker0  worker1 … workerN-1
//!                                     │  (one per replica; idle workers
//!                                     │   steal from the shared queue,
//!                                     │   each batches up to its OWN
//!                                     │   replica's max_batch)
//!                                     │    │        │        │
//!                                     │    v        v        v
//!                                     └─ EnginePool: dyn api::Engine × N
//!                                         │                   │
//!                                   NativeEngine         PjrtEngine
//!                                   (Session per          (AOT XLA,
//!                                    replica — no          fixed batch,
//!                                    arena contention)     padded)
//! ```
//!
//! The stack is backend-agnostic: a [`ModelEntry`] carries an
//! [`pool::EnginePool`] of `Box<dyn Engine>` replicas (see
//! [`crate::api::engine`]). Each batcher worker stacks requests into
//! one borrowed batch tensor and runs its own replica — no per-request
//! input clone on the native path, no cross-replica lock contention.
//! New backends implement the `Engine` trait (plus `clone_replica` to
//! opt into homogeneous pooling) and register here; the batcher, server
//! and router never change.

pub mod batcher;
pub mod metrics;
pub mod pool;
pub mod server;
pub mod trace;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{anyhow, Result};

pub use crate::api::engine::{Engine, NativeEngine, PjrtEngine};
use crate::lut::LutOpts;
use crate::model_fmt::{self, LazyBundle};
use crate::nn::graph::Graph;
use metrics::{ResidencySnapshot, ResidencyStats};
pub use pool::EnginePool;

/// One registered model: a name, a pool of engine replicas, and the
/// per-request input shape the router validates against.
pub struct ModelEntry {
    pub name: String,
    pub pool: EnginePool,
    /// per-request input shape (without batch dim)
    pub item_shape: Vec<usize>,
}

impl ModelEntry {
    /// Register a graph on the rust-native engine: `replicas` sessions
    /// compiled from one shared immutable bundle (each replica owns its
    /// scratch arenas; the graph is lutified/loaded exactly once), each
    /// with arenas sized for `max_batch`.
    pub fn native(
        name: &str,
        graph: &Graph,
        opts: LutOpts,
        max_batch: usize,
        replicas: usize,
    ) -> Result<ModelEntry> {
        let engine = NativeEngine::from_graph(graph, opts, max_batch)?;
        let item_shape = engine.item_shape();
        Ok(ModelEntry {
            name: name.to_string(),
            pool: EnginePool::replicate(Box::new(engine), replicas)?,
            item_shape,
        })
    }

    /// Register any single engine implementation (one-replica pool).
    pub fn from_engine(
        name: &str,
        engine: Box<dyn Engine>,
        item_shape: Vec<usize>,
    ) -> ModelEntry {
        ModelEntry {
            name: name.to_string(),
            pool: EnginePool::single(engine),
            item_shape,
        }
    }

    /// Register a heterogeneous replica pool (e.g. a fixed-batch
    /// `PjrtEngine` beside elastic `NativeEngine`s). The replicas must
    /// compute the same function; the batcher routes any request to any
    /// replica and batches against each replica's own `max_batch`.
    pub fn from_engines(
        name: &str,
        engines: Vec<Box<dyn Engine>>,
        item_shape: Vec<usize>,
    ) -> Result<ModelEntry> {
        Ok(ModelEntry {
            name: name.to_string(),
            pool: EnginePool::from_engines(engines)?,
            item_shape,
        })
    }

    /// The pool's primary replica, for direct (unbatched) execution.
    pub fn engine(&self) -> &dyn Engine {
        self.pool.primary()
    }

    pub fn item_len(&self) -> usize {
        self.item_shape.iter().product()
    }

    /// Bytes the pool keeps resident across all replicas (tables +
    /// arenas; see [`Engine::resident_bytes`]) — what the registry's
    /// `resident_budget_bytes` budgets against.
    pub fn resident_bytes(&self) -> usize {
        self.pool.resident_bytes()
    }
}

/// A lazily registered model: a header-only [`LazyBundle`] plus the
/// pool parameters to apply when the first request pages it in. Also
/// what a warmed model evicts *back to* — the spec is retained for the
/// model's whole lifetime so eviction never loses resolvability.
struct ColdModel {
    bundle: LazyBundle,
    opts: LutOpts,
    max_batch: usize,
    replicas: usize,
}

/// A paged-in lazy model: the live entry, the retained spec it evicts
/// back to, the byte footprint it was charged at page-in time, and its
/// LRU stamp.
struct WarmModel {
    entry: Arc<ModelEntry>,
    spec: ColdModel,
    bytes: usize,
    last_used: AtomicU64,
}

/// Per-model outcome of [`Registry::replicate_to`], so callers can see
/// (and log) which pools actually grew instead of a silent best-effort
/// no-op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicateOutcome {
    /// pool reached the requested size (final replica count)
    Grown(usize),
    /// entry's `Arc` is shared out, so the pool cannot be mutated
    SkippedShared,
    /// engine lacks `clone_replica`; pool stayed at this size
    Unsupported(usize),
}

/// Name -> model registry with routing aliases and a bounded-residency
/// cold-model lifecycle.
///
/// Models register either **eagerly** ([`Registry::register`], the
/// engine pool is built up front, never evicted) or **cold**
/// ([`Registry::register_lazy`], only the bundle header is read — name
/// and input shape — while the table sections stay on disk). Cold
/// models are paged in by the first [`Registry::resolve`] that hits
/// them; paging happens under the cold mutex so concurrent first
/// requests build the pool exactly once. Warmed models then live in an
/// `RwLock` map read on every resolve — the hot path never touches the
/// cold mutex again.
///
/// With [`Registry::set_resident_budget`] set, page-ins evict
/// least-recently-used warmed models *first* (back to their retained
/// specs, resolvable again on the next request), so the
/// `resident_bytes` gauge never exceeds the budget — not even
/// transiently — unless a single model alone is bigger than the whole
/// budget, in which case it still pages in (serving wins) with the
/// cache otherwise empty. Eviction only drops the registry's `Arc`:
/// in-flight handles keep the old pool serving until they drop, and a
/// later resolve rebuilds the model from disk exactly once.
#[derive(Default)]
pub struct Registry {
    models: BTreeMap<String, Arc<ModelEntry>>,
    aliases: BTreeMap<String, String>,
    /// lazily registered and not currently paged in (never requested,
    /// or evicted back) — header-only specs
    cold: Mutex<BTreeMap<String, ColdModel>>,
    /// paged-in lazy models, LRU-stamped; the read path for warm resolves
    warmed: RwLock<BTreeMap<String, WarmModel>>,
    /// byte bound over `warmed` (`None` = never evict)
    resident_budget: Option<usize>,
    stats: ResidencyStats,
    /// monotonic LRU clock (ticks per touch — deterministic, no wall time)
    clock: AtomicU64,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn register(&mut self, entry: ModelEntry) {
        self.models.insert(entry.name.clone(), Arc::new(entry));
    }

    /// Register a bundle cold under the model name its header declares.
    /// Costs one header read (~a few hundred bytes) regardless of table
    /// size, so a server can register a large zoo cheaply; the engine
    /// pool (`opts` / `max_batch` / `replicas`, as in
    /// [`ModelEntry::native`]) is built when the first request arrives.
    pub fn register_lazy(
        &mut self,
        path: &str,
        opts: LutOpts,
        max_batch: usize,
        replicas: usize,
    ) -> Result<String> {
        let bundle = model_fmt::load_bundle_lazy(path)?;
        let name = bundle.model_name().to_string();
        self.cold
            .get_mut()
            .expect("cold-model lock poisoned")
            .insert(name.clone(), ColdModel { bundle, opts, max_batch, replicas });
        Ok(name)
    }

    /// Route alias, e.g. "default" -> "resnet_tiny_lut".
    pub fn alias(&mut self, from: &str, to: &str) {
        self.aliases.insert(from.to_string(), to.to_string());
    }

    /// Bound the total bytes of warmed lazy models (`None` = never
    /// evict). Page-ins that would exceed the budget evict LRU warmed
    /// models first; see the type-level docs for the one exception
    /// (a single model bigger than the whole budget).
    pub fn set_resident_budget(&mut self, bytes: Option<usize>) {
        self.resident_budget = bytes;
    }

    pub fn resident_budget(&self) -> Option<usize> {
        self.resident_budget
    }

    /// Residency gauges and counters (resident bytes/models, page-ins,
    /// evictions) plus the configured budget.
    pub fn residency(&self) -> ResidencySnapshot {
        self.stats.snapshot(self.resident_budget)
    }

    pub fn resolve(&self, name: &str) -> Result<Arc<ModelEntry>> {
        let target = self.aliases.get(name).map(|s| s.as_str()).unwrap_or(name);
        if let Some(e) = self.models.get(target) {
            return Ok(e.clone());
        }
        // Hot path for lazy models: a read lock on the warmed map. This
        // deliberately never touches the cold mutex — resolving an
        // already-warmed model used to serialize every caller on it.
        if let Some(e) = self.touch_warm(target) {
            return Ok(e);
        }
        self.page_in(target, name)
    }

    /// The currently resident entry for `name` (eager or warmed),
    /// without paging a cold model in and without bumping the LRU
    /// stamp — for staleness checks (the server's batcher sweep), not
    /// for serving.
    pub fn peek(&self, name: &str) -> Option<Arc<ModelEntry>> {
        let target = self.aliases.get(name).map(|s| s.as_str()).unwrap_or(name);
        if let Some(e) = self.models.get(target) {
            return Some(e.clone());
        }
        self.warmed
            .read()
            .expect("warmed-model lock poisoned")
            .get(target)
            .map(|w| Arc::clone(&w.entry))
    }

    /// Warm-path lookup: read lock + LRU-stamp bump.
    fn touch_warm(&self, target: &str) -> Option<Arc<ModelEntry>> {
        let warmed = self.warmed.read().expect("warmed-model lock poisoned");
        warmed.get(target).map(|w| {
            w.last_used.store(self.tick(), Ordering::Relaxed);
            Arc::clone(&w.entry)
        })
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Cold path: build the pool from the retained spec. Runs under the
    /// cold mutex so concurrent first requests construct the pool
    /// exactly once — the racer that loses the lock re-checks `warmed`
    /// and reuses the winner's entry.
    fn page_in(&self, target: &str, requested: &str) -> Result<Arc<ModelEntry>> {
        let mut cold = self.cold.lock().expect("cold-model lock poisoned");
        if let Some(e) = self.touch_warm(target) {
            return Ok(e);
        }
        let Some(spec) = cold.get(target) else {
            return Err(anyhow!("unknown model '{requested}'"));
        };
        let graph = spec.bundle.graph()?;
        let entry = Arc::new(ModelEntry::native(
            target,
            &graph,
            spec.opts,
            spec.max_batch,
            spec.replicas,
        )?);
        drop(graph);
        let bytes = entry.resident_bytes();
        // Evict-before-insert: free LRU entries until the newcomer fits,
        // so the resident gauge never exceeds the budget even
        // transiently. `saturating_sub` handles the one exception — a
        // model bigger than the whole budget empties the cache and pages
        // in anyway (serving wins over the bound).
        if let Some(budget) = self.resident_budget {
            self.evict_warmed_to(budget.saturating_sub(bytes) as u64, &mut cold);
        }
        // Only drop the pending spec once the build succeeded (a
        // transiently unreadable bundle stays resolvable); it moves into
        // the warm entry so eviction can put it back.
        let spec = cold.remove(target).expect("pending spec held under the cold lock");
        self.stats.resident_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.stats.resident_models.fetch_add(1, Ordering::Relaxed);
        self.stats.page_ins.fetch_add(1, Ordering::Relaxed);
        self.warmed.write().expect("warmed-model lock poisoned").insert(
            target.to_string(),
            WarmModel {
                entry: Arc::clone(&entry),
                spec,
                bytes,
                last_used: AtomicU64::new(self.tick()),
            },
        );
        Ok(entry)
    }

    /// Evict least-recently-used warmed models until the resident gauge
    /// is at most `target`. Caller holds the cold mutex (lock order is
    /// always cold -> warmed); evicted specs go back into `cold`, so
    /// the models stay resolvable, and any in-flight `Arc` keeps the
    /// old pool serving until it drops.
    fn evict_warmed_to(&self, target: u64, cold: &mut BTreeMap<String, ColdModel>) {
        let mut warmed = self.warmed.write().expect("warmed-model lock poisoned");
        while self.stats.resident_bytes.load(Ordering::Relaxed) > target {
            let victim = warmed
                .iter()
                .min_by_key(|(_, w)| w.last_used.load(Ordering::Relaxed))
                .map(|(name, _)| name.clone());
            let Some(victim) = victim else { break };
            let w = warmed.remove(&victim).expect("victim vanished under the write lock");
            self.stats.resident_bytes.fetch_sub(w.bytes as u64, Ordering::Relaxed);
            self.stats.resident_models.fetch_sub(1, Ordering::Relaxed);
            self.stats.evictions.fetch_add(1, Ordering::Relaxed);
            cold.insert(victim, w.spec);
        }
    }

    /// Every registered model exactly once, whichever lifecycle state
    /// it is in (eager, cold-pending, warmed, or evicted back to cold).
    pub fn names(&self) -> Vec<String> {
        let mut names: std::collections::BTreeSet<String> = self.models.keys().cloned().collect();
        // lock order cold -> warmed matches the page-in path, so a
        // listing taken mid-page-in sees each model in exactly one map
        let cold = self.cold.lock().expect("cold-model lock poisoned");
        let warmed = self.warmed.read().expect("warmed-model lock poisoned");
        names.extend(cold.keys().cloned());
        names.extend(warmed.keys().cloned());
        names.into_iter().collect()
    }

    /// Lazily registered models not currently paged in (never resolved,
    /// or evicted back to their pending spec).
    pub fn cold_names(&self) -> Vec<String> {
        self.cold
            .lock()
            .expect("cold-model lock poisoned")
            .keys()
            .cloned()
            .collect()
    }

    /// Grow every model's pool — eager *and* warmed-lazy — to at least
    /// `n` replicas, reporting a per-model [`ReplicateOutcome`] instead
    /// of silently no-opping: entries whose `Arc` is already shared out
    /// are `SkippedShared`, engines without `clone_replica` are
    /// `Unsupported`. Errors only when a supported clone actually
    /// fails. Growing a warmed model re-measures its footprint and
    /// moves the resident gauge; the next page-in settles any budget
    /// overshoot by evicting.
    pub fn replicate_to(&mut self, n: usize) -> Result<Vec<(String, ReplicateOutcome)>> {
        fn grow(entry: &mut Arc<ModelEntry>, n: usize) -> Result<ReplicateOutcome> {
            match Arc::get_mut(entry) {
                None => Ok(ReplicateOutcome::SkippedShared),
                Some(e) => {
                    let size = e.pool.try_grow_to(n)?;
                    Ok(if size >= n {
                        ReplicateOutcome::Grown(size)
                    } else {
                        ReplicateOutcome::Unsupported(size)
                    })
                }
            }
        }
        let mut outcomes = Vec::new();
        for (name, entry) in self.models.iter_mut() {
            outcomes.push((name.clone(), grow(entry, n)?));
        }
        let warmed = self.warmed.get_mut().expect("warmed-model lock poisoned");
        for (name, w) in warmed.iter_mut() {
            let outcome = grow(&mut w.entry, n)?;
            if !matches!(outcome, ReplicateOutcome::SkippedShared) {
                let bytes = w.entry.resident_bytes();
                self.stats.resident_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
                self.stats.resident_bytes.fetch_sub(w.bytes as u64, Ordering::Relaxed);
                w.bytes = bytes;
            }
            outcomes.push((name.clone(), outcome));
        }
        Ok(outcomes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::models::{build_cnn_graph, ConvSpec};
    use crate::tensor::Tensor;

    fn native_entry(name: &str) -> ModelEntry {
        let g = build_cnn_graph(
            name,
            [8, 8, 3],
            &[ConvSpec { cout: 4, k: 3, stride: 1 }],
            5,
            0,
        );
        ModelEntry::native(name, &g, LutOpts::all(), 8, 1).unwrap()
    }

    #[test]
    fn registry_resolve_and_alias() {
        let mut r = Registry::new();
        r.register(native_entry("m1"));
        r.alias("default", "m1");
        assert_eq!(r.resolve("m1").unwrap().name, "m1");
        assert_eq!(r.resolve("default").unwrap().name, "m1");
        assert!(r.resolve("missing").is_err());
        assert_eq!(r.names(), vec!["m1".to_string()]);
    }

    #[test]
    fn native_entry_runs_any_batch() {
        let e = native_entry("m");
        let mut out = Tensor::zeros(vec![0]);
        for n in [1usize, 3, 7] {
            let x = Tensor::zeros(vec![n, 8, 8, 3]);
            e.engine().run_batch(&x, &mut out).unwrap();
            assert_eq!(out.shape, vec![n, 5]);
        }
        assert_eq!(e.engine().max_batch(), None);
        assert_eq!(e.item_len(), 192);
    }

    #[test]
    fn native_entry_builds_replica_pools() {
        let g = build_cnn_graph(
            "mr",
            [8, 8, 3],
            &[ConvSpec { cout: 4, k: 3, stride: 1 }],
            5,
            0,
        );
        let e = ModelEntry::native("mr", &g, LutOpts::all(), 8, 3).unwrap();
        assert_eq!(e.pool.len(), 3);
        // replicas are numerically interchangeable
        let x = Tensor::new(vec![2, 8, 8, 3], vec![0.5; 2 * 192]);
        let mut a = Tensor::zeros(vec![0]);
        let mut b = Tensor::zeros(vec![0]);
        e.pool.replica(0).run_batch(&x, &mut a).unwrap();
        e.pool.replica(2).run_batch(&x, &mut b).unwrap();
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn registry_replicate_to_grows_native_pools() {
        let mut r = Registry::new();
        r.register(native_entry("grow"));
        assert_eq!(r.resolve("grow").unwrap().pool.len(), 1);
        // the resolve() Arc above is temporary, so get_mut succeeds
        r.replicate_to(4).unwrap();
        assert_eq!(r.resolve("grow").unwrap().pool.len(), 4);
    }

    fn saved_graph(name: &str) -> (crate::nn::graph::Graph, String) {
        let g = build_cnn_graph(name, [8, 8, 3], &[ConvSpec { cout: 4, k: 3, stride: 1 }], 5, 0);
        let dir = std::env::temp_dir().join("lutnn_coord_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("{name}.lutnn")).to_string_lossy().into_owned();
        crate::model_fmt::save_bundle(&g, &path).unwrap();
        (g, path)
    }

    #[test]
    fn lazy_registration_pages_models_in_on_first_resolve() {
        let (_, path) = saved_graph("cold1");
        let mut r = Registry::new();
        let name = r.register_lazy(&path, LutOpts::all(), 8, 1).unwrap();
        assert_eq!(name, "cold1");
        // visible before any paging, tables still on disk
        assert_eq!(r.cold_names(), vec!["cold1".to_string()]);
        assert!(r.names().contains(&"cold1".to_string()));

        let e = r.resolve("cold1").unwrap();
        assert!(r.cold_names().is_empty(), "first resolve must page the model in");
        let e2 = r.resolve("cold1").unwrap();
        assert!(Arc::ptr_eq(&e, &e2), "later resolves must reuse the warmed pool");

        let x = Tensor::zeros(vec![2, 8, 8, 3]);
        let mut out = Tensor::zeros(vec![0]);
        e.engine().run_batch(&x, &mut out).unwrap();
        assert_eq!(out.shape, vec![2, 5]);
    }

    #[test]
    fn lazy_resolve_matches_eager_registration_bitwise() {
        let (g, path) = saved_graph("cold_parity");
        let eager = ModelEntry::native("cold_parity", &g, LutOpts::all(), 8, 1).unwrap();
        let mut r = Registry::new();
        r.register_lazy(&path, LutOpts::all(), 8, 1).unwrap();
        let lazy = r.resolve("cold_parity").unwrap();

        let x = Tensor::new(vec![3, 8, 8, 3], vec![0.25; 3 * 192]);
        let mut a = Tensor::zeros(vec![0]);
        let mut b = Tensor::zeros(vec![0]);
        eager.engine().run_batch(&x, &mut a).unwrap();
        lazy.engine().run_batch(&x, &mut b).unwrap();
        assert_eq!(a.shape, b.shape);
        assert_eq!(a.data, b.data, "paged-in model must compute bitwise what the eager one does");
    }

    #[test]
    fn aliases_route_to_cold_models_and_errors_stay_typed() {
        let (_, path) = saved_graph("cold_alias");
        let mut r = Registry::new();
        r.register_lazy(&path, LutOpts::all(), 4, 1).unwrap();
        r.alias("default", "cold_alias");
        assert_eq!(r.resolve("default").unwrap().name, "cold_alias");
        assert!(r.resolve("still_missing").is_err());
        // registering garbage fails at registration time, not resolve time
        assert!(r.register_lazy("/nonexistent/zoo/m.lutnn", LutOpts::all(), 4, 1).is_err());
    }

    #[test]
    fn many_cold_models_register_cheaply_and_page_independently() {
        let mut r = Registry::new();
        let n = 24;
        for i in 0..n {
            let (_, path) = saved_graph(&format!("zoo{i:02}"));
            r.register_lazy(&path, LutOpts::all(), 4, 1).unwrap();
        }
        assert_eq!(r.names().len(), n);
        assert_eq!(r.cold_names().len(), n);
        // paging one in leaves the other n-1 cold
        r.resolve("zoo07").unwrap();
        assert_eq!(r.cold_names().len(), n - 1);
        assert!(r.names().len() == n, "warmed models stay listed");
    }

    #[test]
    fn warmed_resolve_does_not_take_the_cold_mutex() {
        let (_, path) = saved_graph("warm_nolock");
        let mut r = Registry::new();
        r.register_lazy(&path, LutOpts::all(), 4, 1).unwrap();
        r.resolve("warm_nolock").unwrap();
        let r = Arc::new(r);
        // Jam the cold mutex from this thread: a warmed resolve must
        // still complete (it used to serialize every caller on it).
        let cold_held = r.cold.lock().unwrap();
        let (tx, rx) = std::sync::mpsc::channel();
        let r2 = Arc::clone(&r);
        let resolver = std::thread::spawn(move || {
            let e = r2.resolve("warm_nolock").unwrap();
            tx.send(e.name.clone()).unwrap();
        });
        let name = rx
            .recv_timeout(std::time::Duration::from_secs(5))
            .expect("warmed resolve blocked on the cold mutex");
        assert_eq!(name, "warm_nolock");
        drop(cold_held);
        resolver.join().unwrap();
    }

    #[test]
    fn concurrent_first_resolves_page_in_exactly_once() {
        let (_, path) = saved_graph("race_once");
        let mut r = Registry::new();
        r.register_lazy(&path, LutOpts::all(), 4, 1).unwrap();
        let r = Arc::new(r);
        let barrier = Arc::new(std::sync::Barrier::new(8));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let (r, barrier) = (Arc::clone(&r), Arc::clone(&barrier));
                std::thread::spawn(move || {
                    barrier.wait();
                    r.resolve("race_once").unwrap()
                })
            })
            .collect();
        let entries: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for e in &entries[1..] {
            assert!(Arc::ptr_eq(&entries[0], e), "all racers must share one pool");
        }
        assert_eq!(r.residency().page_ins, 1, "the pool must build exactly once");
    }

    #[test]
    fn eviction_keeps_resident_bytes_within_budget_and_old_arcs_serve() {
        let (_, pa) = saved_graph("evict_a");
        let (_, pb) = saved_graph("evict_b");
        let mut r = Registry::new();
        r.register_lazy(&pa, LutOpts::all(), 4, 1).unwrap();
        r.register_lazy(&pb, LutOpts::all(), 4, 1).unwrap();
        let a = r.resolve("evict_a").unwrap();
        let bytes = r.residency().resident_bytes;
        assert!(bytes > 0, "a paged-in model must account its footprint");
        // budget fits exactly one of the (identically shaped) models
        r.set_resident_budget(Some(bytes as usize));

        let x = Tensor::new(vec![2, 8, 8, 3], vec![0.5; 2 * 192]);
        let mut before = Tensor::zeros(vec![0]);
        a.engine().run_batch(&x, &mut before).unwrap();

        let _b = r.resolve("evict_b").unwrap();
        let snap = r.residency();
        assert_eq!(snap.evictions, 1, "paging b in must evict a");
        assert_eq!(snap.page_ins, 2);
        assert!(snap.resident_bytes <= bytes, "budget exceeded: {snap:?}");
        assert_eq!(r.cold_names(), vec!["evict_a".to_string()]);

        // the in-flight Arc keeps serving, bitwise, after eviction
        let mut after = Tensor::zeros(vec![0]);
        a.engine().run_batch(&x, &mut after).unwrap();
        assert_eq!(before.data, after.data);

        // re-resolving rebuilds a from its retained spec (evicting b)
        let a2 = r.resolve("evict_a").unwrap();
        assert!(!Arc::ptr_eq(&a, &a2), "evicted model must rebuild, not alias the old Arc");
        let mut again = Tensor::zeros(vec![0]);
        a2.engine().run_batch(&x, &mut again).unwrap();
        assert_eq!(before.data, again.data, "re-paged model must match bitwise");
        let snap = r.residency();
        assert_eq!((snap.page_ins, snap.evictions), (3, 2));
    }

    #[test]
    fn names_report_each_model_exactly_once_across_the_lifecycle() {
        let (g, p1) = saved_graph("life_a");
        let (_, p2) = saved_graph("life_b");
        let mut r = Registry::new();
        r.register(ModelEntry::native("life_eager", &g, LutOpts::all(), 4, 1).unwrap());
        r.register_lazy(&p1, LutOpts::all(), 4, 1).unwrap();
        r.register_lazy(&p2, LutOpts::all(), 4, 1).unwrap();
        let all = vec!["life_a".to_string(), "life_b".to_string(), "life_eager".to_string()];
        assert_eq!(r.names(), all, "while pending");
        r.resolve("life_a").unwrap();
        assert_eq!(r.names(), all, "after promotion");
        let bytes = r.residency().resident_bytes as usize;
        r.set_resident_budget(Some(bytes));
        r.resolve("life_b").unwrap(); // evicts life_a
        assert_eq!(r.residency().evictions, 1);
        assert_eq!(r.names(), all, "after eviction");
        assert_eq!(r.cold_names(), vec!["life_a".to_string()]);
        r.resolve("life_a").unwrap(); // pages back in, evicting life_b
        assert_eq!(r.names(), all, "after re-promotion");
    }

    #[test]
    fn replicate_to_reports_per_model_outcomes_and_covers_warmed_entries() {
        let (g, path) = saved_graph("rep_warm");
        let mut r = Registry::new();
        // eager entry whose Arc is shared out -> SkippedShared
        r.register(ModelEntry::native("rep_shared", &g, LutOpts::all(), 4, 1).unwrap());
        let held = r.resolve("rep_shared").unwrap();
        // non-replicable engine -> Unsupported (pool stays at 1)
        let (_, stub) = pool::stubs::StubEngine::elastic().shared();
        r.register(ModelEntry::from_engine("rep_stub", stub, vec![8, 8, 3]));
        // warmed lazy entry (resolve Arc dropped) -> Grown
        r.register_lazy(&path, LutOpts::all(), 4, 1).unwrap();
        r.resolve("rep_warm").unwrap();
        let before_bytes = r.residency().resident_bytes;

        let outcomes = r.replicate_to(3).unwrap();
        let get = |name: &str| outcomes.iter().find(|(n, _)| n == name).unwrap().1;
        assert_eq!(get("rep_shared"), ReplicateOutcome::SkippedShared);
        assert_eq!(get("rep_stub"), ReplicateOutcome::Unsupported(1));
        assert_eq!(get("rep_warm"), ReplicateOutcome::Grown(3));
        assert_eq!(r.resolve("rep_warm").unwrap().pool.len(), 3, "warmed pools must grow");
        assert_eq!(held.pool.len(), 1, "shared entries stay untouched");
        assert!(
            r.residency().resident_bytes > before_bytes,
            "growing a warmed pool must move the resident gauge"
        );
    }

    #[test]
    fn eviction_while_request_in_flight_keeps_old_replica_serving() {
        let (_, path_old) = saved_graph("gate_old");
        let (_, path_new) = saved_graph("gate_new");
        let mut r = Registry::new();
        r.register_lazy(&path_new, LutOpts::all(), 4, 1).unwrap();

        // Hand-warm "gate_old" around a gated stub so the test controls
        // exactly when its in-flight batch finishes; the retained spec
        // is the real bundle, so the post-eviction rebuild is native.
        let bundle = crate::model_fmt::load_bundle_lazy(&path_old).unwrap();
        let (gate_tx, gate_rx) = std::sync::mpsc::channel();
        let (entered_tx, entered_rx) = std::sync::mpsc::channel();
        let (stub, engine) = pool::stubs::StubEngine::elastic()
            .with_entered(entered_tx)
            .with_gate(gate_rx)
            .shared();
        let bytes = 1024usize;
        r.warmed.get_mut().unwrap().insert(
            "gate_old".to_string(),
            WarmModel {
                entry: Arc::new(ModelEntry::from_engine("gate_old", engine, vec![4])),
                spec: ColdModel {
                    bundle,
                    opts: LutOpts::all(),
                    max_batch: 4,
                    replicas: 1,
                },
                bytes,
                last_used: AtomicU64::new(0),
            },
        );
        r.stats.resident_bytes.store(bytes as u64, Ordering::Relaxed);
        r.stats.resident_models.store(1, Ordering::Relaxed);
        r.set_resident_budget(Some(bytes));

        // A request is mid-flight on the warmed stub, parked in the gate.
        let inflight = r.resolve("gate_old").unwrap();
        let worker = std::thread::spawn(move || {
            let x = Tensor::new(vec![1, 4], vec![1.0, 2.0, 3.0, 4.0]);
            let mut out = Tensor::zeros(vec![0]);
            inflight.engine().run_batch(&x, &mut out).unwrap();
            out
        });
        entered_rx
            .recv_timeout(std::time::Duration::from_secs(5))
            .expect("in-flight request never reached the stub");

        // Paging "gate_new" in evicts "gate_old" mid-request.
        r.resolve("gate_new").unwrap();
        assert_eq!(r.residency().evictions, 1);
        assert!(r.cold_names().contains(&"gate_old".to_string()));

        // Release the gate: the evicted replica still answers correctly.
        gate_tx.send(()).unwrap();
        let out = worker.join().unwrap();
        assert_eq!(out.data, pool::stubs::StubEngine::expected_row(&[1.0, 2.0, 3.0, 4.0]));
        assert_eq!(stub.execs().len(), 1);

        // The next resolve rebuilds from the retained spec — a native
        // pool now (CNN shapes), exactly one more page-in.
        let rebuilt = r.resolve("gate_old").unwrap();
        let x = Tensor::zeros(vec![2, 8, 8, 3]);
        let mut out = Tensor::zeros(vec![0]);
        rebuilt.engine().run_batch(&x, &mut out).unwrap();
        assert_eq!(out.shape, vec![2, 5]);
        assert_eq!(r.residency().page_ins, 2, "hand-warmed entry never counted; rebuilds do");
    }

    #[test]
    fn eviction_respects_budget_over_random_resolve_sequences() {
        let mut r = Registry::new();
        let n = 6usize;
        for i in 0..n {
            let (_, path) = saved_graph(&format!("prop{i}"));
            r.register_lazy(&path, LutOpts::all(), 4, 1).unwrap();
        }
        // measure one model's footprint, then budget three of them
        r.resolve("prop0").unwrap();
        let bytes = r.residency().resident_bytes as usize;
        let budget = 3 * bytes;
        r.set_resident_budget(Some(budget));

        let seed = std::env::var("SERVE_STRESS_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        let mut rng = crate::util::prng::Prng::new(seed);
        let mut held: Vec<Arc<ModelEntry>> = Vec::new();
        for step in 0..60 {
            let e = r.resolve(&format!("prop{}", rng.below(n))).unwrap();
            let snap = r.residency();
            assert!(
                snap.resident_bytes <= budget as u64,
                "step {step} (seed {seed}): resident {} exceeds budget {budget}",
                snap.resident_bytes
            );
            // randomly hold or release Arcs: in-flight handles must
            // never block eviction or corrupt the gauge
            if rng.below(2) == 0 {
                held.push(e);
            } else {
                held.clear();
            }
        }
        let snap = r.residency();
        assert!(snap.evictions > 0, "a 6-model sweep under a 3-model budget must evict");
        assert_eq!(
            snap.resident_models as usize,
            r.names().len() - r.cold_names().len(),
            "gauge must agree with the maps"
        );
    }
}
