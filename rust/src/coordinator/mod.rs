//! L3 serving coordinator: model registry, router, dynamic batcher,
//! worker pool, metrics, workload traces and a TCP front-end.
//!
//! Request path (no python anywhere):
//!
//! ```text
//!   client ──TCP line-JSON──> Server ─┐
//!   in-proc callers (examples/benches)┼──> Router (Registry::resolve)
//!                                     │        │
//!                                     │        v
//!                                     │   Batcher queue (per model)
//!                                     │        │ drain + stack [B, item]
//!                                     │        v
//!                                     └── dyn api::Engine::run_batch
//!                                          │              │
//!                                   NativeEngine     PjrtEngine
//!                                   (Session, §5     (AOT XLA on the
//!                                    zero-alloc)      PJRT host thread)
//! ```
//!
//! The stack is backend-agnostic: a [`ModelEntry`] carries any
//! `Box<dyn Engine>` (see [`crate::api::engine`]), the batcher stacks
//! requests into one borrowed batch tensor and the engine writes into a
//! reusable output tensor — no per-request input clone on the native
//! path. New backends implement the three-method `Engine` trait and
//! register here; the batcher, server and router never change.

pub mod batcher;
pub mod metrics;
pub mod server;
pub mod trace;

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

pub use crate::api::engine::{Engine, NativeEngine, PjrtEngine};
use crate::lut::LutOpts;
use crate::nn::graph::Graph;

/// One registered model: a name, an executable engine, and the
/// per-request input shape the router validates against.
pub struct ModelEntry {
    pub name: String,
    pub engine: Box<dyn Engine>,
    /// per-request input shape (without batch dim)
    pub item_shape: Vec<usize>,
}

impl ModelEntry {
    /// Register a graph on the rust-native engine (compiled to a
    /// `Session` with arenas sized for `max_batch`).
    pub fn native(
        name: &str,
        graph: &Graph,
        opts: LutOpts,
        max_batch: usize,
    ) -> Result<ModelEntry> {
        let engine = NativeEngine::from_graph(graph, opts, max_batch)?;
        let item_shape = engine.item_shape();
        Ok(ModelEntry {
            name: name.to_string(),
            engine: Box::new(engine),
            item_shape,
        })
    }

    /// Register any engine implementation.
    pub fn from_engine(
        name: &str,
        engine: Box<dyn Engine>,
        item_shape: Vec<usize>,
    ) -> ModelEntry {
        ModelEntry { name: name.to_string(), engine, item_shape }
    }

    pub fn item_len(&self) -> usize {
        self.item_shape.iter().product()
    }
}

/// Name -> model registry with routing aliases.
#[derive(Default)]
pub struct Registry {
    models: BTreeMap<String, Arc<ModelEntry>>,
    aliases: BTreeMap<String, String>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn register(&mut self, entry: ModelEntry) {
        self.models.insert(entry.name.clone(), Arc::new(entry));
    }

    /// Route alias, e.g. "default" -> "resnet_tiny_lut".
    pub fn alias(&mut self, from: &str, to: &str) {
        self.aliases.insert(from.to_string(), to.to_string());
    }

    pub fn resolve(&self, name: &str) -> Result<Arc<ModelEntry>> {
        let target = self.aliases.get(name).map(|s| s.as_str()).unwrap_or(name);
        self.models
            .get(target)
            .cloned()
            .ok_or_else(|| anyhow!("unknown model '{name}'"))
    }

    pub fn names(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::models::{build_cnn_graph, ConvSpec};
    use crate::tensor::Tensor;

    fn native_entry(name: &str) -> ModelEntry {
        let g = build_cnn_graph(
            name,
            [8, 8, 3],
            &[ConvSpec { cout: 4, k: 3, stride: 1 }],
            5,
            0,
        );
        ModelEntry::native(name, &g, LutOpts::all(), 8).unwrap()
    }

    #[test]
    fn registry_resolve_and_alias() {
        let mut r = Registry::new();
        r.register(native_entry("m1"));
        r.alias("default", "m1");
        assert_eq!(r.resolve("m1").unwrap().name, "m1");
        assert_eq!(r.resolve("default").unwrap().name, "m1");
        assert!(r.resolve("missing").is_err());
        assert_eq!(r.names(), vec!["m1".to_string()]);
    }

    #[test]
    fn native_entry_runs_any_batch() {
        let e = native_entry("m");
        let mut out = Tensor::zeros(vec![0]);
        for n in [1usize, 3, 7] {
            let x = Tensor::zeros(vec![n, 8, 8, 3]);
            e.engine.run_batch(&x, &mut out).unwrap();
            assert_eq!(out.shape, vec![n, 5]);
        }
        assert_eq!(e.engine.max_batch(), None);
        assert_eq!(e.item_len(), 192);
    }
}
