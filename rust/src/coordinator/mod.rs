//! L3 serving coordinator: model registry, router, replica pools with a
//! work-stealing dynamic batcher, metrics, workload traces and a TCP
//! front-end.
//!
//! Request path (no python anywhere):
//!
//! ```text
//!   client ──TCP line-JSON──> Server ─┐
//!   in-proc callers (examples/benches)┼──> Router (Registry::resolve)
//!                                     │        │
//!                                     │        v
//!                                     │  shared injector queue (per model,
//!                                     │  bounded; try_submit sheds on full
//!                                     │  queue or exceeded deadline)
//!                                     │    │        │        │
//!                                     │    v        v        v
//!                                     │  worker0  worker1 … workerN-1
//!                                     │  (one per replica; idle workers
//!                                     │   steal from the shared queue,
//!                                     │   each batches up to its OWN
//!                                     │   replica's max_batch)
//!                                     │    │        │        │
//!                                     │    v        v        v
//!                                     └─ EnginePool: dyn api::Engine × N
//!                                         │                   │
//!                                   NativeEngine         PjrtEngine
//!                                   (Session per          (AOT XLA,
//!                                    replica — no          fixed batch,
//!                                    arena contention)     padded)
//! ```
//!
//! The stack is backend-agnostic: a [`ModelEntry`] carries an
//! [`pool::EnginePool`] of `Box<dyn Engine>` replicas (see
//! [`crate::api::engine`]). Each batcher worker stacks requests into
//! one borrowed batch tensor and runs its own replica — no per-request
//! input clone on the native path, no cross-replica lock contention.
//! New backends implement the `Engine` trait (plus `clone_replica` to
//! opt into homogeneous pooling) and register here; the batcher, server
//! and router never change.

pub mod batcher;
pub mod metrics;
pub mod pool;
pub mod server;
pub mod trace;

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

pub use crate::api::engine::{Engine, NativeEngine, PjrtEngine};
use crate::lut::LutOpts;
use crate::model_fmt::{self, LazyBundle};
use crate::nn::graph::Graph;
pub use pool::EnginePool;

/// One registered model: a name, a pool of engine replicas, and the
/// per-request input shape the router validates against.
pub struct ModelEntry {
    pub name: String,
    pub pool: EnginePool,
    /// per-request input shape (without batch dim)
    pub item_shape: Vec<usize>,
}

impl ModelEntry {
    /// Register a graph on the rust-native engine: `replicas` sessions
    /// compiled from one shared immutable bundle (each replica owns its
    /// scratch arenas; the graph is lutified/loaded exactly once), each
    /// with arenas sized for `max_batch`.
    pub fn native(
        name: &str,
        graph: &Graph,
        opts: LutOpts,
        max_batch: usize,
        replicas: usize,
    ) -> Result<ModelEntry> {
        let engine = NativeEngine::from_graph(graph, opts, max_batch)?;
        let item_shape = engine.item_shape();
        Ok(ModelEntry {
            name: name.to_string(),
            pool: EnginePool::replicate(Box::new(engine), replicas)?,
            item_shape,
        })
    }

    /// Register any single engine implementation (one-replica pool).
    pub fn from_engine(
        name: &str,
        engine: Box<dyn Engine>,
        item_shape: Vec<usize>,
    ) -> ModelEntry {
        ModelEntry {
            name: name.to_string(),
            pool: EnginePool::single(engine),
            item_shape,
        }
    }

    /// Register a heterogeneous replica pool (e.g. a fixed-batch
    /// `PjrtEngine` beside elastic `NativeEngine`s). The replicas must
    /// compute the same function; the batcher routes any request to any
    /// replica and batches against each replica's own `max_batch`.
    pub fn from_engines(
        name: &str,
        engines: Vec<Box<dyn Engine>>,
        item_shape: Vec<usize>,
    ) -> Result<ModelEntry> {
        Ok(ModelEntry {
            name: name.to_string(),
            pool: EnginePool::from_engines(engines)?,
            item_shape,
        })
    }

    /// The pool's primary replica, for direct (unbatched) execution.
    pub fn engine(&self) -> &dyn Engine {
        self.pool.primary()
    }

    pub fn item_len(&self) -> usize {
        self.item_shape.iter().product()
    }
}

/// A lazily registered model: a header-only [`LazyBundle`] plus the
/// pool parameters to apply when the first request pages it in.
struct ColdModel {
    bundle: LazyBundle,
    opts: LutOpts,
    max_batch: usize,
    replicas: usize,
}

#[derive(Default)]
struct ColdState {
    /// registered but never requested — only the bundle header is in memory
    pending: BTreeMap<String, ColdModel>,
    /// paged in on first request
    warmed: BTreeMap<String, Arc<ModelEntry>>,
}

/// Name -> model registry with routing aliases.
///
/// Models register either **eagerly** ([`Registry::register`], the
/// engine pool is built up front) or **cold** ([`Registry::register_lazy`],
/// only the bundle header is read — name and input shape — while the
/// table sections stay on disk). Cold models are paged in by the first
/// [`Registry::resolve`] that hits them; paging happens under a lock so
/// concurrent first requests build the pool exactly once, and the
/// warmed entry is indistinguishable from an eager registration after
/// that.
#[derive(Default)]
pub struct Registry {
    models: BTreeMap<String, Arc<ModelEntry>>,
    aliases: BTreeMap<String, String>,
    cold: Mutex<ColdState>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn register(&mut self, entry: ModelEntry) {
        self.models.insert(entry.name.clone(), Arc::new(entry));
    }

    /// Register a bundle cold under the model name its header declares.
    /// Costs one header read (~a few hundred bytes) regardless of table
    /// size, so a server can register a large zoo cheaply; the engine
    /// pool (`opts` / `max_batch` / `replicas`, as in
    /// [`ModelEntry::native`]) is built when the first request arrives.
    pub fn register_lazy(
        &mut self,
        path: &str,
        opts: LutOpts,
        max_batch: usize,
        replicas: usize,
    ) -> Result<String> {
        let bundle = model_fmt::load_bundle_lazy(path)?;
        let name = bundle.model_name().to_string();
        self.cold
            .get_mut()
            .expect("cold-model lock poisoned")
            .pending
            .insert(name.clone(), ColdModel { bundle, opts, max_batch, replicas });
        Ok(name)
    }

    /// Route alias, e.g. "default" -> "resnet_tiny_lut".
    pub fn alias(&mut self, from: &str, to: &str) {
        self.aliases.insert(from.to_string(), to.to_string());
    }

    pub fn resolve(&self, name: &str) -> Result<Arc<ModelEntry>> {
        let target = self.aliases.get(name).map(|s| s.as_str()).unwrap_or(name);
        if let Some(e) = self.models.get(target) {
            return Ok(e.clone());
        }
        // Cold path: page the model in on first request. Building under
        // the lock means concurrent first requests construct the pool
        // exactly once; later resolves hit `warmed` (or `models`) and
        // never wait on a build.
        let mut cold = self.cold.lock().expect("cold-model lock poisoned");
        if let Some(e) = cold.warmed.get(target) {
            return Ok(e.clone());
        }
        if let Some(spec) = cold.pending.get(target) {
            let graph = spec.bundle.graph()?;
            let entry = Arc::new(ModelEntry::native(
                target,
                &graph,
                spec.opts,
                spec.max_batch,
                spec.replicas,
            )?);
            // only drop the pending spec once the build succeeded, so a
            // transiently unreadable bundle stays resolvable
            cold.pending.remove(target);
            cold.warmed.insert(target.to_string(), entry.clone());
            return Ok(entry);
        }
        Err(anyhow!("unknown model '{name}'"))
    }

    pub fn names(&self) -> Vec<String> {
        let mut names: std::collections::BTreeSet<String> = self.models.keys().cloned().collect();
        let cold = self.cold.lock().expect("cold-model lock poisoned");
        names.extend(cold.pending.keys().cloned());
        names.extend(cold.warmed.keys().cloned());
        names.into_iter().collect()
    }

    /// Lazily registered models that have not been paged in yet.
    pub fn cold_names(&self) -> Vec<String> {
        self.cold
            .lock()
            .expect("cold-model lock poisoned")
            .pending
            .keys()
            .cloned()
            .collect()
    }

    /// Grow every model's pool to at least `n` replicas (best effort:
    /// engines without `clone_replica` — and entries whose `Arc` has
    /// already been shared out — keep their explicit pool size). Errors
    /// only when a supported clone actually fails.
    pub fn replicate_to(&mut self, n: usize) -> Result<()> {
        for entry in self.models.values_mut() {
            if let Some(e) = Arc::get_mut(entry) {
                e.pool.try_grow_to(n)?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::models::{build_cnn_graph, ConvSpec};
    use crate::tensor::Tensor;

    fn native_entry(name: &str) -> ModelEntry {
        let g = build_cnn_graph(
            name,
            [8, 8, 3],
            &[ConvSpec { cout: 4, k: 3, stride: 1 }],
            5,
            0,
        );
        ModelEntry::native(name, &g, LutOpts::all(), 8, 1).unwrap()
    }

    #[test]
    fn registry_resolve_and_alias() {
        let mut r = Registry::new();
        r.register(native_entry("m1"));
        r.alias("default", "m1");
        assert_eq!(r.resolve("m1").unwrap().name, "m1");
        assert_eq!(r.resolve("default").unwrap().name, "m1");
        assert!(r.resolve("missing").is_err());
        assert_eq!(r.names(), vec!["m1".to_string()]);
    }

    #[test]
    fn native_entry_runs_any_batch() {
        let e = native_entry("m");
        let mut out = Tensor::zeros(vec![0]);
        for n in [1usize, 3, 7] {
            let x = Tensor::zeros(vec![n, 8, 8, 3]);
            e.engine().run_batch(&x, &mut out).unwrap();
            assert_eq!(out.shape, vec![n, 5]);
        }
        assert_eq!(e.engine().max_batch(), None);
        assert_eq!(e.item_len(), 192);
    }

    #[test]
    fn native_entry_builds_replica_pools() {
        let g = build_cnn_graph(
            "mr",
            [8, 8, 3],
            &[ConvSpec { cout: 4, k: 3, stride: 1 }],
            5,
            0,
        );
        let e = ModelEntry::native("mr", &g, LutOpts::all(), 8, 3).unwrap();
        assert_eq!(e.pool.len(), 3);
        // replicas are numerically interchangeable
        let x = Tensor::new(vec![2, 8, 8, 3], vec![0.5; 2 * 192]);
        let mut a = Tensor::zeros(vec![0]);
        let mut b = Tensor::zeros(vec![0]);
        e.pool.replica(0).run_batch(&x, &mut a).unwrap();
        e.pool.replica(2).run_batch(&x, &mut b).unwrap();
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn registry_replicate_to_grows_native_pools() {
        let mut r = Registry::new();
        r.register(native_entry("grow"));
        assert_eq!(r.resolve("grow").unwrap().pool.len(), 1);
        // the resolve() Arc above is temporary, so get_mut succeeds
        r.replicate_to(4).unwrap();
        assert_eq!(r.resolve("grow").unwrap().pool.len(), 4);
    }

    fn saved_graph(name: &str) -> (crate::nn::graph::Graph, String) {
        let g = build_cnn_graph(name, [8, 8, 3], &[ConvSpec { cout: 4, k: 3, stride: 1 }], 5, 0);
        let dir = std::env::temp_dir().join("lutnn_coord_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("{name}.lutnn")).to_string_lossy().into_owned();
        crate::model_fmt::save_bundle(&g, &path).unwrap();
        (g, path)
    }

    #[test]
    fn lazy_registration_pages_models_in_on_first_resolve() {
        let (_, path) = saved_graph("cold1");
        let mut r = Registry::new();
        let name = r.register_lazy(&path, LutOpts::all(), 8, 1).unwrap();
        assert_eq!(name, "cold1");
        // visible before any paging, tables still on disk
        assert_eq!(r.cold_names(), vec!["cold1".to_string()]);
        assert!(r.names().contains(&"cold1".to_string()));

        let e = r.resolve("cold1").unwrap();
        assert!(r.cold_names().is_empty(), "first resolve must page the model in");
        let e2 = r.resolve("cold1").unwrap();
        assert!(Arc::ptr_eq(&e, &e2), "later resolves must reuse the warmed pool");

        let x = Tensor::zeros(vec![2, 8, 8, 3]);
        let mut out = Tensor::zeros(vec![0]);
        e.engine().run_batch(&x, &mut out).unwrap();
        assert_eq!(out.shape, vec![2, 5]);
    }

    #[test]
    fn lazy_resolve_matches_eager_registration_bitwise() {
        let (g, path) = saved_graph("cold_parity");
        let eager = ModelEntry::native("cold_parity", &g, LutOpts::all(), 8, 1).unwrap();
        let mut r = Registry::new();
        r.register_lazy(&path, LutOpts::all(), 8, 1).unwrap();
        let lazy = r.resolve("cold_parity").unwrap();

        let x = Tensor::new(vec![3, 8, 8, 3], vec![0.25; 3 * 192]);
        let mut a = Tensor::zeros(vec![0]);
        let mut b = Tensor::zeros(vec![0]);
        eager.engine().run_batch(&x, &mut a).unwrap();
        lazy.engine().run_batch(&x, &mut b).unwrap();
        assert_eq!(a.shape, b.shape);
        assert_eq!(a.data, b.data, "paged-in model must compute bitwise what the eager one does");
    }

    #[test]
    fn aliases_route_to_cold_models_and_errors_stay_typed() {
        let (_, path) = saved_graph("cold_alias");
        let mut r = Registry::new();
        r.register_lazy(&path, LutOpts::all(), 4, 1).unwrap();
        r.alias("default", "cold_alias");
        assert_eq!(r.resolve("default").unwrap().name, "cold_alias");
        assert!(r.resolve("still_missing").is_err());
        // registering garbage fails at registration time, not resolve time
        assert!(r.register_lazy("/nonexistent/zoo/m.lutnn", LutOpts::all(), 4, 1).is_err());
    }

    #[test]
    fn many_cold_models_register_cheaply_and_page_independently() {
        let mut r = Registry::new();
        let n = 24;
        for i in 0..n {
            let (_, path) = saved_graph(&format!("zoo{i:02}"));
            r.register_lazy(&path, LutOpts::all(), 4, 1).unwrap();
        }
        assert_eq!(r.names().len(), n);
        assert_eq!(r.cold_names().len(), n);
        // paging one in leaves the other n-1 cold
        r.resolve("zoo07").unwrap();
        assert_eq!(r.cold_names().len(), n - 1);
        assert!(r.names().len() == n, "warmed models stay listed");
    }
}
