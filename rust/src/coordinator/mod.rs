//! L3 serving coordinator: model registry, router, dynamic batcher,
//! worker pool, metrics, workload traces and a TCP front-end.
//!
//! Request path (no python anywhere):
//!   client -> server (TCP line-JSON) ----\
//!   in-proc callers (examples/benches) ---+--> Router -> Batcher queue
//!                                              -> worker: Backend::run
//!                                              -> per-request reply
//!
//! Backends: `Native` (the rust LUT/dense graph executor — the paper's
//! §5 engine) and `Pjrt` (AOT-compiled XLA graphs from the jax layer).

pub mod batcher;
pub mod metrics;
pub mod server;
pub mod trace;

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::lut::LutOpts;
use crate::nn::graph::Graph;
use crate::runtime::{HostInput, HostedModel};
use crate::tensor::Tensor;

/// An executable model variant.
pub enum Backend {
    /// rust-native graph executor (dense and/or LUT layers)
    Native { graph: Graph, opts: LutOpts },
    /// AOT-compiled XLA graph on the PJRT host thread (fixed batch size)
    Pjrt { model: HostedModel, batch: usize, is_tokens: bool },
}

impl Backend {
    /// Run a batch. `x.shape[0]` is the batch dim. Token inputs for BERT
    /// graphs are carried as f32 ids in the tensor (cast internally).
    pub fn run(&self, x: &Tensor) -> Result<Tensor> {
        match self {
            Backend::Native { graph, opts } => Ok(graph.run(x.clone(), *opts)),
            Backend::Pjrt { model, batch, is_tokens } => {
                anyhow::ensure!(
                    x.shape[0] == *batch,
                    "pjrt model compiled for batch {batch}, got {}",
                    x.shape[0]
                );
                let out = if *is_tokens {
                    let ids: Vec<i32> = x.data.iter().map(|&v| v as i32).collect();
                    model.run(HostInput::I32(ids, x.shape.clone()))?
                } else {
                    model.run(HostInput::F32(x.data.clone(), x.shape.clone()))?
                };
                let n = x.shape[0];
                let m = out.len() / n;
                Ok(Tensor::new(vec![n, m], out))
            }
        }
    }

    /// Max batch this backend accepts in one call (None = unbounded).
    pub fn max_batch(&self) -> Option<usize> {
        match self {
            Backend::Native { .. } => None,
            Backend::Pjrt { batch, .. } => Some(*batch),
        }
    }
}

/// One registered model.
pub struct ModelEntry {
    pub name: String,
    pub backend: Backend,
    /// per-request input shape (without batch dim)
    pub item_shape: Vec<usize>,
}

impl ModelEntry {
    pub fn item_len(&self) -> usize {
        self.item_shape.iter().product()
    }
}

/// Name -> model registry with routing aliases.
#[derive(Default)]
pub struct Registry {
    models: BTreeMap<String, Arc<ModelEntry>>,
    aliases: BTreeMap<String, String>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn register(&mut self, entry: ModelEntry) {
        self.models.insert(entry.name.clone(), Arc::new(entry));
    }

    /// Route alias, e.g. "default" -> "resnet_tiny_lut".
    pub fn alias(&mut self, from: &str, to: &str) {
        self.aliases.insert(from.to_string(), to.to_string());
    }

    pub fn resolve(&self, name: &str) -> Result<Arc<ModelEntry>> {
        let target = self.aliases.get(name).map(|s| s.as_str()).unwrap_or(name);
        self.models
            .get(target)
            .cloned()
            .ok_or_else(|| anyhow!("unknown model '{name}'"))
    }

    pub fn names(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::models::{build_cnn_graph, ConvSpec};

    fn native_entry(name: &str) -> ModelEntry {
        let g = build_cnn_graph(
            name,
            [8, 8, 3],
            &[ConvSpec { cout: 4, k: 3, stride: 1 }],
            5,
            0,
        );
        ModelEntry {
            name: name.into(),
            backend: Backend::Native { graph: g, opts: LutOpts::all() },
            item_shape: vec![8, 8, 3],
        }
    }

    #[test]
    fn registry_resolve_and_alias() {
        let mut r = Registry::new();
        r.register(native_entry("m1"));
        r.alias("default", "m1");
        assert_eq!(r.resolve("m1").unwrap().name, "m1");
        assert_eq!(r.resolve("default").unwrap().name, "m1");
        assert!(r.resolve("missing").is_err());
        assert_eq!(r.names(), vec!["m1".to_string()]);
    }

    #[test]
    fn native_backend_runs_any_batch() {
        let e = native_entry("m");
        for n in [1usize, 3, 7] {
            let x = Tensor::zeros(vec![n, 8, 8, 3]);
            let y = e.backend.run(&x).unwrap();
            assert_eq!(y.shape, vec![n, 5]);
        }
        assert_eq!(e.backend.max_batch(), None);
        assert_eq!(e.item_len(), 192);
    }
}
