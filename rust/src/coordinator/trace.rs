//! Workload trace generation: open-loop Poisson arrivals and closed-loop
//! concurrency, with seeded synthetic inputs — the request generators for
//! the serving benches and the end-to-end example.

use crate::util::prng::Prng;

/// One request in a trace.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// arrival time offset from trace start, seconds
    pub at_s: f64,
    /// request payload (flat input)
    pub input: Vec<f32>,
}

/// Open-loop Poisson arrival trace: `rate` requests/second for `n` events.
pub fn poisson_trace(rate: f64, n: usize, item_len: usize, seed: u64) -> Vec<TraceEvent> {
    let mut rng = Prng::new(seed);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            t += rng.exponential(rate);
            TraceEvent { at_s: t, input: rng.normal_vec(item_len, 1.0) }
        })
        .collect()
}

/// Uniform (constant-rate) trace.
pub fn uniform_trace(rate: f64, n: usize, item_len: usize, seed: u64) -> Vec<TraceEvent> {
    let mut rng = Prng::new(seed);
    (0..n)
        .map(|i| TraceEvent {
            at_s: i as f64 / rate,
            input: rng.normal_vec(item_len, 1.0),
        })
        .collect()
}

/// Burst trace: quiet baseline with periodic bursts (batching stressor).
pub fn bursty_trace(
    base_rate: f64,
    burst_rate: f64,
    period_s: f64,
    burst_frac: f64,
    n: usize,
    item_len: usize,
    seed: u64,
) -> Vec<TraceEvent> {
    let mut rng = Prng::new(seed);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            let phase = (t % period_s) / period_s;
            let rate = if phase < burst_frac { burst_rate } else { base_rate };
            t += rng.exponential(rate);
            TraceEvent { at_s: t, input: rng.normal_vec(item_len, 1.0) }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_is_right() {
        let tr = poisson_trace(100.0, 2000, 4, 0);
        let span = tr.last().unwrap().at_s;
        let rate = 2000.0 / span;
        assert!((rate - 100.0).abs() < 10.0, "rate={rate}");
        assert!(tr.windows(2).all(|w| w[0].at_s <= w[1].at_s));
    }

    #[test]
    fn uniform_spacing() {
        let tr = uniform_trace(10.0, 5, 2, 0);
        assert!((tr[1].at_s - tr[0].at_s - 0.1).abs() < 1e-9);
        assert_eq!(tr[0].input.len(), 2);
    }

    #[test]
    fn bursty_alternates_rates() {
        let tr = bursty_trace(10.0, 1000.0, 1.0, 0.2, 3000, 1, 0);
        // mean rate must sit strictly between base and burst
        let span = tr.last().unwrap().at_s;
        let rate = 3000.0 / span;
        assert!(rate > 10.0 && rate < 1000.0, "rate={rate}");
    }

    #[test]
    fn deterministic() {
        let a = poisson_trace(50.0, 10, 3, 7);
        let b = poisson_trace(50.0, 10, 3, 7);
        assert_eq!(a.len(), b.len());
        assert_eq!(a[5].input, b[5].input);
        assert_eq!(a[5].at_s, b[5].at_s);
    }
}
