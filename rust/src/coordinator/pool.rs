//! Engine replica pools: N executable replicas of one model.
//!
//! A [`EnginePool`] owns the replicas the batcher's worker threads
//! drain one-to-one: replica `i` is driven only by worker `i`, so each
//! replica's scratch arenas (the `Session` ping-pong buffers, im2col
//! patches, index slabs) are never contended — parallelism comes from
//! running *different batches on different replicas*, not from sharing
//! one session across threads.
//!
//! Pools are built two ways:
//! * [`EnginePool::replicate`] — homogeneous: one engine plus `n-1`
//!   copies stamped out through [`Engine::clone_replica`], sharing the
//!   immutable bundle (the graph is never re-lutified or re-loaded).
//! * [`EnginePool::from_engines`] — heterogeneous: explicit replicas,
//!   e.g. a fixed-batch [`crate::api::PjrtEngine`] beside elastic
//!   [`crate::api::NativeEngine`]s. Each batcher worker batches against
//!   its *own* replica's `max_batch`, so a fixed-batch replica never
//!   clamps the elastic ones.

use anyhow::{anyhow, ensure, Result};

use crate::api::Engine;

/// N engine replicas of one model (at least one).
pub struct EnginePool {
    replicas: Vec<Box<dyn Engine>>,
}

impl EnginePool {
    /// Single-replica pool (the pre-pool serving behavior).
    pub fn single(engine: Box<dyn Engine>) -> EnginePool {
        EnginePool { replicas: vec![engine] }
    }

    /// Heterogeneous pool from explicit replicas. Errors on an empty
    /// vector; callers are responsible for the replicas computing the
    /// same function (the batcher routes any request to any replica).
    pub fn from_engines(replicas: Vec<Box<dyn Engine>>) -> Result<EnginePool> {
        ensure!(!replicas.is_empty(), "engine pool needs at least one replica");
        Ok(EnginePool { replicas })
    }

    /// Homogeneous pool: `engine` plus `n - 1` replicas built through
    /// [`Engine::clone_replica`]. With `n == 1` no replication support
    /// is required.
    pub fn replicate(engine: Box<dyn Engine>, n: usize) -> Result<EnginePool> {
        ensure!(n >= 1, "engine pool needs at least one replica");
        let mut pool = EnginePool::single(engine);
        pool.try_grow_to(n)?;
        ensure!(
            pool.len() == n,
            "engine '{}' does not support replication (implement Engine::clone_replica)",
            pool.primary().describe()
        );
        Ok(pool)
    }

    /// Best-effort growth to `n` replicas by cloning the primary.
    /// Engines without [`Engine::clone_replica`] keep their current
    /// size (`Ok`, smaller pool); a failed clone is an error. Returns
    /// the resulting pool size.
    pub fn try_grow_to(&mut self, n: usize) -> Result<usize> {
        while self.replicas.len() < n {
            match self.primary().clone_replica() {
                None => break,
                Some(replica) => self
                    .replicas
                    .push(replica.map_err(|e| anyhow!("cloning replica: {e:#}"))?),
            }
        }
        Ok(self.replicas.len())
    }

    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    /// Replica `i` (panics out of range; workers are spawned 1:1).
    pub fn replica(&self, i: usize) -> &dyn Engine {
        self.replicas[i].as_ref()
    }

    /// The first replica — the reference engine for direct (unbatched)
    /// calls and for stamping out further replicas.
    pub fn primary(&self) -> &dyn Engine {
        self.replicas[0].as_ref()
    }

    /// Per-replica `max_batch` (the batcher clamps each worker to its
    /// own replica's capacity, not to the pool-wide minimum).
    pub fn max_batches(&self) -> Vec<Option<usize>> {
        self.replicas.iter().map(|r| r.max_batch()).collect()
    }

    /// Total resident bytes across all replicas (see
    /// [`Engine::resident_bytes`]; each replica owns its own arenas, so
    /// the sum is the pool's true footprint).
    pub fn resident_bytes(&self) -> usize {
        self.replicas.iter().map(|r| r.resident_bytes()).sum()
    }
}

/// Deterministic test engines for the serving stack (shared by the
/// batcher/server/pool unit tests): a per-row function whose output is
/// independent of batch composition, optional fixed batch (padding
/// contract), and optional entry-signal + gate channels so tests can
/// orchestrate *exactly* when a replica starts and finishes a batch.
#[cfg(test)]
pub(crate) mod stubs {
    use std::sync::mpsc::{Receiver, Sender};
    use std::sync::{Arc, Mutex};

    use anyhow::Result;

    use crate::api::Engine;
    use crate::tensor::Tensor;

    pub struct StubEngine {
        /// `Some(b)`: fixed-batch engine — `run_batch` insists on
        /// exactly `b` rows (the batcher must pad). `None`: elastic.
        fixed: Option<usize>,
        /// Signals every `run_batch` entry with the exec row count.
        entered: Mutex<Option<Sender<usize>>>,
        /// When present, `run_batch` blocks here until the test sends a
        /// release token (or drops the sender).
        gate: Option<Mutex<Receiver<()>>>,
        /// Row sums of every executed batch, in execution order.
        execs: Mutex<Vec<Vec<f32>>>,
    }

    impl StubEngine {
        pub fn elastic() -> StubEngine {
            StubEngine {
                fixed: None,
                entered: Mutex::new(None),
                gate: None,
                execs: Mutex::new(Vec::new()),
            }
        }

        pub fn fixed(batch: usize) -> StubEngine {
            StubEngine { fixed: Some(batch), ..StubEngine::elastic() }
        }

        pub fn with_entered(mut self, tx: Sender<usize>) -> StubEngine {
            self.entered = Mutex::new(Some(tx));
            self
        }

        pub fn with_gate(mut self, rx: Receiver<()>) -> StubEngine {
            self.gate = Some(Mutex::new(rx));
            self
        }

        /// Keep a handle for post-hoc inspection while handing the
        /// engine to a pool.
        pub fn shared(self) -> (Arc<StubEngine>, Box<dyn Engine>) {
            let arc = Arc::new(self);
            (Arc::clone(&arc), Box::new(SharedStub(arc)))
        }

        /// Row sums seen by each executed batch.
        pub fn execs(&self) -> Vec<Vec<f32>> {
            self.execs.lock().unwrap().clone()
        }

        /// The stub's per-row function: `[sum, 2*sum]` — depends only
        /// on the row itself, so outputs are byte-identical whatever
        /// batch (or padding) a request lands in.
        pub fn expected_row(input: &[f32]) -> Vec<f32> {
            let s: f32 = input.iter().sum();
            vec![s, s * 2.0]
        }
    }

    impl Engine for StubEngine {
        fn run_batch(&self, x: &Tensor, out: &mut Tensor) -> Result<()> {
            let n = x.shape[0];
            let item: usize = x.shape[1..].iter().product();
            if let Some(b) = self.fixed {
                anyhow::ensure!(n == b, "fixed stub needs batch {b}, got {n}");
            }
            if let Some(tx) = &*self.entered.lock().unwrap() {
                let _ = tx.send(n);
            }
            if let Some(gate) = &self.gate {
                // a dropped sender releases permanently (shutdown tests)
                let _ = gate.lock().unwrap().recv();
            }
            let sums: Vec<f32> = (0..n)
                .map(|i| x.data[i * item..(i + 1) * item].iter().sum())
                .collect();
            self.execs.lock().unwrap().push(sums.clone());
            out.shape.clear();
            out.shape.extend_from_slice(&[n, 2]);
            out.data.clear();
            for s in sums {
                out.data.push(s);
                out.data.push(s * 2.0);
            }
            Ok(())
        }

        fn max_batch(&self) -> Option<usize> {
            self.fixed
        }

        fn describe(&self) -> String {
            match self.fixed {
                Some(b) => format!("stub (fixed batch {b})"),
                None => "stub (elastic)".to_string(),
            }
        }
    }

    /// `Arc`-backed handle so tests can keep inspecting a stub that a
    /// pool owns.
    pub struct SharedStub(pub Arc<StubEngine>);

    impl Engine for SharedStub {
        fn run_batch(&self, x: &Tensor, out: &mut Tensor) -> Result<()> {
            self.0.run_batch(x, out)
        }

        fn max_batch(&self) -> Option<usize> {
            self.0.max_batch()
        }

        fn describe(&self) -> String {
            self.0.describe()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::NativeEngine;
    use crate::lut::LutOpts;
    use crate::nn::models::{build_cnn_graph, ConvSpec};
    use crate::tensor::Tensor;

    fn native() -> NativeEngine {
        let g = build_cnn_graph(
            "p",
            [8, 8, 3],
            &[ConvSpec { cout: 4, k: 3, stride: 1 }],
            5,
            0,
        );
        NativeEngine::from_graph(&g, LutOpts::all(), 4).unwrap()
    }

    #[test]
    fn replicate_builds_n_identical_replicas() {
        let pool = EnginePool::replicate(Box::new(native()), 3).unwrap();
        assert_eq!(pool.len(), 3);
        assert_eq!(pool.max_batches(), vec![None, None, None]);
        let x = Tensor::new(vec![2, 8, 8, 3], vec![0.25; 2 * 192]);
        let mut first = Tensor::zeros(vec![0]);
        pool.replica(0).run_batch(&x, &mut first).unwrap();
        for i in 1..pool.len() {
            let mut out = Tensor::zeros(vec![0]);
            pool.replica(i).run_batch(&x, &mut out).unwrap();
            assert_eq!(out.shape, first.shape);
            assert_eq!(out.data, first.data, "replica {i} must match bitwise");
        }
    }

    #[test]
    fn replicate_rejects_non_replicable_engines_beyond_one() {
        let (_, stub) = stubs::StubEngine::elastic().shared();
        assert!(EnginePool::replicate(stub, 2).is_err());
        // n == 1 needs no replication capability
        let (_, stub) = stubs::StubEngine::elastic().shared();
        assert_eq!(EnginePool::replicate(stub, 1).unwrap().len(), 1);
    }

    #[test]
    fn try_grow_is_best_effort() {
        let (_, stub) = stubs::StubEngine::elastic().shared();
        let mut pool = EnginePool::single(stub);
        assert_eq!(pool.try_grow_to(4).unwrap(), 1, "stub cannot replicate");
        let mut pool = EnginePool::single(Box::new(native()));
        assert_eq!(pool.try_grow_to(4).unwrap(), 4);
        assert_eq!(pool.try_grow_to(2).unwrap(), 4, "never shrinks");
    }

    #[test]
    fn from_engines_accepts_heterogeneous_rejects_empty() {
        assert!(EnginePool::from_engines(Vec::new()).is_err());
        let (_, fixed) = stubs::StubEngine::fixed(4).shared();
        let (_, elastic) = stubs::StubEngine::elastic().shared();
        let pool = EnginePool::from_engines(vec![fixed, elastic]).unwrap();
        assert_eq!(pool.max_batches(), vec![Some(4), None]);
        assert!(!pool.is_empty());
        assert!(pool.primary().describe().contains("fixed batch 4"));
    }
}
