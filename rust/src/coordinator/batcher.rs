//! Dynamic batcher: the serving core.
//!
//! Requests enter a bounded queue; a dedicated worker thread drains up to
//! `max_batch` items (waiting at most `max_wait` after the first), stacks
//! them into one reusable tensor, runs the model's `Engine` once (the
//! engine borrows the batch — no input clone), splits the outputs and
//! replies on per-request channels. Backpressure: `submit` blocks on
//! the bounded queue (closed-loop clients) while `try_submit` fails fast
//! (open-loop / SLO-shedding clients).

use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::metrics::Metrics;
use super::{Engine, ModelEntry};
use crate::tensor::Tensor;

pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_cap: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            queue_cap: 256,
        }
    }
}

struct Request {
    input: Vec<f32>,
    reply: SyncSender<Result<Vec<f32>>>,
    enqueued: Instant,
}

/// Handle to a running batcher (one per model).
pub struct Batcher {
    tx: SyncSender<Request>,
    pub metrics: Arc<Metrics>,
    item_len: usize,
    worker: Option<thread::JoinHandle<()>>,
}

impl Batcher {
    pub fn spawn(entry: Arc<ModelEntry>, cfg: BatcherConfig) -> Batcher {
        let (tx, rx) = mpsc::sync_channel::<Request>(cfg.queue_cap);
        let metrics = Arc::new(Metrics::new());
        let m2 = Arc::clone(&metrics);
        let item_len = entry.item_len();
        let worker = thread::Builder::new()
            .name(format!("batcher-{}", entry.name))
            .spawn(move || batch_loop(entry, cfg, rx, m2))
            .expect("spawn batcher");
        Batcher { tx, metrics, item_len, worker: Some(worker) }
    }

    /// Blocking submit (applies backpressure when the queue is full).
    pub fn submit(&self, input: Vec<f32>) -> Result<Vec<f32>> {
        anyhow::ensure!(
            input.len() == self.item_len,
            "input len {} != item len {}",
            input.len(),
            self.item_len
        );
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request { input, reply: reply_tx, enqueued: Instant::now() })
            .map_err(|_| anyhow!("batcher shut down"))?;
        reply_rx.recv().map_err(|_| anyhow!("batcher dropped request"))?
    }

    /// Non-blocking submit: sheds load when the queue is full.
    pub fn try_submit(&self, input: Vec<f32>) -> Result<Receiver<Result<Vec<f32>>>> {
        anyhow::ensure!(input.len() == self.item_len, "bad input len");
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        match self.tx.try_send(Request {
            input,
            reply: reply_tx,
            enqueued: Instant::now(),
        }) {
            Ok(()) => Ok(reply_rx),
            Err(TrySendError::Full(_)) => Err(anyhow!("queue full (shed)")),
            Err(TrySendError::Disconnected(_)) => Err(anyhow!("batcher shut down")),
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // Close the queue; worker drains and exits.
        let (dead_tx, _) = mpsc::sync_channel(1);
        let _ = std::mem::replace(&mut self.tx, dead_tx);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn batch_loop(
    entry: Arc<ModelEntry>,
    cfg: BatcherConfig,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
) {
    let item_len = entry.item_len();
    let hard_cap = entry.engine.max_batch().unwrap_or(cfg.max_batch).min(cfg.max_batch);
    // Reused across batches: the engine borrows `xbatch` and writes
    // into `out` — no per-request clone on the native path.
    let mut xbatch = Tensor::zeros(vec![0]);
    let mut out = Tensor::zeros(vec![0]);
    loop {
        // Block for the first request of the batch.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all senders dropped
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.max_wait;
        while batch.len() < hard_cap {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        metrics.record_batch(batch.len());
        metrics.queue_depth.store(batch.len() as u64, Ordering::Relaxed);

        // Stack into the reusable [B, item...] tensor; fixed-batch
        // engines (PJRT) need exactly `max_batch` rows, so pad with
        // zeros and drop padded outputs.
        let real = batch.len();
        let exec_rows = match entry.engine.max_batch() {
            Some(b) => b,
            None => real,
        };
        xbatch.data.clear();
        xbatch.data.resize(exec_rows * item_len, 0.0);
        for (i, r) in batch.iter().enumerate() {
            xbatch.data[i * item_len..(i + 1) * item_len].copy_from_slice(&r.input);
        }
        xbatch.shape.clear();
        xbatch.shape.push(exec_rows);
        xbatch.shape.extend_from_slice(&entry.item_shape);
        let result = entry.engine.run_batch(&xbatch, &mut out);

        match result {
            Ok(()) => {
                let m = out.len() / exec_rows;
                for (i, r) in batch.into_iter().enumerate() {
                    let slice = out.data[i * m..(i + 1) * m].to_vec();
                    metrics.record_request(r.enqueued.elapsed().as_secs_f64());
                    let _ = r.reply.send(Ok(slice));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for r in batch {
                    metrics.record_error();
                    let _ = r.reply.send(Err(anyhow!("{msg}")));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::LutOpts;
    use crate::nn::models::{build_cnn_graph, ConvSpec};

    fn entry() -> Arc<ModelEntry> {
        let g = build_cnn_graph(
            "b",
            [8, 8, 3],
            &[ConvSpec { cout: 4, k: 3, stride: 1 }],
            5,
            0,
        );
        Arc::new(ModelEntry::native("b", &g, LutOpts::all(), 8).unwrap())
    }

    #[test]
    fn single_request_roundtrip() {
        let b = Batcher::spawn(entry(), BatcherConfig::default());
        let out = b.submit(vec![0.5; 192]).unwrap();
        assert_eq!(out.len(), 5);
        assert_eq!(b.metrics.snapshot().requests, 1);
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let b = Arc::new(Batcher::spawn(
            entry(),
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(20),
                queue_cap: 64,
            },
        ));
        let mut handles = Vec::new();
        for i in 0..16 {
            let b = Arc::clone(&b);
            handles.push(thread::spawn(move || {
                b.submit(vec![i as f32 * 0.01; 192]).unwrap()
            }));
        }
        for h in handles {
            let out = h.join().unwrap();
            assert_eq!(out.len(), 5);
        }
        let snap = b.metrics.snapshot();
        assert_eq!(snap.requests, 16);
        // with a 20ms window on a single model, far fewer batches than reqs
        assert!(snap.batches < 16, "batches={}", snap.batches);
    }

    #[test]
    fn rejects_bad_input_len() {
        let b = Batcher::spawn(entry(), BatcherConfig::default());
        assert!(b.submit(vec![0.0; 7]).is_err());
    }

    #[test]
    fn try_submit_sheds_when_full() {
        // queue_cap 1 and a worker kept busy by slow first request is racy
        // to orchestrate; instead just verify try_submit works when idle.
        let b = Batcher::spawn(entry(), BatcherConfig::default());
        let rx = b.try_submit(vec![0.0; 192]).unwrap();
        let out = rx.recv().unwrap().unwrap();
        assert_eq!(out.len(), 5);
    }
}
