//! Dynamic batcher: the serving core — now a replica-pool scheduler.
//!
//! Requests enter one bounded injector queue per model
//! ([`crate::util::threadpool::WorkQueue`]); one worker thread per
//! engine replica drains it. Work distribution is stealing by
//! construction: no request is pinned to a replica, so any idle worker
//! picks up whatever is queued while its siblings are busy. Each
//! worker forms batches against *its own* replica's `max_batch` (a
//! fixed-batch PJRT replica pads to its compiled size; an elastic
//! native replica beside it batches as large as the config allows —
//! no pool-wide clamp to the most restrictive engine), waiting at most
//! `max_wait` after the first request, stacks them into one reusable
//! tensor, runs the replica once (the engine borrows the batch), splits
//! the outputs and replies on per-request channels.
//!
//! Backpressure and shedding: [`Batcher::submit`] blocks on the bounded
//! queue (closed-loop clients); [`Batcher::try_submit`] fails fast when
//! the queue is full, and [`Batcher::try_submit_deadline`] additionally
//! sheds at *dequeue* time if the request aged past its deadline while
//! queued — SLO clients get a fast error instead of a stale result.
//!
//! Shutdown is graceful: dropping the batcher closes the queue (new
//! submits fail), then the workers drain and answer every request
//! already accepted before exiting — no reply channel is ever dropped
//! mid-flight.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{self, Receiver, SyncSender};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::metrics::Metrics;
use super::{Engine, ModelEntry};
use crate::obs::{SpanConfig, SpanOutcome, SpanRecord, SpanRing};
use crate::tensor::Tensor;
use crate::util::threadpool::{PushError, WorkQueue};

#[derive(Clone)]
pub struct BatcherConfig {
    /// Upper batch bound per worker (each worker additionally clamps to
    /// its own replica's `max_batch`).
    pub max_batch: usize,
    /// How long a worker waits for follow-up requests after the first.
    pub max_wait: Duration,
    /// Injector queue capacity (`submit` blocks beyond it, `try_submit`
    /// sheds).
    pub queue_cap: usize,
    /// Stage-span recording: `Some` attaches a [`SpanRing`] to the
    /// batcher and every request's queue → batch-form → execute → reply
    /// timeline is offered to it, tagged with the executing replica and
    /// the real (unpadded) batch size. `None` (the default) takes no
    /// timestamps beyond the existing metrics.
    pub spans: Option<SpanConfig>,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            queue_cap: 256,
            spans: None,
        }
    }
}

struct Request {
    input: Vec<f32>,
    reply: SyncSender<Result<Vec<f32>>>,
    enqueued: Instant,
    /// Queue-age SLO: shed (reply with an error) if the request waited
    /// longer than this before a worker picked it up.
    deadline: Option<Duration>,
}

/// Handle to a running batcher (one per model, one worker per replica).
pub struct Batcher {
    queue: Arc<WorkQueue<Request>>,
    pub metrics: Arc<Metrics>,
    spans: Option<Arc<SpanRing>>,
    item_len: usize,
    workers: Vec<thread::JoinHandle<()>>,
}

impl Batcher {
    pub fn spawn(entry: Arc<ModelEntry>, cfg: BatcherConfig) -> Batcher {
        let queue = Arc::new(WorkQueue::bounded(cfg.queue_cap));
        let metrics = Arc::new(Metrics::new());
        let spans = cfg.spans.map(|c| Arc::new(SpanRing::new(c)));
        let item_len = entry.item_len();
        let workers = (0..entry.pool.len())
            .map(|i| {
                let entry2 = Arc::clone(&entry);
                let queue2 = Arc::clone(&queue);
                let metrics2 = Arc::clone(&metrics);
                let spans2 = spans.clone();
                let (max_batch, max_wait) = (cfg.max_batch, cfg.max_wait);
                thread::Builder::new()
                    .name(format!("batcher-{}-{i}", entry.name))
                    .spawn(move || {
                        worker_loop(entry2, i, max_batch, max_wait, queue2, metrics2, spans2)
                    })
                    .expect("spawn batcher worker")
            })
            .collect();
        Batcher { queue, metrics, spans, item_len, workers }
    }

    /// The stage-span ring, when the config enabled span recording.
    pub fn spans(&self) -> Option<&Arc<SpanRing>> {
        self.spans.as_ref()
    }

    /// Blocking submit (applies backpressure when the queue is full).
    pub fn submit(&self, input: Vec<f32>) -> Result<Vec<f32>> {
        anyhow::ensure!(
            input.len() == self.item_len,
            "input len {} != item len {}",
            input.len(),
            self.item_len
        );
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        self.queue
            .push(Request {
                input,
                reply: reply_tx,
                enqueued: Instant::now(),
                deadline: None,
            })
            .map_err(|_| anyhow!("batcher shut down"))?;
        reply_rx.recv().map_err(|_| anyhow!("batcher dropped request"))?
    }

    /// Metrics snapshot with the pending-depth gauge sampled live from
    /// the injector queue (always exact — there is no hand-maintained
    /// counter to drift or to overcount blocked `submit` callers).
    pub fn snapshot(&self) -> super::metrics::MetricsSnapshot {
        self.metrics.queue_depth.store(self.queue.len() as u64, Ordering::Relaxed);
        self.metrics.snapshot()
    }

    /// Non-blocking submit: sheds load when the queue is full.
    pub fn try_submit(&self, input: Vec<f32>) -> Result<Receiver<Result<Vec<f32>>>> {
        self.try_submit_opt(input, None)
    }

    /// Non-blocking submit with a queue-age SLO: sheds when the queue
    /// is full, *and* sheds at dequeue time (the reply channel yields
    /// an error) if the request waited longer than `deadline` before
    /// any replica picked it up.
    pub fn try_submit_deadline(
        &self,
        input: Vec<f32>,
        deadline: Duration,
    ) -> Result<Receiver<Result<Vec<f32>>>> {
        self.try_submit_opt(input, Some(deadline))
    }

    fn try_submit_opt(
        &self,
        input: Vec<f32>,
        deadline: Option<Duration>,
    ) -> Result<Receiver<Result<Vec<f32>>>> {
        anyhow::ensure!(input.len() == self.item_len, "bad input len");
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        match self.queue.try_push(Request {
            input,
            reply: reply_tx,
            enqueued: Instant::now(),
            deadline,
        }) {
            Ok(()) => Ok(reply_rx),
            Err(PushError::Full(_)) => {
                self.metrics.record_shed();
                if let Some(ring) = &self.spans {
                    ring.record(SpanRecord::unexecuted(SpanOutcome::ShedQueueFull));
                }
                Err(anyhow!("queue full (shed)"))
            }
            Err(PushError::Closed(_)) => Err(anyhow!("batcher shut down")),
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // Close the queue: submits fail from here on, the workers drain
        // every already-accepted request (replying to each) and exit.
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Admit `r` into `batch` unless its queue-age deadline already passed
/// (SLO shedding at dequeue: the client gets a prompt error instead of
/// a stale result). Returns whether the request was admitted.
fn admit(
    r: Request,
    metrics: &Metrics,
    spans: Option<&SpanRing>,
    batch: &mut Vec<Request>,
) -> bool {
    if let Some(d) = r.deadline {
        let waited = r.enqueued.elapsed();
        if waited > d {
            metrics.record_shed();
            if let Some(ring) = spans {
                let mut s = SpanRecord::unexecuted(SpanOutcome::ShedDeadline);
                s.queue_us = waited.as_micros() as u64;
                ring.record(s);
            }
            let _ = r
                .reply
                .send(Err(anyhow!("deadline exceeded after {waited:?} in queue (shed)")));
            return false;
        }
    }
    batch.push(r);
    true
}

/// An executed request's span from its worker-side timeline (`reply_us`
/// is measured at call time, so build the span right after replying).
fn stage_span(
    enqueued: Instant,
    popped: Instant,
    exec_start: Instant,
    exec_end: Instant,
    replica: usize,
    batch_size: usize,
    outcome: SpanOutcome,
) -> SpanRecord {
    SpanRecord {
        seq: 0,
        queue_us: popped.duration_since(enqueued).as_micros() as u64,
        batch_form_us: exec_start.duration_since(popped).as_micros() as u64,
        execute_us: exec_end.duration_since(exec_start).as_micros() as u64,
        reply_us: exec_end.elapsed().as_micros() as u64,
        replica: replica as i64,
        batch_size: batch_size as u64,
        outcome,
    }
}

fn worker_loop(
    entry: Arc<ModelEntry>,
    replica: usize,
    max_batch: usize,
    max_wait: Duration,
    queue: Arc<WorkQueue<Request>>,
    metrics: Arc<Metrics>,
    spans: Option<Arc<SpanRing>>,
) {
    let spans = spans.as_deref();
    let engine = entry.pool.replica(replica);
    let item_len = entry.item_len();
    // Per-replica clamp: this worker batches against its OWN replica's
    // capacity, so one fixed-batch replica never constrains the rest of
    // the pool.
    let max_batch = max_batch.max(1);
    let hard_cap = engine.max_batch().unwrap_or(max_batch).min(max_batch);
    // Reused across batches: the engine borrows `xbatch` and writes
    // into `out` — no per-request clone on the native path.
    let mut xbatch = Tensor::zeros(vec![0]);
    let mut out = Tensor::zeros(vec![0]);
    let mut batch: Vec<Request> = Vec::with_capacity(hard_cap);
    // Pop timestamp per admitted request (parallel to `batch`); only
    // filled when spans are on.
    let mut popped: Vec<Instant> = Vec::with_capacity(hard_cap);
    loop {
        // Block for the first request of this worker's next batch. All
        // workers pop from the one shared queue, so an idle replica
        // steals work a busy sibling cannot take. `None` = the batcher
        // closed and the backlog is fully drained.
        let Some(first) = queue.pop() else { return };
        batch.clear();
        popped.clear();
        let t_pop = spans.map(|_| Instant::now());
        if !admit(first, &metrics, spans, &mut batch) {
            continue; // expired in the queue; no batch window started
        }
        if let Some(t) = t_pop {
            popped.push(t);
        }
        let window = Instant::now() + max_wait;
        while batch.len() < hard_cap {
            match queue.pop_until(window) {
                Some(r) => {
                    let t_pop = spans.map(|_| Instant::now());
                    let admitted = admit(r, &metrics, spans, &mut batch);
                    if let (true, Some(t)) = (admitted, t_pop) {
                        popped.push(t);
                    }
                }
                None => break, // window elapsed (or closed + drained)
            }
        }
        metrics.record_batch(batch.len());
        metrics.replicas_busy.fetch_add(1, Ordering::Relaxed);

        // Stack into the reusable [B, item...] tensor; fixed-batch
        // engines (PJRT) need exactly `max_batch` rows, so pad with
        // zeros and drop padded outputs.
        let real = batch.len();
        let exec_rows = match engine.max_batch() {
            Some(b) => b,
            None => real,
        };
        xbatch.data.clear();
        xbatch.data.resize(exec_rows * item_len, 0.0);
        for (i, r) in batch.iter().enumerate() {
            xbatch.data[i * item_len..(i + 1) * item_len].copy_from_slice(&r.input);
        }
        xbatch.shape.clear();
        xbatch.shape.push(exec_rows);
        xbatch.shape.extend_from_slice(&entry.item_shape);
        let exec_start = spans.map(|_| Instant::now());
        let result = engine.run_batch(&xbatch, &mut out);
        let exec_end = spans.map(|_| Instant::now());
        metrics.replicas_busy.fetch_sub(1, Ordering::Relaxed);

        match result {
            Ok(()) => {
                let m = out.len() / exec_rows;
                for (i, r) in batch.drain(..).enumerate() {
                    let slice = out.data[i * m..(i + 1) * m].to_vec();
                    metrics.record_request(r.enqueued.elapsed().as_secs_f64());
                    let _ = r.reply.send(Ok(slice));
                    if let Some(ring) = spans {
                        ring.record(stage_span(
                            r.enqueued,
                            popped[i],
                            exec_start.expect("spans on"),
                            exec_end.expect("spans on"),
                            replica,
                            real,
                            SpanOutcome::Ok,
                        ));
                    }
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for (i, r) in batch.drain(..).enumerate() {
                    metrics.record_error();
                    let _ = r.reply.send(Err(anyhow!("{msg}")));
                    if let Some(ring) = spans {
                        ring.record(stage_span(
                            r.enqueued,
                            popped[i],
                            exec_start.expect("spans on"),
                            exec_end.expect("spans on"),
                            replica,
                            real,
                            SpanOutcome::Error,
                        ));
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pool::stubs::StubEngine;
    use crate::lut::LutOpts;
    use crate::nn::models::{build_cnn_graph, ConvSpec};
    use crate::util::prng::Prng;

    fn entry() -> Arc<ModelEntry> {
        entry_with_replicas(1)
    }

    fn entry_with_replicas(replicas: usize) -> Arc<ModelEntry> {
        let g = build_cnn_graph(
            "b",
            [8, 8, 3],
            &[ConvSpec { cout: 4, k: 3, stride: 1 }],
            5,
            0,
        );
        Arc::new(ModelEntry::native("b", &g, LutOpts::all(), 8, replicas).unwrap())
    }

    #[test]
    fn single_request_roundtrip() {
        let b = Batcher::spawn(entry(), BatcherConfig::default());
        let out = b.submit(vec![0.5; 192]).unwrap();
        assert_eq!(out.len(), 5);
        assert_eq!(b.metrics.snapshot().requests, 1);
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let b = Arc::new(Batcher::spawn(
            entry(),
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(20),
                queue_cap: 64,
                spans: None,
            },
        ));
        let mut handles = Vec::new();
        for i in 0..16 {
            let b = Arc::clone(&b);
            handles.push(thread::spawn(move || {
                b.submit(vec![i as f32 * 0.01; 192]).unwrap()
            }));
        }
        for h in handles {
            let out = h.join().unwrap();
            assert_eq!(out.len(), 5);
        }
        let snap = b.metrics.snapshot();
        assert_eq!(snap.requests, 16);
        // with a 20ms window on a single replica, far fewer batches than reqs
        assert!(snap.batches < 16, "batches={}", snap.batches);
    }

    #[test]
    fn rejects_bad_input_len() {
        let b = Batcher::spawn(entry(), BatcherConfig::default());
        assert!(b.submit(vec![0.0; 7]).is_err());
        assert!(b.try_submit(vec![0.0; 7]).is_err());
    }

    /// Deterministic shedding, both kinds: the single replica is gated
    /// inside `run_batch`, so the test controls exactly what is queued
    /// when. A full queue sheds at submit; an aged-out deadline request
    /// sheds at dequeue with an error reply.
    #[test]
    fn shed_on_queue_full_and_on_deadline() {
        let (entered_tx, entered_rx) = mpsc::channel();
        let (gate_tx, gate_rx) = mpsc::channel();
        let (stub, engine) =
            StubEngine::elastic().with_entered(entered_tx).with_gate(gate_rx).shared();
        let entry =
            Arc::new(ModelEntry::from_engine("shed", engine, vec![4]));
        let b = Batcher::spawn(
            entry,
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                queue_cap: 3,
                spans: None,
            },
        );
        // A is picked up by the worker, which then blocks in the gate.
        let rx_a = b.try_submit(vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        entered_rx.recv().unwrap();
        assert_eq!(b.snapshot().replicas_busy, 1);

        // Fill the queue behind the blocked worker: B, E (1ns deadline,
        // will age out long before the gate opens), C.
        let rx_b = b.try_submit(vec![1.0; 4]).unwrap();
        let rx_e = b
            .try_submit_deadline(vec![2.0; 4], Duration::from_nanos(1))
            .unwrap();
        let rx_c = b.try_submit(vec![3.0; 4]).unwrap();
        assert_eq!(b.snapshot().queue_depth, 3, "true pending depth, not batch size");

        // Queue full -> capacity shed at submit time.
        let err = b.try_submit(vec![4.0; 4]).unwrap_err();
        assert!(format!("{err}").contains("queue full"), "{err}");

        // Open the gate for good; the worker finishes A, then drains
        // B/E/C — admitting B and C, shedding E on queue age.
        drop(gate_tx);
        assert_eq!(rx_a.recv().unwrap().unwrap(), StubEngine::expected_row(&[1.0, 2.0, 3.0, 4.0]));
        assert_eq!(rx_b.recv().unwrap().unwrap(), StubEngine::expected_row(&[1.0; 4]));
        assert_eq!(rx_c.recv().unwrap().unwrap(), StubEngine::expected_row(&[3.0; 4]));
        let shed = rx_e.recv().unwrap().unwrap_err();
        assert!(format!("{shed}").contains("deadline exceeded"), "{shed}");

        let snap = b.snapshot();
        assert_eq!(snap.requests, 3, "A, B, C served");
        assert_eq!(snap.shed, 2, "one capacity shed + one deadline shed");
        assert_eq!(snap.errors, 0);
        assert_eq!(snap.queue_depth, 0);
        assert_eq!(snap.replicas_busy, 0);
        assert!(stub.execs().iter().all(|e| !e.is_empty()));
    }

    /// Graceful shutdown: dropping the batcher while requests are
    /// queued behind a blocked replica must still answer every one of
    /// them (the close drains; no reply channel is dropped mid-batch).
    #[test]
    fn drop_drains_queued_requests_and_replies() {
        let (entered_tx, entered_rx) = mpsc::channel();
        let (gate_tx, gate_rx) = mpsc::channel();
        let (_stub, engine) =
            StubEngine::elastic().with_entered(entered_tx).with_gate(gate_rx).shared();
        let entry = Arc::new(ModelEntry::from_engine("drain", engine, vec![2]));
        let b = Batcher::spawn(
            entry,
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                queue_cap: 8,
                spans: None,
            },
        );
        let rx_a = b.try_submit(vec![1.0, 1.0]).unwrap();
        entered_rx.recv().unwrap(); // worker holds A inside the gate
        let rx_b = b.try_submit(vec![2.0, 2.0]).unwrap();
        let rx_c = b.try_submit(vec![3.0, 3.0]).unwrap();

        // Drop with the engine still blocked: Drop closes the queue and
        // joins the worker, which must first drain B and C.
        let dropper = thread::spawn(move || drop(b));
        drop(gate_tx); // release the engine
        assert_eq!(rx_a.recv().unwrap().unwrap(), StubEngine::expected_row(&[1.0, 1.0]));
        assert_eq!(rx_b.recv().unwrap().unwrap(), StubEngine::expected_row(&[2.0, 2.0]));
        assert_eq!(rx_c.recv().unwrap().unwrap(), StubEngine::expected_row(&[3.0, 3.0]));
        dropper.join().unwrap();
    }

    /// Heterogeneous pool: a fixed-batch replica pads to its compiled
    /// size while the elastic replica beside it runs unpadded, and both
    /// produce outputs byte-identical to the single-engine path. Also
    /// the deterministic work-stealing witness: the second request is
    /// necessarily taken by the idle worker while the first worker is
    /// blocked inside its engine.
    #[test]
    fn heterogeneous_pool_pads_fixed_replica_only_and_matches_single_engine() {
        let (entered_tx, entered_rx) = mpsc::channel();
        let (gate_fixed_tx, gate_fixed_rx) = mpsc::channel();
        let (gate_elastic_tx, gate_elastic_rx) = mpsc::channel();
        let (fixed, fixed_engine) = StubEngine::fixed(4)
            .with_entered(entered_tx.clone())
            .with_gate(gate_fixed_rx)
            .shared();
        let (elastic, elastic_engine) = StubEngine::elastic()
            .with_entered(entered_tx)
            .with_gate(gate_elastic_rx)
            .shared();
        let entry = Arc::new(
            ModelEntry::from_engines("hetero", vec![fixed_engine, elastic_engine], vec![4])
                .unwrap(),
        );
        assert_eq!(entry.pool.max_batches(), vec![Some(4), None]);
        let b = Batcher::spawn(
            entry,
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                queue_cap: 16,
                spans: None,
            },
        );
        let in_a = vec![1.0, 2.0, 3.0, 4.0];
        let in_b = vec![5.0, 6.0, 7.0, 8.0];
        let rx_a = b.try_submit(in_a.clone()).unwrap();
        entered_rx.recv().unwrap(); // one worker committed to A, gated
        // The other worker is the only idle one: it must steal B.
        let rx_b = b.try_submit(in_b.clone()).unwrap();
        entered_rx.recv().unwrap();
        drop(gate_fixed_tx);
        drop(gate_elastic_tx);
        let out_a = rx_a.recv().unwrap().unwrap();
        let out_b = rx_b.recv().unwrap().unwrap();
        assert_eq!(out_a, StubEngine::expected_row(&in_a));
        assert_eq!(out_b, StubEngine::expected_row(&in_b));

        // Each replica executed exactly one single-request batch.
        let fixed_execs = fixed.execs();
        let elastic_execs = elastic.execs();
        assert_eq!(fixed_execs.len() + elastic_execs.len(), 2);
        for e in &fixed_execs {
            assert_eq!(e.len(), 4, "fixed replica always runs padded to 4 rows");
            assert!(e[1..].iter().all(|&s| s == 0.0), "padding rows are zeros: {e:?}");
        }
        for e in &elastic_execs {
            assert_eq!(e.len(), 1, "elastic replica runs the real batch unpadded");
        }

        // Byte-identical to the single-engine path on the same inputs.
        let (_ref_stub, ref_engine) = StubEngine::elastic().shared();
        let single = Batcher::spawn(
            Arc::new(ModelEntry::from_engine("single", ref_engine, vec![4])),
            BatcherConfig::default(),
        );
        assert_eq!(single.submit(in_a).unwrap(), out_a);
        assert_eq!(single.submit(in_b).unwrap(), out_b);
    }

    /// A replicated native pool must return bytes identical to the
    /// single-engine reference for the same request set, whatever
    /// batches the four workers happened to form (per-item outputs are
    /// batch-composition independent on the native path).
    #[test]
    fn replicated_native_pool_is_bitwise_equal_to_single_engine() {
        let g = build_cnn_graph(
            "bw",
            [8, 8, 3],
            &[ConvSpec { cout: 4, k: 3, stride: 1 }],
            5,
            3,
        );
        let reference = ModelEntry::native("ref", &g, LutOpts::all(), 8, 1).unwrap();
        let pool = Arc::new(ModelEntry::native("pool", &g, LutOpts::all(), 8, 4).unwrap());
        let b = Arc::new(Batcher::spawn(
            pool,
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                queue_cap: 64,
                spans: None,
            },
        ));
        let mut rng = Prng::new(17);
        let inputs: Vec<Vec<f32>> = (0..16).map(|_| rng.normal_vec(192, 1.0)).collect();
        let mut handles = Vec::new();
        for input in &inputs {
            let b = Arc::clone(&b);
            let input = input.clone();
            handles.push(thread::spawn(move || b.submit(input).unwrap()));
        }
        let got: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let mut out = Tensor::zeros(vec![0]);
        for (input, got) in inputs.iter().zip(&got) {
            let x = Tensor::new(vec![1, 8, 8, 3], input.clone());
            reference.engine().run_batch(&x, &mut out).unwrap();
            assert_eq!(&out.data, got, "pool output must match single-engine bitwise");
        }
    }

    /// Seeded threaded stress over a 4-replica stub pool (the CI serving
    /// stress job pins `SERVE_STRESS_SEED`): every reply must carry the
    /// submitted request's own result, and the counters must balance.
    #[test]
    fn stress_replicated_pool_under_concurrent_load() {
        let seed: u64 = std::env::var("SERVE_STRESS_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(42);
        let engines: Vec<Box<dyn crate::api::Engine>> =
            (0..4).map(|_| StubEngine::elastic().shared().1).collect();
        let entry = Arc::new(ModelEntry::from_engines("stress", engines, vec![4]).unwrap());
        let b = Arc::new(Batcher::spawn(
            entry,
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(200),
                queue_cap: 64,
                spans: None,
            },
        ));
        let clients = 8usize;
        let per_client = 40usize;
        let mut handles = Vec::new();
        for c in 0..clients {
            let b = Arc::clone(&b);
            handles.push(thread::spawn(move || {
                let mut rng = Prng::new(seed.wrapping_add(c as u64));
                for _ in 0..per_client {
                    let input = rng.normal_vec(4, 1.0);
                    let out = b.submit(input.clone()).unwrap();
                    assert_eq!(out, StubEngine::expected_row(&input));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = b.snapshot();
        assert_eq!(snap.requests, (clients * per_client) as u64);
        assert_eq!(snap.queue_depth, 0, "injector queue drained");
        assert_eq!(snap.items, snap.requests, "every request in exactly one batch");
        assert_eq!(snap.errors, 0);
        assert_eq!(snap.shed, 0);
        assert_eq!(snap.replicas_busy, 0, "all replicas idle after the load");
    }

    /// Stage spans: the deterministic shed scenario, with spans on,
    /// yields one span per terminal outcome — Ok for every served
    /// request (tagged with the executing replica and the real batch
    /// size), plus the capacity shed and the deadline shed.
    #[test]
    fn spans_record_outcomes_replicas_and_batch_sizes() {
        use crate::obs::SpanConfig;
        let (entered_tx, entered_rx) = mpsc::channel();
        let (gate_tx, gate_rx) = mpsc::channel();
        let (_stub, engine) =
            StubEngine::elastic().with_entered(entered_tx).with_gate(gate_rx).shared();
        let entry = Arc::new(ModelEntry::from_engine("spans", engine, vec![4]));
        let b = Batcher::spawn(
            entry,
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                queue_cap: 3,
                spans: Some(SpanConfig::default()),
            },
        );
        // A alone in batch 1, gated inside the engine; then B, E (ages
        // out), C queue behind it and a 5th submit hits the full queue.
        let rx_a = b.try_submit(vec![1.0; 4]).unwrap();
        entered_rx.recv().unwrap();
        let rx_b = b.try_submit(vec![1.0; 4]).unwrap();
        let rx_e = b
            .try_submit_deadline(vec![2.0; 4], Duration::from_nanos(1))
            .unwrap();
        let rx_c = b.try_submit(vec![3.0; 4]).unwrap();
        assert!(b.try_submit(vec![4.0; 4]).is_err(), "queue full");
        drop(gate_tx);
        assert!(rx_a.recv().unwrap().is_ok());
        assert!(rx_b.recv().unwrap().is_ok());
        assert!(rx_c.recv().unwrap().is_ok());
        assert!(rx_e.recv().unwrap().is_err());

        let ring = b.spans().expect("config enabled spans");
        assert_eq!(ring.offered(), 5);
        let spans = ring.snapshot();
        assert_eq!(spans.len(), 5);
        let count = |o: SpanOutcome| spans.iter().filter(|s| s.outcome == o).count();
        assert_eq!(count(SpanOutcome::Ok), 3);
        assert_eq!(count(SpanOutcome::ShedQueueFull), 1);
        assert_eq!(count(SpanOutcome::ShedDeadline), 1);
        for s in &spans {
            match s.outcome {
                SpanOutcome::Ok => {
                    assert_eq!(s.replica, 0, "single replica executed everything");
                    assert!(s.batch_size >= 1);
                }
                _ => {
                    assert_eq!(s.replica, -1, "shed spans never executed");
                    assert_eq!(s.batch_size, 0);
                }
            }
        }
        // A executed alone; B and C formed one batch behind the gate.
        let mut ok_sizes: Vec<u64> = spans
            .iter()
            .filter(|s| s.outcome == SpanOutcome::Ok)
            .map(|s| s.batch_size)
            .collect();
        ok_sizes.sort_unstable();
        assert_eq!(ok_sizes, vec![1, 2, 2]);
    }
}
