//! Serving metrics: lock-cheap counters plus log-bucketed histograms.
//!
//! Latency and batch-size distributions are [`Hist`]s — fixed-memory
//! HDR-style histograms with atomic buckets — so quantiles stay honest
//! over unbounded runs. (The previous implementation kept the first
//! 65,536 samples in a `Mutex<Vec<f64>>` and silently dropped the rest,
//! biasing p50/p95/p99 toward startup behaviour.)
//!
//! `Metrics` also captures its own construction instant, so throughput
//! in `metrics` responses is computed against the true serve uptime
//! rather than a caller-supplied wall time; `report(wall_s)` remains
//! for callers that measure their own window.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::obs::prom::PromWriter;
use crate::util::hist::{Hist, HistSnapshot};
use crate::util::json::Json;
use crate::util::stats::Summary;

pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub items: AtomicU64,
    pub errors: AtomicU64,
    /// requests shed before execution (queue full at `try_submit`, or
    /// deadline exceeded while queued)
    pub shed: AtomicU64,
    /// gauge: requests currently waiting in the injector queue (the
    /// true pending depth — NOT the size of the last drained batch).
    /// `Batcher::snapshot` samples it live from the queue; reading the
    /// atomic directly returns the last sampled value.
    pub queue_depth: AtomicU64,
    /// gauge: replicas currently executing a batch
    pub replicas_busy: AtomicU64,
    /// per-request end-to-end latency histogram (seconds)
    latencies: Hist,
    /// per-batch size histogram
    batch_sizes: Hist,
    /// monotonic construction instant — the serve-start for throughput
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            items: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            replicas_busy: AtomicU64::new(0),
            latencies: Hist::new(),
            batch_sizes: Hist::new(),
            started: Instant::now(),
        }
    }

    pub fn record_request(&self, latency_s: f64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.latencies.record(latency_s);
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.items.fetch_add(size as u64, Ordering::Relaxed);
        self.batch_sizes.record(size as f64);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Seconds since this `Metrics` was constructed (monotonic).
    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    pub fn latency_summary(&self) -> Option<Summary> {
        self.latencies.snapshot().summary()
    }

    pub fn mean_batch_size(&self) -> f64 {
        self.batch_sizes.snapshot().mean().unwrap_or(0.0)
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let latency_hist = self.latencies.snapshot();
        let batch_hist = self.batch_sizes.snapshot();
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            items: self.items.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            replicas_busy: self.replicas_busy.load(Ordering::Relaxed),
            latency: latency_hist.summary(),
            mean_batch: batch_hist.mean().unwrap_or(0.0),
            latency_hist,
            batch_hist,
            uptime_s: self.uptime_s(),
        }
    }
}

#[derive(Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub items: u64,
    pub errors: u64,
    pub shed: u64,
    pub queue_depth: u64,
    pub replicas_busy: u64,
    pub latency: Option<Summary>,
    pub mean_batch: f64,
    /// full latency histogram (seconds) for quantile export
    pub latency_hist: HistSnapshot,
    /// full batch-size histogram for quantile export
    pub batch_hist: HistSnapshot,
    /// seconds since the `Metrics` was constructed, captured at snapshot
    pub uptime_s: f64,
}

impl MetricsSnapshot {
    pub fn report(&self, wall_s: f64) -> String {
        let mut s = format!(
            "requests={} batches={} items={} errors={} shed={} queue_depth={} \
             replicas_busy={} mean_batch={:.2} throughput={:.1}/s",
            self.requests,
            self.batches,
            self.items,
            self.errors,
            self.shed,
            self.queue_depth,
            self.replicas_busy,
            self.mean_batch,
            self.requests as f64 / wall_s.max(1e-9),
        );
        if let Some(l) = &self.latency {
            s.push_str(&format!(
                " p50={:.2}ms p95={:.2}ms p99={:.2}ms",
                l.p50 * 1e3,
                l.p95 * 1e3,
                l.p99 * 1e3
            ));
        }
        s
    }

    /// `report` against the snapshot's own uptime — immune to callers
    /// passing the wrong wall window.
    pub fn report_uptime(&self) -> String {
        self.report(self.uptime_s)
    }

    /// Structured numeric JSON: every counter/gauge as a number plus
    /// `latency`/`batch` quantile objects; keeps the `report` string
    /// alongside for compatibility.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::num(self.requests as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("items", Json::num(self.items as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("queue_depth", Json::num(self.queue_depth as f64)),
            ("replicas_busy", Json::num(self.replicas_busy as f64)),
            ("mean_batch", Json::num(self.mean_batch)),
            ("uptime_s", Json::num(self.uptime_s)),
            ("throughput_rps", Json::num(self.requests as f64 / self.uptime_s.max(1e-9))),
            ("latency", hist_json(&self.latency_hist)),
            ("batch", hist_json(&self.batch_hist)),
            ("report", Json::str(self.report_uptime())),
        ])
    }
}

fn hist_json(h: &HistSnapshot) -> Json {
    if h.is_empty() {
        return Json::Null;
    }
    Json::obj(vec![
        ("count", Json::num(h.count as f64)),
        ("mean", Json::num(h.mean().unwrap_or(0.0))),
        ("min", Json::num(h.min)),
        ("max", Json::num(h.max)),
        ("p50", Json::num(h.quantile(0.50).unwrap_or(0.0))),
        ("p90", Json::num(h.quantile(0.90).unwrap_or(0.0))),
        ("p95", Json::num(h.quantile(0.95).unwrap_or(0.0))),
        ("p99", Json::num(h.quantile(0.99).unwrap_or(0.0))),
    ])
}

/// Render all per-model snapshots plus registry residency as prometheus
/// text exposition (`obs::prom` grammar; counters suffixed `_total`).
pub fn prometheus_text(
    models: &[(String, MetricsSnapshot)],
    residency: &ResidencySnapshot,
) -> String {
    let mut w = PromWriter::new();
    for (model, s) in models {
        let l = [("model", model.as_str())];
        w.metric("lutnn_requests_total", "counter", "Requests replied");
        w.sample("lutnn_requests_total", &l, s.requests as f64);
        w.metric("lutnn_batches_total", "counter", "Batches executed");
        w.sample("lutnn_batches_total", &l, s.batches as f64);
        w.metric("lutnn_items_total", "counter", "Items across all batches");
        w.sample("lutnn_items_total", &l, s.items as f64);
        w.metric("lutnn_errors_total", "counter", "Engine errors replied");
        w.sample("lutnn_errors_total", &l, s.errors as f64);
        w.metric("lutnn_shed_total", "counter", "Requests shed before execution");
        w.sample("lutnn_shed_total", &l, s.shed as f64);
        w.metric("lutnn_queue_depth", "gauge", "Requests waiting in the injector queue");
        w.sample("lutnn_queue_depth", &l, s.queue_depth as f64);
        w.metric("lutnn_replicas_busy", "gauge", "Replicas currently executing a batch");
        w.sample("lutnn_replicas_busy", &l, s.replicas_busy as f64);
        summary_metric(
            &mut w,
            "lutnn_request_latency_seconds",
            "End-to-end request latency",
            model,
            &s.latency_hist,
        );
        summary_metric(&mut w, "lutnn_batch_size", "Executed batch sizes", model, &s.batch_hist);
    }
    w.metric("lutnn_resident_bytes", "gauge", "Bytes of warmed lazy models resident");
    w.sample("lutnn_resident_bytes", &[], residency.resident_bytes as f64);
    w.metric("lutnn_resident_models", "gauge", "Warmed lazy models resident");
    w.sample("lutnn_resident_models", &[], residency.resident_models as f64);
    w.metric("lutnn_page_ins_total", "counter", "Cold to warm page-ins");
    w.sample("lutnn_page_ins_total", &[], residency.page_ins as f64);
    w.metric("lutnn_evictions_total", "counter", "Warm to cold evictions");
    w.sample("lutnn_evictions_total", &[], residency.evictions as f64);
    if let Some(b) = residency.budget_bytes {
        w.metric("lutnn_resident_budget_bytes", "gauge", "Residency byte budget");
        w.sample("lutnn_resident_budget_bytes", &[], b as f64);
    }
    w.finish()
}

fn summary_metric(w: &mut PromWriter, name: &str, help: &str, model: &str, h: &HistSnapshot) {
    w.metric(name, "summary", help);
    for (q, tag) in [(0.50, "0.5"), (0.90, "0.9"), (0.95, "0.95"), (0.99, "0.99")] {
        if let Some(v) = h.quantile(q) {
            w.sample(name, &[("model", model), ("quantile", tag)], v);
        }
    }
    w.sample(&format!("{name}_sum"), &[("model", model)], h.sum);
    w.sample(&format!("{name}_count"), &[("model", model)], h.count as f64);
}

/// Registry-level residency gauges and counters for the cold-model
/// memory lifecycle (`Registry::register_lazy` paging + LRU eviction).
/// Owned by the registry, not per model: the budget is fleet-wide.
#[derive(Default)]
pub struct ResidencyStats {
    /// gauge: bytes of warmed lazy models currently registry-resident.
    /// In-flight `Arc<ModelEntry>`s of an evicted model keep its pool
    /// alive until they drop, but are no longer counted here — the
    /// gauge tracks what the registry will hand out, which is what the
    /// budget bounds.
    pub resident_bytes: AtomicU64,
    /// gauge: warmed lazy models currently registry-resident
    pub resident_models: AtomicU64,
    /// counter: cold -> warm page-ins (exactly one per pool build)
    pub page_ins: AtomicU64,
    /// counter: warm -> cold evictions (the spec is retained; the next
    /// resolve pages the model back in from disk)
    pub evictions: AtomicU64,
}

impl ResidencyStats {
    pub fn snapshot(&self, budget_bytes: Option<usize>) -> ResidencySnapshot {
        ResidencySnapshot {
            resident_bytes: self.resident_bytes.load(Ordering::Relaxed),
            resident_models: self.resident_models.load(Ordering::Relaxed),
            page_ins: self.page_ins.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            budget_bytes: budget_bytes.map(|b| b as u64),
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ResidencySnapshot {
    pub resident_bytes: u64,
    pub resident_models: u64,
    pub page_ins: u64,
    pub evictions: u64,
    /// `None` = unbudgeted (warmed models are never evicted)
    pub budget_bytes: Option<u64>,
}

impl ResidencySnapshot {
    pub fn report(&self) -> String {
        format!(
            "resident_bytes={} resident_models={} page_ins={} evictions={} budget_bytes={}",
            self.resident_bytes,
            self.resident_models,
            self.page_ins,
            self.evictions,
            self.budget_bytes.map(|b| b.to_string()).unwrap_or_else(|| "none".into()),
        )
    }

    /// Structured numeric JSON with the `report` string alongside.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("resident_bytes", Json::num(self.resident_bytes as f64)),
            ("resident_models", Json::num(self.resident_models as f64)),
            ("page_ins", Json::num(self.page_ins as f64)),
            ("evictions", Json::num(self.evictions as f64)),
            (
                "budget_bytes",
                self.budget_bytes.map(|b| Json::num(b as f64)).unwrap_or(Json::Null),
            ),
            ("report", Json::str(self.report())),
        ])
    }
}

/// RAII latency timer: records on drop.
pub struct LatencyGuard<'a> {
    metrics: &'a Metrics,
    start: Instant,
}

impl<'a> LatencyGuard<'a> {
    pub fn new(metrics: &'a Metrics) -> Self {
        LatencyGuard { metrics, start: Instant::now() }
    }
}

impl Drop for LatencyGuard<'_> {
    fn drop(&mut self) {
        self.metrics.record_request(self.start.elapsed().as_secs_f64());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::{Arc, Barrier};

    #[test]
    fn counters_and_summary() {
        let m = Metrics::new();
        m.record_request(0.010);
        m.record_request(0.020);
        m.record_batch(4);
        m.record_error();
        m.record_shed();
        m.queue_depth.store(3, Ordering::Relaxed);
        m.replicas_busy.store(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.items, 4);
        assert_eq!(s.errors, 1);
        assert_eq!(s.shed, 1);
        assert_eq!(s.queue_depth, 3);
        assert_eq!(s.replicas_busy, 2);
        assert_eq!(s.mean_batch, 4.0);
        let l = s.latency.as_ref().unwrap();
        assert!((l.mean - 0.015).abs() < 1e-9);
        let report = s.report(1.0);
        assert!(report.contains("queue_depth=3"), "{report}");
        assert!(report.contains("replicas_busy=2"), "{report}");
        assert!(report.contains("shed=1"), "{report}");
    }

    /// Regression for the old first-65,536-samples truncation: a tail
    /// distribution arriving *after* that many samples must still move
    /// the reported quantiles.
    #[test]
    fn quantiles_track_a_shifted_tail_past_the_old_reservoir() {
        const OLD_RESERVOIR: usize = 65_536;
        let m = Metrics::new();
        for _ in 0..70_000 {
            m.record_request(0.001);
        }
        for _ in 0..30_000 {
            m.record_request(0.1);
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 100_000);
        assert_eq!(s.latency_hist.count, 100_000);
        assert!(s.latency_hist.total() as usize > OLD_RESERVOIR);
        let l = s.latency.unwrap();
        assert_eq!(l.n, 100_000);
        // 30% of mass is at 0.1 — p50 stays low, p95/p99 must be in the
        // tail (the truncating reservoir reported ~0.001 for all three).
        assert!(l.p50 < 0.01, "p50={}", l.p50);
        assert!(l.p95 > 0.05, "p95={}", l.p95);
        assert!(l.p99 > 0.05, "p99={}", l.p99);
    }

    /// Gate-sequenced concurrency: recorders pause at a barrier so the
    /// mid-run snapshot sees an exact, quiescent state; a free-running
    /// snapshotter meanwhile checks invariants under contention.
    /// Latency values are dyadic (0.25/0.5) so f64 sums are exact in
    /// any interleaving.
    #[test]
    fn concurrent_recording_is_exact_and_untorn() {
        const THREADS: usize = 4;
        const PER: usize = 2_000;
        let m = Arc::new(Metrics::new());
        let barrier = Arc::new(Barrier::new(THREADS + 1));
        let stop = Arc::new(AtomicBool::new(false));
        let snapper = {
            let m = Arc::clone(&m);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut prev = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let s = m.snapshot();
                    assert!(s.requests >= prev, "requests went backwards");
                    prev = s.requests;
                    if let Some(l) = &s.latency {
                        assert!(l.p50 <= l.p95 && l.p95 <= l.p99, "quantile order");
                    }
                }
            })
        };
        let workers: Vec<_> = (0..THREADS)
            .map(|_| {
                let m = Arc::clone(&m);
                let b = Arc::clone(&barrier);
                std::thread::spawn(move || {
                    for _ in 0..PER {
                        m.record_request(0.25);
                        m.record_batch(2);
                    }
                    b.wait();
                    b.wait();
                    for _ in 0..PER {
                        m.record_request(0.5);
                    }
                })
            })
            .collect();
        barrier.wait(); // phase 1 complete on all threads
        let s = m.snapshot();
        let phase1 = (THREADS * PER) as u64;
        assert_eq!(s.requests, phase1);
        assert_eq!(s.batches, phase1);
        assert_eq!(s.items, 2 * phase1);
        assert_eq!(s.latency_hist.count, phase1);
        assert_eq!(s.latency_hist.total(), phase1);
        assert_eq!(s.latency_hist.sum, phase1 as f64 * 0.25);
        assert_eq!(s.latency_hist.min, 0.25);
        assert_eq!(s.latency_hist.max, 0.25);
        barrier.wait(); // release phase 2
        for w in workers {
            w.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        snapper.join().unwrap();
        let s = m.snapshot();
        assert_eq!(s.requests, 2 * phase1);
        assert_eq!(s.latency_hist.count, 2 * phase1);
        assert_eq!(s.latency_hist.sum, phase1 as f64 * 0.25 + phase1 as f64 * 0.5);
        assert_eq!(s.latency_hist.min, 0.25);
        assert_eq!(s.latency_hist.max, 0.5);
    }

    #[test]
    fn uptime_throughput_and_both_report_paths() {
        let m = Metrics::new();
        m.record_request(0.01);
        std::thread::sleep(std::time::Duration::from_millis(2));
        let s = m.snapshot();
        assert!(s.uptime_s > 0.0);
        assert_eq!(s.report_uptime(), s.report(s.uptime_s));
        // Caller-supplied wall path still works and differs.
        assert!(s.report(1.0).contains("throughput=1.0/s"));
    }

    #[test]
    fn metrics_snapshot_json_is_numeric() {
        let m = Metrics::new();
        m.record_request(0.004);
        m.record_request(0.008);
        m.record_batch(2);
        let j = m.snapshot().to_json();
        assert_eq!(j.get("requests").and_then(|v| v.as_f64()), Some(2.0));
        let lat = j.get("latency").expect("latency object");
        let p50 = lat.get("p50").and_then(|v| v.as_f64()).unwrap();
        let p99 = lat.get("p99").and_then(|v| v.as_f64()).unwrap();
        assert!(p50 > 0.0 && p50 <= p99);
        assert!(j.get("report").and_then(|v| v.as_str()).unwrap().contains("requests=2"));
        // Empty histograms serialize as null, not a bogus object.
        let empty = Metrics::new().snapshot().to_json();
        assert!(matches!(empty.get("latency"), Some(Json::Null)));
    }

    #[test]
    fn prometheus_text_round_trips() {
        let m = Metrics::new();
        m.record_request(0.002);
        m.record_batch(1);
        let models = vec![("demo".to_string(), m.snapshot())];
        let res = ResidencyStats::default().snapshot(Some(1 << 20));
        let text = prometheus_text(&models, &res);
        let samples = crate::obs::prom::parse(&text).expect("own exposition must parse");
        let req = samples
            .iter()
            .find(|s| s.name == "lutnn_requests_total" && s.label("model") == Some("demo"))
            .expect("requests sample");
        assert_eq!(req.value, 1.0);
        assert!(samples.iter().any(|s| s.name == "lutnn_resident_budget_bytes"));
        // Both summaries (request latency + batch size) expose p99.
        let p99s = samples.iter().filter(|s| s.label("quantile") == Some("0.99"));
        assert_eq!(p99s.count(), 2, "latency and batch-size summaries expose p99");
    }

    #[test]
    fn residency_snapshot_and_report() {
        let s = ResidencyStats::default();
        s.resident_bytes.store(4096, Ordering::Relaxed);
        s.resident_models.store(2, Ordering::Relaxed);
        s.page_ins.store(5, Ordering::Relaxed);
        s.evictions.store(3, Ordering::Relaxed);
        let snap = s.snapshot(Some(8192));
        assert_eq!(snap.resident_bytes, 4096);
        assert_eq!(snap.resident_models, 2);
        assert_eq!(snap.page_ins, 5);
        assert_eq!(snap.evictions, 3);
        assert_eq!(snap.budget_bytes, Some(8192));
        let report = snap.report();
        assert!(report.contains("resident_bytes=4096"), "{report}");
        assert!(report.contains("evictions=3"), "{report}");
        assert!(report.contains("budget_bytes=8192"), "{report}");
        assert!(s.snapshot(None).report().contains("budget_bytes=none"));
        let j = snap.to_json();
        assert_eq!(j.get("page_ins").and_then(|v| v.as_f64()), Some(5.0));
        assert_eq!(j.get("budget_bytes").and_then(|v| v.as_f64()), Some(8192.0));
        assert!(matches!(s.snapshot(None).to_json().get("budget_bytes"), Some(Json::Null)));
    }

    #[test]
    fn guard_records() {
        let m = Metrics::new();
        {
            let _g = LatencyGuard::new(&m);
        }
        assert_eq!(m.snapshot().requests, 1);
    }
}
