//! Serving metrics: counters + latency reservoir, all lock-cheap.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::Summary;

const RESERVOIR: usize = 65_536;

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub items: AtomicU64,
    pub errors: AtomicU64,
    /// requests shed before execution (queue full at `try_submit`, or
    /// deadline exceeded while queued)
    pub shed: AtomicU64,
    /// gauge: requests currently waiting in the injector queue (the
    /// true pending depth — NOT the size of the last drained batch).
    /// `Batcher::snapshot` samples it live from the queue; reading the
    /// atomic directly returns the last sampled value.
    pub queue_depth: AtomicU64,
    /// gauge: replicas currently executing a batch
    pub replicas_busy: AtomicU64,
    /// per-request end-to-end latency samples (seconds)
    latencies: Mutex<Vec<f64>>,
    /// per-batch sizes
    batch_sizes: Mutex<Vec<f64>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_request(&self, latency_s: f64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let mut g = self.latencies.lock().unwrap();
        if g.len() < RESERVOIR {
            g.push(latency_s);
        }
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.items.fetch_add(size as u64, Ordering::Relaxed);
        let mut g = self.batch_sizes.lock().unwrap();
        if g.len() < RESERVOIR {
            g.push(size as f64);
        }
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn latency_summary(&self) -> Option<Summary> {
        let g = self.latencies.lock().unwrap();
        if g.is_empty() {
            None
        } else {
            Some(Summary::of(&g))
        }
    }

    pub fn mean_batch_size(&self) -> f64 {
        let g = self.batch_sizes.lock().unwrap();
        if g.is_empty() {
            0.0
        } else {
            g.iter().sum::<f64>() / g.len() as f64
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            items: self.items.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            replicas_busy: self.replicas_busy.load(Ordering::Relaxed),
            latency: self.latency_summary(),
            mean_batch: self.mean_batch_size(),
        }
    }
}

#[derive(Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub items: u64,
    pub errors: u64,
    pub shed: u64,
    pub queue_depth: u64,
    pub replicas_busy: u64,
    pub latency: Option<Summary>,
    pub mean_batch: f64,
}

impl MetricsSnapshot {
    pub fn report(&self, wall_s: f64) -> String {
        let mut s = format!(
            "requests={} batches={} items={} errors={} shed={} queue_depth={} \
             replicas_busy={} mean_batch={:.2} throughput={:.1}/s",
            self.requests,
            self.batches,
            self.items,
            self.errors,
            self.shed,
            self.queue_depth,
            self.replicas_busy,
            self.mean_batch,
            self.requests as f64 / wall_s.max(1e-9),
        );
        if let Some(l) = &self.latency {
            s.push_str(&format!(
                " p50={:.2}ms p95={:.2}ms p99={:.2}ms",
                l.p50 * 1e3,
                l.p95 * 1e3,
                l.p99 * 1e3
            ));
        }
        s
    }
}

/// Registry-level residency gauges and counters for the cold-model
/// memory lifecycle (`Registry::register_lazy` paging + LRU eviction).
/// Owned by the registry, not per model: the budget is fleet-wide.
#[derive(Default)]
pub struct ResidencyStats {
    /// gauge: bytes of warmed lazy models currently registry-resident.
    /// In-flight `Arc<ModelEntry>`s of an evicted model keep its pool
    /// alive until they drop, but are no longer counted here — the
    /// gauge tracks what the registry will hand out, which is what the
    /// budget bounds.
    pub resident_bytes: AtomicU64,
    /// gauge: warmed lazy models currently registry-resident
    pub resident_models: AtomicU64,
    /// counter: cold -> warm page-ins (exactly one per pool build)
    pub page_ins: AtomicU64,
    /// counter: warm -> cold evictions (the spec is retained; the next
    /// resolve pages the model back in from disk)
    pub evictions: AtomicU64,
}

impl ResidencyStats {
    pub fn snapshot(&self, budget_bytes: Option<usize>) -> ResidencySnapshot {
        ResidencySnapshot {
            resident_bytes: self.resident_bytes.load(Ordering::Relaxed),
            resident_models: self.resident_models.load(Ordering::Relaxed),
            page_ins: self.page_ins.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            budget_bytes: budget_bytes.map(|b| b as u64),
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ResidencySnapshot {
    pub resident_bytes: u64,
    pub resident_models: u64,
    pub page_ins: u64,
    pub evictions: u64,
    /// `None` = unbudgeted (warmed models are never evicted)
    pub budget_bytes: Option<u64>,
}

impl ResidencySnapshot {
    pub fn report(&self) -> String {
        format!(
            "resident_bytes={} resident_models={} page_ins={} evictions={} budget_bytes={}",
            self.resident_bytes,
            self.resident_models,
            self.page_ins,
            self.evictions,
            self.budget_bytes.map(|b| b.to_string()).unwrap_or_else(|| "none".into()),
        )
    }
}

/// RAII latency timer: records on drop.
pub struct LatencyGuard<'a> {
    metrics: &'a Metrics,
    start: Instant,
}

impl<'a> LatencyGuard<'a> {
    pub fn new(metrics: &'a Metrics) -> Self {
        LatencyGuard { metrics, start: Instant::now() }
    }
}

impl Drop for LatencyGuard<'_> {
    fn drop(&mut self) {
        self.metrics.record_request(self.start.elapsed().as_secs_f64());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_summary() {
        let m = Metrics::new();
        m.record_request(0.010);
        m.record_request(0.020);
        m.record_batch(4);
        m.record_error();
        m.record_shed();
        m.queue_depth.store(3, Ordering::Relaxed);
        m.replicas_busy.store(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.items, 4);
        assert_eq!(s.errors, 1);
        assert_eq!(s.shed, 1);
        assert_eq!(s.queue_depth, 3);
        assert_eq!(s.replicas_busy, 2);
        assert_eq!(s.mean_batch, 4.0);
        let l = s.latency.as_ref().unwrap();
        assert!((l.mean - 0.015).abs() < 1e-9);
        let report = s.report(1.0);
        assert!(report.contains("queue_depth=3"), "{report}");
        assert!(report.contains("replicas_busy=2"), "{report}");
        assert!(report.contains("shed=1"), "{report}");
    }

    #[test]
    fn residency_snapshot_and_report() {
        let s = ResidencyStats::default();
        s.resident_bytes.store(4096, Ordering::Relaxed);
        s.resident_models.store(2, Ordering::Relaxed);
        s.page_ins.store(5, Ordering::Relaxed);
        s.evictions.store(3, Ordering::Relaxed);
        let snap = s.snapshot(Some(8192));
        assert_eq!(snap.resident_bytes, 4096);
        assert_eq!(snap.resident_models, 2);
        assert_eq!(snap.page_ins, 5);
        assert_eq!(snap.evictions, 3);
        assert_eq!(snap.budget_bytes, Some(8192));
        let report = snap.report();
        assert!(report.contains("resident_bytes=4096"), "{report}");
        assert!(report.contains("evictions=3"), "{report}");
        assert!(report.contains("budget_bytes=8192"), "{report}");
        assert!(s.snapshot(None).report().contains("budget_bytes=none"));
    }

    #[test]
    fn guard_records() {
        let m = Metrics::new();
        {
            let _g = LatencyGuard::new(&m);
        }
        assert_eq!(m.snapshot().requests, 1);
    }
}
