//! TCP front-end: newline-delimited JSON over a plain socket.
//!
//! Request : {"model": "name", "input": [f32...]}
//! Response: {"ok": true, "output": [f32...], "latency_us": n}
//!         | {"ok": false, "error": "..."}
//! Special : {"cmd": "metrics"} — structured numeric JSON (per-model
//!           counters + histogram quantiles + residency gauges, each
//!           with the legacy report string alongside); add
//!           {"format": "prometheus"} for text exposition in "text"
//!         | {"cmd": "spans"} — per-model stage-span ring contents
//!           (requires [`ServerConfig::profile`] or an explicit
//!           [`BatcherConfig::spans`])
//!         | {"cmd": "models"} | {"cmd": "shutdown"}
//!
//! One handler thread per connection (from a bounded pool); inference is
//! funneled through each model's dynamic batcher, so concurrent clients
//! coalesce into batches exactly as in-proc callers do.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

use anyhow::Result;

use super::batcher::{Batcher, BatcherConfig};
use super::metrics::{prometheus_text, MetricsSnapshot};
use super::{ModelEntry, Registry, ReplicateOutcome};
use crate::util::json::{self, Json};
use crate::util::threadpool::ThreadPool;

pub struct ServerConfig {
    pub addr: String,
    pub handler_threads: usize,
    /// Grow every model's engine pool to at least this many replicas at
    /// startup (best effort: engines without `clone_replica` keep their
    /// registered pool size, and the skips are logged). The batcher then
    /// runs one worker per replica with work stealing between them.
    pub replicas: usize,
    /// Byte budget over warmed lazy models
    /// ([`Registry::set_resident_budget`]): page-ins evict
    /// least-recently-used warmed models back to their on-disk bundles
    /// first. `None` = never evict.
    pub resident_budget_bytes: Option<usize>,
    /// Convenience switch for `lutnn serve --profile`: turns on
    /// stage-span recording with default [`crate::obs::SpanConfig`]
    /// settings unless `batcher.spans` was already set explicitly.
    pub profile: bool,
    pub batcher: BatcherConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7070".into(),
            handler_threads: 4,
            replicas: 1,
            resident_budget_bytes: None,
            profile: false,
            batcher: BatcherConfig::default(),
        }
    }
}

/// A running server; drop or call `shutdown()` to stop accepting.
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start serving `registry` on `cfg.addr` (port 0 = ephemeral).
    pub fn start(mut registry: Registry, mut cfg: ServerConfig) -> Result<Server> {
        if cfg.profile && cfg.batcher.spans.is_none() {
            cfg.batcher.spans = Some(Default::default());
        }
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));

        registry.set_resident_budget(cfg.resident_budget_bytes);
        // Grow replicable pools to the configured replica target, and
        // log which models were skipped instead of silently no-opping.
        if cfg.replicas > 1 {
            for (name, outcome) in registry.replicate_to(cfg.replicas)? {
                match outcome {
                    ReplicateOutcome::Grown(_) => {}
                    ReplicateOutcome::SkippedShared => eprintln!(
                        "serve: model '{name}' not replicated (entry already shared out)"
                    ),
                    ReplicateOutcome::Unsupported(size) => eprintln!(
                        "serve: model '{name}' stays at {size} replica(s) (engine does not clone)"
                    ),
                }
            }
        }
        // Batchers spawn on demand; eagerly spawn them only for models
        // that are already resident — resolving a whole cold zoo at
        // startup would defeat lazy registration and the budget.
        let mut batchers: BTreeMap<String, ModelBatcher> = BTreeMap::new();
        for name in registry.names() {
            if let Some(entry) = registry.peek(&name) {
                let batcher = Arc::new(Batcher::spawn(Arc::clone(&entry), cfg.batcher.clone()));
                batchers.insert(name, ModelBatcher { batcher, entry });
            }
        }
        let last_evictions = AtomicU64::new(registry.residency().evictions);
        let shared = Arc::new(Shared {
            registry,
            batchers: RwLock::new(batchers),
            batcher_cfg: cfg.batcher,
            last_evictions,
            start: Instant::now(),
        });

        let stop2 = Arc::clone(&stop);
        let pool = ThreadPool::new(cfg.handler_threads);
        let accept_thread = std::thread::Builder::new()
            .name("lutnn-accept".into())
            .spawn(move || {
                loop {
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let shared = Arc::clone(&shared);
                            let stop3 = Arc::clone(&stop2);
                            pool.execute(move || {
                                let _ = handle_conn(stream, &shared, &stop3);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(1));
                        }
                        Err(_) => break,
                    }
                }
                // pool drops here -> handlers join
            })?;
        Ok(Server { addr, stop, accept_thread: Some(accept_thread) })
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }

    /// True once a shutdown has been requested (via cmd or `shutdown()`).
    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// A model's batcher plus the exact pool `Arc` it was spawned against,
/// so staleness (the registry evicted and re-paged the model) is one
/// pointer comparison away.
struct ModelBatcher {
    batcher: Arc<Batcher>,
    entry: Arc<ModelEntry>,
}

struct Shared {
    registry: Registry,
    /// Batchers keyed by canonical model name, spawned on first request
    /// (lazy models must not page in at startup) and replaced when the
    /// registry hands out a different pool for the name.
    batchers: RwLock<BTreeMap<String, ModelBatcher>>,
    batcher_cfg: BatcherConfig,
    /// registry eviction counter at the last stale-batcher sweep
    last_evictions: AtomicU64,
    start: Instant,
}

/// The batcher serving `entry`, spawned on first use. A cached batcher
/// is stale when the registry no longer hands out the same `Arc` (the
/// model was evicted and re-paged in): replacing it drops the old one,
/// which drains its queue against the old pool before the workers exit.
fn batcher_for(shared: &Shared, entry: &Arc<ModelEntry>) -> Arc<Batcher> {
    {
        let batchers = shared.batchers.read().expect("batcher map poisoned");
        if let Some(mb) = batchers.get(&entry.name) {
            if Arc::ptr_eq(&mb.entry, entry) {
                return Arc::clone(&mb.batcher);
            }
        }
    }
    let mut batchers = shared.batchers.write().expect("batcher map poisoned");
    // double-check under the write lock: another handler may have won
    if let Some(mb) = batchers.get(&entry.name) {
        if Arc::ptr_eq(&mb.entry, entry) {
            return Arc::clone(&mb.batcher);
        }
    }
    let batcher = Arc::new(Batcher::spawn(Arc::clone(entry), shared.batcher_cfg.clone()));
    batchers.insert(
        entry.name.clone(),
        ModelBatcher { batcher: Arc::clone(&batcher), entry: Arc::clone(entry) },
    );
    batcher
}

/// Drop batchers whose model was evicted since the last sweep, so a
/// cold model's worker threads and queue don't outlive its pool. Runs
/// opportunistically on the request path, gated on the registry's
/// eviction counter; `Registry::peek` never pages anything back in.
fn sweep_stale_batchers(shared: &Shared) {
    let evictions = shared.registry.residency().evictions;
    if shared.last_evictions.swap(evictions, Ordering::Relaxed) == evictions {
        return;
    }
    let mut batchers = shared.batchers.write().expect("batcher map poisoned");
    batchers.retain(|name, mb| match shared.registry.peek(name) {
        Some(current) => Arc::ptr_eq(&current, &mb.entry),
        None => false,
    });
}

fn handle_conn(stream: TcpStream, shared: &Shared, stop: &AtomicBool) -> Result<()> {
    stream.set_nodelay(true)?;
    // Periodic read timeout so handler threads observe shutdown even on
    // idle connections (otherwise Server::drop would deadlock joining a
    // worker parked in read()).
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match reader.read_line(&mut line) {
            Ok(0) => break, // client closed
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // partial bytes (if any) stay accumulated in `line`
                continue;
            }
            Err(_) => break,
        }
        if !line.trim().is_empty() {
            let resp = handle_line(line.trim(), shared, stop);
            writer.write_all(json::to_string(&resp).as_bytes())?;
            writer.write_all(b"\n")?;
            writer.flush()?;
        }
        line.clear();
        if stop.load(Ordering::Relaxed) {
            break;
        }
    }
    Ok(())
}

fn err_json(msg: impl Into<String>) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::str(msg.into()))])
}

fn handle_line(line: &str, shared: &Shared, stop: &AtomicBool) -> Json {
    let req = match json::parse(line) {
        Ok(j) => j,
        Err(e) => return err_json(format!("bad json: {e}")),
    };
    if let Some(cmd) = req.get("cmd").and_then(|c| c.as_str()) {
        return match cmd {
            "models" => Json::obj(vec![
                ("ok", Json::Bool(true)),
                (
                    "models",
                    Json::Arr(
                        shared.registry.names().into_iter().map(Json::Str).collect(),
                    ),
                ),
                (
                    "cold",
                    Json::Arr(
                        shared.registry.cold_names().into_iter().map(Json::Str).collect(),
                    ),
                ),
            ]),
            "metrics" => {
                let batchers = shared.batchers.read().expect("batcher map poisoned");
                let snaps: Vec<(String, MetricsSnapshot)> = batchers
                    .iter()
                    .map(|(name, mb)| (name.clone(), mb.batcher.snapshot()))
                    .collect();
                drop(batchers);
                let residency = shared.registry.residency();
                if req.get("format").and_then(|f| f.as_str()) == Some("prometheus") {
                    Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("format", Json::str("prometheus")),
                        ("text", Json::str(prometheus_text(&snaps, &residency))),
                    ])
                } else {
                    let mut per_model = std::collections::BTreeMap::new();
                    for (name, snap) in &snaps {
                        per_model.insert(name.clone(), snap.to_json());
                    }
                    Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("uptime_s", Json::num(shared.start.elapsed().as_secs_f64())),
                        ("metrics", Json::Obj(per_model)),
                        ("residency", residency.to_json()),
                    ])
                }
            }
            "spans" => {
                let batchers = shared.batchers.read().expect("batcher map poisoned");
                let mut per_model = std::collections::BTreeMap::new();
                for (name, mb) in batchers.iter() {
                    let Some(ring) = mb.batcher.spans() else { continue };
                    per_model.insert(
                        name.clone(),
                        Json::obj(vec![
                            ("offered", Json::num(ring.offered() as f64)),
                            ("sampled", Json::num(ring.sampled() as f64)),
                            (
                                "spans",
                                Json::Arr(ring.snapshot().iter().map(|s| s.to_json()).collect()),
                            ),
                        ]),
                    );
                }
                Json::obj(vec![("ok", Json::Bool(true)), ("models", Json::Obj(per_model))])
            }
            "shutdown" => {
                stop.store(true, Ordering::Relaxed);
                Json::obj(vec![("ok", Json::Bool(true))])
            }
            other => err_json(format!("unknown cmd '{other}'")),
        };
    }

    let model = req.get("model").and_then(|m| m.as_str()).unwrap_or("default");
    let input: Option<Vec<f32>> = req.get("input").and_then(|i| i.as_arr()).map(|arr| {
        arr.iter()
            .map(|v| v.as_f64().unwrap_or(f64::NAN) as f32)
            .collect()
    });
    let Some(input) = input else {
        return err_json("missing 'input' array");
    };
    let entry = match shared.registry.resolve(model) {
        Ok(e) => e,
        Err(e) => return err_json(format!("{e}")),
    };
    // this resolve may have paged a cold model in (possibly evicting
    // another): retire batchers stranded on evicted pools, then fetch
    // or spawn the one for the current pool
    sweep_stale_batchers(shared);
    let batcher = batcher_for(shared, &entry);
    let t0 = Instant::now();
    match batcher.submit(input) {
        Ok(out) => Json::obj(vec![
            ("ok", Json::Bool(true)),
            (
                "output",
                Json::Arr(out.into_iter().map(|v| Json::num(v as f64)).collect()),
            ),
            ("latency_us", Json::num(t0.elapsed().as_micros() as f64)),
        ]),
        Err(e) => err_json(format!("{e:#}")),
    }
}

/// Minimal blocking client for tests/examples.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client { writer: stream.try_clone()?, reader: BufReader::new(stream) })
    }

    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.writer.write_all(json::to_string(req).as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))
    }

    pub fn infer(&mut self, model: &str, input: &[f32]) -> Result<Vec<f32>> {
        let req = Json::obj(vec![
            ("model", Json::str(model)),
            (
                "input",
                Json::Arr(input.iter().map(|&v| Json::num(v as f64)).collect()),
            ),
        ]);
        let resp = self.call(&req)?;
        anyhow::ensure!(
            resp.get("ok").and_then(|o| o.as_bool()).unwrap_or(false),
            "server error: {:?}",
            resp.get("error")
        );
        Ok(resp
            .get("output")
            .and_then(|o| o.as_arr())
            .unwrap_or(&[])
            .iter()
            .map(|v| v.as_f64().unwrap_or(0.0) as f32)
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ModelEntry;
    use crate::lut::LutOpts;
    use crate::nn::models::{build_cnn_graph, ConvSpec};

    fn test_registry() -> Registry {
        let g = build_cnn_graph(
            "m",
            [8, 8, 3],
            &[ConvSpec { cout: 4, k: 3, stride: 1 }],
            5,
            0,
        );
        let mut r = Registry::new();
        r.register(ModelEntry::native("m", &g, LutOpts::all(), 8, 1).unwrap());
        r.alias("default", "m");
        r
    }

    #[test]
    fn serve_and_infer_over_tcp() {
        let mut server = Server::start(
            test_registry(),
            ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
        )
        .unwrap();
        let mut client = Client::connect(&server.addr).unwrap();

        let out = client.infer("m", &vec![0.25; 192]).unwrap();
        assert_eq!(out.len(), 5);

        // alias routing
        let out2 = client.infer("default", &vec![0.25; 192]).unwrap();
        assert_eq!(out, out2);

        // control plane
        let models = client
            .call(&Json::obj(vec![("cmd", Json::str("models"))]))
            .unwrap();
        assert!(models.get("ok").unwrap().as_bool().unwrap());
        let metrics = client
            .call(&Json::obj(vec![("cmd", Json::str("metrics"))]))
            .unwrap();
        assert!(metrics.get("metrics").is_some());

        // errors
        let bad = client
            .call(&Json::obj(vec![("model", Json::str("nope"))]))
            .unwrap();
        assert!(!bad.get("ok").unwrap().as_bool().unwrap());

        server.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let server = Server::start(
            test_registry(),
            ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
        )
        .unwrap();
        let addr = server.addr;
        let mut handles = Vec::new();
        for _ in 0..4 {
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                for _ in 0..5 {
                    let out = c.infer("m", &vec![0.1; 192]).unwrap();
                    assert_eq!(out.len(), 5);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    /// `ServerConfig::replicas` grows native pools at startup and the
    /// replicated server answers identically to the single-replica one.
    #[test]
    fn replicated_server_serves_identical_results() {
        let single = Server::start(
            test_registry(),
            ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
        )
        .unwrap();
        let pooled = Server::start(
            test_registry(),
            ServerConfig { addr: "127.0.0.1:0".into(), replicas: 3, ..Default::default() },
        )
        .unwrap();
        let mut c1 = Client::connect(&single.addr).unwrap();
        let mut cp = Client::connect(&pooled.addr).unwrap();
        for i in 0..6 {
            let input = vec![0.05 * i as f32; 192];
            assert_eq!(
                c1.infer("m", &input).unwrap(),
                cp.infer("m", &input).unwrap(),
                "replicated server must match single-replica bytes"
            );
        }
    }

    /// Shutdown while a request is in flight: the handler's pending
    /// submit must complete (batchers drain on drop) before `shutdown`
    /// returns — the client receives its answer, not a closed socket.
    #[test]
    fn shutdown_completes_inflight_requests() {
        use crate::coordinator::pool::stubs::StubEngine;
        use std::sync::mpsc;

        let (entered_tx, entered_rx) = mpsc::channel();
        let (gate_tx, gate_rx) = mpsc::channel();
        let (_stub, engine) =
            StubEngine::elastic().with_entered(entered_tx).with_gate(gate_rx).shared();
        let mut r = Registry::new();
        r.register(ModelEntry::from_engine("gated", engine, vec![4]));
        let mut server = Server::start(
            r,
            ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
        )
        .unwrap();
        let addr = server.addr;
        let input = vec![1.0, 2.0, 3.0, 4.0];
        let want = StubEngine::expected_row(&input);
        let client = std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            c.infer("gated", &input).unwrap()
        });
        // The request is in flight (its worker is inside the engine)...
        entered_rx.recv().unwrap();
        // ...when shutdown begins; release the gate so the drain can
        // finish, and both the client and shutdown() must complete.
        let shutter = std::thread::spawn(move || {
            server.shutdown();
            server
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        drop(gate_tx);
        assert_eq!(client.join().unwrap(), want);
        let server = shutter.join().unwrap();
        assert!(server.stopped());
    }

    fn lazy_registry(names: &[&str]) -> Registry {
        let dir = std::env::temp_dir().join("lutnn_server_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let mut r = Registry::new();
        for name in names {
            let g = build_cnn_graph(
                name,
                [8, 8, 3],
                &[ConvSpec { cout: 4, k: 3, stride: 1 }],
                5,
                0,
            );
            let path = dir.join(format!("{name}.lutnn")).to_string_lossy().into_owned();
            crate::model_fmt::save_bundle(&g, &path).unwrap();
            r.register_lazy(&path, LutOpts::all(), 8, 1).unwrap();
        }
        r
    }

    /// Startup must not page lazy models in (no batcher, no pool build);
    /// the first request does, and the metrics command exposes the
    /// registry's residency gauges.
    #[test]
    fn lazy_models_page_in_on_first_request_not_at_startup() {
        let server = Server::start(
            lazy_registry(&["srv_cold"]),
            ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
        )
        .unwrap();
        let mut c = Client::connect(&server.addr).unwrap();

        let resp = c.call(&Json::obj(vec![("cmd", Json::str("metrics"))])).unwrap();
        let page_ins = |resp: &Json| {
            resp.get("residency").unwrap().get("page_ins").unwrap().as_usize().unwrap()
        };
        assert_eq!(page_ins(&resp), 0, "startup paged a model in: {resp:?}");
        let models = c.call(&Json::obj(vec![("cmd", Json::str("models"))])).unwrap();
        assert_eq!(models.get("cold").unwrap().as_arr().unwrap().len(), 1);

        let out = c.infer("srv_cold", &vec![0.25; 192]).unwrap();
        assert_eq!(out.len(), 5);
        let resp = c.call(&Json::obj(vec![("cmd", Json::str("metrics"))])).unwrap();
        assert_eq!(page_ins(&resp), 1);
        let residency = resp.get("residency").unwrap();
        assert_eq!(residency.get("resident_models").unwrap().as_usize().unwrap(), 1);
        // the legacy report string rides along in the structured object
        assert!(residency.get("report").unwrap().as_str().unwrap().contains("page_ins=1"));
        let models = c.call(&Json::obj(vec![("cmd", Json::str("models"))])).unwrap();
        assert!(models.get("cold").unwrap().as_arr().unwrap().is_empty());
    }

    /// With a resident budget sized for one model, serving model B
    /// evicts model A; a later request for A transparently re-pages it
    /// in and answers with the same bytes as before the eviction.
    #[test]
    fn eviction_and_repage_are_transparent_over_tcp() {
        // measure one model's footprint on a throwaway registry
        let probe = lazy_registry(&["srv_a"]);
        probe.resolve("srv_a").unwrap();
        let bytes = probe.residency().resident_bytes as usize;
        assert!(bytes > 0);

        let server = Server::start(
            lazy_registry(&["srv_a", "srv_b"]),
            ServerConfig {
                addr: "127.0.0.1:0".into(),
                resident_budget_bytes: Some(bytes),
                ..Default::default()
            },
        )
        .unwrap();
        let mut c = Client::connect(&server.addr).unwrap();
        let input = vec![0.25; 192];
        let first = c.infer("srv_a", &input).unwrap();
        let _ = c.infer("srv_b", &input).unwrap(); // evicts srv_a
        let resp = c.call(&Json::obj(vec![("cmd", Json::str("metrics"))])).unwrap();
        let residency = resp.get("residency").unwrap();
        assert_eq!(residency.get("evictions").unwrap().as_usize().unwrap(), 1, "{resp:?}");

        let again = c.infer("srv_a", &input).unwrap();
        assert_eq!(first, again, "re-paged model must answer identically");
        let resp = c.call(&Json::obj(vec![("cmd", Json::str("metrics"))])).unwrap();
        let residency = resp.get("residency").unwrap();
        assert_eq!(residency.get("page_ins").unwrap().as_usize().unwrap(), 3, "{resp:?}");
    }

    /// The metrics command returns structured numbers (counters exact,
    /// histogram quantiles ordered, residency gauges as fields) with the
    /// legacy report string alongside; the prometheus exposition parses
    /// through the CI parser with monotone counters; and `--profile`
    /// wiring surfaces stage spans over the spans command.
    #[test]
    fn metrics_are_structured_and_prometheus_parse_round_trips() {
        use crate::obs::prom;

        let server = Server::start(
            test_registry(),
            ServerConfig { addr: "127.0.0.1:0".into(), profile: true, ..Default::default() },
        )
        .unwrap();
        let mut c = Client::connect(&server.addr).unwrap();
        let input = vec![0.25; 192];
        for _ in 0..6 {
            c.infer("m", &input).unwrap();
        }

        let resp = c.call(&Json::obj(vec![("cmd", Json::str("metrics"))])).unwrap();
        let m = resp.get("metrics").unwrap().get("m").unwrap();
        assert_eq!(m.get("requests").unwrap().as_usize().unwrap(), 6);
        assert_eq!(m.get("errors").unwrap().as_usize().unwrap(), 0);
        let lat = m.get("latency").unwrap();
        let p50 = lat.get("p50").unwrap().as_f64().unwrap();
        let p95 = lat.get("p95").unwrap().as_f64().unwrap();
        let p99 = lat.get("p99").unwrap().as_f64().unwrap();
        assert!(p50 > 0.0, "latency histogram recorded nothing");
        assert!(p50 <= p95 && p95 <= p99, "quantile order: {p50} {p95} {p99}");
        assert!(m.get("report").unwrap().as_str().unwrap().contains("requests=6"));
        assert!(resp.get("residency").unwrap().get("resident_bytes").is_some());

        // prometheus exposition round-trips through the CI parser
        let req =
            Json::obj(vec![("cmd", Json::str("metrics")), ("format", Json::str("prometheus"))]);
        let reqs_total = |resp: &Json| {
            let text = resp.get("text").unwrap().as_str().unwrap();
            let samples = prom::parse(text).expect("server exposition must parse");
            samples
                .iter()
                .find(|s| s.name == "lutnn_requests_total" && s.label("model") == Some("m"))
                .expect("requests sample")
                .value
        };
        let first = reqs_total(&c.call(&req).unwrap());
        assert_eq!(first, 6.0);
        c.infer("m", &input).unwrap();
        let second = reqs_total(&c.call(&req).unwrap());
        assert!(second > first, "counter must be monotone: {first} -> {second}");

        // profile=true wired a span ring into the model's batcher
        let spans = c.call(&Json::obj(vec![("cmd", Json::str("spans"))])).unwrap();
        let ms = spans.get("models").unwrap().get("m").unwrap();
        assert!(ms.get("offered").unwrap().as_usize().unwrap() >= 7);
        let arr = ms.get("spans").unwrap().as_arr().unwrap();
        assert!(!arr.is_empty());
        assert!(arr.iter().all(|s| s.get("outcome").unwrap().as_str().unwrap() == "ok"));
    }
}
