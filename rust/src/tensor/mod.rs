//! Tensor substrate: dense row-major f32 tensors + im2col.
//!
//! Deliberately minimal — the engine works on 2-D matrices ([rows, D]
//! im2col patches) and NHWC 4-D activations; no autograd (training lives
//! in L2 python), no broadcasting zoo.

pub mod im2col;

/// Dense row-major f32 tensor with an explicit shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} != data len {}",
            shape,
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Number of leading-dim rows when viewed as [rows, cols].
    pub fn rows(&self) -> usize {
        assert_eq!(self.rank(), 2);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.rank(), 2);
        self.shape[1]
    }

    /// Reshape in place (same element count).
    pub fn reshape(mut self, shape: Vec<usize>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }

    /// Row-major element offset for an NHWC index.
    #[inline]
    pub fn nhwc_offset(&self, n: usize, h: usize, w: usize, c: usize) -> usize {
        debug_assert_eq!(self.rank(), 4);
        ((n * self.shape[1] + h) * self.shape[2] + w) * self.shape[3] + c
    }

    pub fn at4(&self, n: usize, h: usize, w: usize, c: usize) -> f32 {
        self.data[self.nhwc_offset(n, h, w, c)]
    }

    /// Max-abs difference to another tensor (shape-checked).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Mean squared difference (Fig. 3 MSE metric).
    pub fn mse(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        let n = self.data.len().max(1) as f32;
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            / n
    }

    /// Argmax along the last axis of a 2-D tensor (classification).
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.rank(), 2);
        let cols = self.cols();
        self.data
            .chunks_exact(cols)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect()
    }
}

/// Quantized INT8 matrix blob with per-codebook scales — the lookup-table
/// storage type (paper §3.3).
#[derive(Debug, Clone)]
pub struct QTable {
    /// [C, K, M] row-major
    pub data: Vec<i8>,
    pub c: usize,
    pub k: usize,
    pub m: usize,
    /// per-codebook symmetric scale, len C
    pub scale: Vec<f32>,
}

impl QTable {
    #[inline]
    pub fn row(&self, c: usize, k: usize) -> &[i8] {
        let base = (c * self.k + k) * self.m;
        &self.data[base..base + self.m]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::new(vec![1, 2, 2, 3], (0..12).map(|i| i as f32).collect());
        assert_eq!(t.at4(0, 1, 0, 2), 8.0);
        assert_eq!(t.nhwc_offset(0, 1, 1, 0), 9);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn argmax_rows() {
        let t = Tensor::new(vec![2, 3], vec![0.0, 5.0, 1.0, 9.0, 2.0, 3.0]);
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn mse_and_diff() {
        let a = Tensor::new(vec![1, 2], vec![1.0, 2.0]);
        let b = Tensor::new(vec![1, 2], vec![1.0, 4.0]);
        assert_eq!(a.max_abs_diff(&b), 2.0);
        assert_eq!(a.mse(&b), 2.0);
    }

    #[test]
    fn qtable_row() {
        let q = QTable {
            data: (0..24).map(|i| i as i8).collect(),
            c: 2,
            k: 3,
            m: 4,
            scale: vec![1.0, 0.5],
        };
        assert_eq!(q.row(1, 2), &[20, 21, 22, 23]);
    }
}
