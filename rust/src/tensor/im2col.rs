//! im2col: NHWC activations -> [N*Ho*Wo, Cin*k*k] patch matrix.
//!
//! Layout contract (shared with python `layers.im2col` and the pallas
//! kernel): the patch feature dimension is (Cin, kh, kw) **channel-major**
//! so that with V = k*k each codebook covers exactly one input channel's
//! window — the paper's (K, V) = (16, 9) for 3x3 convolutions.

use super::Tensor;

/// "SAME" padding for stride-s convolution (TF semantics, matches jax).
pub fn same_padding(in_size: usize, k: usize, stride: usize) -> (usize, usize) {
    let out = in_size.div_ceil(stride);
    let pad_total = ((out - 1) * stride + k).saturating_sub(in_size);
    (pad_total / 2, pad_total - pad_total / 2)
}

/// Output spatial size for SAME padding.
pub fn same_out_size(in_size: usize, stride: usize) -> usize {
    in_size.div_ceil(stride)
}

/// NHWC -> patches [N*Ho*Wo, Cin*k*k], channel-major feature order.
pub fn im2col(x: &Tensor, k: usize, stride: usize) -> Tensor {
    let (n, h, w, cin) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let ho = same_out_size(h, stride);
    let wo = same_out_size(w, stride);
    let d = cin * k * k;
    let mut out = vec![0.0f32; n * ho * wo * d];
    im2col_into(x, k, stride, &mut out);
    Tensor::new(vec![n * ho * wo, d], out)
}

/// Non-allocating `im2col` into a caller-owned buffer of exactly
/// `N*Ho*Wo * Cin*k*k` elements (the `Session` hot path). Returns
/// `(rows, d)`.
pub fn im2col_into(x: &Tensor, k: usize, stride: usize, out: &mut [f32]) -> (usize, usize) {
    assert_eq!(x.rank(), 4, "im2col expects NHWC");
    let (n, h, w, cin) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (pad_top, _) = same_padding(h, k, stride);
    let (pad_left, _) = same_padding(w, k, stride);
    let ho = same_out_size(h, stride);
    let wo = same_out_size(w, stride);
    let d = cin * k * k;
    assert_eq!(out.len(), n * ho * wo * d, "im2col_into buffer size");
    out.fill(0.0);

    for ni in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                let row = ((ni * ho + oy) * wo + ox) * d;
                let iy0 = (oy * stride) as isize - pad_top as isize;
                let ix0 = (ox * stride) as isize - pad_left as isize;
                for ci in 0..cin {
                    let base = row + ci * k * k;
                    for ky in 0..k {
                        let iy = iy0 + ky as isize;
                        if iy < 0 || iy >= h as isize {
                            continue; // zero padding
                        }
                        for kx in 0..k {
                            let ix = ix0 + kx as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            out[base + ky * k + kx] =
                                x.data[x.nhwc_offset(ni, iy as usize, ix as usize, ci)];
                        }
                    }
                }
            }
        }
    }
    (n * ho * wo, d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_padding_matches_tf() {
        assert_eq!(same_padding(5, 3, 1), (1, 1));
        assert_eq!(same_padding(5, 3, 2), (1, 1));
        assert_eq!(same_padding(4, 2, 2), (0, 0));
        assert_eq!(same_out_size(5, 2), 3);
    }

    #[test]
    fn center_patch_channel_major() {
        // 1x3x3x2 input with distinct values; center patch must be
        // [ch0 3x3 window..., ch1 3x3 window...]
        let x = Tensor::new(
            vec![1, 3, 3, 2],
            (0..18).map(|i| i as f32).collect(),
        );
        let p = im2col(&x, 3, 1);
        assert_eq!(p.shape, vec![9, 18]);
        let center = &p.data[4 * 18..5 * 18];
        let ch0: Vec<f32> = (0..9).map(|i| (i * 2) as f32).collect();
        let ch1: Vec<f32> = (0..9).map(|i| (i * 2 + 1) as f32).collect();
        assert_eq!(&center[..9], ch0.as_slice());
        assert_eq!(&center[9..], ch1.as_slice());
    }

    #[test]
    fn padding_zeros_at_corner() {
        let x = Tensor::new(vec![1, 2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]);
        let p = im2col(&x, 3, 1);
        // top-left output patch: the first row+col of the 3x3 window is pad
        let patch = &p.data[0..9];
        assert_eq!(patch[0], 0.0); // (-1,-1)
        assert_eq!(patch[4], 1.0); // center = x[0,0]
        assert_eq!(patch[8], 4.0); // (1,1) = x[1,1]
    }

    #[test]
    fn stride_two_shape() {
        let x = Tensor::zeros(vec![2, 8, 8, 4]);
        let p = im2col(&x, 3, 2);
        assert_eq!(p.shape, vec![2 * 4 * 4, 4 * 9]);
    }

    #[test]
    fn one_by_one_kernel_is_identity_rows() {
        let x = Tensor::new(vec![1, 2, 2, 3], (0..12).map(|i| i as f32).collect());
        let p = im2col(&x, 1, 1);
        assert_eq!(p.shape, vec![4, 3]);
        assert_eq!(p.data, x.data); // same ordering for 1x1
    }
}
