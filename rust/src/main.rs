//! `lutnn` — LUT-NN serving coordinator CLI (layer 3 leader binary).
//!
//! Subcommands:
//!   serve     start the TCP inference server on .lutnn bundles
//!   infer     one-shot inference from a bundle (native or pjrt engine)
//!   profile   per-layer kernel profile of a bundle: wall time, encode
//!             vs lookup-accumulate split, table bytes touched
//!   cost      print the paper's Table 2 (analytic GFLOPs / model size)
//!   import    parse an NNEF-style text graph into a dense .lutnn
//!             bundle (deterministic weights; see models/zoo/)
//!   convert   LUT-convert a dense bundle in rust (k-means on the fly)
//!   compile   LUT-compile a dense bundle with differentiable centroid
//!             learning (soft-argmin distillation, paper §3) — pass
//!             `synth` as the source for a built-in synthetic teacher,
//!             or a .nnef file to import-and-compile in one step
//!   inspect   dump a bundle's graph/layers/sizes
//!
//! Examples:
//!   lutnn serve --models artifacts --port 7070
//!   lutnn infer artifacts/resnet_tiny_lut.lutnn --batch 4
//!   lutnn profile artifacts/resnet_tiny_lut.lutnn --batch 4 --iters 20
//!   lutnn cost --k 16
//!   lutnn import models/zoo/cnn_tiny.nnef cnn_tiny.lutnn
//!   lutnn compile models/zoo/cnn_tiny.nnef compiled.lutnn --epochs 10
//!   lutnn compile synth compiled.lutnn --centroids 16 --epochs 10
//!   lutnn inspect artifacts/resnet_tiny_lut.lutnn

use anyhow::{anyhow, bail, Context, Result};
use lutnn::api::SessionBuilder;
use lutnn::coordinator::server::{Server, ServerConfig};
use lutnn::coordinator::{ModelEntry, Registry};
use lutnn::cost::{model_cost, LutConfig};
use lutnn::lut::LutOpts;
use lutnn::model_fmt;
use lutnn::model_import;
use lutnn::nn::graph::{Graph, LayerParams};
use lutnn::nn::models;
use lutnn::tensor::Tensor;
use lutnn::train::{self, TrainConfig};
use lutnn::util::benchmark::Table;
use lutnn::util::cli::Args;
use lutnn::util::prng::Prng;

fn main() {
    let args = Args::from_env();
    let result = match args.command.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("infer") => cmd_infer(&args),
        Some("profile") => cmd_profile(&args),
        Some("cost") => cmd_cost(&args),
        Some("import") => cmd_import(&args),
        Some("convert") => cmd_convert(&args),
        Some("compile") => cmd_compile(&args),
        Some("inspect") => cmd_inspect(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "lutnn — DNN inference by centroid learning and table lookup (MobiCom'23)

USAGE: lutnn <serve|infer|profile|cost|convert|compile|inspect> [flags]

  serve    --models <dir|bundle,...> [--port 7070] [--threads 4]
           [--replicas 1] [--max-batch 8] [--max-wait-ms 2]
           [--lazy] [--resident-budget <bytes>] [--profile]
           (--lazy registers bundles cold — header only — and pages each
            in on first request; --resident-budget bounds the bytes of
            paged-in lazy models, evicting LRU models back to disk, and
            implies --lazy; --profile records per-request stage spans,
            queryable over TCP with {{\"cmd\":\"spans\"}})
  infer    <bundle.lutnn> [--batch 1] [--iters 1] [--naive]
  profile  <bundle.lutnn> [--batch 1] [--iters 10] [--json]
  cost     [--k 16] [--v <override>]
  import   <graph.nnef> <out.lutnn>
  convert  <dense.lutnn> <out.lutnn> [--centroids 16] [--bits 8]
  compile  <dense.lutnn|graph.nnef|synth> <out.lutnn> [--centroids 16] [--bits 8]
           [--epochs 15] [--batch 64] [--samples 32] [--lr 0.005]
           [--t-lr 0.05] [--init-t 1.0] [--anneal 0.85] [--seed 0]
           [--threads 1] (distillation workers; results are
            deterministic per seed for any --threads > 1 count)
  inspect  <bundle.lutnn>"
    );
}

fn load_models(spec: &str) -> Result<Vec<(String, String)>> {
    // Returns (name, path) pairs from a dir or a comma list.
    let p = std::path::Path::new(spec);
    let mut out = Vec::new();
    if p.is_dir() {
        for entry in std::fs::read_dir(p)? {
            let path = entry?.path();
            if path.extension().map(|e| e == "lutnn").unwrap_or(false) {
                let name = path.file_stem().unwrap().to_string_lossy().into_owned();
                out.push((name, path.to_string_lossy().into_owned()));
            }
        }
        out.sort();
    } else {
        for part in spec.split(',') {
            let name = std::path::Path::new(part)
                .file_stem()
                .ok_or_else(|| anyhow!("bad model path '{part}'"))?
                .to_string_lossy()
                .into_owned();
            out.push((name, part.to_string()));
        }
    }
    if out.is_empty() {
        bail!("no .lutnn bundles found in '{spec}'");
    }
    Ok(out)
}

fn cmd_serve(args: &Args) -> Result<()> {
    let spec = args.get_or("models", "artifacts");
    let port = args.get_usize("port", 7070);
    let max_batch = args.get_usize("max-batch", 8);
    // N sessions per model from one shared bundle; the batcher runs one
    // work-stealing worker per replica. Registration stays at one
    // replica — Server::start grows every pool to the configured count
    // (one knob, exercised on the production path).
    let replicas = args.get_usize("replicas", 1).max(1);
    // --resident-budget only makes sense over lazy models (eager pools
    // are never evicted), so it implies --lazy.
    let resident_budget = args.get("resident-budget").and_then(|v| v.parse::<usize>().ok());
    let lazy = args.has("lazy") || resident_budget.is_some();
    let mut registry = Registry::new();
    for (name, path) in load_models(&spec)? {
        if lazy {
            // Header-only registration: tables stay on disk until the
            // first request for this model pages them in.
            let reg_name = registry
                .register_lazy(&path, LutOpts::deployed(), max_batch, 1)
                .with_context(|| format!("registering {path}"))?;
            println!("registered '{reg_name}' cold (header only, pages in on first request)");
        } else {
            let graph = model_fmt::load_bundle(&path)
                .with_context(|| format!("loading {path}"))?;
            println!(
                "registered '{name}' ({} params bytes, lut/dense = {:?}, {replicas} replica(s))",
                graph.param_bytes(),
                graph.lut_fraction()
            );
            registry.register(
                ModelEntry::native(&name, &graph, LutOpts::deployed(), max_batch, 1)
                    .with_context(|| format!("compiling session for {name}"))?,
            );
        }
    }
    // Alias by name (never resolve here: that would page a lazy model in
    // before the first request).
    if let Some(first_name) = registry.names().first().cloned() {
        registry.alias("default", &first_name);
    }
    let cfg = ServerConfig {
        addr: format!("127.0.0.1:{port}"),
        handler_threads: args.get_usize("threads", 4),
        replicas,
        resident_budget_bytes: resident_budget,
        batcher: lutnn::coordinator::batcher::BatcherConfig {
            max_batch,
            max_wait: std::time::Duration::from_millis(
                args.get_usize("max-wait-ms", 2) as u64,
            ),
            queue_cap: args.get_usize("queue-cap", 256),
            spans: None,
        },
        profile: args.has("profile"),
    };
    let server = Server::start(registry, cfg)?;
    println!("lutnn serving on {} — send {{\"cmd\":\"shutdown\"}} to stop", server.addr);
    // Block until the acceptor exits (shutdown command or signal).
    loop {
        std::thread::sleep(std::time::Duration::from_millis(200));
        if server.stopped() {
            break;
        }
    }
    Ok(())
}

/// Deterministic synthetic input batch matching the graph: normal
/// activations, or uniform token ids below the vocab for BERT bundles.
fn sample_input(graph: &Graph, batch: usize, seed: u64) -> Tensor {
    let mut shape = vec![batch];
    shape.extend_from_slice(&graph.input_shape[1..]);
    let n: usize = shape.iter().product();
    let mut rng = Prng::new(seed);
    match &graph.bert {
        Some(b) => Tensor::new(shape, (0..n).map(|_| rng.below(b.vocab) as f32).collect()),
        None => Tensor::new(shape, rng.normal_vec(n, 1.0)),
    }
}

fn cmd_infer(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("usage: lutnn infer <bundle.lutnn>"))?;
    let graph = model_fmt::load_bundle(path)?;
    let batch = args.get_usize("batch", 1);
    let iters = args.get_usize("iters", 1);
    let opts = if args.has("naive") { LutOpts::none() } else { LutOpts::deployed() };
    let x = sample_input(&graph, batch, 0);
    let mut session = SessionBuilder::new(&graph)
        .opts(opts)
        .max_batch(batch)
        .build()
        .context("compiling session")?;
    let mut out = Tensor::zeros(vec![0]);
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        session.run(&x, &mut out)?;
    }
    let dt = t0.elapsed().as_secs_f64() / iters as f64;
    println!(
        "model={} batch={batch} out_shape={:?} latency={:.3}ms",
        graph.name,
        out.shape,
        dt * 1e3
    );
    println!("logits[0] = {:?}", &out.data[..out.cols().min(16)]);
    println!("argmax = {:?}", out.argmax_rows());
    Ok(())
}

fn pct(part: u64, total: u64) -> f64 {
    if total == 0 {
        return 0.0;
    }
    part as f64 * 100.0 / total as f64
}

fn cmd_profile(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("usage: lutnn profile <bundle.lutnn>"))?;
    let graph = model_fmt::load_bundle(path)?;
    let batch = args.get_usize("batch", 1);
    let iters = args.get_usize("iters", 10).max(1);
    let x = sample_input(&graph, batch, 0);
    let mut session = SessionBuilder::new(&graph)
        .opts(LutOpts::deployed())
        .max_batch(batch)
        .profile(true)
        .build()
        .context("compiling session")?;
    let mut out = Tensor::zeros(vec![0]);
    for _ in 0..iters {
        session.run(&x, &mut out)?;
    }
    let p = session
        .profile_report()
        .ok_or_else(|| anyhow!("profiling was not enabled"))?;
    let total_ms = p.total_ns as f64 / 1e6;
    println!(
        "model={} batch={batch} iters={} total={total_ms:.3}ms ({:.3}ms/run)",
        graph.name,
        p.runs,
        total_ms / p.runs.max(1) as f64
    );
    let mut t = Table::new(&[
        "layer",
        "kernel",
        "rows",
        "wall ms",
        "encode ms",
        "lookup ms",
        "table KB",
        "% total",
    ]);
    for l in &p.layers {
        t.row(&[
            l.layer.clone(),
            l.kernel.into(),
            format!("{}", l.rows),
            format!("{:.3}", l.wall_ns as f64 / 1e6),
            format!("{:.3}", l.encode_ns as f64 / 1e6),
            format!("{:.3}", l.lookup_ns as f64 / 1e6),
            format!("{:.1}", l.table_bytes_touched as f64 / 1024.0),
            format!("{:.1}%", pct(l.wall_ns, p.total_ns)),
        ]);
    }
    t.row(&[
        "(other)".into(),
        "-".into(),
        "-".into(),
        format!("{:.3}", p.other_ns as f64 / 1e6),
        "-".into(),
        "-".into(),
        "-".into(),
        format!("{:.1}%", pct(p.other_ns, p.total_ns)),
    ]);
    t.print();
    // Per-layer walls plus untimed glue must account for the session
    // total; the gap is the timing overhead itself.
    let accounted = p.accounted_ns();
    println!(
        "accounted {:.3}ms of {total_ms:.3}ms ({:.1}%)",
        accounted as f64 / 1e6,
        pct(accounted, p.total_ns)
    );
    if args.has("json") {
        println!("{}", lutnn::util::json::to_string(&p.to_json()));
    }
    Ok(())
}

fn cmd_cost(args: &Args) -> Result<()> {
    let k = args.get_usize("k", 16);
    let v_override = args.get("v").and_then(|v| v.parse().ok());
    let cfg = LutConfig { k, v_override };
    let mut t = Table::new(&[
        "Model",
        "orig GFLOPs",
        "LUT GFLOPs",
        "reduction",
        "orig MB",
        "LUT MB",
        "size red.",
    ]);
    for m in models::all_paper_models() {
        let c = model_cost(&m, cfg);
        t.row(&[
            c.name.clone(),
            format!("{:.3}", c.dense_gflops),
            format!("{:.3}", c.lut_gflops),
            format!("{:.1}x", c.dense_gflops / c.lut_gflops),
            format!("{:.2}", c.dense_mb),
            format!("{:.2}", c.lut_mb),
            format!("{:.1}x", c.dense_mb / c.lut_mb),
        ]);
    }
    println!("LUT-NN analytic cost model (paper Tables 1-2), K={k}");
    t.print();
    Ok(())
}

fn cmd_import(args: &Args) -> Result<()> {
    let usage = "usage: lutnn import <graph.nnef> <out.lutnn>";
    let src = args.positional.first().ok_or_else(|| anyhow!("{usage}"))?;
    let dst = args.positional.get(1).ok_or_else(|| anyhow!("{usage}"))?;
    let graph = model_import::import_file(src)?;
    println!(
        "imported '{}': input {:?}, {} op(s), {} layer(s), {} param bytes",
        graph.name,
        graph.input_shape,
        graph.ops.len(),
        graph.layers.len(),
        graph.param_bytes()
    );
    model_fmt::save_bundle(&graph, dst)?;
    // Load-back + session smoke: the written bundle must round-trip
    // into a runnable session before we call the import good.
    let reloaded = model_fmt::load_bundle(dst)?;
    let mut session = SessionBuilder::new(&reloaded).build().context("compiling session")?;
    let x = sample_input(&reloaded, graph.input_shape[0].max(1), 0);
    let mut out = Tensor::zeros(vec![0]);
    session.run(&x, &mut out)?;
    println!("wrote {dst}; smoke run ok, out_shape={:?}", out.shape);
    Ok(())
}

fn cmd_convert(args: &Args) -> Result<()> {
    let src = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("usage: lutnn convert <in> <out>"))?;
    let dst = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("usage: lutnn convert <in> <out>"))?;
    let graph = model_fmt::load_bundle(src)?;
    let centroids = args.get_usize("centroids", 16);
    let bits = args.get_usize("bits", 8) as u8;
    // Synthetic calibration batch (rust-side conversion is meant for
    // benching; accuracy-preserving conversion happens in python training).
    let sample = sample_input(&graph, 32, 0);
    let lut = models::lutify_graph(&graph, &sample, centroids, bits, 0);
    model_fmt::save_bundle(&lut, dst)?;
    println!(
        "converted {} -> {} ({} -> {} param bytes)",
        src,
        dst,
        graph.param_bytes(),
        lut.param_bytes()
    );
    Ok(())
}

fn cmd_compile(args: &Args) -> Result<()> {
    let usage = "usage: lutnn compile <dense.lutnn|synth> <out.lutnn>";
    let src = args.positional.first().ok_or_else(|| anyhow!("{usage}"))?;
    let dst = args.positional.get(1).ok_or_else(|| anyhow!("{usage}"))?;
    let cfg = TrainConfig {
        epochs: args.get_usize("epochs", 15),
        batch_size: args.get_usize("batch", 64),
        lr: args.get_f64("lr", 5e-3) as f32,
        temperature_lr: args.get_f64("t-lr", 5e-2) as f32,
        init_t: args.get_f64("init-t", 1.0) as f32,
        anneal: args.get_f64("anneal", 0.85) as f32,
        seed: args.get_usize("seed", 0) as u64,
        threads: args.get_usize("threads", 1),
        ..TrainConfig::default()
    };
    let graph = if src == "synth" {
        // Built-in synthetic dense teacher (the CI smoke-test path and a
        // zero-setup way to try the compile pipeline).
        models::build_cnn_graph(
            "synthetic_teacher",
            [8, 8, 3],
            &[
                models::ConvSpec { cout: 8, k: 3, stride: 1 },
                models::ConvSpec { cout: 16, k: 3, stride: 2 },
            ],
            10,
            cfg.seed,
        )
    } else if src.ends_with(".nnef") {
        // Import-and-compile in one step.
        model_import::import_file(src)?
    } else {
        model_fmt::load_bundle(src)?
    };
    let centroids = args.get_usize("centroids", 16);
    let bits = args.get_usize("bits", 8) as u8;
    let samples = args.get_usize("samples", 32).max(1);

    // Synthetic calibration activations; point `--samples` higher (and
    // feed a real bundle) when compiling for deployment.
    let sample = sample_input(&graph, samples, cfg.seed);

    println!(
        "compiling '{}' (K={centroids}, {bits}-bit tables, {} epochs, t: {} x{}/epoch)",
        graph.name, cfg.epochs, cfg.init_t, cfg.anneal
    );
    let (compiled, reports) = train::compile_graph(&graph, &sample, centroids, bits, &cfg)?;
    let mut t = Table::new(&[
        "layer",
        "loss first",
        "loss last",
        "hard mse init",
        "hard mse final",
        "final t",
    ]);
    for r in &reports {
        let l = &r.report;
        t.row(&[
            r.name.clone(),
            format!("{:.5}", l.epoch_loss.first().copied().unwrap_or(f32::NAN)),
            format!("{:.5}", l.epoch_loss.last().copied().unwrap_or(f32::NAN)),
            format!("{:.5}", l.hard_mse_init),
            format!("{:.5}", l.hard_mse_final),
            format!("{:.4}", l.final_temperature),
        ]);
    }
    t.print();

    model_fmt::save_bundle(&compiled, dst)?;
    println!(
        "wrote {dst} ({} -> {} param bytes)",
        graph.param_bytes(),
        compiled.param_bytes()
    );
    // Load-back check: the compiled bundle must round-trip into a
    // runnable session (the acceptance gate of the compile path).
    let reloaded = model_fmt::load_bundle(dst)?;
    let mut session = SessionBuilder::new(&reloaded).build().context("compiling session")?;
    let mut out = Tensor::zeros(vec![0]);
    session.run(&sample, &mut out)?;
    println!("load check ok: {}", session.describe());
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("usage: lutnn inspect <bundle.lutnn>"))?;
    let graph = model_fmt::load_bundle(path)?;
    println!("model: {}", graph.name);
    println!("input_shape: {:?}", graph.input_shape);
    println!("ops: {}", graph.ops.len());
    if let Some(b) = &graph.bert {
        println!("bert: {b:?}");
    }
    let mut t = Table::new(&["layer", "kind", "bytes"]);
    for (name, l) in &graph.layers {
        let kind = match l {
            LayerParams::Dense { .. } => "dense",
            LayerParams::Lut(_) => "lut",
            LayerParams::Bn { .. } => "bn",
            LayerParams::Ln { .. } => "ln",
            LayerParams::Embedding { .. } => "embedding",
        };
        t.row(&[name.clone(), kind.into(), format!("{}", l.param_bytes())]);
    }
    t.print();
    println!("total param bytes: {}", graph.param_bytes());
    match SessionBuilder::new(&graph).build() {
        Ok(s) => println!("compiled: {}", s.describe()),
        Err(e) => println!("session compile failed: {e:#}"),
    }
    Ok(())
}
