//! Observability substrates for the serving stack.
//!
//! * [`SpanRing`] — a fixed-capacity ring buffer of per-request stage
//!   spans (queue → batch-form → execute → reply) with deterministic
//!   seeded sampling. Recording takes a short mutex hold on the ring
//!   plus two relaxed counters; the ring never allocates past its
//!   capacity, so an idle-to-overloaded server keeps the *latest*
//!   `capacity` spans rather than the first N.
//! * [`prom`] — a minimal prometheus text-exposition writer and a
//!   strict line-grammar parser used by the obs-smoke CI job to prove
//!   the exposition round-trips.
//!
//! Spans carry **durations, not timestamps**: tests construct synthetic
//! records with fixed microsecond values and never read the wall clock,
//! and the sampling decision is a pure function of `(seed, seq)` so any
//! run can be replayed.

pub mod prom;

use crate::util::json::Json;
use crate::util::prng::Prng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Terminal state of a request's span.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanOutcome {
    /// Executed and replied.
    Ok,
    /// Rejected at submit: the bounded injector queue was full.
    ShedQueueFull,
    /// Dropped at dequeue: its deadline expired while queued.
    ShedDeadline,
    /// Engine returned an error; the error was replied.
    Error,
}

impl SpanOutcome {
    pub fn as_str(&self) -> &'static str {
        match self {
            SpanOutcome::Ok => "ok",
            SpanOutcome::ShedQueueFull => "shed_queue_full",
            SpanOutcome::ShedDeadline => "shed_deadline",
            SpanOutcome::Error => "error",
        }
    }
}

/// One request's timeline through the batcher, as stage durations.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    /// Monotonic per-ring sequence number (assigned by [`SpanRing::record`]).
    pub seq: u64,
    /// Time from enqueue to being popped by a worker.
    pub queue_us: u64,
    /// Time from pop to the batch being formed (window wait + padding).
    pub batch_form_us: u64,
    /// Time inside `Engine::run_batch`.
    pub execute_us: u64,
    /// Time from execute-end to the reply being sent.
    pub reply_us: u64,
    /// Replica id that executed the batch, or `-1` if never executed.
    pub replica: i64,
    /// Number of live (admitted) requests in the executed batch.
    pub batch_size: u64,
    pub outcome: SpanOutcome,
}

impl SpanRecord {
    /// A zeroed shed/error span (no execution happened).
    pub fn unexecuted(outcome: SpanOutcome) -> SpanRecord {
        SpanRecord {
            seq: 0,
            queue_us: 0,
            batch_form_us: 0,
            execute_us: 0,
            reply_us: 0,
            replica: -1,
            batch_size: 0,
            outcome,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seq", Json::num(self.seq as f64)),
            ("queue_us", Json::num(self.queue_us as f64)),
            ("batch_form_us", Json::num(self.batch_form_us as f64)),
            ("execute_us", Json::num(self.execute_us as f64)),
            ("reply_us", Json::num(self.reply_us as f64)),
            ("replica", Json::num(self.replica as f64)),
            ("batch_size", Json::num(self.batch_size as f64)),
            ("outcome", Json::str(self.outcome.as_str())),
        ])
    }
}

/// Ring capacity, sampling rate and sampling seed.
#[derive(Clone, Copy, Debug)]
pub struct SpanConfig {
    /// Spans retained (oldest overwritten first). Clamped to >= 1.
    pub capacity: usize,
    /// Fraction of offered spans recorded, in `[0, 1]`. `1.0` keeps all.
    pub sample: f64,
    /// Seed for the per-sequence sampling decision.
    pub seed: u64,
}

impl Default for SpanConfig {
    fn default() -> Self {
        SpanConfig { capacity: 256, sample: 1.0, seed: 0 }
    }
}

struct RingInner {
    buf: Vec<SpanRecord>,
    /// Next write slot; equals `buf.len()` until the ring first fills.
    next: usize,
}

/// Fixed-capacity concurrent span recorder with seeded sampling.
pub struct SpanRing {
    inner: Mutex<RingInner>,
    capacity: usize,
    sample: f64,
    seed: u64,
    offered: AtomicU64,
    sampled: AtomicU64,
}

impl SpanRing {
    pub fn new(cfg: SpanConfig) -> SpanRing {
        let capacity = cfg.capacity.max(1);
        SpanRing {
            inner: Mutex::new(RingInner { buf: Vec::with_capacity(capacity), next: 0 }),
            capacity,
            sample: cfg.sample,
            seed: cfg.seed,
            offered: AtomicU64::new(0),
            sampled: AtomicU64::new(0),
        }
    }

    /// Offer a span. Assigns `seq`, applies the sampling decision
    /// (deterministic in `(seed, seq)`), and overwrites the oldest
    /// retained span once the ring is full.
    pub fn record(&self, mut span: SpanRecord) {
        let seq = self.offered.fetch_add(1, Ordering::Relaxed);
        span.seq = seq;
        if self.sample < 1.0 {
            let roll = Prng::new(self.seed ^ seq.wrapping_mul(0x9E37_79B9_7F4A_7C15)).uniform();
            if roll >= self.sample {
                return;
            }
        }
        self.sampled.fetch_add(1, Ordering::Relaxed);
        let mut g = self.inner.lock().expect("span ring poisoned");
        let i = g.next;
        if g.buf.len() < self.capacity {
            g.buf.push(span);
        } else {
            g.buf[i] = span;
        }
        g.next = (i + 1) % self.capacity;
    }

    /// Spans offered to the ring (sampled or not).
    pub fn offered(&self) -> u64 {
        self.offered.load(Ordering::Relaxed)
    }

    /// Spans actually retained at some point (may exceed capacity).
    pub fn sampled(&self) -> u64 {
        self.sampled.load(Ordering::Relaxed)
    }

    /// Retained spans, oldest first.
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        let g = self.inner.lock().expect("span ring poisoned");
        if g.buf.len() < self.capacity {
            g.buf.clone()
        } else {
            let mut out = Vec::with_capacity(g.buf.len());
            out.extend_from_slice(&g.buf[g.next..]);
            out.extend_from_slice(&g.buf[..g.next]);
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(queue_us: u64) -> SpanRecord {
        SpanRecord {
            seq: 0,
            queue_us,
            batch_form_us: 1,
            execute_us: 2,
            reply_us: 3,
            replica: 0,
            batch_size: 4,
            outcome: SpanOutcome::Ok,
        }
    }

    #[test]
    fn ring_keeps_latest_capacity_spans_in_order() {
        let ring = SpanRing::new(SpanConfig { capacity: 4, sample: 1.0, seed: 0 });
        for i in 0..10 {
            ring.record(span(i));
        }
        assert_eq!(ring.offered(), 10);
        assert_eq!(ring.sampled(), 10);
        let snap = ring.snapshot();
        let seqs: Vec<u64> = snap.iter().map(|s| s.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9]);
        let queues: Vec<u64> = snap.iter().map(|s| s.queue_us).collect();
        assert_eq!(queues, vec![6, 7, 8, 9]);
    }

    #[test]
    fn partial_ring_snapshots_in_insertion_order() {
        let ring = SpanRing::new(SpanConfig { capacity: 8, sample: 1.0, seed: 0 });
        for i in 0..3 {
            ring.record(span(i));
        }
        let seqs: Vec<u64> = ring.snapshot().iter().map(|s| s.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2]);
    }

    #[test]
    fn sampling_is_deterministic_in_seed_and_seq() {
        let mk = || SpanRing::new(SpanConfig { capacity: 1024, sample: 0.5, seed: 42 });
        let (a, b) = (mk(), mk());
        for i in 0..500 {
            a.record(span(i));
            b.record(span(i));
        }
        assert_eq!(a.sampled(), b.sampled());
        let sa: Vec<u64> = a.snapshot().iter().map(|s| s.seq).collect();
        let sb: Vec<u64> = b.snapshot().iter().map(|s| s.seq).collect();
        assert_eq!(sa, sb);
        // Roughly half retained; the decision is per-seq, not per-run.
        assert!(a.sampled() > 150 && a.sampled() < 350, "sampled {}", a.sampled());
        // A different seed keeps a different subset.
        let c = SpanRing::new(SpanConfig { capacity: 1024, sample: 0.5, seed: 7 });
        for i in 0..500 {
            c.record(span(i));
        }
        let sc: Vec<u64> = c.snapshot().iter().map(|s| s.seq).collect();
        assert_ne!(sa, sc);
    }

    #[test]
    fn zero_sampling_retains_nothing_but_counts_offers() {
        let ring = SpanRing::new(SpanConfig { capacity: 16, sample: 0.0, seed: 0 });
        for i in 0..20 {
            ring.record(span(i));
        }
        assert_eq!(ring.offered(), 20);
        assert_eq!(ring.sampled(), 0);
        assert!(ring.snapshot().is_empty());
    }

    #[test]
    fn span_json_has_all_fields() {
        let mut s = span(11);
        s.outcome = SpanOutcome::ShedDeadline;
        let j = crate::util::json::to_string(&s.to_json());
        assert!(j.contains("\"queue_us\":11"), "{j}");
        assert!(j.contains("\"outcome\":\"shed_deadline\""), "{j}");
    }
}
