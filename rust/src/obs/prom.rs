//! Prometheus text exposition: a tiny writer and a strict parser.
//!
//! The writer emits the subset of the exposition format the server
//! needs: `# HELP` / `# TYPE` comments (once per metric name) and
//! sample lines `name{label="value",...} value` with label-value
//! escaping of `\`, `"` and newline. No timestamps.
//!
//! The parser accepts exactly that grammar — metric names matching
//! `[a-zA-Z_:][a-zA-Z0-9_:]*`, label names `[a-zA-Z_][a-zA-Z0-9_]*`,
//! escaped double-quoted label values, and a finite or `Inf`/`NaN`
//! float value — and reports the line number of the first violation.
//! CI uses it to prove the server's exposition round-trips.

use std::collections::BTreeSet;
use std::fmt::Write as _;

/// One parsed sample line.
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

impl Sample {
    /// The value of label `key`, if present.
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

/// Incremental exposition-text builder.
pub struct PromWriter {
    out: String,
    seen: BTreeSet<String>,
}

impl Default for PromWriter {
    fn default() -> Self {
        PromWriter::new()
    }
}

impl PromWriter {
    pub fn new() -> PromWriter {
        PromWriter { out: String::new(), seen: BTreeSet::new() }
    }

    /// Emit `# HELP` / `# TYPE` for `name` the first time it is seen.
    pub fn metric(&mut self, name: &str, mtype: &str, help: &str) {
        if self.seen.insert(name.to_string()) {
            let _ = writeln!(self.out, "# HELP {name} {help}");
            let _ = writeln!(self.out, "# TYPE {name} {mtype}");
        }
    }

    /// Emit one sample line.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(k);
                self.out.push_str("=\"");
                self.out.push_str(&escape_label(v));
                self.out.push('"');
            }
            self.out.push('}');
        }
        let _ = writeln!(self.out, " {value}");
    }

    pub fn finish(self) -> String {
        self.out
    }
}

fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Parse exposition text into samples; errors carry 1-based line numbers.
pub fn parse(text: &str) -> Result<Vec<Sample>, String> {
    let mut out = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        match parse_sample(line) {
            Ok(s) => out.push(s),
            Err(e) => return Err(format!("line {}: {e}", ln + 1)),
        }
    }
    Ok(out)
}

fn is_name_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_' || c == ':'
}

fn is_name_char(c: char) -> bool {
    is_name_start(c) || c.is_ascii_digit()
}

fn parse_sample(line: &str) -> Result<Sample, String> {
    let chars: Vec<char> = line.chars().collect();
    let mut i = 0;
    if chars.is_empty() || !is_name_start(chars[0]) {
        return Err(format!("metric name must start with [a-zA-Z_:] in {line:?}"));
    }
    while i < chars.len() && is_name_char(chars[i]) {
        i += 1;
    }
    let name: String = chars[..i].iter().collect();
    let mut labels = Vec::new();
    if i < chars.len() && chars[i] == '{' {
        i += 1;
        loop {
            while i < chars.len() && chars[i] == ' ' {
                i += 1;
            }
            if i < chars.len() && chars[i] == '}' {
                i += 1;
                break;
            }
            let start = i;
            if i >= chars.len() || !(chars[i].is_ascii_alphabetic() || chars[i] == '_') {
                return Err(format!("label name must start with [a-zA-Z_] in {name}"));
            }
            while i < chars.len() && (chars[i].is_ascii_alphanumeric() || chars[i] == '_') {
                i += 1;
            }
            let key: String = chars[start..i].iter().collect();
            if i >= chars.len() || chars[i] != '=' {
                return Err(format!("expected '=' after label {key:?}"));
            }
            i += 1;
            if i >= chars.len() || chars[i] != '"' {
                return Err(format!("expected opening '\"' for label {key:?}"));
            }
            i += 1;
            let mut val = String::new();
            loop {
                if i >= chars.len() {
                    return Err(format!("unterminated value for label {key:?}"));
                }
                match chars[i] {
                    '"' => {
                        i += 1;
                        break;
                    }
                    '\\' => {
                        match chars.get(i + 1) {
                            Some('\\') => val.push('\\'),
                            Some('"') => val.push('"'),
                            Some('n') => val.push('\n'),
                            other => {
                                return Err(format!("bad escape {other:?} in label {key:?}"))
                            }
                        }
                        i += 2;
                    }
                    c => {
                        val.push(c);
                        i += 1;
                    }
                }
            }
            labels.push((key, val));
            while i < chars.len() && chars[i] == ' ' {
                i += 1;
            }
            match chars.get(i) {
                Some(',') => i += 1,
                Some('}') => {
                    i += 1;
                    break;
                }
                _ => return Err(format!("expected ',' or '}}' in label set of {name}")),
            }
        }
    }
    let rest: String = chars[i..].iter().collect();
    let rest = rest.trim();
    if rest.is_empty() {
        return Err(format!("missing value for metric {name:?}"));
    }
    let value = match rest {
        "Inf" | "+Inf" => f64::INFINITY,
        "-Inf" => f64::NEG_INFINITY,
        "NaN" => f64::NAN,
        _ => rest.parse::<f64>().map_err(|_| format!("bad value {rest:?} for {name:?}"))?,
    };
    Ok(Sample { name, labels, value })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writer_output_round_trips_through_parser() {
        let mut w = PromWriter::new();
        w.metric("lutnn_requests_total", "counter", "Requests replied");
        w.sample("lutnn_requests_total", &[("model", "cnn_tiny")], 42.0);
        w.sample("lutnn_requests_total", &[("model", "weird\"name\\x")], 1.0);
        w.metric("lutnn_latency_seconds", "summary", "Request latency");
        w.sample(
            "lutnn_latency_seconds",
            &[("model", "cnn_tiny"), ("quantile", "0.5")],
            0.00125,
        );
        w.sample("lutnn_latency_seconds_count", &[("model", "cnn_tiny")], 42.0);
        let text = w.finish();
        let samples = parse(&text).expect("round-trip parse");
        assert_eq!(samples.len(), 4);
        assert_eq!(samples[0].name, "lutnn_requests_total");
        assert_eq!(samples[0].label("model"), Some("cnn_tiny"));
        assert_eq!(samples[0].value, 42.0);
        assert_eq!(samples[1].label("model"), Some("weird\"name\\x"));
        assert_eq!(samples[2].label("quantile"), Some("0.5"));
        assert_eq!(samples[2].value, 0.00125);
    }

    #[test]
    fn help_and_type_emitted_once_per_name() {
        let mut w = PromWriter::new();
        w.metric("m_total", "counter", "a counter");
        w.metric("m_total", "counter", "a counter");
        w.sample("m_total", &[], 1.0);
        let text = w.finish();
        assert_eq!(text.matches("# HELP m_total").count(), 1);
        assert_eq!(text.matches("# TYPE m_total").count(), 1);
    }

    #[test]
    fn parser_rejects_bad_lines_with_line_numbers() {
        let bad = [
            "ok_metric 1\n0bad 2",
            "name{label=\"unterminated} 1",
            "name{=\"x\"} 1",
            "name_no_value",
            "name twelve",
            "name{l=\"v\" extra} 1",
        ];
        for text in bad {
            let err = parse(text).expect_err(text);
            assert!(err.starts_with("line "), "{err}");
        }
        assert_eq!(parse("ok_metric 1\n0bad 2").unwrap_err().split(':').next(), Some("line 2"));
    }

    #[test]
    fn parser_accepts_comments_blanks_and_inf() {
        let text = "# HELP x y\n\nx{a=\"b\"} +Inf\nx 3e-4\n";
        let s = parse(text).unwrap();
        assert_eq!(s.len(), 2);
        assert!(s[0].value.is_infinite());
        assert_eq!(s[1].value, 3e-4);
    }
}
