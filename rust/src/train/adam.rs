//! Adam optimizer (hand-rolled, mirroring `python/compile/optim.py`).
//!
//! One [`Adam`] instance owns the first/second-moment state for one
//! parameter group; the paper trains centroids and the temperature with
//! *different* learning rates (Table 3: centroid LR 1e-3/1e-4,
//! temperature LR 1e-1), which callers express through the `lr_scale`
//! argument of [`Adam::step_scaled`] — the effective step size is
//! `lr * lr_scale`, exactly the per-leaf scaling of optim.py.

/// Hyper-parameters shared by every group.
#[derive(Debug, Clone, Copy)]
pub struct AdamConfig {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl Default for AdamConfig {
    fn default() -> AdamConfig {
        AdamConfig { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }
}

/// Adam state for one flat parameter group.
#[derive(Debug, Clone)]
pub struct Adam {
    cfg: AdamConfig,
    m: Vec<f32>,
    v: Vec<f32>,
    step: u32,
}

impl Adam {
    pub fn new(n_params: usize, cfg: AdamConfig) -> Adam {
        Adam { cfg, m: vec![0.0; n_params], v: vec![0.0; n_params], step: 0 }
    }

    /// Steps taken so far.
    pub fn steps(&self) -> u32 {
        self.step
    }

    /// One update at the base learning rate.
    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        self.step_scaled(params, grads, 1.0);
    }

    /// One update with effective LR `cfg.lr * lr_scale` (the Table 3
    /// two-rate setup: the temperature group passes
    /// `temperature_lr / lr`). Bias correction matches optim.py:
    /// `p -= lr_eff * (m / (1 - b1^t)) / (sqrt(v / (1 - b2^t)) + eps)`.
    pub fn step_scaled(&mut self, params: &mut [f32], grads: &[f32], lr_scale: f32) {
        assert_eq!(params.len(), self.m.len(), "parameter group size changed");
        assert_eq!(grads.len(), self.m.len(), "gradient size mismatch");
        self.step += 1;
        let AdamConfig { lr, beta1, beta2, eps } = self.cfg;
        let bc1 = 1.0 - beta1.powi(self.step as i32);
        let bc2 = 1.0 - beta2.powi(self.step as i32);
        let lr_eff = lr * lr_scale;
        for ((p, &g), (m, v)) in params
            .iter_mut()
            .zip(grads)
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            *m = beta1 * *m + (1.0 - beta1) * g;
            *v = beta2 * *v + (1.0 - beta2) * g * g;
            let m_hat = *m / bc1;
            let v_hat = *v / bc2;
            *p -= lr_eff * m_hat / (v_hat.sqrt() + eps);
        }
    }
}

/// Clip a set of gradient groups to a shared global L2 norm (optim.py's
/// `grad_clip`): every group is scaled by `min(1, clip / ||g||_2)`.
/// Returns the pre-clip norm.
pub fn clip_global_norm(groups: &mut [&mut [f32]], clip: f32) -> f32 {
    let mut sq = 0.0f64;
    for g in groups.iter() {
        for &x in g.iter() {
            sq += x as f64 * x as f64;
        }
    }
    let norm = sq.sqrt() as f32;
    if clip > 0.0 && norm > clip {
        let factor = clip / norm;
        for g in groups.iter_mut() {
            for x in g.iter_mut() {
                *x *= factor;
            }
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_on_quadratic() {
        // min_x sum (x - target)^2 — Adam at lr 0.1 gets there fast.
        let target = [3.0f32, -1.5, 0.25];
        let mut x = [0.0f32; 3];
        let mut opt = Adam::new(3, AdamConfig { lr: 0.1, ..AdamConfig::default() });
        for _ in 0..500 {
            let grads: Vec<f32> = x.iter().zip(&target).map(|(&p, &t)| 2.0 * (p - t)).collect();
            opt.step(&mut x, &grads);
        }
        for (p, t) in x.iter().zip(&target) {
            assert!((p - t).abs() < 1e-2, "{x:?}");
        }
        assert_eq!(opt.steps(), 500);
    }

    #[test]
    fn lr_scale_speeds_up_a_group() {
        // Same problem, one group at 10x the base rate: after a few
        // steps the scaled group must be strictly closer to its target.
        let mut slow = [0.0f32];
        let mut fast = [0.0f32];
        let cfg = AdamConfig { lr: 1e-2, ..AdamConfig::default() };
        let mut opt_s = Adam::new(1, cfg);
        let mut opt_f = Adam::new(1, cfg);
        for _ in 0..20 {
            let gs = [2.0 * (slow[0] - 5.0)];
            let gf = [2.0 * (fast[0] - 5.0)];
            opt_s.step_scaled(&mut slow, &gs, 1.0);
            opt_f.step_scaled(&mut fast, &gf, 10.0);
        }
        assert!((fast[0] - 5.0).abs() < (slow[0] - 5.0).abs(), "{fast:?} vs {slow:?}");
    }

    #[test]
    fn deterministic_across_runs() {
        let run = || {
            let mut x = [1.0f32, -2.0];
            let mut opt = Adam::new(2, AdamConfig::default());
            for i in 0..50 {
                let g = [x[0] * 0.3 + i as f32 * 1e-3, x[1] - 0.5];
                opt.step(&mut x, &g);
            }
            x
        };
        let (a, b) = (run(), run());
        assert_eq!(a[0].to_bits(), b[0].to_bits());
        assert_eq!(a[1].to_bits(), b[1].to_bits());
    }

    #[test]
    fn clip_bounds_global_norm() {
        let mut a = [3.0f32, 0.0];
        let mut b = [0.0f32, 4.0];
        let norm = clip_global_norm(&mut [&mut a[..], &mut b[..]], 1.0);
        assert!((norm - 5.0).abs() < 1e-6);
        let clipped: f32 = a.iter().chain(b.iter()).map(|x| x * x).sum();
        assert!((clipped.sqrt() - 1.0).abs() < 1e-5);
        // below the threshold: untouched
        let mut c = [0.3f32];
        clip_global_norm(&mut [&mut c[..]], 1.0);
        assert_eq!(c[0], 0.3);
    }
}
