//! # Native differentiable centroid learning (paper §3)
//!
//! The rust-side compile path: everything needed to *produce* a LUT-NN
//! model — not just execute one — without Python in the loop.
//!
//! * [`softpq`] — the differentiable soft-argmin layer: a temperature-
//!   scaled softmax over negative centroid distances (Eq. 5) with
//!   hand-derived reverse-mode gradients for the centroids, the learned
//!   log-temperature (§3.2) and, optionally, a decoupled output table.
//! * [`adam`] — the Adam optimizer with per-group learning-rate scaling
//!   (Table 3 trains centroids and temperature at different rates) and
//!   global-norm gradient clipping.
//! * [`distill`] — the calibration loop: k-means-initialize (Eq. 1),
//!   minimize soft-forward MSE against the dense teacher on activation
//!   batches, anneal the temperature toward the hard argmin, freeze
//!   into `lut::LutLinear`, and [`compile_graph`] a whole dense teacher
//!   into a bundle-exportable LUT [`crate::nn::graph::Graph`].
//!
//! ## End-to-end
//!
//! ```ignore
//! let (compiled, reports) =
//!     train::compile_graph(&dense_graph, &calibration, 16, 8, &TrainConfig::default())?;
//! model_fmt::save_bundle(&compiled, "model_compiled.lutnn")?;   // -> api::Session loads it
//! ```
//!
//! The CLI front-end is `lutnn compile`; `examples/train_centroids.rs`
//! walks the same pipeline in-process. Temperature schedule: start soft
//! (`init_t`, default 1.0), decay by `anneal` per epoch down to `min_t`
//! while `temperature_lr` lets backprop adjust along the way; as
//! `t -> 0` the soft encoder agrees with the deployed hard argmin
//! (pinned at >= 99% of positions by the parity test in [`softpq`]).
//!
//! Scope: layer-wise distillation on calibration activations. Task-level
//! fine-tuning on real datasets (labels, data augmentation, QAT
//! ablations, BERT) stays in `python/compile/train.py`.

pub mod adam;
pub mod distill;
pub mod softpq;

pub use adam::{clip_global_norm, Adam, AdamConfig};
pub use distill::{compile_graph, distill_layer, DistillReport, LayerReport, TrainConfig};
pub use softpq::{soft_argmax, SoftForward, SoftPqGrads, SoftPqLayer};
