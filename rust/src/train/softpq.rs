//! Soft-PQ: the differentiable centroid-learning layer (paper §3).
//!
//! Mirrors `python/compile/softpq.py` in native rust: the encoder is a
//! temperature-scaled softmax over *negative* squared distances to each
//! codebook's centroids (Eq. 5),
//!
//! ```text
//!   g[n, c, k] = softmax_k( -|a_sub[n,c] - P[c,k]|^2 / t )
//!   out[n, m]  = sum_c  g[n, c, :] . T[c, :, m]  + bias[m]
//! ```
//!
//! and the whole pipeline is differentiable in the centroids `P`, the
//! log-temperature `log t` (§3.2 learned temperature — stored in log
//! space so `t > 0` always) and, optionally, the output table `T`
//! itself. By default the table is *rebuilt from the frozen dense
//! weight every step* (`T[c,k] = P[c,k] . B^c`, paper Fig. 4), so table
//! gradients flow back into the centroids; with
//! [`SoftPqLayer::decouple_table`] the table becomes a free parameter
//! (the Deep Lookup Network style of end-to-end learned tables), which
//! enables deploy-time adaptation when the dense weight is unavailable.
//!
//! As `t -> 0` the softmax collapses onto the closest centroid, so the
//! soft encoder's argmax converges to the hard argmin encode the
//! inference engine (`lut::LutLinear::encode_into`) executes — softmax
//! is order-preserving, so they agree everywhere except FP near-ties.
//! The parity test below pins agreement at >= 99% of positions.
//!
//! All gradients are hand-derived reverse mode (no autodiff substrate in
//! this crate); the finite-difference tests below are the contract.

use std::sync::Mutex;

use crate::lut::LutLinear;
use crate::pq::{build_table, quantize_table, Codebooks};
use crate::util::threadpool::parallel_items;

/// Fixed row-block size the multithreaded forward/backward paths shard
/// minibatches on. The f32 summation *grouping* of the parallel
/// backward is a function of this constant alone — never of the thread
/// count — so `threads = 2` and `threads = 8` produce bit-identical
/// gradients on any machine (`threads = 1` keeps the legacy ungrouped
/// path and may differ in final ulps).
pub const MT_ROW_BLOCK: usize = 32;

/// Trainable state of one LUT-replaced linear operator.
///
/// `cb` (centroids) and `log_t` train; `weight`/`bias` are frozen; the
/// optional `table` trains only after [`SoftPqLayer::decouple_table`].
#[derive(Debug, Clone)]
pub struct SoftPqLayer {
    /// centroids [C, K, V] — trainable
    pub cb: Codebooks,
    /// log of the softmax temperature — trainable (§3.2)
    pub log_t: f32,
    /// frozen dense weight [D, M] the table is rebuilt from
    pub weight: Vec<f32>,
    /// frozen bias [M]
    pub bias: Option<Vec<f32>>,
    /// output features M
    pub m: usize,
    /// decoupled trainable table [C, K, M]; `None` = rebuilt from
    /// `weight` every forward (paper Fig. 4)
    pub table: Option<Vec<f32>>,
}

/// Cached intermediates of one soft forward pass, consumed by
/// [`SoftPqLayer::backward`].
#[derive(Debug, Clone)]
pub struct SoftForward {
    /// squared distances [n, C, K]
    pub dist: Vec<f32>,
    /// soft assignments (softmax over -dist/t) [n, C, K]
    pub soft: Vec<f32>,
    /// table rebuilt from the frozen weight for this pass [C, K, M];
    /// `None` when the layer's own decoupled table was used (backward
    /// borrows it from the layer instead of cloning per minibatch)
    pub table: Option<Vec<f32>>,
    /// layer output [n, M]
    pub out: Vec<f32>,
}

/// Gradients of a scalar loss w.r.t. the trainable parameters.
#[derive(Debug, Clone)]
pub struct SoftPqGrads {
    /// d loss / d centroids [C, K, V]
    pub centroids: Vec<f32>,
    /// d loss / d log_t
    pub log_t: f32,
    /// d loss / d table [C, K, M] — `Some` only for a decoupled table
    pub table: Option<Vec<f32>>,
}

impl SoftPqLayer {
    /// Wrap k-means-initialized codebooks + a frozen dense operator.
    pub fn new(
        cb: Codebooks,
        weight: Vec<f32>,
        bias: Option<Vec<f32>>,
        m: usize,
        init_t: f32,
    ) -> SoftPqLayer {
        assert_eq!(weight.len(), cb.input_dim() * m, "weight must be [D, M]");
        if let Some(b) = &bias {
            assert_eq!(b.len(), m, "bias must be [M]");
        }
        assert!(init_t > 0.0, "temperature must be positive");
        SoftPqLayer { cb, log_t: init_t.ln(), weight, bias, m, table: None }
    }

    /// Detach the table from the frozen weight: from now on `T` is a
    /// free trainable parameter initialized at its current rebuilt
    /// value, and centroid gradients stop flowing through it.
    pub fn decouple_table(&mut self) {
        if self.table.is_none() {
            self.table = Some(build_table(&self.cb, &self.weight, self.m));
        }
    }

    /// Current softmax temperature `t = exp(log_t)`.
    pub fn temperature(&self) -> f32 {
        self.log_t.exp()
    }

    /// Override the temperature (annealing schedules drive this).
    pub fn set_temperature(&mut self, t: f32) {
        assert!(t > 0.0, "temperature must be positive");
        self.log_t = t.ln();
    }

    /// The table this layer currently computes with: the decoupled
    /// parameter if present, else `P . B` rebuilt from the frozen weight.
    pub fn current_table(&self) -> Vec<f32> {
        match &self.table {
            Some(t) => t.clone(),
            None => build_table(&self.cb, &self.weight, self.m),
        }
    }

    /// Soft encode rows of `a` ([n, D]): distances and softmax
    /// assignments, written into caller-owned buffers.
    pub fn soft_encode(&self, a: &[f32], n: usize, dist: &mut Vec<f32>, soft: &mut Vec<f32>) {
        let (c_total, k, v) = (self.cb.c, self.cb.k, self.cb.v);
        let d = self.cb.input_dim();
        assert_eq!(a.len(), n * d);
        let t = self.temperature();
        dist.clear();
        dist.resize(n * c_total * k, 0.0);
        soft.clear();
        soft.resize(n * c_total * k, 0.0);
        for i in 0..n {
            for c in 0..c_total {
                let sub = &a[i * d + c * v..i * d + (c + 1) * v];
                let base = (i * c_total + c) * k;
                for kk in 0..k {
                    let cent = self.cb.centroid(c, kk);
                    let mut s = 0.0f32;
                    for (x, p) in sub.iter().zip(cent) {
                        let diff = x - p;
                        s += diff * diff;
                    }
                    dist[base + kk] = s;
                }
                softmax_neg_scaled(&dist[base..base + k], t, &mut soft[base..base + k]);
            }
        }
    }

    /// The table a forward pass computed with: the pass's own rebuilt
    /// copy, or the layer's decoupled parameter.
    fn pass_table<'a>(&'a self, fwd: &'a SoftForward) -> &'a [f32] {
        match &fwd.table {
            Some(t) => t,
            None => self.table.as_deref().expect("decoupled pass must come from this layer"),
        }
    }

    /// Soft forward pass (the `hard=False` relaxation of softpq.py),
    /// returning every intermediate the backward pass needs.
    pub fn forward(&self, a: &[f32], n: usize) -> SoftForward {
        let rebuilt = match &self.table {
            Some(_) => None,
            None => Some(build_table(&self.cb, &self.weight, self.m)),
        };
        let table: &[f32] = match (&self.table, &rebuilt) {
            (Some(t), _) => t,
            (None, Some(t)) => t,
            (None, None) => unreachable!(),
        };
        let (dist, soft, out) = self.forward_rows(a, n, table);
        SoftForward { dist, soft, table: rebuilt, out }
    }

    /// [`SoftPqLayer::forward`] with the minibatch sharded into
    /// [`MT_ROW_BLOCK`]-row blocks across `threads` pool threads. Every
    /// row's math is independent and block results are stitched back in
    /// row order, so the result is **bitwise identical** to the
    /// sequential forward for any thread count. `threads <= 1` falls
    /// back to the plain path without spawning.
    pub fn forward_mt(&self, a: &[f32], n: usize, threads: usize) -> SoftForward {
        if threads <= 1 || n <= MT_ROW_BLOCK {
            return self.forward(a, n);
        }
        let (c_total, k) = (self.cb.c, self.cb.k);
        let d = self.cb.input_dim();
        let m = self.m;
        assert_eq!(a.len(), n * d);
        let rebuilt = match &self.table {
            Some(_) => None,
            None => Some(build_table(&self.cb, &self.weight, self.m)),
        };
        let table: &[f32] = match (&self.table, &rebuilt) {
            (Some(t), _) => t,
            (None, Some(t)) => t,
            (None, None) => unreachable!(),
        };
        let blocks = n.div_ceil(MT_ROW_BLOCK);
        let slots: Mutex<Vec<Option<(Vec<f32>, Vec<f32>, Vec<f32>)>>> =
            Mutex::new(vec![None; blocks]);
        parallel_items(blocks, threads, |b| {
            let lo = b * MT_ROW_BLOCK;
            let hi = ((b + 1) * MT_ROW_BLOCK).min(n);
            let part = self.forward_rows(&a[lo * d..hi * d], hi - lo, table);
            slots.lock().unwrap()[b] = Some(part);
        });
        let mut dist = Vec::with_capacity(n * c_total * k);
        let mut soft = Vec::with_capacity(n * c_total * k);
        let mut out = Vec::with_capacity(n * m);
        for slot in slots.into_inner().unwrap() {
            let (bd, bs, bo) = slot.expect("every forward block ran");
            dist.extend_from_slice(&bd);
            soft.extend_from_slice(&bs);
            out.extend_from_slice(&bo);
        }
        SoftForward { dist, soft, table: rebuilt, out }
    }

    /// Row-range core of the forward pass against an already-resolved
    /// table: soft encode + table accumulate + bias for `n` rows of `a`.
    fn forward_rows(&self, a: &[f32], n: usize, table: &[f32]) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let (c_total, k) = (self.cb.c, self.cb.k);
        let m = self.m;
        let mut dist = Vec::new();
        let mut soft = Vec::new();
        self.soft_encode(a, n, &mut dist, &mut soft);
        let mut out = vec![0.0f32; n * m];
        for i in 0..n {
            let dst = &mut out[i * m..(i + 1) * m];
            for c in 0..c_total {
                let g = &soft[(i * c_total + c) * k..(i * c_total + c + 1) * k];
                for (kk, &w) in g.iter().enumerate() {
                    if w == 0.0 {
                        continue;
                    }
                    let row = &table[(c * k + kk) * m..(c * k + kk + 1) * m];
                    for (o, &tv) in dst.iter_mut().zip(row) {
                        *o += w * tv;
                    }
                }
            }
            if let Some(b) = &self.bias {
                for (o, &bv) in dst.iter_mut().zip(b) {
                    *o += bv;
                }
            }
        }
        (dist, soft, out)
    }

    /// Reverse-mode gradients for `dout = d loss / d out` ([n, M]).
    ///
    /// Chain, per (row, codebook): with logits `z = -dist / t`,
    ///   `dT[c,k,:]  = sum_n g[n,c,k] * dout[n,:]`
    ///   `dg[k]      = dout . T[c,k,:]`
    ///   `dz[k]      = g[k] * (dg[k] - sum_j g[j] dg[j])`   (softmax JVP)
    ///   `d dist[k]  = -dz[k] / t`
    ///   `d log_t   += sum_k dz[k] * dist[k] / t`
    ///   `dP[c,k,v] += d dist[k] * -2 (a_sub[v] - P[c,k,v])`
    /// and, unless the table is decoupled, `dT` folds into `dP` through
    /// `T[c,k,m] = sum_v P[c,k,v] * B[c*V+v, m]`.
    pub fn backward(&self, a: &[f32], n: usize, fwd: &SoftForward, dout: &[f32]) -> SoftPqGrads {
        let table = self.pass_table(fwd);
        let (d_table, d_cent, d_log_t) =
            self.backward_rows(a, n, &fwd.dist, &fwd.soft, dout, table);
        self.finish_grads(d_table, d_cent, d_log_t)
    }

    /// [`SoftPqLayer::backward`] with per-row work sharded into
    /// [`MT_ROW_BLOCK`]-row blocks across `threads` pool threads. Each
    /// block accumulates its own partial `dT`/`dP`/`d log_t`; partials
    /// are then reduced **sequentially in block order**, so the result
    /// depends only on `MT_ROW_BLOCK` — never on the thread count or on
    /// scheduling. It may differ from the `threads = 1` path in final
    /// ulps (different f32 summation grouping); `threads <= 1` falls
    /// back to the legacy exact path without spawning.
    pub fn backward_mt(
        &self,
        a: &[f32],
        n: usize,
        fwd: &SoftForward,
        dout: &[f32],
        threads: usize,
    ) -> SoftPqGrads {
        if threads <= 1 || n <= MT_ROW_BLOCK {
            return self.backward(a, n, fwd, dout);
        }
        let (c_total, k, v) = (self.cb.c, self.cb.k, self.cb.v);
        let d = self.cb.input_dim();
        let m = self.m;
        assert_eq!(a.len(), n * d);
        assert_eq!(dout.len(), n * m);
        let table = self.pass_table(fwd);
        let blocks = n.div_ceil(MT_ROW_BLOCK);
        let slots: Mutex<Vec<Option<(Vec<f32>, Vec<f32>, f64)>>> = Mutex::new(vec![None; blocks]);
        parallel_items(blocks, threads, |b| {
            let lo = b * MT_ROW_BLOCK;
            let hi = ((b + 1) * MT_ROW_BLOCK).min(n);
            let part = self.backward_rows(
                &a[lo * d..hi * d],
                hi - lo,
                &fwd.dist[lo * c_total * k..hi * c_total * k],
                &fwd.soft[lo * c_total * k..hi * c_total * k],
                &dout[lo * m..hi * m],
                table,
            );
            slots.lock().unwrap()[b] = Some(part);
        });
        let mut d_table = vec![0.0f32; c_total * k * m];
        let mut d_cent = vec![0.0f32; c_total * k * v];
        let mut d_log_t = 0.0f64;
        for slot in slots.into_inner().unwrap() {
            let (bt, bc, bl) = slot.expect("every backward block ran");
            for (acc, &x) in d_table.iter_mut().zip(&bt) {
                *acc += x;
            }
            for (acc, &x) in d_cent.iter_mut().zip(&bc) {
                *acc += x;
            }
            d_log_t += bl;
        }
        self.finish_grads(d_table, d_cent, d_log_t)
    }

    /// Row-range core of the backward pass: raw `dT`/`dP`/`d log_t`
    /// accumulated over `n` rows, *before* the rebuilt-table fold.
    fn backward_rows(
        &self,
        a: &[f32],
        n: usize,
        dist_all: &[f32],
        soft_all: &[f32],
        dout: &[f32],
        table: &[f32],
    ) -> (Vec<f32>, Vec<f32>, f64) {
        let (c_total, k, v) = (self.cb.c, self.cb.k, self.cb.v);
        let d = self.cb.input_dim();
        let m = self.m;
        assert_eq!(a.len(), n * d);
        assert_eq!(dout.len(), n * m);
        let t = self.temperature();

        let mut d_table = vec![0.0f32; c_total * k * m];
        let mut d_cent = vec![0.0f32; c_total * k * v];
        let mut d_log_t = 0.0f64;
        let mut dg = vec![0.0f32; k];
        let mut dz = vec![0.0f32; k];

        for i in 0..n {
            let dorow = &dout[i * m..(i + 1) * m];
            for c in 0..c_total {
                let base = (i * c_total + c) * k;
                let g = &soft_all[base..base + k];
                let dist = &dist_all[base..base + k];
                for (kk, dgk) in dg.iter_mut().enumerate() {
                    let row = &table[(c * k + kk) * m..(c * k + kk + 1) * m];
                    let mut s = 0.0f32;
                    for (&o, &tv) in dorow.iter().zip(row) {
                        s += o * tv;
                    }
                    *dgk = s;
                    let gw = g[kk];
                    if gw != 0.0 {
                        let trow = &mut d_table[(c * k + kk) * m..(c * k + kk + 1) * m];
                        for (td, &o) in trow.iter_mut().zip(dorow) {
                            *td += gw * o;
                        }
                    }
                }
                let mut sdot = 0.0f32;
                for (gw, dgk) in g.iter().zip(&dg) {
                    sdot += gw * dgk;
                }
                for ((zk, &gw), &dgk) in dz.iter_mut().zip(g).zip(&dg) {
                    *zk = gw * (dgk - sdot);
                }
                let sub = &a[i * d + c * v..i * d + (c + 1) * v];
                for (kk, &zk) in dz.iter().enumerate() {
                    d_log_t += zk as f64 * dist[kk] as f64 / t as f64;
                    let dd = -zk / t;
                    if dd == 0.0 {
                        continue;
                    }
                    let cent = self.cb.centroid(c, kk);
                    let crow = &mut d_cent[(c * k + kk) * v..(c * k + kk + 1) * v];
                    for ((cd, &x), &p) in crow.iter_mut().zip(sub).zip(cent) {
                        *cd += dd * -2.0 * (x - p);
                    }
                }
            }
        }
        (d_table, d_cent, d_log_t)
    }

    /// Apply the rebuilt-table fold (once, after any block reduction)
    /// and package the gradients.
    fn finish_grads(&self, d_table: Vec<f32>, mut d_cent: Vec<f32>, d_log_t: f64) -> SoftPqGrads {
        let (c_total, k, v) = (self.cb.c, self.cb.k, self.cb.v);
        let m = self.m;
        if self.table.is_some() {
            return SoftPqGrads { centroids: d_cent, log_t: d_log_t as f32, table: Some(d_table) };
        }
        // Rebuilt table: fold dT into the centroids through T = P . B.
        for c in 0..c_total {
            for kk in 0..k {
                let trow = &d_table[(c * k + kk) * m..(c * k + kk + 1) * m];
                let crow = &mut d_cent[(c * k + kk) * v..(c * k + kk + 1) * v];
                for (vi, cd) in crow.iter_mut().enumerate() {
                    let wrow = &self.weight[(c * v + vi) * m..(c * v + vi + 1) * m];
                    let mut s = 0.0f32;
                    for (&td, &w) in trow.iter().zip(wrow) {
                        s += td * w;
                    }
                    *cd += s;
                }
            }
        }
        SoftPqGrads { centroids: d_cent, log_t: d_log_t as f32, table: None }
    }

    /// Freeze into the inference representation: quantized table +
    /// hard-argmin encode (`lut::LutLinear`), ready for bundle export.
    pub fn into_lut(&self, bits: u8) -> LutLinear {
        match &self.table {
            Some(t) => {
                let qt = quantize_table(t, self.cb.c, self.cb.k, self.m, bits);
                let mut lut = LutLinear::from_parts(self.cb.clone(), qt, self.bias.clone());
                // from_parts only sees the quantized table; keep the
                // exact trained table so forward_f32_table stays
                // quantization-free (same contract as LutLinear::new).
                lut.table_f32 = t.clone();
                lut
            }
            None => LutLinear::new(self.cb.clone(), &self.weight, self.m, self.bias.clone(), bits),
        }
    }
}

/// `out = softmax(-d / t)` with max-subtraction — stable at the tiny
/// temperatures the annealing schedule ends on.
fn softmax_neg_scaled(d: &[f32], t: f32, out: &mut [f32]) {
    let mut zmax = f32::NEG_INFINITY;
    for &x in d {
        let z = -x / t;
        if z > zmax {
            zmax = z;
        }
    }
    let mut sum = 0.0f32;
    for (o, &x) in out.iter_mut().zip(d) {
        let e = (-x / t - zmax).exp();
        *o = e;
        sum += e;
    }
    let inv = 1.0 / sum;
    for o in out.iter_mut() {
        *o *= inv;
    }
}

/// Per-(row, codebook) argmax of soft assignments ([n, C, K] -> [n*C]).
/// As `t -> 0` this is the hard encoder's argmin.
pub fn soft_argmax(soft: &[f32], n: usize, c: usize, k: usize) -> Vec<u16> {
    assert_eq!(soft.len(), n * c * k);
    let mut out = vec![0u16; n * c];
    for (slot, row) in out.iter_mut().zip(soft.chunks_exact(k)) {
        let mut best = 0usize;
        let mut best_v = row[0];
        for (i, &x) in row.iter().enumerate().skip(1) {
            if x > best_v {
                best_v = x;
                best = i;
            }
        }
        *slot = best as u16;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::LutOpts;
    use crate::pq::kmeans::learn_codebooks;
    use crate::util::prng::Prng;
    use crate::util::prop;

    fn fixture(
        seed: u64,
        n: usize,
        c: usize,
        v: usize,
        k: usize,
        m: usize,
    ) -> (Vec<f32>, SoftPqLayer) {
        let mut rng = Prng::new(seed);
        let d = c * v;
        let a = rng.normal_vec(n * d, 1.0);
        let w = rng.normal_vec(d * m, 1.0);
        let cb = learn_codebooks(&a, n, d, c, k, 8, seed);
        let bias = Some(rng.normal_vec(m, 0.3));
        (a, SoftPqLayer::new(cb, w, bias, m, 1.0))
    }

    /// MSE loss in f64 (grad-check noise floor) + its dout.
    fn mse_and_grad(out: &[f32], target: &[f32]) -> (f64, Vec<f32>) {
        let n = out.len() as f64;
        let mut loss = 0.0f64;
        let mut dout = vec![0.0f32; out.len()];
        for ((&o, &t), d) in out.iter().zip(target).zip(dout.iter_mut()) {
            let diff = o as f64 - t as f64;
            loss += diff * diff;
            *d = (2.0 * diff / n) as f32;
        }
        (loss / n, dout)
    }

    fn loss_of(layer: &SoftPqLayer, a: &[f32], n: usize, target: &[f32]) -> f64 {
        let fwd = layer.forward(a, n);
        mse_and_grad(&fwd.out, target).0
    }

    /// Central finite difference of the loss along one parameter.
    fn numeric_grad(
        layer: &SoftPqLayer,
        a: &[f32],
        n: usize,
        target: &[f32],
        poke: impl Fn(&mut SoftPqLayer, f32),
        eps: f32,
    ) -> f64 {
        let mut hi = layer.clone();
        poke(&mut hi, eps);
        let mut lo = layer.clone();
        poke(&mut lo, -eps);
        (loss_of(&hi, a, n, target) - loss_of(&lo, a, n, target)) / (2.0 * eps as f64)
    }

    fn assert_grad_close(analytic: f64, numeric: f64, what: &str) {
        let denom = analytic.abs().max(numeric.abs()).max(1e-3);
        let rel = (analytic - numeric).abs() / denom;
        assert!(rel < 7e-2, "{what}: analytic {analytic} vs numeric {numeric} (rel {rel})");
    }

    #[test]
    fn centroid_and_temperature_grads_match_finite_differences() {
        let (n, c, v, k, m) = (6, 2, 3, 4, 3);
        let (a, layer) = fixture(0, n, c, v, k, m);
        let mut rng = Prng::new(99);
        let target = rng.normal_vec(n * m, 1.0);
        let fwd = layer.forward(&a, n);
        let (_, dout) = mse_and_grad(&fwd.out, &target);
        let grads = layer.backward(&a, n, &fwd, &dout);
        assert!(grads.table.is_none());
        // every 3rd centroid coordinate, to keep the test fast
        for idx in (0..c * k * v).step_by(3) {
            let num = numeric_grad(&layer, &a, n, &target, |l, e| l.cb.data[idx] += e, 1e-2);
            assert_grad_close(grads.centroids[idx] as f64, num, &format!("centroid[{idx}]"));
        }
        let num_t = numeric_grad(&layer, &a, n, &target, |l, e| l.log_t += e, 1e-2);
        assert_grad_close(grads.log_t as f64, num_t, "log_t");
    }

    #[test]
    fn decoupled_table_grads_match_finite_differences() {
        let (n, c, v, k, m) = (5, 2, 2, 3, 3);
        let (a, mut layer) = fixture(1, n, c, v, k, m);
        layer.decouple_table();
        let mut rng = Prng::new(7);
        let target = rng.normal_vec(n * m, 1.0);
        let fwd = layer.forward(&a, n);
        let (_, dout) = mse_and_grad(&fwd.out, &target);
        let grads = layer.backward(&a, n, &fwd, &dout);
        let d_table = grads.table.expect("decoupled table must have grads");
        for idx in (0..c * k * m).step_by(2) {
            let num = numeric_grad(
                &layer,
                &a,
                n,
                &target,
                |l, e| l.table.as_mut().unwrap()[idx] += e,
                1e-2,
            );
            assert_grad_close(d_table[idx] as f64, num, &format!("table[{idx}]"));
        }
        // centroid grads still flow through the distance path
        for idx in (0..c * k * v).step_by(2) {
            let num = numeric_grad(&layer, &a, n, &target, |l, e| l.cb.data[idx] += e, 1e-2);
            assert_grad_close(grads.centroids[idx] as f64, num, &format!("centroid[{idx}]"));
        }
    }

    #[test]
    fn soft_argmax_agrees_with_hard_encode_as_t_goes_to_zero() {
        // Acceptance gate: >= 99% per-position agreement between the
        // annealed soft encoder and the inference engine's hard argmin.
        let (n, c, v, k, m) = (500, 4, 4, 16, 8);
        let (a, mut layer) = fixture(2, n, c, v, k, m);
        layer.set_temperature(1e-4);
        let mut dist = Vec::new();
        let mut soft = Vec::new();
        layer.soft_encode(&a, n, &mut dist, &mut soft);
        let soft_idx = soft_argmax(&soft, n, c, k);

        let lut = layer.into_lut(8);
        let mut hard_idx = vec![0u16; n * c];
        lut.encode_into(&a, n, LutOpts::deployed(), &mut hard_idx);

        let agree = soft_idx.iter().zip(&hard_idx).filter(|(s, h)| s == h).count();
        let frac = agree as f64 / (n * c) as f64;
        assert!(frac >= 0.99, "soft/hard encode agreement {frac} < 0.99");
    }

    #[test]
    fn soft_forward_converges_to_hard_forward_at_low_temperature() {
        let (n, c, v, k, m) = (40, 3, 4, 8, 5);
        let (a, mut layer) = fixture(3, n, c, v, k, m);
        layer.set_temperature(1e-4);
        let fwd = layer.forward(&a, n);
        // f32-table hard forward (no scalar quantization) is the exact
        // t -> 0 limit of the soft relaxation.
        let lut = layer.into_lut(8);
        let hard = lut.forward_f32_table(&a, n, LutOpts::deployed());
        prop::assert_close(&fwd.out, &hard, 1e-4, 1e-3).unwrap();
    }

    #[test]
    fn softmax_stable_at_extreme_temperatures() {
        let d = [1000.0f32, 0.5, 2.0, 3000.0];
        for &t in &[1e-6f32, 1.0, 1e6] {
            let mut out = [0.0f32; 4];
            softmax_neg_scaled(&d, t, &mut out);
            let sum: f32 = out.iter().sum();
            assert!(out.iter().all(|x| x.is_finite()), "t={t}: {out:?}");
            assert!((sum - 1.0).abs() < 1e-5, "t={t}: sum {sum}");
        }
        // tiny t concentrates all mass on the argmin
        let mut out = [0.0f32; 4];
        softmax_neg_scaled(&d, 1e-6, &mut out);
        assert!(out[1] > 0.999, "{out:?}");
    }

    #[test]
    fn forward_mt_is_bitwise_the_sequential_forward() {
        // Rows are independent and blocks stitch back in row order, so
        // any thread count must reproduce the sequential pass exactly.
        let (n, c, v, k, m) = (3 * MT_ROW_BLOCK + 5, 2, 3, 4, 3);
        let (a, mut layer) = fixture(5, n, c, v, k, m);
        for decoupled in [false, true] {
            if decoupled {
                layer.decouple_table();
            }
            let seq = layer.forward(&a, n);
            for threads in [2, 3, 8] {
                let par = layer.forward_mt(&a, n, threads);
                assert_eq!(seq.out.len(), par.out.len());
                for (name, s, p) in [
                    ("dist", &seq.dist, &par.dist),
                    ("soft", &seq.soft, &par.soft),
                    ("out", &seq.out, &par.out),
                ] {
                    let same = s.iter().zip(p).all(|(x, y)| x.to_bits() == y.to_bits());
                    assert!(same, "{name} differs (decoupled={decoupled}, threads={threads})");
                }
                assert_eq!(seq.table, par.table);
            }
        }
    }

    #[test]
    fn backward_mt_is_thread_count_independent_and_close_to_sequential() {
        let (n, c, v, k, m) = (2 * MT_ROW_BLOCK + 9, 2, 3, 4, 3);
        let (a, layer) = fixture(6, n, c, v, k, m);
        let mut rng = Prng::new(42);
        let target = rng.normal_vec(n * m, 1.0);
        let fwd = layer.forward(&a, n);
        let (_, dout) = mse_and_grad(&fwd.out, &target);
        let seq = layer.backward(&a, n, &fwd, &dout);
        let two = layer.backward_mt(&a, n, &fwd, &dout, 2);
        // Grouping is fixed by MT_ROW_BLOCK: every threads > 1 count is
        // bit-identical to every other.
        for threads in [3, 5, 8] {
            let other = layer.backward_mt(&a, n, &fwd, &dout, threads);
            let same = two
                .centroids
                .iter()
                .zip(&other.centroids)
                .all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(same, "threads={threads} centroid grads differ from threads=2");
            assert_eq!(two.log_t.to_bits(), other.log_t.to_bits(), "threads={threads}");
        }
        // And the blocked reduction only regroups f32 sums: it must stay
        // within summation-noise of the legacy sequential path.
        prop::assert_close(&two.centroids, &seq.centroids, 1e-5, 1e-6).unwrap();
        assert!((two.log_t - seq.log_t).abs() <= 1e-5 * seq.log_t.abs().max(1.0));
        // threads=1 is the legacy path, bit for bit.
        let one = layer.backward_mt(&a, n, &fwd, &dout, 1);
        let same =
            one.centroids.iter().zip(&seq.centroids).all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(same && one.log_t.to_bits() == seq.log_t.to_bits());
    }

    #[test]
    fn decouple_table_initializes_from_weight() {
        let (_, mut layer) = fixture(4, 8, 2, 3, 4, 5);
        let rebuilt = layer.current_table();
        layer.decouple_table();
        assert_eq!(layer.table.as_ref().unwrap(), &rebuilt);
        // and is idempotent
        let snapshot = layer.table.clone();
        layer.decouple_table();
        assert_eq!(layer.table, snapshot);
    }
}
