//! Layer-wise distillation: learn soft-PQ centroids against a dense
//! teacher from activation batches, then freeze into the inference
//! representation.
//!
//! This is the rust-native realization of the paper's compile path
//! (§3 + §6.1): k-means-initialize centroids (Eq. 1), minimize the MSE
//! between the soft-PQ output and the dense teacher `a @ B + bias` with
//! Adam (two learning rates — Table 3), anneal the softmax temperature
//! toward the hard argmin, and emit `lut::LutLinear` layers / a whole
//! compiled [`Graph`] that `api::Session` executes directly. No Python
//! in the loop — the deploy-time-adaptation scenario (re-calibrating
//! centroids on fresh activation traces) runs entirely in-process.

use anyhow::{bail, Result};

use crate::lut::LutOpts;
use crate::nn::gemm::gemm;
use crate::nn::graph::{Graph, LayerParams, Op};
use crate::nn::models;
use crate::nn::ops::add_bias_rows;
use crate::pq::kmeans::learn_codebooks;
use crate::tensor::Tensor;
use crate::util::prng::Prng;

use super::adam::{clip_global_norm, Adam, AdamConfig};
use super::softpq::SoftPqLayer;

/// Knobs of the centroid-learning loop. The defaults are tuned for
/// layer-wise distillation on small calibration batches (the `lutnn
/// compile` path); task-level fine-tuning on real datasets stays in
/// `python/compile/train.py`.
#[derive(Debug, Clone, Copy)]
pub struct TrainConfig {
    /// passes over the calibration activations
    pub epochs: usize,
    /// minibatch rows per optimizer step
    pub batch_size: usize,
    /// centroid learning rate (paper Table 3: 1e-3/1e-4 at task level;
    /// layer-wise distillation converges faster, default 5e-3)
    pub lr: f32,
    /// temperature learning rate (Table 3: larger than the centroid LR)
    pub temperature_lr: f32,
    /// initial softmax temperature
    pub init_t: f32,
    /// per-epoch multiplicative temperature decay; 1.0 disables the
    /// schedule (learned temperature only)
    pub anneal: f32,
    /// annealing floor — keeps gradients finite near the hard limit
    pub min_t: f32,
    /// Lloyd iterations for the k-means init (Eq. 1)
    pub kmeans_iters: usize,
    /// global L2 gradient clip (optim.py uses 5.0); 0 disables
    pub grad_clip: f32,
    /// train the output table as a free parameter instead of rebuilding
    /// it from the frozen weight (deploy-time adaptation without `B`)
    pub decouple_table: bool,
    /// seed for k-means init and minibatch shuffling — the whole loop
    /// is deterministic for a fixed config
    pub seed: u64,
    /// worker threads for minibatch forward/backward (1 = the legacy
    /// exact sequential path). Any `threads > 1` is deterministic per
    /// seed *and* thread-count-independent: work shards into fixed
    /// [`super::softpq::MT_ROW_BLOCK`]-row blocks reduced in block
    /// order, so 2 threads and 8 threads are bit-identical (they may
    /// differ from `threads = 1` in final ulps — different f32
    /// summation grouping).
    pub threads: usize,
}

impl Default for TrainConfig {
    fn default() -> TrainConfig {
        TrainConfig {
            epochs: 15,
            batch_size: 64,
            lr: 5e-3,
            temperature_lr: 5e-2,
            init_t: 1.0,
            anneal: 0.85,
            min_t: 1e-3,
            kmeans_iters: 10,
            grad_clip: 5.0,
            decouple_table: false,
            seed: 0,
            threads: 1,
        }
    }
}

/// What one layer's distillation did.
#[derive(Debug, Clone)]
pub struct DistillReport {
    /// mean soft-forward MSE per epoch (the training loss curve)
    pub epoch_loss: Vec<f32>,
    /// temperature after the last epoch
    pub final_temperature: f32,
    /// hard-argmin (f32-table) MSE vs the teacher at the k-means init
    pub hard_mse_init: f32,
    /// the same after training
    pub hard_mse_final: f32,
}

/// Per-layer report of a whole-graph compile.
#[derive(Debug, Clone)]
pub struct LayerReport {
    pub name: String,
    pub report: DistillReport,
}

fn mse(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        let d = x as f64 - y as f64;
        s += d * d;
    }
    (s / a.len().max(1) as f64) as f32
}

/// Hard-argmin forward MSE vs `target`, on the exact f32 table (no
/// scalar quantization — isolates centroid quality).
fn hard_mse(layer: &SoftPqLayer, acts: &[f32], n: usize, target: &[f32]) -> f32 {
    let lut = layer.into_lut(8);
    let out = lut.forward_f32_table(acts, n, LutOpts::deployed());
    mse(&out, target)
}

/// Distill one linear operator: learn `(centroids, temperature[, table])`
/// so the soft-PQ forward on `acts` ([n, D]) matches the dense teacher
/// `acts @ weight + bias`. Returns the trained layer plus its report.
///
/// Deterministic: the same inputs and config produce bit-identical
/// results (seeded k-means init, seeded shuffles, fixed FP op order).
/// With `cfg.threads > 1` minibatch forward/backward shard across the
/// thread pool; results stay bit-identical for any `threads > 1` count
/// (see [`TrainConfig::threads`]).
#[allow(clippy::too_many_arguments)] // mirrors pq::kmeans::learn_codebooks's flat signature
pub fn distill_layer(
    acts: &[f32],
    n: usize,
    weight: &[f32],
    bias: Option<&[f32]>,
    m: usize,
    c: usize,
    k: usize,
    cfg: &TrainConfig,
) -> (SoftPqLayer, DistillReport) {
    assert!(n > 0, "need at least one calibration row");
    assert!(m > 0 && c > 0 && k > 0);
    assert_eq!(acts.len() % n, 0, "acts must be [n, D]");
    let d = acts.len() / n;
    assert_eq!(weight.len(), d * m, "weight must be [D={d}, M={m}]");

    // Teacher outputs (what the table pipeline must reproduce).
    let mut target = vec![0.0f32; n * m];
    gemm(acts, weight, &mut target, n, d, m);
    if let Some(b) = bias {
        add_bias_rows(&mut target, b);
    }

    let cb = learn_codebooks(acts, n, d, c, k, cfg.kmeans_iters, cfg.seed);
    let v = cb.v;
    let mut layer =
        SoftPqLayer::new(cb, weight.to_vec(), bias.map(<[f32]>::to_vec), m, cfg.init_t);
    if cfg.decouple_table {
        layer.decouple_table();
    }
    let hard_mse_init = hard_mse(&layer, acts, n, &target);

    let acfg = AdamConfig { lr: cfg.lr, ..AdamConfig::default() };
    let mut opt_cent = Adam::new(c * k * v, acfg);
    let mut opt_t = Adam::new(1, acfg);
    let mut opt_table = if cfg.decouple_table { Some(Adam::new(c * k * m, acfg)) } else { None };
    let t_scale = cfg.temperature_lr / cfg.lr;

    let bs = cfg.batch_size.clamp(1, n);
    let mut order: Vec<usize> = (0..n).collect();
    let mut rng = Prng::new(cfg.seed ^ 0x5EED_CAFE);
    let mut batch = vec![0.0f32; bs * d];
    let mut tbatch = vec![0.0f32; bs * m];
    let mut dout = vec![0.0f32; bs * m];
    let mut epoch_loss = Vec::with_capacity(cfg.epochs);

    for _epoch in 0..cfg.epochs {
        rng.shuffle(&mut order);
        let mut loss_sum = 0.0f64;
        let mut rows_seen = 0usize;
        for chunk in order.chunks(bs) {
            let nb = chunk.len();
            for (bi, &src) in chunk.iter().enumerate() {
                batch[bi * d..(bi + 1) * d].copy_from_slice(&acts[src * d..(src + 1) * d]);
                tbatch[bi * m..(bi + 1) * m].copy_from_slice(&target[src * m..(src + 1) * m]);
            }
            let fwd = layer.forward_mt(&batch[..nb * d], nb, cfg.threads);
            // MSE loss and its gradient w.r.t. the layer output.
            let denom = (nb * m) as f64;
            let mut loss = 0.0f64;
            for ((&o, &t), g) in
                fwd.out.iter().zip(&tbatch[..nb * m]).zip(dout[..nb * m].iter_mut())
            {
                let diff = o as f64 - t as f64;
                loss += diff * diff;
                *g = (2.0 * diff / denom) as f32;
            }
            loss_sum += loss;
            rows_seen += nb;

            let mut grads =
                layer.backward_mt(&batch[..nb * d], nb, &fwd, &dout[..nb * m], cfg.threads);
            let mut lt = [grads.log_t];
            {
                let mut groups: Vec<&mut [f32]> = vec![&mut grads.centroids, &mut lt];
                if let Some(tg) = grads.table.as_mut() {
                    groups.push(tg);
                }
                clip_global_norm(&mut groups, cfg.grad_clip);
            }
            opt_cent.step(&mut layer.cb.data, &grads.centroids);
            let mut log_t = [layer.log_t];
            opt_t.step_scaled(&mut log_t, &lt, t_scale);
            layer.log_t = log_t[0];
            if let (Some(opt), Some(tg), Some(tp)) =
                (opt_table.as_mut(), grads.table.as_ref(), layer.table.as_mut())
            {
                opt.step(tp, tg);
            }
        }
        epoch_loss.push((loss_sum / (rows_seen * m) as f64) as f32);
        if cfg.anneal < 1.0 {
            let t_next = (layer.temperature() * cfg.anneal).max(cfg.min_t);
            layer.set_temperature(t_next);
        }
    }

    let report = DistillReport {
        epoch_loss,
        final_temperature: layer.temperature(),
        hard_mse_init,
        hard_mse_final: hard_mse(&layer, acts, n, &target),
    };
    (layer, report)
}

/// Compile a dense teacher graph into a LUT graph by distilling every
/// replaceable conv/linear layer on its own captured activations — the
/// rust-native equivalent of the python convert + fine-tune pipeline,
/// and the trained counterpart of `nn::models::lutify_graph` (which
/// stops at the k-means init).
///
/// The first conv stays dense (paper §6.1); `sample` drives the
/// activation capture, so it should follow the deployment input
/// distribution. Returns the compiled graph (name suffixed
/// `_compiled`) plus one [`LayerReport`] per converted layer.
///
/// BERT bundles compile too: `sample` is then a `[N, T]` token-id
/// tensor, every q/k/v/o/f1/f2 projection is distilled on the
/// activations the dense teacher feeds it, and the classification head
/// stays dense (the attention-path analogue of the dense conv stem).
pub fn compile_graph(
    g: &Graph,
    sample: &Tensor,
    k_centroids: usize,
    bits: u8,
    cfg: &TrainConfig,
) -> Result<(Graph, Vec<LayerReport>)> {
    if g.bert.is_some() {
        for (name, l) in &g.layers {
            if matches!(l, LayerParams::Lut(_)) {
                bail!("layer '{name}' is not dense — compile_graph distills a dense teacher");
            }
        }
    } else {
        for op in &g.ops {
            if let Op::Conv { layer, .. } | Op::Linear { layer } = op {
                match g.layers.get(layer.as_str()) {
                    Some(LayerParams::Dense { .. }) => {}
                    Some(_) => bail!(
                        "layer '{layer}' is not dense — compile_graph distills a dense teacher"
                    ),
                    None => bail!("graph references unknown layer '{layer}'"),
                }
            }
        }
    }

    let mut reports = Vec::new();
    let compiled =
        models::replace_linear_layers(g, sample, "_compiled", |name, acts, rows, d, w, b, m| {
            let v = models::pick_v(d);
            let (layer, report) = distill_layer(acts, rows, w, b, m, d / v, k_centroids, cfg);
            reports.push(LayerReport { name: name.to_string(), report });
            LayerParams::Lut(layer.into_lut(bits))
        });
    Ok((compiled, reports))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SessionBuilder;
    use crate::model_fmt::{load_bundle, save_bundle};
    use crate::nn::models::{build_cnn_graph, ConvSpec};

    /// Clustered activations: rows drawn near a few prototypes per
    /// sub-vector, so centroid learning has real signal to capture.
    fn clustered_acts(seed: u64, n: usize, d: usize, protos: usize) -> Vec<f32> {
        let mut rng = Prng::new(seed);
        let centers = rng.normal_vec(protos * d, 1.0);
        let mut acts = vec![0.0f32; n * d];
        for i in 0..n {
            let p = rng.below(protos);
            for (j, a) in acts[i * d..(i + 1) * d].iter_mut().enumerate() {
                *a = centers[p * d + j] + 0.15 * rng.normal();
            }
        }
        acts
    }

    fn teacher(seed: u64, d: usize, m: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Prng::new(seed ^ 0xBEEF);
        (rng.normal_vec(d * m, 0.5), rng.normal_vec(m, 0.2))
    }

    #[test]
    fn soft_loss_decreases_monotonically_on_average() {
        // Acceptance gate: with a fixed temperature (anneal off, so the
        // loss landscape is stationary), the per-epoch training loss
        // must trend down — averaged over 3-epoch windows to absorb
        // minibatch noise — and end below where it started.
        let (n, d, m, c, k) = (256, 16, 6, 4, 8);
        let acts = clustered_acts(0, n, d, 12);
        let (w, b) = teacher(0, d, m);
        let cfg = TrainConfig {
            epochs: 10,
            batch_size: 32,
            anneal: 1.0,
            ..TrainConfig::default()
        };
        let (_, report) = distill_layer(&acts, n, &w, Some(&b), m, c, k, &cfg);
        let loss = &report.epoch_loss;
        assert_eq!(loss.len(), 10);
        assert!(loss.iter().all(|l| l.is_finite()));
        let first: f32 = loss[..3].iter().sum::<f32>() / 3.0;
        let last: f32 = loss[loss.len() - 3..].iter().sum::<f32>() / 3.0;
        assert!(last < first, "windowed loss must decrease: {loss:?}");
        assert!(loss[loss.len() - 1] < loss[0], "final < first: {loss:?}");
    }

    #[test]
    fn annealed_distillation_matches_teacher_within_documented_tolerance() {
        // Documented tolerance: after annealed training, the *hard*
        // argmin forward (what inference executes) stays within the
        // mse < signal-power envelope the engine's own approximation
        // tests use, and training must not degrade the k-means init by
        // more than 5%.
        let (n, d, m, c, k) = (400, 16, 6, 4, 16);
        let acts = clustered_acts(1, n, d, 20);
        let (w, b) = teacher(1, d, m);
        let cfg = TrainConfig { epochs: 12, anneal: 0.7, ..TrainConfig::default() };
        let (layer, report) = distill_layer(&acts, n, &w, Some(&b), m, c, k, &cfg);
        assert!(report.final_temperature < cfg.init_t, "annealing must cool the softmax");

        let mut target = vec![0.0f32; n * m];
        gemm(&acts, &w, &mut target, n, d, m);
        add_bias_rows(&mut target, &b);
        let sig = target.iter().map(|x| (x * x) as f64).sum::<f64>() / target.len() as f64;
        assert!(
            (report.hard_mse_final as f64) < sig,
            "hard mse {} vs signal {sig}",
            report.hard_mse_final
        );
        assert!(
            report.hard_mse_final <= report.hard_mse_init * 1.05,
            "training degraded the init: {} -> {}",
            report.hard_mse_init,
            report.hard_mse_final
        );
        // the frozen layer runs through the real quantized engine
        let lut = layer.into_lut(8);
        let out = lut.forward(&acts, n, LutOpts::deployed());
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn decoupled_table_training_reduces_loss() {
        let (n, d, m, c, k) = (192, 8, 5, 2, 8);
        let acts = clustered_acts(2, n, d, 8);
        let (w, b) = teacher(2, d, m);
        let cfg = TrainConfig {
            epochs: 8,
            batch_size: 32,
            anneal: 1.0,
            decouple_table: true,
            ..TrainConfig::default()
        };
        let (layer, report) = distill_layer(&acts, n, &w, Some(&b), m, c, k, &cfg);
        assert!(layer.table.is_some(), "table must be decoupled");
        let loss = &report.epoch_loss;
        assert!(
            loss[loss.len() - 1] < loss[0],
            "free-table training must reduce loss: {loss:?}"
        );
    }

    #[test]
    fn distillation_is_deterministic() {
        let (n, d, m, c, k) = (96, 8, 4, 2, 8);
        let acts = clustered_acts(3, n, d, 6);
        let (w, b) = teacher(3, d, m);
        let cfg = TrainConfig { epochs: 3, batch_size: 32, ..TrainConfig::default() };
        let (l1, r1) = distill_layer(&acts, n, &w, Some(&b), m, c, k, &cfg);
        let (l2, r2) = distill_layer(&acts, n, &w, Some(&b), m, c, k, &cfg);
        assert_eq!(l1.log_t.to_bits(), l2.log_t.to_bits());
        for (a, b) in l1.cb.data.iter().zip(&l2.cb.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "centroids must be bit-identical");
        }
        for (a, b) in r1.epoch_loss.iter().zip(&r2.epoch_loss) {
            assert_eq!(a.to_bits(), b.to_bits(), "loss curves must be bit-identical");
        }
    }

    #[test]
    fn multithreaded_distillation_is_deterministic_per_seed() {
        // batch_size > MT_ROW_BLOCK so the parallel shards actually
        // engage; thread counts 2 and 5 must produce bit-identical
        // layers (grouping is fixed by the block size, not the pool).
        let (n, d, m, c, k) = (160, 8, 4, 2, 8);
        let acts = clustered_acts(7, n, d, 6);
        let (w, b) = teacher(7, d, m);
        let base = TrainConfig { epochs: 3, batch_size: 80, ..TrainConfig::default() };
        let cfg2 = TrainConfig { threads: 2, ..base };
        let cfg5 = TrainConfig { threads: 5, ..base };
        let (l2, r2) = distill_layer(&acts, n, &w, Some(&b), m, c, k, &cfg2);
        let (l5, r5) = distill_layer(&acts, n, &w, Some(&b), m, c, k, &cfg5);
        assert_eq!(l2.log_t.to_bits(), l5.log_t.to_bits());
        for (x, y) in l2.cb.data.iter().zip(&l5.cb.data) {
            assert_eq!(x.to_bits(), y.to_bits(), "centroids must be thread-count-independent");
        }
        for (x, y) in r2.epoch_loss.iter().zip(&r5.epoch_loss) {
            assert_eq!(x.to_bits(), y.to_bits(), "loss curves must be thread-count-independent");
        }
        // and the parallel path still trains: loss ends below start
        assert!(r2.epoch_loss.iter().all(|l| l.is_finite()));
        let (_, r1) = distill_layer(&acts, n, &w, Some(&b), m, c, k, &base);
        let rel = (r2.epoch_loss[0] - r1.epoch_loss[0]).abs() / r1.epoch_loss[0].max(1e-6);
        assert!(rel < 1e-3, "parallel loss far from sequential: {rel}");
    }

    #[test]
    fn compile_graph_end_to_end_loads_in_session() {
        // The PR's acceptance path: dense teacher -> rust-native compile
        // -> bundle -> api::Session, with the compiled model tracking
        // the teacher. Documented end-to-end tolerance: output MSE below
        // 2x the teacher's signal power (two stacked approximate layers;
        // per-layer quality is gated by the distill tests above).
        let dense = build_cnn_graph(
            "teacher",
            [6, 6, 3],
            &[ConvSpec { cout: 4, k: 3, stride: 1 }, ConvSpec { cout: 8, k: 3, stride: 2 }],
            3,
            0,
        );
        let mut rng = Prng::new(11);
        let sample = Tensor::new(vec![8, 6, 6, 3], rng.normal_vec(8 * 6 * 6 * 3, 1.0));
        let cfg = TrainConfig {
            epochs: 5,
            kmeans_iters: 6,
            anneal: 0.8,
            ..TrainConfig::default()
        };
        let (compiled, reports) = compile_graph(&dense, &sample, 16, 8, &cfg).unwrap();
        assert_eq!(compiled.name, "teacher_compiled");
        assert!(matches!(compiled.layers["c0"], LayerParams::Dense { .. }), "stem stays dense");
        assert!(matches!(compiled.layers["c1"], LayerParams::Lut(_)));
        assert!(matches!(compiled.layers["fc"], LayerParams::Lut(_)));
        assert_eq!(reports.len(), 2);
        for r in &reports {
            assert!(r.report.epoch_loss.iter().all(|l| l.is_finite()), "{}", r.name);
            assert!(r.report.hard_mse_final.is_finite(), "{}", r.name);
        }

        // bundle round-trip, then run through the compiled executor
        let dir = std::env::temp_dir().join("lutnn_train_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("compiled.lutnn").to_string_lossy().into_owned();
        save_bundle(&compiled, &path).unwrap();
        let reloaded = load_bundle(&path).unwrap();

        let mut s_dense = SessionBuilder::new(&dense).max_batch(8).build().unwrap();
        let mut s_pre = SessionBuilder::new(&compiled).max_batch(8).build().unwrap();
        let mut s_post = SessionBuilder::new(&reloaded).max_batch(8).build().unwrap();
        let want = s_dense.run_alloc(&sample).unwrap();
        let pre = s_pre.run_alloc(&sample).unwrap();
        let post = s_post.run_alloc(&sample).unwrap();
        assert_eq!(pre.data, post.data, "bundle round-trip must be forward-exact");
        assert_eq!(pre.shape, want.shape);
        assert!(pre.data.iter().all(|x| x.is_finite()));
        let sig: f32 = want.data.iter().map(|x| x * x).sum::<f32>() / want.len() as f32;
        let err = pre.mse(&want);
        assert!(err < 2.0 * sig, "compiled model too far from teacher: mse {err} sig {sig}");
    }

    #[test]
    fn compile_graph_distills_bert_bundles_end_to_end() {
        // BERT analogue of the CNN acceptance path: dense MiniBert
        // teacher -> in-process compile (q/k/v/o/f1/f2 distilled on
        // captured activations, head stays dense) -> bundle round-trip
        // -> api::Session. Documented tolerance: output MSE below the
        // teacher's signal power — tighter than the CNN bound because
        // residual connections and layernorm keep the approximation
        // error from compounding across blocks.
        use crate::nn::bert::{tests::synthetic_bert, BertConfig};
        let bcfg = BertConfig {
            vocab: 32,
            seq_len: 8,
            d: 16,
            n_heads: 2,
            d_ff: 32,
            n_layers: 2,
            n_out: 4,
        };
        let dense = synthetic_bert(&bcfg, 7);
        let mut rng = Prng::new(13);
        let tokens: Vec<f32> = (0..6 * 8).map(|_| rng.below(32) as f32).collect();
        let sample = Tensor::new(vec![6, 8], tokens);
        let cfg = TrainConfig { epochs: 4, kmeans_iters: 6, anneal: 0.8, ..TrainConfig::default() };
        let (compiled, reports) = compile_graph(&dense, &sample, 16, 8, &cfg).unwrap();

        assert_eq!(compiled.name, "bert-test_compiled");
        assert!(
            matches!(compiled.layers["head"], LayerParams::Dense { .. }),
            "head stays dense (attention-path analogue of the dense stem)"
        );
        for l in 0..2 {
            for nm in ["q", "k", "v", "o", "f1", "f2"] {
                let name = format!("l{l}{nm}");
                assert!(matches!(compiled.layers[&name], LayerParams::Lut(_)), "{name}");
            }
        }
        assert_eq!(reports.len(), 12, "6 projections x 2 blocks");
        for r in &reports {
            assert!(r.report.hard_mse_final.is_finite(), "{}", r.name);
        }

        let dir = std::env::temp_dir().join("lutnn_train_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("compiled_bert.lutnn").to_string_lossy().into_owned();
        save_bundle(&compiled, &path).unwrap();
        let reloaded = load_bundle(&path).unwrap();

        let mut s_dense = SessionBuilder::new(&dense).max_batch(6).build().unwrap();
        let mut s_pre = SessionBuilder::new(&compiled).max_batch(6).build().unwrap();
        let mut s_post = SessionBuilder::new(&reloaded).max_batch(6).build().unwrap();
        let want = s_dense.run_alloc(&sample).unwrap();
        let pre = s_pre.run_alloc(&sample).unwrap();
        let post = s_post.run_alloc(&sample).unwrap();
        assert_eq!(pre.data, post.data, "bundle round-trip must be forward-exact");
        assert_eq!(pre.shape, want.shape);
        assert!(pre.data.iter().all(|x| x.is_finite()));
        let sig: f32 = want.data.iter().map(|x| x * x).sum::<f32>() / want.len() as f32;
        let err = pre.mse(&want);
        assert!(err < sig, "compiled bert too far from teacher: mse {err} sig {sig}");
    }

    #[test]
    fn compile_graph_rejects_non_dense_teachers() {
        let dense = build_cnn_graph("t", [6, 6, 3], &[ConvSpec { cout: 4, k: 3, stride: 1 }], 3, 0);
        let mut rng = Prng::new(5);
        let sample = Tensor::new(vec![4, 6, 6, 3], rng.normal_vec(4 * 6 * 6 * 3, 1.0));
        let lut = crate::nn::models::lutify_graph(&dense, &sample, 8, 8, 0);
        let err = match compile_graph(&lut, &sample, 8, 8, &TrainConfig::default()) {
            Err(e) => e,
            Ok(_) => panic!("compile_graph must reject LUT layers in the teacher"),
        };
        assert!(format!("{err}").contains("not dense"), "{err:#}");
    }
}
