//! Model zoo.
//!
//! Two kinds of models:
//! 1. **Shape-exact paper configs** (`resnet18_cifar`, `resnet18_imagenet`,
//!    `senet18_*`, `vgg11_*`, `bert_base`): the per-linear-op (N, D, M)
//!    shapes of the models the paper evaluates. Used by the analytic cost
//!    model (Tables 1–2) and the operator benches (Fig. 7) — these need no
//!    trained weights.
//! 2. **Runnable synthetic builders** (`build_cnn_graph`, `lutify_graph`):
//!    materialize an executable `Graph` with random weights / k-means-
//!    learned codebooks for the end-to-end latency, memory, scaling and
//!    breakdown benches (Figs. 8–10, §6.3).

use std::collections::BTreeMap;

use crate::lut::LutLinear;
use crate::nn::graph::{Graph, LayerParams, Op};
use crate::pq::kmeans::learn_codebooks;
use crate::tensor::im2col::im2col;
use crate::tensor::Tensor;
use crate::util::prng::Prng;

/// Shape of one linear (LUT-replaceable) operator.
#[derive(Debug, Clone)]
pub struct LinearShape {
    pub name: String,
    /// rows of the im2col'd input per inference (H*W for convs, seq len
    /// for BERT, 1 for FC heads)
    pub n: usize,
    /// input dim (Cin * k * k for convs)
    pub d: usize,
    /// output dim (Cout)
    pub m: usize,
    /// conv kernel size (0 = fully connected)
    pub kernel: usize,
    /// whether LUT-NN replaces this op (first conv stays dense — §6.1)
    pub replaced: bool,
}

#[derive(Debug, Clone)]
pub struct ModelShape {
    pub name: String,
    pub ops: Vec<LinearShape>,
}

fn conv(name: &str, hw: usize, cin: usize, cout: usize, k: usize, replaced: bool) -> LinearShape {
    LinearShape {
        name: name.into(),
        n: hw * hw,
        d: cin * k * k,
        m: cout,
        kernel: k,
        replaced,
    }
}

fn fc(name: &str, n: usize, d: usize, m: usize, replaced: bool) -> LinearShape {
    LinearShape { name: name.into(), n, d, m, kernel: 0, replaced }
}

/// ResNet18, CIFAR variant (3x3 stem, no maxpool — paper §6.1).
pub fn resnet18_cifar() -> ModelShape {
    let mut ops = vec![conv("stem", 32, 3, 64, 3, false)];
    let stages: [(usize, usize, usize); 4] =
        [(64, 32, 0), (128, 16, 64), (256, 8, 128), (512, 4, 256)];
    for (si, &(ch, hw, prev)) in stages.iter().enumerate() {
        for b in 0..2 {
            let cin = if b == 0 && si > 0 { prev } else { ch };
            ops.push(conv(&format!("s{si}b{b}c1"), hw, cin, ch, 3, true));
            ops.push(conv(&format!("s{si}b{b}c2"), hw, ch, ch, 3, true));
            if b == 0 && si > 0 {
                ops.push(conv(&format!("s{si}sc"), hw, prev, ch, 1, true));
            }
        }
    }
    ops.push(fc("fc", 1, 512, 10, true));
    ModelShape { name: "ResNet18 (CIFAR10)".into(), ops }
}

/// ResNet18, ImageNet variant (7x7/2 stem + maxpool — paper §6.1).
pub fn resnet18_imagenet() -> ModelShape {
    let mut ops = vec![LinearShape {
        name: "stem".into(),
        n: 112 * 112,
        d: 3 * 49,
        m: 64,
        kernel: 7,
        replaced: false,
    }];
    let stages: [(usize, usize, usize); 4] =
        [(64, 56, 0), (128, 28, 64), (256, 14, 128), (512, 7, 256)];
    for (si, &(ch, hw, prev)) in stages.iter().enumerate() {
        for b in 0..2 {
            let cin = if b == 0 && si > 0 { prev } else { ch };
            ops.push(conv(&format!("s{si}b{b}c1"), hw, cin, ch, 3, true));
            ops.push(conv(&format!("s{si}b{b}c2"), hw, ch, ch, 3, true));
            if b == 0 && si > 0 {
                ops.push(conv(&format!("s{si}sc"), hw, prev, ch, 1, true));
            }
        }
    }
    ops.push(fc("fc", 1, 512, 1000, true));
    ModelShape { name: "ResNet18".into(), ops }
}

/// SENet18 = ResNet18 + squeeze-excite FC pairs per block (r=16).
fn add_se(mut base: ModelShape, name: &str) -> ModelShape {
    let mut extra = Vec::new();
    for (si, ch) in [(0usize, 64usize), (1, 128), (2, 256), (3, 512)] {
        for b in 0..2 {
            let r = (ch / 16).max(1);
            extra.push(fc(&format!("s{si}b{b}se1"), 1, ch, r, true));
            extra.push(fc(&format!("s{si}b{b}se2"), 1, r, ch, true));
        }
    }
    base.ops.extend(extra);
    base.name = name.into();
    base
}

pub fn senet18_cifar() -> ModelShape {
    add_se(resnet18_cifar(), "SENet18 (CIFAR10)")
}

pub fn senet18_imagenet() -> ModelShape {
    add_se(resnet18_imagenet(), "SENet18")
}

/// VGG11, CIFAR variant: first maxpool removed, final dense layers
/// replaced by GAP + one FC (paper §6.1 deployment practice).
pub fn vgg11_cifar() -> ModelShape {
    let cfg: [(usize, usize, usize); 8] = [
        (3, 64, 32),
        (64, 128, 32),
        (128, 256, 16),
        (256, 256, 16),
        (256, 512, 8),
        (512, 512, 8),
        (512, 512, 4),
        (512, 512, 4),
    ];
    let mut ops = Vec::new();
    for (i, &(cin, cout, hw)) in cfg.iter().enumerate() {
        ops.push(conv(&format!("c{i}"), hw, cin, cout, 3, i > 0));
    }
    ops.push(fc("fc", 1, 512, 10, true));
    ModelShape { name: "VGG11 (CIFAR10)".into(), ops }
}

/// VGG11, ImageNet variant.
pub fn vgg11_imagenet() -> ModelShape {
    let cfg: [(usize, usize, usize); 8] = [
        (3, 64, 224),
        (64, 128, 112),
        (128, 256, 56),
        (256, 256, 56),
        (256, 512, 28),
        (512, 512, 28),
        (512, 512, 14),
        (512, 512, 14),
    ];
    let mut ops = Vec::new();
    for (i, &(cin, cout, hw)) in cfg.iter().enumerate() {
        ops.push(conv(&format!("c{i}"), hw, cin, cout, 3, i > 0));
    }
    ops.push(fc("fc", 1, 512, 1000, true));
    ModelShape { name: "VGG11".into(), ops }
}

/// BERT-base encoder at sequence length 32 (matches the paper's Table 2
/// GFLOPs — see DESIGN.md). 12 layers of q/k/v/o + 2 FFN linears.
pub fn bert_base() -> ModelShape {
    let (d, ff, seq, layers) = (768usize, 3072usize, 32usize, 12usize);
    let mut ops = Vec::new();
    for l in 0..layers {
        for nm in ["q", "k", "v", "o"] {
            ops.push(fc(&format!("l{l}{nm}"), seq, d, d, true));
        }
        ops.push(fc(&format!("l{l}f1"), seq, d, ff, true));
        ops.push(fc(&format!("l{l}f2"), seq, ff, d, true));
    }
    ModelShape { name: "BERT".into(), ops }
}

pub fn all_paper_models() -> Vec<ModelShape> {
    vec![
        resnet18_cifar(),
        senet18_cifar(),
        vgg11_cifar(),
        resnet18_imagenet(),
        senet18_imagenet(),
        vgg11_imagenet(),
        bert_base(),
    ]
}

/// Paper default sub-vector length for an op (§6.1): V=9 for 3x3 convs,
/// V=4 for 1x1 convs / small FC, V=32 for BERT-wide FC.
pub fn default_v(op: &LinearShape) -> usize {
    if op.kernel == 3 && op.d % 9 == 0 {
        9
    } else if op.kernel == 7 && op.d % 49 == 0 {
        49
    } else if op.d >= 768 && op.d % 32 == 0 {
        32
    } else if op.d % 4 == 0 {
        4
    } else if op.d % 2 == 0 {
        2
    } else {
        1
    }
}

// ======================================================================
// Runnable synthetic builders (benches / examples)
// ======================================================================

/// Spec for one stage of a runnable plain-conv CNN.
#[derive(Debug, Clone, Copy)]
pub struct ConvSpec {
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
}

/// Build a runnable dense CNN graph with random weights:
/// convs (+BN+ReLU) per spec, then GAP + FC head.
pub fn build_cnn_graph(
    name: &str,
    input: [usize; 3],
    specs: &[ConvSpec],
    n_classes: usize,
    seed: u64,
) -> Graph {
    let mut rng = Prng::new(seed);
    let mut layers = BTreeMap::new();
    let mut ops = Vec::new();
    let mut cin = input[2];
    for (i, spec) in specs.iter().enumerate() {
        let d = cin * spec.k * spec.k;
        let scale = (2.0 / d as f32).sqrt();
        layers.insert(
            format!("c{i}"),
            LayerParams::Dense {
                w: rng.normal_vec(d * spec.cout, scale),
                b: Some(vec![0.0; spec.cout]),
                m: spec.cout,
            },
        );
        layers.insert(
            format!("bn{i}"),
            LayerParams::Bn {
                gamma: vec![1.0; spec.cout],
                beta: vec![0.0; spec.cout],
                mean: vec![0.0; spec.cout],
                var: vec![1.0; spec.cout],
            },
        );
        ops.push(Op::Conv { layer: format!("c{i}"), k: spec.k, stride: spec.stride });
        ops.push(Op::Bn { layer: format!("bn{i}") });
        ops.push(Op::Relu);
        cin = spec.cout;
    }
    ops.push(Op::Gap);
    let scale = (2.0 / cin as f32).sqrt();
    layers.insert(
        "fc".into(),
        LayerParams::Dense {
            w: rng.normal_vec(cin * n_classes, scale),
            b: Some(vec![0.0; n_classes]),
            m: n_classes,
        },
    );
    ops.push(Op::Linear { layer: "fc".into() });
    Graph {
        name: name.into(),
        input_shape: vec![1, input[0], input[1], input[2]],
        ops,
        layers,
        bert: None,
    }
}

/// Replace every dense conv/linear except the first conv with a LUT layer
/// whose codebooks are k-means-learned from this graph's own activations
/// on `sample` inputs (the rust-native conversion path).
///
/// The returned graph's layers are kernel-tagged: each layer's
/// `LayerParams::kernel_tag()` names the `api::KernelRegistry` entry
/// (`"lut"` for converted layers, `"dense"` for the stem/untouched
/// ones), so `api::SessionBuilder` dispatches without inspecting layer
/// internals.
pub fn lutify_graph(g: &Graph, sample: &Tensor, k_centroids: usize, bits: u8, seed: u64) -> Graph {
    replace_linear_layers(g, sample, "_lut", |_name, acts, rows, d, w, b, m| {
        let v = pick_v(d);
        let cb = learn_codebooks(acts, rows, d, d / v, k_centroids, 8, seed);
        LayerParams::Lut(LutLinear::new(cb, w, m, b.map(<[f32]>::to_vec), bits))
    })
}

/// The graph-rewrite walk shared by [`lutify_graph`] (k-means-only
/// conversion) and `train::compile_graph` (distilled conversion):
/// capture every linear op's input activations on `sample`, keep the
/// first conv dense (paper §6.1), and replace each remaining dense
/// conv/linear with whatever `build(name, acts, rows, d, w, bias, m)`
/// returns. Layers shared by several ops are built once; non-dense
/// layers pass through untouched.
///
/// BERT graphs take the attention capture path: every q/k/v/o/f1/f2
/// projection is replaced on its own captured input, while the tiny
/// classification head stays dense (the attention-path analogue of the
/// dense conv stem).
pub(crate) fn replace_linear_layers(
    g: &Graph,
    sample: &Tensor,
    suffix: &str,
    mut build: impl FnMut(&str, &[f32], usize, usize, &[f32], Option<&[f32]>, usize) -> LayerParams,
) -> Graph {
    if g.bert.is_some() {
        let mut captures: BTreeMap<String, (Vec<f32>, usize, usize)> = BTreeMap::new();
        crate::nn::bert::run_bert_capture(g, sample, &mut captures);
        let mut layers = BTreeMap::new();
        for (name, params) in &g.layers {
            let replaced = match params {
                LayerParams::Dense { w, b, m } if name != "head" => {
                    let (acts, rows, d) = &captures[name];
                    build(name, acts, *rows, *d, w, b.as_deref(), *m)
                }
                _ => params.clone(),
            };
            layers.insert(name.clone(), replaced);
        }
        return Graph {
            name: format!("{}{suffix}", g.name),
            input_shape: g.input_shape.clone(),
            ops: g.ops.clone(),
            layers,
            bert: g.bert.clone(),
        };
    }

    // Re-run the graph, capturing inputs of each linear op.
    let mut captures: BTreeMap<String, (Vec<f32>, usize, usize)> = BTreeMap::new();
    capture_linear_inputs(g, sample, &mut captures);

    let mut new_layers: BTreeMap<String, LayerParams> = BTreeMap::new();
    let mut first_conv_seen = false;
    for op in &g.ops {
        let lname = match op {
            Op::Conv { layer, .. } | Op::Linear { layer } => layer.clone(),
            _ => continue,
        };
        let is_first_conv = matches!(op, Op::Conv { .. }) && !first_conv_seen;
        if matches!(op, Op::Conv { .. }) {
            first_conv_seen = true;
        }
        if is_first_conv || new_layers.contains_key(&lname) {
            continue; // dense stem (paper §6.1) / layer already built
        }
        if let LayerParams::Dense { w, b, m } = &g.layers[&lname] {
            let (acts, rows, d) = &captures[&lname];
            let replaced = build(&lname, acts, *rows, *d, w, b.as_deref(), *m);
            new_layers.insert(lname, replaced);
        }
    }
    let mut layers = BTreeMap::new();
    for (name, params) in &g.layers {
        if let Some(lut) = new_layers.remove(name) {
            layers.insert(name.clone(), lut);
        } else {
            layers.insert(name.clone(), params.clone());
        }
    }
    Graph {
        name: format!("{}{suffix}", g.name),
        input_shape: g.input_shape.clone(),
        ops: g.ops.clone(),
        layers,
        bert: g.bert.clone(),
    }
}

/// Largest supported sub-vector length dividing `d` — the conversion-
/// time heuristic shared by [`lutify_graph`], `train::compile_graph`
/// and the kernel-parity harness (which replays real imported-model
/// shapes through it).
pub fn pick_v(d: usize) -> usize {
    for v in [9usize, 4, 2] {
        if d % v == 0 {
            return v;
        }
    }
    1
}

/// Run the dense graph once, recording each conv/linear input matrix.
fn capture_linear_inputs(
    g: &Graph,
    x: &Tensor,
    out: &mut BTreeMap<String, (Vec<f32>, usize, usize)>,
) {
    use crate::nn::ops as dops;
    let mut cur = x.clone();
    let mut slots: BTreeMap<usize, Tensor> = BTreeMap::new();
    for op in &g.ops {
        match op {
            Op::Conv { layer, k, stride } => {
                let patches = im2col(&cur, *k, *stride);
                out.insert(layer.clone(), (patches.data.clone(), patches.rows(), patches.cols()));
                if let LayerParams::Dense { w, b, m } = &g.layers[layer] {
                    cur = dops::conv2d(&cur, w, b.as_deref(), *m, *k, *stride);
                } else {
                    panic!("capture expects dense graph");
                }
            }
            Op::Linear { layer } => {
                out.insert(layer.clone(), (cur.data.clone(), cur.rows(), cur.cols()));
                if let LayerParams::Dense { w, b, m } = &g.layers[layer] {
                    cur = dops::linear(&cur, w, b.as_deref(), *m);
                } else {
                    panic!("capture expects dense graph");
                }
            }
            Op::Bn { layer } => {
                if let LayerParams::Bn { gamma, beta, mean, var } = &g.layers[layer] {
                    dops::batch_norm(&mut cur, gamma, beta, mean, var);
                }
            }
            Op::Ln { layer } => {
                if let LayerParams::Ln { gamma, beta } = &g.layers[layer] {
                    dops::layer_norm(&mut cur, gamma, beta);
                }
            }
            Op::Relu => dops::relu(&mut cur),
            Op::Gelu => dops::gelu(&mut cur),
            Op::MaxPool { k, stride } => cur = dops::max_pool(&cur, *k, *stride),
            Op::Gap => cur = dops::global_avg_pool(&cur),
            Op::Flatten => {
                let n = cur.shape[0];
                let cols = cur.len() / n;
                cur = cur.reshape(vec![n, cols]);
            }
            Op::Save { slot } => {
                slots.insert(*slot, cur.clone());
            }
            Op::Restore { slot } => cur = slots[slot].clone(),
            Op::Add { slot } => dops::add_inplace(&mut cur, &slots[slot]),
            Op::Mul { slot } => dops::mul_inplace(&mut cur, &slots[slot]),
            Op::Bert => panic!("capture_linear_inputs: bert graphs capture via run_bert_capture"),
        }
    }
}

#[cfg(test)]
#[allow(deprecated)] // exercises the legacy Graph::run entry point
mod tests {
    use super::*;
    use crate::lut::LutOpts;

    #[test]
    fn paper_model_op_counts() {
        // ResNet18 has 17 convs (stem + 16 block convs) + 3 shortcut 1x1
        // + fc = 21 linear ops.
        assert_eq!(resnet18_cifar().ops.len(), 21);
        assert_eq!(vgg11_cifar().ops.len(), 9);
        assert_eq!(bert_base().ops.len(), 72);
        // SENet adds 16 SE linears
        assert_eq!(senet18_cifar().ops.len(), 21 + 16);
    }

    #[test]
    fn first_layer_not_replaced() {
        for m in all_paper_models() {
            if m.name.contains("BERT") {
                continue;
            }
            assert!(!m.ops[0].replaced, "{}", m.name);
            assert!(m.ops[1..].iter().all(|o| o.replaced), "{}", m.name);
        }
    }

    #[test]
    fn default_v_rules() {
        let c3 = conv("x", 8, 64, 64, 3, true);
        assert_eq!(default_v(&c3), 9);
        let c1 = conv("x", 8, 64, 128, 1, true);
        assert_eq!(default_v(&c1), 4);
        let wide = fc("x", 32, 768, 768, true);
        assert_eq!(default_v(&wide), 32);
    }

    #[test]
    fn build_and_run_synthetic_cnn() {
        let g = build_cnn_graph(
            "t",
            [8, 8, 3],
            &[
                ConvSpec { cout: 8, k: 3, stride: 1 },
                ConvSpec { cout: 16, k: 3, stride: 2 },
            ],
            10,
            0,
        );
        let mut rng = Prng::new(1);
        let x = Tensor::new(vec![2, 8, 8, 3], rng.normal_vec(2 * 8 * 8 * 3, 1.0));
        let y = g.run(x, LutOpts::all());
        assert_eq!(y.shape, vec![2, 10]);
    }

    #[test]
    fn lutify_replaces_all_but_first() {
        // Widths chosen so the LUT form is smaller (tables win once
        // M >> K; at toy widths the FP32 centroids dominate).
        let g = build_cnn_graph(
            "t",
            [8, 8, 3],
            &[
                ConvSpec { cout: 16, k: 3, stride: 1 },
                ConvSpec { cout: 64, k: 3, stride: 1 },
            ],
            4,
            0,
        );
        let mut rng = Prng::new(2);
        let x = Tensor::new(vec![4, 8, 8, 3], rng.normal_vec(4 * 8 * 8 * 3, 1.0));
        let gl = lutify_graph(&g, &x, 16, 8, 0);
        assert!(matches!(gl.layers["c0"], LayerParams::Dense { .. }));
        assert!(matches!(gl.layers["c1"], LayerParams::Lut(_)));
        assert!(matches!(gl.layers["fc"], LayerParams::Lut(_)));
        // runs and stays finite
        let y = gl.run(x, LutOpts::all());
        assert!(y.data.iter().all(|v| v.is_finite()));
        // LUT model must be smaller than dense
        assert!(gl.param_bytes() < g.param_bytes());
    }
}
