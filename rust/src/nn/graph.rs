//! Graph executor: runs a `.lutnn` bundle's instruction list with dense
//! and/or LUT layers — the same graph measures both sides of Figs. 7–10.
//!
//! The instruction set mirrors `python/compile/export.py`, plus the
//! importer-facing extensions: conv / bn / ln / relu / gelu / maxpool /
//! gap / flatten / linear / save / restore / add / mul / bert.
//! `save`/`restore`/`add`/`mul` move activations through numbered slots
//! to express residual and gating blocks without a full dataflow graph.

use std::collections::BTreeMap;

use crate::lut::{LutLinear, LutOpts};
use crate::nn::ops;
use crate::tensor::im2col::im2col;
use crate::tensor::Tensor;

/// Parameters of one named layer.
#[derive(Clone)]
pub enum LayerParams {
    Dense { w: Vec<f32>, b: Option<Vec<f32>>, m: usize },
    Lut(LutLinear),
    Bn { gamma: Vec<f32>, beta: Vec<f32>, mean: Vec<f32>, var: Vec<f32> },
    Ln { gamma: Vec<f32>, beta: Vec<f32> },
    Embedding { tok: Vec<f32>, pos: Vec<f32>, d: usize },
}

impl LayerParams {
    /// Deployed parameter bytes (Fig. 10 model-memory accounting).
    pub fn param_bytes(&self) -> usize {
        match self {
            LayerParams::Dense { w, b, .. } => {
                4 * (w.len() + b.as_ref().map(|x| x.len()).unwrap_or(0))
            }
            LayerParams::Lut(l) => l.deployed_bytes(),
            LayerParams::Bn { gamma, .. } => 4 * gamma.len() * 4,
            LayerParams::Ln { gamma, .. } => 4 * gamma.len() * 2,
            LayerParams::Embedding { tok, pos, .. } => 4 * (tok.len() + pos.len()),
        }
    }

    /// Registry tag of the kernel that executes this layer, if it is a
    /// linear (conv / FC) layer — the hook `api::SessionBuilder` uses to
    /// pick an implementation from the `KernelRegistry`.
    pub fn kernel_tag(&self) -> Option<&'static str> {
        match self {
            LayerParams::Dense { .. } => Some("dense"),
            LayerParams::Lut(_) => Some("lut"),
            _ => None,
        }
    }
}

/// One graph instruction.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    Conv { layer: String, k: usize, stride: usize },
    Bn { layer: String },
    /// LayerNorm over the channel (last) axis, via a named `Ln` layer.
    Ln { layer: String },
    Relu,
    Gelu,
    MaxPool { k: usize, stride: usize },
    Gap,
    /// Collapse everything but the batch dim: `[N, ...] -> [N, prod]`.
    /// NHWC activations are row-major, so this is a pure reshape.
    Flatten,
    Linear { layer: String },
    Save { slot: usize },
    Restore { slot: usize },
    Add { slot: usize },
    /// Elementwise multiply with a saved slot (gating blocks).
    Mul { slot: usize },
    Bert,
}

/// Executable model: instruction list + named parameters (+ BERT config).
#[derive(Clone)]
pub struct Graph {
    pub name: String,
    pub input_shape: Vec<usize>,
    pub ops: Vec<Op>,
    pub layers: BTreeMap<String, LayerParams>,
    pub bert: Option<crate::nn::bert::BertConfig>,
}

impl Graph {
    /// Total deployed parameter bytes (Fig. 10).
    pub fn param_bytes(&self) -> usize {
        self.layers.values().map(|l| l.param_bytes()).sum()
    }

    /// Count of LUT vs dense linear ops (diagnostics).
    pub fn lut_fraction(&self) -> (usize, usize) {
        let mut lut = 0;
        let mut dense = 0;
        for l in self.layers.values() {
            match l {
                LayerParams::Lut(_) => lut += 1,
                LayerParams::Dense { .. } => dense += 1,
                _ => {}
            }
        }
        (lut, dense)
    }

    /// Run a forward pass. `batch` replaces the leading input dim.
    ///
    /// Legacy shim: allocates fresh activations per call and takes the
    /// input by value. Prefer compiling once via `api::SessionBuilder`
    /// and calling `Session::run(&input, &mut output)` — bitwise the
    /// same outputs, no per-call allocation.
    #[deprecated(since = "0.2.0", note = "use api::SessionBuilder -> Session::run")]
    pub fn run(&self, x: Tensor, opts: LutOpts) -> Tensor {
        if self.bert.is_some() {
            return crate::nn::bert::run_bert(self, &x, opts);
        }
        let mut cur = x;
        let mut slots: BTreeMap<usize, Tensor> = BTreeMap::new();
        let mut idx_scratch: Vec<u16> = Vec::new();
        for op in &self.ops {
            cur = self.step(op, cur, opts, &mut slots, &mut idx_scratch);
        }
        cur
    }

    fn layer(&self, name: &str) -> &LayerParams {
        self.layers
            .get(name)
            .unwrap_or_else(|| panic!("graph references unknown layer '{name}'"))
    }

    fn step(
        &self,
        op: &Op,
        cur: Tensor,
        opts: LutOpts,
        slots: &mut BTreeMap<usize, Tensor>,
        idx_scratch: &mut Vec<u16>,
    ) -> Tensor {
        match op {
            Op::Conv { layer, k, stride } => {
                let (n, h, w) = (cur.shape[0], cur.shape[1], cur.shape[2]);
                match self.layer(layer) {
                    LayerParams::Dense { w: wm, b, m } => {
                        ops::conv2d(&cur, wm, b.as_deref(), *m, *k, *stride)
                    }
                    LayerParams::Lut(lut) => {
                        let patches = im2col(&cur, *k, *stride);
                        let rows = patches.rows();
                        let mut out = vec![0.0f32; rows * lut.m];
                        lut.forward_into(&patches.data, rows, opts, idx_scratch, &mut out);
                        let ho = crate::tensor::im2col::same_out_size(h, *stride);
                        let wo = crate::tensor::im2col::same_out_size(w, *stride);
                        Tensor::new(vec![n, ho, wo, lut.m], out)
                    }
                    _ => panic!("layer '{layer}' is not a conv"),
                }
            }
            Op::Bn { layer } => {
                let mut cur = cur;
                match self.layer(layer) {
                    LayerParams::Bn { gamma, beta, mean, var } => {
                        ops::batch_norm(&mut cur, gamma, beta, mean, var)
                    }
                    _ => panic!("layer '{layer}' is not bn"),
                }
                cur
            }
            Op::Ln { layer } => {
                let mut cur = cur;
                match self.layer(layer) {
                    LayerParams::Ln { gamma, beta } => ops::layer_norm(&mut cur, gamma, beta),
                    _ => panic!("layer '{layer}' is not layernorm"),
                }
                cur
            }
            Op::Relu => {
                let mut cur = cur;
                ops::relu(&mut cur);
                cur
            }
            Op::Gelu => {
                let mut cur = cur;
                ops::gelu(&mut cur);
                cur
            }
            Op::MaxPool { k, stride } => ops::max_pool(&cur, *k, *stride),
            Op::Gap => ops::global_avg_pool(&cur),
            Op::Flatten => {
                let n = cur.shape[0];
                let cols = cur.len() / n;
                cur.reshape(vec![n, cols])
            }
            Op::Linear { layer } => match self.layer(layer) {
                LayerParams::Dense { w, b, m } => ops::linear(&cur, w, b.as_deref(), *m),
                LayerParams::Lut(lut) => {
                    let rows = cur.rows();
                    let mut out = vec![0.0f32; rows * lut.m];
                    lut.forward_into(&cur.data, rows, opts, idx_scratch, &mut out);
                    Tensor::new(vec![rows, lut.m], out)
                }
                _ => panic!("layer '{layer}' is not linear"),
            },
            Op::Save { slot } => {
                slots.insert(*slot, cur.clone());
                cur
            }
            Op::Restore { slot } => slots
                .get(slot)
                .unwrap_or_else(|| panic!("restore from empty slot {slot}"))
                .clone(),
            Op::Add { slot } => {
                let mut cur = cur;
                let other = slots
                    .get(slot)
                    .unwrap_or_else(|| panic!("add from empty slot {slot}"));
                ops::add_inplace(&mut cur, other);
                cur
            }
            Op::Mul { slot } => {
                let mut cur = cur;
                let other = slots
                    .get(slot)
                    .unwrap_or_else(|| panic!("mul from empty slot {slot}"));
                ops::mul_inplace(&mut cur, other);
                cur
            }
            Op::Bert => unreachable!("bert graphs are dispatched in run()"),
        }
    }
}

#[cfg(test)]
#[allow(deprecated)] // the legacy Graph::run shim is under test here
mod tests {
    use super::*;
    use crate::pq::kmeans::learn_codebooks;
    use crate::util::prng::Prng;

    fn dense_layer(rng: &mut Prng, d: usize, m: usize) -> LayerParams {
        LayerParams::Dense { w: rng.normal_vec(d * m, 0.3), b: Some(vec![0.1; m]), m }
    }

    fn tiny_graph(rng: &mut Prng) -> Graph {
        let mut layers = BTreeMap::new();
        layers.insert("c0".into(), dense_layer(rng, 3 * 9, 4));
        layers.insert(
            "bn0".into(),
            LayerParams::Bn {
                gamma: vec![1.0; 4],
                beta: vec![0.0; 4],
                mean: vec![0.0; 4],
                var: vec![1.0; 4],
            },
        );
        layers.insert("fc".into(), dense_layer(rng, 4, 5));
        Graph {
            name: "tiny".into(),
            input_shape: vec![1, 8, 8, 3],
            ops: vec![
                Op::Conv { layer: "c0".into(), k: 3, stride: 1 },
                Op::Bn { layer: "bn0".into() },
                Op::Relu,
                Op::Gap,
                Op::Linear { layer: "fc".into() },
            ],
            layers,
            bert: None,
        }
    }

    #[test]
    fn runs_dense_graph() {
        let mut rng = Prng::new(0);
        let g = tiny_graph(&mut rng);
        let x = Tensor::new(vec![2, 8, 8, 3], rng.normal_vec(2 * 8 * 8 * 3, 1.0));
        let y = g.run(x, LutOpts::all());
        assert_eq!(y.shape, vec![2, 5]);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn residual_slots() {
        let mut rng = Prng::new(1);
        let mut g = tiny_graph(&mut rng);
        // save -> relu -> add(saved) == relu(x) + x on the GAP features
        g.ops = vec![
            Op::Gap,
            Op::Save { slot: 0 },
            Op::Relu,
            Op::Add { slot: 0 },
        ];
        let x = Tensor::new(vec![1, 2, 2, 3], vec![-1.0; 12]);
        let y = g.run(x, LutOpts::all());
        // gap = -1 per channel; relu -> 0; add -> -1
        assert_eq!(y.data, vec![-1.0, -1.0, -1.0]);
    }

    #[test]
    fn lut_conv_close_to_dense_conv() {
        let mut rng = Prng::new(2);
        let g = tiny_graph(&mut rng);
        let x = Tensor::new(vec![4, 8, 8, 3], rng.normal_vec(4 * 8 * 8 * 3, 1.0));
        let dense_out = g.run(x.clone(), LutOpts::all());

        // Convert c0 to LUT with many centroids (high fidelity).
        let patches = im2col(&x, 3, 1);
        let cb = learn_codebooks(&patches.data, patches.rows(), 27, 3, 64, 15, 0);
        let (w, b, m) = match g.layers.get("c0").unwrap() {
            LayerParams::Dense { w, b, m } => (w.clone(), b.clone(), *m),
            _ => unreachable!(),
        };
        let lut = LutLinear::new(cb, &w, m, b, 8);
        let mut g2 = g;
        g2.layers.insert("c0".into(), LayerParams::Lut(lut));
        let lut_out = g2.run(x, LutOpts::all());
        assert_eq!(lut_out.shape, dense_out.shape);
        // K=64 over 512 rows: approximation should be loose but correlated
        let mse = lut_out.mse(&dense_out);
        let sig: f32 =
            dense_out.data.iter().map(|v| v * v).sum::<f32>() / dense_out.len() as f32;
        assert!(mse < sig, "mse={mse} sig={sig}");
    }

    #[test]
    fn param_bytes_positive() {
        let mut rng = Prng::new(3);
        let g = tiny_graph(&mut rng);
        assert!(g.param_bytes() > 0);
        assert_eq!(g.lut_fraction(), (0, 2));
    }

    #[test]
    #[should_panic(expected = "unknown layer")]
    fn unknown_layer_panics() {
        let mut rng = Prng::new(4);
        let mut g = tiny_graph(&mut rng);
        g.ops = vec![Op::Linear { layer: "nope".into() }];
        g.run(Tensor::zeros(vec![1, 4]), LutOpts::all());
    }
}
