//! Blocked single-precision GEMM — the dense baseline's compute core.
//!
//! `C[n, m] += A[n, d] @ B[d, m]`, row-major. Register-blocked 4x8
//! micro-kernel with k-inner loops the compiler auto-vectorizes; cache
//! blocking over (n, d). Stands in for the BLAS the paper's baselines
//! (ONNX Runtime / TVM) carry.

const MC: usize = 64; // rows per cache block
const KC: usize = 256; // depth per cache block

/// out += a @ b. `out` must be n*m, zeroed by the caller if needed.
pub fn gemm(a: &[f32], b: &[f32], out: &mut [f32], n: usize, d: usize, m: usize) {
    assert_eq!(a.len(), n * d);
    assert_eq!(b.len(), d * m);
    assert_eq!(out.len(), n * m);
    for i0 in (0..n).step_by(MC) {
        let i1 = (i0 + MC).min(n);
        for k0 in (0..d).step_by(KC) {
            let k1 = (k0 + KC).min(d);
            gemm_block(a, b, out, i0, i1, k0, k1, d, m);
        }
    }
}

#[inline]
fn gemm_block(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    i0: usize,
    i1: usize,
    k0: usize,
    k1: usize,
    d: usize,
    m: usize,
) {
    let mut i = i0;
    // 4-row micro-kernel
    while i + 4 <= i1 {
        for k in k0..k1 {
            let a0 = a[i * d + k];
            let a1 = a[(i + 1) * d + k];
            let a2 = a[(i + 2) * d + k];
            let a3 = a[(i + 3) * d + k];
            let brow = &b[k * m..(k + 1) * m];
            let (o0, rest) = out[i * m..].split_at_mut(m);
            let (o1, rest) = rest.split_at_mut(m);
            let (o2, rest) = rest.split_at_mut(m);
            let o3 = &mut rest[..m];
            for j in 0..m {
                let bv = brow[j];
                o0[j] += a0 * bv;
                o1[j] += a1 * bv;
                o2[j] += a2 * bv;
                o3[j] += a3 * bv;
            }
        }
        i += 4;
    }
    while i < i1 {
        for k in k0..k1 {
            let av = a[i * d + k];
            let brow = &b[k * m..(k + 1) * m];
            let orow = &mut out[i * m..(i + 1) * m];
            for j in 0..m {
                orow[j] += av * brow[j];
            }
        }
        i += 1;
    }
}

/// Naive triple loop (test oracle).
pub fn gemm_naive(a: &[f32], b: &[f32], n: usize, d: usize, m: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * m];
    for i in 0..n {
        for k in 0..d {
            for j in 0..m {
                out[i * m + j] += a[i * d + k] * b[k * m + j];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prng::Prng, prop};

    #[test]
    fn matches_naive() {
        let mut rng = Prng::new(0);
        for &(n, d, m) in &[(1, 1, 1), (5, 7, 3), (64, 128, 32), (17, 33, 9)] {
            let a = rng.normal_vec(n * d, 1.0);
            let b = rng.normal_vec(d * m, 1.0);
            let mut out = vec![0.0f32; n * m];
            gemm(&a, &b, &mut out, n, d, m);
            let want = gemm_naive(&a, &b, n, d, m);
            prop::assert_close(&out, &want, 1e-4, 1e-4)
                .unwrap_or_else(|e| panic!("({n},{d},{m}): {e}"));
        }
    }

    #[test]
    fn accumulates_into_out() {
        let a = vec![1.0f32];
        let b = vec![2.0f32];
        let mut out = vec![10.0f32];
        gemm(&a, &b, &mut out, 1, 1, 1);
        assert_eq!(out[0], 12.0);
    }

    #[test]
    fn property_random_shapes() {
        prop::check(40, |g| {
            let n = g.usize(1..32);
            let d = g.usize(1..48);
            let m = g.usize(1..24);
            let a = g.f32_vec(n * d, 1.0);
            let b = g.f32_vec(d * m, 1.0);
            let mut out = vec![0.0f32; n * m];
            gemm(&a, &b, &mut out, n, d, m);
            prop::assert_close(&out, &gemm_naive(&a, &b, n, d, m), 1e-3, 1e-3)
        });
    }
}
