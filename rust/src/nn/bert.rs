//! BERT-style encoder forward (matches `python/compile/models.MiniBert`):
//! token+position embedding, post-LN transformer blocks (MHA + FFN),
//! mean-pool, head. The q/k/v/o and FFN linears dispatch dense-or-LUT
//! exactly like the CNN path; attention itself stays exact (paper §8:
//! scaled dot-product attention has no weights to precompute).

use std::collections::BTreeMap;

use crate::lut::LutOpts;
use crate::nn::graph::{Graph, LayerParams};
use crate::nn::ops;
use crate::tensor::Tensor;

#[derive(Debug, Clone)]
pub struct BertConfig {
    pub vocab: usize,
    pub seq_len: usize,
    pub d: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub n_out: usize,
}

fn apply_linear(g: &Graph, name: &str, x: &Tensor, opts: LutOpts) -> Tensor {
    match g.layers.get(name).unwrap_or_else(|| panic!("missing layer {name}")) {
        LayerParams::Dense { w, b, m } => ops::linear(x, w, b.as_deref(), *m),
        LayerParams::Lut(lut) => {
            let rows = x.rows();
            let out = lut.forward(&x.data, rows, opts);
            Tensor::new(vec![rows, lut.m], out)
        }
        _ => panic!("layer {name} is not linear"),
    }
}

fn apply_ln(g: &Graph, name: &str, x: &mut Tensor) {
    match g.layers.get(name).unwrap_or_else(|| panic!("missing layer {name}")) {
        LayerParams::Ln { gamma, beta } => ops::layer_norm(x, gamma, beta),
        _ => panic!("layer {name} is not layernorm"),
    }
}

/// Activation captures keyed by layer name: `(data, rows, cols)` of the
/// input matrix each linear projection consumed during a forward pass.
type Caps<'a> = Option<&'a mut BTreeMap<String, (Vec<f32>, usize, usize)>>;

fn record(caps: &mut Caps, name: String, x: &Tensor) {
    if let Some(c) = caps.as_mut() {
        c.insert(name, (x.data.clone(), x.rows(), x.cols()));
    }
}

/// Forward pass. `tokens` is a [N, T] tensor whose f32 values are token
/// ids (the wire/bundle format carries them as f32 for uniformity).
pub fn run_bert(g: &Graph, tokens: &Tensor, opts: LutOpts) -> Tensor {
    run_bert_inner(g, tokens, opts, &mut None)
}

/// Dense-teacher forward that also records every linear projection's
/// input activations (q/k/v/o/f1/f2 per block, plus the head) — the
/// capture hook `nn::models::replace_linear_layers` uses for BERT
/// graphs, mirroring `capture_linear_inputs` on the CNN path.
pub(crate) fn run_bert_capture(
    g: &Graph,
    tokens: &Tensor,
    out: &mut BTreeMap<String, (Vec<f32>, usize, usize)>,
) -> Tensor {
    run_bert_inner(g, tokens, LutOpts::deployed(), &mut Some(out))
}

fn run_bert_inner(g: &Graph, tokens: &Tensor, opts: LutOpts, caps: &mut Caps) -> Tensor {
    let cfg = g.bert.as_ref().expect("not a bert graph");
    let (n, t) = (tokens.shape[0], tokens.shape[1]);
    assert!(t <= cfg.seq_len, "sequence longer than model ({t} > {})", cfg.seq_len);
    let d = cfg.d;
    let (tok_emb, pos_emb) = match g.layers.get("emb").expect("missing emb") {
        LayerParams::Embedding { tok, pos, .. } => (tok, pos),
        _ => panic!("emb is not an embedding"),
    };

    // h[n, t, d] flattened to [n*t, d]
    let mut h = vec![0.0f32; n * t * d];
    for i in 0..n {
        for j in 0..t {
            let id = tokens.data[i * t + j] as usize;
            assert!(id < cfg.vocab, "token id {id} out of vocab");
            let dst = &mut h[(i * t + j) * d..(i * t + j + 1) * d];
            for (x, (&e, &p)) in dst
                .iter_mut()
                .zip(tok_emb[id * d..(id + 1) * d].iter().zip(&pos_emb[j * d..(j + 1) * d]))
            {
                *x = e + p;
            }
        }
    }
    let mut h = Tensor::new(vec![n * t, d], h);
    let nh = cfg.n_heads;
    let dh = d / nh;
    let scale = 1.0 / (dh as f32).sqrt();

    for l in 0..cfg.n_layers {
        record(caps, format!("l{l}q"), &h);
        record(caps, format!("l{l}k"), &h);
        record(caps, format!("l{l}v"), &h);
        let q = apply_linear(g, &format!("l{l}q"), &h, opts);
        let k = apply_linear(g, &format!("l{l}k"), &h, opts);
        let v = apply_linear(g, &format!("l{l}v"), &h, opts);
        // attention per (batch, head)
        let mut ctx = vec![0.0f32; n * t * d];
        let mut att = vec![0.0f32; t * t];
        for b in 0..n {
            for head in 0..nh {
                // scores[t, t]
                for i in 0..t {
                    let qrow = &q.data[(b * t + i) * d + head * dh..(b * t + i) * d + (head + 1) * dh];
                    for j in 0..t {
                        let krow = &k.data[(b * t + j) * d + head * dh..(b * t + j) * d + (head + 1) * dh];
                        att[i * t + j] = qrow.iter().zip(krow).map(|(a, b)| a * b).sum::<f32>() * scale;
                    }
                }
                let mut att_t = Tensor::new(vec![t, t], std::mem::take(&mut att));
                ops::softmax_rows(&mut att_t);
                att = att_t.data;
                for i in 0..t {
                    let dst = &mut ctx[(b * t + i) * d + head * dh..(b * t + i) * d + (head + 1) * dh];
                    for j in 0..t {
                        let w = att[i * t + j];
                        let vrow = &v.data[(b * t + j) * d + head * dh..(b * t + j) * d + (head + 1) * dh];
                        for (o, &vv) in dst.iter_mut().zip(vrow) {
                            *o += w * vv;
                        }
                    }
                }
            }
        }
        let ctx = Tensor::new(vec![n * t, d], ctx);
        record(caps, format!("l{l}o"), &ctx);
        let o = apply_linear(g, &format!("l{l}o"), &ctx, opts);
        ops::add_inplace(&mut h, &o);
        apply_ln(g, &format!("l{l}ln1"), &mut h);

        record(caps, format!("l{l}f1"), &h);
        let mut f1 = apply_linear(g, &format!("l{l}f1"), &h, opts);
        ops::gelu(&mut f1);
        record(caps, format!("l{l}f2"), &f1);
        let f2 = apply_linear(g, &format!("l{l}f2"), &f1, opts);
        ops::add_inplace(&mut h, &f2);
        apply_ln(g, &format!("l{l}ln2"), &mut h);
    }

    // mean pool over sequence -> [n, d]
    let mut pooled = vec![0.0f32; n * d];
    for b in 0..n {
        for j in 0..t {
            for c in 0..d {
                pooled[b * d + c] += h.data[(b * t + j) * d + c];
            }
        }
        for c in 0..d {
            pooled[b * d + c] /= t as f32;
        }
    }
    let pooled = Tensor::new(vec![n, d], pooled);
    record(caps, "head".into(), &pooled);
    apply_linear(g, "head", &pooled, opts)
}

#[cfg(test)]
#[allow(deprecated)] // exercises the legacy Graph::run entry point
pub(crate) mod tests {
    use super::*;
    use crate::util::prng::Prng;
    use std::collections::BTreeMap;

    pub fn synthetic_bert(cfg: &BertConfig, seed: u64) -> Graph {
        let mut rng = Prng::new(seed);
        let mut layers = BTreeMap::new();
        layers.insert(
            "emb".into(),
            LayerParams::Embedding {
                tok: rng.normal_vec(cfg.vocab * cfg.d, 0.1),
                pos: rng.normal_vec(cfg.seq_len * cfg.d, 0.1),
                d: cfg.d,
            },
        );
        for l in 0..cfg.n_layers {
            for (nm, di, dm) in [
                ("q", cfg.d, cfg.d),
                ("k", cfg.d, cfg.d),
                ("v", cfg.d, cfg.d),
                ("o", cfg.d, cfg.d),
                ("f1", cfg.d, cfg.d_ff),
                ("f2", cfg.d_ff, cfg.d),
            ] {
                layers.insert(
                    format!("l{l}{nm}"),
                    LayerParams::Dense {
                        w: rng.normal_vec(di * dm, 0.15),
                        b: Some(vec![0.0; dm]),
                        m: dm,
                    },
                );
            }
            for nm in ["ln1", "ln2"] {
                layers.insert(
                    format!("l{l}{nm}"),
                    LayerParams::Ln { gamma: vec![1.0; cfg.d], beta: vec![0.0; cfg.d] },
                );
            }
        }
        layers.insert(
            "head".into(),
            LayerParams::Dense {
                w: rng.normal_vec(cfg.d * cfg.n_out, 0.15),
                b: Some(vec![0.0; cfg.n_out]),
                m: cfg.n_out,
            },
        );
        Graph {
            name: "bert-test".into(),
            input_shape: vec![1, cfg.seq_len],
            ops: vec![crate::nn::graph::Op::Bert],
            layers,
            bert: Some(cfg.clone()),
        }
    }

    #[test]
    fn forward_shape_and_finite() {
        let cfg = BertConfig {
            vocab: 32,
            seq_len: 8,
            d: 16,
            n_heads: 2,
            d_ff: 32,
            n_layers: 2,
            n_out: 4,
        };
        let g = synthetic_bert(&cfg, 0);
        let mut rng = Prng::new(1);
        let tokens: Vec<f32> = (0..3 * 8).map(|_| rng.below(32) as f32).collect();
        let y = g.run(Tensor::new(vec![3, 8], tokens), LutOpts::all());
        assert_eq!(y.shape, vec![3, 4]);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn attention_is_permutation_sensitive() {
        // Positional embeddings must make token order matter.
        let cfg = BertConfig {
            vocab: 16,
            seq_len: 4,
            d: 8,
            n_heads: 2,
            d_ff: 16,
            n_layers: 1,
            n_out: 2,
        };
        let g = synthetic_bert(&cfg, 2);
        let a = g.run(Tensor::new(vec![1, 4], vec![1.0, 2.0, 3.0, 4.0]), LutOpts::all());
        let b = g.run(Tensor::new(vec![1, 4], vec![4.0, 3.0, 2.0, 1.0]), LutOpts::all());
        assert!(a.max_abs_diff(&b) > 1e-4);
    }

    #[test]
    #[should_panic(expected = "out of vocab")]
    fn oov_token_panics() {
        let cfg = BertConfig {
            vocab: 4,
            seq_len: 2,
            d: 8,
            n_heads: 1,
            d_ff: 8,
            n_layers: 1,
            n_out: 2,
        };
        let g = synthetic_bert(&cfg, 3);
        g.run(Tensor::new(vec![1, 2], vec![99.0, 0.0]), LutOpts::all());
    }
}
