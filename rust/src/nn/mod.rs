//! Dense reference engine + graph executor + model shape zoo.
//!
//! The baseline LUT-NN is compared against (stands in for ONNX Runtime /
//! TVM on this testbed — DESIGN.md §Substitutions): im2col convolution
//! over a blocked GEMM, BatchNorm folding, pooling, and a small
//! instruction-list graph executor that runs `.lutnn` bundles with either
//! dense or LUT layers (so the same graph measures both sides of every
//! figure).

pub mod bert;
pub mod gemm;
pub mod graph;
pub mod models;
pub mod ops;
