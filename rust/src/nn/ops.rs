//! Dense layer ops over NHWC tensors: conv (im2col+GEMM), linear,
//! BatchNorm (inference), ReLU, max pool, global average pool, softmax.

use crate::nn::gemm::gemm;
use crate::tensor::im2col::{im2col, same_out_size};
use crate::tensor::Tensor;

/// `out[r, :] += bias` for every row — the shared bias epilogue of the
/// dense ops and every `api::LinearKernel`. Bitwise-equivalent to the
/// inline per-row loop it replaces (same add order per element).
pub fn add_bias_rows(out: &mut [f32], bias: &[f32]) {
    assert!(!bias.is_empty(), "empty bias");
    for row in out.chunks_exact_mut(bias.len()) {
        for (o, &b) in row.iter_mut().zip(bias) {
            *o += b;
        }
    }
}

/// Dense conv: weight as matrix [Cin*k*k, Cout] (channel-major patch
/// layout — the shared im2col contract), bias [Cout].
pub fn conv2d(x: &Tensor, weight: &[f32], bias: Option<&[f32]>, cout: usize, k: usize, stride: usize) -> Tensor {
    let (n, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
    let patches = im2col(x, k, stride);
    let rows = patches.rows();
    let d = patches.cols();
    assert_eq!(weight.len(), d * cout, "conv weight shape mismatch");
    let mut out = vec![0.0f32; rows * cout];
    gemm(&patches.data, weight, &mut out, rows, d, cout);
    if let Some(b) = bias {
        add_bias_rows(&mut out, b);
    }
    let (ho, wo) = (same_out_size(h, stride), same_out_size(w, stride));
    Tensor::new(vec![n, ho, wo, cout], out)
}

/// Linear: x [rows, D] @ w [D, M] + b.
pub fn linear(x: &Tensor, weight: &[f32], bias: Option<&[f32]>, m: usize) -> Tensor {
    let rows = x.rows();
    let d = x.cols();
    assert_eq!(weight.len(), d * m);
    let mut out = vec![0.0f32; rows * m];
    gemm(&x.data, weight, &mut out, rows, d, m);
    if let Some(b) = bias {
        add_bias_rows(&mut out, b);
    }
    Tensor::new(vec![rows, m], out)
}

/// Inference BatchNorm over the channel (last) axis of NHWC/2-D input.
pub fn batch_norm(x: &mut Tensor, gamma: &[f32], beta: &[f32], mean: &[f32], var: &[f32]) {
    let ch = *x.shape.last().unwrap();
    assert_eq!(gamma.len(), ch);
    // Fold into scale/shift once.
    let scale: Vec<f32> = (0..ch).map(|c| gamma[c] / (var[c] + 1e-5).sqrt()).collect();
    let shift: Vec<f32> = (0..ch).map(|c| beta[c] - mean[c] * scale[c]).collect();
    for row in x.data.chunks_exact_mut(ch) {
        for (v, c) in row.iter_mut().zip(0..ch) {
            *v = *v * scale[c] + shift[c];
        }
    }
}

/// LayerNorm over the last axis (BERT path).
pub fn layer_norm(x: &mut Tensor, gamma: &[f32], beta: &[f32]) {
    let ch = *x.shape.last().unwrap();
    for row in x.data.chunks_exact_mut(ch) {
        let mean: f32 = row.iter().sum::<f32>() / ch as f32;
        let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / ch as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for (v, c) in row.iter_mut().zip(0..ch) {
            *v = (*v - mean) * inv * gamma[c] + beta[c];
        }
    }
}

pub fn relu(x: &mut Tensor) {
    for v in &mut x.data {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// GELU (tanh approximation, matches jax.nn.gelu default).
pub fn gelu(x: &mut Tensor) {
    for v in &mut x.data {
        let x3 = *v * *v * *v;
        *v = 0.5 * *v * (1.0 + ((0.7978845608 * (*v + 0.044715 * x3)) as f32).tanh());
    }
}

/// 2x2/stride-2-style max pool (VALID padding).
pub fn max_pool(x: &Tensor, k: usize, stride: usize) -> Tensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let ho = (h - k) / stride + 1;
    let wo = (w - k) / stride + 1;
    let mut out = vec![0.0f32; n * ho * wo * c];
    max_pool_into(x, k, stride, &mut out);
    Tensor::new(vec![n, ho, wo, c], out)
}

/// Non-allocating max pool into a caller-owned `N*Ho*Wo*C` buffer
/// (the `Session` hot path). Returns `(ho, wo)`.
pub fn max_pool_into(x: &Tensor, k: usize, stride: usize, out: &mut [f32]) -> (usize, usize) {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let ho = (h - k) / stride + 1;
    let wo = (w - k) / stride + 1;
    assert_eq!(out.len(), n * ho * wo * c, "max_pool_into buffer size");
    out.fill(f32::NEG_INFINITY);
    for ni in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                for ky in 0..k {
                    for kx in 0..k {
                        let iy = oy * stride + ky;
                        let ix = ox * stride + kx;
                        let src = x.nhwc_offset(ni, iy, ix, 0);
                        let dst = ((ni * ho + oy) * wo + ox) * c;
                        for ci in 0..c {
                            let v = x.data[src + ci];
                            if v > out[dst + ci] {
                                out[dst + ci] = v;
                            }
                        }
                    }
                }
            }
        }
    }
    (ho, wo)
}

/// Global average pool NHWC -> [N, C].
pub fn global_avg_pool(x: &Tensor) -> Tensor {
    let (n, _, _, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let mut out = vec![0.0f32; n * c];
    global_avg_pool_into(x, &mut out);
    Tensor::new(vec![n, c], out)
}

/// Non-allocating global average pool into a caller-owned `N*C` buffer.
pub fn global_avg_pool_into(x: &Tensor, out: &mut [f32]) {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    assert_eq!(out.len(), n * c, "global_avg_pool_into buffer size");
    let inv = 1.0 / (h * w) as f32;
    for ni in 0..n {
        for ci in 0..c {
            let mut s = 0.0f32;
            for hy in 0..h {
                for wx in 0..w {
                    s += x.data[x.nhwc_offset(ni, hy, wx, ci)];
                }
            }
            out[ni * c + ci] = s * inv;
        }
    }
}

/// Row-wise softmax of a 2-D tensor (attention / output probabilities).
pub fn softmax_rows(x: &mut Tensor) {
    let cols = *x.shape.last().unwrap();
    for row in x.data.chunks_exact_mut(cols) {
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Elementwise add (residual connections). Shapes must match.
pub fn add_inplace(x: &mut Tensor, other: &Tensor) {
    assert_eq!(x.shape, other.shape);
    for (a, &b) in x.data.iter_mut().zip(&other.data) {
        *a += b;
    }
}

/// Elementwise multiply (gating / squeeze-excite style). Shapes must match.
pub fn mul_inplace(x: &mut Tensor, other: &Tensor) {
    assert_eq!(x.shape, other.shape);
    for (a, &b) in x.data.iter_mut().zip(&other.data) {
        *a *= b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn conv_identity_kernel() {
        // 1x1 conv with identity weight = passthrough
        let x = Tensor::new(vec![1, 2, 2, 2], (0..8).map(|i| i as f32).collect());
        let w = vec![1.0, 0.0, 0.0, 1.0]; // [2,2] identity
        let y = conv2d(&x, &w, None, 2, 1, 1);
        assert_eq!(y.data, x.data);
    }

    #[test]
    fn conv_shapes_with_stride() {
        let x = Tensor::zeros(vec![2, 8, 8, 3]);
        let w = vec![0.0; 27 * 16];
        let y = conv2d(&x, &w, None, 16, 3, 2);
        assert_eq!(y.shape, vec![2, 4, 4, 16]);
    }

    #[test]
    fn conv_counts_neighbors() {
        // All-ones input, all-ones 3x3 kernel, 1 channel: interior = 9.
        let x = Tensor::new(vec![1, 4, 4, 1], vec![1.0; 16]);
        let w = vec![1.0; 9];
        let y = conv2d(&x, &w, None, 1, 3, 1);
        assert_eq!(y.at4(0, 1, 1, 0), 9.0);
        assert_eq!(y.at4(0, 0, 0, 0), 4.0); // corner
    }

    #[test]
    fn bn_normalizes() {
        let mut x = Tensor::new(vec![1, 1, 1, 2], vec![4.0, 10.0]);
        batch_norm(&mut x, &[1.0, 2.0], &[0.5, 0.0], &[4.0, 10.0], &[1.0, 4.0]);
        assert!((x.data[0] - 0.5).abs() < 1e-5);
        assert!(x.data[1].abs() < 1e-5);
    }

    #[test]
    fn layer_norm_zero_mean_unit_var() {
        let mut rng = Prng::new(0);
        let mut x = Tensor::new(vec![4, 16], rng.normal_vec(64, 3.0));
        layer_norm(&mut x, &vec![1.0; 16], &vec![0.0; 16]);
        for row in x.data.chunks(16) {
            let mean: f32 = row.iter().sum::<f32>() / 16.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 16.0;
            assert!(mean.abs() < 1e-4);
            assert!((var - 1.0).abs() < 1e-2);
        }
    }

    #[test]
    fn pool_and_gap() {
        let x = Tensor::new(
            vec![1, 2, 2, 1],
            vec![1.0, 5.0, 3.0, 2.0],
        );
        let mp = max_pool(&x, 2, 2);
        assert_eq!(mp.data, vec![5.0]);
        let gap = global_avg_pool(&x);
        assert_eq!(gap.data, vec![11.0 / 4.0]);
    }

    #[test]
    fn softmax_rows_normalized() {
        let mut x = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 0.0, 0.0, 0.0]);
        softmax_rows(&mut x);
        for row in x.data.chunks(3) {
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        }
        assert!((x.data[3] - 1.0 / 3.0).abs() < 1e-5);
    }

    #[test]
    fn relu_and_gelu() {
        let mut x = Tensor::new(vec![1, 3], vec![-1.0, 0.0, 2.0]);
        relu(&mut x);
        assert_eq!(x.data, vec![0.0, 0.0, 2.0]);
        let mut g = Tensor::new(vec![1, 2], vec![-10.0, 10.0]);
        gelu(&mut g);
        assert!(g.data[0].abs() < 1e-3);
        assert!((g.data[1] - 10.0).abs() < 1e-3);
    }
}
