//! MADDNESS baseline: hashing-based sub-vector encoding (paper §2.1).
//!
//! A 4-level balanced binary regression tree per codebook: level l splits
//! on one fixed dimension against per-node thresholds; leaves are the
//! K = 2^depth buckets. Higher quantization error than k-means argmin at
//! equal K — the effect Fig. 3b demonstrates. Mirrors the python
//! implementation in `python/compile/maddness.py`.

use crate::util::prng::Prng;

#[derive(Debug, Clone)]
pub struct HashTree {
    pub depth: usize,
    /// split dimension per level, len `depth`
    pub split_dims: Vec<usize>,
    /// thresholds[level][node] for node in 0..2^level
    pub thresholds: Vec<Vec<f32>>,
    /// bucket prototypes [K=2^depth, V]
    pub prototypes: Vec<f32>,
    pub v: usize,
}

impl HashTree {
    pub fn n_buckets(&self) -> usize {
        1 << self.depth
    }

    /// Encode one sub-vector by traversing the tree.
    #[inline]
    pub fn encode(&self, sub: &[f32]) -> usize {
        let mut node = 0usize;
        for level in 0..self.depth {
            let dim = self.split_dims[level];
            let thr = self.thresholds[level][node];
            node = 2 * node + usize::from(sub[dim] > thr);
        }
        node
    }
}

/// Greedy balanced-tree learning over sub-vectors `x` [n, v]:
/// split dim = largest within-bucket variance mass, threshold = median.
pub fn learn_hash_tree(x: &[f32], n: usize, v: usize, depth: usize, seed: u64) -> HashTree {
    assert!(n > 0 && v > 0);
    assert_eq!(x.len(), n * v);
    let mut rng = Prng::new(seed);
    let mut buckets = vec![0usize; n];
    let mut split_dims = Vec::with_capacity(depth);
    let mut thresholds = Vec::with_capacity(depth);

    for level in 0..depth {
        let n_buckets = 1usize << level;
        // score dims by within-bucket variance mass
        let mut scores = vec![0.0f64; v];
        for b in 0..n_buckets {
            let rows: Vec<usize> = (0..n).filter(|&i| buckets[i] == b).collect();
            if rows.len() < 2 {
                continue;
            }
            for dim in 0..v {
                let vals: Vec<f32> = rows.iter().map(|&i| x[i * v + dim]).collect();
                let mean = vals.iter().sum::<f32>() / vals.len() as f32;
                let var: f32 = vals.iter().map(|a| (a - mean) * (a - mean)).sum::<f32>()
                    / vals.len() as f32;
                scores[dim] += (var * vals.len() as f32) as f64;
            }
        }
        let dim = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        split_dims.push(dim);

        let mut level_thresholds = vec![0.0f32; n_buckets];
        let mut new_buckets = buckets.clone();
        for b in 0..n_buckets {
            let mut vals: Vec<f32> = (0..n)
                .filter(|&i| buckets[i] == b)
                .map(|i| x[i * v + dim])
                .collect();
            let thr = if vals.is_empty() {
                0.0
            } else {
                vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
                vals[vals.len() / 2] // median -> balanced split
            };
            level_thresholds[b] = thr;
            for i in 0..n {
                if buckets[i] == b {
                    new_buckets[i] = 2 * b + usize::from(x[i * v + dim] > thr);
                }
            }
        }
        thresholds.push(level_thresholds);
        buckets = new_buckets;
    }

    // bucket-mean prototypes
    let k = 1usize << depth;
    let mut prototypes = vec![0.0f32; k * v];
    for b in 0..k {
        let rows: Vec<usize> = (0..n).filter(|&i| buckets[i] == b).collect();
        if rows.is_empty() {
            let pick = rng.below(n);
            prototypes[b * v..(b + 1) * v].copy_from_slice(&x[pick * v..(pick + 1) * v]);
        } else {
            for dim in 0..v {
                let sum: f32 = rows.iter().map(|&i| x[i * v + dim]).sum();
                prototypes[b * v + dim] = sum / rows.len() as f32;
            }
        }
    }
    HashTree { depth, split_dims, thresholds, prototypes, v }
}

/// A MADDNESS-encoded linear operator: one tree per codebook + tables.
#[derive(Debug, Clone)]
pub struct MaddnessOp {
    pub trees: Vec<HashTree>,
    /// [C, K, M]
    pub table: Vec<f32>,
    pub m: usize,
    pub bias: Option<Vec<f32>>,
}

/// Learn from sample activations [n, D] and a weight matrix [D, M].
pub fn learn_maddness(
    activations: &[f32],
    n: usize,
    d: usize,
    weight: &[f32],
    m: usize,
    bias: Option<Vec<f32>>,
    c: usize,
    depth: usize,
    seed: u64,
) -> MaddnessOp {
    assert_eq!(d % c, 0);
    let v = d / c;
    let k = 1usize << depth;
    let mut trees = Vec::with_capacity(c);
    let mut table = vec![0.0f32; c * k * m];
    let mut slab = vec![0.0f32; n * v];
    for ci in 0..c {
        for i in 0..n {
            slab[i * v..(i + 1) * v]
                .copy_from_slice(&activations[i * d + ci * v..i * d + (ci + 1) * v]);
        }
        let tree = learn_hash_tree(&slab, n, v, depth, seed + ci as u64);
        for b in 0..k {
            let proto = &tree.prototypes[b * v..(b + 1) * v];
            let out = &mut table[(ci * k + b) * m..(ci * k + b + 1) * m];
            for (vi, &pv) in proto.iter().enumerate() {
                let wrow = &weight[(ci * v + vi) * m..(ci * v + vi + 1) * m];
                for (o, &w) in out.iter_mut().zip(wrow) {
                    *o += pv * w;
                }
            }
        }
        trees.push(tree);
    }
    MaddnessOp { trees, table, m, bias }
}

/// Approximate `a @ B` (a: [n, D]) via hash encoding + table accumulation.
pub fn maddness_amm(op: &MaddnessOp, a: &[f32], n: usize, d: usize) -> Vec<f32> {
    let c = op.trees.len();
    let v = d / c;
    let k = op.trees[0].n_buckets();
    let m = op.m;
    let mut out = vec![0.0f32; n * m];
    for i in 0..n {
        let dst = &mut out[i * m..(i + 1) * m];
        for (ci, tree) in op.trees.iter().enumerate() {
            let sub = &a[i * d + ci * v..i * d + (ci + 1) * v];
            let b = tree.encode(sub);
            let row = &op.table[(ci * k + b) * m..(ci * k + b + 1) * m];
            for (o, &t) in dst.iter_mut().zip(row) {
                *o += t;
            }
        }
        if let Some(bias) = &op.bias {
            for (o, &bb) in dst.iter_mut().zip(bias) {
                *o += bb;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pq::kmeans;
    use crate::util::prng::Prng;

    #[test]
    fn tree_encode_in_range_and_deterministic() {
        let mut rng = Prng::new(0);
        let x = rng.normal_vec(256 * 4, 1.0);
        let tree = learn_hash_tree(&x, 256, 4, 4, 0);
        for i in 0..256 {
            let b = tree.encode(&x[i * 4..(i + 1) * 4]);
            assert!(b < 16);
            assert_eq!(b, tree.encode(&x[i * 4..(i + 1) * 4]));
        }
    }

    #[test]
    fn median_splits_are_balanced() {
        let mut rng = Prng::new(1);
        let x = rng.normal_vec(1024 * 4, 1.0);
        let tree = learn_hash_tree(&x, 1024, 4, 4, 0);
        let mut counts = vec![0usize; 16];
        for i in 0..1024 {
            counts[tree.encode(&x[i * 4..(i + 1) * 4])] += 1;
        }
        assert!(*counts.iter().max().unwrap() < 1024 / 16 * 4, "{counts:?}");
    }

    #[test]
    fn amm_captures_signal() {
        let mut rng = Prng::new(2);
        let (n, d, m, c) = (512, 12, 8, 3);
        let a = rng.normal_vec(n * d, 1.0);
        let w = rng.normal_vec(d * m, 1.0);
        let op = learn_maddness(&a, n, d, &w, m, None, c, 4, 0);
        let approx = maddness_amm(&op, &a, n, d);
        // exact
        let mut exact = vec![0.0f32; n * m];
        for i in 0..n {
            for j in 0..m {
                exact[i * m + j] =
                    (0..d).map(|t| a[i * d + t] * w[t * m + j]).sum();
            }
        }
        let err: f32 = approx.iter().zip(&exact).map(|(x, y)| (x - y) * (x - y)).sum::<f32>() / (n * m) as f32;
        let base: f32 = exact.iter().map(|x| x * x).sum::<f32>() / (n * m) as f32;
        assert!(err < base, "err={err} base={base}");
        assert!(err > 1e-6);
    }

    #[test]
    fn hashing_worse_than_kmeans_at_equal_k() {
        // Paper §2.1 / Fig. 3: hashing encoding has higher quantization
        // error than k-means argmin encoding.
        let mut rng = Prng::new(3);
        let (n, v) = (1024, 4);
        let x = rng.normal_vec(n * v, 1.0);
        let tree = learn_hash_tree(&x, n, v, 4, 0);
        let (centers, _) = kmeans::kmeans(&x, n, v, 16, 25, 0);
        let d2 = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(p, q)| (p - q) * (p - q)).sum()
        };
        let mut err_hash = 0.0f64;
        let mut err_kmeans = 0.0f64;
        for i in 0..n {
            let sub = &x[i * v..(i + 1) * v];
            let b = tree.encode(sub);
            err_hash += d2(sub, &tree.prototypes[b * v..(b + 1) * v]) as f64;
            let best = (0..16)
                .map(|c| d2(sub, &centers[c * v..(c + 1) * v]))
                .fold(f32::INFINITY, f32::min);
            err_kmeans += best as f64;
        }
        assert!(err_hash > err_kmeans, "hash={err_hash} kmeans={err_kmeans}");
    }

    #[test]
    fn bias_applied() {
        let mut rng = Prng::new(4);
        let (n, d, m) = (16, 4, 3);
        let a = rng.normal_vec(n * d, 1.0);
        let w = rng.normal_vec(d * m, 1.0);
        let bias = vec![1.0, 2.0, 3.0];
        let op = learn_maddness(&a, n, d, &w, m, Some(bias.clone()), 2, 3, 0);
        let mut op0 = op.clone();
        op0.bias = None;
        let with = maddness_amm(&op, &a, n, d);
        let without = maddness_amm(&op0, &a, n, d);
        for i in 0..n {
            for j in 0..m {
                assert!((with[i * m + j] - without[i * m + j] - bias[j]).abs() < 1e-5);
            }
        }
    }
}
