//! Range-based symmetric scalar quantization (paper §3.3).
//!
//! r = s * q with zero-point 0; s = max|value| / (2^(n-1) - 1) per group
//! (one group = one codebook's K*M table slab). INT8 is the deployed
//! format; INT4 is supported for the §6.3 quantization-level ablation
//! (stored widened to i8 — commodity SIMD has no native int4 lanes, as
//! the paper notes).

/// Quantize `values` ([groups, group_len] row-major) symmetrically per
/// group. Returns (quantized i8, per-group scale).
pub fn quantize_symmetric_per_group(
    values: &[f32],
    groups: usize,
    group_len: usize,
    bits: u8,
) -> (Vec<i8>, Vec<f32>) {
    assert_eq!(values.len(), groups * group_len);
    assert!((2..=8).contains(&bits), "bits must be in 2..=8");
    let qmax = ((1i32 << (bits - 1)) - 1) as f32;
    let qmin = -qmax - 1.0;
    let mut q = vec![0i8; values.len()];
    let mut scales = vec![1.0f32; groups];
    for g in 0..groups {
        let slab = &values[g * group_len..(g + 1) * group_len];
        let absmax = slab.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let scale = if absmax > 0.0 { absmax / qmax } else { 1.0 };
        scales[g] = scale;
        for (dst, &v) in q[g * group_len..(g + 1) * group_len].iter_mut().zip(slab) {
            *dst = (v / scale).round().clamp(qmin, qmax) as i8;
        }
    }
    (q, scales)
}

/// Dequantize back to f32 (test/diagnostic path; the engine accumulates
/// in integer space and applies the scale once per codebook).
pub fn dequantize_per_group(
    q: &[i8],
    scales: &[f32],
    group_len: usize,
) -> Vec<f32> {
    q.iter()
        .enumerate()
        .map(|(i, &v)| v as f32 * scales[i / group_len])
        .collect()
}

/// Max representable quantization error for a group scale.
pub fn max_error(scale: f32) -> f32 {
    scale * 0.5
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prng::Prng, prop};

    #[test]
    fn roundtrip_error_bounded() {
        let mut rng = Prng::new(0);
        let vals = rng.normal_vec(4 * 32, 3.0);
        let (q, s) = quantize_symmetric_per_group(&vals, 4, 32, 8);
        let deq = dequantize_per_group(&q, &s, 32);
        for (g, chunk) in vals.chunks(32).enumerate() {
            for (i, &v) in chunk.iter().enumerate() {
                let err = (v - deq[g * 32 + i]).abs();
                assert!(err <= max_error(s[g]) + 1e-6, "err={err} scale={}", s[g]);
            }
        }
    }

    #[test]
    fn int4_range() {
        let mut rng = Prng::new(1);
        let vals = rng.normal_vec(2 * 16, 1.0);
        let (q, _) = quantize_symmetric_per_group(&vals, 2, 16, 4);
        assert!(q.iter().all(|&v| (-8..=7).contains(&v)));
    }

    #[test]
    fn zero_group_scale_one() {
        let vals = vec![0.0f32; 8];
        let (q, s) = quantize_symmetric_per_group(&vals, 1, 8, 8);
        assert!(q.iter().all(|&v| v == 0));
        assert_eq!(s[0], 1.0);
    }

    #[test]
    fn property_int8_roundtrip() {
        prop::check(50, |g| {
            let groups = g.usize(1..5);
            let len = g.usize(1..64);
            let vals = g.f32_vec(groups * len, 5.0);
            let (q, s) = quantize_symmetric_per_group(&vals, groups, len, 8);
            let deq = dequantize_per_group(&q, &s, len);
            for i in 0..vals.len() {
                let tol = max_error(s[i / len]) + 1e-6;
                if (vals[i] - deq[i]).abs() > tol {
                    return Err(format!("i={i}: {} vs {}", vals[i], deq[i]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn coarser_bits_higher_error() {
        let mut rng = Prng::new(2);
        let vals = rng.normal_vec(256, 2.0);
        let err = |bits| {
            let (q, s) = quantize_symmetric_per_group(&vals, 1, 256, bits);
            let deq = dequantize_per_group(&q, &s, 256);
            vals.iter()
                .zip(&deq)
                .map(|(a, b)| (a - b).abs() as f64)
                .sum::<f64>()
        };
        assert!(err(4) > err(8));
    }
}
