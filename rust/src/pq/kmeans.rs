//! k-means (Lloyd + k-means++ seeding) for PQ codebook learning (Eq. 1).
//!
//! The trained python models ship their centroids inside `.lutnn` bundles,
//! but the rust side also learns codebooks itself: the serving coordinator
//! can LUT-convert a dense bundle on the fly (examples/image_pipeline) and
//! the benches build synthetic LUT layers from sampled activations.

use crate::util::prng::Prng;

use super::Codebooks;

/// Lloyd's algorithm over rows of `x` ([n, v] row-major).
/// Returns (centroids [k, v], assignments [n]).
pub fn kmeans(
    x: &[f32],
    n: usize,
    v: usize,
    k: usize,
    iters: usize,
    seed: u64,
) -> (Vec<f32>, Vec<usize>) {
    assert_eq!(x.len(), n * v);
    assert!(n > 0 && k > 0);
    let mut rng = Prng::new(seed);

    // --- k-means++ seeding -------------------------------------------
    let mut centers = vec![0.0f32; k * v];
    let first = rng.below(n);
    centers[..v].copy_from_slice(&x[first * v..(first + 1) * v]);
    let mut d2: Vec<f32> = (0..n).map(|i| dist2(&x[i * v..(i + 1) * v], &centers[..v])).collect();
    for ci in 1..k {
        let total: f64 = d2.iter().map(|&d| d as f64).sum();
        let pick = if total <= 1e-12 {
            rng.below(n)
        } else {
            // sample proportional to d2
            let mut target = rng.uniform() * total;
            let mut idx = n - 1;
            for (i, &d) in d2.iter().enumerate() {
                target -= d as f64;
                if target <= 0.0 {
                    idx = i;
                    break;
                }
            }
            idx
        };
        centers[ci * v..(ci + 1) * v].copy_from_slice(&x[pick * v..(pick + 1) * v]);
        for i in 0..n {
            let d = dist2(&x[i * v..(i + 1) * v], &centers[ci * v..(ci + 1) * v]);
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }

    // --- Lloyd iterations ---------------------------------------------
    let mut assign = vec![0usize; n];
    for _ in 0..iters {
        let mut changed = false;
        for i in 0..n {
            let row = &x[i * v..(i + 1) * v];
            let mut best = 0usize;
            let mut best_d = f32::INFINITY;
            for c in 0..k {
                let d = dist2(row, &centers[c * v..(c + 1) * v]);
                if d < best_d {
                    best_d = d;
                    best = c;
                }
            }
            if assign[i] != best {
                assign[i] = best;
                changed = true;
            }
        }
        if !changed {
            break;
        }
        // recompute means
        let mut sums = vec![0.0f64; k * v];
        let mut counts = vec![0usize; k];
        for i in 0..n {
            let c = assign[i];
            counts[c] += 1;
            for (s, &val) in sums[c * v..(c + 1) * v].iter_mut().zip(&x[i * v..(i + 1) * v]) {
                *s += val as f64;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // Respawn an empty cluster at a random point — drawn
                // from the same seeded Prng as the k-means++ init, so
                // codebook learning stays run-to-run deterministic for
                // a fixed seed (pinned by `deterministic_for_seed`,
                // which the train::distill determinism test builds on).
                let pick = rng.below(n);
                centers[c * v..(c + 1) * v]
                    .copy_from_slice(&x[pick * v..(pick + 1) * v]);
            } else {
                for (dst, &s) in centers[c * v..(c + 1) * v].iter_mut().zip(&sums[c * v..(c + 1) * v]) {
                    *dst = (s / counts[c] as f64) as f32;
                }
            }
        }
    }
    (centers, assign)
}

#[inline]
fn dist2(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Learn all C codebooks from activations [n, D] (paper Eq. 1): split
/// each row into C sub-vectors of length V = D / C and cluster each slab.
pub fn learn_codebooks(
    activations: &[f32],
    n: usize,
    d: usize,
    c: usize,
    k: usize,
    iters: usize,
    seed: u64,
) -> Codebooks {
    assert_eq!(activations.len(), n * d);
    assert_eq!(d % c, 0, "D={d} not divisible by C={c}");
    let v = d / c;
    let mut data = vec![0.0f32; c * k * v];
    let mut slab = vec![0.0f32; n * v];
    for ci in 0..c {
        for i in 0..n {
            slab[i * v..(i + 1) * v]
                .copy_from_slice(&activations[i * d + ci * v..i * d + (ci + 1) * v]);
        }
        let (centers, _) = kmeans(&slab, n, v, k, iters, seed + ci as u64);
        data[ci * k * v..(ci + 1) * k * v].copy_from_slice(&centers);
    }
    Codebooks::new(c, k, v, data)
}

/// Mean quantization error (Eq. 1 objective) of codebooks on activations.
pub fn quantization_mse(activations: &[f32], n: usize, cb: &Codebooks) -> f32 {
    let d = cb.input_dim();
    assert_eq!(activations.len(), n * d);
    let mut total = 0.0f64;
    for i in 0..n {
        for c in 0..cb.c {
            let sub = &activations[i * d + c * cb.v..i * d + (c + 1) * cb.v];
            let mut best = f32::INFINITY;
            for k in 0..cb.k {
                let dd = dist2(sub, cb.centroid(c, k));
                if dd < best {
                    best = dd;
                }
            }
            total += best as f64;
        }
    }
    (total / (n * cb.c) as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn recovers_separated_clusters() {
        let mut rng = Prng::new(0);
        let true_centers = [[0.0f32, 0.0], [10.0, 0.0], [0.0, 10.0], [10.0, 10.0]];
        let mut x = Vec::new();
        for c in &true_centers {
            for _ in 0..50 {
                x.push(c[0] + 0.1 * rng.normal());
                x.push(c[1] + 0.1 * rng.normal());
            }
        }
        let (centers, assign) = kmeans(&x, 200, 2, 4, 30, 1);
        for tc in &true_centers {
            let best = (0..4)
                .map(|c| dist2(tc, &centers[c * 2..c * 2 + 2]))
                .fold(f32::INFINITY, f32::min);
            assert!(best < 0.25, "missed center {tc:?}");
        }
        assert_eq!(assign.len(), 200);
    }

    #[test]
    fn more_centroids_lower_mse() {
        let mut rng = Prng::new(1);
        let n = 256;
        let d = 8;
        let x = rng.normal_vec(n * d, 1.0);
        let mse: Vec<f32> = [2usize, 8, 32]
            .iter()
            .map(|&k| {
                let cb = learn_codebooks(&x, n, d, 2, k, 20, 0);
                quantization_mse(&x, n, &cb)
            })
            .collect();
        assert!(mse[0] > mse[1] && mse[1] > mse[2], "{mse:?}");
    }

    #[test]
    fn identical_points_stay_finite() {
        let x = vec![1.0f32; 64 * 4];
        let (centers, _) = kmeans(&x, 64, 4, 4, 10, 0);
        assert!(centers.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn deterministic_for_seed() {
        // Data with fewer distinct points than centroids forces the
        // empty-cluster respawn path; determinism must survive it.
        let mut x = Vec::new();
        for i in 0..60 {
            let base = (i % 3) as f32 * 5.0;
            x.extend_from_slice(&[base, base + 1.0]);
        }
        for seed in [0u64, 7, 42] {
            let (ca, aa) = kmeans(&x, 60, 2, 8, 20, seed);
            let (cb, ab) = kmeans(&x, 60, 2, 8, 20, seed);
            assert_eq!(aa, ab, "assignments must be identical (seed {seed})");
            for (a, b) in ca.iter().zip(&cb) {
                assert_eq!(a.to_bits(), b.to_bits(), "centers must match (seed {seed})");
            }
        }
        // learn_codebooks plumbs the same seed through every slab
        let mut rng = Prng::new(9);
        let acts = rng.normal_vec(64 * 8, 1.0);
        let cb1 = learn_codebooks(&acts, 64, 8, 2, 16, 12, 5);
        let cb2 = learn_codebooks(&acts, 64, 8, 2, 16, 12, 5);
        for (a, b) in cb1.data.iter().zip(&cb2.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "codebooks must be bit-identical");
        }
    }

    #[test]
    fn codebook_shapes() {
        let mut rng = Prng::new(2);
        let x = rng.normal_vec(128 * 36, 1.0);
        let cb = learn_codebooks(&x, 128, 36, 4, 16, 5, 0);
        assert_eq!((cb.c, cb.k, cb.v), (4, 16, 9));
    }
}
