//! Product-quantization substrate: codebooks, k-means learning, scalar
//! quantization and the MADDNESS hashing baseline (paper §2).

pub mod kmeans;
pub mod maddness;
pub mod quantize;

use crate::tensor::QTable;

/// Codebooks for one linear operator: centroids [C, K, V] row-major.
#[derive(Debug, Clone)]
pub struct Codebooks {
    pub data: Vec<f32>,
    pub c: usize,
    pub k: usize,
    pub v: usize,
}

impl Codebooks {
    pub fn new(c: usize, k: usize, v: usize, data: Vec<f32>) -> Codebooks {
        assert_eq!(data.len(), c * k * v);
        Codebooks { data, c, k, v }
    }

    #[inline]
    pub fn centroid(&self, c: usize, k: usize) -> &[f32] {
        let base = (c * self.k + k) * self.v;
        &self.data[base..base + self.v]
    }

    /// Per-codebook slab [K, V].
    #[inline]
    pub fn codebook(&self, c: usize) -> &[f32] {
        let base = c * self.k * self.v;
        &self.data[base..base + self.k * self.v]
    }

    pub fn input_dim(&self) -> usize {
        self.c * self.v
    }

    /// |p|^2 per centroid, [C, K] — precomputed for the distance fast path.
    pub fn sq_norms(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.c * self.k];
        for c in 0..self.c {
            for k in 0..self.k {
                out[c * self.k + k] =
                    self.centroid(c, k).iter().map(|x| x * x).sum();
            }
        }
        out
    }
}

/// Build the lookup table T[c,k] = centroid(c,k) . B[c*V..(c+1)*V, :]
/// (paper Eq. 3). `weight` is [D, M] row-major.
pub fn build_table(cb: &Codebooks, weight: &[f32], m: usize) -> Vec<f32> {
    let d = cb.input_dim();
    assert_eq!(weight.len(), d * m, "weight must be [D={d}, M={m}]");
    let mut table = vec![0.0f32; cb.c * cb.k * m];
    for c in 0..cb.c {
        for k in 0..cb.k {
            let cent = cb.centroid(c, k);
            let out = &mut table[(c * cb.k + k) * m..(c * cb.k + k + 1) * m];
            for (vi, &pv) in cent.iter().enumerate() {
                let wrow = &weight[(c * cb.v + vi) * m..(c * cb.v + vi + 1) * m];
                for (o, &w) in out.iter_mut().zip(wrow) {
                    *o += pv * w;
                }
            }
        }
    }
    table
}

/// Quantize a real-valued table [C, K, M] into a QTable (paper §3.3).
pub fn quantize_table(table: &[f32], c: usize, k: usize, m: usize, bits: u8) -> QTable {
    let (data, scale) = quantize::quantize_symmetric_per_group(table, c, k * m, bits);
    QTable { data, c, k, m, scale }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codebook_indexing() {
        let cb = Codebooks::new(2, 2, 3, (0..12).map(|i| i as f32).collect());
        assert_eq!(cb.centroid(1, 0), &[6.0, 7.0, 8.0]);
        assert_eq!(cb.codebook(0).len(), 6);
        assert_eq!(cb.input_dim(), 6);
    }

    #[test]
    fn sq_norms() {
        let cb = Codebooks::new(1, 2, 2, vec![3.0, 4.0, 0.0, 1.0]);
        assert_eq!(cb.sq_norms(), vec![25.0, 1.0]);
    }

    #[test]
    fn build_table_matches_naive() {
        // C=1, K=2, V=2, M=2; B = identity-ish
        let cb = Codebooks::new(1, 2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let weight = vec![1.0, 0.0, 0.0, 1.0]; // [2,2] identity
        let t = build_table(&cb, &weight, 2);
        assert_eq!(t, vec![1.0, 2.0, 3.0, 4.0]);
    }
}
