//! The committed model zoo: three importable fixtures spanning the
//! format's surface (residual CNN, keyword-spotting net with flatten,
//! BERT-tiny), compiled into the binary via `include_str!` so tests and
//! the CLI can exercise the full import -> compile -> serve path with
//! no filesystem dependencies. Weights are derived deterministically
//! from each fixture's seed, so these models are stable across
//! machines and releases.

use std::collections::BTreeSet;

use super::{import_str, ImportError};
use crate::nn::graph::{Graph, LayerParams};

pub const CNN_TINY: &str = include_str!("../../../models/zoo/cnn_tiny.nnef");
pub const KWS_TINY: &str = include_str!("../../../models/zoo/kws_tiny.nnef");
pub const BERT_TINY: &str = include_str!("../../../models/zoo/bert_tiny.nnef");

#[derive(Debug, Clone, Copy)]
pub struct ZooModel {
    pub name: &'static str,
    pub source: &'static str,
}

pub const MODELS: [ZooModel; 3] = [
    ZooModel { name: "cnn_tiny", source: CNN_TINY },
    ZooModel { name: "kws_tiny", source: KWS_TINY },
    ZooModel { name: "bert_tiny", source: BERT_TINY },
];

/// Import a zoo model by name.
pub fn import(name: &str) -> Result<Graph, ImportError> {
    let m = MODELS
        .iter()
        .find(|m| m.name == name)
        .ok_or_else(|| ImportError::new(0, format!("no zoo model named '{name}'")))?;
    import_str(m.source)
}

/// Deduplicated `(d_in, d_out)` shapes of every dense layer across the
/// zoo — the realistic layer geometries the kernel parity harness draws
/// from, instead of purely random dims.
pub fn linear_shapes() -> Vec<(usize, usize)> {
    let mut set = BTreeSet::new();
    for m in &MODELS {
        let g = import_str(m.source).expect("committed zoo fixtures always import");
        for p in g.layers.values() {
            if let LayerParams::Dense { w, m, .. } = p {
                set.insert((w.len() / m, *m));
            }
        }
    }
    set.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_fixture_imports_with_expected_topology() {
        let cnn = import("cnn_tiny").unwrap();
        assert_eq!(cnn.input_shape, vec![1, 16, 16, 3]);
        assert!(cnn.bert.is_none());
        assert!(cnn.layers.contains_key("c2"));

        let kws = import("kws_tiny").unwrap();
        let LayerParams::Dense { w, m, .. } = &kws.layers["y"] else { panic!() };
        assert_eq!((w.len() / m, *m), (1152, 12), "flattened feature width");

        let bert = import("bert_tiny").unwrap();
        let cfg = bert.bert.as_ref().expect("bert_tiny must lower to a fused bert graph");
        assert_eq!((cfg.vocab, cfg.seq_len, cfg.d, cfg.n_layers, cfg.n_out), (64, 16, 32, 2, 4));

        assert!(import("nope").is_err());
    }

    #[test]
    fn linear_shapes_cover_all_three_models() {
        let shapes = linear_shapes();
        // one geometry from each fixture
        assert!(shapes.contains(&(27, 16)), "cnn_tiny stem: {shapes:?}");
        assert!(shapes.contains(&(1152, 12)), "kws_tiny fc: {shapes:?}");
        assert!(shapes.contains(&(32, 32)), "bert_tiny projection: {shapes:?}");
        let mut dedup = shapes.clone();
        dedup.dedup();
        assert_eq!(dedup, shapes, "shapes must be deduplicated");
    }
}
