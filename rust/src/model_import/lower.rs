//! Lowering: validated [`ModuleIr`] to an executable dense [`Graph`].
//!
//! Two jobs happen here. First, the dataflow described by named tensors
//! is flattened onto the engine's linear instruction chain: a tensor
//! consumed anywhere other than immediately after it is produced gets a
//! numbered slot (`Save`/`Restore`), and `add`/`mul` reference their
//! off-chain operand by slot — the same convention the hand-built
//! residual models use. Identity `transpose` nodes are pure renames and
//! emit nothing. Second, every parameterized layer is materialized with
//! deterministic weights: layer seed = model seed XOR FNV-1a(name), He
//! initialization for conv/linear, so the text fixture alone pins the
//! imported graph bit for bit.
//!
//! The BERT triple (`embedding`/`attention`/`mean_pool`) does not map
//! onto the instruction chain — attention is a fused [`Op::Bert`]
//! graph with the conventional layer names `nn::bert` executes (`emb`,
//! `l{i}{q,k,v,o,f1,f2,ln1,ln2}`, `head`). It is accepted only as the
//! exact chain `embedding -> attention -> mean_pool -> linear`; the
//! head linear stays dense downstream (the attention-path analogue of
//! the paper's dense first conv, §6.1).

use std::collections::BTreeMap;

use super::ir::{ModuleIr, NodeIr, OpIr};
use super::ImportError;
use crate::nn::bert::BertConfig;
use crate::nn::graph::{Graph, LayerParams, Op};
use crate::util::prng::Prng;

fn fnv1a64(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Per-layer weight stream: independent of statement order and of every
/// other layer, so renaming one tensor never reshuffles another's init.
fn layer_rng(model_seed: u64, name: &str) -> Prng {
    Prng::new(model_seed ^ fnv1a64(name))
}

/// He-initialized dense layer `[d_in, d_out]` with zero bias.
fn dense(rng: &mut Prng, d_in: usize, d_out: usize) -> LayerParams {
    let scale = (2.0 / d_in as f32).sqrt();
    LayerParams::Dense { w: rng.normal_vec(d_in * d_out, scale), b: Some(vec![0.0; d_out]), m: d_out }
}

/// Near-identity affine params (gamma ~ 1, beta ~ 0) so norm layers are
/// exercised without swamping the signal.
fn affine(rng: &mut Prng, c: usize) -> (Vec<f32>, Vec<f32>) {
    let gamma = rng.normal_vec(c, 0.1).iter().map(|g| 1.0 + g).collect();
    let beta = rng.normal_vec(c, 0.1);
    (gamma, beta)
}

pub fn lower(ir: &ModuleIr) -> Result<Graph, ImportError> {
    if ir.nodes.iter().any(|n| {
        matches!(n.op, OpIr::Embedding { .. } | OpIr::Attention { .. } | OpIr::MeanPool)
    }) {
        return lower_bert(ir);
    }

    // Alias resolution: identity transposes are renames.
    let mut canon: BTreeMap<&str, &str> = BTreeMap::new();
    let resolve = |canon: &BTreeMap<&str, &str>, mut name: &str| -> String {
        while let Some(&src) = canon.get(name) {
            name = src;
        }
        name.to_string()
    };
    for n in &ir.nodes {
        if matches!(n.op, OpIr::Alias) {
            canon.insert(&n.name, &n.args[0]);
        }
    }

    // Pass A: walk the chain, marking every tensor that is consumed
    // while not current — those need slots.
    let chain_ops = || ir.nodes.iter().filter(|n| !matches!(n.op, OpIr::Alias));
    let mut needs_slot: Vec<String> = Vec::new();
    let mut mark = |needs_slot: &mut Vec<String>, name: String| {
        if !needs_slot.contains(&name) {
            needs_slot.push(name);
        }
    };
    // (chain input, off-chain operand) per node, resolved to canonical names
    let mut cur = resolve(&canon, &ir.input_name);
    let mut routed: Vec<(String, Option<String>)> = Vec::new();
    for n in chain_ops() {
        let a0 = resolve(&canon, &n.args[0]);
        let (chain, other) = match n.op {
            OpIr::Add | OpIr::Mul => {
                let a1 = resolve(&canon, &n.args[1]);
                if a0 == cur {
                    (a0, Some(a1))
                } else if a1 == cur {
                    (a1, Some(a0))
                } else {
                    (a0, Some(a1))
                }
            }
            _ => (a0, None),
        };
        if chain != cur {
            mark(&mut needs_slot, chain.clone());
        }
        if let Some(o) = &other {
            mark(&mut needs_slot, o.clone());
        }
        routed.push((chain, other));
        cur = n.name.clone();
    }
    let out_name = resolve(&canon, &ir.output);
    if out_name != cur {
        mark(&mut needs_slot, out_name.clone());
    }

    // Slot ids in definition order: input first, then node results.
    let mut slots: BTreeMap<String, usize> = BTreeMap::new();
    let input_canon = resolve(&canon, &ir.input_name);
    for name in std::iter::once(input_canon.as_str())
        .chain(chain_ops().map(|n| n.name.as_str()))
    {
        if needs_slot.iter().any(|s| s == name) && !slots.contains_key(name) {
            let id = slots.len();
            slots.insert(name.to_string(), id);
        }
    }
    if let Some(stale) = needs_slot.iter().find(|s| !slots.contains_key(*s)) {
        // Unreachable by construction (every tensor is the input or a
        // node result), but fail typed rather than emit a bad graph.
        return Err(ImportError::new(ir.output_line, format!("cannot slot tensor '{stale}'")));
    }

    // Pass B: emit instructions and materialize layers.
    let mut ops = Vec::new();
    let mut layers = BTreeMap::new();
    let mut cur = input_canon.clone();
    if let Some(&s) = slots.get(&input_canon) {
        ops.push(Op::Save { slot: s });
    }
    for (n, (chain, other)) in chain_ops().zip(&routed) {
        if *chain != cur {
            ops.push(Op::Restore { slot: slots[chain] });
        }
        let mut rng = layer_rng(ir.seed, &n.name);
        match &n.op {
            OpIr::Conv { out, k, stride } => {
                let cin = *in_shape(ir, n).last().unwrap();
                layers.insert(n.name.clone(), dense(&mut rng, cin * k * k, *out));
                ops.push(Op::Conv { layer: n.name.clone(), k: *k, stride: *stride });
            }
            OpIr::Linear { out } => {
                let d = in_shape(ir, n)[1];
                layers.insert(n.name.clone(), dense(&mut rng, d, *out));
                ops.push(Op::Linear { layer: n.name.clone() });
            }
            OpIr::BatchNorm => {
                let c = *in_shape(ir, n).last().unwrap();
                let (gamma, beta) = affine(&mut rng, c);
                layers.insert(
                    n.name.clone(),
                    LayerParams::Bn { gamma, beta, mean: vec![0.0; c], var: vec![1.0; c] },
                );
                ops.push(Op::Bn { layer: n.name.clone() });
            }
            OpIr::LayerNorm => {
                let c = *in_shape(ir, n).last().unwrap();
                let (gamma, beta) = affine(&mut rng, c);
                layers.insert(n.name.clone(), LayerParams::Ln { gamma, beta });
                ops.push(Op::Ln { layer: n.name.clone() });
            }
            OpIr::Relu => ops.push(Op::Relu),
            OpIr::Gelu => ops.push(Op::Gelu),
            OpIr::Pool { k, stride } => ops.push(Op::MaxPool { k: *k, stride: *stride }),
            OpIr::Gap => ops.push(Op::Gap),
            OpIr::Flatten => ops.push(Op::Flatten),
            OpIr::Add => ops.push(Op::Add { slot: slots[other.as_ref().unwrap()] }),
            OpIr::Mul => ops.push(Op::Mul { slot: slots[other.as_ref().unwrap()] }),
            OpIr::Alias => unreachable!("aliases are filtered from the chain"),
            OpIr::Embedding { .. } | OpIr::Attention { .. } | OpIr::MeanPool => {
                unreachable!("bert chains lower via lower_bert")
            }
        }
        cur = n.name.clone();
        if let Some(&s) = slots.get(&n.name) {
            ops.push(Op::Save { slot: s });
        }
    }
    if out_name != cur {
        ops.push(Op::Restore { slot: slots[&out_name] });
    }

    Ok(Graph {
        name: ir.name.clone(),
        input_shape: ir.input_shape.clone(),
        ops,
        layers,
        bert: None,
    })
}

/// Shape of a node's primary input (the producing node's output shape,
/// or the module input shape).
fn in_shape<'a>(ir: &'a ModuleIr, node: &NodeIr) -> &'a [usize] {
    let mut name = node.args[0].as_str();
    loop {
        if name == ir.input_name {
            return &ir.input_shape;
        }
        let n = ir
            .nodes
            .iter()
            .find(|n| n.name == name)
            .expect("ir validation resolved every arg");
        if matches!(n.op, OpIr::Alias) {
            name = n.args[0].as_str();
        } else {
            return &n.shape;
        }
    }
}

fn lower_bert(ir: &ModuleIr) -> Result<Graph, ImportError> {
    let chain_msg = "embedding/attention/mean_pool are only supported as the exact chain \
                     embedding -> attention -> mean_pool -> linear";
    let bad = |line: usize| Err(ImportError::new(line, chain_msg));
    let [e, at, mp, head] = &ir.nodes[..] else {
        return bad(ir.nodes.first().map(|n| n.line).unwrap_or(ir.output_line));
    };
    let (OpIr::Embedding { vocab, dim }, OpIr::Attention { layers, heads, ffn }, OpIr::MeanPool, OpIr::Linear { out }) =
        (&e.op, &at.op, &mp.op, &head.op)
    else {
        return bad(e.line);
    };
    for (node, want_arg) in
        [(e, &ir.input_name), (at, &e.name), (mp, &at.name), (head, &mp.name)]
    {
        if &node.args[0] != want_arg {
            return bad(node.line);
        }
    }
    if ir.output != head.name {
        return Err(ImportError::new(ir.output_line, chain_msg));
    }

    let seq_len = ir.input_shape[1];
    let cfg = BertConfig {
        vocab: *vocab,
        seq_len,
        d: *dim,
        n_heads: *heads,
        d_ff: *ffn,
        n_layers: *layers,
        n_out: *out,
    };
    let mut graph_layers = BTreeMap::new();
    let mut rng = layer_rng(ir.seed, "emb");
    graph_layers.insert(
        "emb".to_string(),
        LayerParams::Embedding {
            tok: rng.normal_vec(cfg.vocab * cfg.d, 0.1),
            pos: rng.normal_vec(cfg.seq_len * cfg.d, 0.1),
            d: cfg.d,
        },
    );
    for l in 0..cfg.n_layers {
        for (nm, di, dm) in [
            ("q", cfg.d, cfg.d),
            ("k", cfg.d, cfg.d),
            ("v", cfg.d, cfg.d),
            ("o", cfg.d, cfg.d),
            ("f1", cfg.d, cfg.d_ff),
            ("f2", cfg.d_ff, cfg.d),
        ] {
            let name = format!("l{l}{nm}");
            let mut rng = layer_rng(ir.seed, &name);
            graph_layers.insert(name, dense(&mut rng, di, dm));
        }
        for nm in ["ln1", "ln2"] {
            graph_layers.insert(
                format!("l{l}{nm}"),
                LayerParams::Ln { gamma: vec![1.0; cfg.d], beta: vec![0.0; cfg.d] },
            );
        }
    }
    let mut rng = layer_rng(ir.seed, "head");
    graph_layers.insert("head".to_string(), dense(&mut rng, cfg.d, cfg.n_out));

    Ok(Graph {
        name: ir.name.clone(),
        input_shape: ir.input_shape.clone(),
        ops: vec![Op::Bert],
        layers: graph_layers,
        bert: Some(cfg),
    })
}

#[cfg(test)]
#[allow(deprecated)] // parity is checked through the legacy Graph::run shim
mod tests {
    use super::super::import_str;
    use super::*;
    use crate::lut::LutOpts;
    use crate::tensor::Tensor;

    #[test]
    fn residual_block_gets_slots() {
        let g = import_str(
            "model \"res\" { seed = 1 };\n\
             input x: f32[1, 8, 8, 4];\n\
             c = conv2d(x) { out = 4, kernel = 3 };\n\
             s = add(c, x);\n\
             output s;\n",
        )
        .unwrap();
        // input is consumed off-chain by add -> saved to slot 0 up front
        assert_eq!(g.ops[0], Op::Save { slot: 0 });
        assert_eq!(g.ops[2], Op::Add { slot: 0 });
        let mut rng = Prng::new(9);
        let x = Tensor::new(vec![1, 8, 8, 4], rng.normal_vec(64 * 4, 1.0));
        let y = g.run(x, LutOpts::all());
        assert_eq!(y.shape, vec![1, 8, 8, 4]);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn identity_transpose_is_a_pure_rename() {
        let src = |with_t: bool| {
            format!(
                "model \"t\" {{ seed = 2 }};\n\
                 input x: f32[1, 4, 4, 2];\n\
                 c = conv2d(x) {{ out = 2, kernel = 3 }};\n\
                 {}\
                 r = reshape({}) {{ shape = [-1] }};\n\
                 y = linear(r) {{ out = 3 }};\n\
                 output y;\n",
                if with_t { "t = transpose(c) { perm = [0, 1, 2, 3] };\n" } else { "" },
                if with_t { "t" } else { "c" },
            )
        };
        let a = import_str(&src(true)).unwrap();
        let b = import_str(&src(false)).unwrap();
        assert_eq!(a.ops, b.ops, "alias must not change the instruction stream");
        let mut rng = Prng::new(3);
        let x = Tensor::new(vec![1, 4, 4, 2], rng.normal_vec(32, 1.0));
        let ya = a.run(x.clone(), LutOpts::all());
        let yb = b.run(x, LutOpts::all());
        assert_eq!(ya.data, yb.data);
    }

    #[test]
    fn gating_mul_and_off_chain_output() {
        // Both operands of mul are off-chain at some point; output is
        // not the final statement's result.
        let g = import_str(
            "model \"gate\" { seed = 4 };\n\
             input x: f32[1, 6];\n\
             a = linear(x) { out = 6 };\n\
             b = gelu(a);\n\
             m = mul(b, x);\n\
             z = relu(m);\n\
             output m;\n",
        )
        .unwrap();
        assert_eq!(*g.ops.last().unwrap(), Op::Restore { slot: 1 });
        let y = g.run(Tensor::new(vec![1, 6], vec![0.5; 6]), LutOpts::all());
        assert_eq!(y.shape, vec![1, 6]);
    }

    #[test]
    fn imports_are_deterministic_and_name_keyed() {
        let src = "model \"d\" { seed = 7 };\n\
                   input x: f32[1, 4];\n\
                   y = linear(x) { out = 2 };\n\
                   output y;\n";
        let a = import_str(src).unwrap();
        let b = import_str(src).unwrap();
        let (LayerParams::Dense { w: wa, .. }, LayerParams::Dense { w: wb, .. }) =
            (&a.layers["y"], &b.layers["y"])
        else {
            panic!()
        };
        assert_eq!(wa, wb, "same text must give bit-identical weights");
        // different seed -> different weights
        let c = import_str(&src.replace("seed = 7", "seed = 8")).unwrap();
        let LayerParams::Dense { w: wc, .. } = &c.layers["y"] else { panic!() };
        assert_ne!(wa, wc);
    }

    #[test]
    fn bert_chain_lowers_to_fused_graph() {
        let g = import_str(
            "model \"b\" { seed = 5 };\n\
             input tok: i32[2, 6];\n\
             e = embedding(tok) { vocab = 16, dim = 8 };\n\
             h = attention(e) { layers = 1, heads = 2, ffn = 16 };\n\
             p = mean_pool(h);\n\
             y = linear(p) { out = 3 };\n\
             output y;\n",
        )
        .unwrap();
        assert_eq!(g.ops, vec![Op::Bert]);
        let cfg = g.bert.as_ref().unwrap();
        assert_eq!((cfg.vocab, cfg.seq_len, cfg.d, cfg.n_out), (16, 6, 8, 3));
        for name in ["emb", "l0q", "l0f2", "l0ln1", "head"] {
            assert!(g.layers.contains_key(name), "missing conventional layer {name}");
        }
        let y = g.run(Tensor::new(vec![2, 6], vec![1.0; 12]), LutOpts::all());
        assert_eq!(y.shape, vec![2, 3]);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn broken_bert_chains_diagnose_on_the_offending_line() {
        // relu between attention and mean_pool breaks the fused form
        let e = import_str(
            "model \"b\";\n\
             input tok: i32[2, 6];\n\
             e = embedding(tok) { vocab = 16, dim = 8 };\n\
             h = attention(e) { layers = 1, heads = 2, ffn = 16 };\n\
             r = relu(h);\n\
             p = mean_pool(r);\n\
             y = linear(p) { out = 3 };\n\
             output y;\n",
        )
        .unwrap_err();
        assert!(e.message.contains("exact chain"), "{e}");
        assert!(e.line >= 3, "line {} should point into the chain", e.line);
    }
}
