//! Recursive-descent parser: token stream to statement AST. Syntax only
//! — op names, attribute keys and shapes are checked later by `ir`, so
//! the parser stays a faithful mirror of the grammar:
//!
//! ```text
//! module    := stmt* ;
//! stmt      := model | input | output | op ;
//! model     := "model" STR attrs? ";" ;
//! input     := "input" IDENT ":" IDENT "[" num ("," num)* "]" ";" ;
//! output    := "output" IDENT ";" ;
//! op        := IDENT "=" IDENT "(" IDENT ("," IDENT)* ")" attrs? ";" ;
//! attrs     := "{" (IDENT "=" value) ("," IDENT "=" value)* "}" ;
//! value     := NUM | STR | "[" NUM ("," NUM)* "]" ;
//! ```

use super::lex::{SpannedTok, Tok};
use super::ImportError;

#[derive(Debug, Clone, PartialEq)]
pub enum AttrValue {
    Num(f64),
    Str(String),
    List(Vec<f64>),
}

#[derive(Debug, Clone)]
pub struct Attr {
    pub key: String,
    pub value: AttrValue,
    pub line: usize,
}

#[derive(Debug, Clone)]
pub enum StmtKind {
    Model { name: String, attrs: Vec<Attr> },
    Input { name: String, dtype: String, shape: Vec<f64> },
    Op { result: String, op: String, args: Vec<String>, attrs: Vec<Attr> },
    Output { name: String },
}

#[derive(Debug, Clone)]
pub struct Stmt {
    pub kind: StmtKind,
    pub line: usize,
}

struct Parser<'a> {
    toks: &'a [SpannedTok],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn line(&self) -> usize {
        self.toks
            .get(self.pos)
            .or_else(|| self.toks.last())
            .map(|t| t.line)
            .unwrap_or(1)
    }

    fn err(&self, msg: impl Into<String>) -> ImportError {
        ImportError::new(self.line(), msg)
    }

    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos).map(|t| &t.tok)
    }

    fn next(&mut self, what: &str) -> Result<&'a Tok, ImportError> {
        let t = self
            .toks
            .get(self.pos)
            .ok_or_else(|| self.err(format!("expected {what}, found end of file")))?;
        self.pos += 1;
        Ok(&t.tok)
    }

    fn punct(&mut self, c: char) -> Result<(), ImportError> {
        match self.next(&format!("'{c}'"))? {
            Tok::Punct(p) if *p == c => Ok(()),
            other => Err(ImportError::new(
                self.toks[self.pos - 1].line,
                format!("expected '{c}', found {other}"),
            )),
        }
    }

    fn ident(&mut self, what: &str) -> Result<String, ImportError> {
        match self.next(what)? {
            Tok::Ident(s) => Ok(s.clone()),
            other => Err(ImportError::new(
                self.toks[self.pos - 1].line,
                format!("expected {what}, found {other}"),
            )),
        }
    }

    fn num(&mut self, what: &str) -> Result<f64, ImportError> {
        match self.next(what)? {
            Tok::Num(n) => Ok(*n),
            other => Err(ImportError::new(
                self.toks[self.pos - 1].line,
                format!("expected {what}, found {other}"),
            )),
        }
    }

    fn num_list(&mut self, what: &str) -> Result<Vec<f64>, ImportError> {
        self.punct('[')?;
        let mut out = vec![self.num(what)?];
        loop {
            match self.next("',' or ']'")? {
                Tok::Punct(',') => out.push(self.num(what)?),
                Tok::Punct(']') => return Ok(out),
                other => {
                    return Err(ImportError::new(
                        self.toks[self.pos - 1].line,
                        format!("expected ',' or ']', found {other}"),
                    ))
                }
            }
        }
    }

    fn attrs(&mut self) -> Result<Vec<Attr>, ImportError> {
        let mut out = Vec::new();
        if self.peek() != Some(&Tok::Punct('{')) {
            return Ok(out);
        }
        self.punct('{')?;
        loop {
            let line = self.line();
            let key = self.ident("attribute name")?;
            self.punct('=')?;
            let value = match self.peek() {
                Some(Tok::Punct('[')) => AttrValue::List(self.num_list("list element")?),
                Some(Tok::Str(_)) => match self.next("attribute value")? {
                    Tok::Str(s) => AttrValue::Str(s.clone()),
                    _ => unreachable!(),
                },
                _ => AttrValue::Num(self.num("attribute value")?),
            };
            out.push(Attr { key, value, line });
            match self.next("',' or '}'")? {
                Tok::Punct(',') => {}
                Tok::Punct('}') => return Ok(out),
                other => {
                    return Err(ImportError::new(
                        self.toks[self.pos - 1].line,
                        format!("expected ',' or '}}', found {other}"),
                    ))
                }
            }
        }
    }

    fn stmt(&mut self) -> Result<Stmt, ImportError> {
        let line = self.line();
        let head = self.ident("statement")?;
        let kind = match head.as_str() {
            "model" => {
                let name = match self.next("model name string")? {
                    Tok::Str(s) => s.clone(),
                    other => {
                        return Err(ImportError::new(
                            self.toks[self.pos - 1].line,
                            format!("expected model name string, found {other}"),
                        ))
                    }
                };
                let attrs = self.attrs()?;
                StmtKind::Model { name, attrs }
            }
            "input" => {
                let name = self.ident("input tensor name")?;
                self.punct(':')?;
                let dtype = self.ident("dtype")?;
                let shape = self.num_list("shape dim")?;
                StmtKind::Input { name, dtype, shape }
            }
            "output" => StmtKind::Output { name: self.ident("output tensor name")? },
            _ => {
                self.punct('=')?;
                let op = self.ident("op name")?;
                self.punct('(')?;
                let mut args = vec![self.ident("argument tensor")?];
                loop {
                    match self.next("',' or ')'")? {
                        Tok::Punct(',') => args.push(self.ident("argument tensor")?),
                        Tok::Punct(')') => break,
                        other => {
                            return Err(ImportError::new(
                                self.toks[self.pos - 1].line,
                                format!("expected ',' or ')', found {other}"),
                            ))
                        }
                    }
                }
                let attrs = self.attrs()?;
                StmtKind::Op { result: head, op, args, attrs }
            }
        };
        self.punct(';')?;
        Ok(Stmt { kind, line })
    }
}

pub fn parse(toks: &[SpannedTok]) -> Result<Vec<Stmt>, ImportError> {
    let mut p = Parser { toks, pos: 0 };
    let mut out = Vec::new();
    while p.peek().is_some() {
        out.push(p.stmt()?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::super::lex::lex;
    use super::*;

    fn parse_src(src: &str) -> Result<Vec<Stmt>, ImportError> {
        parse(&lex(src)?)
    }

    #[test]
    fn parses_all_statement_forms() {
        let stmts = parse_src(
            "model \"m\" { seed = 3 };\n\
             input x: f32[1, 4];\n\
             y = linear(x) { out = 2 };\n\
             z = add(y, y);\n\
             output z;\n",
        )
        .unwrap();
        assert_eq!(stmts.len(), 5);
        assert!(matches!(&stmts[0].kind, StmtKind::Model { name, .. } if name == "m"));
        match &stmts[2].kind {
            StmtKind::Op { result, op, args, attrs } => {
                assert_eq!((result.as_str(), op.as_str()), ("y", "linear"));
                assert_eq!(args, &["x"]);
                assert_eq!(attrs[0].value, AttrValue::Num(2.0));
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(stmts[3].line, 4);
    }

    #[test]
    fn attr_value_kinds() {
        let stmts =
            parse_src("y = pool(x) { kind = \"max\", kernel = 2, shape = [1, -1] };\n").unwrap();
        let StmtKind::Op { attrs, .. } = &stmts[0].kind else { panic!() };
        assert_eq!(attrs[0].value, AttrValue::Str("max".into()));
        assert_eq!(attrs[2].value, AttrValue::List(vec![1.0, -1.0]));
    }

    #[test]
    fn syntax_errors_carry_the_line() {
        let err = parse_src("input x: f32[1, 4];\ny = linear x;\n").unwrap_err();
        assert_eq!(err.line, 2);
        assert!(err.message.contains("expected '('"), "{}", err.message);
        let err = parse_src("y = linear(x)").unwrap_err();
        assert!(err.message.contains("end of file"), "{}", err.message);
    }
}
