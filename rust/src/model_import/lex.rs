//! Line-tracked lexer for the neutral graph text format. Tokens are
//! deliberately few — identifiers, string/number literals, and the
//! punctuation the statement grammar needs — so every character the
//! format does not know is rejected with its line number.

use super::ImportError;

#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    Ident(String),
    Str(String),
    Num(f64),
    /// one of `= ( ) { } [ ] , : ;`
    Punct(char),
}

impl std::fmt::Display for Tok {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Tok::Ident(s) => write!(f, "'{s}'"),
            Tok::Str(s) => write!(f, "\"{s}\""),
            Tok::Num(n) => write!(f, "{n}"),
            Tok::Punct(c) => write!(f, "'{c}'"),
        }
    }
}

#[derive(Debug, Clone)]
pub struct SpannedTok {
    pub tok: Tok,
    pub line: usize,
}

pub fn lex(src: &str) -> Result<Vec<SpannedTok>, ImportError> {
    let mut out = Vec::new();
    let mut line = 1usize;
    let mut it = src.chars().peekable();
    while let Some(&ch) = it.peek() {
        match ch {
            '\n' => {
                line += 1;
                it.next();
            }
            c if c.is_whitespace() => {
                it.next();
            }
            '#' => {
                while let Some(&c) = it.peek() {
                    if c == '\n' {
                        break;
                    }
                    it.next();
                }
            }
            '"' => {
                it.next();
                let mut s = String::new();
                loop {
                    match it.next() {
                        Some('"') => break,
                        Some('\n') | None => {
                            return Err(ImportError::new(line, "unterminated string literal"))
                        }
                        Some(c) => s.push(c),
                    }
                }
                out.push(SpannedTok { tok: Tok::Str(s), line });
            }
            c if c.is_ascii_alphabetic() || c == '_' => {
                let mut s = String::new();
                while let Some(&c) = it.peek() {
                    if c.is_ascii_alphanumeric() || c == '_' {
                        s.push(c);
                        it.next();
                    } else {
                        break;
                    }
                }
                out.push(SpannedTok { tok: Tok::Ident(s), line });
            }
            c if c.is_ascii_digit() || c == '-' => {
                let mut s = String::new();
                s.push(c);
                it.next();
                if c == '-' && !it.peek().is_some_and(|d| d.is_ascii_digit()) {
                    return Err(ImportError::new(line, "'-' must start a number literal"));
                }
                let mut prev = c;
                while let Some(&d) = it.peek() {
                    let exp_sign = (d == '+' || d == '-') && (prev == 'e' || prev == 'E');
                    if d.is_ascii_digit() || d == '.' || d == 'e' || d == 'E' || exp_sign {
                        s.push(d);
                        prev = d;
                        it.next();
                    } else {
                        break;
                    }
                }
                let n: f64 = s
                    .parse()
                    .map_err(|_| ImportError::new(line, format!("bad number literal '{s}'")))?;
                out.push(SpannedTok { tok: Tok::Num(n), line });
            }
            c if "=(){}[],:;".contains(c) => {
                it.next();
                out.push(SpannedTok { tok: Tok::Punct(c), line });
            }
            c => return Err(ImportError::new(line, format!("unexpected character '{c}'"))),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexes_statements_with_lines() {
        let toks = lex("# hi\nmodel \"m\";\nx = f(y) { k = 2.5 };\n").unwrap();
        assert_eq!(toks[0].tok, Tok::Ident("model".into()));
        assert_eq!(toks[0].line, 2);
        assert_eq!(toks[1].tok, Tok::Str("m".into()));
        let num = toks.iter().find(|t| matches!(t.tok, Tok::Num(_))).unwrap();
        assert_eq!(num.tok, Tok::Num(2.5));
        assert_eq!(num.line, 3);
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let toks = lex("[-1, 2e3]").unwrap();
        assert_eq!(toks[1].tok, Tok::Num(-1.0));
        assert_eq!(toks[3].tok, Tok::Num(2000.0));
    }

    #[test]
    fn rejects_junk_with_line_number() {
        let err = lex("ok;\n@").unwrap_err();
        assert_eq!(err.line, 2);
        assert!(err.message.contains("unexpected character '@'"));
        let err = lex("\"open").unwrap_err();
        assert!(err.message.contains("unterminated"));
    }
}
