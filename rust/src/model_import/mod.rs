//! Neutral graph importer: parse an NNEF-style text description of a
//! network into a validated IR, infer every activation shape, and lower
//! it onto the engine's own [`Graph`] ISA — the front door for models
//! that were not born inside this repository.
//!
//! The pipeline is three total passes, each with line-numbered
//! diagnostics ([`ImportError`]):
//!
//! ```text
//! .nnef text --lex/parse--> AST --validate--> ModuleIr --lower--> Graph
//!                 |                  |                     |
//!            syntax errors     op whitelist +        slot assignment,
//!                              shape inference       deterministic weights
//! ```
//!
//! The format (see `models/zoo/*.nnef` for worked examples):
//!
//! ```text
//! # comment
//! model "cnn_tiny" { seed = 11 };
//! input x: f32[1, 16, 16, 3];
//! c0 = conv2d(x) { out = 16, kernel = 3, stride = 1 };
//! r0 = relu(c0);
//! y  = linear(g) { out = 10 };
//! output y;
//! ```
//!
//! Weights are not carried in the text: every parameterized layer is
//! materialized deterministically from the model seed and the layer
//! name, so a fixture file fully determines the imported graph, bit for
//! bit. The imported graph is a *dense teacher* — feed it to
//! [`crate::train::compile_graph`] to distill LUT layers, then
//! [`crate::model_fmt::save_bundle`] / [`crate::api::SessionBuilder`]
//! to serve it (`lutnn import` wires the whole chain).
//!
//! Op whitelist: `conv2d`, `linear`, `batchnorm`, `layernorm`, `relu`,
//! `gelu`, `pool` (max), `gap`, `reshape` (flatten only), `transpose`
//! (identity only), `add`, `mul`, plus the BERT triple `embedding` /
//! `attention` / `mean_pool`, which is accepted only as the exact chain
//! `embedding -> attention -> mean_pool -> linear` and lowers to a
//! [`Op::Bert`](crate::nn::graph::Op) graph.

mod ir;
mod lex;
mod lower;
mod parse;
pub mod zoo;

pub use ir::{Dtype, ModuleIr, NodeIr, OpIr};

use anyhow::Context;

use crate::nn::graph::Graph;

/// A diagnostic pinned to a 1-based source line. Everything the
/// importer can reject — syntax, unknown ops, bad attributes, shape
/// mismatches — surfaces as one of these.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ImportError {
    pub line: usize,
    pub message: String,
}

impl ImportError {
    pub(crate) fn new(line: usize, message: impl Into<String>) -> ImportError {
        ImportError { line, message: message.into() }
    }
}

impl std::fmt::Display for ImportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ImportError {}

/// Parse + validate + shape-infer, stopping before lowering. Useful
/// for tooling that wants the typed IR (shapes, per-node lines).
pub fn parse_module(src: &str) -> Result<ModuleIr, ImportError> {
    ir::build(&parse::parse(&lex::lex(src)?)?)
}

/// Full import: text to an executable dense [`Graph`].
pub fn import_str(src: &str) -> Result<Graph, ImportError> {
    lower::lower(&parse_module(src)?)
}

/// Import from a file on disk.
pub fn import_file(path: &str) -> anyhow::Result<Graph> {
    let src = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    import_str(&src).with_context(|| format!("importing {path}"))
}
