//! Validated importer IR: the statement AST re-checked against the op
//! whitelist, with every attribute typed and every activation shape
//! inferred. All rejection paths produce an [`ImportError`] carrying
//! the 1-based line of the offending statement — unknown ops, unknown
//! or ill-typed attributes, arity mistakes, dtype violations and shape
//! mismatches all diagnose here, before any weights are materialized.

use std::collections::BTreeMap;

use super::parse::{Attr, AttrValue, Stmt, StmtKind};
use super::ImportError;
use crate::tensor::im2col::same_out_size;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    /// token ids; only valid as the module input of an embedding chain
    I32,
}

impl std::fmt::Display for Dtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Dtype::F32 => "f32",
            Dtype::I32 => "i32",
        })
    }
}

/// One whitelisted op with its validated attributes.
#[derive(Debug, Clone, PartialEq)]
pub enum OpIr {
    Conv { out: usize, k: usize, stride: usize },
    Linear { out: usize },
    BatchNorm,
    LayerNorm,
    Relu,
    Gelu,
    Pool { k: usize, stride: usize },
    Gap,
    /// `reshape { shape = [-1] }`: collapse to `[N, prod]`
    Flatten,
    /// identity `transpose`: pure rename, no instruction is emitted
    Alias,
    Add,
    Mul,
    Embedding { vocab: usize, dim: usize },
    Attention { layers: usize, heads: usize, ffn: usize },
    MeanPool,
}

/// One op statement after validation.
#[derive(Debug, Clone)]
pub struct NodeIr {
    pub name: String,
    pub op: OpIr,
    pub args: Vec<String>,
    /// inferred output shape (batch dim included)
    pub shape: Vec<usize>,
    pub dtype: Dtype,
    pub line: usize,
}

/// A whole validated module: single input, single output, nodes in
/// statement order.
#[derive(Debug, Clone)]
pub struct ModuleIr {
    pub name: String,
    pub seed: u64,
    pub input_name: String,
    pub input_shape: Vec<usize>,
    pub input_dtype: Dtype,
    pub nodes: Vec<NodeIr>,
    pub output: String,
    pub output_line: usize,
}

const OPS: &[&str] = &[
    "conv2d", "linear", "batchnorm", "layernorm", "relu", "gelu", "pool", "gap", "reshape",
    "transpose", "add", "mul", "embedding", "attention", "mean_pool",
];

fn err(line: usize, msg: impl Into<String>) -> ImportError {
    ImportError::new(line, msg)
}

/// Attribute bag: typed take-by-key with an unused-key sweep, so every
/// op both gets the attributes it wants and rejects the ones it does
/// not understand.
struct Attrs<'a> {
    op: &'a str,
    line: usize,
    map: BTreeMap<&'a str, &'a Attr>,
}

impl<'a> Attrs<'a> {
    fn new(op: &'a str, line: usize, attrs: &'a [Attr]) -> Result<Attrs<'a>, ImportError> {
        let mut map = BTreeMap::new();
        for a in attrs {
            if map.insert(a.key.as_str(), a).is_some() {
                return Err(err(a.line, format!("duplicate attribute '{}' on {op}", a.key)));
            }
        }
        Ok(Attrs { op, line, map })
    }

    fn usize_opt(&mut self, key: &str) -> Result<Option<usize>, ImportError> {
        let Some(a) = self.map.remove(key) else { return Ok(None) };
        match a.value {
            AttrValue::Num(n) if n >= 0.0 && n.fract() == 0.0 && n <= u32::MAX as f64 => {
                Ok(Some(n as usize))
            }
            _ => Err(err(
                a.line,
                format!("attribute '{key}' on {} must be a non-negative integer", self.op),
            )),
        }
    }

    fn usize_req(&mut self, key: &str) -> Result<usize, ImportError> {
        self.usize_opt(key)?
            .ok_or_else(|| err(self.line, format!("{} requires attribute '{key}'", self.op)))
    }

    fn str_opt(&mut self, key: &str) -> Result<Option<String>, ImportError> {
        let Some(a) = self.map.remove(key) else { return Ok(None) };
        match &a.value {
            AttrValue::Str(s) => Ok(Some(s.clone())),
            _ => Err(err(a.line, format!("attribute '{key}' on {} must be a string", self.op))),
        }
    }

    fn list_req(&mut self, key: &str) -> Result<Vec<f64>, ImportError> {
        let a = self
            .map
            .remove(key)
            .ok_or_else(|| err(self.line, format!("{} requires attribute '{key}'", self.op)))?;
        match &a.value {
            AttrValue::List(v) => Ok(v.clone()),
            _ => Err(err(a.line, format!("attribute '{key}' on {} must be a list", self.op))),
        }
    }

    /// Reject whatever the op did not consume.
    fn finish(self) -> Result<(), ImportError> {
        if let Some((key, a)) = self.map.into_iter().next() {
            return Err(err(a.line, format!("unsupported attribute '{key}' on {}", self.op)));
        }
        Ok(())
    }
}

struct TensorInfo {
    shape: Vec<usize>,
    dtype: Dtype,
}

pub fn build(stmts: &[Stmt]) -> Result<ModuleIr, ImportError> {
    let mut name = None;
    let mut seed = 0u64;
    let mut input: Option<(String, Vec<usize>, Dtype, usize)> = None;
    let mut output: Option<(String, usize)> = None;
    let mut nodes: Vec<NodeIr> = Vec::new();
    let mut tensors: BTreeMap<String, TensorInfo> = BTreeMap::new();

    for stmt in stmts {
        let line = stmt.line;
        match &stmt.kind {
            StmtKind::Model { name: n, attrs } => {
                if name.is_some() {
                    return Err(err(line, "duplicate model statement"));
                }
                name = Some(n.clone());
                let mut a = Attrs::new("model", line, attrs)?;
                if let Some(s) = a.usize_opt("seed")? {
                    seed = s as u64;
                }
                a.finish()?;
            }
            StmtKind::Input { name: n, dtype, shape } => {
                if input.is_some() {
                    return Err(err(line, "only one input is supported"));
                }
                let dt = match dtype.as_str() {
                    "f32" => Dtype::F32,
                    "i32" => Dtype::I32,
                    other => return Err(err(line, format!("unknown dtype '{other}'"))),
                };
                let dims = shape
                    .iter()
                    .map(|&d| {
                        if d >= 1.0 && d.fract() == 0.0 && d <= u32::MAX as f64 {
                            Ok(d as usize)
                        } else {
                            Err(err(line, format!("input dims must be positive integers, got {d}")))
                        }
                    })
                    .collect::<Result<Vec<usize>, _>>()?;
                if tensors.contains_key(n) {
                    return Err(err(line, format!("tensor '{n}' defined twice")));
                }
                tensors.insert(n.clone(), TensorInfo { shape: dims.clone(), dtype: dt });
                input = Some((n.clone(), dims, dt, line));
            }
            StmtKind::Output { name: n } => {
                if output.is_some() {
                    return Err(err(line, "only one output is supported"));
                }
                if !tensors.contains_key(n) {
                    return Err(err(line, format!("unknown tensor '{n}'")));
                }
                output = Some((n.clone(), line));
            }
            StmtKind::Op { result, op, args, attrs } => {
                if output.is_some() {
                    return Err(err(line, "op after output statement"));
                }
                if tensors.contains_key(result) {
                    return Err(err(line, format!("tensor '{result}' defined twice")));
                }
                let node = check_op(result, op, args, attrs, line, &tensors)?;
                tensors.insert(
                    result.clone(),
                    TensorInfo { shape: node.shape.clone(), dtype: node.dtype },
                );
                nodes.push(node);
            }
        }
    }

    let name = name.ok_or_else(|| err(1, "missing model statement"))?;
    let (input_name, input_shape, input_dtype, input_line) =
        input.ok_or_else(|| err(1, "missing input statement"))?;
    let (output, output_line) = output.ok_or_else(|| err(1, "missing output statement"))?;
    if input_dtype == Dtype::I32
        && !nodes.iter().any(|n| matches!(n.op, OpIr::Embedding { .. }))
    {
        return Err(err(input_line, "i32 input requires an embedding op to consume it"));
    }
    Ok(ModuleIr {
        name,
        seed,
        input_name,
        input_shape,
        input_dtype,
        nodes,
        output,
        output_line,
    })
}

fn check_op(
    result: &str,
    op: &str,
    args: &[String],
    attrs: &[Attr],
    line: usize,
    tensors: &BTreeMap<String, TensorInfo>,
) -> Result<NodeIr, ImportError> {
    if !OPS.contains(&op) {
        return Err(err(line, format!("unknown op '{op}' (supported: {})", OPS.join(", "))));
    }
    let arity = match op {
        "add" | "mul" => 2,
        _ => 1,
    };
    if args.len() != arity {
        return Err(err(line, format!("{op} takes {arity} argument(s), got {}", args.len())));
    }
    let mut ins = Vec::with_capacity(arity);
    for a in args {
        ins.push(
            tensors.get(a).ok_or_else(|| err(line, format!("unknown tensor '{a}'")))?,
        );
    }
    // Everything except embedding consumes f32 activations.
    if op != "embedding" {
        for (a, t) in args.iter().zip(&ins) {
            if t.dtype != Dtype::F32 {
                return Err(err(line, format!("{op} requires f32 input, but '{a}' is {}", t.dtype)));
            }
        }
    }
    let x = &ins[0];
    let mut a = Attrs::new(op, line, attrs)?;
    let rank_err = |want: &str| {
        err(line, format!("{op} expects a {want} input, got shape {:?}", x.shape))
    };

    let (opir, shape, dtype) = match op {
        "conv2d" => {
            let out = a.usize_req("out")?;
            let k = a.usize_req("kernel")?;
            let stride = a.usize_opt("stride")?.unwrap_or(1);
            if out == 0 || stride == 0 {
                return Err(err(line, "conv2d 'out' and 'stride' must be >= 1"));
            }
            if k == 0 || k % 2 == 0 {
                return Err(err(line, format!("conv2d kernel must be odd (same padding), got {k}")));
            }
            let [n, h, w, _c] = x.shape[..] else { return Err(rank_err("rank-4 NHWC")) };
            let sh = vec![n, same_out_size(h, stride), same_out_size(w, stride), out];
            (OpIr::Conv { out, k, stride }, sh, Dtype::F32)
        }
        "linear" => {
            let out = a.usize_req("out")?;
            if out == 0 {
                return Err(err(line, "linear 'out' must be >= 1"));
            }
            let [n, _d] = x.shape[..] else { return Err(rank_err("rank-2 [N, D]")) };
            (OpIr::Linear { out }, vec![n, out], Dtype::F32)
        }
        "batchnorm" => {
            if x.shape.len() != 4 {
                return Err(rank_err("rank-4 NHWC"));
            }
            (OpIr::BatchNorm, x.shape.clone(), Dtype::F32)
        }
        "layernorm" => {
            if x.shape.len() < 2 {
                return Err(rank_err("rank >= 2"));
            }
            (OpIr::LayerNorm, x.shape.clone(), Dtype::F32)
        }
        "relu" => (OpIr::Relu, x.shape.clone(), Dtype::F32),
        "gelu" => (OpIr::Gelu, x.shape.clone(), Dtype::F32),
        "pool" => {
            if let Some(kind) = a.str_opt("kind")? {
                if kind != "max" {
                    return Err(err(
                        line,
                        format!("unsupported attribute value kind=\"{kind}\" — only \"max\" pooling is supported"),
                    ));
                }
            }
            let k = a.usize_opt("kernel")?.unwrap_or(2);
            let stride = a.usize_opt("stride")?.unwrap_or(k);
            if k == 0 || stride == 0 {
                return Err(err(line, "pool 'kernel' and 'stride' must be >= 1"));
            }
            let [n, h, w, c] = x.shape[..] else { return Err(rank_err("rank-4 NHWC")) };
            if h < k || w < k {
                return Err(err(
                    line,
                    format!("pool kernel {k} does not fit the {h}x{w} activation"),
                ));
            }
            let sh = vec![n, (h - k) / stride + 1, (w - k) / stride + 1, c];
            (OpIr::Pool { k, stride }, sh, Dtype::F32)
        }
        "gap" => {
            let [n, _h, _w, c] = x.shape[..] else { return Err(rank_err("rank-4 NHWC")) };
            (OpIr::Gap, vec![n, c], Dtype::F32)
        }
        "reshape" => {
            let target = a.list_req("shape")?;
            if target != [-1.0] {
                return Err(err(
                    line,
                    format!("only reshape to [-1] (flatten) is supported, got {target:?}"),
                ));
            }
            let n = x.shape[0];
            let cols: usize = x.shape[1..].iter().product();
            (OpIr::Flatten, vec![n, cols], Dtype::F32)
        }
        "transpose" => {
            let perm = a.list_req("perm")?;
            let identity: Vec<f64> = (0..x.shape.len()).map(|i| i as f64).collect();
            if perm != identity {
                return Err(err(
                    line,
                    format!("only the identity transpose is supported, got perm {perm:?}"),
                ));
            }
            (OpIr::Alias, x.shape.clone(), Dtype::F32)
        }
        "add" | "mul" => {
            if ins[0].shape != ins[1].shape {
                return Err(err(
                    line,
                    format!(
                        "{op} operand shapes differ: '{}' is {:?}, '{}' is {:?}",
                        args[0], ins[0].shape, args[1], ins[1].shape
                    ),
                ));
            }
            let o = if op == "add" { OpIr::Add } else { OpIr::Mul };
            (o, x.shape.clone(), Dtype::F32)
        }
        "embedding" => {
            let vocab = a.usize_req("vocab")?;
            let dim = a.usize_req("dim")?;
            if vocab == 0 || dim == 0 {
                return Err(err(line, "embedding 'vocab' and 'dim' must be >= 1"));
            }
            if x.dtype != Dtype::I32 {
                return Err(err(
                    line,
                    format!("embedding requires an i32 token input, but '{}' is {}", args[0], x.dtype),
                ));
            }
            let [n, t] = x.shape[..] else { return Err(rank_err("rank-2 [N, T] token")) };
            (OpIr::Embedding { vocab, dim }, vec![n, t, dim], Dtype::F32)
        }
        "attention" => {
            let layers = a.usize_req("layers")?;
            let heads = a.usize_req("heads")?;
            let ffn = a.usize_req("ffn")?;
            if layers == 0 || heads == 0 || ffn == 0 {
                return Err(err(line, "attention 'layers', 'heads' and 'ffn' must be >= 1"));
            }
            let [_n, _t, d] = x.shape[..] else { return Err(rank_err("rank-3 [N, T, D]")) };
            if d % heads != 0 {
                return Err(err(
                    line,
                    format!("attention width {d} is not divisible by {heads} heads"),
                ));
            }
            (OpIr::Attention { layers, heads, ffn }, x.shape.clone(), Dtype::F32)
        }
        "mean_pool" => {
            let [n, _t, d] = x.shape[..] else { return Err(rank_err("rank-3 [N, T, D]")) };
            (OpIr::MeanPool, vec![n, d], Dtype::F32)
        }
        _ => unreachable!("op whitelist covers every branch"),
    };
    a.finish()?;
    Ok(NodeIr { name: result.to_string(), op: opir, args: args.to_vec(), shape, dtype, line })
}

#[cfg(test)]
mod tests {
    use super::super::parse_module;
    use super::*;

    #[test]
    fn infers_cnn_shapes() {
        let m = parse_module(
            "model \"m\" { seed = 5 };\n\
             input x: f32[1, 8, 8, 3];\n\
             c = conv2d(x) { out = 4, kernel = 3, stride = 2 };\n\
             p = pool(c) { kind = \"max\", kernel = 2, stride = 2 };\n\
             g = gap(p);\n\
             y = linear(g) { out = 10 };\n\
             output y;\n",
        )
        .unwrap();
        assert_eq!(m.seed, 5);
        assert_eq!(m.nodes[0].shape, vec![1, 4, 4, 4]); // same-pad conv, stride 2
        assert_eq!(m.nodes[1].shape, vec![1, 2, 2, 4]); // valid 2x2 max pool
        assert_eq!(m.nodes[2].shape, vec![1, 4]);
        assert_eq!(m.nodes[3].shape, vec![1, 10]);
        assert_eq!(m.output, "y");
    }

    #[test]
    fn unknown_op_names_the_line_and_whitelist() {
        let e = parse_module(
            "model \"m\";\ninput x: f32[1, 4];\ny = frobnicate(x);\noutput y;\n",
        )
        .unwrap_err();
        assert_eq!(e.line, 3);
        assert!(e.message.contains("unknown op 'frobnicate'"), "{e}");
        assert!(e.message.contains("conv2d"), "whitelist hint: {e}");
    }

    #[test]
    fn shape_and_dtype_violations_diagnose() {
        // linear on rank-4
        let e = parse_module(
            "model \"m\";\ninput x: f32[1, 4, 4, 2];\ny = linear(x) { out = 2 };\noutput y;\n",
        )
        .unwrap_err();
        assert_eq!(e.line, 3);
        assert!(e.message.contains("rank-2"), "{e}");
        // add with mismatched operands
        let e = parse_module(
            "model \"m\";\ninput x: f32[1, 4];\na = linear(x) { out = 2 };\n\
             b = add(a, x);\noutput b;\n",
        )
        .unwrap_err();
        assert_eq!(e.line, 4);
        assert!(e.message.contains("operand shapes differ"), "{e}");
        // relu on tokens
        let e = parse_module(
            "model \"m\";\ninput t: i32[1, 4];\ny = relu(t);\noutput y;\n",
        )
        .unwrap_err();
        assert!(e.message.contains("requires f32 input"), "{e}");
    }

    #[test]
    fn attribute_violations_diagnose() {
        // avg pooling is not whitelisted
        let e = parse_module(
            "model \"m\";\ninput x: f32[1, 4, 4, 2];\np = pool(x) { kind = \"avg\" };\noutput p;\n",
        )
        .unwrap_err();
        assert_eq!(e.line, 3);
        assert!(e.message.contains("only \"max\" pooling"), "{e}");
        // unknown attribute
        let e = parse_module(
            "model \"m\";\ninput x: f32[1, 4, 4, 2];\n\
             c = conv2d(x) { out = 2, kernel = 3, dilation = 2 };\noutput c;\n",
        )
        .unwrap_err();
        assert!(e.message.contains("unsupported attribute 'dilation'"), "{e}");
        // even kernels have no same-padding
        let e = parse_module(
            "model \"m\";\ninput x: f32[1, 4, 4, 2];\nc = conv2d(x) { out = 2, kernel = 4 };\noutput c;\n",
        )
        .unwrap_err();
        assert!(e.message.contains("must be odd"), "{e}");
        // non-flatten reshape
        let e = parse_module(
            "model \"m\";\ninput x: f32[1, 4, 4, 2];\nr = reshape(x) { shape = [4, 8] };\noutput r;\n",
        )
        .unwrap_err();
        assert!(e.message.contains("only reshape to [-1]"), "{e}");
    }

    #[test]
    fn structural_violations_diagnose() {
        let e = parse_module("model \"m\";\ninput x: f32[1, 4];\noutput nope;\n").unwrap_err();
        assert!(e.message.contains("unknown tensor 'nope'"), "{e}");
        let e = parse_module("input x: f32[1, 4];\noutput x;\n").unwrap_err();
        assert!(e.message.contains("missing model statement"), "{e}");
        let e = parse_module(
            "model \"m\";\ninput x: f32[1, 4];\nx = relu(x);\noutput x;\n",
        )
        .unwrap_err();
        assert!(e.message.contains("defined twice"), "{e}");
        let e = parse_module("model \"m\";\ninput t: i32[1, 4];\noutput t;\n").unwrap_err();
        assert!(e.message.contains("requires an embedding"), "{e}");
    }
}
