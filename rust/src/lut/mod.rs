//! The LUT-NN table-lookup execution engine (paper §5) — the hot path.
//!
//! A linear operator `a @ B + bias` is executed as:
//!   1. **Closest centroid search** (§5.1): squared-distance computation
//!      of every input sub-vector against its codebook + argmin.
//!   2. **Table read and accumulation** (§5.2): gather the precomputed
//!      `centroid . B` rows from the (INT8-quantized) lookup table and
//!      accumulate across codebooks.
//!
//! The four optimizations of the paper's §6.3 breakdown are individually
//! toggleable (`LutOpts`), with the CPU-portable realizations documented
//! in DESIGN.md §Hardware-Adaptation:
//!   ① centroid-stationary distance loops (codebook resident across rows)
//!   ② intra-codebook-parallel (4-way interleaved) argmin reduction
//!   ③ blocked sequential table reads (the role NEON/SSE shuffle served)
//!   ④ mixed-precision integer accumulation with a common table scale

pub mod decomposed;
pub mod engine;
pub mod layout;
pub mod simd;

pub use decomposed::DecomposedTable;
pub use engine::{LutLinear, LutOpts, LutScratch};
pub use layout::{AlignedVec, TABLE_ALIGN};
