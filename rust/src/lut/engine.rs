//! LUT-AMM forward engine: the compute core behind the `"lut"` kernel.
//!
//! [`LutLinear`] executes one linear operator `a @ B + bias` as the
//! paper's two-stage pipeline (§5):
//!
//! 1. **Closest centroid search** (`encode_into`, §5.1): each input
//!    sub-vector is matched to its codebook's nearest centroid. The
//!    centroid-stationary path lowers the whole codebook's distance
//!    computation to one `[n, V] x [V, K]` GEMM with `|p|^2` pre-seeded
//!    and `-2 P^T` pre-scaled.
//! 2. **Table read and accumulation** (`lookup_accumulate`, §5.2):
//!    gather precomputed `centroid . B` rows from the INT8 table and
//!    accumulate across codebooks — in i16/i32 integer lanes at a
//!    common scale on the deployed path.
//!
//! The four §6.3 optimization toggles live in [`LutOpts`]; every
//! combination computes the same operator (the opt-config agreement
//! tests below pin this down).
//!
//! Layering: this module is deliberately below the public API. The
//! executor (`api::Session`) reaches it through the object-safe
//! `api::LinearKernel` trait (`api::LutKernel` wraps a `LutLinear` +
//! frozen `LutOpts`), so alternative table kernels can replace it
//! per-layer via the `api::KernelRegistry` without touching callers.
//! `forward_into` is the allocation-free entry point the kernel calls;
//! `forward` is an allocating convenience for tests and one-shot use.

use crate::lut::layout::{AlignedVec, TABLE_ALIGN};
use crate::pq::{build_table, quantize_table, Codebooks};
use crate::tensor::QTable;

/// §6.3 optimization toggles. `LutOpts::all()` is the deployed config;
/// `LutOpts::none()` is the naive baseline the breakdown bench starts from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LutOpts {
    /// ① codebook-resident distance computation (memory optimization)
    pub centroid_stationary: bool,
    /// ② interleaved 4-way argmin (instruction-level parallelism)
    pub interleaved_argmin: bool,
    /// ③ blocked sequential table reads (vectorizable gather)
    pub blocked_table_read: bool,
    /// ④ integer accumulation at a common scale (mixed precision)
    pub mixed_accum: bool,
}

impl LutOpts {
    pub fn all() -> LutOpts {
        LutOpts {
            centroid_stationary: true,
            interleaved_argmin: true,
            blocked_table_read: true,
            mixed_accum: true,
        }
    }
    pub fn none() -> LutOpts {
        LutOpts {
            centroid_stationary: false,
            interleaved_argmin: false,
            blocked_table_read: false,
            mixed_accum: false,
        }
    }
    /// The config tuned for THIS testbed (EXPERIMENTS.md §Perf): the
    /// interleaved argmin only pays off with real SIMD compare lanes
    /// (NEON `vpmin` / AVX `vminps`); in portable scalar rust the
    /// sequential scan over K=16 measures ~25% faster, so the deployed
    /// path disables it. `all()` remains the paper-complete config.
    pub fn deployed() -> LutOpts {
        LutOpts { interleaved_argmin: false, ..LutOpts::all() }
    }
}

impl Default for LutOpts {
    fn default() -> Self {
        LutOpts::deployed()
    }
}

/// Reusable working memory for [`LutLinear`] forwards. All buffers are
/// resized within capacity per call, so a scratch reused across calls
/// (and across layers — sizes settle at the per-layer maxima during the
/// first pass) keeps the hot path allocation-free.
#[derive(Debug, Default, Clone)]
pub struct LutScratch {
    /// centroid indices [n, C]
    pub idx: Vec<u16>,
    /// per-codebook input slab [n, V] (centroid-stationary encode)
    pub slab: Vec<f32>,
    /// distance scores [n, K]
    pub scores: Vec<f32>,
    /// i16 group accumulator [M] (mixed-precision path)
    pub acc16: Vec<i16>,
    /// i32 row accumulator [M] (mixed-precision path)
    pub acc32: Vec<i32>,
}

/// A LUT-replaced linear operator (conv-as-matmul or FC).
#[derive(Debug, Clone)]
pub struct LutLinear {
    pub cb: Codebooks,
    /// |p|^2 per centroid [C, K] (distance fast path; shared with the
    /// explicit-SIMD encode in [`crate::lut::simd`])
    pub(crate) sqn: Vec<f32>,
    /// codebooks transposed to [C, V, K] — K-contiguous so the distance
    /// inner loop vectorizes across centroids (perf pass, EXPERIMENTS.md
    /// §Perf iteration 1)
    cb_t: Vec<f32>,
    /// cb_t pre-scaled by -2 so the distance GEMM needs no epilogue
    /// (perf iteration 2: scores = sqn + slab @ (-2 P^T))
    pub(crate) cb_t2: Vec<f32>,
    /// INT8 table with per-codebook scales (bundle format)
    pub qtable: QTable,
    /// table requantized to one common scale (enables cross-codebook
    /// integer accumulation — paper §5.2 mixed precision); rows are
    /// `[C, K, M]` row-major — the inner-loop access order — with the
    /// first row pinned to a cache line (see `lut::layout`)
    qcommon: AlignedVec<i8>,
    common_scale: f32,
    /// dequantized f32 table (naive/FP32 paths and tests)
    pub table_f32: Vec<f32>,
    pub bias: Option<Vec<f32>>,
    pub m: usize,
}

impl LutLinear {
    /// Build from codebooks + dense weight (Eq. 3 table construction).
    pub fn new(
        cb: Codebooks,
        weight: &[f32],
        m: usize,
        bias: Option<Vec<f32>>,
        bits: u8,
    ) -> LutLinear {
        let table = build_table(&cb, weight, m);
        let qtable = quantize_table(&table, cb.c, cb.k, m, bits);
        let mut lut = Self::from_parts(cb, qtable, bias);
        // from_parts only sees the quantized table; when built from the
        // dense weight we keep the *exact* FP32 table for the unquantized
        // ablation path.
        lut.table_f32 = table;
        lut
    }

    /// Build from an already-quantized table (bundle load path).
    pub fn from_parts(cb: Codebooks, qtable: QTable, bias: Option<Vec<f32>>) -> LutLinear {
        let m = qtable.m;
        assert_eq!(qtable.c, cb.c);
        assert_eq!(qtable.k, cb.k);
        let sqn = cb.sq_norms();
        let mut cb_t = vec![0.0f32; cb.c * cb.v * cb.k];
        for c in 0..cb.c {
            for k in 0..cb.k {
                for t in 0..cb.v {
                    cb_t[(c * cb.v + t) * cb.k + k] = cb.centroid(c, k)[t];
                }
            }
        }
        let cb_t2: Vec<f32> = cb_t.iter().map(|&x| -2.0 * x).collect();
        // dequantized copy
        let mut table_f32 = vec![0.0f32; qtable.data.len()];
        for c in 0..qtable.c {
            let s = qtable.scale[c];
            let base = c * qtable.k * m;
            for i in 0..qtable.k * m {
                table_f32[base + i] = qtable.data[base + i] as f32 * s;
            }
        }
        // requantize to common scale for integer accumulation (§5.2):
        // q' = round(q * scale_c / scale_max) keeps |q'| <= 127.
        let common_scale = qtable.scale.iter().cloned().fold(0.0f32, f32::max).max(1e-30);
        let mut qcommon = AlignedVec::<i8>::zeroed(qtable.data.len(), TABLE_ALIGN);
        let qc = qcommon.as_mut_slice();
        for c in 0..qtable.c {
            let ratio = qtable.scale[c] / common_scale;
            let base = c * qtable.k * m;
            for i in 0..qtable.k * m {
                qc[base + i] =
                    (qtable.data[base + i] as f32 * ratio).round().clamp(-128.0, 127.0) as i8;
            }
        }
        LutLinear { cb, sqn, cb_t, cb_t2, qtable, qcommon, common_scale, table_f32, bias, m }
    }

    pub fn input_dim(&self) -> usize {
        self.cb.input_dim()
    }

    /// The common table scale of the §5.2 integer-accumulation path —
    /// one quantization step of the deployed output, the unit kernel
    /// tolerance bounds are expressed in.
    pub fn common_scale(&self) -> f32 {
        self.common_scale
    }

    /// Bytes of the hot lookup table the deployed path reads (the
    /// common-scale INT8 table) — the quantity `benches/memory_footprint`
    /// gates per model.
    pub fn table_bytes(&self) -> usize {
        self.qcommon.len()
    }

    /// Alignment (bytes) the hot table's first row is pinned to — the
    /// tract `LutKer::table_alignment_bytes()` contract.
    pub fn table_alignment_bytes(&self) -> usize {
        self.qcommon.align_bytes()
    }

    /// Bytes held by the deployed representation (Fig. 10 accounting):
    /// codebooks f32 + INT8 table + scales + bias.
    pub fn deployed_bytes(&self) -> usize {
        self.cb.data.len() * 4
            + self.qtable.data.len()
            + self.qtable.scale.len() * 4
            + self.bias.as_ref().map(|b| b.len() * 4).unwrap_or(0)
    }

    // ------------------------------------------------------------------
    // Stage 1: closest centroid search (§5.1)
    // ------------------------------------------------------------------

    /// Encode rows of `a` ([n, D]) to centroid indices ([n, C] into `idx`).
    pub fn encode_into(&self, a: &[f32], n: usize, opts: LutOpts, idx: &mut [u16]) {
        let d = self.input_dim();
        assert_eq!(a.len(), n * d);
        assert_eq!(idx.len(), n * self.cb.c);
        if opts.centroid_stationary {
            let (mut slab, mut scores) = (Vec::new(), Vec::new());
            self.encode_centroid_stationary(a, n, opts, &mut slab, &mut scores, idx);
        } else {
            self.encode_naive(a, n, opts, idx);
        }
    }

    /// Naive layout: rows outer, codebooks inner, full |a-p|^2 per pair.
    /// Re-reads the codebook from memory for every row (the access
    /// pattern §5.1 calls out as memory-bound).
    fn encode_naive(&self, a: &[f32], n: usize, opts: LutOpts, idx: &mut [u16]) {
        let (c_total, k, v) = (self.cb.c, self.cb.k, self.cb.v);
        let d = c_total * v;
        for i in 0..n {
            for c in 0..c_total {
                let sub = &a[i * d + c * v..i * d + (c + 1) * v];
                let cbk = self.cb.codebook(c);
                let mut scores = [0.0f32; 256];
                for kk in 0..k {
                    let cent = &cbk[kk * v..(kk + 1) * v];
                    let mut s = 0.0f32;
                    for t in 0..v {
                        let diff = sub[t] - cent[t];
                        s += diff * diff;
                    }
                    scores[kk] = s;
                }
                idx[i * c_total + c] = argmin(&scores[..k], opts.interleaved_argmin) as u16;
            }
        }
    }

    /// Centroid-stationary: codebooks outer, rows inner — each codebook
    /// slab (K*V f32, KBs) stays cache-resident across the whole input,
    /// and distances use the |p|^2 - 2 a.p form with precomputed norms.
    ///
    /// The codebook is read from the transposed [V, K] layout so the
    /// inner loop runs K-contiguous FMAs the compiler vectorizes
    /// (K = 16 -> two 8-lane AVX fma per feature) — this is the portable
    /// realization of the paper's NEON distance kernel.
    fn encode_centroid_stationary(
        &self,
        a: &[f32],
        n: usize,
        opts: LutOpts,
        slab: &mut Vec<f32>,
        scores: &mut Vec<f32>,
        idx: &mut [u16],
    ) {
        let (c_total, k, v) = (self.cb.c, self.cb.k, self.cb.v);
        let d = c_total * v;
        // Perf iteration 2 (EXPERIMENTS.md §Perf): the whole codebook's
        // distance computation is one [n, v] x [v, k] GEMM on the blocked
        // kernel, with |p|^2 pre-seeded into the accumulator and P^T
        // pre-scaled by -2 — ~5x the MAC rate of the per-row loop.
        // Both buffers are fully overwritten below, so reuse is exact.
        slab.resize(n * v, 0.0);
        scores.resize(n * k, 0.0);
        for c in 0..c_total {
            let cbt2 = &self.cb_t2[c * v * k..(c + 1) * v * k];
            let sqn = &self.sqn[c * k..(c + 1) * k];
            for i in 0..n {
                slab[i * v..(i + 1) * v]
                    .copy_from_slice(&a[i * d + c * v..i * d + (c + 1) * v]);
                scores[i * k..(i + 1) * k].copy_from_slice(sqn);
            }
            crate::nn::gemm::gemm(&slab[..], cbt2, &mut scores[..], n, v, k);
            for i in 0..n {
                idx[i * c_total + c] =
                    argmin(&scores[i * k..(i + 1) * k], opts.interleaved_argmin) as u16;
            }
        }
    }

    // ------------------------------------------------------------------
    // Stage 2: table read and accumulation (§5.2)
    // ------------------------------------------------------------------

    /// Accumulate table rows for encoded indices into `out` ([n, M]).
    pub fn lookup_accumulate(&self, idx: &[u16], n: usize, opts: LutOpts, out: &mut [f32]) {
        let (mut acc16, mut acc32) = (Vec::new(), Vec::new());
        self.accumulate_buffered(idx, n, opts, &mut acc16, &mut acc32, out);
    }

    /// Accumulation core with caller-owned integer accumulators (the
    /// scratch-reusing forward path; also driven directly by the
    /// SIMD/int8 kernels in `api::kernel`).
    pub(crate) fn accumulate_buffered(
        &self,
        idx: &[u16],
        n: usize,
        opts: LutOpts,
        acc16: &mut Vec<i16>,
        acc32: &mut Vec<i32>,
        out: &mut [f32],
    ) {
        let m = self.m;
        assert_eq!(out.len(), n * m);
        assert_eq!(idx.len(), n * self.cb.c);
        match (opts.mixed_accum, opts.blocked_table_read) {
            (true, true) => self.accumulate_int_blocked(idx, n, acc16, acc32, out),
            (true, false) => self.accumulate_int_scalar(idx, n, acc32, out),
            (false, true) => self.accumulate_f32_blocked(idx, n, out),
            (false, false) => self.accumulate_f32_scalar(idx, n, out),
        }
        if let Some(bias) = &self.bias {
            crate::nn::ops::add_bias_rows(&mut out[..n * m], bias);
        }
    }

    /// Naive: per-element indexed reads + per-element dequantize multiply.
    fn accumulate_f32_scalar(&self, idx: &[u16], n: usize, out: &mut [f32]) {
        let (c_total, k, m) = (self.cb.c, self.cb.k, self.m);
        for i in 0..n {
            for c in 0..c_total {
                let kk = idx[i * c_total + c] as usize;
                let s = self.qtable.scale[c];
                for j in 0..m {
                    out[i * m + j] +=
                        self.qtable.data[(c * k + kk) * m + j] as f32 * s;
                }
            }
        }
    }

    /// ③ blocked: slice the table row once, accumulate with an unrolled
    /// loop the compiler can vectorize; still f32 (per-codebook scale).
    fn accumulate_f32_blocked(&self, idx: &[u16], n: usize, out: &mut [f32]) {
        let (c_total, m) = (self.cb.c, self.m);
        for i in 0..n {
            let dst = &mut out[i * m..(i + 1) * m];
            for c in 0..c_total {
                let kk = idx[i * c_total + c] as usize;
                let row = self.qtable.row(c, kk);
                let s = self.qtable.scale[c];
                for (o, &q) in dst.iter_mut().zip(row) {
                    *o += q as f32 * s;
                }
            }
        }
    }

    /// ④ without ③: integer accumulation at the common scale but with
    /// per-element indexed reads.
    fn accumulate_int_scalar(&self, idx: &[u16], n: usize, acc: &mut Vec<i32>, out: &mut [f32]) {
        let (c_total, k, m) = (self.cb.c, self.cb.k, self.m);
        let qc = self.qcommon.as_slice();
        acc.resize(m, 0);
        for i in 0..n {
            acc.fill(0);
            for c in 0..c_total {
                let kk = idx[i * c_total + c] as usize;
                for j in 0..m {
                    acc[j] += qc[(c * k + kk) * m + j] as i32;
                }
            }
            for j in 0..m {
                out[i * m + j] += acc[j] as f32 * self.common_scale;
            }
        }
    }

    /// ③+④ deployed path: common-scale INT8 rows accumulated in i16
    /// within overflow-safe codebook groups, widened to i32 between
    /// groups (the paper's INT16-lanes-then-INT32 scheme), one f32 scale
    /// multiply per output element at the end.
    fn accumulate_int_blocked(
        &self,
        idx: &[u16],
        n: usize,
        acc16: &mut Vec<i16>,
        acc32: &mut Vec<i32>,
        out: &mut [f32],
    ) {
        let (c_total, k, m) = (self.cb.c, self.cb.k, self.m);
        let qc = self.qcommon.as_slice();
        // |q| <= 127, i16 max 32767 -> up to 256 safe adds per i16 lane.
        const GROUP: usize = 256;
        acc16.resize(m, 0);
        acc32.resize(m, 0);
        for i in 0..n {
            acc32.fill(0);
            let row_idx = &idx[i * c_total..(i + 1) * c_total];
            for group in row_idx.chunks(GROUP).enumerate() {
                let (g, chunk) = group;
                acc16.fill(0);
                for (cc, &kk16) in chunk.iter().enumerate() {
                    let c = g * GROUP + cc;
                    let kk = kk16 as usize;
                    let base = (c * k + kk) * m;
                    let row = &qc[base..base + m];
                    for (a, &q) in acc16.iter_mut().zip(row) {
                        *a += q as i16;
                    }
                }
                for (a32, &a16) in acc32.iter_mut().zip(acc16.iter()) {
                    *a32 += a16 as i32;
                }
            }
            let dst = &mut out[i * m..(i + 1) * m];
            for (o, &a) in dst.iter_mut().zip(acc32.iter()) {
                *o += a as f32 * self.common_scale;
            }
        }
    }

    // ------------------------------------------------------------------

    /// Stage-1-only scratch forward: encode `a` into `s.idx` (sized
    /// here) using `s.slab`/`s.scores`. Op order is identical to the
    /// encode half of [`LutLinear::forward_scratch`] — the profiling
    /// path times the phases separately without changing behaviour.
    pub fn encode_scratch(&self, a: &[f32], n: usize, opts: LutOpts, s: &mut LutScratch) {
        let d = self.input_dim();
        assert_eq!(a.len(), n * d);
        let LutScratch { idx, slab, scores, .. } = s;
        idx.clear();
        idx.resize(n * self.cb.c, 0);
        if opts.centroid_stationary {
            self.encode_centroid_stationary(a, n, opts, slab, scores, idx);
        } else {
            self.encode_naive(a, n, opts, idx);
        }
    }

    /// Stage-2-only scratch forward: zero `out[..n*M]` and accumulate
    /// from the indices [`LutLinear::encode_scratch`] left in `s.idx`
    /// (bias applied last).
    pub fn accumulate_scratch(&self, n: usize, opts: LutOpts, s: &mut LutScratch, out: &mut [f32]) {
        let LutScratch { idx, acc16, acc32, .. } = s;
        let out = &mut out[..n * self.m];
        out.fill(0.0);
        self.accumulate_buffered(idx, n, opts, acc16, acc32, out);
    }

    /// Full LUT-AMM forward: `out[n, M] = approx(a @ B) + bias`, with
    /// every working buffer taken from `s` (resized within capacity —
    /// the allocation-free path `api::LutKernel` drives).
    pub fn forward_scratch(
        &self,
        a: &[f32],
        n: usize,
        opts: LutOpts,
        s: &mut LutScratch,
        out: &mut [f32],
    ) {
        self.encode_scratch(a, n, opts, s);
        self.accumulate_scratch(n, opts, s, out);
    }

    /// Full LUT-AMM forward: `out[n, M] = approx(a @ B) + bias`.
    /// `idx_scratch` must be n*C long (callers reuse it across layers);
    /// the remaining working buffers are allocated per call — use
    /// [`LutLinear::forward_scratch`] on allocation-sensitive paths.
    pub fn forward_into(
        &self,
        a: &[f32],
        n: usize,
        opts: LutOpts,
        idx_scratch: &mut Vec<u16>,
        out: &mut [f32],
    ) {
        let mut s = LutScratch::default();
        std::mem::swap(&mut s.idx, idx_scratch);
        self.forward_scratch(a, n, opts, &mut s, out);
        std::mem::swap(&mut s.idx, idx_scratch);
    }

    /// Convenience allocating forward.
    pub fn forward(&self, a: &[f32], n: usize, opts: LutOpts) -> Vec<f32> {
        let mut out = vec![0.0f32; n * self.m];
        let mut idx = Vec::new();
        self.forward_into(a, n, opts, &mut idx, &mut out);
        out
    }

    /// FP32-table forward (no scalar quantization — ablation baseline).
    pub fn forward_f32_table(&self, a: &[f32], n: usize, opts: LutOpts) -> Vec<f32> {
        let (c_total, k, m) = (self.cb.c, self.cb.k, self.m);
        let mut idx = vec![0u16; n * c_total];
        self.encode_into(a, n, opts, &mut idx);
        let mut out = vec![0.0f32; n * m];
        for i in 0..n {
            let dst = &mut out[i * m..(i + 1) * m];
            for c in 0..c_total {
                let kk = idx[i * c_total + c] as usize;
                let row = &self.table_f32[(c * k + kk) * m..(c * k + kk + 1) * m];
                for (o, &t) in dst.iter_mut().zip(row) {
                    *o += t;
                }
            }
            if let Some(bias) = &self.bias {
                for (o, &b) in dst.iter_mut().zip(bias) {
                    *o += b;
                }
            }
        }
        out
    }
}

/// Argmin over scores. `interleaved = false` is the strict sequential
/// compare chain (each step RAW-depends on the previous — the pattern
/// §5.1 calls out). `interleaved = true` is the intra-codebook-parallel
/// realization: a branch-free vectorizable min-reduction followed by an
/// equality scan for the index — two data-parallel passes instead of one
/// dependent chain.
#[inline]
pub(crate) fn argmin(scores: &[f32], interleaved: bool) -> usize {
    if !interleaved || scores.len() < 8 {
        let mut best = 0usize;
        let mut best_v = scores[0];
        for (i, &s) in scores.iter().enumerate().skip(1) {
            if s < best_v {
                best_v = s;
                best = i;
            }
        }
        return best;
    }
    // pass 1: four independent min lanes (no cross-iteration dependency;
    // lowers to SIMD min), then a 4-way reduce
    let mut lanes = [f32::INFINITY; 4];
    let mut chunks = scores.chunks_exact(4);
    for ch in &mut chunks {
        for (l, &s) in lanes.iter_mut().zip(ch) {
            *l = if s < *l { s } else { *l };
        }
    }
    let mut min = lanes[0].min(lanes[1]).min(lanes[2].min(lanes[3]));
    for &s in chunks.remainder() {
        min = min.min(s);
    }
    // pass 2: first index equal to the min (tie-break = lowest index,
    // matching the sequential scan)
    scores.iter().position(|&s| s == min).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pq::kmeans::learn_codebooks;
    use crate::util::{prng::Prng, prop};

    fn exact_mm(a: &[f32], w: &[f32], n: usize, d: usize, m: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n * m];
        for i in 0..n {
            for t in 0..d {
                let av = a[i * d + t];
                for j in 0..m {
                    out[i * m + j] += av * w[t * m + j];
                }
            }
        }
        out
    }

    fn setup(seed: u64, n: usize, c: usize, v: usize, k: usize, m: usize) -> (Vec<f32>, Vec<f32>, LutLinear) {
        let mut rng = Prng::new(seed);
        let d = c * v;
        let a = rng.normal_vec(n * d, 1.0);
        let w = rng.normal_vec(d * m, 1.0);
        let cb = learn_codebooks(&a, n, d, c, k, 10, seed);
        let lut = LutLinear::new(cb, &w, m, None, 8);
        (a, w, lut)
    }

    #[test]
    fn all_opt_configs_agree_on_indices() {
        let (a, _, lut) = setup(0, 40, 4, 9, 16, 8);
        let mut base = vec![0u16; 40 * 4];
        lut.encode_into(&a, 40, LutOpts::none(), &mut base);
        for &cs in &[false, true] {
            for &il in &[false, true] {
                let opts = LutOpts {
                    centroid_stationary: cs,
                    interleaved_argmin: il,
                    ..LutOpts::none()
                };
                let mut idx = vec![0u16; 40 * 4];
                lut.encode_into(&a, 40, opts, &mut idx);
                assert_eq!(idx, base, "cs={cs} il={il}");
            }
        }
    }

    #[test]
    fn accumulate_paths_agree() {
        let (a, _, lut) = setup(1, 32, 4, 4, 16, 24);
        let naive = lut.forward(&a, 32, LutOpts::none());
        for &bt in &[false, true] {
            for &ma in &[false, true] {
                let opts = LutOpts {
                    blocked_table_read: bt,
                    mixed_accum: ma,
                    ..LutOpts::all()
                };
                let got = lut.forward(&a, 32, opts);
                // integer common-scale path re-rounds: tolerance one step
                let tol = if ma { lut.common_scale * lut.cb.c as f32 } else { 1e-4 };
                prop::assert_close(&got, &naive, 1e-4, tol).unwrap_or_else(|e| {
                    panic!("bt={bt} ma={ma}: {e}")
                });
            }
        }
    }

    #[test]
    fn approximates_exact_mm() {
        // With K=64 on clustered data, LUT-AMM must capture most signal.
        let (a, w, lut) = setup(2, 128, 4, 4, 64, 16);
        let approx = lut.forward(&a, 128, LutOpts::all());
        let exact = exact_mm(&a, &w, 128, 16, 16);
        let err: f32 = approx.iter().zip(&exact).map(|(x, y)| (x - y) * (x - y)).sum::<f32>() / exact.len() as f32;
        let sig: f32 = exact.iter().map(|x| x * x).sum::<f32>() / exact.len() as f32;
        assert!(err < sig * 0.5, "err={err} sig={sig}");
    }

    #[test]
    fn exact_when_inputs_are_centroids() {
        // Rows drawn exactly from centroids -> quantization error only.
        let mut rng = Prng::new(3);
        let (c, k, v, m, n) = (3, 8, 4, 6, 20);
        let d = c * v;
        let cb_data = rng.normal_vec(c * k * v, 1.0);
        let cb = Codebooks::new(c, k, v, cb_data);
        let w = rng.normal_vec(d * m, 1.0);
        let mut a = vec![0.0f32; n * d];
        for i in 0..n {
            for ci in 0..c {
                let kk = rng.below(k);
                a[i * d + ci * v..i * d + (ci + 1) * v].copy_from_slice(cb.centroid(ci, kk));
            }
        }
        let lut = LutLinear::new(cb, &w, m, None, 8);
        let approx = lut.forward_f32_table(&a, n, LutOpts::all());
        let exact = exact_mm(&a, &w, n, d, m);
        prop::assert_close(&approx, &exact, 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn bias_applied_once() {
        let (a, _, mut lutless) = setup(4, 8, 2, 4, 8, 5);
        let no_bias = lutless.forward(&a, 8, LutOpts::all());
        lutless.bias = Some(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        let with_bias = lutless.forward(&a, 8, LutOpts::all());
        for i in 0..8 {
            for j in 0..5 {
                let diff = with_bias[i * 5 + j] - no_bias[i * 5 + j];
                assert!((diff - (j + 1) as f32).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn argmin_interleaved_matches_sequential_property() {
        prop::check(200, |g| {
            let len = g.usize(1..40);
            let scores = g.f32_vec(len, 1.0);
            let a = argmin(&scores, false);
            let b = argmin(&scores, true);
            if scores[a] != scores[b] {
                return Err(format!("{a} vs {b} on {scores:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn forward_property_all_paths_close() {
        prop::check(25, |g| {
            let n = g.usize(1..20);
            let c = g.usize(1..5);
            let v = *g.pick(&[2usize, 4, 9]);
            let k = *g.pick(&[8usize, 16]);
            let m = g.usize(1..20);
            let d = c * v;
            let a = g.f32_vec(n * d, 1.0);
            let w = g.f32_vec(d * m, 1.0);
            let cb = learn_codebooks(&a, n, d, c, k, 5, g.case_seed);
            let lut = LutLinear::new(cb, &w, m, None, 8);
            let naive = lut.forward(&a, n, LutOpts::none());
            let fast = lut.forward(&a, n, LutOpts::all());
            let tol = lut.common_scale * c as f32 + 1e-4;
            prop::assert_close(&fast, &naive, 1e-4, tol)
        });
    }

    #[test]
    fn deployed_bytes_accounting() {
        let (_, _, lut) = setup(5, 16, 4, 9, 16, 32);
        let expect = 4 * 16 * 9 * 4 + 4 * 16 * 32 + 4 * 4;
        assert_eq!(lut.deployed_bytes(), expect);
    }

    #[test]
    fn hot_table_is_cache_line_aligned_even_after_clone() {
        let (_, _, lut) = setup(6, 16, 3, 4, 8, 7);
        assert_eq!(lut.table_bytes(), 3 * 8 * 7);
        assert_eq!(lut.table_alignment_bytes(), crate::lut::TABLE_ALIGN);
        assert!(lut.qcommon.is_aligned());
        let cloned = lut.clone();
        assert!(cloned.qcommon.is_aligned(), "clone must re-pin the table");
        assert_eq!(cloned.qcommon.as_slice(), lut.qcommon.as_slice());
    }
}
