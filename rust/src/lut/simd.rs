//! Explicit-SIMD closest-centroid search (paper §5.1) — the encode core
//! behind the `"lut-simd"` kernel.
//!
//! Two implementations of the same distance kernel, selected at runtime:
//!
//! * **portable** — safe Rust structured as 8-wide independent lanes the
//!   compiler lowers to SIMD (the auto-vectorizing realization; always
//!   compiled, used on non-x86 targets and when AVX2 is absent).
//! * **avx2** — `core::arch::x86_64` intrinsics (`vmulps`/`vaddps`),
//!   compiled only with `--features simd` on x86_64 and dispatched via
//!   `is_x86_feature_detected!` (`std::simd` remains nightly-only, so the
//!   stable intrinsic path realizes the paper's NEON distance kernel).
//!
//! **Bitwise contract**: both paths perform, per score element, the exact
//! FP operation sequence of the scalar centroid-stationary path
//! (`scores[k] = sqn[k]`, then `+= a[t] * (-2 p[t][k])` for `t`
//! ascending — the order `nn::gemm::gemm` uses). rustc never reorders or
//! contracts float ops (no fast-math, no implicit FMA), so the SIMD
//! encode is bit-identical to the scalar reference on every input — the
//! `kernel_parity` fuzz harness asserts this across random shapes.
//!
//! The argmin is the §6.3 ② intra-codebook-parallel realization: a
//! branch-free min reduction over 4 independent lanes followed by a
//! first-index-equal scan, which matches the sequential scan's
//! lowest-index tie-break exactly (see `engine::argmin`).

use super::engine::{argmin, LutLinear};

/// Name of the distance-kernel implementation the current build/CPU
/// actually dispatches to: `"avx2"` or `"portable"`.
pub fn active_backend() -> &'static str {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return "avx2";
        }
    }
    "portable"
}

/// Encode rows of `a` ([n, D]) to centroid indices ([n, C] into `idx`),
/// vectorized over the K dimension. `scores` is caller-owned scratch
/// (resized to K within capacity). Produces indices bit-identical to
/// `LutLinear::encode_into` with `centroid_stationary = true`.
pub fn encode_simd(
    lut: &LutLinear,
    a: &[f32],
    n: usize,
    scores: &mut Vec<f32>,
    idx: &mut [u16],
) {
    let (c_total, k, v) = (lut.cb.c, lut.cb.k, lut.cb.v);
    let d = c_total * v;
    assert_eq!(a.len(), n * d, "encode_simd input size");
    assert_eq!(idx.len(), n * c_total, "encode_simd index size");
    scores.resize(k, 0.0);
    // Hoist backend selection out of the n*C hot loop (the runtime
    // feature probe is an atomic load — cheap, but invariant here).
    let accumulate = select_accumulate(k);
    for c in 0..c_total {
        // Codebook-stationary: the [V, K] transposed, -2-prescaled
        // centroid block and the |p|^2 row stay hot across all n rows.
        let cbt2 = &lut.cb_t2[c * v * k..(c + 1) * v * k];
        let sqn = &lut.sqn[c * k..(c + 1) * k];
        for i in 0..n {
            let sub = &a[i * d + c * v..i * d + (c + 1) * v];
            scores.copy_from_slice(sqn);
            accumulate(sub, cbt2, scores);
            idx[i * c_total + c] = argmin(scores, true) as u16;
        }
    }
}

/// Pick the accumulate implementation once per encode: AVX2 when the
/// build carries it, the CPU reports it, and K fills at least one
/// 8-wide register; the portable lanes otherwise.
fn select_accumulate(k: usize) -> fn(&[f32], &[f32], &mut [f32]) {
    let _ = k; // only consulted on the intrinsic-capable cfg
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if k >= 8 && std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: avx2 runtime-verified; bounds asserted by callers.
            return |sub: &[f32], w: &[f32], scores: &mut [f32]| unsafe {
                distance_accumulate_avx2(sub, w, scores)
            };
        }
    }
    distance_accumulate_portable
}

/// `scores[k] = seed[k] + sum_t sub[t] * w[t*K + k]`, t ascending per
/// element — the §5.1 distance computation for one (row, codebook) pair.
/// `w` is the K-contiguous `[V, K]` block, `seed` the precomputed |p|^2.
#[inline]
pub fn distance_scores(sub: &[f32], w: &[f32], seed: &[f32], scores: &mut [f32]) {
    let k = scores.len();
    assert_eq!(seed.len(), k);
    assert_eq!(w.len(), sub.len() * k);
    scores.copy_from_slice(seed);
    select_accumulate(k)(sub, w, scores);
}

/// Portable lane-structured accumulate: 8 independent K-lane chains per
/// chunk (no cross-lane dependency — lowers to SIMD mul/add on any
/// target the compiler knows).
fn distance_accumulate_portable(sub: &[f32], w: &[f32], scores: &mut [f32]) {
    let k = scores.len();
    for (t, &a) in sub.iter().enumerate() {
        let wrow = &w[t * k..(t + 1) * k];
        let mut sc = scores.chunks_exact_mut(8);
        let mut wc = wrow.chunks_exact(8);
        for (s8, w8) in (&mut sc).zip(&mut wc) {
            for (s, &wv) in s8.iter_mut().zip(w8) {
                *s += a * wv;
            }
        }
        for (s, &wv) in sc.into_remainder().iter_mut().zip(wc.remainder()) {
            *s += a * wv;
        }
    }
}

/// AVX2 accumulate: one broadcast `a[t]`, 8-lane `vmulps` + `vaddps` per
/// K chunk. Deliberately *not* FMA — a fused multiply-add rounds once
/// where mul+add rounds twice, which would break the bitwise contract
/// with the scalar path.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx2")]
unsafe fn distance_accumulate_avx2(sub: &[f32], w: &[f32], scores: &mut [f32]) {
    use std::arch::x86_64::*;
    let k = scores.len();
    let k8 = k & !7usize;
    for (t, &a) in sub.iter().enumerate() {
        let av = _mm256_set1_ps(a);
        let wrow = w.as_ptr().add(t * k);
        let sp = scores.as_mut_ptr();
        let mut kk = 0usize;
        while kk < k8 {
            let acc = _mm256_loadu_ps(sp.add(kk));
            let prod = _mm256_mul_ps(av, _mm256_loadu_ps(wrow.add(kk)));
            _mm256_storeu_ps(sp.add(kk), _mm256_add_ps(acc, prod));
            kk += 8;
        }
        while kk < k {
            *sp.add(kk) += a * *wrow.add(kk);
            kk += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::LutOpts;
    use crate::pq::kmeans::learn_codebooks;
    use crate::util::prop;

    /// Strict scalar oracle: one dependent chain per element, t ascending.
    fn scores_oracle(sub: &[f32], w: &[f32], seed: &[f32]) -> Vec<f32> {
        let k = seed.len();
        let mut s = seed.to_vec();
        for (t, &a) in sub.iter().enumerate() {
            for kk in 0..k {
                s[kk] += a * w[t * k + kk];
            }
        }
        s
    }

    #[test]
    fn distance_scores_bitwise_matches_oracle() {
        prop::check(100, |g| {
            let v = g.usize(1..12);
            let k = g.usize(1..40); // crosses the 8-lane boundary + remainders
            let sub = g.f32_vec(v, 1.0);
            let w = g.f32_vec(v * k, 1.0);
            let seed = g.f32_vec(k, 1.0);
            let mut got = vec![0.0f32; k];
            distance_scores(&sub, &w, &seed, &mut got);
            let want = scores_oracle(&sub, &w, &seed);
            if got != want {
                return Err(format!("k={k} v={v}: {got:?} vs {want:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn encode_simd_bitwise_matches_scalar_encode() {
        prop::check(60, |g| {
            let n = g.usize(1..12);
            let c = g.usize(1..5);
            let v = *g.pick(&[1usize, 2, 4, 9]);
            let k = *g.pick(&[1usize, 4, 8, 12, 16]);
            let d = c * v;
            let a = g.f32_vec(n * d, 1.0);
            let cb = learn_codebooks(&a, n, d, c, k, 4, g.case_seed);
            let lut = LutLinear::new(cb, &g.f32_vec(d * 3, 1.0), 3, None, 8);
            let mut want = vec![0u16; n * c];
            lut.encode_into(&a, n, LutOpts::deployed(), &mut want);
            let mut got = vec![u16::MAX; n * c];
            let mut scores = Vec::new();
            encode_simd(&lut, &a, n, &mut scores, &mut got);
            if got != want {
                return Err(format!("n={n} c={c} v={v} k={k}: {got:?} vs {want:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn backend_reports_a_known_name() {
        assert!(["avx2", "portable"].contains(&active_backend()));
    }
}
