//! Explicit-SIMD closest-centroid search (paper §5.1) — the encode core
//! behind the `"lut-simd"` kernel.
//!
//! Four implementations of the same distance kernel, selected at runtime
//! (see [`BACKENDS`] / [`active_backend`]):
//!
//! * **portable** — safe Rust structured as 8-wide independent lanes the
//!   compiler lowers to SIMD (the auto-vectorizing realization; always
//!   compiled, used whenever no intrinsic arm applies).
//! * **avx2** — `core::arch::x86_64` 8-lane intrinsics
//!   (`vmulps`/`vaddps`), compiled only with `--features simd` on x86_64
//!   and dispatched via `is_x86_feature_detected!`.
//! * **avx512** — the same kernel at 16 lanes (`_mm512_*`), probed via
//!   `is_x86_feature_detected!("avx512f")` and preferred over AVX2 when
//!   K fills a 16-wide register.
//! * **neon** — `core::arch::aarch64` 4-lane intrinsics
//!   (`vmulq_f32`/`vaddq_f32` — the paper's reference distance kernel).
//!   NEON is architecturally mandatory on aarch64, so no runtime probe
//!   is needed; the arm compiles with `--features simd` on aarch64 only
//!   (kept buildable by the CI `aarch64-unknown-linux-gnu` check leg).
//!
//! **Bitwise contract**: every arm performs, per score element, the exact
//! FP operation sequence of the scalar centroid-stationary path
//! (`scores[k] = sqn[k]`, then `+= a[t] * (-2 p[t][k])` for `t`
//! ascending — the order `nn::gemm::gemm` uses). Each element's chain
//! depends only on its own index, so lane width (4/8/16) cannot change
//! results; what would break them is fused multiply-add, which rounds
//! once where mul+add rounds twice — so `vfma`/`vfmadd` are **banned**
//! in every arm (each uses an explicit multiply then an explicit add),
//! and rustc never contracts float ops on its own (no fast-math). The
//! `kernel_parity` fuzz harness asserts bitwise equality across random
//! shapes, and the in-module tests pin every arm the running CPU can
//! execute against the strict scalar oracle.
//!
//! The argmin is the §6.3 ② intra-codebook-parallel realization: a
//! branch-free min reduction over 4 independent lanes followed by a
//! first-index-equal scan, which matches the sequential scan's
//! lowest-index tie-break exactly (see `engine::argmin`).

use super::engine::{argmin, LutLinear};

/// Every distance-kernel backend name [`active_backend`] can return —
/// the closed enum `BENCH_e2e_latency.json`'s `simd_backend` field is
/// documented against (`util::schema`'s mirror test pins membership).
pub const BACKENDS: [&str; 4] = ["portable", "avx2", "avx512", "neon"];

/// Name of the distance-kernel implementation the current build/CPU
/// actually dispatches to. One of [`BACKENDS`]; the x86 probe prefers
/// the widest available arm (`avx512` > `avx2` > `portable`).
pub fn active_backend() -> &'static str {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if std::arch::is_x86_feature_detected!("avx512f") {
            return "avx512";
        }
        if std::arch::is_x86_feature_detected!("avx2") {
            return "avx2";
        }
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    {
        return "neon";
    }
    #[allow(unreachable_code)]
    "portable"
}

/// Encode rows of `a` ([n, D]) to centroid indices ([n, C] into `idx`),
/// vectorized over the K dimension. `scores` is caller-owned scratch
/// (resized to K within capacity). Produces indices bit-identical to
/// `LutLinear::encode_into` with `centroid_stationary = true`.
pub fn encode_simd(
    lut: &LutLinear,
    a: &[f32],
    n: usize,
    scores: &mut Vec<f32>,
    idx: &mut [u16],
) {
    let (c_total, k, v) = (lut.cb.c, lut.cb.k, lut.cb.v);
    let d = c_total * v;
    assert_eq!(a.len(), n * d, "encode_simd input size");
    assert_eq!(idx.len(), n * c_total, "encode_simd index size");
    scores.resize(k, 0.0);
    // Hoist backend selection out of the n*C hot loop (the runtime
    // feature probe is an atomic load — cheap, but invariant here).
    let accumulate = select_accumulate(k);
    for c in 0..c_total {
        // Codebook-stationary: the [V, K] transposed, -2-prescaled
        // centroid block and the |p|^2 row stay hot across all n rows.
        let cbt2 = &lut.cb_t2[c * v * k..(c + 1) * v * k];
        let sqn = &lut.sqn[c * k..(c + 1) * k];
        for i in 0..n {
            let sub = &a[i * d + c * v..i * d + (c + 1) * v];
            scores.copy_from_slice(sqn);
            accumulate(sub, cbt2, scores);
            idx[i * c_total + c] = argmin(scores, true) as u16;
        }
    }
}

/// Pick the accumulate implementation once per encode: the widest
/// intrinsic arm the build carries, the CPU reports, and K fills at
/// least one register of (16 lanes for AVX-512, 8 for AVX2, 4 for
/// NEON); the portable lanes otherwise.
fn select_accumulate(k: usize) -> fn(&[f32], &[f32], &mut [f32]) {
    let _ = k; // only consulted on the intrinsic-capable cfgs
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if k >= 16 && std::arch::is_x86_feature_detected!("avx512f") {
            // SAFETY: avx512f runtime-verified; bounds asserted by callers.
            return |sub: &[f32], w: &[f32], scores: &mut [f32]| unsafe {
                distance_accumulate_avx512(sub, w, scores)
            };
        }
        if k >= 8 && std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: avx2 runtime-verified; bounds asserted by callers.
            return |sub: &[f32], w: &[f32], scores: &mut [f32]| unsafe {
                distance_accumulate_avx2(sub, w, scores)
            };
        }
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    {
        if k >= 4 {
            // SAFETY: NEON is baseline on aarch64; bounds asserted by callers.
            return |sub: &[f32], w: &[f32], scores: &mut [f32]| unsafe {
                distance_accumulate_neon(sub, w, scores)
            };
        }
    }
    distance_accumulate_portable
}

/// `scores[k] = seed[k] + sum_t sub[t] * w[t*K + k]`, t ascending per
/// element — the §5.1 distance computation for one (row, codebook) pair.
/// `w` is the K-contiguous `[V, K]` block, `seed` the precomputed |p|^2.
#[inline]
pub fn distance_scores(sub: &[f32], w: &[f32], seed: &[f32], scores: &mut [f32]) {
    let k = scores.len();
    assert_eq!(seed.len(), k);
    assert_eq!(w.len(), sub.len() * k);
    scores.copy_from_slice(seed);
    select_accumulate(k)(sub, w, scores);
}

/// Portable lane-structured accumulate: 8 independent K-lane chains per
/// chunk (no cross-lane dependency — lowers to SIMD mul/add on any
/// target the compiler knows).
fn distance_accumulate_portable(sub: &[f32], w: &[f32], scores: &mut [f32]) {
    let k = scores.len();
    for (t, &a) in sub.iter().enumerate() {
        let wrow = &w[t * k..(t + 1) * k];
        let mut sc = scores.chunks_exact_mut(8);
        let mut wc = wrow.chunks_exact(8);
        for (s8, w8) in (&mut sc).zip(&mut wc) {
            for (s, &wv) in s8.iter_mut().zip(w8) {
                *s += a * wv;
            }
        }
        for (s, &wv) in sc.into_remainder().iter_mut().zip(wc.remainder()) {
            *s += a * wv;
        }
    }
}

/// AVX2 accumulate: one broadcast `a[t]`, 8-lane `vmulps` + `vaddps` per
/// K chunk. Deliberately *not* FMA — a fused multiply-add rounds once
/// where mul+add rounds twice, which would break the bitwise contract
/// with the scalar path.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx2")]
unsafe fn distance_accumulate_avx2(sub: &[f32], w: &[f32], scores: &mut [f32]) {
    use std::arch::x86_64::*;
    let k = scores.len();
    let k8 = k & !7usize;
    for (t, &a) in sub.iter().enumerate() {
        let av = _mm256_set1_ps(a);
        let wrow = w.as_ptr().add(t * k);
        let sp = scores.as_mut_ptr();
        let mut kk = 0usize;
        while kk < k8 {
            let acc = _mm256_loadu_ps(sp.add(kk));
            let prod = _mm256_mul_ps(av, _mm256_loadu_ps(wrow.add(kk)));
            _mm256_storeu_ps(sp.add(kk), _mm256_add_ps(acc, prod));
            kk += 8;
        }
        while kk < k {
            *sp.add(kk) += a * *wrow.add(kk);
            kk += 1;
        }
    }
}

/// AVX-512 accumulate: the AVX2 kernel at 16 lanes (`_mm512_mul_ps` +
/// `_mm512_add_ps`, never `_mm512_fmadd_ps` — same no-FMA rule). Each
/// score element still sees exactly one multiply and one add per `t`,
/// so widening the register changes nothing bitwise.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx512f")]
unsafe fn distance_accumulate_avx512(sub: &[f32], w: &[f32], scores: &mut [f32]) {
    use std::arch::x86_64::*;
    let k = scores.len();
    let k16 = k & !15usize;
    for (t, &a) in sub.iter().enumerate() {
        let av = _mm512_set1_ps(a);
        let wrow = w.as_ptr().add(t * k);
        let sp = scores.as_mut_ptr();
        let mut kk = 0usize;
        while kk < k16 {
            let acc = _mm512_loadu_ps(sp.add(kk));
            let prod = _mm512_mul_ps(av, _mm512_loadu_ps(wrow.add(kk)));
            _mm512_storeu_ps(sp.add(kk), _mm512_add_ps(acc, prod));
            kk += 16;
        }
        while kk < k {
            *sp.add(kk) += a * *wrow.add(kk);
            kk += 1;
        }
    }
}

/// NEON accumulate: one `vdupq_n_f32` broadcast, 4-lane `vmulq_f32` +
/// `vaddq_f32` per K chunk — the paper's reference distance kernel.
/// Deliberately built from separate multiply and add intrinsics:
/// `vmlaq_f32` lowers to `fmla` (fused, rounds once) on aarch64 and
/// would break the bitwise contract with the scalar path.
#[cfg(all(feature = "simd", target_arch = "aarch64"))]
unsafe fn distance_accumulate_neon(sub: &[f32], w: &[f32], scores: &mut [f32]) {
    use core::arch::aarch64::*;
    let k = scores.len();
    let k4 = k & !3usize;
    for (t, &a) in sub.iter().enumerate() {
        let av = vdupq_n_f32(a);
        let wrow = w.as_ptr().add(t * k);
        let sp = scores.as_mut_ptr();
        let mut kk = 0usize;
        while kk < k4 {
            let acc = vld1q_f32(sp.add(kk));
            let prod = vmulq_f32(av, vld1q_f32(wrow.add(kk)));
            vst1q_f32(sp.add(kk), vaddq_f32(acc, prod));
            kk += 4;
        }
        while kk < k {
            *sp.add(kk) += a * *wrow.add(kk);
            kk += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lut::LutOpts;
    use crate::pq::kmeans::learn_codebooks;
    use crate::util::prop;

    /// Strict scalar oracle: one dependent chain per element, t ascending.
    fn scores_oracle(sub: &[f32], w: &[f32], seed: &[f32]) -> Vec<f32> {
        let k = seed.len();
        let mut s = seed.to_vec();
        for (t, &a) in sub.iter().enumerate() {
            for kk in 0..k {
                s[kk] += a * w[t * k + kk];
            }
        }
        s
    }

    #[test]
    fn distance_scores_bitwise_matches_oracle() {
        prop::check(100, |g| {
            let v = g.usize(1..12);
            let k = g.usize(1..40); // crosses the 8/16-lane boundaries + remainders
            let sub = g.f32_vec(v, 1.0);
            let w = g.f32_vec(v * k, 1.0);
            let seed = g.f32_vec(k, 1.0);
            let mut got = vec![0.0f32; k];
            distance_scores(&sub, &w, &seed, &mut got);
            let want = scores_oracle(&sub, &w, &seed);
            if got != want {
                return Err(format!("k={k} v={v}: {got:?} vs {want:?}"));
            }
            Ok(())
        });
    }

    /// Every intrinsic arm the running CPU can execute, called directly
    /// (bypassing `select_accumulate`'s K threshold), must be bitwise
    /// the scalar oracle on every K — including lane remainders 7, 9,
    /// 15, 17 and the sub-register sizes the dispatcher would normally
    /// route to portable.
    #[test]
    fn every_executable_arm_is_bitwise_the_oracle() {
        type Arm = (&'static str, fn(&[f32], &[f32], &mut [f32]));
        let mut arms: Vec<Arm> = vec![("portable", distance_accumulate_portable)];
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                arms.push(("avx2", |s, w, sc| unsafe { distance_accumulate_avx2(s, w, sc) }));
            }
            if std::arch::is_x86_feature_detected!("avx512f") {
                arms.push(("avx512", |s, w, sc| unsafe {
                    distance_accumulate_avx512(s, w, sc)
                }));
            }
        }
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        arms.push(("neon", |s, w, sc| unsafe { distance_accumulate_neon(s, w, sc) }));
        prop::check(120, |g| {
            let v = g.usize(1..12);
            let k = *g.pick(&[1usize, 3, 4, 7, 8, 9, 15, 16, 17, 24, 31, 32, 33]);
            let sub = g.f32_vec(v, 1.0);
            let w = g.f32_vec(v * k, 1.0);
            let seed = g.f32_vec(k, 1.0);
            let want = scores_oracle(&sub, &w, &seed);
            for (name, arm) in &arms {
                let mut got = seed.clone();
                arm(&sub, &w, &mut got);
                if got != want {
                    return Err(format!("{name} k={k} v={v}: {got:?} vs {want:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn encode_simd_bitwise_matches_scalar_encode() {
        prop::check(60, |g| {
            let n = g.usize(1..12);
            let c = g.usize(1..5);
            let v = *g.pick(&[1usize, 2, 4, 9]);
            let k = *g.pick(&[1usize, 4, 7, 8, 9, 12, 15, 16, 17]);
            let d = c * v;
            let a = g.f32_vec(n * d, 1.0);
            let cb = learn_codebooks(&a, n, d, c, k, 4, g.case_seed);
            let lut = LutLinear::new(cb, &g.f32_vec(d * 3, 1.0), 3, None, 8);
            let mut want = vec![0u16; n * c];
            lut.encode_into(&a, n, LutOpts::deployed(), &mut want);
            let mut got = vec![u16::MAX; n * c];
            let mut scores = Vec::new();
            encode_simd(&lut, &a, n, &mut scores, &mut got);
            if got != want {
                return Err(format!("n={n} c={c} v={v} k={k}: {got:?} vs {want:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn backend_reports_a_known_name() {
        assert!(BACKENDS.contains(&active_backend()));
        // the enum itself stays closed and duplicate-free
        assert_eq!(BACKENDS[0], "portable");
        for (i, a) in BACKENDS.iter().enumerate() {
            assert!(!BACKENDS[i + 1..].contains(a), "duplicate backend {a}");
        }
    }
}
