//! Cache-aware table storage: alignment-pinned buffers for the hot
//! lookup tables.
//!
//! Every LUT-family kernel reads its table rows M-contiguously in the
//! inner accumulation loop, so the layout contract is: tables are
//! stored `[C, K, M]` row-major (rows packed, no stride padding — the
//! access order *is* the storage order) with the first element pinned
//! to a cache-line boundary. This is the same discipline tract's
//! `LutKer::table_alignment_bytes()` imposes per micro-kernel: the
//! kernel declares the alignment, the storage honors it, and the
//! session's memory report exposes both so regressions are measurable
//! (`benches/memory_footprint.rs`).
//!
//! [`AlignedVec`] is the safe realization: it over-allocates a plain
//! `Vec<T>` by one alignment unit and exposes the aligned window, so no
//! `unsafe` allocator calls are needed and the buffer stays a normal
//! owned allocation.

/// Cache-line alignment every LUT-family kernel pins its hot table to.
pub const TABLE_ALIGN: usize = 64;

/// A fixed-length buffer whose first exposed element sits on an
/// `align`-byte boundary. The buffer never grows after construction;
/// `Clone` re-derives the aligned window for the new allocation.
#[derive(Debug)]
pub struct AlignedVec<T: Copy + Default> {
    buf: Vec<T>,
    /// element offset of the aligned window inside `buf`
    offset: usize,
    len: usize,
    align: usize,
}

impl<T: Copy + Default> AlignedVec<T> {
    /// A zero-filled aligned buffer of `len` elements. `align` must be
    /// a power of two and a multiple of the element size.
    pub fn zeroed(len: usize, align: usize) -> AlignedVec<T> {
        let size = std::mem::size_of::<T>();
        assert!(align.is_power_of_two(), "alignment must be a power of two");
        assert!(align % size == 0, "alignment must be a multiple of the element size");
        let slack = align / size;
        let buf = vec![T::default(); len + slack];
        // The Vec allocation is element-aligned, so the byte misfit is a
        // multiple of the element size and the window offset is exact.
        let mis = buf.as_ptr() as usize % align;
        let offset = if mis == 0 { 0 } else { (align - mis) / size };
        AlignedVec { buf, offset, len, align }
    }

    /// An aligned copy of `data`.
    pub fn from_slice(data: &[T], align: usize) -> AlignedVec<T> {
        let mut v = Self::zeroed(data.len(), align);
        v.as_mut_slice().copy_from_slice(data);
        v
    }

    pub fn as_slice(&self) -> &[T] {
        &self.buf[self.offset..self.offset + self.len]
    }

    pub fn as_mut_slice(&mut self) -> &mut [T] {
        &mut self.buf[self.offset..self.offset + self.len]
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The alignment (bytes) the window was pinned to at construction.
    pub fn align_bytes(&self) -> usize {
        self.align
    }

    /// Whether the exposed window actually starts on the pinned
    /// boundary (true by construction; asserted in tests).
    pub fn is_aligned(&self) -> bool {
        self.as_slice().as_ptr() as usize % self.align == 0
    }
}

impl<T: Copy + Default> Clone for AlignedVec<T> {
    fn clone(&self) -> Self {
        // Recompute the window for the fresh allocation — copying
        // `offset` verbatim would mis-align the clone.
        Self::from_slice(self.as_slice(), self.align)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeroed_is_aligned_and_zero() {
        for len in [0usize, 1, 7, 64, 255] {
            for align in [1usize, 16, 64, 128] {
                let v = AlignedVec::<u8>::zeroed(len, align);
                assert!(v.is_aligned(), "len={len} align={align}");
                assert_eq!(v.len(), len);
                assert_eq!(v.align_bytes(), align);
                assert!(v.as_slice().iter().all(|&b| b == 0));
            }
        }
    }

    #[test]
    fn from_slice_round_trips_every_element_type() {
        let bytes: Vec<i8> = (0..100).map(|i| (i as i8).wrapping_mul(3)).collect();
        let v = AlignedVec::from_slice(&bytes, TABLE_ALIGN);
        assert!(v.is_aligned());
        assert_eq!(v.as_slice(), &bytes[..]);

        let floats: Vec<f32> = (0..33).map(|i| i as f32 * 0.5).collect();
        let f = AlignedVec::from_slice(&floats, TABLE_ALIGN);
        assert!(f.is_aligned());
        assert_eq!(f.as_slice(), &floats[..]);
    }

    #[test]
    fn clone_stays_aligned() {
        let v = AlignedVec::from_slice(&[1i8, 2, 3, 4, 5], TABLE_ALIGN);
        let c = v.clone();
        assert!(c.is_aligned(), "clone must re-derive its window");
        assert_eq!(c.as_slice(), v.as_slice());
    }

    #[test]
    fn mutation_stays_in_window() {
        let mut v = AlignedVec::<u8>::zeroed(16, 64);
        v.as_mut_slice().copy_from_slice(&[7u8; 16]);
        assert!(v.as_slice().iter().all(|&b| b == 7));
        assert!(v.is_aligned());
    }
}
