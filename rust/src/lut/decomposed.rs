//! Decomposed lookup tables (ReducedLUT-style, PAPERS.md: "Table
//! Decomposition with Don't Care Conditions"): split each `[C, K, M]`
//! table into a **shared base** plus **small residual sub-tables**.
//!
//! Per codebook `c`, the base row `base[c] = mean_k T[c, k, :]` carries
//! the part of the table every centroid choice shares; since the base
//! rows are added regardless of which centroid wins, they fold across
//! codebooks into one `[M]` vector `base_total = sum_c base[c]` — the
//! rank-one component of the output. What remains per `(c, k)` is the
//! residual `T[c, k, :] - base[c]`, which is small (centroids cluster,
//! so table rows cluster) and quantizes to **4-bit signed** values at a
//! per-codebook scale, nibble-packed two to a byte.
//!
//! Memory: `4*M + C*K*ceil(M/2) + 4*C` bytes vs the deployed INT8
//! table's `C*K*M` — approaching **2x smaller** as tables grow, at a
//! bounded accuracy cost (residual quantization only; the base is kept
//! exact f32). The residual sub-tables are stored `[C, K, ceil(M/2)]`
//! row-major — the inner-loop access order — pinned to
//! [`TABLE_ALIGN`](crate::lut::layout::TABLE_ALIGN) like every other
//! hot table (see `lut::layout`).
//!
//! The `"lut-dec"` kernel (`api::DecLutKernel`) executes this
//! decomposition; its documented error bound vs the scalar `"lut"`
//! reference is pinned by the `kernel_parity` fuzz harness.

use crate::lut::layout::{AlignedVec, TABLE_ALIGN};
use crate::lut::LutLinear;

/// 4-bit signed residual range: values quantize to `-7..=7` (symmetric,
/// so the scale maps `max|resid|` to 7) and are stored biased by +8 in
/// one nibble.
const RESID_MAX: f32 = 7.0;

/// A `[C, K, M]` table decomposed into a shared base vector plus
/// nibble-packed 4-bit residual sub-tables.
#[derive(Debug, Clone)]
pub struct DecomposedTable {
    /// rank-one component folded across codebooks: `sum_c mean_k T[c,k,:]`, `[M]` f32
    pub base_total: Vec<f32>,
    /// residual quantization step per codebook, `[C]`
    pub scales: Vec<f32>,
    /// nibble-packed residuals `[C, K, ceil(M/2)]`, low nibble = even
    /// output index, biased by +8 (cache-line aligned)
    resid: AlignedVec<u8>,
    pub c: usize,
    pub k: usize,
    pub m: usize,
}

impl DecomposedTable {
    /// Decompose the exact f32 table of `lut`.
    pub fn decompose(lut: &LutLinear) -> DecomposedTable {
        let (c_total, k, m) = (lut.qtable.c, lut.qtable.k, lut.m);
        let table = &lut.table_f32;
        assert_eq!(table.len(), c_total * k * m);

        // Per-codebook mean rows, folded into the shared base vector.
        let mut base = vec![0.0f32; c_total * m];
        let mut base_total = vec![0.0f32; m];
        for c in 0..c_total {
            let brow = &mut base[c * m..(c + 1) * m];
            for kk in 0..k {
                let row = &table[(c * k + kk) * m..(c * k + kk + 1) * m];
                for (b, &t) in brow.iter_mut().zip(row) {
                    *b += t;
                }
            }
            let inv_k = 1.0 / k as f32;
            for (bt, b) in base_total.iter_mut().zip(brow.iter_mut()) {
                *b *= inv_k;
                *bt += *b;
            }
        }

        // Per-codebook residual scale: max|resid| maps to RESID_MAX.
        let mut scales = vec![0.0f32; c_total];
        for c in 0..c_total {
            let brow = &base[c * m..(c + 1) * m];
            let mut max_abs = 0.0f32;
            for kk in 0..k {
                let row = &table[(c * k + kk) * m..(c * k + kk + 1) * m];
                for (&t, &b) in row.iter().zip(brow) {
                    max_abs = max_abs.max((t - b).abs());
                }
            }
            scales[c] = (max_abs / RESID_MAX).max(1e-30);
        }

        // Quantize + nibble-pack the residual sub-tables.
        let row_bytes = m.div_ceil(2);
        let mut resid = AlignedVec::<u8>::zeroed(c_total * k * row_bytes, TABLE_ALIGN);
        let packed = resid.as_mut_slice();
        for c in 0..c_total {
            let brow = &base[c * m..(c + 1) * m];
            let inv_s = 1.0 / scales[c];
            for kk in 0..k {
                let row = &table[(c * k + kk) * m..(c * k + kk + 1) * m];
                let dst = &mut packed[(c * k + kk) * row_bytes..(c * k + kk + 1) * row_bytes];
                for j in 0..m {
                    let r = (row[j] - brow[j]) * inv_s;
                    let q = r.round().clamp(-RESID_MAX, RESID_MAX) as i32;
                    let nib = (q + 8) as u8; // biased: 1..=15
                    if j & 1 == 0 {
                        dst[j / 2] = nib;
                    } else {
                        dst[j / 2] |= nib << 4;
                    }
                }
            }
        }

        DecomposedTable { base_total, scales, resid, c: c_total, k, m }
    }

    /// Bytes per packed residual row (`ceil(M/2)`).
    pub fn row_bytes(&self) -> usize {
        self.m.div_ceil(2)
    }

    /// The packed residual sub-tables, `[C, K, row_bytes]` row-major.
    pub fn resid(&self) -> &[u8] {
        self.resid.as_slice()
    }

    /// Bytes held by the decomposed representation (base + residual
    /// sub-tables + scales) — the Fig. 10-style table accounting the
    /// memory bench reads.
    pub fn table_bytes(&self) -> usize {
        self.base_total.len() * 4 + self.resid.len() + self.scales.len() * 4
    }

    /// Alignment (bytes) the residual sub-tables are pinned to.
    pub fn table_alignment_bytes(&self) -> usize {
        self.resid.align_bytes()
    }

    /// Dequantized residual for output `j` of row `(c, kk)` — test/debug
    /// path; the kernel inlines this unpacking.
    pub fn residual_at(&self, c: usize, kk: usize, j: usize) -> f32 {
        let byte = self.resid.as_slice()[(c * self.k + kk) * self.row_bytes() + j / 2];
        let nib = if j & 1 == 0 { byte & 0x0F } else { byte >> 4 };
        (nib as i32 - 8) as f32 * self.scales[c]
    }

    /// Reconstructed table entry `(c, kk, j)` = shared mean row +
    /// dequantized residual. Reconstruction error is bounded by half a
    /// residual step: `|recon - T[c,kk,j]| <= scales[c] / 2`.
    pub fn reconstruct_at(&self, base: &[f32], c: usize, kk: usize, j: usize) -> f32 {
        base[c * self.m + j] + self.residual_at(c, kk, j)
    }

    /// Worst-case per-element reconstruction error accumulated across
    /// all C codebooks: `sum_c scales[c] / 2`.
    pub fn max_abs_error(&self) -> f32 {
        self.scales.iter().sum::<f32>() * 0.5
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pq::kmeans::learn_codebooks;
    use crate::util::prng::Prng;

    fn fixture(seed: u64, n: usize, c: usize, v: usize, k: usize, m: usize) -> LutLinear {
        let mut rng = Prng::new(seed);
        let d = c * v;
        let a = rng.normal_vec(n * d, 1.0);
        let w = rng.normal_vec(d * m, 1.0);
        let cb = learn_codebooks(&a, n, d, c, k, 5, seed);
        LutLinear::new(cb, &w, m, None, 8)
    }

    /// Recompute the per-codebook mean rows the decomposition is
    /// defined against (the folded `base_total` loses the per-codebook
    /// split, which the reconstruction bound needs).
    fn mean_rows(lut: &LutLinear) -> Vec<f32> {
        let (c_total, k, m) = (lut.qtable.c, lut.qtable.k, lut.m);
        let mut base = vec![0.0f32; c_total * m];
        for c in 0..c_total {
            for kk in 0..k {
                for j in 0..m {
                    base[c * m + j] += lut.table_f32[(c * k + kk) * m + j];
                }
            }
            for j in 0..m {
                base[c * m + j] /= k as f32;
            }
        }
        base
    }

    #[test]
    fn reconstruction_error_within_half_step_per_codebook() {
        for (seed, c, v, k, m) in [(0u64, 4, 4, 16, 8), (1, 2, 9, 8, 17), (2, 1, 3, 1, 5)] {
            let lut = fixture(seed, 32, c, v, k, m);
            let dec = DecomposedTable::decompose(&lut);
            let base = mean_rows(&lut);
            for ci in 0..c {
                let half = dec.scales[ci] * 0.5 + 1e-6;
                for kk in 0..k {
                    for j in 0..m {
                        let got = dec.reconstruct_at(&base, ci, kk, j);
                        let want = lut.table_f32[(ci * k + kk) * m + j];
                        assert!(
                            (got - want).abs() <= half,
                            "c={ci} k={kk} j={j}: |{got} - {want}| > {half}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn base_total_is_the_sum_of_mean_rows() {
        let lut = fixture(3, 24, 3, 4, 8, 6);
        let dec = DecomposedTable::decompose(&lut);
        let base = mean_rows(&lut);
        for j in 0..6 {
            let want: f32 = (0..3).map(|c| base[c * 6 + j]).sum();
            assert!((dec.base_total[j] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn decomposed_table_is_smaller_than_the_int8_table() {
        // On realistic table geometry the nibble-packed residuals
        // approach half the INT8 table; base + scales are O(M + C).
        let lut = fixture(4, 64, 4, 4, 16, 32);
        let dec = DecomposedTable::decompose(&lut);
        let int8_bytes = lut.qtable.data.len();
        assert!(
            dec.table_bytes() < int8_bytes,
            "{} !< {int8_bytes}",
            dec.table_bytes()
        );
        // exact accounting: 4M + C*K*ceil(M/2) + 4C
        assert_eq!(dec.table_bytes(), 4 * 32 + 4 * 16 * 16 + 4 * 4);
    }

    #[test]
    fn residual_storage_is_cache_line_aligned() {
        let lut = fixture(5, 16, 2, 4, 8, 7);
        let dec = DecomposedTable::decompose(&lut);
        assert_eq!(dec.table_alignment_bytes(), TABLE_ALIGN);
        assert_eq!(dec.resid().as_ptr() as usize % TABLE_ALIGN, 0);
        // odd M: rows pack to ceil(7/2) = 4 bytes
        assert_eq!(dec.row_bytes(), 4);
        assert_eq!(dec.resid().len(), 2 * 8 * 4);
    }

    #[test]
    fn single_centroid_tables_have_zero_residuals() {
        // K = 1: the mean row IS the only row, so residuals vanish and
        // the scale floors at the epsilon.
        let lut = fixture(6, 16, 2, 3, 1, 5);
        let dec = DecomposedTable::decompose(&lut);
        for c in 0..2 {
            for j in 0..5 {
                assert_eq!(dec.residual_at(c, 0, j), 0.0, "c={c} j={j}");
            }
        }
        assert!(dec.max_abs_error() <= 1e-6);
    }
}
