//! `SessionBuilder` -> `Session`: the compiled, zero-allocation form of
//! a [`Graph`].
//!
//! At build time the graph's instruction list is lowered to a `Step`
//! plan: every conv/linear op becomes a boxed [`LinearKernel`] chosen
//! through the [`KernelRegistry`], BatchNorm folds to a per-channel
//! scale/shift, and a shape walk sizes every scratch arena (ping-pong
//! activation buffers, im2col patch matrix, centroid-index buffer,
//! residual slots) for the configured `max_batch`. `Session::run` then
//! executes the plan against caller-owned input/output tensors with no
//! heap allocation on the steady-state hot path — the repeated-call
//! pointer-stability test below is the contract.
//!
//! Numerical contract: `Session::run` is bitwise-identical to the
//! legacy `Graph::run` for every `LutOpts` configuration (the parity
//! property test), because both paths execute the exact same kernel
//! code (`gemm`, `im2col_into`, `LutLinear::forward_into`, the pooling
//! loops) in the same order.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::kernel::{KernelPhases, LinearKernel, Scratch};
use super::registry::{KernelBuildCtx, KernelRegistry};
use crate::lut::LutOpts;
use crate::nn::graph::{Graph, LayerParams, Op};
use crate::nn::ops;
use crate::tensor::im2col::{im2col_into, same_out_size};
use crate::tensor::Tensor;
use crate::util::json::Json;

/// One lowered instruction of the compiled plan.
enum Step {
    Conv { name: String, kernel: Box<dyn LinearKernel>, k: usize, stride: usize },
    Linear { name: String, kernel: Box<dyn LinearKernel> },
    Bn { scale: Vec<f32>, shift: Vec<f32> },
    Ln { gamma: Vec<f32>, beta: Vec<f32> },
    Relu,
    Gelu,
    MaxPool { k: usize, stride: usize },
    Gap,
    Flatten,
    Save { slot: usize },
    Restore { slot: usize },
    Add { slot: usize },
    Mul { slot: usize },
}

/// Per-batch-item scratch sizes (every arena scales linearly with the
/// batch dimension, so capacity for batch `n` is `n * per_item`).
#[derive(Debug, Default, Clone)]
struct PerItem {
    act: usize,
    patches: usize,
    idx: usize,
    slots: BTreeMap<usize, usize>,
}

/// One linear layer's accumulated profile rows (see [`SessionProfile`]).
#[derive(Debug, Clone)]
pub struct LayerProfile {
    pub layer: String,
    /// registry tag of the kernel executing the layer
    pub kernel: &'static str,
    /// index of the layer's step in the compiled plan
    pub step: usize,
    /// profiled `run` calls that executed this layer
    pub calls: u64,
    /// total input rows processed across those calls
    pub rows: u64,
    /// wall time inside the layer's step (im2col + kernel forward)
    pub wall_ns: u64,
    /// closest-centroid encode time (§5.1; 0 where the kernel reports
    /// no phase split)
    pub encode_ns: u64,
    /// table read/accumulate time (§5.2; 0 without a phase split)
    pub lookup_ns: u64,
    /// table bytes attributed via
    /// [`LinearKernel::table_bytes_touched`]
    pub table_bytes_touched: u64,
}

/// Accumulated per-layer profile of a session built with
/// [`SessionBuilder::profile`]`(true)`.
///
/// Zero overhead when off: the default session holds no
/// `SessionProfile` allocation and `Session::run` takes no timestamps —
/// the hot loop is byte-for-byte the unprofiled path.
#[derive(Debug, Clone, Default)]
pub struct SessionProfile {
    /// one row per linear step, in plan order
    pub layers: Vec<LayerProfile>,
    /// time in non-linear steps (norms, pools, residual plumbing); for
    /// BERT reference-path sessions, the whole forward
    pub other_ns: u64,
    /// total wall time across profiled runs (timed around the full
    /// `run` body, so it dominates the per-step sums)
    pub total_ns: u64,
    /// profiled `run` calls
    pub runs: u64,
}

impl SessionProfile {
    /// Wall nanoseconds across all linear layers.
    pub fn linear_wall_ns(&self) -> u64 {
        self.layers.iter().map(|l| l.wall_ns).sum()
    }

    /// Nanoseconds attributed to steps (linear + other); always
    /// `<= total_ns` since step windows are sub-intervals of the run.
    pub fn accounted_ns(&self) -> u64 {
        self.linear_wall_ns() + self.other_ns
    }

    pub fn to_json(&self) -> Json {
        let layers: Vec<Json> = self
            .layers
            .iter()
            .map(|l| {
                Json::obj(vec![
                    ("layer", Json::str(l.layer.clone())),
                    ("kernel", Json::str(l.kernel)),
                    ("step", Json::num(l.step as f64)),
                    ("calls", Json::num(l.calls as f64)),
                    ("rows", Json::num(l.rows as f64)),
                    ("wall_ns", Json::num(l.wall_ns as f64)),
                    ("encode_ns", Json::num(l.encode_ns as f64)),
                    ("lookup_ns", Json::num(l.lookup_ns as f64)),
                    ("table_bytes_touched", Json::num(l.table_bytes_touched as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("runs", Json::num(self.runs as f64)),
            ("total_ns", Json::num(self.total_ns as f64)),
            ("other_ns", Json::num(self.other_ns as f64)),
            ("linear_wall_ns", Json::num(self.linear_wall_ns() as f64)),
            ("layers", Json::Arr(layers)),
        ])
    }
}

/// Builder for [`Session`]: configure opts / registry / batch capacity,
/// then `build()` to validate the graph and preallocate arenas.
pub struct SessionBuilder<'g> {
    graph: &'g Graph,
    opts: LutOpts,
    registry: KernelRegistry,
    max_batch: usize,
    overrides: BTreeMap<String, String>,
    auto: Option<crate::cost::AutoPickPolicy>,
    profile: bool,
}

impl<'g> SessionBuilder<'g> {
    pub fn new(graph: &'g Graph) -> SessionBuilder<'g> {
        SessionBuilder {
            graph,
            opts: LutOpts::deployed(),
            registry: KernelRegistry::with_defaults(),
            max_batch: graph.input_shape.first().copied().unwrap_or(1).max(1),
            overrides: BTreeMap::new(),
            auto: None,
            profile: false,
        }
    }

    /// §6.3 optimization toggles for LUT kernels (default: `deployed()`).
    pub fn opts(mut self, opts: LutOpts) -> Self {
        self.opts = opts;
        self
    }

    /// Swap in a custom kernel registry.
    pub fn registry(mut self, registry: KernelRegistry) -> Self {
        self.registry = registry;
        self
    }

    /// Batch size the scratch arenas are pre-sized for. Larger batches
    /// still run — buffers grow once — but steady-state zero-allocation
    /// is guaranteed only up to this capacity.
    pub fn max_batch(mut self, n: usize) -> Self {
        self.max_batch = n.max(1);
        self
    }

    /// Force a specific registered kernel for one layer (per-layer
    /// kernel selection; default is the layer's own `kernel_tag()`).
    /// Explicit overrides always beat [`SessionBuilder::auto_kernels`].
    pub fn kernel_override(mut self, layer: &str, kernel: &str) -> Self {
        self.overrides.insert(layer.to_string(), kernel.to_string());
        self
    }

    /// Let the analytic cost model ([`crate::cost::auto_pick_tag`]) pick
    /// a kernel per LUT layer from its shape (rows, D, M, K, V) — the
    /// Table 1 MAC counts decide between `lut`, `lut-simd` and (policy
    /// permitting) `lut-i8` / `lut-dec`. Layers with an explicit
    /// [`SessionBuilder::kernel_override`] are untouched; dense layers
    /// keep the `dense` GEMM unless the policy allows int8, in which
    /// case they take the quantized `dense-i8` baseline; a dense verdict
    /// on a LUT layer clamps to the scalar `lut` kernel (there are no
    /// dense weights to fall back to).
    pub fn auto_kernels(mut self, policy: crate::cost::AutoPickPolicy) -> Self {
        self.auto = Some(policy);
        self
    }

    /// Record per-layer wall time, the encode vs lookup-accumulate split
    /// and table-bytes attribution on every [`Session::run`], surfaced
    /// via [`Session::profile_report`]. Off by default: an unprofiled
    /// session allocates no [`SessionProfile`] and takes no timestamps
    /// in the hot loop.
    pub fn profile(mut self, on: bool) -> Self {
        self.profile = on;
        self
    }

    pub fn build(self) -> Result<Session> {
        let g = self.graph;
        if g.bert.is_some() {
            // BERT bundles execute through the reference attention path;
            // the plan/arena machinery covers the instruction-list CNNs.
            // NOTE: the session owns a one-time copy of the graph's
            // parameters here — per-replica cost, not per-request.
            return Ok(Session {
                name: g.name.clone(),
                item_shape: g.input_shape[1..].to_vec(),
                steps: Vec::new(),
                scratch: Scratch::default(),
                bufs: [empty_buf(0), empty_buf(0)],
                patches: Vec::new(),
                slots: BTreeMap::new(),
                per_item: PerItem::default(),
                cap_batch: self.max_batch,
                param_bytes: g.param_bytes(),
                opts: self.opts,
                // No lowered linear steps: the whole forward lands in
                // `other_ns` when profiling is on.
                profile: self.profile.then(|| Box::new(SessionProfile::default())),
                bert: Some(g.clone()),
            });
        }

        let ctx = KernelBuildCtx { opts: self.opts };
        let item_shape: Vec<usize> = g.input_shape[1..].to_vec();
        let mut sh = match item_shape.len() {
            3 => SimShape::S4 { h: item_shape[0], w: item_shape[1], c: item_shape[2] },
            1 => SimShape::S2 { cols: item_shape[0] },
            r => bail!("unsupported input rank {} (shape {:?})", r + 1, g.input_shape),
        };
        let mut per = PerItem { act: sh.elems(), ..PerItem::default() };
        let mut slot_shapes: BTreeMap<usize, SimShape> = BTreeMap::new();
        let mut steps = Vec::with_capacity(g.ops.len());
        let mut param_bytes = 0usize;
        let mut linear_layers: std::collections::BTreeSet<&str> = std::collections::BTreeSet::new();

        fn layer<'a>(g: &'a Graph, name: &str) -> Result<&'a LayerParams> {
            g.layers.get(name).ok_or_else(|| anyhow!("graph references unknown layer '{name}'"))
        }
        // `rows` is the per-item row count of the op (H_out*W_out for
        // convs, 1 for FC) — the N of the cost model's MAC counts.
        let kernel_for =
            |name: &str, params: &LayerParams, rows: usize| -> Result<Box<dyn LinearKernel>> {
            let tag: &str = match self.overrides.get(name) {
                Some(t) => t.as_str(),
                None => {
                    let default = params
                        .kernel_tag()
                        .ok_or_else(|| anyhow!("layer '{name}' is not a linear layer"))?;
                    match (self.auto, params) {
                        (Some(mut policy), LayerParams::Lut(l)) => {
                            // Both alternative kernels encode centroid-
                            // stationary: under a naive-encode config
                            // their outputs (and lut-i8's tolerance
                            // bound) are vs a different reference —
                            // never auto-pick them there.
                            policy.simd &= self.opts.centroid_stationary;
                            policy.allow_i8 &= self.opts.centroid_stationary;
                            policy.allow_dec &= self.opts.centroid_stationary;
                            match crate::cost::auto_pick_tag(
                                rows,
                                l.input_dim(),
                                l.m,
                                l.cb.k,
                                l.cb.v,
                                policy,
                            ) {
                                // a LUT layer has no dense weights to
                                // fall back to — clamp to the reference
                                "dense" | "dense-i8" => "lut",
                                t => t,
                            }
                        }
                        // int8-vs-int8 pricing: an int8-permitting
                        // policy routes dense layers through the
                        // quantized dense baseline
                        (Some(policy), LayerParams::Dense { .. }) if policy.allow_i8 => "dense-i8",
                        _ => default,
                    }
                }
            };
            self.registry
                .build(tag, params, &ctx)
                .with_context(|| format!("building kernel for layer '{name}'"))
        };

        for op in &g.ops {
            match op {
                Op::Conv { layer: lname, k, stride } => {
                    let SimShape::S4 { h, w, c } = sh else {
                        bail!("conv '{lname}' needs a 4-D activation");
                    };
                    linear_layers.insert(lname);
                    let (ho, wo) = (same_out_size(h, *stride), same_out_size(w, *stride));
                    let rows = ho * wo;
                    let kernel = kernel_for(lname, layer(g, lname)?, rows)?;
                    ensure!(
                        kernel.in_dim() == c * k * k,
                        "conv '{lname}': kernel in_dim {} != Cin*k*k = {}",
                        kernel.in_dim(),
                        c * k * k
                    );
                    let m = kernel.out_dim();
                    per.patches = per.patches.max(rows * kernel.in_dim());
                    per.idx = per.idx.max(kernel.scratch_indices(rows));
                    param_bytes += kernel.param_bytes();
                    sh = SimShape::S4 { h: ho, w: wo, c: m };
                    per.act = per.act.max(sh.elems());
                    steps.push(Step::Conv {
                        name: lname.clone(),
                        kernel,
                        k: *k,
                        stride: *stride,
                    });
                }
                Op::Linear { layer: lname } => {
                    let SimShape::S2 { cols } = sh else {
                        bail!("linear '{lname}' needs a 2-D activation (did you forget Gap?)");
                    };
                    linear_layers.insert(lname);
                    let kernel = kernel_for(lname, layer(g, lname)?, 1)?;
                    ensure!(
                        kernel.in_dim() == cols,
                        "linear '{lname}': kernel in_dim {} != activation cols {}",
                        kernel.in_dim(),
                        cols
                    );
                    per.idx = per.idx.max(kernel.scratch_indices(1));
                    param_bytes += kernel.param_bytes();
                    sh = SimShape::S2 { cols: kernel.out_dim() };
                    per.act = per.act.max(sh.elems());
                    steps.push(Step::Linear { name: lname.clone(), kernel });
                }
                Op::Bn { layer: lname } => {
                    let LayerParams::Bn { gamma, beta, mean, var } = layer(g, lname)? else {
                        bail!("layer '{lname}' is not bn");
                    };
                    ensure!(
                        gamma.len() == sh.channels(),
                        "bn '{lname}': {} channels vs activation {}",
                        gamma.len(),
                        sh.channels()
                    );
                    // Identical folding to ops::batch_norm (bitwise parity).
                    let scale: Vec<f32> =
                        (0..gamma.len()).map(|c| gamma[c] / (var[c] + 1e-5).sqrt()).collect();
                    let shift: Vec<f32> =
                        (0..gamma.len()).map(|c| beta[c] - mean[c] * scale[c]).collect();
                    param_bytes += 4 * gamma.len() * 4;
                    steps.push(Step::Bn { scale, shift });
                }
                Op::Ln { layer: lname } => {
                    let LayerParams::Ln { gamma, beta } = layer(g, lname)? else {
                        bail!("layer '{lname}' is not layernorm");
                    };
                    ensure!(
                        gamma.len() == sh.channels(),
                        "ln '{lname}': {} channels vs activation {}",
                        gamma.len(),
                        sh.channels()
                    );
                    param_bytes += 4 * gamma.len() * 2;
                    steps.push(Step::Ln { gamma: gamma.clone(), beta: beta.clone() });
                }
                Op::Relu => steps.push(Step::Relu),
                Op::Gelu => steps.push(Step::Gelu),
                Op::MaxPool { k, stride } => {
                    let SimShape::S4 { h, w, c } = sh else {
                        bail!("maxpool needs a 4-D activation");
                    };
                    ensure!(h >= *k && w >= *k, "maxpool window {k} larger than {h}x{w}");
                    sh = SimShape::S4 {
                        h: (h - k) / stride + 1,
                        w: (w - k) / stride + 1,
                        c,
                    };
                    per.act = per.act.max(sh.elems());
                    steps.push(Step::MaxPool { k: *k, stride: *stride });
                }
                Op::Gap => {
                    let SimShape::S4 { c, .. } = sh else {
                        bail!("gap needs a 4-D activation");
                    };
                    sh = SimShape::S2 { cols: c };
                    steps.push(Step::Gap);
                }
                Op::Flatten => {
                    // NHWC is row-major, so flattening is a pure reshape
                    // (also the identity on already-2-D activations).
                    sh = SimShape::S2 { cols: sh.elems() };
                    steps.push(Step::Flatten);
                }
                Op::Save { slot } => {
                    let e = per.slots.entry(*slot).or_insert(0);
                    *e = (*e).max(sh.elems());
                    slot_shapes.insert(*slot, sh);
                    steps.push(Step::Save { slot: *slot });
                }
                Op::Restore { slot } => {
                    sh = *slot_shapes
                        .get(slot)
                        .ok_or_else(|| anyhow!("restore from never-saved slot {slot}"))?;
                    steps.push(Step::Restore { slot: *slot });
                }
                Op::Add { slot } => {
                    let saved = slot_shapes
                        .get(slot)
                        .ok_or_else(|| anyhow!("add from never-saved slot {slot}"))?;
                    ensure!(
                        *saved == sh,
                        "add: slot {slot} shape {saved:?} != activation {sh:?}"
                    );
                    steps.push(Step::Add { slot: *slot });
                }
                Op::Mul { slot } => {
                    let saved = slot_shapes
                        .get(slot)
                        .ok_or_else(|| anyhow!("mul from never-saved slot {slot}"))?;
                    ensure!(
                        *saved == sh,
                        "mul: slot {slot} shape {saved:?} != activation {sh:?}"
                    );
                    steps.push(Step::Mul { slot: *slot });
                }
                Op::Bert => bail!("bert op in a graph without a bert config"),
            }
        }

        // A typo'd override would otherwise silently run the default
        // kernel; reject any override that matched no linear op.
        for name in self.overrides.keys() {
            ensure!(
                linear_layers.contains(name.as_str()),
                "kernel_override for '{name}' matched no conv/linear layer in the plan"
            );
        }

        let n = self.max_batch;
        let slots = per
            .slots
            .iter()
            .map(|(&slot, &sz)| (slot, empty_buf(n * sz)))
            .collect();
        let profile = self.profile.then(|| {
            let layers = steps
                .iter()
                .enumerate()
                .filter_map(|(si, s)| {
                    let (name, kernel) = match s {
                        Step::Conv { name, kernel, .. } => (name, kernel),
                        Step::Linear { name, kernel } => (name, kernel),
                        _ => return None,
                    };
                    Some(LayerProfile {
                        layer: name.clone(),
                        kernel: kernel.name(),
                        step: si,
                        calls: 0,
                        rows: 0,
                        wall_ns: 0,
                        encode_ns: 0,
                        lookup_ns: 0,
                        table_bytes_touched: 0,
                    })
                })
                .collect();
            Box::new(SessionProfile { layers, ..SessionProfile::default() })
        });
        Ok(Session {
            name: g.name.clone(),
            item_shape,
            steps,
            scratch: Scratch::with_index_capacity(n * per.idx),
            bufs: [empty_buf(n * per.act), empty_buf(n * per.act)],
            patches: Vec::with_capacity(n * per.patches),
            slots,
            per_item: per,
            cap_batch: n,
            param_bytes,
            opts: self.opts,
            profile,
            bert: None,
        })
    }
}

fn empty_buf(cap: usize) -> Tensor {
    Tensor { shape: vec![0], data: Vec::with_capacity(cap) }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SimShape {
    S4 { h: usize, w: usize, c: usize },
    S2 { cols: usize },
}

impl SimShape {
    fn elems(self) -> usize {
        match self {
            SimShape::S4 { h, w, c } => h * w * c,
            SimShape::S2 { cols } => cols,
        }
    }

    fn channels(self) -> usize {
        match self {
            SimShape::S4 { c, .. } => c,
            SimShape::S2 { cols } => cols,
        }
    }
}

/// One row of [`Session::memory_report`]: where a linear layer's bytes
/// live and how its hot table is pinned.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerMemory {
    pub layer: String,
    /// registry tag of the kernel executing the layer
    pub kernel: &'static str,
    /// deployed parameter bytes (Fig. 10 accounting)
    pub param_bytes: usize,
    /// bytes of the kernel's hot lookup-table storage (0 for dense)
    pub table_bytes: usize,
    /// alignment (bytes) the table storage is pinned to (1 for dense)
    pub table_align: usize,
}

/// Where the current activation lives during a run.
#[derive(Clone, Copy)]
enum Cur {
    /// still the caller's input tensor (borrowed, never mutated)
    In,
    /// ping-pong arena `bufs[i]`
    Buf(usize),
}

/// A compiled, arena-backed executor for one model. Create via
/// [`SessionBuilder`]; call [`Session::run`] with caller-owned input
/// and output tensors.
pub struct Session {
    name: String,
    item_shape: Vec<usize>,
    steps: Vec<Step>,
    scratch: Scratch,
    bufs: [Tensor; 2],
    patches: Vec<f32>,
    slots: BTreeMap<usize, Tensor>,
    per_item: PerItem,
    cap_batch: usize,
    param_bytes: usize,
    opts: LutOpts,
    /// `Some` only when built with [`SessionBuilder::profile`]; boxed so
    /// the common unprofiled session stays pointer-thin.
    profile: Option<Box<SessionProfile>>,
    bert: Option<Graph>,
}

impl Session {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Per-request input shape (without the batch dim).
    pub fn item_shape(&self) -> &[usize] {
        &self.item_shape
    }

    /// Deployed parameter bytes of the compiled plan (linear kernels +
    /// folded normalization layers; for BERT bundles, the whole graph).
    pub fn param_bytes(&self) -> usize {
        self.param_bytes
    }

    /// Per-linear-layer memory accounting: kernel tag, deployed
    /// parameter bytes, hot-table bytes and the alignment the table is
    /// pinned to — the rows `benches/memory_footprint` measures and the
    /// CI memory gate enforces.
    pub fn memory_report(&self) -> Vec<LayerMemory> {
        self.steps
            .iter()
            .filter_map(|s| match s {
                Step::Conv { name, kernel, .. } | Step::Linear { name, kernel } => {
                    Some(LayerMemory {
                        layer: name.clone(),
                        kernel: kernel.name(),
                        param_bytes: kernel.param_bytes(),
                        table_bytes: kernel.table_bytes(),
                        table_align: kernel.table_alignment_bytes(),
                    })
                }
                _ => None,
            })
            .collect()
    }

    /// Total hot lookup-table bytes across the compiled plan.
    pub fn table_bytes(&self) -> usize {
        self.memory_report().iter().map(|l| l.table_bytes).sum()
    }

    /// Bytes this replica keeps resident: the deployed parameters and
    /// tables ([`Session::memory_report`]'s `param_bytes` accounting,
    /// plus folded norm layers) and the f32 activation arenas sized at
    /// build time (ping-pong buffers, im2col patches, residual slots).
    /// Index/accumulator scratch slabs are excluded — they are small
    /// relative to tables and resized per batch shape. This is the unit
    /// `coordinator::Registry` budgets warmed lazy models against.
    pub fn resident_bytes(&self) -> usize {
        let arena_f32s = self.bufs.iter().map(|b| b.data.capacity()).sum::<usize>()
            + self.patches.capacity()
            + self.slots.values().map(|t| t.data.capacity()).sum::<usize>();
        self.param_bytes + 4 * arena_f32s
    }

    /// `(layer, kernel tag, param bytes)` for every linear step.
    pub fn kernel_report(&self) -> Vec<(String, &'static str, usize)> {
        self.steps
            .iter()
            .filter_map(|s| match s {
                Step::Conv { name, kernel, .. } | Step::Linear { name, kernel } => {
                    Some((name.clone(), kernel.name(), kernel.param_bytes()))
                }
                _ => None,
            })
            .collect()
    }

    /// The accumulated per-layer profile, when the session was built
    /// with [`SessionBuilder::profile`]`(true)`; `None` otherwise.
    pub fn profile_report(&self) -> Option<&SessionProfile> {
        self.profile.as_deref()
    }

    /// One-line human description (engine listings, logs).
    pub fn describe(&self) -> String {
        if self.bert.is_some() {
            return format!("session '{}' (bert reference path)", self.name);
        }
        let kernels: Vec<String> = self
            .kernel_report()
            .into_iter()
            .map(|(layer, tag, _)| format!("{layer}:{tag}"))
            .collect();
        format!(
            "session '{}': {} steps, cap_batch {}, kernels [{}]",
            self.name,
            self.steps.len(),
            self.cap_batch,
            kernels.join(", ")
        )
    }

    /// Scratch-arena base pointers, for the zero-allocation contract
    /// test: two identical-batch runs must return identical values.
    pub fn scratch_ptrs(&self) -> Vec<usize> {
        let s = &self.scratch.lut;
        let mut p = vec![
            self.bufs[0].data.as_ptr() as usize,
            self.bufs[1].data.as_ptr() as usize,
            self.patches.as_ptr() as usize,
            s.idx.as_ptr() as usize,
            s.slab.as_ptr() as usize,
            s.scores.as_ptr() as usize,
            s.acc16.as_ptr() as usize,
            s.acc32.as_ptr() as usize,
        ];
        p.extend(self.slots.values().map(|t| t.data.as_ptr() as usize));
        p
    }

    /// Grow arenas for a batch larger than the built capacity.
    fn ensure_capacity(&mut self, n: usize) {
        if n <= self.cap_batch {
            return;
        }
        let per = self.per_item.clone();
        for b in &mut self.bufs {
            reserve_to(&mut b.data, n * per.act);
        }
        reserve_to(&mut self.patches, n * per.patches);
        reserve_to(&mut self.scratch.lut.idx, n * per.idx);
        for (slot, sz) in &per.slots {
            if let Some(t) = self.slots.get_mut(slot) {
                reserve_to(&mut t.data, n * sz);
            }
        }
        self.cap_batch = n;
    }

    /// Forward pass: `x.shape[0]` is the batch dim, the rest must match
    /// the graph's per-item input shape. `out` is overwritten (shape and
    /// data); reusing the same `out` across calls keeps the hot path
    /// allocation-free.
    pub fn run(&mut self, x: &Tensor, out: &mut Tensor) -> Result<()> {
        if let Some(g) = &self.bert {
            let t0 = self.profile.is_some().then(Instant::now);
            let y = crate::nn::bert::run_bert(g, x, self.opts);
            write_out(out, &y.shape, &y.data);
            if let (Some(t0), Some(p)) = (t0, self.profile.as_deref_mut()) {
                let dt = t0.elapsed().as_nanos() as u64;
                p.other_ns += dt;
                p.total_ns += dt;
                p.runs += 1;
            }
            return Ok(());
        }
        ensure!(
            x.shape.len() == 1 + self.item_shape.len() && x.shape[1..] == self.item_shape[..],
            "input shape {:?} does not match item shape {:?}",
            x.shape,
            self.item_shape
        );
        let n = x.shape[0];
        ensure!(n > 0, "empty batch");
        self.ensure_capacity(n);

        let profiling = self.profile.is_some();
        let t_run = profiling.then(Instant::now);
        // Cursor into `profile.layers`, advanced on every linear step
        // (layers were collected from the plan in the same order).
        let mut li = 0usize;
        let mut cur = Cur::In;
        for si in 0..self.steps.len() {
            let t_step = profiling.then(Instant::now);
            // (rows, phases, table bytes) captured inside the linear
            // arms; attributed after the match so the `steps` borrow
            // is released first.
            let mut lin: Option<(u64, KernelPhases, u64)> = None;
            match &self.steps[si] {
                Step::Conv { kernel, k, stride, .. } => {
                    let (src, dst, di) = src_dst(x, &mut self.bufs, cur);
                    let (nb, h, w) = (src.shape[0], src.shape[1], src.shape[2]);
                    let (ho, wo) = (same_out_size(h, *stride), same_out_size(w, *stride));
                    let rows = nb * ho * wo;
                    let (d, m) = (kernel.in_dim(), kernel.out_dim());
                    self.patches.resize(rows * d, 0.0);
                    im2col_into(src, *k, *stride, &mut self.patches[..rows * d]);
                    dst.data.resize(rows * m, 0.0);
                    if profiling {
                        let ph = kernel.forward_profiled(
                            &self.patches[..rows * d],
                            rows,
                            &mut self.scratch,
                            &mut dst.data,
                        );
                        lin = Some((rows as u64, ph, kernel.table_bytes_touched(rows) as u64));
                    } else {
                        kernel.forward_into(
                            &self.patches[..rows * d],
                            rows,
                            &mut self.scratch,
                            &mut dst.data,
                        );
                    }
                    set_shape(dst, &[nb, ho, wo, m]);
                    cur = Cur::Buf(di);
                }
                Step::Linear { kernel, .. } => {
                    let (src, dst, di) = src_dst(x, &mut self.bufs, cur);
                    let rows = src.shape[0];
                    let m = kernel.out_dim();
                    dst.data.resize(rows * m, 0.0);
                    if profiling {
                        let ph = kernel.forward_profiled(
                            &src.data,
                            rows,
                            &mut self.scratch,
                            &mut dst.data,
                        );
                        lin = Some((rows as u64, ph, kernel.table_bytes_touched(rows) as u64));
                    } else {
                        kernel.forward_into(&src.data, rows, &mut self.scratch, &mut dst.data);
                    }
                    set_shape(dst, &[rows, m]);
                    cur = Cur::Buf(di);
                }
                Step::Bn { scale, shift } => {
                    let t = make_mut(x, &mut self.bufs, &mut cur);
                    let ch = *t.shape.last().unwrap();
                    for row in t.data.chunks_exact_mut(ch) {
                        for (v, c) in row.iter_mut().zip(0..ch) {
                            *v = *v * scale[c] + shift[c];
                        }
                    }
                }
                Step::Ln { gamma, beta } => {
                    let t = make_mut(x, &mut self.bufs, &mut cur);
                    ops::layer_norm(t, gamma, beta);
                }
                Step::Relu => {
                    let t = make_mut(x, &mut self.bufs, &mut cur);
                    for v in &mut t.data {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                }
                Step::Gelu => {
                    let t = make_mut(x, &mut self.bufs, &mut cur);
                    ops::gelu(t);
                }
                Step::Flatten => {
                    // Pure metadata change; materialize first so the
                    // borrowed input tensor is never reshaped.
                    let t = make_mut(x, &mut self.bufs, &mut cur);
                    let n0 = t.shape[0];
                    let cols = t.data.len() / n0;
                    set_shape(t, &[n0, cols]);
                }
                Step::MaxPool { k, stride } => {
                    let (src, dst, di) = src_dst(x, &mut self.bufs, cur);
                    let (nb, h, w, c) =
                        (src.shape[0], src.shape[1], src.shape[2], src.shape[3]);
                    let (ho, wo) = ((h - k) / stride + 1, (w - k) / stride + 1);
                    dst.data.resize(nb * ho * wo * c, 0.0);
                    ops::max_pool_into(src, *k, *stride, &mut dst.data);
                    set_shape(dst, &[nb, ho, wo, c]);
                    cur = Cur::Buf(di);
                }
                Step::Gap => {
                    let (src, dst, di) = src_dst(x, &mut self.bufs, cur);
                    let (nb, c) = (src.shape[0], src.shape[3]);
                    dst.data.resize(nb * c, 0.0);
                    ops::global_avg_pool_into(src, &mut dst.data);
                    set_shape(dst, &[nb, c]);
                    cur = Cur::Buf(di);
                }
                Step::Save { slot } => {
                    let src: &Tensor = match cur {
                        Cur::In => x,
                        Cur::Buf(i) => &self.bufs[i],
                    };
                    let t = self.slots.get_mut(slot).expect("slot sized at build");
                    write_out(t, &src.shape, &src.data);
                }
                Step::Restore { slot } => {
                    let di = match cur {
                        Cur::In => 0,
                        Cur::Buf(i) => 1 - i,
                    };
                    let s = &self.slots[slot];
                    let dst = &mut self.bufs[di];
                    write_out(dst, &s.shape, &s.data);
                    cur = Cur::Buf(di);
                }
                Step::Add { slot } => {
                    let other = &self.slots[slot];
                    let t = make_mut(x, &mut self.bufs, &mut cur);
                    debug_assert_eq!(t.shape, other.shape);
                    for (a, &b) in t.data.iter_mut().zip(&other.data) {
                        *a += b;
                    }
                }
                Step::Mul { slot } => {
                    let other = &self.slots[slot];
                    let t = make_mut(x, &mut self.bufs, &mut cur);
                    debug_assert_eq!(t.shape, other.shape);
                    for (a, &b) in t.data.iter_mut().zip(&other.data) {
                        *a *= b;
                    }
                }
            }
            if let Some(t0) = t_step {
                let dt = t0.elapsed().as_nanos() as u64;
                let p = self.profile.as_deref_mut().expect("profiling implies profile");
                match lin {
                    Some((rows, ph, bytes)) => {
                        let l = &mut p.layers[li];
                        li += 1;
                        l.calls += 1;
                        l.rows += rows;
                        l.wall_ns += dt;
                        l.encode_ns += ph.encode_ns;
                        l.lookup_ns += ph.lookup_ns;
                        l.table_bytes_touched += bytes;
                    }
                    None => p.other_ns += dt,
                }
            }
        }

        let final_t: &Tensor = match cur {
            Cur::In => x,
            Cur::Buf(i) => &self.bufs[i],
        };
        write_out(out, &final_t.shape, &final_t.data);
        if let (Some(t0), Some(p)) = (t_run, self.profile.as_deref_mut()) {
            p.total_ns += t0.elapsed().as_nanos() as u64;
            p.runs += 1;
        }
        Ok(())
    }

    /// Allocating convenience wrapper around [`Session::run`].
    pub fn run_alloc(&mut self, x: &Tensor) -> Result<Tensor> {
        let mut out = Tensor::zeros(vec![0]);
        self.run(x, &mut out)?;
        Ok(out)
    }
}

/// Overwrite `t` with `shape`/`data` without allocating when capacity
/// suffices.
fn write_out(t: &mut Tensor, shape: &[usize], data: &[f32]) {
    t.data.clear();
    t.data.extend_from_slice(data);
    set_shape(t, shape);
}

fn set_shape(t: &mut Tensor, dims: &[usize]) {
    t.shape.clear();
    t.shape.extend_from_slice(dims);
}

fn reserve_to<T>(v: &mut Vec<T>, cap: usize) {
    if v.capacity() < cap {
        v.reserve(cap - v.len());
    }
}

/// Split-borrow the read buffer (or the caller's input) and the write
/// buffer; returns `(src, dst, dst_index)`.
fn src_dst<'a>(
    input: &'a Tensor,
    bufs: &'a mut [Tensor; 2],
    cur: Cur,
) -> (&'a Tensor, &'a mut Tensor, usize) {
    match cur {
        Cur::In => {
            let (d, _) = bufs.split_at_mut(1);
            (input, &mut d[0], 0)
        }
        Cur::Buf(i) => {
            let (a, b) = bufs.split_at_mut(1);
            if i == 0 {
                (&a[0], &mut b[0], 1)
            } else {
                (&b[0], &mut a[0], 0)
            }
        }
    }
}

/// For in-place steps: materialize the current activation in an arena
/// (copying the borrowed input on first use) and return it mutably.
fn make_mut<'a>(input: &Tensor, bufs: &'a mut [Tensor; 2], cur: &mut Cur) -> &'a mut Tensor {
    if matches!(cur, Cur::In) {
        write_out(&mut bufs[0], &input.shape, &input.data);
        *cur = Cur::Buf(0);
    }
    match *cur {
        Cur::Buf(i) => &mut bufs[i],
        Cur::In => unreachable!(),
    }
}

#[cfg(test)]
#[allow(deprecated)] // parity tests deliberately compare against Graph::run
mod tests {
    use super::*;
    use crate::model_fmt::{load_bundle, save_bundle};
    use crate::nn::graph::Op;
    use crate::nn::models::{build_cnn_graph, lutify_graph, ConvSpec};
    use crate::util::prng::Prng;
    use crate::util::prop;

    fn lut_cnn(seed: u64) -> (Graph, Graph, Tensor) {
        let dense = build_cnn_graph(
            "t",
            [8, 8, 3],
            &[
                ConvSpec { cout: 8, k: 3, stride: 1 },
                ConvSpec { cout: 16, k: 3, stride: 2 },
            ],
            5,
            seed,
        );
        let mut rng = Prng::new(seed ^ 0xABCD);
        let x = Tensor::new(vec![4, 8, 8, 3], rng.normal_vec(4 * 8 * 8 * 3, 1.0));
        let lut = lutify_graph(&dense, &x, 8, 8, seed);
        (dense, lut, x)
    }

    fn opts_matrix() -> [LutOpts; 4] {
        [
            LutOpts::none(),
            LutOpts::all(),
            LutOpts::deployed(),
            LutOpts {
                centroid_stationary: false,
                interleaved_argmin: true,
                blocked_table_read: true,
                mixed_accum: false,
            },
        ]
    }

    #[test]
    fn session_matches_graph_run_bitwise() {
        let (dense, lut, x) = lut_cnn(0);
        for graph in [&dense, &lut] {
            for opts in opts_matrix() {
                let want = graph.run(x.clone(), opts);
                let mut sess =
                    SessionBuilder::new(graph).opts(opts).max_batch(4).build().unwrap();
                let got = sess.run_alloc(&x).unwrap();
                assert_eq!(got.shape, want.shape);
                assert_eq!(got.data, want.data, "bitwise parity ({opts:?})");
            }
        }
    }

    #[test]
    fn session_parity_property_random_cnns() {
        prop::check(6, |g| {
            let n_conv = g.usize(1..3);
            let specs: Vec<ConvSpec> = (0..n_conv)
                .map(|_| ConvSpec {
                    cout: *g.pick(&[4usize, 8]),
                    k: 3,
                    stride: *g.pick(&[1usize, 2]),
                })
                .collect();
            let n_classes = g.usize(2..6);
            let dense = build_cnn_graph("p", [8, 8, 3], &specs, n_classes, g.case_seed);
            let batch = g.usize(1..4);
            let x = Tensor::new(
                vec![batch, 8, 8, 3],
                g.f32_vec(batch * 8 * 8 * 3, 1.0),
            );
            let lut = lutify_graph(&dense, &x, 8, 8, g.case_seed);
            for graph in [&dense, &lut] {
                for opts in opts_matrix() {
                    let want = graph.run(x.clone(), opts);
                    let mut sess = SessionBuilder::new(graph)
                        .opts(opts)
                        .max_batch(batch)
                        .build()
                        .map_err(|e| format!("build: {e:#}"))?;
                    let got = sess.run_alloc(&x).map_err(|e| format!("run: {e:#}"))?;
                    if got.shape != want.shape || got.data != want.data {
                        return Err(format!(
                            "parity failed on '{}' opts {opts:?}",
                            graph.name
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn residual_slots_match_graph_run() {
        let (dense, _, _) = lut_cnn(1);
        let mut g = dense;
        // conv -> save -> relu -> add (residual) -> gap -> save -> relu
        // -> restore exercises Save/Add/Restore against the legacy path.
        g.ops = vec![
            Op::Conv { layer: "c0".into(), k: 3, stride: 1 },
            Op::Save { slot: 0 },
            Op::Relu,
            Op::Add { slot: 0 },
            Op::Gap,
            Op::Save { slot: 1 },
            Op::Relu,
            Op::Restore { slot: 1 },
        ];
        let mut rng = Prng::new(9);
        let x = Tensor::new(vec![2, 8, 8, 3], rng.normal_vec(2 * 8 * 8 * 3, 1.0));
        let want = g.run(x.clone(), LutOpts::deployed());
        let mut sess = SessionBuilder::new(&g).max_batch(2).build().unwrap();
        let got = sess.run_alloc(&x).unwrap();
        assert_eq!(got.shape, want.shape);
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn steady_state_hot_path_is_pointer_stable() {
        let (_, lut, x) = lut_cnn(2);
        let mut sess = SessionBuilder::new(&lut).max_batch(4).build().unwrap();
        let mut out = Tensor::zeros(vec![0]);
        sess.run(&x, &mut out).unwrap(); // warmup settles all arenas
        let ptrs = sess.scratch_ptrs();
        let out_ptr = out.data.as_ptr() as usize;
        let first = out.data.clone();
        for _ in 0..5 {
            sess.run(&x, &mut out).unwrap();
            assert_eq!(sess.scratch_ptrs(), ptrs, "scratch arenas must not reallocate");
            assert_eq!(out.data.as_ptr() as usize, out_ptr, "output buffer must be reused");
            assert_eq!(out.data, first, "deterministic forward");
        }
        // a larger batch grows arenas once, then is steady again
        let mut rng = Prng::new(3);
        let big = Tensor::new(vec![9, 8, 8, 3], rng.normal_vec(9 * 8 * 8 * 3, 1.0));
        sess.run(&big, &mut out).unwrap();
        let ptrs_big = sess.scratch_ptrs();
        sess.run(&big, &mut out).unwrap();
        assert_eq!(sess.scratch_ptrs(), ptrs_big);
    }

    #[test]
    fn bundle_roundtrip_preserves_params_and_outputs() {
        let (_, lut, x) = lut_cnn(3);
        let dir = std::env::temp_dir().join("lutnn_api_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.lutnn").to_string_lossy().into_owned();
        save_bundle(&lut, &path).unwrap();
        let reloaded = load_bundle(&path).unwrap();

        let mut s1 = SessionBuilder::new(&lut).max_batch(4).build().unwrap();
        let mut s2 = SessionBuilder::new(&reloaded).max_batch(4).build().unwrap();
        assert_eq!(s1.param_bytes(), s2.param_bytes());
        assert_eq!(s1.kernel_report(), s2.kernel_report());
        let y1 = s1.run_alloc(&x).unwrap();
        let y2 = s2.run_alloc(&x).unwrap();
        assert_eq!(y1.shape, y2.shape);
        assert_eq!(y1.data, y2.data, "bundle round-trip must be forward-exact");
    }

    #[test]
    fn custom_kernel_registers_and_overrides() {
        use crate::api::kernel::DenseKernel;

        /// A kernel that doubles the dense output — enough to observe
        /// per-layer dispatch without touching the executor.
        struct DoubledDense(DenseKernel);
        impl LinearKernel for DoubledDense {
            fn name(&self) -> &'static str {
                "dense2x"
            }
            fn in_dim(&self) -> usize {
                self.0.in_dim()
            }
            fn out_dim(&self) -> usize {
                self.0.out_dim()
            }
            fn param_bytes(&self) -> usize {
                self.0.param_bytes()
            }
            fn forward_into(
                &self,
                input: &[f32],
                rows: usize,
                scratch: &mut Scratch,
                out: &mut [f32],
            ) {
                self.0.forward_into(input, rows, scratch, out);
                for v in &mut out[..rows * self.out_dim()] {
                    *v *= 2.0;
                }
            }
        }

        let (dense, _, x) = lut_cnn(4);
        let mut plain = SessionBuilder::new(&dense).max_batch(4).build().unwrap();
        let base = plain.run_alloc(&x).unwrap();

        let mut reg = KernelRegistry::with_defaults();
        reg.register("dense2x", |params, _ctx| match params {
            LayerParams::Dense { w, b, m } => Ok(Box::new(DoubledDense(DenseKernel::new(
                w.clone(),
                b.clone(),
                *m,
            ))) as Box<dyn LinearKernel>),
            _ => Err(anyhow!("dense2x needs dense params")),
        });
        let mut sess = SessionBuilder::new(&dense)
            .registry(reg)
            .kernel_override("fc", "dense2x")
            .max_batch(4)
            .build()
            .unwrap();
        assert!(sess.describe().contains("fc:dense2x"), "{}", sess.describe());
        let got = sess.run_alloc(&x).unwrap();
        let want: Vec<f32> = base.data.iter().map(|v| v * 2.0).collect();
        assert_eq!(got.data, want, "fc runs through the overridden kernel");
    }

    #[test]
    fn auto_kernels_pick_per_layer_and_respect_overrides() {
        use crate::cost::AutoPickPolicy;
        let (_, lut, x) = lut_cnn(8);
        // Explicit policy literal: the exact()/fast() constructors
        // consult the runtime backend, which would make this test
        // host-dependent. lut-simd stays bitwise on every backend.
        let exact = AutoPickPolicy { simd: true, allow_i8: false, allow_dec: false };
        let mut auto = SessionBuilder::new(&lut)
            .auto_kernels(exact)
            .max_batch(4)
            .build()
            .unwrap();
        let report = auto.kernel_report();
        let tag = |n: &str| report.iter().find(|(l, _, _)| l.as_str() == n).unwrap().1;
        // c0 is the dense stem; c1 (d=72, m=16, K=8, V=9) is encode-bound
        // with K filling the lanes; the tiny fc head (d=16, m=5) gets a
        // "dense" verdict clamped back to the scalar lut reference.
        assert_eq!(tag("c0"), "dense");
        assert_eq!(tag("c1"), "lut-simd");
        assert_eq!(tag("fc"), "lut");
        // exact policy == bitwise-identical outputs to the scalar session
        let mut scalar = SessionBuilder::new(&lut).max_batch(4).build().unwrap();
        assert_eq!(
            auto.run_alloc(&x).unwrap().data,
            scalar.run_alloc(&x).unwrap().data,
            "exact auto-pick must not change output bytes"
        );
        // explicit override always beats the auto-picker; an
        // int8-permitting policy routes the dense stem through the
        // quantized dense baseline
        let fast = AutoPickPolicy { simd: true, allow_i8: true, allow_dec: false };
        let sess = SessionBuilder::new(&lut)
            .auto_kernels(fast)
            .kernel_override("c1", "lut")
            .max_batch(4)
            .build()
            .unwrap();
        let report = sess.kernel_report();
        let tag = |n: &str| report.iter().find(|(l, _, _)| l.as_str() == n).unwrap().1;
        assert_eq!(tag("c1"), "lut");
        assert_eq!(tag("c0"), "dense-i8");
        // naive-encode configs must never auto-pick the (centroid-
        // stationary) simd kernel, whatever the policy says
        let sess = SessionBuilder::new(&lut)
            .opts(LutOpts::none())
            .auto_kernels(exact)
            .max_batch(4)
            .build()
            .unwrap();
        let report = sess.kernel_report();
        let tag = |n: &str| report.iter().find(|(l, _, _)| l.as_str() == n).unwrap().1;
        assert_eq!(tag("c1"), "lut", "no lut-simd under naive encode");
    }

    #[test]
    fn memory_report_accounts_tables_per_kernel() {
        let (_, lut, x) = lut_cnn(10);
        // c0 stays the dense stem; route c1 through the decomposed
        // kernel and fc through the scalar reference.
        let mut sess = SessionBuilder::new(&lut)
            .kernel_override("c1", "lut-dec")
            .max_batch(4)
            .build()
            .unwrap();
        let report = sess.memory_report();
        let row = |n: &str| report.iter().find(|l| l.layer == n).unwrap().clone();
        assert_eq!(row("c0").kernel, "dense");
        assert_eq!((row("c0").table_bytes, row("c0").table_align), (0, 1));
        assert_eq!(row("c1").kernel, "lut-dec");
        assert_eq!(row("fc").kernel, "lut");
        // every LUT-family table is cache-line pinned
        assert_eq!(row("c1").table_align, crate::lut::TABLE_ALIGN);
        assert_eq!(row("fc").table_align, crate::lut::TABLE_ALIGN);
        assert!(row("c1").table_bytes > 0 && row("fc").table_bytes > 0);
        assert_eq!(
            sess.table_bytes(),
            report.iter().map(|l| l.table_bytes).sum::<usize>()
        );
        // the decomposed table must undercut the scalar kernel's INT8
        // table for the same layer
        let scalar = SessionBuilder::new(&lut).max_batch(4).build().unwrap();
        let scalar_row = scalar
            .memory_report()
            .into_iter()
            .find(|l| l.layer == "c1")
            .unwrap();
        assert!(
            row("c1").table_bytes < scalar_row.table_bytes,
            "dec {} !< lut {}",
            row("c1").table_bytes,
            scalar_row.table_bytes
        );
        // the decomposed session still runs (accuracy is pinned by the
        // kernel_parity harness; here we only need a sane forward)
        let y = sess.run_alloc(&x).unwrap();
        assert_eq!(y.shape[0], 4);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn empty_registry_fails_build_with_clear_error() {
        let (dense, _, _) = lut_cnn(9);
        let err = SessionBuilder::new(&dense)
            .registry(KernelRegistry::empty())
            .build()
            .unwrap_err();
        assert!(
            format!("{err:#}").contains("no kernel registered"),
            "{err:#}"
        );
    }

    #[test]
    fn build_rejects_broken_graphs() {
        let (dense, _, _) = lut_cnn(5);
        // unknown layer
        let mut g1 = build_cnn_graph("x", [8, 8, 3], &[], 3, 0);
        g1.ops = vec![Op::Linear { layer: "nope".into() }];
        assert!(SessionBuilder::new(&g1).build().is_err());
        // linear before gap (4-D activation)
        let mut g2 = dense;
        g2.ops = vec![Op::Linear { layer: "fc".into() }];
        assert!(SessionBuilder::new(&g2).build().is_err());
        // kernel override naming a layer that is not in the plan
        let (ok_graph, _, _) = lut_cnn(7);
        let err = SessionBuilder::new(&ok_graph)
            .kernel_override("fd", "dense")
            .build()
            .unwrap_err();
        assert!(format!("{err}").contains("'fd'"), "{err:#}");
    }

    #[test]
    fn run_rejects_wrong_item_shape() {
        let (dense, _, _) = lut_cnn(6);
        let mut sess = SessionBuilder::new(&dense).build().unwrap();
        let bad = Tensor::zeros(vec![1, 4, 4, 3]);
        assert!(sess.run_alloc(&bad).is_err());
    }

    #[test]
    fn profiling_is_opt_in_and_bitwise_free() {
        let (_, lut, x) = lut_cnn(11);
        // default: no SessionProfile is allocated at all
        let mut plain = SessionBuilder::new(&lut).max_batch(4).build().unwrap();
        assert!(plain.profile_report().is_none());
        let want = plain.run_alloc(&x).unwrap();

        let mut prof = SessionBuilder::new(&lut).profile(true).max_batch(4).build().unwrap();
        let runs = 3u64;
        let mut got = Tensor::zeros(vec![0]);
        for _ in 0..runs {
            prof.run(&x, &mut got).unwrap();
        }
        assert_eq!(got.shape, want.shape);
        assert_eq!(got.data, want.data, "profiling must not change output bytes");

        let p = prof.profile_report().unwrap();
        assert_eq!(p.runs, runs);
        assert!(p.total_ns > 0);
        assert!(p.accounted_ns() <= p.total_ns, "step time exceeds run time");
        // one profile row per linear step, aligned with kernel_report
        let kr = prof.kernel_report();
        assert_eq!(p.layers.len(), kr.len());
        assert!(!p.layers.is_empty());
        for (l, (name, tag, _)) in p.layers.iter().zip(&kr) {
            assert_eq!(&l.layer, name);
            assert_eq!(l.kernel, *tag);
            assert_eq!(l.calls, runs);
            assert!(l.rows > 0);
            assert!(
                l.encode_ns + l.lookup_ns <= l.wall_ns,
                "phase split {}+{} exceeds step wall {} for '{}'",
                l.encode_ns,
                l.lookup_ns,
                l.wall_ns,
                l.layer
            );
            if l.kernel == "dense" {
                assert_eq!(l.table_bytes_touched, 0, "dense '{}' has no tables", l.layer);
            } else {
                assert!(l.table_bytes_touched > 0, "lut '{}' touched no table bytes", l.layer);
            }
        }
        let j = crate::util::json::to_string(&p.to_json());
        assert!(j.contains("\"layers\":["), "{j}");
        assert!(j.contains("\"runs\":3"), "{j}");
    }

    #[test]
    fn bert_bundles_fall_back_to_reference_path() {
        let cfg = crate::nn::bert::BertConfig {
            vocab: 32,
            seq_len: 8,
            d: 16,
            n_heads: 2,
            d_ff: 32,
            n_layers: 1,
            n_out: 4,
        };
        let g = crate::nn::bert::tests::synthetic_bert(&cfg, 0);
        let mut rng = Prng::new(1);
        let tokens: Vec<f32> = (0..2 * 8).map(|_| rng.below(32) as f32).collect();
        let x = Tensor::new(vec![2, 8], tokens);
        let want = g.run(x.clone(), LutOpts::deployed());
        let mut sess = SessionBuilder::new(&g).build().unwrap();
        let got = sess.run_alloc(&x).unwrap();
        assert_eq!(got.shape, want.shape);
        assert_eq!(got.data, want.data);
        assert_eq!(sess.param_bytes(), g.param_bytes());
    }
}
