//! The `Engine` trait: backend-agnostic batch execution.
//!
//! The serving stack (router -> batcher -> worker) talks only to this
//! trait; concrete engines are the rust-native [`Session`]
//! ([`NativeEngine`]) and the AOT-compiled XLA graphs on the PJRT host
//! thread ([`PjrtEngine`]). New backends implement three methods and
//! plug into `coordinator::ModelEntry` without touching the batcher or
//! the server.

use std::sync::Mutex;

use anyhow::{ensure, Result};

use super::session::{Session, SessionBuilder};
use crate::lut::LutOpts;
use crate::nn::graph::Graph;
use crate::runtime::{HostInput, HostedModel};
use crate::tensor::Tensor;

/// An executable model backend. `run_batch` writes the `[B, M]` output
/// into a caller-owned tensor so engines can keep the hot path free of
/// per-request allocation and input cloning.
pub trait Engine: Send + Sync {
    /// Run one batch; `x.shape[0]` is the batch dim. Overwrites `out`.
    fn run_batch(&self, x: &Tensor, out: &mut Tensor) -> Result<()>;

    /// Max batch accepted in one call (`None` = unbounded; the batcher
    /// pads fixed-batch engines up to this size).
    fn max_batch(&self) -> Option<usize>;

    /// One-line human description for listings and logs.
    fn describe(&self) -> String;
}

/// The rust-native table-lookup/dense engine: a [`Session`] behind a
/// mutex (the session owns mutable scratch arenas; the batcher worker
/// is the only steady-state caller, so the lock is uncontended).
pub struct NativeEngine {
    session: Mutex<Session>,
}

impl NativeEngine {
    pub fn new(session: Session) -> NativeEngine {
        NativeEngine { session: Mutex::new(session) }
    }

    /// Convenience: compile `graph` with `opts`, arenas sized for
    /// `max_batch`.
    pub fn from_graph(graph: &Graph, opts: LutOpts, max_batch: usize) -> Result<NativeEngine> {
        Ok(NativeEngine::new(
            SessionBuilder::new(graph).opts(opts).max_batch(max_batch).build()?,
        ))
    }

    /// Per-request input shape (without the batch dim).
    pub fn item_shape(&self) -> Vec<usize> {
        self.session.lock().unwrap().item_shape().to_vec()
    }
}

impl Engine for NativeEngine {
    fn run_batch(&self, x: &Tensor, out: &mut Tensor) -> Result<()> {
        self.session.lock().unwrap().run(x, out)
    }

    fn max_batch(&self) -> Option<usize> {
        None // sessions grow their arenas on demand
    }

    fn describe(&self) -> String {
        self.session.lock().unwrap().describe()
    }
}

/// AOT-compiled XLA graph on the PJRT host thread (fixed batch size).
/// Token inputs for BERT graphs are carried as f32 ids in the tensor
/// and cast on the way in.
pub struct PjrtEngine {
    model: HostedModel,
    batch: usize,
    is_tokens: bool,
}

impl PjrtEngine {
    pub fn new(model: HostedModel, batch: usize, is_tokens: bool) -> PjrtEngine {
        PjrtEngine { model, batch, is_tokens }
    }
}

impl Engine for PjrtEngine {
    fn run_batch(&self, x: &Tensor, out: &mut Tensor) -> Result<()> {
        ensure!(
            x.shape[0] == self.batch,
            "pjrt model compiled for batch {}, got {}",
            self.batch,
            x.shape[0]
        );
        let y = if self.is_tokens {
            let ids: Vec<i32> = x.data.iter().map(|&v| v as i32).collect();
            self.model.run(HostInput::I32(ids, x.shape.clone()))?
        } else {
            self.model.run(HostInput::F32(x.data.clone(), x.shape.clone()))?
        };
        let n = x.shape[0];
        let m = y.len() / n;
        out.shape.clear();
        out.shape.extend_from_slice(&[n, m]);
        out.data.clear();
        out.data.extend_from_slice(&y);
        Ok(())
    }

    fn max_batch(&self) -> Option<usize> {
        Some(self.batch)
    }

    fn describe(&self) -> String {
        format!(
            "pjrt '{}' (batch {}, {})",
            self.model.name,
            self.batch,
            if self.is_tokens { "token input" } else { "f32 input" }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::models::{build_cnn_graph, ConvSpec};

    #[test]
    fn native_engine_runs_any_batch() {
        let g = build_cnn_graph(
            "e",
            [8, 8, 3],
            &[ConvSpec { cout: 4, k: 3, stride: 1 }],
            5,
            0,
        );
        let eng = NativeEngine::from_graph(&g, LutOpts::deployed(), 4).unwrap();
        assert_eq!(eng.max_batch(), None);
        assert_eq!(eng.item_shape(), vec![8, 8, 3]);
        let mut out = Tensor::zeros(vec![0]);
        for n in [1usize, 3, 7] {
            let x = Tensor::zeros(vec![n, 8, 8, 3]);
            eng.run_batch(&x, &mut out).unwrap();
            assert_eq!(out.shape, vec![n, 5]);
        }
        assert!(eng.describe().contains("c0:dense"), "{}", eng.describe());
    }

    #[test]
    fn engine_is_object_safe_and_dyn_usable() {
        let g = build_cnn_graph(
            "dy",
            [8, 8, 3],
            &[ConvSpec { cout: 4, k: 3, stride: 1 }],
            3,
            1,
        );
        let eng: Box<dyn Engine> =
            Box::new(NativeEngine::from_graph(&g, LutOpts::deployed(), 2).unwrap());
        let mut out = Tensor::zeros(vec![0]);
        eng.run_batch(&Tensor::zeros(vec![2, 8, 8, 3]), &mut out).unwrap();
        assert_eq!(out.shape, vec![2, 3]);
    }
}
