//! The `Engine` trait: backend-agnostic batch execution.
//!
//! The serving stack (router -> batcher -> worker) talks only to this
//! trait; concrete engines are the rust-native [`Session`]
//! ([`NativeEngine`]) and the AOT-compiled XLA graphs on the PJRT host
//! thread ([`PjrtEngine`]). New backends implement three methods and
//! plug into `coordinator::ModelEntry` without touching the batcher or
//! the server.

use std::sync::{Arc, Mutex};

use anyhow::{ensure, Result};

use super::session::{Session, SessionBuilder};
use crate::lut::LutOpts;
use crate::nn::graph::Graph;
use crate::runtime::{HostInput, HostedModel};
use crate::tensor::Tensor;

/// An executable model backend. `run_batch` writes the `[B, M]` output
/// into a caller-owned tensor so engines can keep the hot path free of
/// per-request allocation and input cloning.
pub trait Engine: Send + Sync {
    /// Run one batch; `x.shape[0]` is the batch dim. Overwrites `out`.
    fn run_batch(&self, x: &Tensor, out: &mut Tensor) -> Result<()>;

    /// Max batch accepted in one call (`None` = unbounded; the batcher
    /// pads fixed-batch engines up to this size).
    fn max_batch(&self) -> Option<usize>;

    /// One-line human description for listings and logs.
    fn describe(&self) -> String;

    /// Replication capability for engine pools
    /// ([`crate::coordinator::pool::EnginePool`]): build an independent
    /// replica of this engine — own scratch arenas, shared immutable
    /// model — without re-deriving the model (no second lutification,
    /// no second AOT compile). A replica must be numerically identical
    /// to the original (bitwise on the native path).
    ///
    /// `None` means the engine does not support replication (the
    /// default); `Some(Err(..))` means it tried and failed.
    fn clone_replica(&self) -> Option<Result<Box<dyn Engine>>> {
        None
    }

    /// Approximate bytes this replica keeps resident (deployed tables +
    /// scratch arenas) — the unit `coordinator::Registry` budgets lazy
    /// models against when `resident_budget_bytes` is set. The default
    /// `0` marks an engine as unaccounted: the registry treats it as
    /// free and never evicts on its behalf.
    fn resident_bytes(&self) -> usize {
        0
    }
}

/// Everything needed to stamp out another [`NativeEngine`] replica:
/// the immutable bundle (shared via `Arc`, never copied again) plus
/// the session build configuration.
#[derive(Clone)]
struct ReplicaSpec {
    graph: Arc<Graph>,
    opts: LutOpts,
    max_batch: usize,
}

/// The rust-native table-lookup/dense engine: a [`Session`] behind a
/// mutex (the session owns mutable scratch arenas; one batcher worker
/// is the only steady-state caller per replica, so the lock is
/// uncontended).
pub struct NativeEngine {
    session: Mutex<Session>,
    /// Present when built from a graph; enables [`Engine::clone_replica`].
    spec: Option<ReplicaSpec>,
}

impl NativeEngine {
    /// Wrap an already-built session. Such an engine cannot replicate
    /// itself (it has no bundle to rebuild from); use
    /// [`NativeEngine::from_graph`] when the engine should be poolable.
    pub fn new(session: Session) -> NativeEngine {
        NativeEngine { session: Mutex::new(session), spec: None }
    }

    /// Convenience: compile `graph` with `opts`, arenas sized for
    /// `max_batch`. Keeps one shared copy of the graph so replicas can
    /// be cloned off this engine without re-lutifying.
    ///
    /// Memory note: the retained bundle costs one extra copy of the
    /// model parameters per *model* (replicas share it via `Arc`) for
    /// the engine's lifetime — the price of late replication (serve
    /// `--replicas`, future autoscaling). Memory-constrained
    /// single-replica deployments can wrap a built session in
    /// [`NativeEngine::new`] instead, which retains nothing.
    pub fn from_graph(graph: &Graph, opts: LutOpts, max_batch: usize) -> Result<NativeEngine> {
        NativeEngine::from_shared(Arc::new(graph.clone()), opts, max_batch)
    }

    /// As [`NativeEngine::from_graph`] but reusing a caller-held
    /// `Arc<Graph>` (no graph copy at all).
    pub fn from_shared(graph: Arc<Graph>, opts: LutOpts, max_batch: usize) -> Result<NativeEngine> {
        let session = SessionBuilder::new(&graph).opts(opts).max_batch(max_batch).build()?;
        Ok(NativeEngine {
            session: Mutex::new(session),
            spec: Some(ReplicaSpec { graph, opts, max_batch }),
        })
    }

    /// Per-request input shape (without the batch dim).
    pub fn item_shape(&self) -> Vec<usize> {
        self.session.lock().unwrap().item_shape().to_vec()
    }
}

impl Engine for NativeEngine {
    fn run_batch(&self, x: &Tensor, out: &mut Tensor) -> Result<()> {
        self.session.lock().unwrap().run(x, out)
    }

    fn max_batch(&self) -> Option<usize> {
        None // sessions grow their arenas on demand
    }

    fn describe(&self) -> String {
        self.session.lock().unwrap().describe()
    }

    fn resident_bytes(&self) -> usize {
        // Tables + arenas of this replica's session. The `ReplicaSpec`'s
        // shared `Arc<Graph>` (one per model, not per replica) is not
        // counted — it is the price of late replication, not of serving.
        self.session.lock().unwrap().resident_bytes()
    }

    fn clone_replica(&self) -> Option<Result<Box<dyn Engine>>> {
        let spec = self.spec.as_ref()?;
        let built = SessionBuilder::new(&spec.graph)
            .opts(spec.opts)
            .max_batch(spec.max_batch)
            .build()
            .map(|session| {
                Box::new(NativeEngine {
                    session: Mutex::new(session),
                    spec: Some(spec.clone()),
                }) as Box<dyn Engine>
            });
        Some(built)
    }
}

/// AOT-compiled XLA graph on the PJRT host thread (fixed batch size).
/// Token inputs for BERT graphs are carried as f32 ids in the tensor
/// and cast on the way in.
pub struct PjrtEngine {
    model: HostedModel,
    batch: usize,
    is_tokens: bool,
}

impl PjrtEngine {
    pub fn new(model: HostedModel, batch: usize, is_tokens: bool) -> PjrtEngine {
        PjrtEngine { model, batch, is_tokens }
    }
}

impl Engine for PjrtEngine {
    fn run_batch(&self, x: &Tensor, out: &mut Tensor) -> Result<()> {
        ensure!(
            x.shape[0] == self.batch,
            "pjrt model compiled for batch {}, got {}",
            self.batch,
            x.shape[0]
        );
        let y = if self.is_tokens {
            let ids: Vec<i32> = x.data.iter().map(|&v| v as i32).collect();
            self.model.run(HostInput::I32(ids, x.shape.clone()))?
        } else {
            self.model.run(HostInput::F32(x.data.clone(), x.shape.clone()))?
        };
        let n = x.shape[0];
        let m = y.len() / n;
        out.shape.clear();
        out.shape.extend_from_slice(&[n, m]);
        out.data.clear();
        out.data.extend_from_slice(&y);
        Ok(())
    }

    fn max_batch(&self) -> Option<usize> {
        Some(self.batch)
    }

    fn describe(&self) -> String {
        format!(
            "pjrt '{}' (batch {}, {})",
            self.model.name,
            self.batch,
            if self.is_tokens { "token input" } else { "f32 input" }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::models::{build_cnn_graph, ConvSpec};

    #[test]
    fn native_engine_runs_any_batch() {
        let g = build_cnn_graph(
            "e",
            [8, 8, 3],
            &[ConvSpec { cout: 4, k: 3, stride: 1 }],
            5,
            0,
        );
        let eng = NativeEngine::from_graph(&g, LutOpts::deployed(), 4).unwrap();
        assert_eq!(eng.max_batch(), None);
        assert_eq!(eng.item_shape(), vec![8, 8, 3]);
        let mut out = Tensor::zeros(vec![0]);
        for n in [1usize, 3, 7] {
            let x = Tensor::zeros(vec![n, 8, 8, 3]);
            eng.run_batch(&x, &mut out).unwrap();
            assert_eq!(out.shape, vec![n, 5]);
        }
        assert!(eng.describe().contains("c0:dense"), "{}", eng.describe());
    }

    #[test]
    fn clone_replica_is_bitwise_identical_and_independent() {
        let g = build_cnn_graph(
            "rep",
            [8, 8, 3],
            &[ConvSpec { cout: 4, k: 3, stride: 1 }],
            5,
            2,
        );
        let eng = NativeEngine::from_graph(&g, LutOpts::deployed(), 4).unwrap();
        let replica = eng.clone_replica().expect("from_graph engines replicate").unwrap();
        let mut rng = crate::util::prng::Prng::new(11);
        let x = Tensor::new(vec![3, 8, 8, 3], rng.normal_vec(3 * 8 * 8 * 3, 1.0));
        let (mut a, mut b) = (Tensor::zeros(vec![0]), Tensor::zeros(vec![0]));
        eng.run_batch(&x, &mut a).unwrap();
        replica.run_batch(&x, &mut b).unwrap();
        assert_eq!(a.shape, b.shape);
        assert_eq!(a.data, b.data, "replica must match the original bitwise");
        // replicas of replicas keep the capability
        assert!(replica.clone_replica().is_some());
        // wrapping a bare session does not (no bundle to rebuild from)
        let bare = NativeEngine::new(
            SessionBuilder::new(&g).opts(LutOpts::deployed()).max_batch(2).build().unwrap(),
        );
        assert!(bare.clone_replica().is_none());
    }

    #[test]
    fn engine_is_object_safe_and_dyn_usable() {
        let g = build_cnn_graph(
            "dy",
            [8, 8, 3],
            &[ConvSpec { cout: 4, k: 3, stride: 1 }],
            3,
            1,
        );
        let eng: Box<dyn Engine> =
            Box::new(NativeEngine::from_graph(&g, LutOpts::deployed(), 2).unwrap());
        let mut out = Tensor::zeros(vec![0]);
        eng.run_batch(&Tensor::zeros(vec![2, 8, 8, 3]), &mut out).unwrap();
        assert_eq!(out.shape, vec![2, 3]);
    }
}
