//! # Unified inference API
//!
//! The single public entry point for executing LUT-NN models: a
//! trait-based kernel layer, a compiled zero-allocation executor, and a
//! backend-agnostic engine interface for the serving stack.
//!
//! ## Request path
//!
//! ```text
//!   client ──TCP──> coordinator::Server ─┐
//!   in-proc caller (example / bench) ────┤
//!                                        v
//!                            Router -> Batcher queue
//!                                        │ stack [B, item]
//!                                        v
//!                         dyn Engine::run_batch(&x, &mut out)
//!                          │                          │
//!                  NativeEngine                  PjrtEngine
//!                          │                          │
//!                  Session::run              PJRT host thread
//!                          │                  (AOT XLA graph)
//!                          v
//!            plan of Steps over scratch arenas
//!            (ping-pong activations, im2col patches,
//!             centroid indices, residual slots)
//!                          │
//!                          v
//!              dyn LinearKernel::forward_into
//!               │                        │
//!          DenseKernel              LutKernel          <- KernelRegistry
//!        (blocked GEMM)      (encode + table lookup)      ("dense","lut",
//!                                                          your kernel here)
//! ```
//!
//! ## The three layers
//!
//! * [`LinearKernel`] ([`kernel`]) — object-safe operator kernel:
//!   `forward_into(input, rows, scratch, out)` plus `param_bytes`/`name`
//!   metadata. Implementations are pure compute and never allocate on
//!   the forward path.
//! * [`Session`] / [`SessionBuilder`] ([`session`]) — compiles a
//!   [`crate::nn::graph::Graph`] into a step plan with every scratch
//!   arena sized once at build time; `session.run(&input, &mut output)`
//!   is zero-clone and, at steady state, zero-allocation.
//! * [`Engine`] ([`engine`]) — `run_batch`/`max_batch`/`describe` over
//!   whole batches; [`NativeEngine`] wraps a session, [`PjrtEngine`]
//!   wraps an AOT-compiled XLA executable. The coordinator stack is
//!   generic over `dyn Engine`.
//!
//! New kernels register by name in the [`KernelRegistry`] and new
//! backends implement [`Engine`]; neither requires touching the
//! executor, the batcher, or the server.
//!
//! The legacy `Graph::run` entry point remains as a deprecated shim for
//! one release; it clones activations per call and should not be used
//! on serving paths.

pub mod engine;
pub mod kernel;
pub mod registry;
pub mod session;

pub use engine::{Engine, NativeEngine, PjrtEngine};
pub use kernel::{DenseKernel, LinearKernel, LutKernel, Scratch};
pub use registry::{KernelBuildCtx, KernelFactory, KernelRegistry};
pub use session::{Session, SessionBuilder};
