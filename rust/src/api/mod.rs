//! # Unified inference API
//!
//! The single public entry point for executing LUT-NN models: a
//! trait-based kernel layer, a compiled zero-allocation executor, and a
//! backend-agnostic engine interface for the serving stack.
//!
//! ## Request path
//!
//! ```text
//!   client ──TCP──> coordinator::Server ─┐
//!   in-proc caller (example / bench) ────┤
//!                                        v
//!                    Router -> Batcher injector queue
//!                                        │ work-stealing workers,
//!                                        │ one per engine replica,
//!                                        │ stack [B, item] each
//!                                        v
//!              EnginePool: dyn Engine::run_batch(&x, &mut out)
//!                          │                          │
//!                  NativeEngine × N              PjrtEngine
//!                          │                          │
//!                  Session::run              PJRT host thread
//!                  (own arenas per            (AOT XLA graph)
//!                   replica)
//!                          v
//!            plan of Steps over scratch arenas
//!            (ping-pong activations, im2col patches,
//!             centroid indices, residual slots)
//!                          │
//!                          v
//!              dyn LinearKernel::forward_into
//!               │           │            │           │           │            │
//!          DenseKernel DenseI8Kernel LutKernel SimdLutKernel LutI8Kernel DecLutKernel
//!        (blocked GEMM) (int8 madd   (scalar    (SIMD vector  (global-    (shared base
//!                        micro-kernel) reference) encode:      scale        + 4-bit
//!                                                 neon/avx2/   int8 add)    residuals)
//!                                                 avx512/
//!                                                 portable)
//!            <- KernelRegistry ("dense","dense-i8","lut","lut-simd","lut-i8","lut-dec", yours)
//! ```
//!
//! ## The three layers
//!
//! * [`LinearKernel`] ([`kernel`]) — object-safe operator kernel:
//!   `forward_into(input, rows, scratch, out)` plus `param_bytes`/`name`
//!   metadata. Implementations are pure compute and never allocate on
//!   the forward path.
//! * [`Session`] / [`SessionBuilder`] ([`session`]) — compiles a
//!   [`crate::nn::graph::Graph`] into a step plan with every scratch
//!   arena sized once at build time; `session.run(&input, &mut output)`
//!   is zero-clone and, at steady state, zero-allocation. Built with
//!   [`SessionBuilder::profile`]`(true)` it also accumulates a
//!   per-layer [`SessionProfile`] (wall time, encode vs
//!   lookup-accumulate split, table bytes touched) at zero cost to
//!   unprofiled sessions.
//! * [`Engine`] ([`engine`]) — `run_batch`/`max_batch`/`describe` over
//!   whole batches; [`NativeEngine`] wraps a session, [`PjrtEngine`]
//!   wraps an AOT-compiled XLA executable. The coordinator stack is
//!   generic over `dyn Engine`; engines that implement the optional
//!   `clone_replica` capability can be pooled into N-replica
//!   `coordinator::EnginePool`s without re-deriving the model.
//!
//! ## Registering a custom kernel
//!
//! New kernels register by name in the [`KernelRegistry`] and new
//! backends implement [`Engine`]; neither requires touching the
//! executor, the batcher, or the server:
//!
//! ```ignore
//! let mut reg = KernelRegistry::with_defaults();
//! reg.register_unique("my-kernel", |params, ctx| match params {
//!     LayerParams::Lut(l) => Ok(Box::new(MyKernel::new(l.clone(), ctx.opts)) as _),
//!     _ => Err(anyhow!("'my-kernel' needs Lut layer params")),
//! })?;
//! let sess = SessionBuilder::new(&graph)
//!     .registry(reg)
//!     .kernel_override("conv3", "my-kernel") // force one layer
//!     .build()?;
//! ```
//!
//! `register_unique` refuses to shadow an existing tag; plain `register`
//! deliberately overrides (last write wins).
//!
//! ## Kernel selection
//!
//! Per layer, in priority order:
//! 1. an explicit [`SessionBuilder::kernel_override`] (a typo'd layer
//!    name is a build error);
//! 2. with [`SessionBuilder::auto_kernels`], the analytic cost model
//!    ([`crate::cost::auto_pick_tag`]) compares Table 1 MAC counts —
//!    dense `rows*D*M` vs LUT `rows*D*K + rows*M*C` — and routes
//!    table-read-bound layers (`M*C > D*K`) to `"lut-i8"` (policy
//!    permitting), encode-bound layers with `K >= 8` to `"lut-simd"`,
//!    the rest to the scalar `"lut"`;
//! 3. otherwise the layer's own `kernel_tag()` (`"dense"`/`"lut"`).
//!
//! Numerical contract per tag: `"lut-simd"` is **bitwise-identical** to
//! `"lut"` (same FP ops in the same order; enforced by the
//! `kernel_parity` fuzz harness). `"lut-i8"` requantizes the whole table
//! to one global INT8 scale and differs from `"lut"` by at most
//! `C * (global_scale + common_scale)` per output element
//! ([`LutI8Kernel::abs_tolerance`]) — pick it only where that bound is
//! acceptable (the `AutoPickPolicy::fast` opt-in). `"lut-dec"` executes
//! the decomposed table (shared f32 base + 4-bit residual sub-tables,
//! approaching half the table bytes — see [`crate::lut::decomposed`])
//! and differs from `"lut"` by at most
//! `sum_c resid_scale[c] + C * common_scale`
//! ([`DecLutKernel::abs_tolerance`]); both bounds are fuzzed in
//! `kernel_parity`. `"dense-i8"` is the honest quantized *dense*
//! baseline (global-scale int8 weights, dynamic per-row input
//! quantization, exact-i32 accumulate) and differs from `"dense"` by at
//! most `~ D * max|x| * max|W| / 127` per element
//! ([`DenseI8Kernel::abs_tolerance`]); its AVX2 `madd` micro-kernel and
//! portable loop are bitwise-identical (integer math is
//! order-independent).
//!
//! Memory contract per tag: every LUT-family kernel stores its hot
//! table `[C, K, M]` row-major (rows M-contiguous — the inner-loop
//! access order) pinned to a cache-line boundary, and reports
//! `table_bytes()` / `table_alignment_bytes()` through
//! [`Session::memory_report`] — the numbers `benches/memory_footprint`
//! gates in CI. See `crate::lut::layout`.
//!
//! The legacy `Graph::run` entry point remains as a deprecated shim for
//! one release; it clones activations per call and should not be used
//! on serving paths.

pub mod engine;
pub mod kernel;
pub mod registry;
pub mod session;

pub use engine::{Engine, NativeEngine, PjrtEngine};
pub use kernel::{
    DecLutKernel, DenseI8Kernel, DenseKernel, KernelPhases, LinearKernel, LutI8Kernel, LutKernel,
    Scratch, SimdLutKernel,
};
pub use registry::{KernelBuildCtx, KernelFactory, KernelRegistry};
pub use session::{LayerMemory, LayerProfile, Session, SessionBuilder, SessionProfile};
