//! The `LinearKernel` trait: one object-safe interface over every
//! implementation of `out = approx(input @ W) + bias`.
//!
//! This is the tract-style `Lut`/`LutKer` split: the executor
//! ([`crate::api::Session`]) talks only to this trait, while concrete
//! kernels (dense GEMM, LUT table-lookup, and future SIMD/int8/decomposed
//! variants) live behind it and are selected through the
//! [`crate::api::KernelRegistry`]. A kernel is pure compute: it never
//! allocates on the forward path — all working memory comes from the
//! caller-owned [`Scratch`] and `out` buffers.

use crate::lut::{LutLinear, LutOpts, LutScratch};
use crate::nn::gemm::gemm;

/// Caller-owned scratch shared across every kernel invocation in a
/// forward pass. The index buffer is sized by `SessionBuilder` at build
/// time; the remaining LUT working buffers settle at their per-layer
/// maxima during the first run. Either way, steady-state calls only
/// `resize` within capacity (pointer-stable, allocation-free).
#[derive(Default)]
pub struct Scratch {
    /// working memory for LUT-family kernels (indices, distance
    /// buffers, integer accumulators)
    pub lut: LutScratch,
}

impl Scratch {
    pub fn with_index_capacity(cap: usize) -> Scratch {
        Scratch {
            lut: LutScratch { idx: Vec::with_capacity(cap), ..LutScratch::default() },
        }
    }
}

/// An executable linear operator `[rows, in_dim] -> [rows, out_dim]`.
///
/// Object-safe: the session holds `Box<dyn LinearKernel>` and new
/// implementations plug in via the registry without touching the
/// executor. Implementations must be deterministic — the same input
/// bytes produce the same output bytes (the session parity tests rely
/// on it).
pub trait LinearKernel: Send + Sync {
    /// Registry tag of the implementation (e.g. `"dense"`, `"lut"`).
    fn name(&self) -> &'static str;

    /// Input feature dimension D.
    fn in_dim(&self) -> usize;

    /// Output feature dimension M.
    fn out_dim(&self) -> usize;

    /// Bytes held by the deployed parameter representation
    /// (Fig. 10 model-memory accounting).
    fn param_bytes(&self) -> usize;

    /// `u16` index-scratch elements needed to process `rows` rows
    /// (0 for kernels that do no encoding).
    fn scratch_indices(&self, rows: usize) -> usize {
        let _ = rows;
        0
    }

    /// Compute `out[..rows*out_dim] = forward(input[..rows*in_dim])`,
    /// overwriting `out`. Must not allocate beyond `scratch` growth
    /// within its reserved capacity.
    fn forward_into(&self, input: &[f32], rows: usize, scratch: &mut Scratch, out: &mut [f32]);
}

/// Dense reference kernel: blocked GEMM + bias (the ORT/TVM stand-in).
pub struct DenseKernel {
    w: Vec<f32>,
    b: Option<Vec<f32>>,
    d: usize,
    m: usize,
}

impl DenseKernel {
    pub fn new(w: Vec<f32>, b: Option<Vec<f32>>, m: usize) -> DenseKernel {
        assert!(m > 0 && w.len() % m == 0, "dense weight must be [D, M]");
        let d = w.len() / m;
        DenseKernel { w, b, d, m }
    }
}

impl LinearKernel for DenseKernel {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn in_dim(&self) -> usize {
        self.d
    }

    fn out_dim(&self) -> usize {
        self.m
    }

    fn param_bytes(&self) -> usize {
        4 * (self.w.len() + self.b.as_ref().map(|x| x.len()).unwrap_or(0))
    }

    fn forward_into(&self, input: &[f32], rows: usize, _scratch: &mut Scratch, out: &mut [f32]) {
        let (d, m) = (self.d, self.m);
        assert_eq!(input.len(), rows * d, "dense kernel input size");
        let out = &mut out[..rows * m];
        out.fill(0.0);
        gemm(input, &self.w, out, rows, d, m);
        if let Some(b) = &self.b {
            for row in out.chunks_exact_mut(m) {
                for (o, &bb) in row.iter_mut().zip(b) {
                    *o += bb;
                }
            }
        }
    }
}

/// LUT-NN table-lookup kernel (paper §5): closest-centroid encode +
/// quantized table read/accumulate, with the §6.3 optimization toggles
/// frozen into the kernel at build time.
pub struct LutKernel {
    lut: LutLinear,
    opts: LutOpts,
}

impl LutKernel {
    pub fn new(lut: LutLinear, opts: LutOpts) -> LutKernel {
        LutKernel { lut, opts }
    }

    pub fn opts(&self) -> LutOpts {
        self.opts
    }
}

impl LinearKernel for LutKernel {
    fn name(&self) -> &'static str {
        "lut"
    }

    fn in_dim(&self) -> usize {
        self.lut.input_dim()
    }

    fn out_dim(&self) -> usize {
        self.lut.m
    }

    fn param_bytes(&self) -> usize {
        self.lut.deployed_bytes()
    }

    fn scratch_indices(&self, rows: usize) -> usize {
        rows * self.lut.cb.c
    }

    fn forward_into(&self, input: &[f32], rows: usize, scratch: &mut Scratch, out: &mut [f32]) {
        self.lut
            .forward_scratch(input, rows, self.opts, &mut scratch.lut, &mut out[..rows * self.lut.m]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ops;
    use crate::pq::kmeans::learn_codebooks;
    use crate::tensor::Tensor;
    use crate::util::prng::Prng;

    #[test]
    fn dense_kernel_matches_ops_linear() {
        let mut rng = Prng::new(0);
        let (n, d, m) = (7, 12, 5);
        let w = rng.normal_vec(d * m, 0.5);
        let b = vec![0.25; m];
        let x = Tensor::new(vec![n, d], rng.normal_vec(n * d, 1.0));
        let want = ops::linear(&x, &w, Some(&b), m);
        let kern = DenseKernel::new(w, Some(b), m);
        let mut scratch = Scratch::default();
        let mut out = vec![7.0f32; n * m]; // pre-poisoned: kernel must overwrite
        kern.forward_into(&x.data, n, &mut scratch, &mut out);
        assert_eq!(out, want.data, "dense kernel must be bitwise ops::linear");
        assert_eq!(kern.param_bytes(), 4 * (d * m + m));
        assert_eq!(kern.scratch_indices(99), 0);
    }

    #[test]
    fn lut_kernel_matches_lutlinear_forward() {
        let mut rng = Prng::new(1);
        let (n, c, v, k, m) = (9, 3, 4, 8, 6);
        let d = c * v;
        let a = rng.normal_vec(n * d, 1.0);
        let w = rng.normal_vec(d * m, 1.0);
        let cb = learn_codebooks(&a, n, d, c, k, 5, 0);
        let lut = LutLinear::new(cb, &w, m, Some(vec![0.5; m]), 8);
        let want = lut.forward(&a, n, LutOpts::deployed());
        let kern = LutKernel::new(lut, LutOpts::deployed());
        let mut scratch = Scratch::default();
        let mut out = vec![-3.0f32; n * m];
        kern.forward_into(&a, n, &mut scratch, &mut out);
        assert_eq!(out, want, "lut kernel must be bitwise LutLinear::forward");
        assert_eq!(kern.in_dim(), d);
        assert_eq!(kern.out_dim(), m);
        assert_eq!(kern.scratch_indices(n), n * c);
    }
}
