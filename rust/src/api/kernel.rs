//! The `LinearKernel` trait: one object-safe interface over every
//! implementation of `out = approx(input @ W) + bias`.
//!
//! This is the tract-style `Lut`/`LutKer` split: the executor
//! ([`crate::api::Session`]) talks only to this trait, while concrete
//! kernels (dense GEMM, LUT table-lookup, and future SIMD/int8/decomposed
//! variants) live behind it and are selected through the
//! [`crate::api::KernelRegistry`]. A kernel is pure compute: it never
//! allocates on the forward path — all working memory comes from the
//! caller-owned [`Scratch`] and `out` buffers.

use crate::lut::layout::{AlignedVec, TABLE_ALIGN};
use crate::lut::{simd, DecomposedTable, LutLinear, LutOpts, LutScratch};
use crate::nn::gemm::gemm;
use crate::nn::ops::add_bias_rows;
use std::time::Instant;

/// Caller-owned scratch shared across every kernel invocation in a
/// forward pass. The index buffer is sized by `SessionBuilder` at build
/// time; the remaining LUT working buffers settle at their per-layer
/// maxima during the first run. Either way, steady-state calls only
/// `resize` within capacity (pointer-stable, allocation-free).
#[derive(Default)]
pub struct Scratch {
    /// working memory for LUT-family kernels (indices, distance
    /// buffers, integer accumulators)
    pub lut: LutScratch,
}

impl Scratch {
    pub fn with_index_capacity(cap: usize) -> Scratch {
        Scratch {
            lut: LutScratch { idx: Vec::with_capacity(cap), ..LutScratch::default() },
        }
    }
}

/// Per-call phase timing reported by [`LinearKernel::forward_profiled`]:
/// nanoseconds spent in closest-centroid encode (paper §5.1) vs table
/// read/accumulate (§5.2). Kernels without a meaningful split report
/// zeros — the caller still has the layer wall time.
#[derive(Clone, Copy, Debug, Default)]
pub struct KernelPhases {
    pub encode_ns: u64,
    pub lookup_ns: u64,
}

/// An executable linear operator `[rows, in_dim] -> [rows, out_dim]`.
///
/// Object-safe: the session holds `Box<dyn LinearKernel>` and new
/// implementations plug in via the registry without touching the
/// executor. Implementations must be deterministic — the same input
/// bytes produce the same output bytes (the session parity tests rely
/// on it).
pub trait LinearKernel: Send + Sync {
    /// Registry tag of the implementation (e.g. `"dense"`, `"lut"`).
    fn name(&self) -> &'static str;

    /// Input feature dimension D.
    fn in_dim(&self) -> usize;

    /// Output feature dimension M.
    fn out_dim(&self) -> usize;

    /// Bytes held by the deployed parameter representation
    /// (Fig. 10 model-memory accounting).
    fn param_bytes(&self) -> usize;

    /// `u16` index-scratch elements needed to process `rows` rows
    /// (0 for kernels that do no encoding).
    fn scratch_indices(&self, rows: usize) -> usize {
        let _ = rows;
        0
    }

    /// Bytes of the kernel's hot lookup-table storage — the table-read
    /// working set `benches/memory_footprint` gates per model. 0 for
    /// kernels without tables (dense GEMM).
    fn table_bytes(&self) -> usize {
        0
    }

    /// Alignment (bytes) the kernel's table storage is pinned to — the
    /// tract `LutKer::table_alignment_bytes()` contract; 1 for kernels
    /// without tables.
    fn table_alignment_bytes(&self) -> usize {
        1
    }

    /// Compute `out[..rows*out_dim] = forward(input[..rows*in_dim])`,
    /// overwriting `out`. Must not allocate beyond `scratch` growth
    /// within its reserved capacity.
    fn forward_into(&self, input: &[f32], rows: usize, scratch: &mut Scratch, out: &mut [f32]);

    /// Table bytes a forward over `rows` rows reads (each codebook's
    /// selected table row touched once per input row, in the kernel's
    /// deployed element width); 0 for kernels without tables. A static
    /// attribution, not a cache measurement.
    fn table_bytes_touched(&self, rows: usize) -> usize {
        let _ = rows;
        0
    }

    /// Profiled forward: byte-identical output to
    /// [`LinearKernel::forward_into`], additionally reporting the
    /// encode/lookup phase split. The default delegates to
    /// `forward_into` and reports zeros (no phase attribution).
    fn forward_profiled(
        &self,
        input: &[f32],
        rows: usize,
        scratch: &mut Scratch,
        out: &mut [f32],
    ) -> KernelPhases {
        self.forward_into(input, rows, scratch, out);
        KernelPhases::default()
    }
}

/// Dense reference kernel: blocked GEMM + bias (the ORT/TVM stand-in).
pub struct DenseKernel {
    w: Vec<f32>,
    b: Option<Vec<f32>>,
    d: usize,
    m: usize,
}

impl DenseKernel {
    pub fn new(w: Vec<f32>, b: Option<Vec<f32>>, m: usize) -> DenseKernel {
        assert!(m > 0 && w.len() % m == 0, "dense weight must be [D, M]");
        let d = w.len() / m;
        DenseKernel { w, b, d, m }
    }
}

impl LinearKernel for DenseKernel {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn in_dim(&self) -> usize {
        self.d
    }

    fn out_dim(&self) -> usize {
        self.m
    }

    fn param_bytes(&self) -> usize {
        4 * (self.w.len() + self.b.as_ref().map(|x| x.len()).unwrap_or(0))
    }

    fn forward_into(&self, input: &[f32], rows: usize, _scratch: &mut Scratch, out: &mut [f32]) {
        let (d, m) = (self.d, self.m);
        assert_eq!(input.len(), rows * d, "dense kernel input size");
        let out = &mut out[..rows * m];
        out.fill(0.0);
        gemm(input, &self.w, out, rows, d, m);
        if let Some(b) = &self.b {
            add_bias_rows(out, b);
        }
    }
}

/// Int8 dense GEMM kernel (`"dense-i8"`): the honest quantized baseline
/// the paper's int8 comparisons are made against (tract-`linalg`-style
/// tiled micro-kernel with a portable fallback).
///
/// Weights are quantized once at build time to a single global scale
/// (symmetric, `sw = max|W| / 127`); each input row is quantized
/// dynamically at its own scale (`sa = max|row| / 127`). The inner loop
/// is pure `i32` multiply-accumulate — exact and order-independent — so
/// the AVX2 `madd` micro-kernel and the portable path produce **bitwise
/// identical** output (unlike the f32 LUT encode, where only op-order
/// discipline keeps arms equal). One `sa * sw` dequant multiply per
/// output element at the end, bias last.
///
/// Output differs from the f32 `"dense"` reference by bounded
/// quantization error — see [`DenseI8Kernel::abs_tolerance`] for the
/// documented input-dependent per-element bound the parity harness
/// enforces.
pub struct DenseI8Kernel {
    /// global-scale INT8 weights, [D, M] row-major (M-contiguous rows,
    /// cache-line pinned so the 16-wide column loads never split lines)
    qw: AlignedVec<i8>,
    sw: f32,
    wmax: f32,
    b: Option<Vec<f32>>,
    d: usize,
    m: usize,
}

impl DenseI8Kernel {
    pub fn new(w: Vec<f32>, b: Option<Vec<f32>>, m: usize) -> DenseI8Kernel {
        assert!(m > 0 && w.len() % m == 0, "dense-i8 weight must be [D, M]");
        let d = w.len() / m;
        let wmax = w.iter().fold(0.0f32, |acc, &x| acc.max(x.abs()));
        let sw = (wmax / 127.0).max(1e-30);
        let q: Vec<i8> = w
            .iter()
            .map(|&x| (x / sw).round().clamp(-127.0, 127.0) as i8)
            .collect();
        DenseI8Kernel { qw: AlignedVec::from_slice(&q, TABLE_ALIGN), sw, wmax, b, d, m }
    }

    /// Global weight quantization step (`max|W| / 127`).
    pub fn weight_scale(&self) -> f32 {
        self.sw
    }

    /// Documented per-element absolute error bound vs the f32 `"dense"`
    /// reference, for inputs with `max|x| <= input_max_abs`. Each of the
    /// D accumulated terms errs by at most
    /// `sa*|qa|*ew + sw*|qw|*ea + ea*ew` with `ea <= sa/2`, `ew <= sw/2`
    /// and `|qa|,|qw| <= 127`, i.e. `~ amax * wmax / 127` per term; the
    /// 1.05 factor absorbs the cross term and the reference's own f32
    /// accumulation rounding.
    pub fn abs_tolerance(&self, input_max_abs: f32) -> f32 {
        self.d as f32 * input_max_abs.abs() * self.wmax * (1.0 / 127.0) * 1.05 + 1e-4
    }

    /// One forward row: dynamic input quantization, exact-i32
    /// accumulate via `row_acc`, dequant + write. `qa`/`acc32` are
    /// caller scratch resized to D/M.
    fn forward_row(
        &self,
        row: &[f32],
        qa: &mut [i16],
        acc32: &mut [i32],
        row_acc: fn(&[i8], &[i16], usize, &mut [i32]),
        dst: &mut [f32],
    ) {
        let amax = row.iter().fold(0.0f32, |acc, &x| acc.max(x.abs()));
        let sa = (amax / 127.0).max(1e-30);
        for (q, &x) in qa.iter_mut().zip(row) {
            *q = (x / sa).round().clamp(-127.0, 127.0) as i16;
        }
        row_acc(self.qw.as_slice(), qa, self.m, acc32);
        let scale = sa * self.sw;
        for (o, &a) in dst.iter_mut().zip(acc32.iter()) {
            *o = a as f32 * scale;
        }
    }
}

/// Pick the int8 row-accumulate implementation once per forward: the
/// AVX2 `madd` micro-kernel when the build carries it and the CPU
/// reports it, the portable loop otherwise. Both are exact in i32, so
/// the choice never changes output bytes.
fn select_row_accumulate() -> fn(&[i8], &[i16], usize, &mut [i32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: avx2 runtime-verified; bounds asserted by callers.
            return |qw: &[i8], qa: &[i16], m: usize, acc: &mut [i32]| unsafe {
                dense_i8_row_avx2(qw, qa, m, acc)
            };
        }
    }
    dense_i8_row_portable
}

/// Portable int8 row kernel: `acc[j] = sum_t qa[t] * qw[t*M + j]`, all
/// in exact i32 (max |acc| ~ D * 127^2, far from overflow for any D the
/// importer admits). Overwrites `acc`.
fn dense_i8_row_portable(qw: &[i8], qa: &[i16], m: usize, acc: &mut [i32]) {
    acc.fill(0);
    for (t, &av) in qa.iter().enumerate() {
        let av = av as i32;
        let wrow = &qw[t * m..(t + 1) * m];
        for (a, &q) in acc.iter_mut().zip(wrow) {
            *a += av * q as i32;
        }
    }
}

/// AVX2 int8 row kernel: per 16-output column block, depth is walked in
/// pairs — two weight rows are sign-extended to i16
/// (`_mm256_cvtepi8_epi16`), interleaved (`unpacklo/hi_epi16`) so each
/// 32-bit element holds the `(w_t[j], w_{t+1}[j])` pair, and one
/// `_mm256_madd_epi16` against the broadcast `(qa[t], qa[t+1])` pair
/// produces `qa[t]*w_t[j] + qa[t+1]*w_{t+1}[j]` — two MACs per
/// instruction with no repacked weight copy. The interleave leaves
/// block columns permuted across the two accumulators
/// (`acc_lo` = j {0..3, 8..11}, `acc_hi` = j {4..7, 12..15}); the
/// store un-permutes. Odd depth takes a scalar last row per block; the
/// column remainder (m % 16) is scalar. Exact i32 throughout — bitwise
/// identical to [`dense_i8_row_portable`]. Overwrites `acc`.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx2")]
unsafe fn dense_i8_row_avx2(qw: &[i8], qa: &[i16], m: usize, acc: &mut [i32]) {
    use std::arch::x86_64::*;
    let d = qa.len();
    let d2 = d & !1usize;
    let m16 = m & !15usize;
    let mut j0 = 0usize;
    while j0 < m16 {
        let mut acc_lo = _mm256_setzero_si256();
        let mut acc_hi = _mm256_setzero_si256();
        let mut t = 0usize;
        while t < d2 {
            let w0 = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                qw.as_ptr().add(t * m + j0) as *const __m128i
            ));
            let w1 = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                qw.as_ptr().add((t + 1) * m + j0) as *const __m128i,
            ));
            let il_lo = _mm256_unpacklo_epi16(w0, w1);
            let il_hi = _mm256_unpackhi_epi16(w0, w1);
            let pair = (qa[t] as u16 as u32) | ((qa[t + 1] as u16 as u32) << 16);
            let av = _mm256_set1_epi32(pair as i32);
            acc_lo = _mm256_add_epi32(acc_lo, _mm256_madd_epi16(il_lo, av));
            acc_hi = _mm256_add_epi32(acc_hi, _mm256_madd_epi16(il_hi, av));
            t += 2;
        }
        let mut tmp_lo = [0i32; 8];
        let mut tmp_hi = [0i32; 8];
        _mm256_storeu_si256(tmp_lo.as_mut_ptr() as *mut __m256i, acc_lo);
        _mm256_storeu_si256(tmp_hi.as_mut_ptr() as *mut __m256i, acc_hi);
        for j in 0..4 {
            acc[j0 + j] = tmp_lo[j];
            acc[j0 + 4 + j] = tmp_hi[j];
            acc[j0 + 8 + j] = tmp_lo[4 + j];
            acc[j0 + 12 + j] = tmp_hi[4 + j];
        }
        if d2 < d {
            let t = d - 1;
            let av = qa[t] as i32;
            for j in 0..16 {
                acc[j0 + j] += av * qw[t * m + j0 + j] as i32;
            }
        }
        j0 += 16;
    }
    for j in m16..m {
        let mut s = 0i32;
        for (t, &av) in qa.iter().enumerate() {
            s += av as i32 * qw[t * m + j] as i32;
        }
        acc[j] = s;
    }
}

impl LinearKernel for DenseI8Kernel {
    fn name(&self) -> &'static str {
        "dense-i8"
    }

    fn in_dim(&self) -> usize {
        self.d
    }

    fn out_dim(&self) -> usize {
        self.m
    }

    fn param_bytes(&self) -> usize {
        // INT8 weights + one f32 scale + f32 bias
        self.qw.len() + 4 + self.b.as_ref().map(|x| x.len() * 4).unwrap_or(0)
    }

    fn forward_into(&self, input: &[f32], rows: usize, scratch: &mut Scratch, out: &mut [f32]) {
        let (d, m) = (self.d, self.m);
        assert_eq!(input.len(), rows * d, "dense-i8 input size");
        let out = &mut out[..rows * m];
        // qa rides in the i16 scratch lane, the accumulator in the i32
        // one — same buffers the LUT family uses, so a shared Scratch
        // settles at the per-layer maximum either way.
        let LutScratch { acc16: qa, acc32, .. } = &mut scratch.lut;
        qa.resize(d, 0);
        acc32.resize(m, 0);
        let row_acc = select_row_accumulate();
        for i in 0..rows {
            let (row, dst) = (&input[i * d..(i + 1) * d], &mut out[i * m..(i + 1) * m]);
            self.forward_row(row, qa, acc32, row_acc, dst);
        }
        if let Some(b) = &self.b {
            add_bias_rows(out, b);
        }
    }
}

/// LUT-NN table-lookup kernel (paper §5): closest-centroid encode +
/// quantized table read/accumulate, with the §6.3 optimization toggles
/// frozen into the kernel at build time.
pub struct LutKernel {
    lut: LutLinear,
    opts: LutOpts,
}

impl LutKernel {
    pub fn new(lut: LutLinear, opts: LutOpts) -> LutKernel {
        LutKernel { lut, opts }
    }

    pub fn opts(&self) -> LutOpts {
        self.opts
    }
}

impl LinearKernel for LutKernel {
    fn name(&self) -> &'static str {
        "lut"
    }

    fn in_dim(&self) -> usize {
        self.lut.input_dim()
    }

    fn out_dim(&self) -> usize {
        self.lut.m
    }

    fn param_bytes(&self) -> usize {
        self.lut.deployed_bytes()
    }

    fn scratch_indices(&self, rows: usize) -> usize {
        rows * self.lut.cb.c
    }

    fn table_bytes(&self) -> usize {
        self.lut.table_bytes()
    }

    fn table_alignment_bytes(&self) -> usize {
        self.lut.table_alignment_bytes()
    }

    fn forward_into(&self, input: &[f32], rows: usize, scratch: &mut Scratch, out: &mut [f32]) {
        self.lut
            .forward_scratch(input, rows, self.opts, &mut scratch.lut, &mut out[..rows * self.lut.m]);
    }

    fn table_bytes_touched(&self, rows: usize) -> usize {
        // mixed_accum reads the common-scale i8 table, else the f32 one
        let elem = if self.opts.mixed_accum { 1 } else { 4 };
        rows * self.lut.cb.c * self.lut.m * elem
    }

    fn forward_profiled(
        &self,
        input: &[f32],
        rows: usize,
        scratch: &mut Scratch,
        out: &mut [f32],
    ) -> KernelPhases {
        let out = &mut out[..rows * self.lut.m];
        let t0 = Instant::now();
        self.lut.encode_scratch(input, rows, self.opts, &mut scratch.lut);
        let t1 = Instant::now();
        self.lut.accumulate_scratch(rows, self.opts, &mut scratch.lut, out);
        KernelPhases {
            encode_ns: (t1 - t0).as_nanos() as u64,
            lookup_ns: t1.elapsed().as_nanos() as u64,
        }
    }
}

/// Explicit-SIMD LUT kernel: the [`crate::lut::simd`] vectorized
/// closest-centroid encode (AVX2 intrinsics behind `--features simd`,
/// lane-structured portable fallback otherwise) feeding the same
/// table-accumulate core as [`LutKernel`].
///
/// **Bitwise contract**: for any input, `forward_into` produces bytes
/// identical to `LutKernel` built with the same `LutOpts` (as long as
/// `centroid_stationary` is on, which every shipped config sets) — the
/// SIMD encode performs the same FP ops in the same per-element order.
/// The `kernel_parity` fuzz harness pins this across random shapes.
pub struct SimdLutKernel {
    lut: LutLinear,
    opts: LutOpts,
}

impl SimdLutKernel {
    pub fn new(lut: LutLinear, opts: LutOpts) -> SimdLutKernel {
        SimdLutKernel { lut, opts }
    }

    /// Which distance-kernel implementation this build/CPU dispatches to
    /// — one of [`crate::lut::simd::BACKENDS`].
    pub fn backend(&self) -> &'static str {
        simd::active_backend()
    }
}

impl LinearKernel for SimdLutKernel {
    fn name(&self) -> &'static str {
        "lut-simd"
    }

    fn in_dim(&self) -> usize {
        self.lut.input_dim()
    }

    fn out_dim(&self) -> usize {
        self.lut.m
    }

    fn param_bytes(&self) -> usize {
        self.lut.deployed_bytes()
    }

    fn scratch_indices(&self, rows: usize) -> usize {
        rows * self.lut.cb.c
    }

    fn table_bytes(&self) -> usize {
        self.lut.table_bytes()
    }

    fn table_alignment_bytes(&self) -> usize {
        self.lut.table_alignment_bytes()
    }

    fn forward_into(&self, input: &[f32], rows: usize, scratch: &mut Scratch, out: &mut [f32]) {
        let lut = &self.lut;
        assert_eq!(input.len(), rows * lut.input_dim(), "lut-simd input size");
        let out = &mut out[..rows * lut.m];
        out.fill(0.0);
        let LutScratch { idx, scores, acc16, acc32, .. } = &mut scratch.lut;
        idx.clear();
        idx.resize(rows * lut.cb.c, 0);
        simd::encode_simd(lut, input, rows, scores, idx);
        lut.accumulate_buffered(idx, rows, self.opts, acc16, acc32, out);
    }

    fn table_bytes_touched(&self, rows: usize) -> usize {
        let elem = if self.opts.mixed_accum { 1 } else { 4 };
        rows * self.lut.cb.c * self.lut.m * elem
    }

    fn forward_profiled(
        &self,
        input: &[f32],
        rows: usize,
        scratch: &mut Scratch,
        out: &mut [f32],
    ) -> KernelPhases {
        let lut = &self.lut;
        assert_eq!(input.len(), rows * lut.input_dim(), "lut-simd input size");
        let out = &mut out[..rows * lut.m];
        out.fill(0.0);
        let LutScratch { idx, scores, acc16, acc32, .. } = &mut scratch.lut;
        idx.clear();
        idx.resize(rows * lut.cb.c, 0);
        let t0 = Instant::now();
        simd::encode_simd(lut, input, rows, scores, idx);
        let t1 = Instant::now();
        lut.accumulate_buffered(idx, rows, self.opts, acc16, acc32, out);
        KernelPhases {
            encode_ns: (t1 - t0).as_nanos() as u64,
            lookup_ns: t1.elapsed().as_nanos() as u64,
        }
    }
}

/// Int8 LUT kernel (TableNet-style multiplier-less lookup-add): the
/// whole table requantized once to a single global scale, accumulated in
/// pure `i32` adds across all codebooks, one f32 scale multiply + bias
/// per output element at the end.
///
/// Unlike the deployed `"lut"` path (per-codebook INT8 scales rescaled
/// to a common scale, i16 group lanes), this kernel trades the
/// double-rounding for the simplest possible inner loop. Output differs
/// from the scalar reference by bounded requantization error — see
/// [`LutI8Kernel::abs_tolerance`] for the documented per-element bound
/// the parity harness enforces.
pub struct LutI8Kernel {
    lut: LutLinear,
    /// whole table at one global scale, [C, K, M] row-major (rows read
    /// M-contiguously; first row cache-line pinned — see `lut::layout`)
    q: AlignedVec<i8>,
    scale: f32,
}

impl LutI8Kernel {
    pub fn new(lut: LutLinear) -> LutI8Kernel {
        let max_abs = lut.table_f32.iter().fold(0.0f32, |acc, &x| acc.max(x.abs()));
        let scale = (max_abs / 127.0).max(1e-30);
        let q: Vec<i8> = lut
            .table_f32
            .iter()
            .map(|&x| (x / scale).round().clamp(-127.0, 127.0) as i8)
            .collect();
        LutI8Kernel { lut, q: AlignedVec::from_slice(&q, TABLE_ALIGN), scale }
    }

    /// Global table quantization step.
    pub fn scale(&self) -> f32 {
        self.scale
    }

    /// Documented per-element absolute error bound vs the scalar `"lut"`
    /// reference: each of the C accumulated table rows carries at most
    /// half a quantization step under this kernel's global scale plus
    /// half a step under the reference's common scale (the reference
    /// re-rounds per-codebook INT8 onto its common scale, so its own
    /// error contributes symmetrically).
    pub fn abs_tolerance(&self) -> f32 {
        self.lut.cb.c as f32 * (self.scale + self.lut.common_scale()) + 1e-4
    }

    /// §5.2 half: global-scale i32 lookup-adds, one scale multiply per
    /// output element, bias last (shared by the plain and profiled
    /// forwards so the split cannot drift).
    fn accumulate(&self, idx: &[u16], rows: usize, acc32: &mut Vec<i32>, out: &mut [f32]) {
        let lut = &self.lut;
        let (c_total, k, m) = (lut.cb.c, lut.cb.k, lut.m);
        let q = self.q.as_slice();
        acc32.resize(m, 0);
        for i in 0..rows {
            acc32.fill(0);
            for c in 0..c_total {
                let kk = idx[i * c_total + c] as usize;
                let base = (c * k + kk) * m;
                let row = &q[base..base + m];
                // multiplier-less lookup-add: i32 += i8 widening only
                for (a, &qv) in acc32.iter_mut().zip(row) {
                    *a += qv as i32;
                }
            }
            let dst = &mut out[i * m..(i + 1) * m];
            for (o, &a) in dst.iter_mut().zip(acc32.iter()) {
                *o = a as f32 * self.scale;
            }
        }
        if let Some(b) = &lut.bias {
            add_bias_rows(out, b);
        }
    }
}

impl LinearKernel for LutI8Kernel {
    fn name(&self) -> &'static str {
        "lut-i8"
    }

    fn in_dim(&self) -> usize {
        self.lut.input_dim()
    }

    fn out_dim(&self) -> usize {
        self.lut.m
    }

    fn param_bytes(&self) -> usize {
        // codebooks f32 + global-scale INT8 table + one f32 scale + bias
        self.lut.cb.data.len() * 4
            + self.q.len()
            + 4
            + self.lut.bias.as_ref().map(|b| b.len() * 4).unwrap_or(0)
    }

    fn scratch_indices(&self, rows: usize) -> usize {
        rows * self.lut.cb.c
    }

    fn table_bytes(&self) -> usize {
        self.q.len()
    }

    fn table_alignment_bytes(&self) -> usize {
        self.q.align_bytes()
    }

    fn forward_into(&self, input: &[f32], rows: usize, scratch: &mut Scratch, out: &mut [f32]) {
        let lut = &self.lut;
        assert_eq!(input.len(), rows * lut.input_dim(), "lut-i8 input size");
        let out = &mut out[..rows * lut.m];
        let LutScratch { idx, scores, acc32, .. } = &mut scratch.lut;
        idx.clear();
        idx.resize(rows * lut.cb.c, 0);
        simd::encode_simd(lut, input, rows, scores, idx);
        self.accumulate(idx, rows, acc32, out);
    }

    fn table_bytes_touched(&self, rows: usize) -> usize {
        rows * self.lut.cb.c * self.lut.m
    }

    fn forward_profiled(
        &self,
        input: &[f32],
        rows: usize,
        scratch: &mut Scratch,
        out: &mut [f32],
    ) -> KernelPhases {
        let lut = &self.lut;
        assert_eq!(input.len(), rows * lut.input_dim(), "lut-i8 input size");
        let out = &mut out[..rows * lut.m];
        let LutScratch { idx, scores, acc32, .. } = &mut scratch.lut;
        idx.clear();
        idx.resize(rows * lut.cb.c, 0);
        let t0 = Instant::now();
        simd::encode_simd(lut, input, rows, scores, idx);
        let t1 = Instant::now();
        self.accumulate(idx, rows, acc32, out);
        KernelPhases {
            encode_ns: (t1 - t0).as_nanos() as u64,
            lookup_ns: t1.elapsed().as_nanos() as u64,
        }
    }
}

/// Decomposed-table LUT kernel (ReducedLUT-style, see
/// [`crate::lut::decomposed`]): the `[C, K, M]` table split into a
/// shared f32 base vector (folded across codebooks) plus 4-bit
/// nibble-packed residual sub-tables at per-codebook scales —
/// approaching **half** the deployed INT8 table's bytes on realistic
/// geometry, at a bounded accuracy cost.
///
/// Output differs from the scalar `"lut"` reference by bounded residual
/// quantization error — see [`DecLutKernel::abs_tolerance`] for the
/// documented per-element bound the parity harness enforces.
pub struct DecLutKernel {
    lut: LutLinear,
    dec: DecomposedTable,
}

impl DecLutKernel {
    pub fn new(lut: LutLinear) -> DecLutKernel {
        let dec = DecomposedTable::decompose(&lut);
        DecLutKernel { lut, dec }
    }

    /// The decomposed table (base vector, residual scales, packed
    /// sub-tables).
    pub fn decomposed(&self) -> &DecomposedTable {
        &self.dec
    }

    /// Documented per-element absolute error bound vs the scalar `"lut"`
    /// reference: accumulating C residual rows carries at most half a
    /// residual quantization step per codebook (`sum_c scales[c] / 2`,
    /// the base is exact f32), while the reference itself re-rounds
    /// per-codebook INT8 onto its common scale (up to half a common
    /// step per codebook). Both contributions are doubled for slack the
    /// way `LutI8Kernel::abs_tolerance` is.
    pub fn abs_tolerance(&self) -> f32 {
        let sum_scales: f32 = self.dec.scales.iter().sum();
        sum_scales + self.lut.cb.c as f32 * self.lut.common_scale() + 1e-4
    }

    /// §5.2 half: shared base copy + nibble residual accumulation, bias
    /// last (shared by the plain and profiled forwards).
    fn accumulate(&self, idx: &[u16], rows: usize, out: &mut [f32]) {
        let lut = &self.lut;
        let (c_total, k, m) = (lut.cb.c, lut.cb.k, lut.m);
        let dec = &self.dec;
        let row_bytes = dec.row_bytes();
        let resid = dec.resid();
        for i in 0..rows {
            let dst = &mut out[i * m..(i + 1) * m];
            // shared base first (the folded rank-one component), then
            // one small residual row per codebook
            dst.copy_from_slice(&dec.base_total);
            for c in 0..c_total {
                let kk = idx[i * c_total + c] as usize;
                let base = (c * k + kk) * row_bytes;
                let row = &resid[base..base + row_bytes];
                let s = dec.scales[c];
                for j in 0..m {
                    let byte = row[j / 2];
                    let nib = if j & 1 == 0 { byte & 0x0F } else { byte >> 4 };
                    dst[j] += (nib as i32 - 8) as f32 * s;
                }
            }
        }
        if let Some(b) = &lut.bias {
            add_bias_rows(out, b);
        }
    }
}

impl LinearKernel for DecLutKernel {
    fn name(&self) -> &'static str {
        "lut-dec"
    }

    fn in_dim(&self) -> usize {
        self.lut.input_dim()
    }

    fn out_dim(&self) -> usize {
        self.lut.m
    }

    fn param_bytes(&self) -> usize {
        // codebooks f32 + decomposed table (base + packed residuals +
        // scales) + bias
        self.lut.cb.data.len() * 4
            + self.dec.table_bytes()
            + self.lut.bias.as_ref().map(|b| b.len() * 4).unwrap_or(0)
    }

    fn scratch_indices(&self, rows: usize) -> usize {
        rows * self.lut.cb.c
    }

    fn table_bytes(&self) -> usize {
        self.dec.table_bytes()
    }

    fn table_alignment_bytes(&self) -> usize {
        self.dec.table_alignment_bytes()
    }

    fn forward_into(&self, input: &[f32], rows: usize, scratch: &mut Scratch, out: &mut [f32]) {
        let lut = &self.lut;
        assert_eq!(input.len(), rows * lut.input_dim(), "lut-dec input size");
        let out = &mut out[..rows * lut.m];
        let LutScratch { idx, scores, .. } = &mut scratch.lut;
        idx.clear();
        idx.resize(rows * lut.cb.c, 0);
        simd::encode_simd(lut, input, rows, scores, idx);
        self.accumulate(idx, rows, out);
    }

    fn table_bytes_touched(&self, rows: usize) -> usize {
        // f32 base vector once per row + one packed residual row per
        // codebook
        rows * (4 * self.lut.m + self.lut.cb.c * self.dec.row_bytes())
    }

    fn forward_profiled(
        &self,
        input: &[f32],
        rows: usize,
        scratch: &mut Scratch,
        out: &mut [f32],
    ) -> KernelPhases {
        let lut = &self.lut;
        assert_eq!(input.len(), rows * lut.input_dim(), "lut-dec input size");
        let out = &mut out[..rows * lut.m];
        let LutScratch { idx, scores, .. } = &mut scratch.lut;
        idx.clear();
        idx.resize(rows * lut.cb.c, 0);
        let t0 = Instant::now();
        simd::encode_simd(lut, input, rows, scores, idx);
        let t1 = Instant::now();
        self.accumulate(idx, rows, out);
        KernelPhases {
            encode_ns: (t1 - t0).as_nanos() as u64,
            lookup_ns: t1.elapsed().as_nanos() as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ops;
    use crate::pq::kmeans::learn_codebooks;
    use crate::tensor::Tensor;
    use crate::util::{prng::Prng, prop};

    #[test]
    fn dense_kernel_matches_ops_linear() {
        let mut rng = Prng::new(0);
        let (n, d, m) = (7, 12, 5);
        let w = rng.normal_vec(d * m, 0.5);
        let b = vec![0.25; m];
        let x = Tensor::new(vec![n, d], rng.normal_vec(n * d, 1.0));
        let want = ops::linear(&x, &w, Some(&b), m);
        let kern = DenseKernel::new(w, Some(b), m);
        let mut scratch = Scratch::default();
        let mut out = vec![7.0f32; n * m]; // pre-poisoned: kernel must overwrite
        kern.forward_into(&x.data, n, &mut scratch, &mut out);
        assert_eq!(out, want.data, "dense kernel must be bitwise ops::linear");
        assert_eq!(kern.param_bytes(), 4 * (d * m + m));
        assert_eq!(kern.scratch_indices(99), 0);
    }

    #[test]
    fn lut_kernel_matches_lutlinear_forward() {
        let mut rng = Prng::new(1);
        let (n, c, v, k, m) = (9, 3, 4, 8, 6);
        let d = c * v;
        let a = rng.normal_vec(n * d, 1.0);
        let w = rng.normal_vec(d * m, 1.0);
        let cb = learn_codebooks(&a, n, d, c, k, 5, 0);
        let lut = LutLinear::new(cb, &w, m, Some(vec![0.5; m]), 8);
        let want = lut.forward(&a, n, LutOpts::deployed());
        let kern = LutKernel::new(lut, LutOpts::deployed());
        let mut scratch = Scratch::default();
        let mut out = vec![-3.0f32; n * m];
        kern.forward_into(&a, n, &mut scratch, &mut out);
        assert_eq!(out, want, "lut kernel must be bitwise LutLinear::forward");
        assert_eq!(kern.in_dim(), d);
        assert_eq!(kern.out_dim(), m);
        assert_eq!(kern.scratch_indices(n), n * c);
    }

    fn lut_fixture(seed: u64, n: usize, c: usize, v: usize, k: usize, m: usize) -> (Vec<f32>, LutLinear) {
        let mut rng = Prng::new(seed);
        let d = c * v;
        let a = rng.normal_vec(n * d, 1.0);
        let w = rng.normal_vec(d * m, 1.0);
        let cb = learn_codebooks(&a, n, d, c, k, 5, seed);
        (a, LutLinear::new(cb, &w, m, Some(rng.normal_vec(m, 0.5)), 8))
    }

    #[test]
    fn simd_kernel_bitwise_matches_lut_kernel() {
        let (n, m) = (11, 7);
        let (a, lut) = lut_fixture(5, n, 4, 9, 16, m);
        for opts in [
            LutOpts::deployed(),
            LutOpts::all(),
            LutOpts { blocked_table_read: false, ..LutOpts::deployed() },
            LutOpts { mixed_accum: false, ..LutOpts::deployed() },
        ] {
            let reference = LutKernel::new(lut.clone(), opts);
            let candidate = SimdLutKernel::new(lut.clone(), opts);
            let (mut s1, mut s2) = (Scratch::default(), Scratch::default());
            let mut o1 = vec![1.0f32; n * m];
            let mut o2 = vec![-1.0f32; n * m];
            reference.forward_into(&a, n, &mut s1, &mut o1);
            candidate.forward_into(&a, n, &mut s2, &mut o2);
            assert_eq!(o1, o2, "lut-simd must be bitwise lut ({opts:?})");
        }
        let kern = SimdLutKernel::new(lut, LutOpts::deployed());
        assert!(simd::BACKENDS.contains(&kern.backend()));
        assert_eq!(kern.name(), "lut-simd");
        assert_eq!(kern.scratch_indices(3), 3 * 4);
    }

    #[test]
    fn i8_kernel_within_documented_tolerance() {
        let (n, m) = (13, 9);
        let (a, lut) = lut_fixture(6, n, 3, 4, 8, m);
        let reference = LutKernel::new(lut.clone(), LutOpts::deployed());
        let candidate = LutI8Kernel::new(lut.clone());
        let (mut s1, mut s2) = (Scratch::default(), Scratch::default());
        let mut o1 = vec![9.0f32; n * m];
        let mut o2 = vec![-9.0f32; n * m];
        reference.forward_into(&a, n, &mut s1, &mut o1);
        candidate.forward_into(&a, n, &mut s2, &mut o2);
        prop::assert_close(&o2, &o1, 0.0, candidate.abs_tolerance()).unwrap();
        assert!(candidate.scale() > 0.0);
        // int8 table + f32 codebooks is smaller than the reference's
        // per-codebook-scale representation (C scales vs 1).
        assert!(candidate.param_bytes() <= reference.param_bytes() + 4 * lut.cb.c);
    }

    #[test]
    fn dec_kernel_within_documented_tolerance() {
        let (n, m) = (12, 10);
        let (a, lut) = lut_fixture(9, n, 4, 4, 16, m);
        let reference = LutKernel::new(lut.clone(), LutOpts::deployed());
        let candidate = DecLutKernel::new(lut.clone());
        let (mut s1, mut s2) = (Scratch::default(), Scratch::default());
        let mut o1 = vec![5.0f32; n * m];
        let mut o2 = vec![-5.0f32; n * m];
        reference.forward_into(&a, n, &mut s1, &mut o1);
        candidate.forward_into(&a, n, &mut s2, &mut o2);
        prop::assert_close(&o2, &o1, 0.0, candidate.abs_tolerance()).unwrap();
        assert_eq!(candidate.name(), "lut-dec");
        assert_eq!((candidate.in_dim(), candidate.out_dim()), (16, m));
        assert_eq!(candidate.scratch_indices(3), 3 * 4);
    }

    #[test]
    fn dec_kernel_table_is_smaller_than_every_int8_sibling() {
        let (_, lut) = lut_fixture(10, 16, 4, 4, 16, 32);
        let dec = DecLutKernel::new(lut.clone());
        let scalar = LutKernel::new(lut.clone(), LutOpts::deployed());
        let i8k = LutI8Kernel::new(lut);
        assert!(
            dec.table_bytes() < scalar.table_bytes()
                && dec.table_bytes() < i8k.table_bytes(),
            "dec {} vs lut {} / lut-i8 {}",
            dec.table_bytes(),
            scalar.table_bytes(),
            i8k.table_bytes()
        );
        // every LUT-family table is cache-line pinned; dense has none
        assert_eq!(dec.table_alignment_bytes(), TABLE_ALIGN);
        assert_eq!(scalar.table_alignment_bytes(), TABLE_ALIGN);
        assert_eq!(i8k.table_alignment_bytes(), TABLE_ALIGN);
        let dense = DenseKernel::new(vec![0.0; 8], None, 2);
        assert_eq!((dense.table_bytes(), dense.table_alignment_bytes()), (0, 1));
    }

    #[test]
    fn dense_i8_kernel_within_documented_tolerance() {
        prop::check(40, |g| {
            let n = g.usize(1..8);
            let d = g.usize(1..40);
            let m = *g.pick(&[1usize, 4, 7, 9, 15, 16, 17, 31, 33]);
            let mut rng = Prng::new(g.case_seed);
            let w = rng.normal_vec(d * m, 0.7);
            let b = Some(rng.normal_vec(m, 0.3));
            let x = rng.normal_vec(n * d, 1.0);
            let reference = DenseKernel::new(w.clone(), b.clone(), m);
            let candidate = DenseI8Kernel::new(w, b, m);
            let (mut s1, mut s2) = (Scratch::default(), Scratch::default());
            let mut o1 = vec![4.0f32; n * m];
            let mut o2 = vec![-4.0f32; n * m];
            reference.forward_into(&x, n, &mut s1, &mut o1);
            candidate.forward_into(&x, n, &mut s2, &mut o2);
            let amax = x.iter().fold(0.0f32, |acc, &v| acc.max(v.abs()));
            prop::assert_close(&o2, &o1, 0.0, candidate.abs_tolerance(amax))
                .map_err(|e| format!("n={n} d={d} m={m}: {e}"))?;
            Ok(())
        });
    }

    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    #[test]
    fn dense_i8_avx2_micro_kernel_is_bitwise_the_portable_loop() {
        if !std::arch::is_x86_feature_detected!("avx2") {
            return; // nothing to compare on this CPU
        }
        prop::check(60, |g| {
            // depth parities + column remainders around the 16-wide block
            let d = *g.pick(&[1usize, 2, 3, 8, 15, 16, 17, 64, 577]);
            let m = *g.pick(&[1usize, 7, 9, 15, 16, 17, 31, 32, 33, 48]);
            let qw: Vec<i8> = g
                .f32_vec(d * m, 2.0)
                .iter()
                .map(|&x| (x * 40.0).clamp(-127.0, 127.0) as i8)
                .collect();
            let qa: Vec<i16> = g
                .f32_vec(d, 2.0)
                .iter()
                .map(|&x| (x * 40.0).clamp(-127.0, 127.0) as i16)
                .collect();
            let mut want = vec![0i32; m];
            dense_i8_row_portable(&qw, &qa, m, &mut want);
            let mut got = vec![i32::MIN; m]; // poisoned: kernel must overwrite
            unsafe { dense_i8_row_avx2(&qw, &qa, m, &mut got) };
            if got != want {
                return Err(format!("d={d} m={m}: {got:?} vs {want:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn dense_i8_metadata_and_size() {
        let mut rng = Prng::new(3);
        let (d, m) = (20, 6);
        let w = rng.normal_vec(d * m, 1.0);
        let f32k = DenseKernel::new(w.clone(), Some(vec![0.1; m]), m);
        let i8k = DenseI8Kernel::new(w, Some(vec![0.1; m]), m);
        assert_eq!(i8k.name(), "dense-i8");
        assert_eq!((i8k.in_dim(), i8k.out_dim()), (d, m));
        assert_eq!(i8k.scratch_indices(9), 0);
        // dense GEMM reads no lookup tables — the memory gate counts
        // its weights under param_bytes only
        assert_eq!((i8k.table_bytes(), i8k.table_alignment_bytes()), (0, 1));
        assert!(i8k.param_bytes() < f32k.param_bytes() / 3, "int8 weights ~4x smaller");
        assert!(i8k.weight_scale() > 0.0);
    }

    #[test]
    fn kernels_share_one_scratch_across_shapes() {
        // Heterogeneous layers reusing a single Scratch (the session
        // pattern) must not corrupt each other's working memory.
        let (a1, lut1) = lut_fixture(7, 6, 2, 4, 8, 5);
        let (a2, lut2) = lut_fixture(8, 3, 5, 2, 16, 11);
        let k1 = SimdLutKernel::new(lut1, LutOpts::deployed());
        let k2 = LutI8Kernel::new(lut2.clone());
        let k2_ref = LutI8Kernel::new(lut2);
        let mut shared = Scratch::default();
        let mut o1 = vec![0.0f32; 6 * 5];
        k1.forward_into(&a1, 6, &mut shared, &mut o1);
        let mut o2 = vec![0.0f32; 3 * 11];
        k2.forward_into(&a2, 3, &mut shared, &mut o2);
        // replay with a fresh scratch: identical bytes
        let mut fresh = Scratch::default();
        let mut o2b = vec![7.0f32; 3 * 11];
        k2_ref.forward_into(&a2, 3, &mut fresh, &mut o2b);
        assert_eq!(o2, o2b, "scratch reuse must not change results");
    }

    #[test]
    fn profiled_forward_is_bitwise_and_attributes_tables() {
        let (n, m) = (10, 6);
        let (a, lut) = lut_fixture(11, n, 3, 4, 8, m);
        let kernels: Vec<Box<dyn LinearKernel>> = vec![
            Box::new(LutKernel::new(lut.clone(), LutOpts::deployed())),
            Box::new(SimdLutKernel::new(lut.clone(), LutOpts::deployed())),
            Box::new(LutI8Kernel::new(lut.clone())),
            Box::new(DecLutKernel::new(lut.clone())),
        ];
        for k in &kernels {
            let (mut s1, mut s2) = (Scratch::default(), Scratch::default());
            let mut o1 = vec![3.0f32; n * m];
            let mut o2 = vec![-3.0f32; n * m];
            k.forward_into(&a, n, &mut s1, &mut o1);
            let _ph = k.forward_profiled(&a, n, &mut s2, &mut o2);
            assert_eq!(o1, o2, "{}: profiled forward must be bitwise", k.name());
            assert!(k.table_bytes_touched(n) > 0, "{} touches tables", k.name());
            assert_eq!(k.table_bytes_touched(0), 0, "{}", k.name());
        }
        // deployed "lut" reads the common-scale i8 table: C*M bytes/row
        assert_eq!(kernels[0].table_bytes_touched(n), n * 3 * m);
        // kernels without a phase split report zeros via the default
        let dense = DenseKernel::new(vec![0.0; 12], None, 3);
        let mut s = Scratch::default();
        let mut o = vec![0.0f32; 2 * 3];
        let ph = dense.forward_profiled(&[0.0; 8], 2, &mut s, &mut o);
        assert_eq!((ph.encode_ns, ph.lookup_ns), (0, 0));
        assert_eq!(dense.table_bytes_touched(2), 0);
    }
}
