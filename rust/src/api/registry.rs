//! Open kernel registry: maps a kernel tag (`"dense"`, `"lut"`, ...)
//! to a factory that builds a [`LinearKernel`] from a layer's
//! parameters. New implementations (SIMD argmin, int8 GEMM, decomposed
//! ReducedLUT tables, ...) register by name — the executor never
//! changes.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use super::kernel::{DenseKernel, LinearKernel, LutKernel};
use crate::lut::LutOpts;
use crate::nn::graph::LayerParams;

/// Build-time context handed to every kernel factory.
#[derive(Debug, Clone, Copy)]
pub struct KernelBuildCtx {
    /// §6.3 optimization toggles for LUT-family kernels.
    pub opts: LutOpts,
}

/// A factory producing a kernel from layer parameters, or an error when
/// the parameters do not fit the implementation.
pub type KernelFactory =
    Box<dyn Fn(&LayerParams, &KernelBuildCtx) -> Result<Box<dyn LinearKernel>> + Send + Sync>;

/// Name -> factory registry. `with_defaults()` registers the two
/// built-in kernels; callers may add or override entries before handing
/// the registry to a `SessionBuilder`.
pub struct KernelRegistry {
    factories: BTreeMap<String, KernelFactory>,
}

impl KernelRegistry {
    /// An empty registry (no kernels — for fully custom stacks).
    pub fn empty() -> KernelRegistry {
        KernelRegistry { factories: BTreeMap::new() }
    }

    /// Registry with the built-in `"dense"` and `"lut"` kernels.
    pub fn with_defaults() -> KernelRegistry {
        let mut r = KernelRegistry::empty();
        r.register("dense", |params, _ctx| match params {
            LayerParams::Dense { w, b, m } => {
                Ok(Box::new(DenseKernel::new(w.clone(), b.clone(), *m)) as Box<dyn LinearKernel>)
            }
            _ => Err(anyhow!("'dense' kernel needs Dense layer params")),
        });
        r.register("lut", |params, ctx| match params {
            LayerParams::Lut(lut) => {
                Ok(Box::new(LutKernel::new(lut.clone(), ctx.opts)) as Box<dyn LinearKernel>)
            }
            _ => Err(anyhow!("'lut' kernel needs Lut layer params")),
        });
        r
    }

    /// Register (or override) a factory under `name`.
    pub fn register<F>(&mut self, name: &str, factory: F)
    where
        F: Fn(&LayerParams, &KernelBuildCtx) -> Result<Box<dyn LinearKernel>>
            + Send
            + Sync
            + 'static,
    {
        self.factories.insert(name.to_string(), Box::new(factory));
    }

    /// Registered kernel tags, sorted.
    pub fn names(&self) -> Vec<String> {
        self.factories.keys().cloned().collect()
    }

    /// Instantiate the kernel registered under `tag` for `params`.
    pub fn build(
        &self,
        tag: &str,
        params: &LayerParams,
        ctx: &KernelBuildCtx,
    ) -> Result<Box<dyn LinearKernel>> {
        let f = self
            .factories
            .get(tag)
            .ok_or_else(|| anyhow!("no kernel registered under '{tag}' (have: {:?})", self.names()))?;
        f(params, ctx)
    }
}

impl Default for KernelRegistry {
    fn default() -> Self {
        KernelRegistry::with_defaults()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_build_matching_kinds() {
        let r = KernelRegistry::with_defaults();
        assert_eq!(r.names(), vec!["dense".to_string(), "lut".to_string()]);
        let ctx = KernelBuildCtx { opts: LutOpts::deployed() };
        let dense = LayerParams::Dense { w: vec![0.0; 8], b: None, m: 2 };
        let k = r.build("dense", &dense, &ctx).unwrap();
        assert_eq!((k.name(), k.in_dim(), k.out_dim()), ("dense", 4, 2));
        // mismatched tag/params is an error, unknown tag names the options
        assert!(r.build("lut", &dense, &ctx).is_err());
        let err = format!("{}", r.build("simd", &dense, &ctx).unwrap_err());
        assert!(err.contains("simd") && err.contains("dense"), "{err}");
    }
}
