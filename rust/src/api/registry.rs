//! Open kernel registry: maps a kernel tag (`"dense"`, `"lut"`, ...)
//! to a factory that builds a [`LinearKernel`] from a layer's
//! parameters. New implementations (SIMD argmin, int8 GEMM, decomposed
//! ReducedLUT tables, ...) register by name — the executor never
//! changes.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use super::kernel::{
    DecLutKernel, DenseI8Kernel, DenseKernel, LinearKernel, LutI8Kernel, LutKernel, SimdLutKernel,
};
use crate::lut::LutOpts;
use crate::nn::graph::LayerParams;

/// Build-time context handed to every kernel factory.
#[derive(Debug, Clone, Copy)]
pub struct KernelBuildCtx {
    /// §6.3 optimization toggles for LUT-family kernels.
    pub opts: LutOpts,
}

/// A factory producing a kernel from layer parameters, or an error when
/// the parameters do not fit the implementation.
pub type KernelFactory =
    Box<dyn Fn(&LayerParams, &KernelBuildCtx) -> Result<Box<dyn LinearKernel>> + Send + Sync>;

/// Name -> factory registry. `with_defaults()` registers the two
/// built-in kernels; callers may add or override entries before handing
/// the registry to a `SessionBuilder`.
pub struct KernelRegistry {
    factories: BTreeMap<String, KernelFactory>,
}

impl KernelRegistry {
    /// An empty registry (no kernels — for fully custom stacks).
    pub fn empty() -> KernelRegistry {
        KernelRegistry { factories: BTreeMap::new() }
    }

    /// Registry with the built-in kernels: `"dense"`, `"dense-i8"`
    /// (global-scale int8 GEMM, the honest quantized dense baseline —
    /// see `DenseI8Kernel::abs_tolerance`), `"lut"` (scalar
    /// reference), `"lut-simd"` (explicit-SIMD encode, bitwise-equal to
    /// `"lut"`), `"lut-i8"` (global-scale int8 lookup-add, bounded
    /// requantization error — see `LutI8Kernel::abs_tolerance`), and
    /// `"lut-dec"` (decomposed shared-base + 4-bit residual sub-tables,
    /// ~half the table bytes — see `DecLutKernel::abs_tolerance`).
    pub fn with_defaults() -> KernelRegistry {
        let mut r = KernelRegistry::empty();
        r.register("dense", |params, _ctx| match params {
            LayerParams::Dense { w, b, m } => {
                Ok(Box::new(DenseKernel::new(w.clone(), b.clone(), *m)) as Box<dyn LinearKernel>)
            }
            _ => Err(anyhow!("'dense' kernel needs Dense layer params")),
        });
        r.register("dense-i8", |params, _ctx| match params {
            LayerParams::Dense { w, b, m } => {
                Ok(Box::new(DenseI8Kernel::new(w.clone(), b.clone(), *m)) as Box<dyn LinearKernel>)
            }
            _ => Err(anyhow!("'dense-i8' kernel needs Dense layer params")),
        });
        r.register("lut", |params, ctx| match params {
            LayerParams::Lut(lut) => {
                Ok(Box::new(LutKernel::new(lut.clone(), ctx.opts)) as Box<dyn LinearKernel>)
            }
            _ => Err(anyhow!("'lut' kernel needs Lut layer params")),
        });
        // Both alternative kernels encode centroid-stationary; building
        // them under a naive-encode config would silently change the
        // reference their bitwise/tolerance contracts are stated
        // against, so the factories refuse.
        r.register("lut-simd", |params, ctx| match params {
            LayerParams::Lut(lut) if ctx.opts.centroid_stationary => {
                Ok(Box::new(SimdLutKernel::new(lut.clone(), ctx.opts)) as Box<dyn LinearKernel>)
            }
            LayerParams::Lut(_) => Err(anyhow!(
                "'lut-simd' requires centroid_stationary opts (its encode is \
                 centroid-stationary; the bitwise contract is vs that reference)"
            )),
            _ => Err(anyhow!("'lut-simd' kernel needs Lut layer params")),
        });
        r.register("lut-i8", |params, ctx| match params {
            LayerParams::Lut(lut) if ctx.opts.centroid_stationary => {
                Ok(Box::new(LutI8Kernel::new(lut.clone())) as Box<dyn LinearKernel>)
            }
            LayerParams::Lut(_) => Err(anyhow!(
                "'lut-i8' requires centroid_stationary opts (its encode is \
                 centroid-stationary; abs_tolerance is stated vs that reference)"
            )),
            _ => Err(anyhow!("'lut-i8' kernel needs Lut layer params")),
        });
        r.register("lut-dec", |params, ctx| match params {
            LayerParams::Lut(lut) if ctx.opts.centroid_stationary => {
                Ok(Box::new(DecLutKernel::new(lut.clone())) as Box<dyn LinearKernel>)
            }
            LayerParams::Lut(_) => Err(anyhow!(
                "'lut-dec' requires centroid_stationary opts (its encode is \
                 centroid-stationary; abs_tolerance is stated vs that reference)"
            )),
            _ => Err(anyhow!("'lut-dec' kernel needs Lut layer params")),
        });
        r
    }

    /// Register (or override) a factory under `name`.
    pub fn register<F>(&mut self, name: &str, factory: F)
    where
        F: Fn(&LayerParams, &KernelBuildCtx) -> Result<Box<dyn LinearKernel>>
            + Send
            + Sync
            + 'static,
    {
        self.factories.insert(name.to_string(), Box::new(factory));
    }

    /// Like [`KernelRegistry::register`] but refuses to shadow an
    /// existing entry — for plugins that must not silently replace a
    /// built-in (or each other).
    pub fn register_unique<F>(&mut self, name: &str, factory: F) -> Result<()>
    where
        F: Fn(&LayerParams, &KernelBuildCtx) -> Result<Box<dyn LinearKernel>>
            + Send
            + Sync
            + 'static,
    {
        if self.factories.contains_key(name) {
            return Err(anyhow!(
                "kernel '{name}' is already registered (use register() to override)"
            ));
        }
        self.register(name, factory);
        Ok(())
    }

    /// Registered kernel tags, sorted.
    pub fn names(&self) -> Vec<String> {
        self.factories.keys().cloned().collect()
    }

    /// True when no factories are registered.
    pub fn is_empty(&self) -> bool {
        self.factories.is_empty()
    }

    /// Instantiate the kernel registered under `tag` for `params`.
    pub fn build(
        &self,
        tag: &str,
        params: &LayerParams,
        ctx: &KernelBuildCtx,
    ) -> Result<Box<dyn LinearKernel>> {
        let f = self
            .factories
            .get(tag)
            .ok_or_else(|| anyhow!("no kernel registered under '{tag}' (have: {:?})", self.names()))?;
        f(params, ctx)
    }
}

impl Default for KernelRegistry {
    fn default() -> Self {
        KernelRegistry::with_defaults()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_build_matching_kinds() {
        let r = KernelRegistry::with_defaults();
        assert_eq!(
            r.names(),
            vec![
                "dense".to_string(),
                "dense-i8".to_string(),
                "lut".to_string(),
                "lut-dec".to_string(),
                "lut-i8".to_string(),
                "lut-simd".to_string(),
            ]
        );
        let ctx = KernelBuildCtx { opts: LutOpts::deployed() };
        let dense = LayerParams::Dense { w: vec![0.0; 8], b: None, m: 2 };
        let k = r.build("dense", &dense, &ctx).unwrap();
        assert_eq!((k.name(), k.in_dim(), k.out_dim()), ("dense", 4, 2));
        let k8 = r.build("dense-i8", &dense, &ctx).unwrap();
        assert_eq!((k8.name(), k8.in_dim(), k8.out_dim()), ("dense-i8", 4, 2));
        // mismatched tag/params is an error, unknown tag names the options
        assert!(r.build("lut", &dense, &ctx).is_err());
        assert!(r.build("lut-simd", &dense, &ctx).is_err());
        assert!(r.build("lut-i8", &dense, &ctx).is_err());
        assert!(r.build("lut-dec", &dense, &ctx).is_err());
        let err = format!("{}", r.build("simd", &dense, &ctx).unwrap_err());
        assert!(err.contains("simd") && err.contains("dense"), "{err}");
    }

    #[test]
    fn lut_family_tags_build_lut_kernels() {
        use crate::pq::kmeans::learn_codebooks;
        use crate::util::prng::Prng;
        let mut rng = Prng::new(0);
        let (n, c, v, k, m) = (8, 2, 4, 8, 3);
        let d = c * v;
        let a = rng.normal_vec(n * d, 1.0);
        let cb = learn_codebooks(&a, n, d, c, k, 3, 0);
        let lut = crate::lut::LutLinear::new(cb, &rng.normal_vec(d * m, 1.0), m, None, 8);
        let params = LayerParams::Lut(lut);
        let ctx = KernelBuildCtx { opts: LutOpts::deployed() };
        let r = KernelRegistry::with_defaults();
        for tag in ["lut", "lut-simd", "lut-i8", "lut-dec"] {
            let kern = r.build(tag, &params, &ctx).unwrap();
            assert_eq!(kern.name(), tag);
            assert_eq!((kern.in_dim(), kern.out_dim()), (d, m));
            assert_eq!(kern.scratch_indices(5), 5 * c);
        }
    }

    #[test]
    fn lut_family_factories_refuse_naive_encode_opts() {
        use crate::pq::kmeans::learn_codebooks;
        use crate::util::prng::Prng;
        let mut rng = Prng::new(1);
        let (n, c, v, k, m) = (6, 2, 4, 8, 3);
        let d = c * v;
        let a = rng.normal_vec(n * d, 1.0);
        let cb = learn_codebooks(&a, n, d, c, k, 3, 0);
        let lut = crate::lut::LutLinear::new(cb, &rng.normal_vec(d * m, 1.0), m, None, 8);
        let params = LayerParams::Lut(lut);
        let r = KernelRegistry::with_defaults();
        let naive = KernelBuildCtx { opts: LutOpts::none() };
        for tag in ["lut-simd", "lut-i8", "lut-dec"] {
            let err = format!("{}", r.build(tag, &params, &naive).unwrap_err());
            assert!(err.contains("centroid_stationary"), "{tag}: {err}");
        }
        // the scalar reference accepts every opts config
        assert!(r.build("lut", &params, &naive).is_ok());
    }

    #[test]
    fn register_unique_rejects_duplicates_register_overrides() {
        let mut r = KernelRegistry::with_defaults();
        let dup = r.register_unique("lut", |_, _| Err(anyhow!("never built")));
        let err = format!("{}", dup.unwrap_err());
        assert!(err.contains("already registered"), "{err}");
        r.register_unique("mine", |_, _| Err(anyhow!("mine: unbuildable")))
            .unwrap();
        assert!(r.names().contains(&"mine".to_string()));
        // plain register() deliberately shadows
        r.register("lut", |_, _| Err(anyhow!("shadowed")));
        let ctx = KernelBuildCtx { opts: LutOpts::deployed() };
        let dense = LayerParams::Dense { w: vec![0.0; 4], b: None, m: 2 };
        let err = format!("{}", r.build("lut", &dense, &ctx).unwrap_err());
        assert!(err.contains("shadowed"), "{err}");
    }

    #[test]
    fn empty_registry_builds_nothing() {
        let r = KernelRegistry::empty();
        assert!(r.is_empty());
        assert!(r.names().is_empty());
        let ctx = KernelBuildCtx { opts: LutOpts::deployed() };
        let dense = LayerParams::Dense { w: vec![0.0; 4], b: None, m: 2 };
        let err = format!("{}", r.build("dense", &dense, &ctx).unwrap_err());
        assert!(err.contains("no kernel registered"), "{err}");
    }
}
