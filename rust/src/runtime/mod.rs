//! PJRT runtime: load AOT-compiled HLO text (from `python/compile/aot.py`)
//! and execute it on the CPU PJRT client via the `xla` crate.
//!
//! HLO **text** is the interchange format — jax >= 0.5 serialized protos
//! carry 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! One `PjRtEngine` holds the client; each loaded graph is compiled once
//! into a `CompiledModel` and executed from the request path with no
//! python anywhere.
//!
//! Build environments without the PJRT toolchain compile against the
//! vendored `xla` stub (rust/vendor/xla): every entry point here keeps
//! its signature but returns an error at runtime. Gate PJRT paths
//! behind [`pjrt_available`] (and artifact-dependent tests behind
//! [`artifacts_available`]) so `cargo test` stays green either way.

use anyhow::{anyhow, Context, Result};

use crate::tensor::Tensor;

pub struct PjRtEngine {
    client: xla::PjRtClient,
}

pub struct CompiledModel {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    /// expected input element count (sanity check at call time), if known
    pub input_len: Option<usize>,
}

impl PjRtEngine {
    pub fn cpu() -> Result<PjRtEngine> {
        Ok(PjRtEngine { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file produced by the AOT path.
    pub fn load_hlo_text(&self, path: &str, input_len: Option<usize>) -> Result<CompiledModel> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing HLO text {path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {path}: {e:?}"))?;
        let name = std::path::Path::new(path)
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| path.to_string());
        Ok(CompiledModel { exe, name, input_len })
    }
}

impl CompiledModel {
    /// Execute with one f32 input tensor; returns the first tuple element
    /// as a flat f32 vec (AOT graphs are lowered with return_tuple=True).
    pub fn run_f32(&self, input: &Tensor) -> Result<Vec<f32>> {
        if let Some(expect) = self.input_len {
            anyhow::ensure!(
                input.len() == expect,
                "input len {} != compiled len {}",
                input.len(),
                expect
            );
        }
        let dims: Vec<i64> = input.shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(&input.data)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape input: {e:?}"))?;
        self.run_literals(&[lit])
    }

    /// Execute with an i32 input tensor (token ids for the BERT graphs).
    pub fn run_i32(&self, values: &[i32], shape: &[usize]) -> Result<Vec<f32>> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(values)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape input: {e:?}"))?;
        self.run_literals(&[lit])
    }

    /// Execute with arbitrary pre-built literals (multi-input op graphs).
    pub fn run_literals(&self, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal_sync: {e:?}"))?;
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("expected 1-tuple output: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }
}

// ---------------------------------------------------------------------
// PJRT host thread
// ---------------------------------------------------------------------
//
// The `xla` crate's client/executable types hold `Rc`s and raw pointers
// and are neither Send nor Sync, but the coordinator is multi-threaded.
// A single dedicated host thread owns the PJRT client and every compiled
// model; other threads talk to it over a channel (one in-flight request
// at a time per host — the CPU PJRT client is single-stream anyway).

use std::sync::mpsc::{self, SyncSender};
use std::sync::Mutex;

/// Input payload for a hosted model call.
pub enum HostInput {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

struct HostJob {
    model: usize,
    input: HostInput,
    reply: SyncSender<Result<Vec<f32>>>,
}

/// Handle to the PJRT host thread; cheap to clone, Send + Sync.
pub struct PjrtHost {
    tx: Mutex<SyncSender<HostJob>>,
    _thread: std::thread::JoinHandle<()>,
}

/// One hosted compiled model.
#[derive(Clone)]
pub struct HostedModel {
    host: std::sync::Arc<PjrtHost>,
    id: usize,
    pub name: String,
}

impl PjrtHost {
    /// Spawn the host thread, loading+compiling each HLO text file.
    /// Returns handles in the same order as `paths`.
    pub fn spawn(paths: Vec<String>) -> Result<(std::sync::Arc<PjrtHost>, Vec<HostedModel>)> {
        let (tx, rx) = mpsc::sync_channel::<HostJob>(64);
        let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<Vec<String>>>(1);
        let paths2 = paths.clone();
        let thread = std::thread::Builder::new()
            .name("pjrt-host".into())
            .spawn(move || {
                let engine = match PjRtEngine::cpu() {
                    Ok(e) => e,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let mut models = Vec::new();
                let mut names = Vec::new();
                for p in &paths2 {
                    match engine.load_hlo_text(p, None) {
                        Ok(m) => {
                            names.push(m.name.clone());
                            models.push(m);
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                            return;
                        }
                    }
                }
                let _ = ready_tx.send(Ok(names));
                while let Ok(job) = rx.recv() {
                    let result = match &job.input {
                        HostInput::F32(data, shape) => models[job.model]
                            .run_f32(&Tensor::new(shape.clone(), data.clone())),
                        HostInput::I32(data, shape) => {
                            models[job.model].run_i32(data, shape)
                        }
                    };
                    let _ = job.reply.send(result);
                }
            })?;
        let names = ready_rx
            .recv()
            .map_err(|_| anyhow!("pjrt host died during startup"))??;
        let host = std::sync::Arc::new(PjrtHost { tx: Mutex::new(tx), _thread: thread });
        let handles = names
            .into_iter()
            .enumerate()
            .map(|(id, name)| HostedModel { host: std::sync::Arc::clone(&host), id, name })
            .collect();
        Ok((host, handles))
    }
}

impl HostedModel {
    pub fn run(&self, input: HostInput) -> Result<Vec<f32>> {
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        self.host
            .tx
            .lock()
            .unwrap()
            .send(HostJob { model: self.id, input, reply: reply_tx })
            .map_err(|_| anyhow!("pjrt host shut down"))?;
        reply_rx.recv().map_err(|_| anyhow!("pjrt host dropped request"))?
    }
}

/// Artifact path relative to the repo root, honoring the LUTNN_ARTIFACTS
/// env var so tests/benches run from any cwd.
pub fn artifact_path(name: &str) -> String {
    let dir = std::env::var("LUTNN_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    format!("{dir}/{name}")
}

/// True if `make artifacts` outputs are present.
pub fn artifacts_available() -> bool {
    std::path::Path::new(&artifact_path("manifest.json")).exists()
}

/// True when a PJRT client can boot in this build (false under the
/// vendored `xla` stub). PJRT-dependent tests and benches skip when
/// this is false. The probe boots a client once and caches the result
/// (real PJRT initialization is expensive).
pub fn pjrt_available() -> bool {
    static AVAILABLE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *AVAILABLE.get_or_init(|| PjRtEngine::cpu().is_ok())
}

/// Read a flat little-endian f32 binary file (golden vectors).
pub fn read_f32_file(path: &str) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
    anyhow::ensure!(bytes.len() % 4 == 0, "{path}: not a multiple of 4 bytes");
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT integration tests that need artifacts live in
    // rust/tests/; here only the cheap pieces (no env mutation races).

    #[test]
    fn artifact_path_default() {
        if std::env::var("LUTNN_ARTIFACTS").is_err() {
            assert_eq!(artifact_path("x.hlo.txt"), "artifacts/x.hlo.txt");
        }
    }

    #[test]
    fn cpu_client_boots_when_toolchain_present() {
        match PjRtEngine::cpu() {
            Ok(eng) => assert!(!eng.platform().is_empty()),
            Err(e) => {
                assert!(!pjrt_available());
                eprintln!("skipping: PJRT unavailable in this build ({e:#})");
            }
        }
    }

    #[test]
    fn read_f32_file_roundtrip() {
        let p = std::env::temp_dir().join("lutnn_f32_test.bin");
        let vals = [1.0f32, -2.5, 3.25];
        let mut bytes = Vec::new();
        for v in vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&p, &bytes).unwrap();
        let got = read_f32_file(p.to_str().unwrap()).unwrap();
        assert_eq!(got, vals);
    }
}
