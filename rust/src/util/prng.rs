//! Deterministic PRNG: splitmix64 core with uniform/normal/choice helpers.
//!
//! Used by the synthetic-model builders, the workload trace generator and
//! the property-testing substrate. Deterministic across platforms so test
//! failures reproduce exactly from a seed.

#[derive(Debug, Clone)]
pub struct Prng {
    state: u64,
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        Prng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// splitmix64 step.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos())
            as f32
    }

    /// Exponential with rate `lambda` (Poisson inter-arrival times).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.uniform().max(1e-12).ln() / lambda
    }

    /// Vector of standard normals scaled by `scale`.
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * scale).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(7);
        let mut b = Prng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Prng::new(1);
        let xs: Vec<f64> = (0..20_000).map(|_| r.uniform()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Prng::new(2);
        let xs: Vec<f32> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / xs.len() as f32;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Prng::new(3);
        let lambda = 4.0;
        let mean: f64 =
            (0..20_000).map(|_| r.exponential(lambda)).sum::<f64>() / 20_000.0;
        assert!((mean - 1.0 / lambda).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Prng::new(4);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn below_in_range() {
        let mut r = Prng::new(5);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
