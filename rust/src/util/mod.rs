//! Dependency-free substrates: JSON codec, PRNG, statistics, thread pool,
//! CLI parsing, micro-benchmark harness and a property-testing helper.
//!
//! The build environment vendors only the `xla` crate's closure, so the
//! conveniences normally imported from crates.io (serde, rayon, clap,
//! criterion, proptest) are implemented here at the scale this project
//! needs (DESIGN.md S13/S18/S19).

pub mod benchmark;
pub mod cli;
pub mod hist;
pub mod json;
pub mod prng;
pub mod prop;
pub mod schema;
pub mod stats;
pub mod threadpool;

/// Boolean env-var flag: set and neither empty nor `"0"` means on
/// (`FLAG=0` must mean off — shared by `E2E_FAST`, `UPDATE_GOLDEN`).
pub fn env_flag(name: &str) -> bool {
    std::env::var(name).map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}
