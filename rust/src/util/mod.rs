//! Dependency-free substrates: JSON codec, PRNG, statistics, thread pool,
//! CLI parsing, micro-benchmark harness and a property-testing helper.
//!
//! The build environment vendors only the `xla` crate's closure, so the
//! conveniences normally imported from crates.io (serde, rayon, clap,
//! criterion, proptest) are implemented here at the scale this project
//! needs (DESIGN.md S13/S18/S19).

pub mod benchmark;
pub mod cli;
pub mod json;
pub mod prng;
pub mod prop;
pub mod stats;
pub mod threadpool;
