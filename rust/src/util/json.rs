//! Minimal JSON value model, parser and serializer.
//!
//! Covers the full JSON grammar (RFC 8259) minus exotic number forms;
//! used for `.lutnn` bundle headers, the serving wire protocol, bench
//! result files and config. Errors carry byte offsets for debuggability.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access: `j.get("a")`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// `[1,2,3]` -> `vec![1,2,3]` for numeric arrays.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError { offset: self.pos, message: msg.into() })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            self.err(format!("expected '{}'", b as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected a JSON value"),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            self.err(format!("expected '{word}'"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match s.parse::<f64>() {
            Ok(n) => Ok(Json::Num(n)),
            Err(_) => self.err(format!("bad number '{s}'")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or(JsonError {
                                offset: self.pos,
                                message: "bad \\u escape".into(),
                            })?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or(JsonError {
                                    offset: self.pos,
                                    message: "bad hex digit".into(),
                                })?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8 in place.
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    let end = (start + len).min(self.bytes.len());
                    match std::str::from_utf8(&self.bytes[start..end]) {
                        Ok(s) => {
                            out.push_str(s);
                            self.pos = end;
                        }
                        Err(_) => return self.err("invalid utf-8"),
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

/// Parse a JSON document (must consume all non-whitespace input).
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing characters");
    }
    Ok(v)
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_num(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

/// Serialize compactly (no whitespace), deterministic key order.
pub fn to_string(v: &Json) -> String {
    let mut out = String::new();
    write_value(v, &mut out);
    out
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => write_num(*n, out),
        Json::Str(s) => escape_into(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape_into(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-2.5e1").unwrap(), Json::Num(-25.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a":[1,2,{"b":false}],"c":"x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x");
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"graph":[{"op":"conv","stride":2}],"k":16,"name":"m","ok":true,"t":0.125}"#;
        let j = parse(src).unwrap();
        assert_eq!(to_string(&j), src);
    }

    #[test]
    fn unicode_string() {
        let j = parse("\"caf\u{e9} \\u00e9\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "café é");
    }

    #[test]
    fn errors_have_offsets() {
        let e = parse("{\"a\": }").unwrap_err();
        assert!(e.offset > 0);
        assert!(parse("[1,2").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn usize_vec() {
        let j = parse("[1,8,8,3]").unwrap();
        assert_eq!(j.as_usize_vec().unwrap(), vec![1, 8, 8, 3]);
    }
}
