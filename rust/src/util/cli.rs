//! Tiny CLI argument parser: subcommand + `--flag value` / `--switch`.
//!
//! Grammar note: a `--name` followed by a non-`--` token greedily binds
//! it as the flag's value, so bare switches must come after positionals
//! or use no trailing token (`lutnn infer bundle.lutnn --naive`). Use
//! `--flag=value` to be unambiguous.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.command = it.next();
            }
        }
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                // --key=value or --key value or --switch
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("serve model.lutnn --port 7070 --threads=2 --verbose");
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.get_usize("port", 0), 7070);
        assert_eq!(a.get_usize("threads", 0), 2);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["model.lutnn"]);
    }

    #[test]
    fn greedy_value_binding_documented() {
        // `--verbose model.lutnn` binds the token as a value — the
        // documented ambiguity of the grammar.
        let a = parse("serve --verbose model.lutnn");
        assert_eq!(a.get("verbose"), Some("model.lutnn"));
    }

    #[test]
    fn defaults() {
        let a = parse("bench");
        assert_eq!(a.get_or("mode", "native"), "native");
        assert_eq!(a.get_f64("rate", 1.5), 1.5);
        assert!(!a.has("verbose"));
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--help");
        assert_eq!(a.command, None);
        assert!(a.has("help"));
    }

    #[test]
    fn negative_number_value() {
        let a = parse("infer --offset -3");
        // "-3" does not start with "--" so it is consumed as the value
        assert_eq!(a.get("offset"), Some("-3"));
    }
}
