//! Fixed-size worker thread pool, scoped parallel-for, and the shared
//! work-injector queue.
//!
//! The coordinator's worker pool, the replica batcher's injector
//! ([`WorkQueue`]) and the multi-thread benches (Fig. 9) build on this.
//! Plain std threads + channels + condvars; no external deps.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Instant;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Long-lived pool: submit boxed jobs, drop to join.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    sender: Option<mpsc::Sender<Job>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        assert!(size > 0);
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                thread::Builder::new()
                    .name(format!("lutnn-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { workers, sender: Some(sender), size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker hung up");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Split `0..n` into contiguous chunks and run `f(range)` on `threads`
/// scoped threads (no 'static bound). Returns when all chunks finish.
pub fn parallel_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        f(0..n);
        return;
    }
    let chunk = n.div_ceil(threads);
    thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(lo..hi));
        }
    });
}

/// Why a `try_push` failed.
#[derive(Debug)]
pub enum PushError<T> {
    /// Queue at capacity; the item is handed back (load shedding).
    Full(T),
    /// Queue closed; the item is handed back.
    Closed(T),
}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded multi-producer/multi-consumer injector queue.
///
/// The dynamic batcher's work-stealing core: producers push requests,
/// one consumer per engine replica pops them. Because every consumer
/// pops from this one shared deque, an idle replica automatically
/// steals work that would otherwise wait behind a busy one — no
/// per-replica assignment, no rebalancing pass.
///
/// Close semantics are drain-friendly: after [`WorkQueue::close`],
/// pushes fail immediately but pops keep returning queued items until
/// the queue is empty — consumers can reply to every accepted request
/// before exiting (graceful shutdown).
pub struct WorkQueue<T> {
    state: Mutex<QueueState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

impl<T> WorkQueue<T> {
    /// Queue accepting at most `cap` pending items (min 1).
    pub fn bounded(cap: usize) -> WorkQueue<T> {
        WorkQueue {
            state: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Blocking push (backpressure): waits while the queue is full.
    /// `Err(item)` once the queue is closed.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut g = self.state.lock().unwrap();
        loop {
            if g.closed {
                return Err(item);
            }
            if g.items.len() < self.cap {
                g.items.push_back(item);
                drop(g);
                self.not_empty.notify_one();
                return Ok(());
            }
            g = self.not_full.wait(g).unwrap();
        }
    }

    /// Non-blocking push: sheds instead of waiting when full.
    pub fn try_push(&self, item: T) -> Result<(), PushError<T>> {
        let mut g = self.state.lock().unwrap();
        if g.closed {
            return Err(PushError::Closed(item));
        }
        if g.items.len() >= self.cap {
            return Err(PushError::Full(item));
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop: waits for an item; `None` only once the queue is
    /// closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.state.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Pop with a deadline: waits until `deadline` for an item; `None`
    /// on timeout or on closed-and-drained (batch-window follow-ups).
    pub fn pop_until(&self, deadline: Instant) -> Option<T> {
        let mut g = self.state.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            (g, _) = self.not_empty.wait_timeout(g, deadline - now).unwrap();
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        let item = self.state.lock().unwrap().items.pop_front();
        if item.is_some() {
            self.not_full.notify_one();
        }
        item
    }

    /// Close the queue: producers fail fast, consumers drain what is
    /// already queued and then observe end-of-stream.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Current pending-item count (the true queue depth).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Work-stealing-lite dynamic scheduling over `n` items: threads pull the
/// next index from a shared atomic counter. Better than static chunks when
/// per-item cost varies (e.g. mixed request sizes).
pub fn parallel_items<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    thread::scope(|s| {
        for _ in 0..threads {
            let counter = &counter;
            let f = &f;
            s.spawn(move || loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_chunks_covers_range() {
        let hits: Vec<AtomicUsize> =
            (0..103).map(|_| AtomicUsize::new(0)).collect();
        parallel_chunks(103, 4, |range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_items_covers_range() {
        let hits: Vec<AtomicUsize> =
            (0..57).map(|_| AtomicUsize::new(0)).collect();
        parallel_items(57, 3, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn work_queue_fifo_and_bounds() {
        let q: WorkQueue<u32> = WorkQueue::bounded(2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        assert!(matches!(q.try_push(3), Err(PushError::Full(3))));
        assert_eq!(q.len(), 2);
        assert_eq!(q.try_pop(), Some(1));
        assert_eq!(q.try_pop(), Some(2));
        assert_eq!(q.try_pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn work_queue_close_drains_then_ends() {
        let q: WorkQueue<u32> = WorkQueue::bounded(8);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.close();
        assert!(matches!(q.try_push(3), Err(PushError::Closed(3))));
        assert!(q.push(4).is_err());
        // consumers still drain queued items after close...
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop_until(Instant::now()), Some(2));
        // ...then observe end-of-stream instead of blocking
        assert_eq!(q.pop(), None);
        assert_eq!(q.pop_until(Instant::now() + std::time::Duration::from_secs(5)), None);
    }

    #[test]
    fn work_queue_pop_until_times_out_when_empty() {
        let q: WorkQueue<u32> = WorkQueue::bounded(1);
        let t0 = Instant::now();
        assert_eq!(q.pop_until(t0 + std::time::Duration::from_millis(20)), None);
        assert!(t0.elapsed() >= std::time::Duration::from_millis(20));
    }

    #[test]
    fn work_queue_blocking_push_waits_for_space() {
        let q = Arc::new(WorkQueue::<u32>::bounded(1));
        q.push(1).unwrap();
        let q2 = Arc::clone(&q);
        let pusher = thread::spawn(move || q2.push(2));
        // the pusher is stuck on the full queue until a pop frees a slot
        thread::sleep(std::time::Duration::from_millis(10));
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop(), Some(1));
        assert!(pusher.join().unwrap().is_ok());
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn work_queue_mpmc_delivers_every_item_once() {
        let q = Arc::new(WorkQueue::<usize>::bounded(16));
        let hits: Arc<Vec<AtomicUsize>> =
            Arc::new((0..200).map(|_| AtomicUsize::new(0)).collect());
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = Arc::clone(&q);
                let hits = Arc::clone(&hits);
                thread::spawn(move || {
                    while let Some(i) = q.pop() {
                        hits[i].fetch_add(1, Ordering::SeqCst);
                    }
                })
            })
            .collect();
        for i in 0..200 {
            q.push(i).unwrap();
        }
        q.close();
        for c in consumers {
            c.join().unwrap();
        }
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn single_thread_fallback() {
        let mut hit = vec![false; 10];
        let cell = std::sync::Mutex::new(&mut hit);
        parallel_chunks(10, 1, |r| {
            let mut g = cell.lock().unwrap();
            for i in r {
                g[i] = true;
            }
        });
        assert!(hit.iter().all(|&b| b));
    }
}
