//! Fixed-size worker thread pool with scoped parallel-for.
//!
//! The coordinator's worker pool and the multi-thread benches (Fig. 9)
//! build on this. Plain std threads + channels; no external deps.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Long-lived pool: submit boxed jobs, drop to join.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    sender: Option<mpsc::Sender<Job>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        assert!(size > 0);
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                thread::Builder::new()
                    .name(format!("lutnn-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { workers, sender: Some(sender), size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker hung up");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Split `0..n` into contiguous chunks and run `f(range)` on `threads`
/// scoped threads (no 'static bound). Returns when all chunks finish.
pub fn parallel_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        f(0..n);
        return;
    }
    let chunk = n.div_ceil(threads);
    thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(lo..hi));
        }
    });
}

/// Work-stealing-lite dynamic scheduling over `n` items: threads pull the
/// next index from a shared atomic counter. Better than static chunks when
/// per-item cost varies (e.g. mixed request sizes).
pub fn parallel_items<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    thread::scope(|s| {
        for _ in 0..threads {
            let counter = &counter;
            let f = &f;
            s.spawn(move || loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_chunks_covers_range() {
        let hits: Vec<AtomicUsize> =
            (0..103).map(|_| AtomicUsize::new(0)).collect();
        parallel_chunks(103, 4, |range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_items_covers_range() {
        let hits: Vec<AtomicUsize> =
            (0..57).map(|_| AtomicUsize::new(0)).collect();
        parallel_items(57, 3, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn single_thread_fallback() {
        let mut hit = vec![false; 10];
        let cell = std::sync::Mutex::new(&mut hit);
        parallel_chunks(10, 1, |r| {
            let mut g = cell.lock().unwrap();
            for i in r {
                g[i] = true;
            }
        });
        assert!(hit.iter().all(|&b| b));
    }
}
