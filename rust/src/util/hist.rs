//! Log-bucketed fixed-memory histograms (HDR-style).
//!
//! `Hist` records non-negative `f64` samples into a fixed array of
//! atomic buckets derived from the IEEE-754 bit pattern: the unbiased
//! exponent selects an octave and the top [`SUB_BITS`] mantissa bits
//! split each octave into [`SUB`] linear sub-buckets. Within the
//! covered exponent window ([`MIN_EXP`] ..= [`MAX_EXP`]) every bucket
//! spans `2^e / SUB`, so a quantile estimated at the bucket midpoint is
//! within half a bucket of the exact order statistic:
//!
//! ```text
//! |mid - exact| <= width/2 = 2^e / (2*SUB)
//! exact >= bucket_lo >= 2^e
//! => relative error <= 1 / (2*SUB) = REL_ERROR_BOUND
//! ```
//!
//! Recording is lock-free (`fetch_add` on the bucket + CAS loops for
//! the f64 running sums), histograms merge bucket-wise, and the whole
//! structure is a fixed ~32 KiB regardless of sample count — unlike a
//! saturating sample vector, the tail of a long run is never dropped.
//!
//! Values outside the window clamp to the edge buckets; negative and
//! non-finite samples clamp to zero. For latencies in seconds the
//! window spans ~1 ns .. ~10^10 s, so clamping never occurs in
//! practice.

use super::stats::Summary;
use std::sync::atomic::{AtomicU64, Ordering};

/// Mantissa bits used per octave (64 linear sub-buckets).
pub const SUB_BITS: u32 = 6;
/// Sub-buckets per octave.
pub const SUB: usize = 1 << SUB_BITS;
/// Smallest covered unbiased exponent (values below clamp to bucket 0).
pub const MIN_EXP: i32 = -30;
/// Largest covered unbiased exponent (values above clamp to the last bucket).
pub const MAX_EXP: i32 = 33;
/// Number of octaves in the window.
pub const OCTAVES: usize = (MAX_EXP - MIN_EXP + 1) as usize;
/// Total bucket count (`OCTAVES * SUB`).
pub const BUCKETS: usize = OCTAVES * SUB;
/// Documented worst-case relative quantile error inside the window.
pub const REL_ERROR_BOUND: f64 = 1.0 / (2 * SUB) as f64;

/// Bucket index for a sample (clamps negatives/non-finite to 0).
fn bucket_of(v: f64) -> usize {
    if !v.is_finite() || v <= 0.0 {
        return 0;
    }
    let bits = v.to_bits();
    let exp = ((bits >> 52) & 0x7ff) as i32 - 1023;
    if exp < MIN_EXP {
        return 0;
    }
    if exp > MAX_EXP {
        return BUCKETS - 1;
    }
    let sub = ((bits >> (52 - SUB_BITS)) & (SUB as u64 - 1)) as usize;
    (exp - MIN_EXP) as usize * SUB + sub
}

/// Midpoint of bucket `i` (the quantile estimate for samples landing there).
fn bucket_mid(i: usize) -> f64 {
    let exp = MIN_EXP + (i / SUB) as i32;
    let sub = (i % SUB) as f64;
    (exp as f64).exp2() * (1.0 + (sub + 0.5) / SUB as f64)
}

/// CAS-loop update of an `AtomicU64` holding `f64` bits.
fn atomic_f64_update(cell: &AtomicU64, f: impl Fn(f64) -> f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let new = f(f64::from_bits(cur)).to_bits();
        match cell.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(prev) => cur = prev,
        }
    }
}

/// Fixed-memory concurrent histogram. All methods take `&self`.
pub struct Hist {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    /// f64 bits of the running sum (exact mean, unlike bucketed moments).
    sum: AtomicU64,
    /// f64 bits of the running sum of squares.
    sum_sq: AtomicU64,
    /// f64 bits of the exact minimum (`+inf` when empty).
    min: AtomicU64,
    /// f64 bits of the exact maximum (`-inf` when empty).
    max: AtomicU64,
}

impl Default for Hist {
    fn default() -> Self {
        Hist::new()
    }
}

impl Hist {
    pub fn new() -> Hist {
        Hist {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0.0f64.to_bits()),
            sum_sq: AtomicU64::new(0.0f64.to_bits()),
            min: AtomicU64::new(f64::INFINITY.to_bits()),
            max: AtomicU64::new(f64::NEG_INFINITY.to_bits()),
        }
    }

    /// Record one sample. Negative or non-finite values clamp to `0.0`.
    pub fn record(&self, v: f64) {
        let v = if v.is_finite() && v >= 0.0 { v } else { 0.0 };
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        atomic_f64_update(&self.sum, |s| s + v);
        atomic_f64_update(&self.sum_sq, |s| s + v * v);
        atomic_f64_update(&self.min, |m| m.min(v));
        atomic_f64_update(&self.max, |m| m.max(v));
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Fold another histogram into this one, bucket-wise.
    pub fn merge(&self, other: &Hist) {
        let n = other.count.load(Ordering::Relaxed);
        if n == 0 {
            return;
        }
        for (b, ob) in self.buckets.iter().zip(other.buckets.iter()) {
            let v = ob.load(Ordering::Relaxed);
            if v != 0 {
                b.fetch_add(v, Ordering::Relaxed);
            }
        }
        let sum = f64::from_bits(other.sum.load(Ordering::Relaxed));
        let sum_sq = f64::from_bits(other.sum_sq.load(Ordering::Relaxed));
        let omin = f64::from_bits(other.min.load(Ordering::Relaxed));
        let omax = f64::from_bits(other.max.load(Ordering::Relaxed));
        atomic_f64_update(&self.sum, |s| s + sum);
        atomic_f64_update(&self.sum_sq, |s| s + sum_sq);
        atomic_f64_update(&self.min, |m| m.min(omin));
        atomic_f64_update(&self.max, |m| m.max(omax));
        self.count.fetch_add(n, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Point-in-time copy. Concurrent recorders may leave the copy a few
    /// samples ahead/behind between fields; quantiles are computed from
    /// the bucket array itself so ordering invariants always hold.
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: f64::from_bits(self.sum.load(Ordering::Relaxed)),
            sum_sq: f64::from_bits(self.sum_sq.load(Ordering::Relaxed)),
            min: f64::from_bits(self.min.load(Ordering::Relaxed)),
            max: f64::from_bits(self.max.load(Ordering::Relaxed)),
        }
    }
}

/// Owned copy of a [`Hist`] with quantile/summary accessors.
#[derive(Clone, Debug)]
pub struct HistSnapshot {
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: f64,
    pub sum_sq: f64,
    pub min: f64,
    pub max: f64,
}

impl HistSnapshot {
    /// Total samples according to the bucket array (authoritative for
    /// quantiles; equals `count` whenever the source was quiescent).
    pub fn total(&self) -> u64 {
        self.buckets.iter().sum()
    }

    pub fn is_empty(&self) -> bool {
        self.total() == 0
    }

    /// Exact mean from the running sum (`None` when empty).
    pub fn mean(&self) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        Some(self.sum / self.count as f64)
    }

    /// Nearest-rank quantile estimate, `q` in `[0, 1]`. Within the
    /// exponent window the estimate is within [`REL_ERROR_BOUND`]
    /// relative error of the exact order statistic.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        let n = self.total();
        if n == 0 {
            return None;
        }
        let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
        let mut cum = 0u64;
        let mut idx = self.buckets.len() - 1;
        for (i, &b) in self.buckets.iter().enumerate() {
            cum += b;
            if cum >= rank {
                idx = i;
                break;
            }
        }
        let mut v = bucket_mid(idx);
        // Exact min/max can only tighten the estimate; skip when the
        // copy raced and they are not yet coherent.
        if self.min <= self.max {
            v = v.clamp(self.min, self.max);
        }
        Some(v)
    }

    /// Bridge to [`Summary`]: exact n/mean/std/min/max, bucketed
    /// p50/p95/p99. `None` when empty.
    pub fn summary(&self) -> Option<Summary> {
        if self.count == 0 || self.is_empty() {
            return None;
        }
        let n = self.count;
        let mean = self.sum / n as f64;
        let var = (self.sum_sq / n as f64 - mean * mean).max(0.0);
        Some(Summary {
            n: n as usize,
            mean,
            std: var.sqrt(),
            min: self.min,
            p50: self.quantile(0.50)?,
            p95: self.quantile(0.95)?,
            p99: self.quantile(0.99)?,
            max: self.max,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn exact_quantile(sorted: &[f64], q: f64) -> f64 {
        let n = sorted.len() as u64;
        let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
        sorted[(rank - 1) as usize]
    }

    fn assert_bound(values: &mut Vec<f64>, label: &str) {
        let h = Hist::new();
        for &v in values.iter() {
            h.record(v);
        }
        values.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let snap = h.snapshot();
        assert_eq!(snap.count, values.len() as u64, "{label}: count");
        assert_eq!(snap.total(), values.len() as u64, "{label}: bucket total");
        for &q in &[0.5, 0.9, 0.95, 0.99, 0.999] {
            let est = snap.quantile(q).unwrap();
            let exact = exact_quantile(values, q);
            let rel = (est - exact).abs() / exact;
            assert!(
                rel <= REL_ERROR_BOUND + 1e-12,
                "{label}: q={q} est={est} exact={exact} rel={rel} > {REL_ERROR_BOUND}"
            );
        }
    }

    #[test]
    fn quantile_error_bound_across_distributions() {
        let n = 20_000;
        let mut rng = Prng::new(0xB0B5);
        let mut uniform: Vec<f64> = (0..n).map(|_| 1e-3 + rng.uniform()).collect();
        assert_bound(&mut uniform, "uniform");
        let mut lognormal: Vec<f64> = (0..n).map(|_| (rng.normal() as f64).exp()).collect();
        assert_bound(&mut lognormal, "lognormal");
        let mut bimodal: Vec<f64> = (0..n)
            .map(|_| {
                if rng.uniform() < 0.7 {
                    1e-3 * (1.0 + rng.uniform())
                } else {
                    10.0 * (1.0 + rng.uniform())
                }
            })
            .collect();
        assert_bound(&mut bimodal, "bimodal");
    }

    #[test]
    fn exact_moments_and_minmax() {
        let h = Hist::new();
        for v in [0.01, 0.015, 0.02] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 3);
        assert!((s.mean().unwrap() - 0.015).abs() < 1e-12);
        assert_eq!(s.min, 0.01);
        assert_eq!(s.max, 0.02);
        let sum: Summary = s.summary().unwrap();
        assert_eq!(sum.n, 3);
        assert!((sum.mean - 0.015).abs() < 1e-12);
        assert!(sum.p50 >= sum.min && sum.p50 <= sum.max);
    }

    #[test]
    fn quantiles_are_monotone_in_q() {
        let mut rng = Prng::new(7);
        let h = Hist::new();
        for _ in 0..5_000 {
            h.record(rng.exponential(2.0));
        }
        let s = h.snapshot();
        let mut prev = 0.0;
        for i in 1..=100 {
            let q = i as f64 / 100.0;
            let v = s.quantile(q).unwrap();
            assert!(v >= prev, "quantile({q}) = {v} < {prev}");
            prev = v;
        }
    }

    #[test]
    fn merge_matches_recording_into_one() {
        let mut rng = Prng::new(42);
        let a = Hist::new();
        let b = Hist::new();
        let whole = Hist::new();
        for i in 0..4_000 {
            let v = rng.exponential(1.0) + 1e-6;
            let half = if i % 2 == 0 { &a } else { &b };
            half.record(v);
            whole.record(v);
        }
        a.merge(&b);
        let (sa, sw) = (a.snapshot(), whole.snapshot());
        assert_eq!(sa.buckets, sw.buckets);
        assert_eq!(sa.count, sw.count);
        assert_eq!(sa.min, sw.min);
        assert_eq!(sa.max, sw.max);
        assert!((sa.sum - sw.sum).abs() < 1e-9 * sw.sum.abs().max(1.0));
        // Merging an empty histogram keeps min/max untouched.
        a.merge(&Hist::new());
        assert_eq!(a.snapshot().min, sw.min);
    }

    #[test]
    fn clamps_out_of_range_samples() {
        let h = Hist::new();
        h.record(-5.0);
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        h.record(0.0);
        h.record(1e300); // above the window: last bucket
        let s = h.snapshot();
        assert_eq!(s.total(), 5);
        assert_eq!(s.buckets[0], 4);
        assert_eq!(s.buckets[BUCKETS - 1], 1);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 1e300);
    }

    #[test]
    fn empty_histogram_reports_none() {
        let s = Hist::new().snapshot();
        assert!(s.is_empty());
        assert!(s.quantile(0.5).is_none());
        assert!(s.mean().is_none());
        assert!(s.summary().is_none());
    }
}
