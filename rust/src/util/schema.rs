//! Structural JSON schema check for bench artifacts.
//!
//! The committed `BENCH_*.json` placeholders double as schemas: field
//! names and nesting are authoritative, `null` leaves mark values that
//! are environment-dependent (numbers/strings measured at bench time).
//! [`check_shape`] verifies a freshly produced document against such a
//! placeholder, so the uploaded artifact cannot silently drift from the
//! committed shape — the bench refuses to overwrite the placeholder
//! with a document whose field names or types changed.
//!
//! Rules:
//! * schema `null` is a scalar wildcard (matches null/number/string/bool)
//! * other scalars must match by kind (number vs number, ...)
//! * arrays: every element of the value must match the schema array's
//!   first element; an empty schema array accepts any array
//! * objects: exactly the same key set, each value checked recursively

use crate::util::json::Json;

fn kind(v: &Json) -> &'static str {
    match v {
        Json::Null => "null",
        Json::Bool(_) => "bool",
        Json::Num(_) => "number",
        Json::Str(_) => "string",
        Json::Arr(_) => "array",
        Json::Obj(_) => "object",
    }
}

/// Check `value` against the placeholder `schema`; `Err` names the path
/// and kind of the first mismatch.
pub fn check_shape(schema: &Json, value: &Json) -> Result<(), String> {
    check_at(schema, value, "$")
}

fn check_at(schema: &Json, value: &Json, path: &str) -> Result<(), String> {
    match (schema, value) {
        (Json::Null, Json::Null | Json::Num(_) | Json::Str(_) | Json::Bool(_)) => Ok(()),
        (Json::Null, _) => Err(format!("{path}: expected a scalar, got {}", kind(value))),
        (Json::Num(_), Json::Num(_)) => Ok(()),
        (Json::Str(_), Json::Str(_)) => Ok(()),
        (Json::Bool(_), Json::Bool(_)) => Ok(()),
        (Json::Arr(s), Json::Arr(vs)) => {
            if let Some(elem) = s.first() {
                for (i, item) in vs.iter().enumerate() {
                    check_at(elem, item, &format!("{path}[{i}]"))?;
                }
            }
            Ok(())
        }
        (Json::Obj(s), Json::Obj(v)) => {
            for key in s.keys() {
                if !v.contains_key(key) {
                    return Err(format!("{path}: missing field '{key}'"));
                }
            }
            for key in v.keys() {
                if !s.contains_key(key) {
                    return Err(format!("{path}: unexpected field '{key}'"));
                }
            }
            for (key, sv) in s {
                check_at(sv, &v[key], &format!("{path}.{key}"))?;
            }
            Ok(())
        }
        _ => Err(format!("{path}: expected {}, got {}", kind(schema), kind(value))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{self, Json};

    fn p(s: &str) -> Json {
        json::parse(s).unwrap()
    }

    #[test]
    fn null_is_a_scalar_wildcard() {
        let schema = p(r#"{"a":null,"b":null}"#);
        assert!(check_shape(&schema, &p(r#"{"a":1.5,"b":"avx2"}"#)).is_ok());
        assert!(check_shape(&schema, &p(r#"{"a":null,"b":true}"#)).is_ok());
        let err = check_shape(&schema, &p(r#"{"a":[1],"b":2}"#)).unwrap_err();
        assert!(err.contains("$.a") && err.contains("scalar"), "{err}");
    }

    #[test]
    fn scalar_kinds_must_match() {
        assert!(check_shape(&p("1"), &p("2.5")).is_ok());
        assert!(check_shape(&p("\"x\""), &p("\"y\"")).is_ok());
        let err = check_shape(&p("1"), &p("\"oops\"")).unwrap_err();
        assert!(err.contains("expected number, got string"), "{err}");
    }

    #[test]
    fn object_key_drift_is_caught_both_ways() {
        let schema = p(r#"{"bench":"x","ms":null}"#);
        let err = check_shape(&schema, &p(r#"{"bench":"x"}"#)).unwrap_err();
        assert!(err.contains("missing field 'ms'"), "{err}");
        let err = check_shape(&schema, &p(r#"{"bench":"x","ms":1,"extra":2}"#)).unwrap_err();
        assert!(err.contains("unexpected field 'extra'"), "{err}");
    }

    #[test]
    fn arrays_check_every_element_against_the_template() {
        let schema = p(r#"[{"model":null,"ms":null}]"#);
        assert!(check_shape(&schema, &p("[]")).is_ok());
        assert!(check_shape(&schema, &p(r#"[{"model":"a","ms":1},{"model":"b","ms":2}]"#)).is_ok());
        let err = check_shape(&schema, &p(r#"[{"model":"a","ms":1},{"model":"b"}]"#)).unwrap_err();
        assert!(err.contains("$[1]") && err.contains("'ms'"), "{err}");
        // empty schema array = unconstrained elements
        assert!(check_shape(&p("[]"), &p("[1,\"two\",{}]")).is_ok());
    }

    #[test]
    fn nested_paths_are_reported() {
        let schema = p(r#"{"shootout":{"shape":{"rows":256},"kernel_ms":{"lut":null}}}"#);
        let doc = p(r#"{"shootout":{"shape":{"rows":128},"kernel_ms":{"lut":[1]}}}"#);
        let err = check_shape(&schema, &doc).unwrap_err();
        assert!(err.contains("$.shootout.kernel_ms.lut"), "{err}");
    }

    /// The committed bench baseline must parse, carry the fields the
    /// bench emits, and accept a document with the bench's exact shape —
    /// `cargo test` catches schema/bench drift without running the bench.
    /// `simd_backend` (and the shootout's `backend`) are an enum:
    /// exactly the names in [`crate::lut::simd::BACKENDS`].
    #[test]
    fn committed_bench_placeholder_matches_the_bench_document_shape() {
        use crate::lut::simd::BACKENDS;
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_e2e_latency.json");
        let text = std::fs::read_to_string(path).expect("committed BENCH_e2e_latency.json");
        let schema = json::parse(&text).expect("placeholder must be valid json");
        // The committed backend fields must be null (unmeasured wildcard)
        // or a member of the documented backend enum — never a free-form
        // string (kernel_parity and the bench dispatch on these names).
        for field in [
            schema.get("simd_backend"),
            schema.get("kernel_shootout").and_then(|s| s.get("backend")),
        ] {
            let field = field.expect("backend fields must exist");
            match field {
                Json::Null => {}
                Json::Str(s) => assert!(
                    BACKENDS.contains(&s.as_str()),
                    "backend '{s}' is not in lut::simd::BACKENDS {BACKENDS:?}"
                ),
                other => panic!("backend field must be null or string, got {other:?}"),
            }
        }
        // The committed gate config must price every non-reference
        // shootout kernel and carry provenance ratios.
        let gate = schema.get("perf_gate").expect("perf_gate section");
        assert_eq!(gate.get("reference").and_then(|v| v.as_str()), Some("lut"));
        for name in ["dense", "dense-i8", "lut-simd", "lut-i8", "lut-dec"] {
            let max = gate.get("max_ratio").and_then(|m| m.get(name)).and_then(|v| v.as_f64());
            let meas =
                gate.get("measured_ratio").and_then(|m| m.get(name)).and_then(|v| v.as_f64());
            let (max, meas) = (
                max.unwrap_or_else(|| panic!("perf_gate.max_ratio.{name} missing")),
                meas.unwrap_or_else(|| panic!("perf_gate.measured_ratio.{name} missing")),
            );
            assert!(max > meas, "{name}: max_ratio {max} must leave slack over measured {meas}");
        }
        // mirror of the document benches/e2e_latency.rs assembles
        let ms = |v: f64| Json::num(v);
        let kernel_ms = |base: f64| {
            Json::obj(vec![
                ("dense", ms(base * 2.0)),
                ("dense-i8", ms(base * 3.5)),
                ("lut", ms(base)),
                ("lut-simd", ms(base * 1.2)),
                ("lut-i8", ms(base * 1.3)),
                ("lut-dec", ms(base * 3.8)),
            ])
        };
        let doc = Json::obj(vec![
            ("bench", Json::str("e2e_latency")),
            ("note", Json::str("measured run")),
            ("simd_backend", Json::str("portable")),
            (
                "kernel_shootout",
                Json::obj(vec![
                    (
                        "shape",
                        Json::obj(vec![
                            ("rows", ms(256.0)),
                            ("d", ms(576.0)),
                            ("m", ms(128.0)),
                            ("k", ms(16.0)),
                            ("v", ms(9.0)),
                        ]),
                    ),
                    ("backend", Json::str("portable")),
                    ("kernel_ms", kernel_ms(0.8)),
                    ("simd_speedup_vs_scalar", ms(1.25)),
                ]),
            ),
            (
                "zoo_geometry_sweep",
                Json::Arr(vec![Json::obj(vec![
                    ("model", Json::str("cnn_tiny")),
                    ("d", ms(288.0)),
                    ("m", ms(32.0)),
                    ("kernel_ms", kernel_ms(0.1)),
                ])]),
            ),
            (
                "profile",
                Json::obj(vec![
                    ("model", Json::str("cnn_tiny")),
                    (
                        "layers",
                        Json::Arr(vec![Json::obj(vec![
                            ("layer", Json::str("c1")),
                            ("kernel", Json::str("lut")),
                            ("wall_ms", ms(1.0)),
                            ("encode_ms", ms(0.6)),
                            ("lookup_ms", ms(0.3)),
                        ])]),
                    ),
                    ("slowest_layer", Json::str("c1")),
                ]),
            ),
            (
                "perf_gate",
                Json::obj(vec![
                    ("enforce_env", Json::str("PERF_GATE")),
                    ("reference", Json::str("lut")),
                    (
                        "max_ratio",
                        Json::obj(vec![
                            ("dense", ms(7.5)),
                            ("dense-i8", ms(13.0)),
                            ("lut-simd", ms(4.5)),
                            ("lut-i8", ms(4.6)),
                            ("lut-dec", ms(14.0)),
                        ]),
                    ),
                    (
                        "measured_ratio",
                        Json::obj(vec![
                            ("dense", ms(2.4)),
                            ("dense-i8", ms(4.2)),
                            ("lut-simd", ms(1.5)),
                            ("lut-i8", ms(1.5)),
                            ("lut-dec", ms(4.6)),
                        ]),
                    ),
                ]),
            ),
            (
                "replica_sweep",
                Json::Arr(vec![Json::obj(vec![
                    ("replicas", ms(1.0)),
                    ("throughput_rps", ms(500.0)),
                    ("speedup_vs_1", ms(1.0)),
                    ("p50_ms", ms(2.0)),
                    ("p95_ms", ms(4.0)),
                ])]),
            ),
            (
                "models",
                Json::Arr(vec![Json::obj(vec![
                    ("model", Json::str("VGG11 (CIFAR10)")),
                    ("engine", Json::str("native")),
                    ("dense_ms", ms(10.0)),
                    ("lut_ms", ms(5.0)),
                ])]),
            ),
        ]);
        check_shape(&schema, &doc).expect("bench document shape drifted from the placeholder");
    }

    /// The committed memory baseline must carry EXACTLY the table bytes
    /// the registry kernels report on the zoo models — the same
    /// accounting `benches/memory_footprint.rs` gates in CI. Table
    /// bytes are pure shape arithmetic, so `cargo test` can pin the
    /// committed numbers bit-exactly on any machine; a drifting
    /// baseline (or a kernel storage regression) fails here before the
    /// bench even runs.
    #[test]
    fn committed_memory_baseline_matches_measured_zoo_table_bytes() {
        use crate::api::{KernelBuildCtx, KernelRegistry};
        use crate::lut::{LutLinear, LutOpts};
        use crate::model_import::zoo;
        use crate::nn::graph::LayerParams;
        use crate::nn::models::pick_v;
        use crate::pq::Codebooks;
        use crate::util::prng::Prng;

        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_memory_footprint.json");
        let text = std::fs::read_to_string(path).expect("committed BENCH_memory_footprint.json");
        let schema = json::parse(&text).expect("baseline must be valid json");
        let models = schema.get("models").and_then(|v| v.as_arr()).expect("baseline models array");
        assert_eq!(models.len(), zoo::MODELS.len(), "one baseline row per zoo model");

        let reg = KernelRegistry::with_defaults();
        let ctx = KernelBuildCtx { opts: LutOpts::deployed() };
        for (zm, row) in zoo::MODELS.iter().zip(models) {
            assert_eq!(row.get("model").and_then(|v| v.as_str()), Some(zm.name));
            let g = zoo::import(zm.name).unwrap();
            let (mut int8, mut dec, mut layers) = (0usize, 0usize, 0usize);
            for (i, params) in g.layers.values().enumerate() {
                let LayerParams::Dense { w, m, .. } = params else { continue };
                layers += 1;
                let (d, m) = (w.len() / m, *m);
                let v = pick_v(d);
                let (c, k) = (d / v, 16usize);
                let mut rng = Prng::new(0xF00D + i as u64);
                let cb = Codebooks::new(c, k, v, rng.normal_vec(c * k * v, 1.0));
                let lut = LayerParams::Lut(LutLinear::new(cb, w, m, None, 8));
                int8 += reg.build("lut-i8", &lut, &ctx).unwrap().table_bytes();
                dec += reg.build("lut-dec", &lut, &ctx).unwrap().table_bytes();
            }
            let get = |k: &str| row.get(k).and_then(|v| v.as_usize()).unwrap_or(usize::MAX);
            assert_eq!(get("dense_layers"), layers, "{}: dense layer count", zm.name);
            assert_eq!(get("int8_table_bytes"), int8, "{}: int8 table bytes", zm.name);
            assert_eq!(get("dec_table_bytes"), dec, "{}: decomposed table bytes", zm.name);
            assert!(
                dec * 2 > int8 && dec < int8,
                "{}: decomposition must shrink tables (towards 2x): {dec} vs {int8}",
                zm.name
            );
        }
    }
}
