//! Property-testing substrate: seeded random case generation with
//! first-failure shrink-lite reporting (proptest is not vendored).
//!
//! Usage:
//! ```ignore
//! prop::check(100, |g| {
//!     let n = g.usize(1..64);
//!     let xs = g.f32_vec(n, 10.0);
//!     // ... assert invariant, return Err(msg) to fail
//!     Ok(())
//! });
//! ```

use super::prng::Prng;

pub struct Gen {
    rng: Prng,
    pub case_seed: u64,
}

impl Gen {
    /// Standalone generator for deterministic one-off cases (fixtures
    /// outside a `check` loop).
    pub fn from_seed(seed: u64) -> Gen {
        Gen { rng: Prng::new(seed), case_seed: seed }
    }

    pub fn usize(&mut self, range: std::ops::Range<usize>) -> usize {
        range.start + self.rng.below(range.end - range.start)
    }

    pub fn f32(&mut self, scale: f32) -> f32 {
        self.rng.normal() * scale
    }

    pub fn f32_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        self.rng.normal_vec(n, scale)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Run `cases` random cases of property `f`. Panics with the seed of the
/// first failing case so it can be replayed with `check_one`.
pub fn check<F>(cases: usize, f: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    check_seeded(0xC0FFEE, cases, f)
}

pub fn check_seeded<F>(seed: u64, cases: usize, f: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B9);
        let mut g = Gen { rng: Prng::new(case_seed), case_seed };
        if let Err(msg) = f(&mut g) {
            panic!(
                "property failed on case {case} (replay: check_one({case_seed:#x})): {msg}"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn check_one<F>(case_seed: u64, f: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let mut g = Gen { rng: Prng::new(case_seed), case_seed };
    if let Err(msg) = f(&mut g) {
        panic!("property failed (seed {case_seed:#x}): {msg}");
    }
}

/// Assert two f32 slices are element-wise close.
pub fn assert_close(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol {
            return Err(format!(
                "elem {i}: {x} vs {y} (|diff|={} > tol={tol})",
                (x - y).abs()
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(50, |g| {
            let n = g.usize(1..100);
            let xs = g.f32_vec(n, 1.0);
            if xs.len() == n {
                Ok(())
            } else {
                Err("len".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(50, |g| {
            let n = g.usize(1..100);
            if n < 90 {
                Ok(())
            } else {
                Err(format!("n={n}"))
            }
        });
    }

    #[test]
    fn assert_close_works() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0001], 1e-3, 1e-3).is_ok());
        assert!(assert_close(&[1.0], &[2.0], 1e-3, 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-3, 1e-3).is_err());
    }
}
