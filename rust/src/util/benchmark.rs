//! Criterion-less micro-benchmark harness.
//!
//! Warmup + timed iterations with per-iteration wall-clock sampling,
//! producing a `stats::Summary`. Benches (one per paper table/figure)
//! print aligned tables and append machine-readable JSON lines to
//! `results/bench.jsonl` so EXPERIMENTS.md can be regenerated.

use std::time::{Duration, Instant};

use super::json::Json;
use super::stats::Summary;

pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub target_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 2000,
            target_time: Duration::from_millis(700),
        }
    }
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary, // seconds per iteration
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.summary.mean * 1e3
    }
    pub fn mean_us(&self) -> f64 {
        self.summary.mean * 1e6
    }
}

/// Run `f` repeatedly; each call is one sample. Returns per-iter stats.
pub fn bench<F: FnMut()>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.min_iters);
    let start = Instant::now();
    while samples.len() < cfg.max_iters
        && (samples.len() < cfg.min_iters || start.elapsed() < cfg.target_time)
    {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), summary: Summary::of(&samples) }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Append a JSON line describing a bench row to `results/bench.jsonl`.
pub fn record_jsonl(bench_file: &str, row: &Json) {
    use std::io::Write;
    let _ = std::fs::create_dir_all("results");
    if let Ok(mut f) = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(format!("results/{bench_file}"))
    {
        let _ = writeln!(f, "{}", super::json::to_string(row));
    }
}

/// Fixed-width table printer for bench output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!(
            "{}",
            widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("  ")
        );
        for row in &self.rows {
            line(row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let cfg = BenchConfig {
            warmup_iters: 1,
            min_iters: 5,
            max_iters: 5,
            target_time: Duration::from_millis(1),
        };
        let r = bench("spin", &cfg, || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert_eq!(r.summary.n, 5);
        assert!(r.summary.mean > 0.0);
        assert!(r.summary.min <= r.summary.p50);
        assert!(r.summary.p50 <= r.summary.max);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["op", "ms"]);
        t.row(&["conv1".into(), "1.25".into()]);
        t.print(); // visual; just ensure no panic
    }
}
