//! Summary statistics for latency/throughput measurements.

/// Percentile/mean summary of a sample of durations (or any f64 values).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(values: &[f64]) -> Summary {
        assert!(!values.is_empty(), "Summary::of on empty sample");
        let mut sorted: Vec<f64> = values.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
            / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
            p99: percentile(&sorted, 0.99),
            max: sorted[n - 1],
        }
    }
}

/// Linear-interpolated percentile of an already-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean (used for "avg speedup" rows as the paper does).
pub fn geomean(values: &[f64]) -> f64 {
    assert!(!values.is_empty());
    (values.iter().map(|v| v.max(1e-300).ln()).sum::<f64>()
        / values.len() as f64)
        .exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert_eq!(percentile(&v, 0.5), 5.0);
        assert_eq!(percentile(&v, 0.0), 0.0);
        assert_eq!(percentile(&v, 1.0), 10.0);
    }

    #[test]
    fn unordered_input() {
        let s = Summary::of(&[5.0, 1.0, 3.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn geomean_of_speedups() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[1.0]) - 1.0).abs() < 1e-12);
    }
}
